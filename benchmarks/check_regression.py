"""CI throughput-regression gate.

Compares a fresh ``BENCH_*.json`` (written by ``benchmarks.run --json`` /
``bench_throughput.main``) against the committed ``BENCH_baseline.json`` and
fails when a guarded metric regresses by more than ``--tolerance`` (default
30%).

Guarded metrics are RELATIVE speedups (v2-codec vs legacy on the same data,
parallel vs serial on the same machine), not absolute MB/s: CI runners and
dev machines differ wildly in absolute throughput, but a relative speedup
collapsing by a third means the optimized path itself regressed.  The
``QUALITY_GATES`` list additionally holds ABSOLUTE pass/fail criteria on
the candidate alone — data-deterministic ratios/bounds, plus a few MB/s
floors set far enough under any plausible runner that only an
order-of-magnitude collapse trips them.

Usage:
    python -m benchmarks.check_regression BENCH_baseline.json BENCH_new.json
"""
from __future__ import annotations

import argparse
import json
import sys

#: (path into the perf dict, human label); each must stay >= (1-tol) * baseline
GUARDED = [
    (("huffman", "speedup_enc"), "Huffman encode speedup (v2 vs legacy)"),
    (("huffman", "speedup_encdec"), "Huffman enc+dec speedup (v2 vs legacy)"),
    (("chunked_workers", "speedup_w4_vs_pr1"), "chunked w4 vs PR1-equivalent"),
    (("chunked_workers", "speedup_w2_vs_w1"), "chunked w2 vs w1"),
    # transform subsystem: rate-distortion advantage on its home workload
    # (data-deterministic ratio quotient, not MB/s — machine independent)
    (("transform", "ratio_vs_lorenzo"), "transform ratio advantage vs Lorenzo (oscillatory)"),
    (("transform", "bound_ok"), "transform round-trip within error bound"),
]

#: ABSOLUTE gates on the candidate artifact (no baseline needed — the bench
#: fixtures are seed-deterministic, so these are pass/fail criteria, not
#: machine-relative speedups).  (path, label, check(value, perf) -> ok)
QUALITY_GATES = [
    (
        ("quality", "achieved_psnr"),
        "quality-targeted achieved PSNR within [target-1, target+1] dB",
        lambda v, perf: perf["quality"]["target_psnr"] - 1.0
        <= v
        <= perf["quality"]["target_psnr"] + 1.0,
    ),
    (
        ("quality", "pwr_bound_ok"),
        "pointwise-relative bound holds for every nonzero element",
        lambda v, perf: v >= 1.0,
    ),
    (
        ("quality", "pwr_zeros_exact"),
        "pointwise-relative zeros reconstruct exactly",
        lambda v, perf: v >= 1.0,
    ),
    # block-hybrid engine (PR5): per-block selection must strictly beat the
    # best single-predictor pipeline on the mixed-regime fixture, with the
    # ABS bound verified pointwise — both data-deterministic (fixed seed)
    (
        ("hybrid", "ratio_vs_best_single"),
        "hybrid ratio strictly better than best single-predictor pipeline",
        lambda v, perf: v > 1.0,
    ),
    (
        ("hybrid", "bound_ok"),
        "hybrid round-trip within the ABS bound pointwise",
        lambda v, perf: v >= 1.0,
    ),
    # fast tier (PR6): fixed-length coding must stay >= 5x faster than the
    # chunked Lorenzo pipeline at the same ABS bound (machine-relative, both
    # measured in the same run), with the bound verified pointwise
    (
        ("fast", "speedup_vs_chunked"),
        "fast tier >= 5x chunked-Lorenzo compress at the same ABS bound",
        lambda v, perf: v >= 5.0,
    ),
    (
        ("fast", "bound_ok"),
        "fast tier round-trip within the ABS bound pointwise",
        lambda v, perf: v >= 1.0,
    ),
    # absolute MB/s floors: measured 156 / 21 MB/s idle on the dev container
    # and 69 / 10 MB/s under full CPU contention — floors sit well under the
    # contended numbers so slow CI runners pass while an order-of-magnitude
    # collapse (e.g. an accidental float64 temp on the fast path, measured
    # at 34 MB/s) still fails loudly
    (
        ("fast", "fast_compress_MBps"),
        "fast tier absolute compress throughput floor (40 MB/s)",
        lambda v, perf: v >= 40.0,
    ),
    (
        ("chunked_workers", "compress_MBps_w1"),
        "chunked engine absolute compress throughput floor (4 MB/s)",
        lambda v, perf: v >= 4.0,
    ),
    # integrity layer (PR7): checksum trailers + strict verification must
    # cost < 5% on both the chunked tier (many per-chunk CRCs) and the fast
    # tier (throughput-critical, fixed costs loom largest).  Both timings in
    # each pair come from the same run on the same machine, so the ratio is
    # machine-independent; best-of-3 timing keeps jitter under the gate.
    (
        ("integrity", "chunked", "compress_overhead_pct"),
        "integrity trailer compress overhead < 5% (chunked tier)",
        lambda v, perf: v < 5.0,
    ),
    (
        ("integrity", "chunked", "verify_overhead_pct"),
        "strict-verify decompress overhead < 5% (chunked tier)",
        lambda v, perf: v < 5.0,
    ),
    (
        ("integrity", "fast", "compress_overhead_pct"),
        "integrity trailer compress overhead < 5% (fast tier)",
        lambda v, perf: v < 5.0,
    ),
    (
        ("integrity", "fast", "verify_overhead_pct"),
        "strict-verify decompress overhead < 5% (fast tier)",
        lambda v, perf: v < 5.0,
    ),
    # telemetry spine (PR8): stage spans + decision records must be free
    # when no trace is active (< 1%) and cheap when one is (< 5%), on both
    # the chunked tier (many spans, one decision per chunk) and the fast
    # tier.  Same isolated-added-work methodology as the integrity gates —
    # per-event cost times the event count one compress emits, against the
    # untraced compress timing — so the ratio is machine-independent.
    (
        ("telemetry", "chunked", "overhead_off_pct"),
        "telemetry disabled-path overhead < 1% (chunked tier)",
        lambda v, perf: v < 1.0,
    ),
    (
        ("telemetry", "chunked", "overhead_on_pct"),
        "telemetry traced-path overhead < 5% (chunked tier)",
        lambda v, perf: v < 5.0,
    ),
    (
        ("telemetry", "fast", "overhead_off_pct"),
        "telemetry disabled-path overhead < 1% (fast tier)",
        lambda v, perf: v < 1.0,
    ),
    (
        ("telemetry", "fast", "overhead_on_pct"),
        "telemetry traced-path overhead < 5% (fast tier)",
        lambda v, perf: v < 5.0,
    ),
    # in-training compression (PR10): the compressed DP reduction schedule
    # must cut collective bytes >= 1.3x vs a bf16 all-reduce at int8 (the
    # byte model is exact arithmetic — machine-independent), the jit codec's
    # per-block bound must hold pointwise on the gradient fixture, and error
    # feedback must keep the time-averaged dequantized gradient within 1% of
    # the true one (what the >=20-step trajectory parity rests on)
    (
        ("grad", "collective_cut_int8"),
        "compressed DP reduction cuts collective bytes >= 1.3x at int8",
        lambda v, perf: v >= 1.3,
    ),
    (
        ("grad", "bound_ok"),
        "jit codec per-block bound holds pointwise on gradient fixture",
        lambda v, perf: v >= 1.0,
    ),
    (
        ("grad", "feedback_avg_err"),
        "error-feedback time-average gradient error < 0.01",
        lambda v, perf: v < 0.01,
    ),
    # elastic chunk-range restore (PR10): a quarter-leaf read must decode
    # well under the full container (strictly fewer bytes with margin) and
    # match the full decode's rows exactly
    (
        ("elastic", "quarter_read_frac"),
        "chunk-range quarter read decodes < 60% of container bytes",
        lambda v, perf: v < 0.6,
    ),
    (
        ("elastic", "range_values_exact"),
        "chunk-range rows identical to full decode",
        lambda v, perf: v >= 1.0,
    ),
    # serving layer (PR9): the decode-state cache must buy >= 5x p99 latency
    # on repeated random-access chunk fetches vs the uncached path (both
    # timed in the same run on the same machine — machine-independent), the
    # steady-state hit rate of the service workload must stay >= 90%, and
    # concurrent/coalesced fetch results must be byte-identical to serial
    # (1.0 rows — any mismatch is a correctness failure, not a perf one)
    (
        ("serving", "p99_speedup_cached"),
        "serving cached random-access p99 >= 5x uncached",
        lambda v, perf: v >= 5.0,
    ),
    (
        ("serving", "cache_hit_rate"),
        "serving steady-state chunk-cache hit rate >= 90%",
        lambda v, perf: v >= 0.90,
    ),
    (
        ("serving", "concurrent_byte_identical"),
        "4-worker concurrent fetches byte-identical to serial",
        lambda v, perf: v >= 1.0,
    ),
    (
        ("serving", "coalesced_equal"),
        "coalesced-batch fetch results equal unbatched",
        lambda v, perf: v >= 1.0,
    ),
    # generous absolute ceiling: a request through the full async path
    # (queue + coalesce + pool + strict CRC) collapsing past 250 ms p99 on
    # any plausible runner means the serving path itself broke
    (
        ("serving", "service_p99_ms"),
        "service request p99 under 250 ms",
        lambda v, perf: v < 250.0,
    ),
]


def _perf_of(doc):
    """Accept either a bare perf dict, a bench_throughput result, or a
    ``benchmarks.run --json`` artifact (perf under the throughput row)."""
    if "perf" in doc:
        return doc["perf"]
    if "huffman" in doc:
        return doc
    for row in doc.get("results", []):
        derived = row.get("derived")
        if isinstance(derived, dict) and "perf" in derived:
            return derived["perf"]
    raise SystemExit("no throughput perf section found in artifact")


def _get(perf, path):
    cur = perf
    for key in path:
        if not isinstance(cur, dict) or key not in cur:
            return None
        cur = cur[key]
    return float(cur)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline")
    ap.add_argument("candidate")
    ap.add_argument("--tolerance", type=float, default=0.30)
    args = ap.parse_args(argv)
    with open(args.baseline) as f:
        base = _perf_of(json.load(f))
    with open(args.candidate) as f:
        cand = _perf_of(json.load(f))
    backends = (base.get("lossless_backend"), cand.get("lossless_backend"))
    backend_mismatch = backends[0] != backends[1]
    if backend_mismatch:
        print(
            f"lossless backend differs (baseline={backends[0]}, candidate="
            f"{backends[1]}): engine-level ratios include the lossless "
            "stage's runtime share — chunked rows compared at 2x tolerance"
        )
    failures = []
    cand_cores = int(cand.get("cpu_count") or 0)
    for path, label in GUARDED:
        tol = args.tolerance
        if backend_mismatch and path[0] == "chunked_workers":
            tol = min(0.9, 2.0 * tol)
        if path[-1].startswith("speedup_w") and 0 < cand_cores < 2:
            # thread scaling is physically impossible on a 1-core box; the
            # metric measures the machine, not the code, so gating it there
            # would only ever report false regressions
            print(f"SKIP {label}: candidate ran on a single core")
            continue
        b, c = _get(base, path), _get(cand, path)
        if b is None or c is None:
            print(f"SKIP {label}: metric missing (baseline={b}, candidate={c})")
            continue
        floor = b * (1.0 - tol)
        status = "ok" if c >= floor else "REGRESSION"
        print(f"{status:10s} {label}: baseline {b:.2f} candidate {c:.2f} floor {floor:.2f}")
        if c < floor:
            failures.append(label)
    quality_failures = []
    for path, label, check in QUALITY_GATES:
        c = _get(cand, path)
        if c is None:
            print(f"SKIP {label}: metric missing from candidate")
            continue
        ok = check(c, cand)
        print(f"{'ok' if ok else 'FAILED':10s} {label}: candidate {c:.2f}")
        if not ok:
            quality_failures.append(label)
    if failures:
        print(f"FAILED: {len(failures)} metric(s) regressed >30%: {failures}")
    if quality_failures:
        print(
            f"FAILED: {len(quality_failures)} absolute quality criteria not "
            f"met: {quality_failures}"
        )
    if failures or quality_failures:
        return 1
    print("throughput regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
