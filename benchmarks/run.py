"""Benchmark registry — one entry per paper table/figure + the framework
integration benches + the roofline reader.  Prints ``name,us_per_call,
derived`` CSV lines per the harness contract; detailed per-bench output goes
to stdout above each summary line.

  PYTHONPATH=src python -m benchmarks.run            # reduced sizes
  PYTHONPATH=src python -m benchmarks.run --full     # paper-scale sizes
  PYTHONPATH=src python -m benchmarks.run --only gamess
"""
from __future__ import annotations

import argparse
import json
import time
import traceback


def _timed(name, fn, full):
    t0 = time.perf_counter()
    try:
        derived = fn(full)
        dt = (time.perf_counter() - t0) * 1e6
        print(f"{name},{dt:.0f},ok")
        return {"name": name, "us": dt, "status": "ok", "derived": derived}
    except Exception as e:
        dt = (time.perf_counter() - t0) * 1e6
        traceback.print_exc()
        print(f"{name},{dt:.0f},FAILED:{e}")
        return {"name": name, "us": dt, "status": f"FAILED:{e}", "derived": None}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument(
        "--json",
        default=None,
        metavar="PATH",
        help="write results (name/us/status/derived rows) to a JSON artifact",
    )
    args = ap.parse_args()

    from . import (
        bench_aps,
        bench_chunked,
        bench_gamess,
        bench_integrations,
        bench_pipelines,
        bench_sustainability,
        bench_throughput,
        roofline,
    )

    benches = {
        "gamess_table1_fig4": bench_gamess.main,  # paper Table 1 + Fig 4
        "aps_fig6": bench_aps.main,  # paper Fig 6
        "pipelines_fig7": bench_pipelines.main,  # paper Fig 7
        "throughput_fig8": bench_throughput.main,  # paper Fig 8
        "chunked_streaming": bench_chunked.main,  # chunked engine vs one-shot
        "sustainability_s6_1": bench_sustainability.main,  # paper §6.1/Table 2
        "integrations": bench_integrations.main,  # beyond-paper (grad/kv/opt/ckpt)
        "roofline": roofline.main,  # deliverable (g)
    }
    print("name,us_per_call,derived")
    results = []
    for name, fn in benches.items():
        if args.only and args.only not in name:
            continue
        results.append(_timed(name, fn, args.full))
    if args.json:
        from repro.core import lossless

        doc = {
            "full": args.full,
            "lossless_backend": lossless.effective_backend("zstd"),
            "results": results,
        }
        with open(args.json, "w") as f:
            json.dump(doc, f, default=str, indent=1)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
