"""Paper §6.1 (Table 2): sustainability comparison.

SZ2 needs >120 functions because it specializes (datatype x dimensionality x
direction) by hand.  SZ3's abstractions (datatype templates, the
multidimensional iterator, compile-time composition) collapse that.  This
benchmark *measures* the same claim on this repo: module counts, LoC per
module, and the implied SZ2-style expansion factor (how many hand-written
functions the composition machinery replaces), plus integration overhead
(bytes of glue per pipeline — the compose functions in pipeline.py).
"""
from __future__ import annotations

import ast
import inspect
from pathlib import Path

SRC = Path(__file__).resolve().parent.parent / "src" / "repro" / "core"

DTYPES = 10  # FP32/64, (U)INT8/16/32/64 — paper Table 2
DIMS = 4
DIRECTIONS = 2


def module_stats():
    rows = []
    for f in sorted(SRC.glob("*.py")):
        tree = ast.parse(f.read_text())
        funcs = [n for n in ast.walk(tree) if isinstance(n, (ast.FunctionDef,))]
        classes = [n for n in ast.walk(tree) if isinstance(n, ast.ClassDef)]
        loc = len(f.read_text().splitlines())
        rows.append(
            {
                "module": f.name,
                "loc": loc,
                "classes": len(classes),
                "functions": len(funcs),
            }
        )
    return rows


def expansion_factor():
    """Pipeline instances composable from the registered modules vs the
    SZ2-style per-(dtype x dim x direction) hand specialization count."""
    from repro.core import encoders, lossless, predictors, preprocess, quantizers

    n_pre = len(preprocess._REGISTRY)
    n_pred = len(predictors._REGISTRY)
    n_quant = len(quantizers._REGISTRY)
    n_enc = len(encoders._REGISTRY)
    n_ll = len(lossless._REGISTRY)
    composable = n_pre * n_pred * n_quant * n_enc * n_ll
    sz2_style = composable * DTYPES * DIMS * DIRECTIONS
    return {
        "modules": {
            "preprocessors": n_pre,
            "predictors": n_pred,
            "quantizers": n_quant,
            "encoders": n_enc,
            "lossless": n_ll,
        },
        "composable_pipelines": composable,
        "sz2_style_function_count": sz2_style,
        "actual_driver_loc": _driver_loc(),
    }


def _driver_loc():
    from repro.core import pipeline

    return len(inspect.getsource(pipeline).splitlines())


def main(full: bool = False):
    rows = module_stats()
    print("module,loc,classes,functions")
    total = 0
    for r in rows:
        total += r["loc"]
        print(f"{r['module']},{r['loc']},{r['classes']},{r['functions']}")
    exp = expansion_factor()
    print(f"TOTAL core loc,{total},,")
    print(
        f"composable_pipelines,{exp['composable_pipelines']},"
        f"sz2_style_functions,{exp['sz2_style_function_count']}"
    )
    return {"modules": rows, "expansion": exp}


if __name__ == "__main__":
    main()
