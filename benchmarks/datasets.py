"""Synthetic analogues of the paper's evaluation datasets.

The real GAMESS ERI / APS ptychography / SDRBench fields are not
redistributable offline, so each generator reproduces the *structural
characteristics the paper's method exploits*, with knobs calibrated so the
qualitative orderings of the paper hold (pattern periodicity & scale decay
for GAMESS [§4.1]; photon-count Poisson stacks with strong temporal and weak
spatial correlation for APS [§5.1]; smooth multi-scale Gaussian random fields
with domain-appropriate spectra for the 8-dataset table [§6.2 Table 3]).
Every generator is deterministic in (seed, size).
"""
from __future__ import annotations

import numpy as np


def gamess_eri(
    n_blocks: int = 20000,
    pattern: int = 96,
    unpred_frac: float = 0.15,
    eb: float = 1e-10,
    seed: int = 7,
    dtype=np.float64,
) -> np.ndarray:
    """Two-electron repulsion integral stream: periodic pattern scaled per
    block (SZ-Pastri's premise).  Residuals after scaled-pattern prediction
    are calibrated against the target error bound so the quantization-
    integer statistics match paper Fig 3: a zero-centred population of
    predictable codes plus ~15-20% heavy-tail points outside the range
    ("a significant percentage (20%) ... fall out of the quantization
    range"), which is exactly the regime the unpred-aware quantizer (§4.2)
    attacks."""
    rng = np.random.default_rng(seed)
    t = np.linspace(0, 1, pattern)
    base = np.exp(-6 * t) * np.sin(24 * t) + 0.3 * np.exp(-9 * t) * np.cos(53 * t)
    scales = np.exp(rng.normal(-6.0, 2.5, n_blocks))  # log-normal magnitudes
    x = scales[:, None] * base[None, :]
    # predictable residuals: a few quantization bins wide
    x = x + rng.normal(0.0, 15.0 * eb, (n_blocks, pattern))
    # non-conforming blocks: integrals whose shell shape breaks the pattern
    # (block-level, as in real ERI tiles) -> their points fall out of the
    # quantization range but keep smooth structure the bitplane encoding
    # exploits (paper §4.2)
    bad = rng.random(n_blocks) < unpred_frac
    alt = np.exp(-3 * t) * np.cos(31 * t + 0.7)
    alt_scales = np.exp(rng.normal(-9.0, 1.5, n_blocks))
    x[bad] += alt_scales[bad, None] * alt[None, :]
    return np.ascontiguousarray(x.reshape(-1).astype(dtype))


def aps_ptycho(
    frames: int = 400, h: int = 64, w: int = 64, seed: int = 11
) -> np.ndarray:
    """X-ray diffraction stack: integer photon counts, bright central speckle,
    high correlation along time (scan positions move slowly), low spatial
    correlation — the regime where the paper's transposed-1D pipeline wins."""
    rng = np.random.default_rng(seed)
    yy, xx = np.mgrid[0:h, 0:w]
    r2 = ((yy - h / 2) ** 2 + (xx - w / 2) ** 2) / (0.08 * h * w)
    envelope = 40.0 * np.exp(-r2)
    # slowly-drifting speckle field -> temporal correlation
    phase = rng.standard_normal((h, w))
    drift = rng.standard_normal((h, w)) * 0.05
    out = np.empty((frames, h, w), np.float32)
    for t in range(frames):
        speckle = np.abs(np.fft.ifft2(np.fft.fft2(np.exp(1j * (phase + t * drift))) * np.exp(-r2)))
        lam = envelope * (0.2 + speckle / max(1e-9, speckle.max()))
        out[t] = rng.poisson(lam).astype(np.float32)
    return out


def _gaussian_random_field(shape, slope: float, seed: int) -> np.ndarray:
    """FFT-synthesized field with power-law spectrum k^-slope."""
    rng = np.random.default_rng(seed)
    white = rng.standard_normal(shape)
    f = np.fft.fftn(white)
    k = np.zeros(shape)
    for ax, n in enumerate(shape):
        kk = np.fft.fftfreq(n) * n
        sh = [1] * len(shape)
        sh[ax] = n
        k = k + (kk.reshape(sh)) ** 2
    k = np.sqrt(np.maximum(k, 1e-9))
    f = f * k ** (-slope / 2.0)
    out = np.real(np.fft.ifftn(f))
    out = (out - out.mean()) / (out.std() + 1e-12)
    return out.astype(np.float32)


DOMAIN_FIELDS = {
    # name: (shape, spectral slope, post)
    "hacc_vx": ((64, 128, 128), 1.2, "none"),  # cosmology particle velocity
    "atm_t2m": ((512, 1024), 2.8, "none"),  # climate 2-D, very smooth
    "hurricane_p": ((48, 128, 128), 2.2, "none"),
    "nyx_rho": ((96, 96, 96), 1.8, "exp"),  # density: log-normal-ish
    "scale_qv": ((48, 160, 160), 2.4, "relu"),  # moisture: nonneg, sharp
    "qmcpack_o": ((24, 48, 48, 48), 1.6, "none"),  # 4-D orbital
    "rtm_wave": ((96, 96, 96), 1.4, "wave"),  # seismic wavefield
    "miranda_u": ((96, 128, 128), 2.0, "none"),  # turbulence
}


def domain_field(name: str, seed: int = 3) -> np.ndarray:
    shape, slope, post = DOMAIN_FIELDS[name]
    x = _gaussian_random_field(shape, slope, seed + hash(name) % 1000)
    if post == "exp":
        x = np.exp(1.5 * x).astype(np.float32)
    elif post == "relu":
        x = np.maximum(x, 0).astype(np.float32)
    elif post == "wave":
        t = np.linspace(0, 6 * np.pi, shape[0], dtype=np.float32)
        x = (x * np.sin(t)[:, None, None]).astype(np.float32)
    return x


def all_domain_fields(seed: int = 3):
    return {k: domain_field(k, seed) for k in DOMAIN_FIELDS}
