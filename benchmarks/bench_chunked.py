"""Chunked engine vs one-shot pipelines: ratio parity + streaming throughput.

Three questions, per field:
  1. does per-chunk adaptive selection match (or beat) the best one-shot
     pipeline's ratio at the same bound?  (acceptance: within +-5% on the
     GAMESS-like stream at abs eb 1e-3)
  2. what does chunking cost/gain in compress+decompress MB/s?
  3. does the frame stream round-trip with the error bound intact?
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (
    ChunkedCompressor,
    CompressionConfig,
    ErrorBoundMode,
    compress_stream,
    decompress,
    decompress_stream,
    sz3_lorenzo,
    sz3_lr,
)

from . import datasets


def _bench_one(name, data, conf, chunk_bytes):
    rows = []
    abs_eb = conf.resolve_abs_eb(
        float(data.max() - data.min()), float(np.abs(data).max())
    )
    for cname, comp in [
        ("one-shot SZ3-LR", sz3_lr()),
        ("one-shot SZ3-Lorenzo", sz3_lorenzo()),
        ("chunked-adaptive", ChunkedCompressor(chunk_bytes=chunk_bytes)),
    ]:
        t0 = time.perf_counter()
        res = comp.compress(data, conf)
        c_dt = time.perf_counter() - t0
        t0 = time.perf_counter()
        xhat = decompress(res.blob)
        d_dt = time.perf_counter() - t0
        err = float(np.abs(data.astype(np.float64) - xhat.astype(np.float64)).max())
        rows.append(
            {
                "field": name,
                "engine": cname,
                "ratio": round(res.ratio, 3),
                "compress_MBps": round(data.nbytes / 1e6 / c_dt, 1),
                "decompress_MBps": round(data.nbytes / 1e6 / d_dt, 1),
                "max_err": err,
                "bound_ok": bool(err <= abs_eb * (1 + 1e-12)),
            }
        )
    # streaming path: frames produced/consumed one chunk at a time
    t0 = time.perf_counter()
    n_out = 0
    frames = []
    for frame in compress_stream(data, conf, chunk_bytes=chunk_bytes):
        n_out += len(frame)
        frames.append(frame)
    parts = [p for p in decompress_stream(frames)]
    s_dt = time.perf_counter() - t0
    xs = np.concatenate([np.atleast_1d(p) for p in parts]).reshape(data.shape)
    err = float(np.abs(data.astype(np.float64) - xs.astype(np.float64)).max())
    rows.append(
        {
            "field": name,
            "engine": "chunked-stream(rt)",
            "ratio": round(data.nbytes / max(1, n_out), 3),
            "compress_MBps": round(data.nbytes / 1e6 / s_dt, 1),
            "decompress_MBps": float("nan"),
            "max_err": err,
            "bound_ok": bool(err <= abs_eb * (1 + 1e-12)),
        }
    )
    return rows


def run(full: bool = False, chunk_bytes: int = 1 << 22):
    n_blocks = 20000 if full else 4000
    shape = (192, 192, 192) if full else (96, 96, 96)
    fields = {
        "gamess_eri": (
            datasets.gamess_eri(n_blocks=n_blocks),
            CompressionConfig(mode=ErrorBoundMode.ABS, eb=1e-3),
        ),
        "miranda_u": (
            datasets.domain_field("miranda_u")[tuple(slice(0, s) for s in shape)],
            CompressionConfig(mode=ErrorBoundMode.REL, eb=1e-3),
        ),
    }
    rows = []
    for name, (data, conf) in fields.items():
        rows += _bench_one(name, np.ascontiguousarray(data), conf, chunk_bytes)
    return rows


def main(full: bool = False):
    rows = run(full)
    print("field,engine,ratio,compress_MBps,decompress_MBps,max_err,bound_ok")
    for r in rows:
        print(
            f"{r['field']},{r['engine']},{r['ratio']},{r['compress_MBps']},"
            f"{r['decompress_MBps']},{r['max_err']:.3e},{r['bound_ok']}"
        )
    return rows


if __name__ == "__main__":
    main(True)
