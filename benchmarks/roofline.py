"""Roofline table builder (deliverable g): reads results/dryrun/*/*.json and
emits the per-(arch x shape x mesh) three-term table + bottleneck + the
MODEL_FLOPS/HLO_FLOPs useful-compute ratio, as markdown and CSV."""
from __future__ import annotations

import json
from pathlib import Path

DEFAULT_DIR = Path("results/dryrun")


def load(results_dir=DEFAULT_DIR):
    rows = []
    for mesh_dir in sorted(Path(results_dir).glob("*")):
        for f in sorted(mesh_dir.glob("*.json")):
            if f.name.endswith(".error.json"):
                rows.append(
                    {
                        "file": str(f),
                        "arch": f.stem.split("__")[0],
                        "shape": f.stem.split("__")[1].replace(".error", ""),
                        "mesh": mesh_dir.name,
                        "status": "ERROR",
                    }
                )
                continue
            d = json.loads(f.read_text())
            if "skipped" in d:
                rows.append(
                    {
                        "arch": d["arch"],
                        "shape": d["shape"],
                        "mesh": mesh_dir.name,
                        "status": "SKIP",
                        "note": d["skipped"][:40],
                    }
                )
                continue
            r = d["roofline"]
            tag = ""
            parts = f.stem.split("__")
            if len(parts) > 2:
                tag = parts[2]
            rows.append(
                {
                    "arch": d["arch"],
                    "shape": d["shape"],
                    "mesh": mesh_dir.name,
                    "tag": tag,
                    "status": "OK",
                    "compute_s": r["compute_s"],
                    "memory_s": r["memory_s"],
                    "collective_s": r["collective_s"],
                    "bottleneck": r["bottleneck"],
                    "useful_ratio": r["useful_flops_ratio"],
                    "compile_s": d["timing"]["compile_s"],
                    "temp_gb": d["memory_analysis"].get("temp_size_in_bytes", 0) / 1e9,
                }
            )
    return rows


def markdown(rows) -> str:
    hdr = (
        "| arch | shape | mesh | tag | compute_s | memory_s | collective_s | "
        "bottleneck | useful FLOPs ratio |\n|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for r in rows:
        if r["status"] != "OK":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | | — | — | — | "
                f"{r['status']} | {r.get('note','')} |"
            )
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r.get('tag','')} | "
            f"{r['compute_s']:.4f} | {r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"**{r['bottleneck']}** | {r['useful_ratio']:.3f} |"
        )
    return hdr + "\n".join(lines)


def main(full: bool = False):
    rows = load()
    print("arch,shape,mesh,tag,status,compute_s,memory_s,collective_s,bottleneck")
    for r in rows:
        if r["status"] == "OK":
            print(
                f"{r['arch']},{r['shape']},{r['mesh']},{r.get('tag','')},OK,"
                f"{r['compute_s']:.5f},{r['memory_s']:.5f},{r['collective_s']:.5f},{r['bottleneck']}"
            )
        else:
            print(f"{r['arch']},{r['shape']},{r['mesh']},,{r['status']},,,,")
    return rows


if __name__ == "__main__":
    main()
