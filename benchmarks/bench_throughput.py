"""Paper Fig 8: compression/decompression throughput (MB/s) at REL eb=1e-3.

Includes the device-kernel path (dual-quant Lorenzo via the Pallas ops in
interpret mode on CPU; compiled on real TPUs) alongside the host pipelines,
which is this repo's analogue of the paper's SZ3-LR-s speed-oriented build.

PR2 additions (``perf_rows``): before/after rows for the word-packed Huffman
codec (v2 vs the retained legacy implementation, same data) and for the
parallel chunked engine (workers=1/2/4, plus the combined delta vs the
PR1-equivalent serial+legacy configuration).  ``main`` writes the combined
result to a ``BENCH_*.json`` at the repo root so the perf trajectory is
recorded per change; ``benchmarks/check_regression.py`` diffs the relative
speedups against the committed ``BENCH_baseline.json`` in CI.
"""
from __future__ import annotations

import json
import os
import time
from pathlib import Path

import numpy as np

from repro.core import (
    CompressionConfig,
    ErrorBoundMode,
    decompress,
    encoders,
    lossless,
    metrics,
    sz3_auto,
    sz3_chunked,
    sz3_fast,
    sz3_hybrid,
    sz3_interp,
    sz3_lorenzo,
    sz3_lr,
    sz3_pwr,
    sz3_quality,
    sz3_transform,
    sz3_truncation,
)
from repro.core import telemetry
from repro.core.chunking import ChunkedCompressor

from . import datasets

REPO_ROOT = Path(__file__).resolve().parent.parent

# One warm Trace shared by every timing loop in this module.  Previously each
# repeat threw away everything but the min() — and any loop that wanted a
# trace opened a fresh one inside the timed region, paying trace setup on
# every repeat.  All repeats now land in this trace's histograms, so
# best-of-N AND percentile spread come from the same samples.
_WARM = telemetry.Trace("bench")


def _best(fn, repeats=2, label=None):
    """Best-of-N timing; each repeat is also observed into the warm bench
    trace (as ``<label>_seconds``) so percentiles are reportable."""
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        dt = time.perf_counter() - t0
        best = min(best, dt)
        if label is not None:
            _WARM.observe(f"{label}_seconds", dt)
    return best, out


def timing_percentiles():
    """p50/p90 (and min/max) of every labelled timing loop this run."""
    return {
        name: hist.snapshot() for name, hist in sorted(_WARM.histograms.items())
    }


def huffman_rows(full: bool = False, seed: int = 3):
    """Huffman encode+decode, v2 word-packed vs legacy, same code stream.

    The code stream is what a 16M-element (quick: 4M) float32 smooth field
    feeds the entropy stage: two-sided-geometric quantization codes around
    the zero bin plus sparse unpredictable markers.
    """
    n = (1 << 24) if full else (1 << 22)
    rng = np.random.default_rng(seed)
    codes = (32768 + np.rint(rng.standard_normal(n) * 2.5)).astype(np.uint16)
    codes[rng.random(n) < 0.001] = 0
    src_mb = n * 4 / 1e6  # of the float32 array the codes stand for

    v2 = encoders.HuffmanEncoder()
    legacy = encoders.LegacyHuffmanEncoder()
    t_enc, blob = _best(lambda: v2.encode(codes))
    t_dec, out = _best(lambda: v2.decode(blob, n))
    assert np.array_equal(out, codes.astype(np.int64))
    t_lenc, lblob = _best(lambda: legacy.encode(codes), repeats=1)
    t_ldec, lout = _best(lambda: legacy.decode(lblob, n), repeats=1)
    assert np.array_equal(lout, codes.astype(np.int64))
    return {
        "n_codes": n,
        "src_float32_MB": round(src_mb, 1),
        "enc_MBps_v2": round(src_mb / t_enc, 1),
        "dec_MBps_v2": round(src_mb / t_dec, 1),
        "enc_MBps_legacy": round(src_mb / t_lenc, 1),
        "dec_MBps_legacy": round(src_mb / t_ldec, 1),
        "speedup_enc": round(t_lenc / t_enc, 2),
        "speedup_dec": round(t_ldec / t_dec, 2),
        "speedup_encdec": round((t_lenc + t_ldec) / (t_enc + t_dec), 2),
    }


def chunked_rows(full: bool = False, seed: int = 3):
    """End-to-end chunked compress/decompress at several worker counts, plus
    the PR1-equivalent configuration (serial, legacy Huffman) on both."""
    shape = (512, 256, 128) if full else (256, 256, 64)  # 64MB / 16MB f32
    rng = np.random.default_rng(seed)
    data = np.cumsum(rng.standard_normal(shape).astype(np.float32), axis=0)
    conf = CompressionConfig(mode=ErrorBoundMode.REL, eb=1e-3)
    mb = data.nbytes / 1e6
    out = {"data_MB": round(mb, 1), "cpu_count": os.cpu_count()}
    blob = None
    times = {}
    for w in (1, 2, 4):
        eng = ChunkedCompressor(chunk_bytes=1 << 22, workers=w)
        dt, res = _best(lambda: eng.compress(data, conf), label=f"chunked_compress_w{w}")
        times[w] = dt
        out[f"compress_MBps_w{w}"] = round(mb / dt, 1)
        if blob is None:
            blob = res.blob
        else:
            assert res.blob == blob, "parallel container not byte-identical"
    out["speedup_w4_vs_w1"] = round(times[1] / times[4], 2)
    out["speedup_w2_vs_w1"] = round(times[1] / times[2], 2)
    for w in (1, 4):
        dt, _ = _best(lambda: decompress(blob, workers=w), label=f"chunked_decompress_w{w}")
        out[f"decompress_MBps_w{w}"] = round(mb / dt, 1)
    # PR1-equivalent engine: serial + legacy Huffman swapped in for the
    # factories' default encoder (restored afterwards)
    v2_cls = encoders.HuffmanEncoder
    try:
        encoders.HuffmanEncoder = encoders.LegacyHuffmanEncoder
        eng = ChunkedCompressor(chunk_bytes=1 << 22, workers=1)
        dt_pr1, _ = _best(lambda: eng.compress(data, conf), repeats=1)
    finally:
        encoders.HuffmanEncoder = v2_cls
    out["compress_MBps_pr1_equiv"] = round(mb / dt_pr1, 1)
    out["speedup_w4_vs_pr1"] = round(dt_pr1 / times[4], 2)
    return out


def transform_rows(full: bool = False, seed: int = 3):
    """Transform-coder subsystem health: ratio advantage over Lorenzo on an
    oscillatory field (the workload class the subsystem exists for) and
    round-trip throughput.  The ratio advantage is data-deterministic (fixed
    seed), so the regression gate can guard it machine-independently."""
    n = (1 << 23) if full else (1 << 21)
    rng = np.random.default_rng(seed)
    t = np.arange(n, dtype=np.float64)
    # near-Nyquist tone + smooth drift + noise floor: Lorenzo-hostile
    data = (
        np.sin(0.93 * np.pi * t)
        + 0.1 * np.sin(2e-4 * t)
        + 0.01 * rng.standard_normal(n)
    ).astype(np.float32)
    conf = CompressionConfig(mode=ErrorBoundMode.REL, eb=1e-3)
    mb = data.nbytes / 1e6
    comp_t, comp_l = sz3_transform(), sz3_lorenzo()
    t_enc, res_t = _best(lambda: comp_t.compress(data, conf))
    t_dec, xhat = _best(lambda: decompress(res_t.blob))
    _, res_l = _best(lambda: comp_l.compress(data, conf), repeats=1)
    eb_abs = 1e-3 * float(data.max() - data.min())
    bound_ok = float(np.abs(xhat.astype(np.float64) - data).max() <= eb_abs * (1 + 1e-9))
    auto = sz3_auto(chunk_bytes=1 << 20)
    _, res_a = _best(lambda: auto.compress(data, conf, with_stats=True), repeats=1)
    picked = [c["pipeline"] for c in res_a.meta["chunks"]]
    return {
        "n": n,
        "data_MB": round(mb, 1),
        "ratio_transform": round(res_t.ratio, 2),
        "ratio_lorenzo": round(res_l.ratio, 2),
        "ratio_vs_lorenzo": round(res_t.ratio / res_l.ratio, 3),
        "bound_ok": bound_ok,
        "compress_MBps": round(mb / t_enc, 1),
        "decompress_MBps": round(mb / t_dec, 1),
        "auto_ratio": round(res_a.ratio, 2),
        "auto_transform_chunk_share": round(
            sum(p == "sz3_transform" for p in picked) / max(1, len(picked)), 3
        ),
    }


def quality_rows(full: bool = False, seed: int = 3):
    """Quality-targeted controller + pointwise-relative pipeline health.

    Fixed seeds make every number data-deterministic, so check_regression.py
    can gate them as ABSOLUTE criteria (achieved PSNR within tolerance of the
    target; pointwise bound + exact zeros hold) on any machine.
    """
    target = 60.0
    shape = (512, 128, 32) if full else (192, 96, 32)
    rng = np.random.default_rng(seed)
    data = np.cumsum(rng.standard_normal(shape).astype(np.float32), axis=0)
    mb = data.nbytes / 1e6
    q = sz3_quality(target_psnr=target, chunk_bytes=1 << 20)
    t_enc, res = _best(lambda: q.compress(data), repeats=1)
    xhat = decompress(res.blob)
    achieved = metrics.psnr(data, xhat)
    # pointwise-relative: lognormal magnitudes with signs + exact zeros —
    # the workload REL's absmax fallback used to butcher
    pwr_eb = 1e-3
    vals = np.exp(rng.normal(0, 4, (1 << 19 if full else 1 << 17,))).astype(np.float64)
    vals[rng.random(vals.size) < 0.3] *= -1
    vals[rng.random(vals.size) < 0.01] = 0.0
    comp_p = sz3_pwr(eb=pwr_eb, chunk_bytes=1 << 20)
    t_pwr, res_p = _best(lambda: comp_p.compress(vals), repeats=1)
    vhat = decompress(res_p.blob)
    nz = vals != 0
    max_rel = float(np.abs((vhat[nz] - vals[nz]) / vals[nz]).max())
    return {
        "target_psnr": target,
        "achieved_psnr": round(float(achieved), 2),
        "psnr_within_tol": float(target - 1.0 <= achieved <= target + 1.0),
        "ratio_at_target": round(res.ratio, 2),
        "controller_MBps": round(mb / t_enc, 1),
        "pwr_eb": pwr_eb,
        "pwr_max_rel": max_rel,
        "pwr_bound_ok": float(max_rel <= pwr_eb * (1 + 1e-9)),
        "pwr_zeros_exact": float(np.all(vhat[~nz] == 0.0)),
        "pwr_ratio": round(res_p.ratio, 2),
        "pwr_MBps": round(vals.nbytes / 1e6 / t_pwr, 1),
    }


def mixed_regime_field(shape=(256, 256), seed: int = 3) -> np.ndarray:
    """The hybrid engine's acceptance fixture: four 16-aligned regimes whose
    per-block winners differ (smooth -> Lorenzo-1, quadratic -> Lorenzo-2,
    oscillatory -> zero-predictor, noisy plane -> regression, zero tile ->
    zero), so no single-predictor pipeline can match per-block selection.
    Seed-deterministic: the gate ratios are machine-independent."""
    rng = np.random.default_rng(seed)
    h, w = shape
    x = np.zeros(shape, np.float64)
    h2, w2 = h // 2, w // 2
    x[:h2, :w2] = np.cumsum(rng.standard_normal((h2, w2)), axis=0)
    i, j = np.meshgrid(
        np.arange(h2, dtype=np.float64),
        np.arange(w2, dtype=np.float64),
        indexing="ij",
    )
    x[h2:, :w2] = 2e-3 * (i * i + j * j)
    t = np.arange(h2 * w2, dtype=np.float64)
    x[:h2, w2:] = np.sin(0.93 * np.pi * t).reshape(h2, w2) + 0.01 * (
        rng.standard_normal((h2, w2))
    )
    x[h2:, w2:] = 0.4 * i + 0.2 * j + 2.5e-3 * rng.standard_normal((h2, w2))
    x[h2 : h2 + 48, w2 : w2 + 48] = 0.0
    return x.astype(np.float32)


def hybrid_rows(full: bool = False, seed: int = 3):
    """Block-hybrid engine vs every single-predictor pipeline at the same
    ABS bound on the mixed-regime fixture (the PR5 acceptance criterion:
    hybrid strictly better than the best of them, bound verified pointwise).
    Ratios are data-deterministic, so check_regression.py gates them as
    absolute criteria."""
    shape = (512, 512) if full else (256, 256)
    data = mixed_regime_field(shape, seed)
    eb = 1e-3
    conf = CompressionConfig(mode=ErrorBoundMode.ABS, eb=eb)
    mb = data.nbytes / 1e6
    comp_h = sz3_hybrid()
    t_enc, res_h = _best(lambda: comp_h.compress(data, conf, with_stats=True))
    t_dec, xhat = _best(lambda: decompress(res_h.blob))
    bound_ok = float(
        np.abs(xhat.astype(np.float64) - data).max() <= eb * (1 + 1e-9)
    )
    singles = {}
    for name, comp in [
        ("lorenzo", sz3_lorenzo()),
        ("lr", sz3_lr()),
        ("interp", sz3_interp()),
    ]:
        _, res = _best(lambda: comp.compress(data, conf), repeats=1)
        singles[name] = res.ratio
    best_single = max(singles.values())
    return {
        "data_MB": round(mb, 1),
        "eb_abs": eb,
        "ratio_hybrid": round(res_h.ratio, 3),
        **{f"ratio_{k}": round(v, 3) for k, v in singles.items()},
        "ratio_vs_best_single": round(res_h.ratio / best_single, 3),
        "bound_ok": bound_ok,
        "compress_MBps": round(mb / t_enc, 1),
        "decompress_MBps": round(mb / t_dec, 1),
        "tag_shares": {
            k: round(v, 3) for k, v in res_h.meta["tag_shares"].items()
        },
    }


def fast_rows(full: bool = False, seed: int = 3):
    """SZx-style fixed-length tier (PR6 acceptance): compress/decompress
    throughput and the speedup over the chunked Lorenzo pipeline at the SAME
    absolute bound, bound verified pointwise.  The MB/s numbers feed the
    ABSOLUTE floors in check_regression.py (tuned well under any CI machine's
    capability), the speedup is machine-relative and gated at >= 5x."""
    n = (1 << 24) if full else (1 << 22)  # 64MB / 16MB float32
    rng = np.random.default_rng(seed)
    data = np.cumsum(rng.standard_normal(n).astype(np.float32)).astype(
        np.float32
    )
    eb = 1e-3
    conf = CompressionConfig(mode=ErrorBoundMode.ABS, eb=eb)
    mb = data.nbytes / 1e6
    comp_f = sz3_fast()
    t_enc, res_f = _best(
        lambda: comp_f.compress(data, conf), repeats=3, label="fast_compress"
    )
    t_dec, xhat = _best(
        lambda: decompress(res_f.blob), repeats=3, label="fast_decompress"
    )
    bound_ok = float(
        np.abs(xhat.astype(np.float64) - data).max() <= eb * (1 + 1e-9)
    )
    # reference: the chunked engine pinned to the Lorenzo pipeline (the
    # throughput-oriented prediction configuration)
    eng = ChunkedCompressor(candidates=("sz3_lorenzo",), chunk_bytes=1 << 22)
    t_ch, res_ch = _best(lambda: eng.compress(data, conf), repeats=1)
    return {
        "data_MB": round(mb, 1),
        "eb_abs": eb,
        "fast_compress_MBps": round(mb / t_enc, 1),
        "fast_decompress_MBps": round(mb / t_dec, 1),
        "fast_ratio": round(res_f.ratio, 2),
        "chunked_compress_MBps": round(mb / t_ch, 1),
        "chunked_ratio": round(res_ch.ratio, 2),
        "speedup_vs_chunked": round(t_ch / t_enc, 2),
        "bound_ok": bound_ok,
    }


def integrity_rows(full: bool = False, seed: int = 3):
    """Cost of the integrity layer (PR7 acceptance): checksum trailers plus
    strict verification, measured on the two tiers where overhead matters
    most — the chunked engine (many per-chunk CRCs) and the fast tier (the
    throughput-critical path, so fixed costs show up largest).  The GATED
    overhead percentages time the ADDED work directly — trailer build on the
    compress side, ``verify_container`` on the decompress side — against the
    base-path timing: differencing two whole-path timings is too noisy on a
    loaded 1-core runner to gate at 5%.  The on/off MBps rows stay as the
    informational end-to-end view."""
    from repro.core import integrity
    from repro.core.pipeline import container_body, parse_header

    rng = np.random.default_rng(seed)
    out = {"checksum_algo": integrity.CHECKSUM_ALGO}
    eb = 1e-3
    conf = CompressionConfig(mode=ErrorBoundMode.ABS, eb=eb)
    tiers = {
        "chunked": (
            ChunkedCompressor(chunk_bytes=1 << 21, workers=1),
            np.cumsum(
                rng.standard_normal(
                    (256, 256, 64) if full else (128, 128, 64)
                ).astype(np.float32),
                axis=0,
            ),
        ),
        "fast": (
            sz3_fast(),
            np.cumsum(
                rng.standard_normal((1 << 24) if full else (1 << 22)).astype(
                    np.float32
                )
            ).astype(np.float32),
        ),
    }
    for tier, (comp, data) in tiers.items():
        mb = data.nbytes / 1e6
        with integrity.trailers_disabled():
            t_c_off, res_off = _best(lambda: comp.compress(data, conf), repeats=3)
        t_c_on, res_on = _best(lambda: comp.compress(data, conf), repeats=3)
        t_d_off, x_off = _best(
            lambda: decompress(res_on.blob, verify="off"), repeats=3
        )
        t_d_on, x_on = _best(
            lambda: decompress(res_on.blob, verify="strict"), repeats=3
        )
        assert np.array_equal(x_off, x_on)
        # the added work, timed in isolation (stable even under contention):
        # compress side appends build_trailer, strict decode prepends
        # verify_container — both relative to the integrity-off base timing
        header, body_off = parse_header(res_on.blob)
        head = res_on.blob[:body_off]
        body = container_body(res_on.blob, body_off)
        bounds = integrity.chunk_bounds_of(header, len(body))
        t_trailer, _ = _best(
            lambda: integrity.build_trailer(head, body, bounds), repeats=5
        )
        t_verify, _ = _best(
            lambda: integrity.verify_container(res_on.blob, header, body_off),
            repeats=5,
        )
        out[tier] = {
            "data_MB": round(mb, 1),
            "trailer_bytes": len(res_on.blob) - len(res_off.blob),
            "compress_MBps_off": round(mb / t_c_off, 1),
            "compress_MBps_on": round(mb / t_c_on, 1),
            "decompress_MBps_off": round(mb / t_d_off, 1),
            "decompress_MBps_strict": round(mb / t_d_on, 1),
            "compress_overhead_pct": round(100 * t_trailer / t_c_off, 2),
            "verify_overhead_pct": round(100 * t_verify / t_d_off, 2),
            "compress_delta_pct": round(100 * (t_c_on / t_c_off - 1), 2),
            "verify_delta_pct": round(100 * (t_d_on / t_d_off - 1), 2),
            "size_overhead_pct": round(
                100 * (len(res_on.blob) / len(res_off.blob) - 1), 3
            ),
        }
    return out


def _span_total(span) -> int:
    return sum(1 + _span_total(c) for c in span.children)


def telemetry_rows(full: bool = False, seed: int = 3, trace_path=None):
    """Cost of the telemetry spine (PR8 acceptance): stage spans + selection
    decision records must cost < 1% of compress time when no trace is active
    and < 5% when one is, on the chunked tier (many spans and one decision
    per chunk) and on the fast tier (throughput-critical, fixed costs loom
    largest).  As with the integrity gate, the GATED percentages time the
    ADDED work in isolation — the per-event cost of a disabled-path span()
    (one ContextVar read) or of a live span / decision record, multiplied
    by the event count one compress actually emits — expressed against the
    untraced compress timing.  Differencing two whole-path timings is too
    noisy on a loaded 1-core runner to gate at 1%; the direct on/off deltas
    are reported informationally.  ``trace_path`` saves the chunked tier's
    trace as a JSON artifact (uploaded by CI)."""
    rng = np.random.default_rng(seed)
    conf = CompressionConfig(mode=ErrorBoundMode.ABS, eb=1e-3)
    tiers = {
        "chunked": (
            ChunkedCompressor(chunk_bytes=1 << 21, workers=1),
            np.cumsum(
                rng.standard_normal(
                    (256, 256, 64) if full else (128, 128, 64)
                ).astype(np.float32),
                axis=0,
            ),
        ),
        "fast": (
            sz3_fast(),
            np.cumsum(
                rng.standard_normal((1 << 24) if full else (1 << 22)).astype(
                    np.float32
                )
            ).astype(np.float32),
        ),
    }
    # per-event costs, measured once on this machine
    reps = 20_000
    t0 = time.perf_counter()
    for _ in range(reps):
        with telemetry.span("noop"):
            pass
    per_noop = (time.perf_counter() - t0) / reps
    with telemetry.trace("cost_probe") as probe:
        t0 = time.perf_counter()
        for _ in range(reps):
            with telemetry.span("live"):
                pass
        per_span = (time.perf_counter() - t0) / reps
        t0 = time.perf_counter()
        for i in range(reps):
            telemetry.record_decision(
                telemetry.make_decision(
                    "sz3_chunked",
                    "sz3_lorenzo",
                    index=i,
                    candidates=["sz3_lorenzo", "sz3_lr", "sz3_interp"],
                    estimates={"sz3_lorenzo": 2.7, "sz3_lr": 3.1, "sz3_interp": 3.0},
                    est_bits=2.7,
                    realized_bits=2.9,
                    margin=1.1,
                    n_elems=1 << 19,
                )
            )
        per_decision = (time.perf_counter() - t0) / reps
    out = {
        "noop_span_ns": round(per_noop * 1e9, 1),
        "live_span_ns": round(per_span * 1e9, 1),
        "decision_record_ns": round(per_decision * 1e9, 1),
    }
    for tier, (comp, data) in tiers.items():
        mb = data.nbytes / 1e6
        t_off, _ = _best(lambda: comp.compress(data, conf), repeats=3)
        with telemetry.trace(f"bench_{tier}") as tr:
            t_on, _ = _best(lambda: comp.compress(data, conf), repeats=3)
        # the sel_header/decision construction only runs under a trace, so
        # counting the traced run's events over-counts the disabled path —
        # conservative in the right direction for both gates.  The trace
        # holds 3 repeats, so divide for the per-compress event count.
        n_spans = -(-_span_total(tr.root) // 3)
        n_decisions = -(-len(tr.decisions) // 3)
        if tier == "chunked" and trace_path is not None:
            tr.save_json(trace_path)
            out["trace_artifact"] = str(trace_path)
        out[tier] = {
            "data_MB": round(mb, 1),
            "spans_per_compress": n_spans,
            "decisions_per_compress": n_decisions,
            "compress_MBps_off": round(mb / t_off, 1),
            "compress_MBps_on": round(mb / t_on, 1),
            "overhead_off_pct": round(100 * n_spans * per_noop / t_off, 3),
            "overhead_on_pct": round(
                100 * (n_spans * per_span + n_decisions * per_decision) / t_off,
                3,
            ),
            "delta_on_pct": round(100 * (t_on / t_off - 1), 2),
        }
    return out


def grad_rows(full: bool = False, seed: int = 3):
    """In-training gradient compression (PR10 acceptance): the jit codec
    facade's encode/decode throughput on a gradient-sized array, the
    per-block bound verified pointwise, the error-feedback time-average
    error (the unbiasedness the >=20-step trajectory test relies on), and
    the collective-bytes model of the compressed DP reduction — the int8
    schedule must cut reduction bytes >= 1.3x vs a bf16 all-reduce.  All
    data-deterministic (fixed seed), so check_regression.py gates them as
    absolute criteria."""
    import jax
    import jax.numpy as jnp

    from repro.compression.grad import collective_bytes
    from repro.core import jitmode

    n = (1 << 23) if full else (1 << 21)
    rng = np.random.default_rng(seed)
    # gradient-like: smooth layer structure times heavy-tailed magnitudes
    g = (
        np.cumsum(rng.standard_normal(n).astype(np.float32)) * 1e-3
        + rng.standard_normal(n).astype(np.float32)
    ).astype(np.float32)
    mb = g.nbytes / 1e6
    pol = jitmode.JitPolicy.parse("int8:bs=512")
    enc = jax.jit(jitmode.encode, static_argnums=1)
    dec = jax.jit(jitmode.decode)
    gj = jnp.asarray(g)
    c = enc(gj, pol)  # compile
    _ = dec(c).block_until_ready()
    t_enc, c = _best(
        lambda: jax.block_until_ready(enc(gj, pol)), repeats=3,
        label="grad_encode",
    )
    t_dec, back = _best(
        lambda: dec(c).block_until_ready(), repeats=3, label="grad_decode"
    )
    back = np.asarray(back)
    bound = np.repeat(np.asarray(c.bound()), pol.bs)[:n]
    bound_ok = float(np.all(np.abs(back - g) <= bound))
    # error feedback: the time-average of dequantized grads must converge
    # to the true gradient (what keeps compressed/uncompressed trajectories
    # close) — measured on a slice so the loop stays cheap
    gs = jnp.asarray(g[: 1 << 16])
    fb = jnp.zeros_like(gs)
    acc = np.zeros(gs.shape, np.float64)
    steps = 30
    for _ in range(steps):
        d = dec(enc(gs + fb, pol))
        fb = gs + fb - d
        acc += np.asarray(d, np.float64)
    fb_err = float(np.abs(acc / steps - np.asarray(gs, np.float64)).max())
    acc8 = collective_bytes(n, dp=8, policy=8)
    acc4 = collective_bytes(n, dp=8, policy=4)
    return {
        "n": n,
        "data_MB": round(mb, 1),
        "policy": "int8:bs=512",
        "encode_MBps": round(mb / t_enc, 1),
        "decode_MBps": round(mb / t_dec, 1),
        "bound_ok": bound_ok,
        "feedback_avg_err": fb_err,
        "collective_cut_int8": round(acc8["cut_vs_bf16_allreduce"], 3),
        "collective_cut_int4": round(acc4["cut_vs_bf16_allreduce"], 3),
    }


def elastic_rows(full: bool = False, seed: int = 3):
    """Elastic chunk-range restore (PR10 acceptance): reading a quarter of a
    big lossy checkpoint leaf through ``ChunkRangeReader`` must decode
    strictly fewer container bytes than the full leaf and reproduce the full
    decode's rows exactly (the reshard differential).  Byte fractions are
    data-deterministic; the MB/s rows are informational."""
    from repro.ft.checkpoint import LeafPolicy, decode_leaf, encode_leaf
    from repro.ft.elastic import ChunkRangeReader

    rows = 8192 if full else 4096
    rng = np.random.default_rng(seed)
    leaf = (
        np.cumsum(rng.standard_normal((rows, 512)).astype(np.float32), 0)
        * 1e-3
    )
    mb = leaf.nbytes / 1e6
    t_enc, (blob, meta) = _best(
        lambda: encode_leaf(leaf, LeafPolicy("lossy", 1e-4)), repeats=1
    )
    assert meta["codec"] in ("sz3_auto_rel", "sz3_chunked_rel"), meta["codec"]
    t_full, host = _best(
        lambda: decode_leaf(blob, meta), repeats=2, label="elastic_full_decode"
    )
    q = rows // 4

    def quarter():
        r = ChunkRangeReader(blob)
        return r, r.rows(0, q)

    t_qr, (reader, got) = _best(quarter, repeats=2, label="elastic_quarter")
    exact = float(np.array_equal(got, host.reshape(rows, -1)[:q]))
    return {
        "leaf_MB": round(mb, 1),
        "codec": meta["codec"],
        "container_bytes": len(blob),
        "quarter_read_frac": round(reader.bytes_read / len(blob), 3),
        "range_values_exact": exact,
        "full_decode_MBps": round(mb / t_full, 1),
        "quarter_decode_MBps": round(mb / 4 / t_qr, 1),
    }


def perf_rows(full: bool = False, trace_path=None):
    from .bench_serving import serving_rows  # lazy: avoids a module cycle

    return {
        "lossless_backend": lossless.effective_backend("zstd"),
        "cpu_count": os.cpu_count(),
        "huffman": huffman_rows(full),
        "chunked_workers": chunked_rows(full),
        "transform": transform_rows(full),
        "quality": quality_rows(full),
        "hybrid": hybrid_rows(full),
        "fast": fast_rows(full),
        "integrity": integrity_rows(full),
        "grad": grad_rows(full),
        "elastic": elastic_rows(full),
        "telemetry": telemetry_rows(full, trace_path=trace_path),
        "serving": serving_rows(full),
        "timing_percentiles": timing_percentiles(),
    }


def run(fields=None, seed: int = 3, repeats: int = 1):
    fields = fields or ["miranda_u", "nyx_rho", "atm_t2m"]
    rows = []
    for fname in fields:
        data = datasets.domain_field(fname, seed)
        conf = CompressionConfig(mode=ErrorBoundMode.REL, eb=1e-3)
        for cname, comp in [
            ("SZ3-Truncation", sz3_truncation(2)),
            ("SZ3-Lorenzo(dualquant)", sz3_lorenzo()),
            ("SZ3-LR", sz3_lr()),
            ("SZ3-Interp", sz3_interp()),
            ("SZ3-Transform", sz3_transform()),
            ("SZ3-Hybrid(blockwise)", sz3_hybrid()),
            ("SZ3-Fast(fixed-length)", sz3_fast()),
            ("SZ3-Chunked(adaptive)", sz3_chunked(chunk_bytes=1 << 21)),
            ("SZ3-Auto(pred+transform+hybrid)", sz3_auto(chunk_bytes=1 << 21)),
        ]:
            c_dt, res = _best(
                lambda: comp.compress(data, conf), repeats=repeats,
                label=f"fig8_{fname}_compress",
            )
            d_dt, xhat = _best(
                lambda: decompress(res.blob), repeats=repeats,
                label=f"fig8_{fname}_decompress",
            )
            rows.append(
                {
                    "field": fname,
                    "pipeline": cname,
                    "ratio": round(res.ratio, 2),
                    "compress_MBps": round(data.nbytes / 1e6 / c_dt, 1),
                    "decompress_MBps": round(data.nbytes / 1e6 / d_dt, 1),
                }
            )
    return rows


def write_bench_json(perf, tag: str = "latest") -> str:
    """Record the perf trajectory at the repo root (acceptance artifact)."""
    path = REPO_ROOT / f"BENCH_{tag}.json"
    with open(path, "w") as f:
        json.dump(perf, f, indent=1, default=str)
    return str(path)


def perf_main(full: bool = False, tag: str = None):
    """Perf rows only (codec + engine before/after) + BENCH json artifact.

    The CI regression gate runs this — it skips the Fig-8 field matrix the
    gate never reads.  Alongside the BENCH json it saves the chunked tier's
    JSON stage trace (``TRACE_<tag>.json``), uploaded by CI as an artifact.
    """
    tag = tag or ("full" if full else "quick")
    perf = perf_rows(full, trace_path=REPO_ROOT / f"TRACE_{tag}.json")
    print("perf:", json.dumps(perf))
    path = write_bench_json({"perf": perf}, tag)
    print(f"wrote {path}")
    return perf


def main(full: bool = False, write_json: bool = False):
    rows = run(list(datasets.DOMAIN_FIELDS) if full else None)
    print("field,pipeline,ratio,compress_MBps,decompress_MBps")
    for r in rows:
        print(
            f"{r['field']},{r['pipeline']},{r['ratio']},{r['compress_MBps']},{r['decompress_MBps']}"
        )
    perf = perf_rows(full)
    print("perf:", json.dumps(perf))
    out = {"pipelines": rows, "perf": perf}
    if write_json:  # registry runs (benchmarks.run) must stay side-effect free
        path = write_bench_json(out, "full" if full else "quick")
        print(f"wrote {path}")
    return out


if __name__ == "__main__":
    main(True, write_json=True)
