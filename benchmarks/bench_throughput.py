"""Paper Fig 8: compression/decompression throughput (MB/s) at REL eb=1e-3.

Includes the device-kernel path (dual-quant Lorenzo via the Pallas ops in
interpret mode on CPU; compiled on real TPUs) alongside the host pipelines,
which is this repo's analogue of the paper's SZ3-LR-s speed-oriented build.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (
    CompressionConfig,
    ErrorBoundMode,
    decompress,
    sz3_chunked,
    sz3_interp,
    sz3_lorenzo,
    sz3_lr,
    sz3_truncation,
)

from . import datasets


def run(fields=None, seed: int = 3, repeats: int = 1):
    fields = fields or ["miranda_u", "nyx_rho", "atm_t2m"]
    rows = []
    for fname in fields:
        data = datasets.domain_field(fname, seed)
        conf = CompressionConfig(mode=ErrorBoundMode.REL, eb=1e-3)
        for cname, comp in [
            ("SZ3-Truncation", sz3_truncation(2)),
            ("SZ3-Lorenzo(dualquant)", sz3_lorenzo()),
            ("SZ3-LR", sz3_lr()),
            ("SZ3-Interp", sz3_interp()),
            ("SZ3-Chunked(adaptive)", sz3_chunked(chunk_bytes=1 << 21)),
        ]:
            t0 = time.perf_counter()
            for _ in range(repeats):
                res = comp.compress(data, conf)
            c_dt = (time.perf_counter() - t0) / repeats
            t0 = time.perf_counter()
            for _ in range(repeats):
                xhat = decompress(res.blob)
            d_dt = (time.perf_counter() - t0) / repeats
            rows.append(
                {
                    "field": fname,
                    "pipeline": cname,
                    "ratio": round(res.ratio, 2),
                    "compress_MBps": round(data.nbytes / 1e6 / c_dt, 1),
                    "decompress_MBps": round(data.nbytes / 1e6 / d_dt, 1),
                }
            )
    return rows


def main(full: bool = False):
    rows = run(list(datasets.DOMAIN_FIELDS) if full else None)
    print("field,pipeline,ratio,compress_MBps,decompress_MBps")
    for r in rows:
        print(
            f"{r['field']},{r['pipeline']},{r['ratio']},{r['compress_MBps']},{r['decompress_MBps']}"
        )
    return rows


if __name__ == "__main__":
    main(True)
