"""Paper Table 1 + Fig 3 + Fig 4: GAMESS ERI compression.

Compares SZ-Pastri (baseline [19]) / SZ-Pastri-with-zstd / SZ3-Pastri
(unpred-aware quantizer + lossless stage, paper §4.2) at abs eb=1e-10 and
sweeps the rate-distortion curve.  ``--hist`` reports the quantization-
integer split (data/pattern/scale populations, Fig 3).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (
    CompressionConfig,
    ErrorBoundMode,
    decompress,
    metrics,
    sz3_pastri,
    sz_pastri,
    sz_pastri_zstd,
)

from . import datasets


def run(n_blocks: int = 8000, eb: float = 1e-10, pattern: int = 96, seed: int = 7):
    rows = []
    for field_seed, field_name in [(seed, "ff|ff"), (seed + 1, "ff|dd"), (seed + 2, "dd|dd")]:
        data = datasets.gamess_eri(n_blocks=n_blocks, pattern=pattern, seed=field_seed)
        conf = CompressionConfig(mode=ErrorBoundMode.ABS, eb=eb)
        for name, comp in [
            ("SZ-Pastri", sz_pastri(pattern)),
            ("SZ-Pastri-with-zstd", sz_pastri_zstd(pattern)),
            ("SZ3-Pastri", sz3_pastri(pattern)),
        ]:
            t0 = time.perf_counter()
            res = comp.compress(data, conf)
            dt = time.perf_counter() - t0
            xhat = decompress(res.blob)
            err = metrics.max_abs_error(data, xhat)
            assert err <= eb * 1.0001, (name, err)
            rows.append(
                {
                    "dataset": field_name,
                    "compressor": name,
                    "ratio": round(res.ratio, 2),
                    "speed_MBps": round(data.nbytes / 1e6 / dt, 2),
                    "max_err": err,
                }
            )
    return rows


def rate_distortion(n_blocks: int = 4000, pattern: int = 96, seed: int = 7):
    """Fig 4: bitrate vs PSNR for the three compressors."""
    data = datasets.gamess_eri(n_blocks=n_blocks, pattern=pattern, seed=seed)
    curves = {}
    for name, mk in [
        ("SZ-Pastri", sz_pastri),
        ("SZ-Pastri-with-zstd", sz_pastri_zstd),
        ("SZ3-Pastri", sz3_pastri),
    ]:
        pts = []
        for eb in [1e-8, 1e-9, 1e-10, 1e-11, 1e-12]:
            comp = mk(pattern)
            res = comp.compress(data, CompressionConfig(eb=eb))
            xhat = decompress(res.blob)
            pts.append(
                {
                    "eb": eb,
                    "bitrate": metrics.bit_rate(data, len(res.blob)),
                    "psnr": round(metrics.psnr(data, xhat), 2),
                }
            )
        curves[name] = pts
    return curves


def quant_histogram(n_blocks: int = 4000, eb: float = 1e-10, pattern: int = 96):
    """Fig 3: distribution of quantization integers + unpredictable fraction."""
    data = datasets.gamess_eri(n_blocks=n_blocks, pattern=pattern)
    comp = sz3_pastri(pattern)
    res = comp.compress(data, CompressionConfig(eb=eb), with_stats=True)
    codes = res.codes
    sec = res.meta["sections"]
    parts = {
        "pattern": codes[: sec[0]],
        "scales": codes[sec[0] : sec[0] + sec[1]],
        "data": codes[sec[0] + sec[1] : sec[0] + sec[1] + sec[2]],
    }
    out = {}
    for k, v in parts.items():
        unpred = float((v == 0).mean()) if v.size else 0.0
        out[k] = {"n": int(v.size), "unpredictable_frac": round(unpred, 4)}
    return out


def main(full: bool = False):
    n = 8000 if full else 1500
    rows = run(n_blocks=n)
    print("dataset,compressor,ratio,speed_MBps")
    for r in rows:
        print(f"{r['dataset']},{r['compressor']},{r['ratio']},{r['speed_MBps']}")
    return rows


if __name__ == "__main__":
    main(True)
