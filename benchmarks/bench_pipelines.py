"""Paper Fig 7: rate-distortion of SZ3-LR / SZ3-Interp / SZ3-Truncation on
the eight-domain dataset table (§6.2, Table 3 analogues)."""
from __future__ import annotations

import numpy as np

from repro.core import (
    CompressionConfig,
    ErrorBoundMode,
    decompress,
    metrics,
    sz3_interp,
    sz3_lr,
    sz3_truncation,
)

from . import datasets

REL_EBS = [1e-2, 1e-3, 1e-4, 1e-5]


def run(fields=None, seed: int = 3):
    fields = fields or list(datasets.DOMAIN_FIELDS)
    out = {}
    for fname in fields:
        data = datasets.domain_field(fname, seed)
        curves = {}
        for cname, mk in [("SZ3-LR", sz3_lr), ("SZ3-Interp", sz3_interp)]:
            pts = []
            for eb in REL_EBS:
                comp = mk()
                res = comp.compress(
                    data, CompressionConfig(mode=ErrorBoundMode.REL, eb=eb)
                )
                xhat = decompress(res.blob)
                rng = float(data.max() - data.min())
                err = metrics.max_abs_error(data, xhat)
                assert err <= eb * rng * 1.001, (fname, cname, eb, err)
                pts.append(
                    {
                        "eb": eb,
                        "bitrate": round(metrics.bit_rate(data, len(res.blob)), 3),
                        "psnr": round(metrics.psnr(data, xhat), 2),
                    }
                )
            curves[cname] = pts
        # truncation sweeps kept bytes instead of eb
        pts = []
        for k in (1, 2, 3):
            comp = sz3_truncation(k)
            res = comp.compress(data)
            xhat = decompress(res.blob)
            pts.append(
                {
                    "keep_bytes": k,
                    "bitrate": round(metrics.bit_rate(data, len(res.blob)), 3),
                    "psnr": round(metrics.psnr(data, xhat), 2),
                }
            )
        curves["SZ3-Truncation"] = pts
        out[fname] = curves
    return out


def main(full: bool = False):
    fields = list(datasets.DOMAIN_FIELDS) if full else ["miranda_u", "atm_t2m", "nyx_rho"]
    res = run(fields)
    print("field,pipeline,point,bitrate,psnr")
    for f, curves in res.items():
        for c, pts in curves.items():
            for i, p in enumerate(pts):
                print(f"{f},{c},{i},{p['bitrate']},{p['psnr']}")
    return res


if __name__ == "__main__":
    main(True)
