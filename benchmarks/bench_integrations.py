"""Beyond-paper integration benchmarks: the quantizer module on the training
and serving paths.

  * grad-compress: error-feedback int8/int4 DP reduction — convergence on a
    ridge-regression probe vs exact reduction + collective-byte accounting.
  * kv-cache: int8 per-token quantization SNR + attention-output drift.
  * opt-state: 8-bit moments — AdamW trajectory divergence on a quadratic.
  * checkpoint: ratio/latency of the SZ3-compressed checkpoint vs raw.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def grad_compress_probe(bits: int = 8, steps: int = 60, n: int = 4096, seed: int = 0):
    """Single-process simulation of R replicas with error feedback."""
    from repro.compression.grad import dequantize_shard, quantize_shard

    rng = np.random.default_rng(seed)
    R = 4
    A = rng.standard_normal((n, 64)).astype(np.float32)
    w_true = rng.standard_normal(64).astype(np.float32)
    y = A @ w_true + 0.01 * rng.standard_normal(n).astype(np.float32)
    shards = np.array_split(np.arange(n), R)

    def run(compressed: bool):
        w = np.zeros(64, np.float32)
        fb = [np.zeros(64, np.float32) for _ in range(R)]
        for _ in range(steps):
            gs = []
            for r in range(R):
                Ar, yr = A[shards[r]], y[shards[r]]
                g = 2 * Ar.T @ (Ar @ w - yr) / len(yr)
                gs.append(g)
            if compressed:
                deq = []
                for r in range(R):
                    v = gs[r] / R + fb[r]
                    codes, scale = quantize_shard(jnp.asarray(v), bits)
                    d = np.asarray(dequantize_shard(codes, scale, v.size, bits))
                    fb[r] = v - d
                    deq.append(d)
                g = np.sum(deq, axis=0)
            else:
                g = np.mean(gs, axis=0)
            w = w - 0.05 * g
        return float(np.mean((A @ w - y) ** 2))

    exact = run(False)
    comp = run(True)
    # collective bytes per step per device (ring models)
    nb = 64 * 4
    baseline = 2 * nb  # all-reduce bf16 ~ 2N
    ours = 2 * nb / 2 + nb // (1 if bits == 8 else 2) // 4  # RS bf16 + AG codes
    return {
        "bits": bits,
        "mse_exact": exact,
        "mse_compressed": comp,
        "rel_gap": abs(comp - exact) / max(1e-12, exact),
        "bytes_ratio": ours / baseline,
    }


def kv_cache_quality(seed: int = 0):
    from repro.compression.kvcache import quantization_snr_db, quantize_tokens, dequantize_tokens

    rng = np.random.default_rng(seed)
    k = rng.standard_normal((512, 8, 64)).astype(np.float32) * 3.0
    snr = quantization_snr_db(jnp.asarray(k))
    q, s = quantize_tokens(jnp.asarray(k))
    kd = np.asarray(dequantize_tokens(q, s))
    # attention drift on random queries
    qv = rng.standard_normal((16, 64)).astype(np.float32)
    a_ref = jax.nn.softmax(np.einsum("qd,tkd->qtk", qv, k) / 8.0, axis=1)
    a_q = jax.nn.softmax(np.einsum("qd,tkd->qtk", qv, kd) / 8.0, axis=1)
    drift = float(np.abs(np.asarray(a_ref) - np.asarray(a_q)).max())
    return {"snr_db": round(snr, 1), "attn_weight_drift": drift}


def opt_state_probe(steps: int = 120, seed: int = 0):
    from repro.optim import AdamWConfig, init_state, update

    rng = np.random.default_rng(seed)
    dim = 512
    h = rng.standard_normal((dim, dim)).astype(np.float32)
    H = h @ h.T / dim + 0.1 * np.eye(dim, dtype=np.float32)
    w0 = jnp.asarray(rng.standard_normal(dim).astype(np.float32))

    def run(compress: bool):
        cfg = AdamWConfig(lr=1e-2, weight_decay=0.0, compress_moments=compress)
        params = {"w": w0}
        st = init_state(params, cfg)
        for _ in range(steps):
            g = {"w": jnp.asarray(H) @ params["w"]}
            params, st, _ = update(params, g, st, cfg)
        return float(0.5 * params["w"] @ (jnp.asarray(H) @ params["w"]))

    exact, comp = run(False), run(True)
    from repro.compression.opt_state import compression_ratio

    return {
        "loss_exact": exact,
        "loss_compressed": comp,
        "rel_gap": abs(comp - exact) / max(1e-9, abs(exact)),
        "moment_memory_ratio": round(compression_ratio(np.zeros((512, 512))), 2),
    }


def checkpoint_probe(tmpdir: str = "/tmp/repro_ckpt_bench", seed: int = 0):
    import shutil

    import repro.configs as configs
    from repro import models
    from repro.ft import CheckpointManager
    from repro.optim import AdamWConfig, init_state
    from repro.parallel.plan import ParallelPlan

    shutil.rmtree(tmpdir, ignore_errors=True)
    cfg = configs.get_smoke("granite-3-8b")
    plan = ParallelPlan()
    params = models.init_params(jax.random.PRNGKey(seed), cfg, plan)
    state = {"params": params, "opt": init_state(params, AdamWConfig())}
    # give moments realistic smooth statistics
    state["opt"]["m"] = jax.tree.map(
        lambda p: (jnp.cumsum(jax.random.normal(jax.random.PRNGKey(1), p.shape), -1) * 1e-4).astype(jnp.float32),
        state["params"],
    )
    mgr = CheckpointManager(tmpdir, use_async=False)
    t0 = time.perf_counter()
    manifest = mgr._write(0, jax.tree.map(np.asarray, state), {})
    dt = time.perf_counter() - t0
    restored, _ = mgr.restore(jax.tree.map(np.asarray, state), 0)
    ok = all(
        np.allclose(a, b, atol=2e-4 * max(1.0, float(np.abs(a).max())))
        for a, b in zip(jax.tree.leaves(jax.tree.map(np.asarray, state)), jax.tree.leaves(restored))
    )
    shutil.rmtree(tmpdir, ignore_errors=True)
    return {
        "ratio": round(manifest["ratio"], 2),
        "write_MBps": round(manifest["bytes_in"] / 1e6 / dt, 1),
        "restore_ok": ok,
    }


def main(full: bool = False):
    out = {
        "grad_int8": grad_compress_probe(8),
        "grad_int4": grad_compress_probe(4),
        "kv_cache": kv_cache_quality(),
        "opt_state": opt_state_probe(),
        "checkpoint": checkpoint_probe(),
    }
    for k, v in out.items():
        print(k, v)
    return out


if __name__ == "__main__":
    main()
