"""Paper Fig 6: APS ptychography rate-distortion.

SZ3-APS (adaptive pipeline, §5.2) vs the generic LR compressor applied to the
3-D stack, to the flat 1-D stream, and to the transposed 1-D stream (the
paper's three SZ-2.1 baselines).  The adaptive pipeline must (a) match the
3-D compressor at high error bounds and (b) go lossless with the best ratio
below the 0.5 threshold on integer counts.
"""
from __future__ import annotations

import numpy as np

from repro.core import (
    CompressionConfig,
    ErrorBoundMode,
    SZ3Compressor,
    decompress,
    metrics,
    sz3_aps,
    sz3_lr,
)
from repro.core import predictors, preprocess, quantizers, encoders, lossless

from . import datasets


def _lr_1d_transposed():
    return SZ3Compressor(
        preprocessor=preprocess.Transpose(perm=(1, 2, 0), flatten=True),
        predictor=predictors.LorenzoPredictor(order=1),
        quantizer=quantizers.LinearScaleQuantizer(),
        encoder=encoders.HuffmanEncoder(),
        lossless=lossless.Zstd(),
    )


def _lr_1d():
    return SZ3Compressor(
        preprocessor=preprocess.Linearize(),
        predictor=predictors.LorenzoPredictor(order=1),
        quantizer=quantizers.LinearScaleQuantizer(),
        encoder=encoders.HuffmanEncoder(),
        lossless=lossless.Zstd(),
    )


def run(frames: int = 200, hw: int = 48, seed: int = 11):
    data = datasets.aps_ptycho(frames=frames, h=hw, w=hw, seed=seed)
    ebs = [0.25, 0.5, 1.0, 2.0, 4.0, 8.0]
    out = {}
    for name, mk in [
        ("SZ3-APS", sz3_aps),
        ("SZ-LR-3D", sz3_lr),
        ("SZ-LR-1D", _lr_1d),
        ("SZ-LR-1D-transposed", _lr_1d_transposed),
    ]:
        pts = []
        for eb in ebs:
            comp = mk()
            res = comp.compress(data, CompressionConfig(mode=ErrorBoundMode.ABS, eb=eb))
            xhat = decompress(res.blob)
            err = metrics.max_abs_error(data, xhat)
            lossless_hit = bool(np.array_equal(xhat, data))
            pts.append(
                {
                    "eb": eb,
                    "ratio": round(res.ratio, 2),
                    "bitrate": round(metrics.bit_rate(data, len(res.blob)), 3),
                    "psnr": round(metrics.psnr(data, xhat), 2) if not lossless_hit else float("inf"),
                    "lossless": lossless_hit,
                    "bound_ok": bool(err <= max(eb, 0.5) * 1.0001),
                }
            )
        out[name] = pts
    return out


def main(full: bool = False):
    res = run(frames=200 if full else 64, hw=48 if full else 32)
    print("compressor,eb,ratio,psnr,lossless")
    for name, pts in res.items():
        for p in pts:
            print(f"{name},{p['eb']},{p['ratio']},{p['psnr']},{p['lossless']}")
    return res


if __name__ == "__main__":
    main(True)
