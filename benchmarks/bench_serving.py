"""Serving-path latency bench: random-access chunk fetches, cached vs cold.

The throughput bench (``bench_throughput``) answers "how fast does a bulk
stream move"; this one answers the serving question — what latency does ONE
random-access read pay, and what do the PR 9 caches buy.  Three measurements:

  * **cold vs cached random access** — ``decompress_chunk`` with a fresh
    header parse + cleared Huffman-table LRU per fetch (what every read paid
    before the decode-state cache) against (a) fetches that reuse a parsed
    :class:`~repro.core.chunking.ChunkedIndex` and warm tables (metadata
    layer, reported) and (b) fetches served by the decoded-chunk LRU
    (repeated reads of hot pages — the serving steady state).  The hot-path
    p99 quotient is the headline gate (>= 5x): profiling shows the entropy
    decode dominates per-chunk latency ~10x over parse + table build, so
    only the result layer can buy an order of magnitude.
  * **service request latency** — p50/p99 of ``await fetch`` through the
    full async path (queue, coalescing dispatcher, worker pool, strict
    per-chunk CRC verify), plus the index-cache hit rate of the workload.
  * **correctness under concurrency** — 4-worker concurrent fetches and
    coalesced batches must be byte-identical to serial reads (1.0/0.0 rows
    gated in ``check_regression``).

``python -m benchmarks.bench_serving`` writes ``BENCH_PR9.json`` at the repo
root; CI gates it via ``check_regression`` QUALITY_GATES.
"""
from __future__ import annotations

import asyncio
import json
import time
from typing import Dict

import numpy as np

from repro.core import CompressionConfig, ErrorBoundMode, encoders, sz3_chunked
from repro.core.chunking import decompress_chunk, parse_chunked_index
from repro.serve.offload import DecodeStateCache, OffloadService

from . import datasets

#: small chunks on purpose: serving reads are page-granular, and the fixed
#: per-read costs (header parse, table build) loom largest when the chunk
#: payload is small — exactly the regime the decode-state cache targets
CHUNK_BYTES = 8192


def _build_container(seed: int = 3) -> bytes:
    data = datasets.domain_field("miranda_u", seed).astype(np.float32)
    data = np.ascontiguousarray(data.reshape(data.shape[0], -1))
    conf = CompressionConfig(mode=ErrorBoundMode.REL, eb=1e-3)
    comp = sz3_chunked(chunk_bytes=CHUNK_BYTES)
    return comp.compress(data, conf).blob


def random_access_rows(full: bool = False, seed: int = 3) -> Dict[str, float]:
    """Cold / warm-metadata / hot-chunk per-fetch latency, one container."""
    blob = _build_container(seed)
    idx = parse_chunked_index(blob)
    n = idx.n_chunks
    fetches = 400 if full else 120
    rng = np.random.default_rng(seed)
    order = rng.integers(0, n, fetches)

    cold = np.empty(fetches)
    for i, c in enumerate(order):
        encoders.clear_table_cache(reset_stats=False)
        t0 = time.perf_counter()
        decompress_chunk(blob, int(c))  # fresh parse + cold tables
        cold[i] = time.perf_counter() - t0

    # warm metadata: parsed index reused, table LRU hot after one pass
    for c in range(n):
        decompress_chunk(blob, c, parsed=idx)
    warm = np.empty(fetches)
    for i, c in enumerate(order):
        t0 = time.perf_counter()
        decompress_chunk(blob, int(c), parsed=idx)
        warm[i] = time.perf_counter() - t0

    # hot: repeated reads served by the decoded-chunk LRU (steady state of
    # a serving loop that re-reads resident KV pages)
    cache = DecodeStateCache(max_entries=8, max_chunk_bytes=64 << 20)
    for c in range(n):  # populate
        if cache.get_chunk(blob, c) is None:
            cache.put_chunk(blob, c, decompress_chunk(blob, c, parsed=idx))
    hot = np.empty(fetches)
    for i, c in enumerate(order):
        t0 = time.perf_counter()
        arr = cache.get_chunk(blob, int(c))
        if arr is None:  # pragma: no cover - budget sized to hold all chunks
            arr = decompress_chunk(blob, int(c), parsed=idx)
            cache.put_chunk(blob, int(c), arr)
        hot[i] = time.perf_counter() - t0

    p = lambda a, q: float(np.percentile(a, q) * 1e3)
    return {
        "n_chunks": n,
        "chunk_bytes": CHUNK_BYTES,
        "fetches": fetches,
        "uncached_p50_ms": round(p(cold, 50), 4),
        "uncached_p99_ms": round(p(cold, 99), 4),
        "warm_meta_p50_ms": round(p(warm, 50), 4),
        "warm_meta_p99_ms": round(p(warm, 99), 4),
        "cached_p50_ms": round(p(hot, 50), 4),
        "cached_p99_ms": round(p(hot, 99), 4),
        "p99_speedup_warm_meta": round(p(cold, 99) / max(p(warm, 99), 1e-9), 2),
        "p50_speedup_cached": round(p(cold, 50) / max(p(hot, 50), 1e-9), 2),
        "p99_speedup_cached": round(p(cold, 99) / max(p(hot, 99), 1e-9), 2),
    }


def service_rows(full: bool = False, seed: int = 3) -> Dict[str, float]:
    """End-to-end async service: latency percentiles, hit rate, identity."""
    blob = _build_container(seed)
    n = parse_chunked_index(blob).n_chunks
    serial = [decompress_chunk(blob, i) for i in range(n)]
    fetches = 300 if full else 120
    rng = np.random.default_rng(seed + 1)
    order = [int(c) for c in rng.integers(0, n, fetches)]

    async def _run() -> Dict[str, float]:
        svc = OffloadService(workers=4, coalesce_ms=0.5, cache_entries=8)
        try:
            await svc.put_compressed("bench", "page", blob, n_in=None)
            # 4-worker concurrent fetch of every chunk vs serial reads
            outs = await asyncio.gather(
                *[svc.fetch("bench", "page", i) for i in range(n)]
            )
            identical = all(
                np.array_equal(a, b) for a, b in zip(outs, serial)
            )
            # coalesced burst (one enqueue round) vs a no-coalescing service
            svc0 = OffloadService(workers=4, coalesce_ms=0.0)
            await svc0.put_compressed("bench", "page", blob, n_in=None)
            batched = await asyncio.gather(
                *[svc.fetch("bench", "page", c) for c in order[:32]]
            )
            unbatched = await asyncio.gather(
                *[svc0.fetch("bench", "page", c) for c in order[:32]]
            )
            coalesced_equal = all(
                np.array_equal(a, b) for a, b in zip(batched, unbatched)
            )
            await svc0.close()
            # request-latency distribution, one awaited fetch at a time;
            # hit rates are measured over this steady-state phase only (the
            # preceding identity pass is the mandatory cold fill)
            before = svc.cache.stats()
            lat = np.empty(fetches)
            for i, c in enumerate(order):
                t0 = time.perf_counter()
                await svc.fetch("bench", "page", c)
                lat[i] = time.perf_counter() - t0
            stats = svc.cache.stats()
            d = lambda k: stats[k] - before[k]
            idx_rate = d("hits") / max(1, d("hits") + d("misses"))
            chunk_rate = d("chunk_hits") / max(
                1, d("chunk_hits") + d("chunk_misses")
            )
            return {
                "service_fetches": fetches,
                "service_p50_ms": round(float(np.percentile(lat, 50) * 1e3), 4),
                "service_p99_ms": round(float(np.percentile(lat, 99) * 1e3), 4),
                "index_cache_hit_rate": round(idx_rate, 4),
                "cache_hit_rate": round(chunk_rate, 4),
                "concurrent_byte_identical": 1.0 if identical else 0.0,
                "coalesced_equal": 1.0 if coalesced_equal else 0.0,
            }
        finally:
            await svc.close()

    return asyncio.run(_run())


def serving_rows(full: bool = False, seed: int = 3) -> Dict[str, float]:
    out = random_access_rows(full, seed)
    out.update(service_rows(full, seed))
    return out


def main(full: bool = False, tag: str = "PR9") -> Dict[str, float]:
    from .bench_throughput import write_bench_json

    rows = serving_rows(full)
    perf = {"serving": rows}
    print("serving:", json.dumps(rows))
    path = write_bench_json({"perf": perf}, tag)
    print(f"wrote {path}")
    return rows


if __name__ == "__main__":
    main()
