"""Chunked streaming engine: round-trips, v1/v2 container compatibility,
per-chunk error bounds, streaming == one-shot, adaptive selection."""
import io

import numpy as np
import pytest

from repro.core import (
    ChunkedCompressor,
    CompressionConfig,
    ErrorBoundMode,
    compress_stream,
    decompress,
    decompress_chunk,
    decompress_stream,
    frames_to_blob,
    parse_header,
    read_frames,
    select_pipeline,
    sz3_lorenzo,
    sz3_lr,
    write_frames,
)
from repro.core.chunking import DEFAULT_CANDIDATES, chunk_slices


def _smooth(shape, dtype, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(shape)
    for ax in range(x.ndim):
        x = np.cumsum(x, axis=ax) / np.sqrt(x.shape[ax])
    return x.astype(dtype)


def _gamess_like(n_blocks=1500, pattern=96, seed=7):
    """Periodic pattern scaled per block (the paper's GAMESS ERI structure)."""
    rng = np.random.default_rng(seed)
    t = np.linspace(0, 1, pattern)
    base = np.exp(-6 * t) * np.sin(24 * t)
    scales = np.exp(rng.normal(-2.0, 1.0, n_blocks))
    x = scales[:, None] * base[None, :] + rng.normal(0, 1e-4, (n_blocks, pattern))
    return x.reshape(-1)


# ---------------------------------------------------------------------------
# chunk geometry
# ---------------------------------------------------------------------------

def test_chunk_slices_cover_exactly():
    slices = chunk_slices((100, 7), itemsize=8, chunk_bytes=7 * 8 * 9)
    rows = [s.stop - s.start for s in slices]
    assert sum(rows) == 100
    assert all(r <= 9 for r in rows)
    assert slices[0].start == 0 and slices[-1].stop == 100


def test_chunk_slices_huge_row_still_one_row():
    # a single row larger than the budget must still make progress
    slices = chunk_slices((4, 1000), itemsize=8, chunk_bytes=16)
    assert len(slices) == 4


# ---------------------------------------------------------------------------
# round-trips
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [np.float32, np.float64])
@pytest.mark.parametrize("shape", [(4000,), (120, 40), (24, 20, 18)])
def test_roundtrip_dtypes_shapes_multichunk(dtype, shape):
    x = _smooth(shape, dtype)
    eng = ChunkedCompressor(chunk_bytes=x.nbytes // 5)  # force ~5 chunks
    conf = CompressionConfig(mode=ErrorBoundMode.ABS, eb=1e-3)
    res = eng.compress(x, conf, with_stats=True)
    assert len(res.meta["chunks"]) >= 4
    xhat = decompress(res.blob)
    assert xhat.shape == x.shape and xhat.dtype == x.dtype
    assert np.abs(x.astype(np.float64) - xhat.astype(np.float64)).max() <= 1e-3


def test_roundtrip_int_input_casts_like_v1():
    x = np.arange(5000, dtype=np.int32).reshape(50, 100)
    res = ChunkedCompressor(chunk_bytes=4000).compress(
        x, CompressionConfig(mode=ErrorBoundMode.ABS, eb=0.5)
    )
    xhat = decompress(res.blob)
    assert xhat.dtype == np.float32  # same cast rule as the v1 driver
    assert np.abs(x - xhat.astype(np.float64)).max() <= 0.5


def test_error_bound_preserved_per_chunk_rel_mode():
    # REL resolves against GLOBAL range; every chunk must honour that bound
    x = _smooth((300, 64), np.float64, seed=3)
    x[200:] *= 50.0  # chunks with very different local ranges
    conf = CompressionConfig(mode=ErrorBoundMode.REL, eb=1e-4)
    abs_eb = 1e-4 * (x.max() - x.min())
    res = ChunkedCompressor(chunk_bytes=x.nbytes // 6).compress(x, conf)
    xhat = decompress(res.blob)
    assert np.abs(x - xhat).max() <= abs_eb * (1 + 1e-12)


# ---------------------------------------------------------------------------
# container compatibility
# ---------------------------------------------------------------------------

def test_v1_blobs_still_decode():
    x = _smooth((40, 40), np.float32)
    conf = CompressionConfig(mode=ErrorBoundMode.ABS, eb=1e-3)
    blob = sz3_lorenzo().compress(x, conf).blob
    header, _ = parse_header(blob)
    assert header["v"] == 1
    xhat = decompress(blob)
    assert np.abs(x.astype(np.float64) - xhat.astype(np.float64)).max() <= 1e-3


def test_v2_header_records_chunk_table():
    x = _smooth((200, 32), np.float64)
    res = ChunkedCompressor(chunk_bytes=x.nbytes // 4).compress(x, None)
    header, body_off = parse_header(res.blob)
    assert header["v"] == 2 and header["kind"] == "chunked"
    chunks = header["chunks"]
    assert sum(c["n0"] for c in chunks) == 200
    # offsets tile the body exactly
    assert chunks[0]["off"] == 0
    for a, b in zip(chunks, chunks[1:]):
        assert b["off"] == a["off"] + a["len"]
    # chunks tile the DECLARED body exactly; the integrity trailer (if
    # written) sits beyond it, so compare against blen rather than len(blob)
    blen = int.from_bytes(res.blob[12:20], "little")
    assert chunks[-1]["off"] + chunks[-1]["len"] == blen
    assert body_off + blen <= len(res.blob)
    for c in chunks:
        assert c["pipeline"] in DEFAULT_CANDIDATES


def test_random_access_single_chunk():
    x = _smooth((160, 48), np.float64)
    eng = ChunkedCompressor(chunk_bytes=x.nbytes // 4)
    res = eng.compress(x)
    header, _ = parse_header(res.blob)
    full = decompress(res.blob)
    row = 0
    for i, c in enumerate(header["chunks"]):
        part = decompress_chunk(res.blob, i)
        np.testing.assert_array_equal(part, full[row : row + c["n0"]])
        row += c["n0"]


# ---------------------------------------------------------------------------
# streaming
# ---------------------------------------------------------------------------

def test_stream_equals_one_shot_blob_and_data():
    x = _smooth((300, 50), np.float64, seed=11)
    conf = CompressionConfig(mode=ErrorBoundMode.ABS, eb=1e-3)
    cb = x.nbytes // 5
    one_shot = ChunkedCompressor(chunk_bytes=cb).compress(x, conf)
    frames = list(compress_stream(x, conf, chunk_bytes=cb))
    assert frames_to_blob(frames) == one_shot.blob
    parts = list(decompress_stream(frames))
    np.testing.assert_array_equal(np.concatenate(parts), decompress(one_shot.blob))


def test_stream_file_roundtrip_bounded_frames():
    x = _smooth((256, 32), np.float32, seed=5)
    conf = CompressionConfig(mode=ErrorBoundMode.ABS, eb=1e-2)
    buf = io.BytesIO()
    write_frames(compress_stream(x, conf, chunk_bytes=x.nbytes // 8), buf)
    buf.seek(0)
    parts = list(decompress_stream(read_frames(buf)))
    assert len(parts) >= 8
    xhat = np.concatenate(parts)
    assert np.abs(x.astype(np.float64) - xhat.astype(np.float64)).max() <= 1e-2


def test_stream_of_slabs_roundtrips():
    slabs = [_smooth((64, 16), np.float64, seed=s) for s in range(3)]
    conf = CompressionConfig(mode=ErrorBoundMode.ABS, eb=1e-3)
    frames = list(compress_stream(iter(slabs), conf, chunk_bytes=1 << 13))
    xhat = np.concatenate(list(decompress_stream(frames)))
    x = np.concatenate(slabs)
    assert np.abs(x - xhat).max() <= 1e-3


# ---------------------------------------------------------------------------
# adaptive selection
# ---------------------------------------------------------------------------

def test_select_pipeline_returns_candidate_and_scores():
    x = _smooth((64, 64), np.float64)
    name, scores = select_pipeline(
        x, 1e-3, CompressionConfig(mode=ErrorBoundMode.ABS, eb=1e-3)
    )
    assert name in DEFAULT_CANDIDATES
    assert set(scores) <= set(DEFAULT_CANDIDATES)


def test_heterogeneous_data_gets_heterogeneous_pipelines():
    rng = np.random.default_rng(0)
    smooth = _smooth((150, 64), np.float64)
    noise = rng.standard_normal((150, 64)) * 30.0
    x = np.concatenate([smooth, noise])
    res = ChunkedCompressor(chunk_bytes=x.nbytes // 10).compress(
        x, CompressionConfig(mode=ErrorBoundMode.ABS, eb=1e-3), with_stats=True
    )
    picked = {c["pipeline"] for c in res.meta["chunks"]}
    assert len(picked) >= 2, res.meta["chunks"]
    xhat = decompress(res.blob)
    assert np.abs(x - xhat).max() <= 1e-3


def test_chunked_ratio_matches_one_shot_on_gamess_like():
    # acceptance criterion: abs eb 1e-3, ratio within +-5% of the one-shot
    # pipeline (and the bound verified)
    x = _gamess_like()
    conf = CompressionConfig(mode=ErrorBoundMode.ABS, eb=1e-3)
    res = ChunkedCompressor(chunk_bytes=x.nbytes // 4).compress(x, conf)
    xhat = decompress(res.blob)
    assert np.abs(x - xhat).max() <= 1e-3
    best_one_shot = max(
        sz3_lorenzo().compress(x, conf).ratio, sz3_lr().compress(x, conf).ratio
    )
    assert res.ratio >= 0.95 * best_one_shot, (res.ratio, best_one_shot)


# ---------------------------------------------------------------------------
# integration: checkpoint codec
# ---------------------------------------------------------------------------

def test_checkpoint_leaf_uses_chunked_codec_and_roundtrips():
    from repro.ft.checkpoint import LeafPolicy, decode_leaf, encode_leaf

    x = _smooth((1024, 1024), np.float32, seed=2)  # 4 MiB -> chunked codec
    blob, meta = encode_leaf(x, LeafPolicy("lossy", 1e-4))
    # big leaves ride the hybrid (prediction+transform) chunked codec; the
    # legacy "sz3_chunked_rel" tag still decodes (decode_leaf accepts both)
    assert meta["codec"] == "sz3_auto_rel"
    legacy_meta = dict(meta, codec="sz3_chunked_rel")
    assert np.array_equal(decode_leaf(blob, legacy_meta), decode_leaf(blob, meta))
    xhat = decode_leaf(blob, meta)
    assert xhat.shape == x.shape and xhat.dtype == x.dtype
    abs_eb = 1e-4 * float(x.max() - x.min())
    assert np.abs(x.astype(np.float64) - xhat.astype(np.float64)).max() <= abs_eb

    small = _smooth((64, 64), np.float32, seed=2)  # stays on the v1 codec
    blob, meta = encode_leaf(small, LeafPolicy("lossy", 1e-4))
    assert meta["codec"] == "sz3_lorenzo_rel"
    decode_leaf(blob, meta)


# ---------------------------------------------------------------------------
# strided probe sampling (the piecewise-selection bias fix)
# ---------------------------------------------------------------------------

def _piecewise_chunk(n=1 << 18, seed=7):
    """Oscillatory edges, smooth centre: a single centred probe sees ONLY the
    smooth regime and mis-ranks candidates for the 2/3 of the chunk that is
    oscillatory (transform's home turf)."""
    rng = np.random.default_rng(seed)
    t = np.arange(n, dtype=np.float64)
    x = (
        np.sin(0.91 * np.pi * t)
        + 0.05 * np.cumsum(rng.standard_normal(n)) / np.sqrt(n)
    ).astype(np.float32)
    mid = slice(n // 2 - 20000, n // 2 + 20000)
    x[mid] = (np.cumsum(rng.standard_normal(40000)) * 0.01).astype(np.float32)
    return x


def test_sample_block_probes_span_piecewise_regimes():
    """The sample must contain material from the chunk's edges, not just its
    centre: the oscillatory edges have O(1) point-to-point jumps, the smooth
    centre has O(1e-2) ones."""
    from repro.core.chunking import SAMPLE_BUDGET, _sample_block

    x = _piecewise_chunk()
    s = _sample_block(x)
    assert s.size <= SAMPLE_BUDGET
    assert np.abs(np.diff(s.astype(np.float64))).max() > 0.5, (
        "sample saw no oscillatory content — probe placement regressed to "
        "the centre-only block"
    )
    # determinism: same chunk -> same sample (parallel byte-identity relies
    # on selection being a pure function of the chunk)
    assert np.array_equal(s, _sample_block(x))


def test_strided_probes_fix_piecewise_selection_bias():
    """Regression pin for the centred-sample bias: on the piecewise fixture
    the full-array best candidate is the transform coder; multi-probe
    sampling must rank it first, while the old single centred probe
    (probes=1) demonstrably picks a smooth-regime pipeline instead."""
    from repro.core.chunking import _sample_block
    from repro.core.transform import AUTO_CANDIDATES

    x = _piecewise_chunk()
    conf = CompressionConfig(mode=ErrorBoundMode.ABS, eb=1e-3)
    winner, _ = select_pipeline(x, 1e-3, conf, AUTO_CANDIDATES)
    assert winner == "sz3_transform", winner
    # the old behaviour is _sample_block with a single probe: its sample is
    # entirely smooth-centre data, so transform cannot win there
    old_sample = _sample_block(x, probes=1)
    assert np.abs(np.diff(old_sample.astype(np.float64))).max() < 0.5


def test_sample_block_shapes_and_budget():
    from repro.core.chunking import SAMPLE_BUDGET, _sample_block

    for shape in [(64, 64, 64), (1, 1 << 20), (1 << 20,), (4096,), (10, 10)]:
        arr = np.zeros(shape, np.float32)
        s = _sample_block(arr)
        assert s.ndim == arr.ndim
        assert s.size <= max(arr.size, SAMPLE_BUDGET)
        if arr.size > SAMPLE_BUDGET:
            assert s.size >= SAMPLE_BUDGET // 2, (shape, s.shape)
