"""Golden-blob conformance: committed v1/v2/v3/v4/v5 containers must keep
decoding to bit-identical payloads.

The corpus under ``tests/data/`` (see ``gen_conformance.py`` there) pins one
blob per container generation; any change to a decode path, a header field
default, a side-channel layout, or a predictor's reconstruction arithmetic
that alters the meaning of an ALREADY-WRITTEN stream fails here — old streams
in the wild cannot be re-encoded.
"""
import pathlib

import numpy as np
import pytest

from repro.core import decompress, parse_header
from repro.core.chunking import decompress_chunk

DATA = pathlib.Path(__file__).resolve().parent / "data"

CORPUS = sorted(p.stem for p in DATA.glob("*.sz3"))

#: every container generation must stay represented — deleting a corpus pair
#: must fail the suite, not silently shrink coverage
EXPECTED_GENERATIONS = {
    "v1_lorenzo_abs": (1, None),
    "v1_lr_rel": (1, None),
    "v1_log_pwrel": (1, None),
    "v2_chunked_rel": (2, "chunked"),
    "v2_quality_psnr": (2, "chunked"),
    "v3_transform_abs": (3, "transform"),
    "v4_pwr": (4, "pwr"),
    "v5_hybrid_mixed_abs": (5, "hybrid"),
    "v5_hybrid_const_rel": (5, "hybrid"),
    "v6_fast_mixed_abs": (6, "fast"),
    "v6_fast_const_rel": (6, "fast"),
}


def test_corpus_complete():
    missing = set(EXPECTED_GENERATIONS) - set(CORPUS)
    assert not missing, f"conformance corpus entries missing: {sorted(missing)}"


@pytest.mark.parametrize("name", CORPUS)
def test_decode_bit_exact(name):
    blob = (DATA / f"{name}.sz3").read_bytes()
    expected = np.load(DATA / f"{name}.npy")
    out = decompress(blob)
    assert out.dtype == expected.dtype, f"{name}: dtype drifted"
    assert out.shape == expected.shape, f"{name}: shape drifted"
    assert out.tobytes() == expected.tobytes(), (
        f"{name}: decoded payload is no longer bit-identical — a decode path "
        "changed the meaning of an already-written stream"
    )


@pytest.mark.parametrize("name", sorted(EXPECTED_GENERATIONS))
def test_header_generation_stable(name):
    version, kind = EXPECTED_GENERATIONS[name]
    header, body_off = parse_header((DATA / f"{name}.sz3").read_bytes())
    assert header.get("v", 1) == version
    if kind is not None:
        assert header["kind"] == kind
    assert body_off > 20


@pytest.mark.parametrize("name", ["v2_chunked_rel", "v2_quality_psnr", "v4_pwr"])
def test_multi_chunk_random_access(name):
    """Per-chunk random access must reproduce the same bytes as full decode."""
    blob = (DATA / f"{name}.sz3").read_bytes()
    header, _ = parse_header(blob)
    expected = np.load(DATA / f"{name}.npy")
    parts = [decompress_chunk(blob, i) for i in range(len(header["chunks"]))]
    assert len(parts) > 1, f"{name}: corpus blob should be multi-chunk"
    joined = np.concatenate(parts, axis=0).astype(expected.dtype)
    assert joined.reshape(expected.shape).tobytes() == expected.tobytes()


def test_v5_hybrid_side_channels_pinned():
    """The v5 header must keep carrying the per-block predictor-tag array
    (2 bits/block) and the regression coefficient streams: the mixed-regime
    corpus blob exercises every tag, so any layout drift fails here."""
    header, _ = parse_header((DATA / "v5_hybrid_mixed_abs.sz3").read_bytes())
    assert header["spec"]["kind"] == "hybrid"
    hm = header["hyb_meta"]
    assert hm["bs"] == 16
    assert all(c > 0 for c in hm["counts"]), (
        f"corpus blob no longer exercises every predictor tag: {hm['counts']}"
    )
    assert hm["n_reg"] == hm["counts"][3] > 0  # coefficient streams present
    assert header["tag_len"] == (hm["nb"] + 3) // 4
    # the constant-block fixture must keep hitting the zero fast path
    h2, _ = parse_header((DATA / "v5_hybrid_const_rel.sz3").read_bytes())
    assert h2["hyb_meta"]["counts"][0] > 0


def test_quality_records_survive_in_v2_container():
    """The quality container is a plain v2 blob whose chunk table carries
    achieved-quality records; both the records and the summary must parse."""
    header, _ = parse_header((DATA / "v2_quality_psnr.sz3").read_bytes())
    assert header["quality"]["target"] == {"kind": "psnr", "value": 50.0}
    assert header["quality"]["achieved_psnr"] >= 49.0
    for chunk in header["chunks"]:
        assert {"eb", "mse", "psnr", "bits"} <= set(chunk["q"])
