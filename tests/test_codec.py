"""Tests for the numcodecs-compatible codec facade (``repro.codec``).

The ``Sz3Codec`` class works as a plain object without numcodecs installed
(encode/decode/get_config/from_config are self-contained), so the contract
tests below always run; the zarr round-trip integration test is gated on the
optional stack being importable.
"""
import numpy as np
import pytest

from repro.codec import Sz3Codec


def _smooth(shape, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(shape)
    for ax in range(x.ndim):
        x = np.cumsum(x, axis=ax)
    return np.ascontiguousarray(x.astype(dtype))


# ---------------------------------------------------------------------------
# plain-object contract (no numcodecs required)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "kwargs,tol_of",
    [
        ({"eb_mode": "abs", "eb_abs": 1e-3}, lambda x: 1e-3),
        ({"eb_mode": "rel", "eb_rel": 1e-4}, lambda x: 1e-4 * np.ptp(x)),
        (
            {"eb_mode": "abs-and-rel", "eb_abs": 1e-3, "eb_rel": 1e-4},
            lambda x: min(1e-3, 1e-4 * np.ptp(x)),
        ),
        (
            {"eb_mode": "abs", "eb_abs": 1e-3, "predictor": "fast"},
            lambda x: 1e-3,
        ),
        (
            {"eb_mode": "abs", "eb_abs": 1e-3, "predictor": "hybrid"},
            lambda x: 1e-3,
        ),
    ],
)
def test_encode_decode_bound(kwargs, tol_of):
    codec = Sz3Codec(**kwargs)
    x = _smooth((64, 48), seed=3)
    out = np.asarray(codec.decode(codec.encode(x)))
    assert out.shape == x.shape and out.dtype == x.dtype
    tol = tol_of(np.asarray(x, np.float64))
    assert np.abs(out.astype(np.float64) - x).max() <= tol * (1 + 1e-6)


def test_pw_rel_bound_nonzero_pointwise():
    codec = Sz3Codec(eb_mode="pw_rel", eb_rel=1e-3)
    rng = np.random.default_rng(5)
    x = np.exp(rng.normal(0, 2, 4000)).astype(np.float32)
    x[rng.random(4000) < 0.3] *= -1
    x[::97] = 0.0
    out = np.asarray(codec.decode(codec.encode(x)))
    nz = x != 0
    rel = np.abs(out[nz].astype(np.float64) - x[nz]) / np.abs(x[nz])
    assert rel.max() <= 1e-3 * (1 + 1e-6)
    assert np.all(out[~nz] == 0.0)


def test_decode_into_out_buffer():
    codec = Sz3Codec(eb_mode="abs", eb_abs=1e-3)
    x = _smooth((1000,), seed=1)
    blob = codec.encode(x)
    out = np.empty_like(x)
    ret = codec.decode(blob, out=out)
    assert ret is out
    assert np.abs(out - x).max() <= 1e-3 * (1 + 1e-6)
    buf = bytearray(x.nbytes)
    codec.decode(blob, out=buf)
    assert np.abs(np.frombuffer(buf, x.dtype) - x).max() <= 1e-3 * (1 + 1e-6)


def test_config_roundtrip_identity():
    codec = Sz3Codec(
        eb_mode="abs-or-rel", eb_abs=2e-3, eb_rel=1e-5, predictor="fast"
    )
    cfg = codec.get_config()
    assert cfg["id"] == "repro.sz3"
    clone = Sz3Codec.from_config(cfg)
    assert clone.get_config() == cfg
    x = _smooth((500,), seed=2)
    assert clone.decode(codec.encode(x)) is not None


@pytest.mark.parametrize(
    "bad",
    [
        {"eb_mode": "nope"},
        {"predictor": "nope"},
        {"eb_mode": "abs-and-rel"},  # composite without eb_rel
        {"eb_mode": "psnr"},  # psnr without eb_psnr
    ],
)
def test_validation_rejections(bad):
    with pytest.raises(ValueError):
        Sz3Codec(**bad)


def test_non_float_buffer_rejected():
    codec = Sz3Codec()
    with pytest.raises((TypeError, ValueError)):
        codec.encode(np.array(["a", "b"]))


# ---------------------------------------------------------------------------
# zarr integration (optional stack)
# ---------------------------------------------------------------------------
def test_zarr_roundtrip():
    pytest.importorskip("numcodecs")
    zarr = pytest.importorskip("zarr")

    x = _smooth((128, 96), seed=9)
    codec = Sz3Codec(eb_mode="abs", eb_abs=1e-3, predictor="fast")
    try:
        z = zarr.array(x, chunks=(64, 48), compressor=codec)
    except TypeError:  # zarr v3 spells the kwarg differently
        z = zarr.array(x, chunks=(64, 48), compressors=[codec])
    out = np.asarray(z[:])
    assert out.shape == x.shape
    assert np.abs(out.astype(np.float64) - x).max() <= 1e-3 * (1 + 1e-6)
