"""Fault-injection resilience suite (PR7 acceptance).

Drives the deterministic mutation grid (``repro.core.faults``) over real
containers from every generation and enforces the decode contract:

    for ANY mutated blob, decode must (a) return the exact pristine result,
    (b) raise a typed ``ValueError`` subclass, or (c) in salvage mode,
    return data plus a ``SalvageReport`` — never a hang, an unbounded
    allocation, a raw ``struct.error``/``KeyError``/``IndexError``, or
    silently wrong bytes while checksums are on.

Also pins: the committed corrupted-blob fixtures (strict error AND the
exact recovered/lost chunk sets in salvage mode), the malformed-input error
contract across entry points, trailer semantics (strip detection, legacy
blobs), worker-timeout degradation, stream verification, and checkpoint
partial restore.  A hypothesis fuzz lane explores beyond the grid when
hypothesis is installed (CI's [test] extra has it; the lane is additive).
"""
import io
import json
import pathlib
import threading
import time
import zlib

import numpy as np
import pytest

from repro.core import (
    CompressionConfig,
    ContainerError,
    ErrorBoundMode,
    IntegrityError,
    SalvageReport,
    decompress,
    decompress_chunk,
    faults,
    integrity,
    parse_header,
    read_frames,
    sz3_chunked,
    sz3_fast,
    sz3_hybrid,
    sz3_lorenzo,
    sz3_pwr,
    sz3_transform,
    sz3_truncation,
    verify_blob,
)
from repro.core.chunking import (
    ChunkedCompressor,
    _parallel_map_ordered,
    compress_stream,
    decompress_stream,
)

DATA = pathlib.Path(__file__).parent / "data" / "faults"

ABS = CompressionConfig(mode=ErrorBoundMode.ABS, eb=1e-3)
REL = CompressionConfig(mode=ErrorBoundMode.REL, eb=1e-3)
PWR = CompressionConfig(mode=ErrorBoundMode.PW_REL, eb=1e-3)

# decode of a few-KB blob must never take longer than this, mutated or not
TIME_BUDGET_S = 10.0


def _smooth(shape, seed, dtype=np.float32):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=shape)
    for ax in range(len(shape)):
        x = np.cumsum(x, axis=ax) / np.sqrt(shape[ax])
    return x.astype(dtype)


def _pwr_field(seed):
    w = np.exp(_smooth((48, 16), seed, np.float64))
    w[3, 3] = 0.0
    w[::7, 2] *= -1
    return w


@pytest.fixture(scope="module")
def containers():
    """One freshly written container per generation (trailers on)."""
    osc = (
        np.sin(0.9 * np.pi * np.arange(1200)) + 0.05 * _smooth((1200,), 31)
    ).astype(np.float32)
    return {
        "v1": sz3_lorenzo().compress(_smooth((32, 20), 32), ABS).blob,
        "v1t": sz3_truncation(2).compress(_smooth((30, 16), 33), REL).blob,
        "v2": sz3_chunked(chunk_bytes=2048).compress(_smooth((40, 28), 34), REL).blob,
        "v3": sz3_transform().compress(osc, ABS).blob,
        "v4": sz3_pwr(eb=1e-3, chunk_bytes=4096).compress(_pwr_field(35), PWR).blob,
        "v5": sz3_hybrid().compress(_smooth((48, 48), 36), ABS).blob,
        "v6": sz3_fast().compress(
            np.cumsum(_smooth((1100,), 37)).astype(np.float32), ABS
        ).blob,
    }


def _contract(pristine_out, mutated, verify):
    """Assert the decode contract on one mutated blob; returns a tag."""
    t0 = time.perf_counter()
    try:
        got = decompress(mutated, verify=verify)
    except ValueError:
        tag = "typed-error"
    except MemoryError:
        pytest.fail(f"unbounded allocation attempted (verify={verify})")
    else:
        if verify == "salvage":
            data, report = got
            assert isinstance(report, SalvageReport)
            tag = "salvage-report" if not report.ok else "decode"
            got = data
        else:
            tag = "decode"
        if verify == "strict" and tag == "decode":
            # strict success while checksums are on => bytes must be right
            assert got.dtype == pristine_out.dtype
            assert got.shape == pristine_out.shape
            assert np.array_equal(
                got.view(np.uint8) if got.dtype.kind == "V" else got,
                pristine_out,
            ), "strict decode of a corrupt blob returned WRONG bytes"
    assert time.perf_counter() - t0 < TIME_BUDGET_S, "decode contract: too slow"
    return tag


@pytest.mark.parametrize("gen", ["v1", "v1t", "v2", "v3", "v4", "v5", "v6"])
def test_mutation_grid_contract(containers, gen):
    blob = containers[gen]
    pristine = decompress(blob, verify="strict")
    n = strict_errors = 0
    for name, mut in faults.mutation_grid(blob, seed=7):
        assert mut != blob, f"grid yielded identity mutation {name}"
        n += 1
        for verify in ("strict", "salvage", "off"):
            tag = _contract(pristine, mut, verify)
            if verify == "strict" and tag == "typed-error":
                strict_errors += 1
    assert n >= 15, "mutation grid unexpectedly small"
    # the grid flips real bytes in checksummed regions: the strict lane must
    # actually be catching things, not vacuously passing
    assert strict_errors >= n // 2


@pytest.mark.parametrize("gen", ["v1", "v2", "v3", "v4", "v5", "v6"])
def test_strict_names_the_damage(containers, gen):
    """A body bit-flip under strict decode raises IntegrityError (not just
    any ValueError): the checksum layer, not a downstream parse accident,
    is what reports it."""
    blob = containers[gen]
    _, body_off = parse_header(blob)
    body_len = integrity._declared_body_len(blob)
    mut = faults.bit_flip(blob, body_off + body_len // 2, 4)
    with pytest.raises(IntegrityError):
        decompress(mut, verify="strict")


def test_trailer_roundtrip_and_strip_detection(containers):
    blob = containers["v2"]
    assert verify_blob(blob) is True  # trailer present, every checksum good
    header, body_off = parse_header(blob)
    res = integrity.inspect(blob, header, body_off)
    assert res.has_trailer and res.ok and res.bad_chunks in (None, [])
    # stripping the trailer is a downgrade attack: the header's itg flag
    # survives (it is under the header CRC), so strict decode refuses
    tr = integrity.read_trailer(blob)
    stripped = blob[: tr.start]
    with pytest.raises(IntegrityError, match="trailer"):
        decompress(stripped, verify="strict")
    # verify="off" still decodes the stripped blob (the trailer is additive)
    np.testing.assert_array_equal(
        decompress(stripped, verify="off"), decompress(blob, verify="strict")
    )


def test_legacy_blobs_decode_unverified():
    """Pre-trailer containers (no itg flag, no trailer) stay decodable under
    every verify mode — the trailer is backward compatible."""
    x = _smooth((24, 12), 40)
    with integrity.trailers_disabled():
        blob = sz3_lorenzo().compress(x, ABS).blob
    assert integrity.read_trailer(blob) is None
    strict = decompress(blob, verify="strict")
    off = decompress(blob, verify="off")
    data, report = decompress(blob, verify="salvage")
    np.testing.assert_array_equal(strict, off)
    np.testing.assert_array_equal(strict, data)
    assert report.ok and not report.checksummed


def test_trailer_is_byte_deterministic():
    x = _smooth((20, 20), 41)
    b1 = sz3_chunked(chunk_bytes=1024).compress(x, REL).blob
    b2 = sz3_chunked(chunk_bytes=1024).compress(x, REL).blob
    assert b1 == b2


# ---------------------------------------------------------------------------
# committed corrupted-blob fixtures: strict error + salvage sets, pinned
# ---------------------------------------------------------------------------

FIXTURES = sorted(
    p.stem[: -len("_corrupt")] for p in DATA.glob("*_corrupt.sz3")
)


def _manifest():
    return json.loads((DATA / "manifest.json").read_text())


def test_fixture_corpus_complete():
    man = _manifest()
    gens = {man[n]["generation"] for n in FIXTURES}
    assert gens == {"v1", "v2", "v3", "v4", "v5", "v6"}


@pytest.mark.parametrize("name", FIXTURES)
def test_fixture_pristine_decodes_strict(name):
    blob = (DATA / f"{name}.sz3").read_bytes()
    want = np.load(DATA / f"{name}.npy")
    got = decompress(blob, verify="strict")
    assert got.dtype == want.dtype and got.shape == want.shape
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("name", FIXTURES)
def test_fixture_corrupt_strict_raises(name):
    corrupt = (DATA / f"{name}_corrupt.sz3").read_bytes()
    with pytest.raises(IntegrityError):
        decompress(corrupt, verify="strict")


@pytest.mark.parametrize("name", FIXTURES)
def test_fixture_corrupt_salvage_sets(name):
    man = _manifest()[name]
    corrupt = (DATA / f"{name}_corrupt.sz3").read_bytes()
    pristine = np.load(DATA / f"{name}.npy")
    data, report = decompress(corrupt, verify="salvage")
    assert isinstance(report, SalvageReport)
    assert not report.ok and report.checksummed
    damaged = sorted(d.index for d in report.damage)
    if "damaged_chunks" in man:  # v2/v4 multi-chunk: exact set pinned
        assert damaged == man["damaged_chunks"]
        assert sorted(report.recovered) == sorted(
            set(range(man["n_chunks"])) - set(man["damaged_chunks"])
        )
    else:  # single-body generations: all-or-nothing
        assert damaged == [0] and report.recovered == []
    # recovered elements byte-exact, lost elements zero-filled
    lost = np.zeros(pristine.size, dtype=bool)
    for a, b in report.lost_ranges():
        lost[a:b] = True
    flat_got, flat_want = data.ravel(), pristine.ravel()
    np.testing.assert_array_equal(flat_got[~lost], flat_want[~lost])
    assert not flat_got[lost].any()


# ---------------------------------------------------------------------------
# satellite: error contract — malformed input raises ValueError subclasses
# ---------------------------------------------------------------------------

MALFORMED = {
    "empty": b"",
    "short": b"SZ3",
    "bad-magic": b"XXXX" + b"\x00" * 40,
    "garbage": bytes(range(256)) * 2,
    "magic-only": b"SZ3J",
    "negative-lengths": b"SZ3J" + (-5).to_bytes(8, "little", signed=True) * 2,
    "huge-lengths": b"SZ3J" + (1 << 60).to_bytes(8, "little") * 2,
    "truncated-header": b"SZ3J"
    + (100).to_bytes(8, "little")
    + (0).to_bytes(8, "little")
    + b"\x81",
}


@pytest.mark.parametrize("case", sorted(MALFORMED))
@pytest.mark.parametrize(
    "entry",
    [
        lambda b: decompress(b),
        lambda b: decompress(b, verify="off"),
        lambda b: decompress(b, verify="salvage"),
        lambda b: parse_header(b),
        lambda b: decompress_chunk(b, 0),
        lambda b: verify_blob(b),
    ],
    ids=["decompress", "off", "salvage", "parse_header", "chunk", "verify"],
)
def test_malformed_error_contract(case, entry):
    blob = MALFORMED[case]
    with pytest.raises(ValueError):
        entry(blob)


@pytest.mark.parametrize("gen", ["v1", "v2", "v3", "v4", "v5", "v6"])
def test_truncation_ladder_error_contract(containers, gen):
    """Every truncation point of a real blob raises a typed error (or, for
    cuts beyond the checksummed core, may still decode) — never a raw
    struct/index/key error."""
    blob = containers[gen]
    for keep in (0, 3, 4, 12, 19, 20, 21, len(blob) // 2, len(blob) - 1):
        cut = blob[:keep]
        try:
            decompress(cut, verify="strict")
        except ValueError:
            pass


def test_inflated_lengths_do_not_allocate(containers):
    """Hostile size claims must be rejected against the real blob length
    before any allocation happens (bounded by MAX_OUTPUT_BYTES at most)."""
    for gen, blob in containers.items():
        for which in ("header", "body"):
            mut = faults.inflate_length(blob, which, factor=1 << 30)
            with pytest.raises(ValueError):
                decompress(mut, verify="off")


def test_corrupt_frame_stream_rejected():
    neg = (-1).to_bytes(8, "little", signed=True)
    with pytest.raises(ContainerError):
        list(read_frames(io.BytesIO(neg)))
    huge = (1 << 60).to_bytes(8, "little")
    with pytest.raises(ContainerError):
        list(read_frames(io.BytesIO(huge)))
    with pytest.raises(ContainerError):
        list(read_frames(io.BytesIO((100).to_bytes(8, "little") + b"xy")))


# ---------------------------------------------------------------------------
# streaming verify
# ---------------------------------------------------------------------------

def test_stream_verify_strict_and_salvage():
    x = _smooth((64, 16), 50)
    frames = list(compress_stream(x, REL, chunk_bytes=1024))
    # corrupt the middle payload frame
    payload = [i for i, f in enumerate(frames) if f[:4] == b"SZ3J"]
    k = payload[len(payload) // 2]
    bad = list(frames)
    _, body_off = parse_header(frames[k])
    bad[k] = faults.bit_flip(frames[k], body_off + 4, 2)
    with pytest.raises(IntegrityError):
        list(decompress_stream(bad))
    # salvage: the damaged frame zero-fills and reports; the rest decode
    out = list(decompress_stream(bad, verify="salvage"))
    reports = [r for _, r in out]
    assert sum(not r.ok for r in reports) == 1
    good = list(decompress_stream(frames))
    for i, ((arr, rep), want) in enumerate(zip(out, good)):
        if rep.ok:
            np.testing.assert_array_equal(arr, want)
        else:
            assert not arr.any()


# ---------------------------------------------------------------------------
# worker timeout -> degrade-to-serial
# ---------------------------------------------------------------------------

def test_parallel_map_timeout_degrades_to_serial():
    calls = []
    lock = threading.Lock()

    def fn(x):
        with lock:
            first = not calls
            calls.append(x)
        if first:
            time.sleep(0.5)  # only the first (pool) execution stalls
        return x * 2

    out = list(_parallel_map_ordered(fn, range(8), workers=2, timeout=0.05))
    assert out == [x * 2 for x in range(8)]


def test_chunk_timeout_roundtrip():
    x = _smooth((48, 24), 51)
    eng = ChunkedCompressor(chunk_bytes=2048, workers=2, chunk_timeout=60.0)
    res = eng.compress(x, REL)
    np.testing.assert_array_equal(
        decompress(res.blob, verify="strict"),
        decompress(sz3_chunked(chunk_bytes=2048).compress(x, REL).blob),
    )


# ---------------------------------------------------------------------------
# checkpoint: per-leaf checksums, partial restore, bounded I/O retry
# ---------------------------------------------------------------------------

def _ckpt_roundtrip(tmp_path):
    from repro.ft.checkpoint import CheckpointManager

    state = {
        "w": _smooth((16, 16), 60),
        "b": np.ones(16, np.float32),
        "m": _smooth((128,), 61),
    }
    mgr = CheckpointManager(tmp_path, use_async=False)
    mgr.save(1, state)
    return mgr, state


def _leaf_file(tmp_path, key_fragment):
    d = tmp_path / "step_1"
    man = json.loads((d / "manifest.json").read_text())
    key = next(k for k in man["leaves"] if key_fragment in k)
    return d / man["leaves"][key]["file"]


def test_checkpoint_leaf_checksum_strict(tmp_path):
    mgr, state = _ckpt_roundtrip(tmp_path)
    f = _leaf_file(tmp_path, "w")
    blob = bytearray(f.read_bytes())
    blob[len(blob) // 2] ^= 0x40
    f.write_bytes(bytes(blob))
    with pytest.raises(IntegrityError, match="checksum"):
        mgr.restore(state)


def test_checkpoint_partial_restore_refills(tmp_path):
    mgr, state = _ckpt_roundtrip(tmp_path)
    f = _leaf_file(tmp_path, "w")
    blob = bytearray(f.read_bytes())
    blob[len(blob) // 2] ^= 0x40
    f.write_bytes(bytes(blob))
    got, extra, report = mgr.restore(state, salvage=True)
    assert not report.ok
    assert [r for _, r in report.refilled] == ["checksum"]
    # damaged leaf refilled from the template's own value
    np.testing.assert_array_equal(got["w"], state["w"])
    np.testing.assert_array_equal(got["b"], state["b"])
    # shape-only template -> zeros
    import jax

    tmpl = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state
    )
    got, extra, report = mgr.restore(tmpl, salvage=True)
    assert [p for p, _ in report.refilled] and not report.ok
    np.testing.assert_array_equal(got["w"], np.zeros_like(state["w"]))


def test_checkpoint_missing_leaf_salvage(tmp_path):
    mgr, state = _ckpt_roundtrip(tmp_path)
    _leaf_file(tmp_path, "m").unlink()
    with pytest.raises((KeyError, FileNotFoundError)):
        mgr.restore(state)
    got, extra, report = mgr.restore(state, salvage=True)
    assert [r for _, r in report.refilled] == ["missing"]
    np.testing.assert_array_equal(got["m"], state["m"])


def test_checkpoint_io_retry(tmp_path, monkeypatch):
    mgr, state = _ckpt_roundtrip(tmp_path)
    real = pathlib.Path.read_bytes
    fails = {"n": 0}

    def flaky(self):
        if self.suffix == ".bin" and fails["n"] < 2:
            fails["n"] += 1
            raise OSError("transient I/O blip")
        return real(self)

    monkeypatch.setattr(pathlib.Path, "read_bytes", flaky)
    got, _ = mgr.restore(state, io_backoff=0.001)
    assert fails["n"] == 2
    np.testing.assert_array_equal(got["w"], state["w"])


def test_checkpoint_legacy_crc_manifest(tmp_path):
    """Manifests written before per-leaf csum entries still verify (zlib
    crc path) and still fail loudly when the blob is damaged."""
    mgr, state = _ckpt_roundtrip(tmp_path)
    d = tmp_path / "step_1"
    man = json.loads((d / "manifest.json").read_text())
    for meta in man["leaves"].values():
        meta.pop("csum", None)
    (d / "manifest.json").write_text(json.dumps(man))
    got, _ = mgr.restore(state)
    np.testing.assert_array_equal(got["w"], state["w"])
    f = _leaf_file(tmp_path, "b")
    blob = bytearray(f.read_bytes())
    blob[5] ^= 0xFF
    f.write_bytes(bytes(blob))
    with pytest.raises(IOError):
        mgr.restore(state)


# ---------------------------------------------------------------------------
# hypothesis fuzz lane (additive: runs wherever hypothesis is installed)
# ---------------------------------------------------------------------------

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic grid still runs; CI's [test] extra has it
    HAVE_HYPOTHESIS = False

_FUZZ_BLOB = {}


def _fuzz_blob():
    if "b" not in _FUZZ_BLOB:
        _FUZZ_BLOB["b"] = (
            sz3_chunked(chunk_bytes=1024).compress(_smooth((24, 16), 70), REL).blob
        )
        _FUZZ_BLOB["out"] = decompress(_FUZZ_BLOB["b"], verify="strict")
    return _FUZZ_BLOB["b"], _FUZZ_BLOB["out"]


if HAVE_HYPOTHESIS:

    @settings(
        max_examples=150,
        deadline=None,
        suppress_health_check=[HealthCheck.function_scoped_fixture],
    )
    @given(st.data())
    def test_fuzz_random_mutations(data):
        blob, pristine = _fuzz_blob()
        n_muts = data.draw(st.integers(1, 4))
        mut = blob
        for _ in range(n_muts):
            op = data.draw(st.sampled_from(["flip", "zero", "trunc", "splice"]))
            if op == "flip":
                mut = faults.bit_flip(
                    mut,
                    data.draw(st.integers(0, max(0, len(mut) - 1))),
                    data.draw(st.integers(0, 7)),
                )
            elif op == "zero":
                mut = faults.zero_range(
                    mut,
                    data.draw(st.integers(0, max(0, len(mut) - 1))),
                    data.draw(st.integers(1, 64)),
                )
            elif op == "trunc":
                mut = faults.truncate(mut, data.draw(st.integers(0, len(mut))))
            else:
                mut = faults.splice(
                    mut,
                    data.draw(st.integers(0, max(0, len(mut) - 1))),
                    data.draw(st.integers(0, max(0, len(mut) - 1))),
                    data.draw(st.integers(1, 64)),
                )
        for verify in ("strict", "salvage", "off"):
            if mut == blob and verify == "strict":
                continue  # identity composition: trivially decodes
            _contract(pristine, mut, verify)

    @settings(max_examples=60, deadline=None)
    @given(raw=st.binary(min_size=0, max_size=200))
    def test_fuzz_arbitrary_bytes(raw):
        for blob in (raw, b"SZ3J" + raw):
            try:
                decompress(blob)
            except ValueError:
                pass
