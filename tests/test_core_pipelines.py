"""Round-trip + error-bound tests for every composed pipeline (paper §3.3)."""
import numpy as np
import pytest

from repro.core import (
    CompressionConfig,
    ErrorBoundMode,
    SZ3Compressor,
    decompress,
    metrics,
    predictors,
    preprocess,
    quantizers,
    sz3_aps,
    sz3_interp,
    sz3_lorenzo,
    sz3_lr,
    sz3_pastri,
    sz3_truncation,
    sz_pastri,
    sz_pastri_zstd,
)


def smooth_field(shape, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=shape)
    for ax in range(len(shape)):
        x = np.cumsum(x, axis=ax) / np.sqrt(shape[ax])
    return x.astype(dtype)


PIPELINES = {
    "lorenzo": sz3_lorenzo,
    "lr": sz3_lr,
    "interp": sz3_interp,
}


@pytest.mark.parametrize("name", list(PIPELINES))
@pytest.mark.parametrize("shape", [(2000,), (64, 80), (16, 24, 20)])
@pytest.mark.parametrize("eb", [1e-1, 1e-3])
def test_abs_bound_roundtrip(name, shape, eb):
    x = smooth_field(shape, seed=hash((name, shape)) % 100)
    comp = PIPELINES[name]()
    res = comp.compress(x, CompressionConfig(mode=ErrorBoundMode.ABS, eb=eb))
    xhat = decompress(res.blob)
    assert xhat.shape == x.shape and xhat.dtype == x.dtype
    assert metrics.max_abs_error(x, xhat) <= eb * (1 + 1e-6)
    assert res.ratio > 1.0


@pytest.mark.parametrize("name", list(PIPELINES))
def test_rel_bound(name):
    x = smooth_field((40, 50), seed=3) * 100.0
    eb = 1e-3
    comp = PIPELINES[name]()
    res = comp.compress(x, CompressionConfig(mode=ErrorBoundMode.REL, eb=eb))
    xhat = decompress(res.blob)
    rng = float(x.max() - x.min())
    assert metrics.max_abs_error(x, xhat) <= eb * rng * (1 + 1e-6)


def test_pw_rel_bound_log_transform():
    rng = np.random.default_rng(0)
    x = np.exp(rng.normal(0, 3, (50, 40))).astype(np.float64)
    x[4, 7] = 0.0
    x[10, 3] = -x[10, 3]
    comp = SZ3Compressor(
        preprocessor=preprocess.LogTransform(),
        predictor=predictors.LorenzoPredictor(),
    )
    res = comp.compress(x, CompressionConfig(mode=ErrorBoundMode.PW_REL, eb=1e-3))
    xhat = decompress(res.blob)
    assert metrics.max_pw_rel_error(x, xhat) <= 1e-3 * (1 + 1e-9)
    assert xhat[4, 7] == 0.0
    assert np.sign(xhat[10, 3]) == np.sign(x[10, 3])


def test_f64_tiny_eb():
    x = smooth_field((5000,), seed=1, dtype=np.float64) * 1e-4
    eb = 1e-12
    res = sz3_lorenzo().compress(x, CompressionConfig(eb=eb))
    xhat = decompress(res.blob)
    assert metrics.max_abs_error(x, xhat) <= eb * (1 + 1e-6)


def test_pastri_family():
    rng = np.random.default_rng(2)
    P = 64
    pattern = np.exp(-np.linspace(0, 5, P)) * np.cos(np.linspace(0, 15, P))
    scales = np.exp(rng.normal(0, 2, 500))
    x = (scales[:, None] * pattern[None, :]).reshape(-1).astype(np.float64)
    eb = 1e-9
    ratios = {}
    for name, mk in [
        ("sz_pastri", sz_pastri),
        ("sz_pastri_zstd", sz_pastri_zstd),
        ("sz3_pastri", sz3_pastri),
    ]:
        res = mk(P).compress(x, CompressionConfig(eb=eb))
        xhat = decompress(res.blob)
        assert metrics.max_abs_error(x, xhat) <= eb * (1 + 1e-6), name
        ratios[name] = res.ratio
    # the paper's ordering: SZ3-Pastri > SZ-Pastri-with-zstd > SZ-Pastri
    assert ratios["sz3_pastri"] >= ratios["sz_pastri_zstd"] >= ratios["sz_pastri"]


def test_pattern_autodetect():
    P = 48
    t = np.arange(P * 300, dtype=np.float64)
    x = np.sin(2 * np.pi * t / P) * np.exp(-((t % P)) / 20)
    det = predictors.PatternPredictor.detect_period(x)
    assert det % P == 0 or P % det == 0 or abs(det - P) <= 2


def test_aps_adaptive_lossless_on_integers():
    rng = np.random.default_rng(4)
    img = rng.poisson(3.0, (32, 16, 16)).astype(np.float32)
    res = sz3_aps().compress(img, CompressionConfig(eb=0.1))
    xhat = decompress(res.blob)
    assert np.array_equal(xhat, img)  # paper: "turns out to be lossless"


def test_aps_adaptive_high_eb_switches_pipeline():
    rng = np.random.default_rng(5)
    img = rng.poisson(3.0, (32, 16, 16)).astype(np.float32)
    res = sz3_aps().compress(img, CompressionConfig(eb=4.0))
    xhat = decompress(res.blob)
    assert metrics.max_abs_error(img, xhat) <= 4.0 * 1.0001


def test_truncation():
    x = smooth_field((100, 100), seed=6)
    res = sz3_truncation(2).compress(x)
    xhat = decompress(res.blob)
    # byte truncation: bounded relative error per element magnitude scale
    assert res.ratio == pytest.approx(2.0, rel=0.2)
    assert np.abs(x - xhat).max() / np.abs(x).max() < 0.01


def test_sequential_oracle_matches_bound():
    x = smooth_field((24, 30), seed=7, dtype=np.float64)
    comp = SZ3Compressor(predictor=predictors.LorenzoSequentialPredictor())
    res = comp.compress(x, CompressionConfig(eb=1e-4))
    xhat = decompress(res.blob)
    assert metrics.max_abs_error(x, xhat) <= 1e-4 * (1 + 1e-9)


def test_second_order_lorenzo():
    x = smooth_field((50, 60), seed=8)
    comp = sz3_lorenzo(order=2)
    res = comp.compress(x, CompressionConfig(eb=1e-3))
    xhat = decompress(res.blob)
    assert metrics.max_abs_error(x, xhat) <= 1e-3 * (1 + 1e-6)


def test_unpred_aware_beats_linear_on_spiky_data():
    """Paper §4.2: bitplane storage of unpredictables compresses better."""
    rng = np.random.default_rng(9)
    x = smooth_field((30000,), seed=9, dtype=np.float64)
    spikes = rng.random(x.size) < 0.2
    x[spikes] += rng.standard_normal(int(spikes.sum())) * 100
    conf = CompressionConfig(eb=1e-8)
    r_lin = SZ3Compressor(
        predictor=predictors.LorenzoPredictor(),
        quantizer=quantizers.LinearScaleQuantizer(),
    ).compress(x, conf)
    r_un = SZ3Compressor(
        predictor=predictors.LorenzoPredictor(),
        quantizer=quantizers.UnpredAwareQuantizer(),
    ).compress(x, conf)
    assert metrics.max_abs_error(x, decompress(r_un.blob)) <= 1e-8 * (1 + 1e-9)
    assert r_un.ratio > r_lin.ratio
