"""Error-mode x pipeline matrix: every registered pipeline against every
error-bound mode on the fixture classes that break naive implementations.

The mode definitions asserted pointwise (see README "Error-bound modes"):

  ABS     max |x - x_hat| <= eb                   over finite positions
  REL     max |x - x_hat| <= eb * range(finite x) over finite positions
  PW_REL  |x_i - x_hat_i| <= eb * |x_i| for every finite nonzero element,
          exact zeros reconstruct exactly, non-finite values round-trip
          (PW_REL-native pipelines carry them in a side channel)

Pipelines that cannot honour PW_REL without the log-transform composition
must REFUSE (raise ValueError) rather than silently degrade to the
conservative eb*absmax bound — that silent degradation is the bug this
matrix exists to keep dead.
"""
import numpy as np
import pytest

from repro.core import (
    CompressionConfig,
    ErrorBoundMode,
    PIPELINES,
    decompress,
    sz3_quality,
)

EB = 1e-3

#: pipeline name -> factory kwargs (small chunks so multi-chunk paths engage)
MATRIX_PIPELINES = {
    "sz3_lorenzo": {},
    "sz3_lr": {},
    "sz3_interp": {},
    "sz3_transform": {},
    "sz3_hybrid": {},
    "sz3_fast": {},
    "sz3_auto": {"chunk_bytes": 1 << 15},
    "sz3_pwr": {"chunk_bytes": 1 << 15},
}

#: pipelines that honour PW_REL natively (log-composed side channels)
PW_REL_NATIVE = {"sz3_auto", "sz3_pwr", "sz3_chunked", "sz3_hybrid", "sz3_fast"}

#: pipelines that only accept PW_REL configs (first-class PW_REL engine)
PW_REL_ONLY = {"sz3_pwr"}

#: pipelines guaranteed to round-trip non-finite values bit-for-bit under
#: ABS/REL: the transform coder and every prediction pipeline (non-finite
#: points ride the exact fail/unpredictable channel since the prequantize
#: non-finite fix)
NONFINITE_EXACT = {
    "sz3_lorenzo",
    "sz3_lr",
    "sz3_interp",
    "sz3_transform",
    "sz3_hybrid",
    "sz3_fast",
    "sz3_auto",
}


def _smooth(shape, seed, dtype=np.float32):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=shape)
    for ax in range(len(shape)):
        x = np.cumsum(x, axis=ax) / np.sqrt(shape[ax])
    return x.astype(dtype)


def _fixtures():
    rng = np.random.default_rng(42)
    smooth = _smooth((96, 40), seed=1) * 5.0 + 7.0
    t = np.arange(6000, dtype=np.float64)
    oscillatory = (np.sin(0.91 * np.pi * t) + 0.2).astype(np.float32)
    constant = np.full((64, 32), 3.75, np.float32)
    zero_crossing = np.sin(np.linspace(-6 * np.pi, 6 * np.pi, 5000)).astype(
        np.float64
    )
    zero_crossing[::250] = 0.0  # exact zeros among sign changes
    nonfinite = _smooth((80, 25), seed=2) + 2.0
    nonfinite[3, 4] = np.nan
    nonfinite[10, 11] = np.inf
    nonfinite[20, 2] = -np.inf
    # block-boundary-straddling discontinuities on a blocksize±1-sized array:
    # the hybrid engine tiles 2-D data into 16x16 blocks, so (33, 17) forces
    # one-past-the-edge tiles on BOTH axes, and the steps sit exactly ON the
    # 16-boundaries (the classic off-by-one tiling bug bait)
    straddle = _smooth((33, 17), seed=3, dtype=np.float64) * 0.5
    straddle[16:, :] += 100.0
    straddle[:, 16:] -= 75.0
    straddle[32, :] *= -1.0  # the single-row tail tile
    # denormals scattered through a normal-scale field: ABS/REL must absorb
    # them into the quantization grid, PW_REL must reconstruct them exactly
    # (the LogTransform raw side channel — no log-domain bound survives the
    # exp2 + cast back at subnormal scale)
    denormal = _smooth((40, 20), seed=4, dtype=np.float64) + 4.0
    denormal[::7, 3] = 5e-324  # smallest positive float64 subnormal
    denormal[1::7, 4] = -2.5e-310
    denormal[2::11, 5] = float(np.finfo(np.float32).tiny) / 8  # f32-subnormal
    del rng
    return {
        "smooth": smooth,
        "oscillatory": oscillatory,
        "constant": constant,
        "zero_crossing": zero_crossing,
        "nonfinite": nonfinite,
        "straddle": straddle,
        "denormal": denormal,
    }


FIXTURES = _fixtures()


def _assert_mode_bound(mode, x, xhat, fixture):
    x64 = np.asarray(x, np.float64)
    xh64 = np.asarray(xhat, np.float64)
    fin = np.isfinite(x64)
    slack = 1 + 1e-6
    if mode == ErrorBoundMode.ABS:
        assert np.abs(x64[fin] - xh64[fin]).max(initial=0.0) <= EB * slack
    elif mode == ErrorBoundMode.REL:
        rng = x64[fin].max() - x64[fin].min() if fin.any() else 0.0
        tol = EB * rng * slack
        if rng == 0:
            # degenerate range: the engines clamp to a near-lossless bound
            tol = 1e-300
        assert np.abs(x64[fin] - xh64[fin]).max(initial=0.0) <= tol
    else:  # PW_REL, asserted pointwise per the definition
        nz = fin & (x64 != 0)
        rel = np.abs(x64[nz] - xh64[nz]) / np.abs(x64[nz])
        assert rel.max(initial=0.0) <= EB * slack
        zeros = fin & (x64 == 0)
        assert np.all(xh64[zeros] == 0.0), "exact zeros must reconstruct exactly"
        # PW_REL-native pipelines carry non-finite values in a side channel
        nf = ~fin
        if nf.any():
            assert np.array_equal(
                xh64[nf], x64[nf], equal_nan=True
            ), "non-finite values must round-trip through the side channel"


@pytest.mark.parametrize("fixture", sorted(FIXTURES))
@pytest.mark.parametrize(
    "mode", [ErrorBoundMode.ABS, ErrorBoundMode.REL, ErrorBoundMode.PW_REL]
)
@pytest.mark.parametrize("name", sorted(MATRIX_PIPELINES))
def test_mode_matrix(name, mode, fixture):
    x = FIXTURES[fixture]
    comp = PIPELINES[name](**MATRIX_PIPELINES[name])
    conf = CompressionConfig(mode=mode, eb=EB)
    native_pwrel = name in PW_REL_NATIVE
    if mode == ErrorBoundMode.PW_REL and not native_pwrel:
        # refusal is the contract: no silent eb*absmax degradation
        with pytest.raises(ValueError):
            comp.compress(x, conf)
        return
    if mode != ErrorBoundMode.PW_REL and name in PW_REL_ONLY:
        with pytest.raises(ValueError):
            comp.compress(x, conf)
        return
    if mode == ErrorBoundMode.PW_REL and fixture in ("constant", "smooth"):
        # PW_REL needs data away from zero only for a meaningful ratio — it
        # is still well-defined here; nothing to skip, keep going
        pass
    res = comp.compress(x, conf)
    xhat = decompress(res.blob)
    assert xhat.shape == x.shape and xhat.dtype == x.dtype
    _assert_mode_bound(mode, x, xhat, fixture)
    if fixture == "nonfinite" and name in NONFINITE_EXACT:
        nf = ~np.isfinite(np.asarray(x, np.float64))
        assert np.array_equal(
            np.asarray(xhat, np.float64)[nf],
            np.asarray(x, np.float64)[nf],
            equal_nan=True,
        )


@pytest.mark.parametrize("fixture", sorted(FIXTURES))
def test_quality_pipeline_meets_psnr_floor(fixture):
    """sz3_quality in the matrix: its contract is the PSNR floor over finite
    positions, whatever the fixture looks like."""
    target = 50.0
    x = FIXTURES[fixture]
    res = sz3_quality(target_psnr=target, chunk_bytes=1 << 15).compress(x)
    achieved = res.meta["quality"]["achieved_psnr"]
    assert achieved >= target - 1.0, f"{fixture}: achieved {achieved:.2f} dB"
    xhat = decompress(res.blob)
    # independent verification of the recorded number (finite positions)
    x64 = np.asarray(x, np.float64)
    fin = np.isfinite(x64)
    m = float(np.mean((x64[fin] - np.asarray(xhat, np.float64)[fin]) ** 2))
    if m > 0 and fin.any():
        rng = float(x64[fin].max() - x64[fin].min())
        if rng > 0:
            measured = 20 * np.log10(rng) - 10 * np.log10(m)
            assert measured >= target - 1.0


#: the engines the composite-mode contract is asserted against (the chunked
#: families resolve composite bounds per call; the bare pipelines get them
#: through the shared resolve_abs_eb, so a couple of each suffices)
COMPOSITE_PIPELINES = {
    "sz3_fast": {},
    "sz3_lorenzo": {},
    "sz3_hybrid": {},
    "sz3_chunked": {"chunk_bytes": 1 << 15},
}


@pytest.mark.parametrize("fixture", ["smooth", "oscillatory", "straddle"])
@pytest.mark.parametrize(
    "mode", [ErrorBoundMode.ABS_AND_REL, ErrorBoundMode.ABS_OR_REL]
)
@pytest.mark.parametrize("name", sorted(COMPOSITE_PIPELINES))
def test_composite_modes(name, mode, fixture):
    """abs-and-rel = min(eb_abs, eb_rel*range); abs-or-rel = max of the two —
    asserted pointwise against independently computed bounds."""
    x = FIXTURES[fixture]
    eb_abs, eb_rel = 1e-3, 2e-5
    comp = PIPELINES[name](**COMPOSITE_PIPELINES[name])
    conf = CompressionConfig(mode=mode, eb=eb_abs, eb_rel=eb_rel)
    res = comp.compress(x, conf)
    xhat = decompress(res.blob)
    assert xhat.shape == x.shape and xhat.dtype == x.dtype
    x64 = np.asarray(x, np.float64)
    fin = np.isfinite(x64)
    rng = float(x64[fin].max() - x64[fin].min())
    pick = min if mode == ErrorBoundMode.ABS_AND_REL else max
    tol = pick(eb_abs, eb_rel * rng) * (1 + 1e-6)
    err = np.abs(x64[fin] - np.asarray(xhat, np.float64)[fin]).max(initial=0.0)
    assert err <= tol, f"{name}/{fixture}: {err} > {tol}"


def test_composite_modes_require_eb_rel():
    conf = CompressionConfig(mode=ErrorBoundMode.ABS_AND_REL, eb=1e-3)
    with pytest.raises(ValueError, match="eb_rel"):
        conf.resolve_abs_eb(10.0, 5.0)


def test_composite_mode_resolution_values():
    c = CompressionConfig(mode=ErrorBoundMode.ABS_AND_REL, eb=1e-3, eb_rel=1e-5)
    assert c.resolve_abs_eb(10.0, 5.0) == pytest.approx(1e-4)  # min wins
    assert c.resolve_abs_eb(1000.0, 500.0) == pytest.approx(1e-3)
    c = CompressionConfig(mode=ErrorBoundMode.ABS_OR_REL, eb=1e-3, eb_rel=1e-5)
    assert c.resolve_abs_eb(10.0, 5.0) == pytest.approx(1e-3)  # max wins
    assert c.resolve_abs_eb(1000.0, 500.0) == pytest.approx(1e-2)


def test_pw_rel_conservative_fallback_is_opt_in():
    conf = CompressionConfig(mode=ErrorBoundMode.PW_REL, eb=1e-2)
    with pytest.raises(ValueError, match="allow_conservative"):
        conf.resolve_abs_eb(10.0, 5.0)
    assert conf.resolve_abs_eb(10.0, 5.0, allow_conservative=True) == 5e-2


def test_metrics_constant_and_empty_regression():
    """PSNR/NRMSE on constant (range-0) and empty arrays: inf/0.0, never a
    RuntimeWarning-laced nan (the divide-by-zero regression)."""
    import warnings

    from repro.core import metrics

    const = np.full(64, 2.5, np.float32)
    empty = np.zeros(0, np.float32)
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # any RuntimeWarning -> failure
        assert metrics.psnr(const, const) == float("inf")
        assert metrics.nrmse(const, const) == 0.0
        assert metrics.mse(empty, empty) == 0.0
        assert metrics.psnr(empty, empty) == float("inf")
        assert metrics.nrmse(empty, empty) == 0.0
        off = metrics.psnr(const, const + 0.1)
        assert np.isfinite(off) and not np.isnan(off)
        assert metrics.nrmse(const, const + 0.1) == float("inf")


# The hypothesis round-trip fuzz for the PW_REL sign/zero/non-finite side
# channel lives in tests/test_core_property.py (whole-module importorskip
# pattern — keeping it here would skip this entire matrix where hypothesis
# is not installed).
