"""Block-hybrid engine unit tests: tiling/edge handling, per-block selection,
tag + coefficient side channels, the shared code stream, the v5 container,
and the chunk-level estimate_error hook."""
import numpy as np
import pytest

from repro.core import (
    CompressionConfig,
    ErrorBoundMode,
    PIPELINES,
    decompress,
    parse_header,
    select_pipeline,
    sz3_hybrid,
)
from repro.core.blockwise import (
    BLOCK_SIDES,
    TAG_LOR1,
    TAG_LOR2,
    TAG_REG,
    TAG_ZERO,
    _pack_tags,
    _unpack_tags,
    block_side_for,
)

EB = 1e-3
ABS = CompressionConfig(mode=ErrorBoundMode.ABS, eb=EB)


def _smooth(shape, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(shape)
    for ax in range(len(shape)):
        x = np.cumsum(x, axis=ax) / np.sqrt(shape[ax])
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# tiling / edge handling
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "shape",
    [
        (255,), (256,), (257,), (3,),            # 1-D: blocksize ±1 and tiny
        (15, 16), (16, 17), (17, 17), (1, 5),    # 2-D around bs=16
        (7, 8, 9), (8, 8, 8), (9, 9, 9),         # 3-D around bs=8
        (3, 4, 5, 6),                            # ndim >= 4 fallback side
    ],
)
def test_roundtrip_bound_odd_shapes(shape):
    x = _smooth(shape, seed=1)
    res = sz3_hybrid().compress(x, ABS)
    xhat = decompress(res.blob)
    assert xhat.shape == x.shape and xhat.dtype == x.dtype
    assert np.abs(xhat.astype(np.float64) - x).max() <= EB


def test_empty_and_scalar():
    for arr in [np.zeros((0, 5), np.float32), np.float32(3.25), np.zeros(0)]:
        res = sz3_hybrid().compress(arr, ABS)
        out = decompress(res.blob)
        assert out.shape == np.asarray(arr).shape
        if np.asarray(arr).size:
            assert float(out) == pytest.approx(float(arr), abs=EB)


def test_block_side_by_ndim():
    assert block_side_for(1) == BLOCK_SIDES[1] == 256
    assert block_side_for(2) == BLOCK_SIDES[2] == 16
    assert block_side_for(3) == BLOCK_SIDES[3] == 8
    assert block_side_for(5) == 4
    assert block_side_for(2, override=32) == 32


def test_tag_packing_roundtrip():
    rng = np.random.default_rng(0)
    for n in [0, 1, 3, 4, 5, 63, 64, 1001]:
        tags = rng.integers(0, 4, n).astype(np.uint8)
        assert np.array_equal(_unpack_tags(_pack_tags(tags), n), tags)


# ---------------------------------------------------------------------------
# per-block selection picks the right predictor per regime
# ---------------------------------------------------------------------------

def test_selection_routes_regimes_to_expected_tags():
    rng = np.random.default_rng(3)
    x = np.zeros((64, 64), np.float64)
    x[:32, :32] = np.cumsum(rng.standard_normal((32, 32)), axis=0)  # smooth
    i, j = np.meshgrid(np.arange(32.0), np.arange(32.0), indexing="ij")
    x[32:, :32] = 2e-3 * (i * i + j * j)  # quadratic
    x[:32, 32:] = 0.5 * i + 0.25 * j + 2.5e-3 * rng.standard_normal((32, 32))
    # bottom-right stays zero
    res = sz3_hybrid().compress(x.astype(np.float32), ABS, with_stats=True)
    shares = res.meta["tag_shares"]
    assert shares["zero"] > 0, shares          # the zero tile
    assert shares["lorenzo2"] > 0, shares      # the quadratic tile
    assert shares["regression"] > 0, shares    # the noisy plane tile
    counts = res.meta["counts"]
    assert counts[TAG_ZERO] + counts[TAG_LOR1] + counts[TAG_LOR2] + counts[
        TAG_REG
    ] == res.meta["nb"]
    xhat = decompress(res.blob)
    assert np.abs(xhat.astype(np.float64) - x).max() <= EB


def test_constant_blocks_cost_almost_nothing():
    """Per-block constants + zero blocks: the emitted codes are near-zero
    entropy, so the container must be tiny relative to the raw bytes."""
    vals = np.repeat(
        np.repeat(np.arange(40, dtype=np.float32).reshape(8, 5), 16, 0), 16, 1
    )  # (128, 80): large enough that the fixed header cost is negligible
    res = sz3_hybrid().compress(vals, ABS)
    assert res.ratio > 40, res.ratio
    assert np.abs(decompress(res.blob).astype(np.float64) - vals).max() <= EB


# ---------------------------------------------------------------------------
# v5 container
# ---------------------------------------------------------------------------

def test_v5_header_fields_and_dispatch():
    x = _smooth((40, 30), seed=2)
    blob = sz3_hybrid().compress(x, ABS).blob
    header, body_off = parse_header(blob)
    assert header["v"] == 5 and header["kind"] == "hybrid"
    assert header["spec"]["kind"] == "hybrid"
    assert header["tag_len"] == (header["hyb_meta"]["nb"] + 3) // 4
    assert header["enc_len"] > 0 and body_off > 20
    # generic decompress auto-detects the v5 generation
    assert decompress(blob).shape == x.shape


def test_registered_and_contestable():
    assert "sz3_hybrid" in PIPELINES
    from repro.core import AUTO_CANDIDATES

    assert "sz3_hybrid" in AUTO_CANDIDATES


def test_estimate_error_is_selectable_currency():
    """The chunk-level estimator returns bits/element comparable across
    pipelines: near zero on trivial data, large on noise, and plumbed
    through select_pipeline without a trial-only fallback."""
    comp = sz3_hybrid()
    conf = CompressionConfig()
    low = comp.estimate_error(np.zeros(4096, np.float32), EB, conf)
    rng = np.random.default_rng(0)
    high = comp.estimate_error(
        rng.standard_normal(4096).astype(np.float32) * 100, EB, conf
    )
    assert 0.0 <= low < 0.5 < high
    # and the contest accepts it: a hybrid-only candidate list short-circuits,
    # so contest it against one other pipeline
    winner, scores = select_pipeline(
        np.zeros((64, 64), np.float32), EB, conf, ("sz3_lorenzo", "sz3_hybrid")
    )
    assert "sz3_hybrid" in scores


# ---------------------------------------------------------------------------
# error-bound robustness specific to the block paths
# ---------------------------------------------------------------------------

def test_outlier_blocks_stay_in_bound():
    """Spikes far outside the quantizer range ride the unpredictable/fail
    channels regardless of which candidate owns the block."""
    rng = np.random.default_rng(5)
    x = _smooth((48, 48), seed=5, dtype=np.float64)
    x[::9, ::7] += 1e9  # out-of-range under eb=1e-3
    res = sz3_hybrid().compress(x, ABS)
    xhat = decompress(res.blob)
    assert np.abs(xhat - x).max() <= EB


def test_pw_rel_native_roundtrip_f32_and_f64():
    for dtype, eb in [(np.float64, 1e-3), (np.float32, 1e-2)]:
        rng = np.random.default_rng(6)
        v = np.exp(rng.normal(0, 3, 3000)).astype(dtype)
        v[rng.random(3000) < 0.25] *= -1
        v[rng.random(3000) < 0.02] = 0.0
        conf = CompressionConfig(mode=ErrorBoundMode.PW_REL, eb=eb)
        vhat = decompress(sz3_hybrid().compress(v, conf).blob)
        nz = v != 0
        v64, vh64 = v.astype(np.float64), vhat.astype(np.float64)
        assert np.abs((vh64[nz] - v64[nz]) / v64[nz]).max() <= eb * (1 + 1e-9)
        assert np.all(vh64[~nz] == 0.0)


def test_int_input_coerced_like_other_pipelines():
    x = np.arange(1000, dtype=np.int32).reshape(20, 50)
    res = sz3_hybrid().compress(x, ABS)
    xhat = decompress(res.blob)
    assert xhat.dtype == np.float32
    assert np.abs(xhat.astype(np.float64) - x).max() <= EB
