"""Hypothesis property tests for the system's core invariants:

  * error bound holds for EVERY pipeline x shape x eb x data distribution;
  * encoders and the lossless stage round-trip bit-exactly;
  * the bitplane codec is exact on arbitrary int64;
  * dual-quant Lorenzo and the sequential oracle both respect the bound.
"""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed — `pip install -e .[test]` for the full suite",
)
from hypothesis import given, settings, strategies as st

from repro.core import (
    CompressionConfig,
    ErrorBoundMode,
    SZ3Compressor,
    decompress,
    encoders,
    metrics,
    predictors,
    quantizers,
)
from repro.core.quantizers import bitplane_decode, bitplane_encode


@st.composite
def arrays(draw, max_elems=6000):
    ndim = draw(st.integers(1, 3))
    dims = draw(
        st.lists(st.integers(2, 40), min_size=ndim, max_size=ndim).filter(
            lambda d: int(np.prod(d)) <= max_elems
        )
    )
    seed = draw(st.integers(0, 2**31 - 1))
    kind = draw(st.sampled_from(["smooth", "noise", "spiky", "constant"]))
    dtype = draw(st.sampled_from([np.float32, np.float64]))
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(dims)
    if kind == "smooth":
        for ax in range(len(dims)):
            x = np.cumsum(x, axis=ax)
    elif kind == "spiky":
        mask = rng.random(dims) < 0.1
        x = x + mask * rng.standard_normal(dims) * 1e4
    elif kind == "constant":
        x = np.full(dims, float(rng.normal()))
    return x.astype(dtype)


@settings(max_examples=25, deadline=None)
@given(
    x=arrays(),
    eb=st.sampled_from([1e-1, 1e-3, 1e-6]),
    pred=st.sampled_from(["lorenzo", "regression", "interp", "composite"]),
    quant=st.sampled_from(["linear", "unpred_aware"]),
)
def test_error_bound_invariant(x, eb, pred, quant):
    comp = SZ3Compressor(
        predictor=predictors.make(pred),
        quantizer=quantizers.make(quant),
    )
    res = comp.compress(x, CompressionConfig(mode=ErrorBoundMode.ABS, eb=eb))
    xhat = decompress(res.blob)
    assert xhat.shape == x.shape
    assert metrics.max_abs_error(x, xhat) <= eb * (1 + 1e-6)


@settings(max_examples=30, deadline=None)
@given(
    syms=st.lists(st.integers(0, 70000), min_size=0, max_size=5000),
    enc_name=st.sampled_from(["huffman", "bitpack", "raw"]),
)
def test_encoder_roundtrip_exact(syms, enc_name):
    arr = np.asarray(syms, np.uint32)
    enc = encoders.make(enc_name)
    blob = enc.encode(arr)
    out = enc.decode(blob, arr.size)
    assert np.array_equal(np.asarray(out, np.int64), arr.astype(np.int64))


@settings(max_examples=30, deadline=None)
@given(syms=st.lists(st.integers(0, 70000), min_size=0, max_size=5000))
def test_huffman_v1_v2_stream_compat(syms):
    """Word-packed v2 streams round-trip AND pre-PR2 v1 blobs still decode
    (both directions: the legacy decoder also reads v2 streams)."""
    arr = np.asarray(syms, np.uint32)
    v2 = encoders.HuffmanEncoder()
    legacy = encoders.LegacyHuffmanEncoder()
    blob_v2 = v2.encode(arr)
    blob_v1 = legacy.encode(arr)
    expect = arr.astype(np.int64)
    assert np.array_equal(v2.decode(blob_v2, arr.size), expect)
    assert np.array_equal(v2.decode(blob_v1, arr.size), expect)
    assert np.array_equal(legacy.decode(blob_v2, arr.size), expect)


@settings(max_examples=10, deadline=None)
@given(x=arrays(max_elems=4000), workers=st.integers(2, 4))
def test_chunked_workers_byte_identical_property(x, workers):
    from repro.core import ChunkedCompressor

    conf = CompressionConfig(mode=ErrorBoundMode.ABS, eb=1e-3)
    cb = max(1, x.nbytes // 3)
    serial = ChunkedCompressor(chunk_bytes=cb, workers=1).compress(x, conf).blob
    parallel = ChunkedCompressor(chunk_bytes=cb, workers=workers).compress(x, conf).blob
    assert serial == parallel


@settings(max_examples=30, deadline=None)
@given(
    vals=st.lists(
        st.integers(-(2**62), 2**62), min_size=0, max_size=2000
    )
)
def test_bitplane_roundtrip_exact(vals):
    arr = np.asarray(vals, np.int64)
    blob = bitplane_encode(arr)
    out, consumed = bitplane_decode(blob)
    assert consumed == len(blob)
    assert np.array_equal(out, arr)


@settings(max_examples=20, deadline=None)
@given(
    codes=st.lists(st.integers(0, 65535), min_size=1, max_size=3000),
)
def test_fixed_huffman_roundtrip(codes):
    arr = np.asarray(codes, np.uint16)
    enc = encoders.FixedHuffmanEncoder(radius=32768)
    blob = enc.encode(arr)
    out = enc.decode(blob, arr.size)
    assert np.array_equal(out.astype(np.int64), arr.astype(np.int64))


@settings(max_examples=15, deadline=None)
@given(x=arrays(max_elems=1500), eb=st.sampled_from([1e-2, 1e-4]))
def test_sequential_vs_dualquant_both_bounded(x, eb):
    for pred in [predictors.LorenzoPredictor(), predictors.LorenzoSequentialPredictor()]:
        comp = SZ3Compressor(predictor=pred)
        res = comp.compress(x, CompressionConfig(eb=eb))
        xhat = decompress(res.blob)
        assert metrics.max_abs_error(x, xhat) <= eb * (1 + 1e-6), type(pred).__name__


@settings(max_examples=25, deadline=None)
@given(x=arrays(), eb=st.sampled_from([1e-1, 1e-3, 1e-6]))
def test_transform_error_bound_invariant(x, eb):
    """The transform coder (fourth family) honours the ABS bound on every
    shape x distribution x dtype the prediction pipelines are held to."""
    from repro.core import sz3_transform

    res = sz3_transform().compress(x, CompressionConfig(mode=ErrorBoundMode.ABS, eb=eb))
    xhat = decompress(res.blob)
    assert xhat.shape == x.shape
    assert metrics.max_abs_error(x, xhat) <= eb * (1 + 1e-9)


@settings(max_examples=10, deadline=None)
@given(x=arrays(max_elems=4000), workers=st.integers(2, 4))
def test_auto_chunked_workers_byte_identical_property(x, workers):
    """The hybrid (prediction+transform) candidate set keeps the chunked
    engine's serial-vs-parallel byte-identity guarantee."""
    from repro.core import sz3_auto

    conf = CompressionConfig(mode=ErrorBoundMode.ABS, eb=1e-3)
    cb = max(1, x.nbytes // 3)
    serial = sz3_auto(chunk_bytes=cb, workers=1).compress(x, conf).blob
    parallel = sz3_auto(chunk_bytes=cb, workers=workers).compress(x, conf).blob
    assert serial == parallel
    assert metrics.max_abs_error(x, decompress(parallel)) <= 1e-3 * (1 + 1e-9)


@settings(max_examples=10, deadline=None)
@given(x=arrays(max_elems=3000), eb=st.sampled_from([1e-1, 1e-3]))
def test_v1_v2_streams_unchanged_by_transform(x, eb):
    """Adding the v3 transform family must leave the existing container
    generations untouched: v1 single-pipeline blobs and v2 chunked blobs
    (DEFAULT candidates) carry no transform chunks and still decode."""
    from repro.core import ChunkedCompressor, parse_header
    from repro.core.chunking import DEFAULT_CANDIDATES

    conf = CompressionConfig(mode=ErrorBoundMode.ABS, eb=eb)
    v1 = SZ3Compressor().compress(x, conf).blob
    h1, _ = parse_header(v1)
    assert h1["v"] == 1 and h1["spec"]["kind"] != "transform"
    assert metrics.max_abs_error(x, decompress(v1)) <= eb * (1 + 1e-6)
    assert "sz3_transform" not in DEFAULT_CANDIDATES  # v2 byte stability
    v2 = ChunkedCompressor(chunk_bytes=max(1, x.nbytes // 2)).compress(x, conf).blob
    h2, _ = parse_header(v2)
    assert h2["v"] == 2
    assert all(c["pipeline"] != "sz3_transform" for c in h2["chunks"])
    assert metrics.max_abs_error(x, decompress(v2)) <= eb * (1 + 1e-6)


@settings(max_examples=15, deadline=None)
@given(
    x=arrays(max_elems=4000),
    eb=st.sampled_from([1e-1, 1e-3]),
    n_chunks=st.integers(2, 6),
)
def test_streaming_equals_one_shot(x, eb, n_chunks):
    """The frame stream reassembles into the EXACT one-shot v2 container and
    decodes to the exact same array (chunked engine invariant)."""
    from repro.core import ChunkedCompressor, compress_stream, decompress_stream
    from repro.core.chunking import frames_to_blob

    conf = CompressionConfig(mode=ErrorBoundMode.ABS, eb=eb)
    cb = max(1, x.nbytes // n_chunks)
    res = ChunkedCompressor(chunk_bytes=cb).compress(x, conf)
    frames = list(compress_stream(x, conf, chunk_bytes=cb))
    assert frames_to_blob(frames) == res.blob
    one_shot = decompress(res.blob)
    streamed = np.concatenate(
        [np.atleast_1d(p) for p in decompress_stream(frames)]
    ).reshape(x.shape)
    assert np.array_equal(streamed.astype(np.float64), one_shot.astype(np.float64))
    assert metrics.max_abs_error(x, one_shot) <= eb * (1 + 1e-6)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(1, 400),
    eb=st.sampled_from([1e-2, 1e-3, 1e-4]),
    zero_frac=st.floats(0.0, 0.5),
    neg_frac=st.floats(0.0, 1.0),
    with_nonfinite=st.booleans(),
)
def test_pwr_side_channel_fuzz(seed, n, eb, zero_frac, neg_frac, with_nonfinite):
    """PW_REL side-channel round trip: the pointwise bound for every finite
    nonzero element, signs preserved, exact zeros exact, non-finite values
    bit-stable — under arbitrary sign/zero/magnitude mixes (the fuzz
    companion to tests/test_error_modes.py)."""
    from repro.core import PIPELINES

    rng = np.random.default_rng(seed)
    x = np.exp(rng.normal(0, 5, n))
    x[rng.random(n) < neg_frac] *= -1
    x[rng.random(n) < zero_frac] = 0.0
    if with_nonfinite and n >= 3:
        x[rng.integers(n)] = np.nan
        x[rng.integers(n)] = np.inf
    comp = PIPELINES["sz3_pwr"](eb=eb, chunk_bytes=1 << 12)
    xhat = decompress(comp.compress(x).blob)
    fin = np.isfinite(x)
    nz = fin & (x != 0)
    if nz.any():
        assert (np.abs(x[nz] - xhat[nz]) / np.abs(x[nz])).max() <= eb * (1 + 1e-9)
        assert np.array_equal(np.sign(xhat[nz]), np.sign(x[nz]))
    assert np.all(xhat[fin & (x == 0)] == 0.0)
    assert np.array_equal(xhat[~fin], x[~fin], equal_nan=True)
