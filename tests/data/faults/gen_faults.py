"""(Re)generate the corrupted-blob negative fixtures.

Run from the repo root:

    PYTHONPATH=src python tests/data/faults/gen_faults.py

One entry per container generation (v1-v6).  Each entry is a triple:

  * ``<name>.sz3``          pristine blob WITH its integrity trailer
  * ``<name>.npy``          the exact array the pristine blob decodes to
  * ``<name>_corrupt.sz3``  the same blob with one deterministic fault

plus a shared ``manifest.json`` recording, per entry, what was damaged and
— for the chunked generations — which chunk indices salvage mode must
recover vs lose.  ``tests/test_faults.py`` pins BOTH directions on these:
strict decode of the corrupt blob raises a typed ``IntegrityError``, and
salvage decode recovers exactly the recorded chunk set byte-for-byte.

These live in a subdirectory (not ``tests/data/`` itself) because the
conformance corpus globs ``tests/data/*.sz3`` and requires a matching
golden ``.npy`` for every stem it finds.

Like the conformance corpus: only ever ADD entries; regenerating committed
ones silently rewrites the contract the fixtures exist to pin.
"""
import json
import pathlib
import sys

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[3] / "src"))

from repro.core import (  # noqa: E402
    CompressionConfig,
    ErrorBoundMode,
    decompress,
    faults,
    parse_header,
    sz3_chunked,
    sz3_fast,
    sz3_hybrid,
    sz3_lorenzo,
    sz3_pwr,
    sz3_transform,
)

HERE = pathlib.Path(__file__).resolve().parent


def smooth(shape, seed, dtype=np.float32):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=shape)
    for ax in range(len(shape)):
        x = np.cumsum(x, axis=ax) / np.sqrt(shape[ax])
    return x.astype(dtype)


def emit(manifest, name, blob, corrupt, note, **extra):
    path = HERE / f"{name}.sz3"
    if path.exists():
        print(f"SKIP {name}: already committed")
        return
    decoded = decompress(blob, verify="strict")
    path.write_bytes(blob)
    np.save(HERE / f"{name}.npy", decoded)
    (HERE / f"{name}_corrupt.sz3").write_bytes(corrupt)
    manifest[name] = {"fault": note, **extra}
    print(f"wrote {name}: {len(blob)}B pristine, fault = {note}")


def main():
    abs_conf = CompressionConfig(mode=ErrorBoundMode.ABS, eb=1e-3)
    rel_conf = CompressionConfig(mode=ErrorBoundMode.REL, eb=1e-3)
    pwr_conf = CompressionConfig(mode=ErrorBoundMode.PW_REL, eb=1e-3)
    manifest = {}

    # v1: single-body Lorenzo — bitflip mid-body (whole-digest catch)
    blob = sz3_lorenzo().compress(smooth((40, 24), 21), abs_conf).blob
    _, body_off = parse_header(blob)
    pos = body_off + (len(blob) - body_off) // 3
    emit(
        manifest,
        "v1_lorenzo",
        blob,
        faults.bit_flip(blob, pos, 5),
        f"bitflip body byte {pos}",
        generation="v1",
    )

    # v2: 4-chunk container — flip a byte inside chunk 1 only; salvage must
    # recover chunks {0, 2, 3} byte-exact and report chunk 1 lost
    z = smooth((48, 32), 22)
    blob = sz3_chunked(chunk_bytes=2048).compress(z, rel_conf).blob
    header, _ = parse_header(blob)
    n_chunks = len(header["chunks"])
    bad = 1
    emit(
        manifest,
        "v2_chunked",
        blob,
        faults.corrupt_chunk(blob, bad),
        f"bitflip inside chunk {bad} of {n_chunks}",
        generation="v2",
        n_chunks=n_chunks,
        damaged_chunks=[bad],
    )

    # v3: transform coder — bitflip mid-body
    osc = (
        np.sin(0.9 * np.pi * np.arange(1536)) + 0.05 * smooth((1536,), 23)
    ).astype(np.float32)
    blob = sz3_transform().compress(osc, abs_conf).blob
    _, body_off = parse_header(blob)
    pos = body_off + (len(blob) - body_off) // 2
    emit(
        manifest,
        "v3_transform",
        blob,
        faults.bit_flip(blob, pos, 1),
        f"bitflip body byte {pos}",
        generation="v3",
    )

    # v4: pointwise-relative chunked — damage the LAST chunk; salvage must
    # recover every earlier chunk (log side channels intact per chunk)
    w = np.exp(smooth((64, 24), seed=24, dtype=np.float64))
    w[5, 5] = 0.0
    w[::9, 3] *= -1
    blob = sz3_pwr(eb=1e-3, chunk_bytes=4096).compress(w, pwr_conf).blob
    header, _ = parse_header(blob)
    n_chunks = len(header["chunks"])
    bad = n_chunks - 1
    emit(
        manifest,
        "v4_pwr",
        blob,
        faults.corrupt_chunk(blob, bad),
        f"bitflip inside chunk {bad} of {n_chunks}",
        generation="v4",
        n_chunks=n_chunks,
        damaged_chunks=[bad],
    )

    # v5: block-hybrid — bitflip in the tag/coefficient stream region
    rng = np.random.default_rng(25)
    m = np.cumsum(rng.standard_normal((64, 64)), axis=0).astype(np.float32)
    m[16:32, 16:32] = 0.0
    blob = sz3_hybrid().compress(m, abs_conf).blob
    _, body_off = parse_header(blob)
    pos = body_off + (len(blob) - body_off) * 2 // 3
    emit(
        manifest,
        "v5_hybrid",
        blob,
        faults.bit_flip(blob, pos, 3),
        f"bitflip body byte {pos}",
        generation="v5",
    )

    # v6: fast tier — bitflip in the bit-plane section
    rng = np.random.default_rng(26)
    f = np.concatenate(
        [np.full(512, 1.5), np.cumsum(rng.standard_normal(700))]
    ).astype(np.float32)
    blob = sz3_fast().compress(f, abs_conf).blob
    _, body_off = parse_header(blob)
    pos = body_off + (len(blob) - body_off) // 2
    emit(
        manifest,
        "v6_fast",
        blob,
        faults.bit_flip(blob, pos, 7),
        f"bitflip body byte {pos}",
        generation="v6",
    )

    man_path = HERE / "manifest.json"
    if manifest:
        merged = {}
        if man_path.exists():
            merged = json.loads(man_path.read_text())
        merged.update(manifest)
        man_path.write_text(json.dumps(merged, indent=1, sort_keys=True) + "\n")
        print(f"manifest: {sorted(merged)}")


if __name__ == "__main__":
    main()
