"""(Re)generate the golden-blob conformance corpus.

Run from the repo root:

    PYTHONPATH=src python tests/data/gen_conformance.py

Each corpus entry is a pair ``<name>.sz3`` (a committed container blob) +
``<name>.npy`` (the exact array its decode must keep producing, byte for
byte).  ``tests/test_container_conformance.py`` decodes every committed blob
with the CURRENT code and compares against the committed payload — so a
change that silently alters the meaning of an already-written v1/v2/v3/v4
stream fails loudly, forever.

Only ever ADD entries (a new container generation gets a new pair); never
regenerate existing pairs unless a format break is intentional and
documented — regenerating is exactly the failure mode this corpus exists to
catch.

Blobs are written with the process-effective lossless backend; the container
records the actual backend name, so corpus blobs decode in any environment
(gzip/lzma ship with CPython; zstd-written blobs need zstandard, which the
[test] extra installs).
"""
import pathlib
import sys

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[2] / "src"))

from repro.core import (  # noqa: E402
    CompressionConfig,
    ErrorBoundMode,
    SZ3Compressor,
    predictors,
    preprocess,
    sz3_chunked,
    sz3_fast,
    sz3_hybrid,
    sz3_lorenzo,
    sz3_lr,
    sz3_pwr,
    sz3_quality,
    sz3_transform,
    decompress,
)

HERE = pathlib.Path(__file__).resolve().parent


def smooth(shape, seed, dtype=np.float32):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=shape)
    for ax in range(len(shape)):
        x = np.cumsum(x, axis=ax) / np.sqrt(shape[ax])
    return x.astype(dtype)


def emit(name: str, blob: bytes) -> None:
    path = HERE / f"{name}.sz3"
    if path.exists():
        print(f"SKIP {name}: already committed (delete explicitly to regenerate)")
        return
    decoded = decompress(blob)
    path.write_bytes(blob)
    np.save(HERE / f"{name}.npy", decoded)
    print(f"wrote {name}: blob {len(blob)}B, payload {decoded.shape} {decoded.dtype}")


def main():
    abs_conf = CompressionConfig(mode=ErrorBoundMode.ABS, eb=1e-3)
    rel_conf = CompressionConfig(mode=ErrorBoundMode.REL, eb=1e-3)
    pwr_conf = CompressionConfig(mode=ErrorBoundMode.PW_REL, eb=1e-3)

    x = smooth((40, 12), seed=11)
    emit("v1_lorenzo_abs", sz3_lorenzo().compress(x, abs_conf).blob)
    emit("v1_lr_rel", sz3_lr().compress(smooth((30, 18), seed=12), rel_conf).blob)

    # v1 + log preprocessor: the single-pipeline PW_REL composition
    y = np.exp(smooth((25, 16), seed=13, dtype=np.float64) * 2.0)
    y[3, 4] = 0.0
    y[7, 7] = -y[7, 7]
    comp_log = SZ3Compressor(
        preprocessor=preprocess.LogTransform(),
        predictor=predictors.LorenzoPredictor(),
    )
    emit("v1_log_pwrel", comp_log.compress(y, pwr_conf).blob)

    # v2 multi-chunk (3 chunks, adaptive selection)
    z = smooth((48, 32), seed=14)
    emit("v2_chunked_rel", sz3_chunked(chunk_bytes=2048).compress(z, rel_conf).blob)

    # v2 + quality records (decodes through the plain v2 path)
    emit(
        "v2_quality_psnr",
        sz3_quality(target_psnr=50.0, chunk_bytes=2048).compress(z).blob,
    )

    # v3 blockwise transform
    osc = (np.sin(0.9 * np.pi * np.arange(1536)) + 0.05 * smooth((1536,), 15)).astype(
        np.float32
    )
    emit("v3_transform_abs", sz3_transform().compress(osc, abs_conf).blob)

    # v4 pointwise-relative chunked (log side channels per chunk)
    w = np.exp(smooth((64, 24), seed=16, dtype=np.float64))
    w[5, 5] = 0.0
    w[::9, 3] *= -1
    emit("v4_pwr", sz3_pwr(eb=1e-3, chunk_bytes=4096).compress(w, pwr_conf).blob)

    # v5 block-hybrid: mixed-regime fixture with 16-aligned regime tiles so
    # every predictor tag appears (zero / lorenzo-1 / lorenzo-2 / regression
    # side channels + the shared stream), pinned so the per-block tag format
    # and the coefficient-stream layout can never silently drift
    rng = np.random.default_rng(17)
    m = np.zeros((64, 64), np.float64)
    m[:32, :32] = np.cumsum(rng.standard_normal((32, 32)), axis=0)  # smooth
    i, j = np.meshgrid(np.arange(32.0), np.arange(32.0), indexing="ij")
    m[32:, :32] = 2e-3 * (i * i + j * j)  # gentle quadratic (order-2 turf)
    m[:32, 32:] = (  # noisy tilted plane (regression turf)
        0.5 * i + 0.25 * j + 2.5e-3 * rng.standard_normal((32, 32))
    )
    t = np.arange(32 * 32, dtype=np.float64)
    m[32:, 32:] = np.sin(0.93 * np.pi * t).reshape(32, 32)  # oscillatory
    m[32:48, 32:48] = 0.0  # exact-zero tile (the constant-block fast path)
    emit("v5_hybrid_mixed_abs", sz3_hybrid().compress(m.astype(np.float32), abs_conf).blob)

    # v5 constant-block fixture: per-block constants + exact-zero blocks
    c = np.repeat(
        np.repeat(rng.integers(-4, 5, (3, 2)).astype(np.float32) * 1.25, 16, axis=0),
        16,
        axis=1,
    )
    c[16:32, :] = 0.0
    emit("v5_hybrid_const_rel", sz3_hybrid().compress(c, rel_conf).blob)

    # v6 fast tier, mixed fixture: constant blocks, nonconstant blocks at
    # several widths, a non-finite triple and a tail block — pins the const
    # bitmap, the width-pooled plane layout, the fail channel and the edge
    # padding all in one blob
    rng = np.random.default_rng(18)
    f = np.concatenate(
        [
            np.full(512, -1.75),
            np.cumsum(rng.standard_normal(512)),
            np.cumsum(rng.standard_normal(512)) * 40.0,  # wider planes
            np.zeros(256),
            np.cumsum(rng.standard_normal(37)),  # tail block (edge padded)
        ]
    ).astype(np.float32)
    f[700] = np.nan
    f[701] = np.inf
    f[1500] = -np.inf
    emit("v6_fast_mixed_abs", sz3_fast().compress(f, abs_conf).blob)

    # v6 constant fixture under REL: range 0 resolves to a tiny abs bound,
    # so every block must take the mean-only constant path — pins the
    # all-const body layout (bitmap + means, no width/plane sections)
    g = np.full(2100, 2.5, np.float32)
    emit("v6_fast_const_rel", sz3_fast().compress(g, rel_conf).blob)


if __name__ == "__main__":
    main()
