"""Fault tolerance: compressed checkpoints (atomic, bounded-lossy), restore,
resume-determinism, heartbeat policy, elastic replanning."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro import models
from repro.data import make_pipeline
from repro.ft import (
    CheckpointManager,
    CheckpointPolicy,
    Decision,
    HeartbeatMonitor,
    LeafPolicy,
    replan,
    validate_divisibility,
)
from repro.ft.elastic import best_mesh_shape
from repro.optim import AdamWConfig, init_state
from repro.parallel import ParallelPlan
from repro.train.step import init_train_state, make_train_step

PLAN = ParallelPlan()


def _state(seed=0):
    cfg = configs.get_smoke("qwen1.5-0.5b")
    opt = AdamWConfig()
    return cfg, opt, init_train_state(jax.random.PRNGKey(seed), cfg, PLAN, opt)


def test_checkpoint_roundtrip_lossless_params(tmp_path):
    cfg, opt, state = _state()
    mgr = CheckpointManager(tmp_path, use_async=False)
    mgr.save(7, state)
    template = jax.tree.map(np.asarray, state)
    restored, _ = mgr.restore(template)
    for a, b in zip(
        jax.tree.leaves(template["params"]), jax.tree.leaves(restored["params"])
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_lossy_moments_bounded(tmp_path):
    cfg, opt, state = _state()
    # realistic smooth moments
    state["opt"]["m"] = jax.tree.map(
        lambda p: jnp.cumsum(
            jax.random.normal(jax.random.PRNGKey(0), p.shape), -1
        ).astype(jnp.float32)
        * 1e-3,
        state["params"],
    )
    mgr = CheckpointManager(tmp_path, use_async=False)
    manifest = mgr._write(1, jax.tree.map(np.asarray, state), {})
    restored, _ = mgr.restore(jax.tree.map(np.asarray, state))
    for a, b in zip(
        jax.tree.leaves(jax.tree.map(np.asarray, state["opt"]["m"])),
        jax.tree.leaves(restored["opt"]["m"]),
    ):
        rng = float(a.max() - a.min())
        if a.size >= 1024 and rng > 0:
            assert np.abs(a - b).max() <= 1e-4 * rng * (1 + 1e-6)
        else:
            np.testing.assert_array_equal(a, b)
    assert manifest["ratio"] > 1.2  # compression actually happened


def test_checkpoint_async_and_gc(tmp_path):
    cfg, opt, state = _state()
    mgr = CheckpointManager(tmp_path, keep=2, use_async=True)
    for s in [1, 2, 3, 4]:
        mgr.save(s, state)
    mgr.wait()
    assert mgr.list_steps() == [3, 4]


def test_checkpoint_atomic_no_partial(tmp_path):
    cfg, opt, state = _state()
    mgr = CheckpointManager(tmp_path, use_async=False)
    mgr.save(1, state)
    # a leftover tmp dir from a "crashed" save must not affect restore
    (tmp_path / ".tmp_step_2").mkdir()
    (tmp_path / ".tmp_step_2" / "garbage.bin").write_bytes(b"xx")
    restored, _ = mgr.restore(jax.tree.map(np.asarray, state))
    assert mgr.list_steps() == [1]


def test_checkpoint_corruption_detected(tmp_path):
    cfg, opt, state = _state()
    mgr = CheckpointManager(tmp_path, use_async=False)
    mgr.save(1, state)
    d = tmp_path / "step_1"
    victim = next(p for p in d.glob("*.bin"))
    blob = bytearray(victim.read_bytes())
    if len(blob) > 10:
        blob[5] ^= 0xFF
    victim.write_bytes(bytes(blob))
    with pytest.raises(Exception):
        mgr.restore(jax.tree.map(np.asarray, state))


def test_train_resume_deterministic(tmp_path):
    """save at step k, restore, and the (k+1)th step matches bit-for-bit
    (lossless params + deterministic data pipeline)."""
    cfg = configs.get_smoke("qwen1.5-0.5b")
    opt = AdamWConfig(lr=1e-3)
    state = init_train_state(jax.random.PRNGKey(0), cfg, PLAN, opt)
    step = make_train_step(cfg, PLAN, opt)
    pipe = make_pipeline(cfg, seq=16, global_batch=2)
    policy = CheckpointPolicy(rules=(("", LeafPolicy("lossless")),))
    mgr = CheckpointManager(tmp_path, policy=policy, use_async=False)

    for k in range(2):
        state, _ = step(state, {k2: jnp.asarray(v) for k2, v in pipe.batch_at(k).items()})
    mgr.save(2, state)
    state_a, _ = step(state, {k2: jnp.asarray(v) for k2, v in pipe.batch_at(2).items()})

    template = jax.tree.map(np.asarray, state)
    restored, _ = mgr.restore(template)
    restored = jax.tree.map(jnp.asarray, restored)
    state_b, _ = step(restored, {k2: jnp.asarray(v) for k2, v in pipe.batch_at(2).items()})
    for a, b in zip(jax.tree.leaves(state_a["params"]), jax.tree.leaves(state_b["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_heartbeat_straggler_and_death():
    t = [0.0]
    mon = HeartbeatMonitor(
        ["h0", "h1", "h2"], timeout_s=10, straggler_factor=2.0, clock=lambda: t[0]
    )
    for step in range(6):
        t[0] += 1.0
        mon.beat("h0", 1.0)
        mon.beat("h1", 1.0)
        mon.beat("h2", 3.5)  # slow host
    dec = {d.host: d for d in mon.observe()}
    assert dec["h0"].kind == "ok" and dec["h2"].kind == "straggler"
    t[0] += 20.0
    mon.beat("h0", 1.0)
    mon.beat("h2", 3.5)
    dec = {d.host: d for d in mon.observe()}
    assert dec["h1"].kind == "dead"
    assert set(mon.survivors()) == {"h0", "h2"}


def test_elastic_replan_and_divisibility():
    assert best_mesh_shape(512, 16) == (32, 16)
    assert best_mesh_shape(256, 16) == (16, 16)
    assert best_mesh_shape(240, 16) == (15, 16)
    assert best_mesh_shape(12, 16) == (12, 1) or best_mesh_shape(12, 16)[0] * best_mesh_shape(12, 16)[1] <= 12
    cfg = configs.get("granite-3-8b")
    checks = validate_divisibility(cfg, PLAN)
    assert all(checks.values())
