"""Tests for the SZx-style ultra-fast fixed-length tier (v6, ``sz3_fast``).

Covers the format contract (round-trip + pointwise bound across shapes,
dtypes and edge sizes), the constant-block fast path, the exact fail channel
(non-finite values and clip-range stragglers), the device classify+reduce
route (kernel-vs-ref agreement and both-routes bound), the bits/element
estimator, and the ``speed_tier`` selection knob that makes the tier win the
chunked contest when throughput is priced in.
"""
import numpy as np
import pytest

from repro.core import (
    CompressionConfig,
    ErrorBoundMode,
    FastModeCompressor,
    PIPELINES,
    decompress,
    parse_header,
    sz3_chunked,
    sz3_fast,
)
from repro.core.chunking import select_pipeline


def _bound_ok(x, xhat, eb, slack=1e-6):
    x64 = np.asarray(x, np.float64)
    fin = np.isfinite(x64)
    err = np.abs(x64[fin] - np.asarray(xhat, np.float64)[fin])
    return float(err.max(initial=0.0)) <= eb * (1 + slack)


def _smooth(n, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.standard_normal(n)).astype(dtype)


# ---------------------------------------------------------------------------
# round-trip + bound across shapes / dtypes / edge sizes
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "shape,dtype",
    [
        ((1000,), np.float32),
        ((37, 53), np.float64),
        ((5,), np.float32),  # smaller than one block
        ((256,), np.float32),  # exactly one block
        ((4, 4, 17), np.float64),
        ((0,), np.float32),  # empty
    ],
)
def test_roundtrip_abs(shape, dtype):
    rng = np.random.default_rng(int(np.prod(shape)) + 1)
    x = np.cumsum(rng.standard_normal(shape).reshape(-1)).reshape(shape)
    x = x.astype(dtype)
    eb = 1e-3
    blob = sz3_fast().compress(x, CompressionConfig(ErrorBoundMode.ABS, eb)).blob
    xhat = decompress(blob)
    assert xhat.shape == x.shape and xhat.dtype == x.dtype
    assert _bound_ok(x, xhat, eb)
    header, _ = parse_header(blob)
    assert header["v"] == 6 and header["kind"] == "fast"


def test_roundtrip_rel():
    x = _smooth(20000, seed=7)
    conf = CompressionConfig(ErrorBoundMode.REL, 1e-4)
    xhat = decompress(sz3_fast().compress(x, conf).blob)
    assert _bound_ok(x, xhat, 1e-4 * float(x.max() - x.min()))


@pytest.mark.parametrize("bs", [128, 256])
def test_block_sizes(bs):
    x = _smooth(5000, seed=3)
    blob = sz3_fast(bs=bs).compress(
        x, CompressionConfig(ErrorBoundMode.ABS, 1e-3)
    ).blob
    header, _ = parse_header(blob)
    assert header["spec"]["bs"] == bs
    assert _bound_ok(x, decompress(blob), 1e-3)


def test_invalid_block_size_rejected():
    with pytest.raises(ValueError, match="block size"):
        sz3_fast(bs=100)


# ---------------------------------------------------------------------------
# constant-block fast path
# ---------------------------------------------------------------------------
def test_constant_array_all_const_blocks():
    x = np.full(5000, 3.25, np.float32)
    res = sz3_fast().compress(
        x, CompressionConfig(ErrorBoundMode.ABS, 1e-6), with_stats=True
    )
    assert res.meta["n_const"] == res.meta["nb"]
    assert np.array_equal(decompress(res.blob), x)  # mean == the exact value
    # 1 tag bit + one mean per 256 elements: ~50x+ on constant data
    assert res.ratio > 30


def test_mixed_const_and_coded_blocks():
    rng = np.random.default_rng(11)
    x = np.concatenate(
        [np.full(1024, -2.5), np.cumsum(rng.standard_normal(1024)), np.zeros(512)]
    ).astype(np.float32)
    res = sz3_fast().compress(
        x, CompressionConfig(ErrorBoundMode.ABS, 1e-3), with_stats=True
    )
    assert 0 < res.meta["n_const"] < res.meta["nb"]
    assert _bound_ok(x, decompress(res.blob), 1e-3)


# ---------------------------------------------------------------------------
# exact fail channel: non-finite values and clip-range stragglers
# ---------------------------------------------------------------------------
def test_nonfinite_exact_restore():
    x = _smooth(4096, seed=5)
    x[5], x[99], x[2047] = np.nan, np.inf, -np.inf
    xhat = decompress(
        sz3_fast().compress(x, CompressionConfig(ErrorBoundMode.ABS, 1e-3)).blob
    )
    assert np.isnan(xhat[5]) and xhat[99] == np.inf and xhat[2047] == -np.inf
    assert _bound_ok(x, xhat, 1e-3)


def test_clip_range_outlier_rides_fail_channel():
    # a residual of ~1e30/(2e-6) quantization steps blows the 2^30 code clip;
    # the point must come back exact through the fail channel, not clamped
    x = _smooth(2048, seed=9)
    x[7] = np.float32(1e30)
    res = sz3_fast().compress(
        x, CompressionConfig(ErrorBoundMode.ABS, 1e-6), with_stats=True
    )
    xhat = decompress(res.blob)
    assert xhat[7] == x[7]
    assert res.meta["nfail"] >= 1
    assert _bound_ok(x, xhat, 1e-6)


# ---------------------------------------------------------------------------
# device route: kernel-vs-ref agreement and both-routes bound
# ---------------------------------------------------------------------------
def test_kernel_matches_reference_stats():
    jax = pytest.importorskip("jax")
    from repro.kernels.fastmode import ops as fops

    rng = np.random.default_rng(2)
    xb = np.cumsum(rng.standard_normal((64, 256)), axis=1).astype(np.float32)
    means_k, dev_k = fops.block_stats(xb)
    means_r, dev_r = fops.ref_block_stats(jax.numpy.asarray(xb))
    np.testing.assert_allclose(means_k, np.asarray(means_r), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(dev_k, np.asarray(dev_r), rtol=1e-5, atol=1e-5)


def test_device_route_bound_holds():
    pytest.importorskip("jax")
    # f64 input through the f32 kernel hint: classification may differ from
    # the host route, but the bound must close identically (host verify)
    x = _smooth(70000, seed=4, dtype=np.float64)
    conf = CompressionConfig(ErrorBoundMode.ABS, 1e-3)
    xhat = decompress(sz3_fast(device="force").compress(x, conf).blob)
    assert _bound_ok(x, xhat, 1e-3)
    # host route on the same data for cross-checking the decode path
    assert _bound_ok(x, decompress(sz3_fast(device="off").compress(x, conf).blob), 1e-3)


# ---------------------------------------------------------------------------
# estimator + selection knob
# ---------------------------------------------------------------------------
def test_estimate_error_orders_workloads():
    comp = FastModeCompressor()
    conf = CompressionConfig(ErrorBoundMode.ABS, 1e-3)
    rng = np.random.default_rng(6)
    const_bits = comp.estimate_error(np.full(4096, 1.5, np.float32), 1e-3, conf)
    noisy_bits = comp.estimate_error(
        rng.standard_normal(4096).astype(np.float32) * 100, 1e-3, conf
    )
    assert 0 < const_bits < 1.0  # ~tag + mean amortized over 256 elements
    assert noisy_bits > 5 * const_bits


def test_throughput_tier_selects_fast():
    x = _smooth(1 << 15, seed=8)
    conf = CompressionConfig(ErrorBoundMode.ABS, 1e-3)
    candidates = ("sz3_lorenzo", "sz3_fast", "sz3_hybrid")
    ratio_winner, _ = select_pipeline(x, 1e-3, conf, candidates)
    tp_winner, costs = select_pipeline(
        x, 1e-3, conf, candidates, speed_tier="throughput"
    )
    assert tp_winner == "sz3_fast"
    assert set(costs) == set(candidates)
    # ratio mode still prices coded bits only — smooth data favours Lorenzo
    assert ratio_winner != "sz3_fast"


def test_chunked_throughput_tier_end_to_end():
    x = _smooth(1 << 16, seed=10)
    conf = CompressionConfig(ErrorBoundMode.ABS, 1e-3)
    eng = sz3_chunked(chunk_bytes=1 << 16, speed_tier="throughput")
    res = eng.compress(x, conf, with_stats=True)
    assert all(c["pipeline"] == "sz3_fast" for c in res.meta["chunks"])
    assert _bound_ok(x, decompress(res.blob), 1e-3)


def test_invalid_speed_tier_rejected():
    with pytest.raises(ValueError, match="speed_tier"):
        sz3_chunked(speed_tier="nope")


def test_fast_registered_everywhere():
    from repro.core.transform import AUTO_CANDIDATES

    assert "sz3_fast" in PIPELINES
    assert "sz3_fast" in AUTO_CANDIDATES
