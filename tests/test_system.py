"""End-to-end system behaviour: short training runs move the loss, the serve
loop generates, and the whole train->checkpoint->elastic-restore->serve story
holds together on CPU."""
import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro import models
from repro.data import make_pipeline
from repro.ft import CheckpointManager, CheckpointPolicy, LeafPolicy
from repro.optim import AdamWConfig
from repro.parallel import ParallelPlan
from repro.train.step import init_train_state, make_train_step

PLAN = ParallelPlan()


def test_training_reduces_loss():
    cfg = configs.get_smoke("h2o-danube-1.8b")
    opt = AdamWConfig(lr=3e-3, weight_decay=0.0)
    state = init_train_state(jax.random.PRNGKey(0), cfg, PLAN, opt)
    step = jax.jit(make_train_step(cfg, PLAN, opt, total_steps=60))
    pipe = make_pipeline(cfg, seq=32, global_batch=4)
    losses = []
    for k in range(25):
        batch = {k2: jnp.asarray(v) for k2, v in pipe.batch_at(k % 4).items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses[::6]
    assert np.isfinite(losses).all()


def test_moe_training_reduces_loss():
    cfg = configs.get_smoke("deepseek-moe-16b")
    opt = AdamWConfig(lr=3e-3, weight_decay=0.0)
    state = init_train_state(jax.random.PRNGKey(0), cfg, PLAN, opt)
    step = jax.jit(make_train_step(cfg, PLAN, opt, total_steps=40))
    pipe = make_pipeline(cfg, seq=32, global_batch=4)
    losses = []
    for k in range(15):
        batch = {k2: jnp.asarray(v) for k2, v in pipe.batch_at(k % 4).items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2, losses[::4]


def test_microbatched_step_matches_unbatched():
    import dataclasses

    cfg = configs.get_smoke("qwen1.5-0.5b")
    opt = AdamWConfig(lr=1e-3)
    pipe = make_pipeline(cfg, seq=16, global_batch=4)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
    outs = {}
    for mb in [1, 2]:
        plan = dataclasses.replace(PLAN, microbatches=mb)
        state = init_train_state(jax.random.PRNGKey(0), cfg, plan, opt)
        step = make_train_step(cfg, plan, opt)
        state, m = step(state, batch)
        outs[mb] = (
            float(m["loss"]),
            np.asarray(jax.tree.leaves(state["params"])[0], np.float32),
        )
    assert abs(outs[1][0] - outs[2][0]) < 1e-3
    np.testing.assert_allclose(outs[1][1], outs[2][1], atol=2e-3)


def test_train_checkpoint_serve_cycle(tmp_path):
    cfg = configs.get_smoke("granite-3-8b")
    opt = AdamWConfig(lr=1e-3)
    state = init_train_state(jax.random.PRNGKey(0), cfg, PLAN, opt)
    step = jax.jit(make_train_step(cfg, PLAN, opt))
    pipe = make_pipeline(cfg, seq=16, global_batch=2)
    for k in range(3):
        state, _ = step(state, {k2: jnp.asarray(v) for k2, v in pipe.batch_at(k).items()})
    mgr = CheckpointManager(
        tmp_path, CheckpointPolicy(rules=(("", LeafPolicy("lossless")),)), use_async=False
    )
    mgr.save(3, state)
    restored, _ = mgr.restore(jax.tree.map(np.asarray, state))
    params = jax.tree.map(jnp.asarray, restored["params"])
    cache = models.init_cache(params, cfg, PLAN, 1, 8)
    tok = jnp.zeros((1, 1), jnp.int32)
    toks = []
    for _ in range(5):
        logits, cache = models.decode_step(params, cache, tok, cfg, PLAN)
        tok = jnp.argmax(logits, -1, keepdims=True).astype(jnp.int32)
        toks.append(int(tok[0, 0]))
    assert all(0 <= t < cfg.vocab for t in toks)


def test_data_pipeline_deterministic_and_elastic():
    cfg = configs.get_smoke("qwen1.5-0.5b")
    p1 = make_pipeline(cfg, seq=16, global_batch=8, seed=5)
    p2 = make_pipeline(cfg, seq=16, global_batch=8, seed=5)
    b1, b2 = p1.batch_at(17), p2.batch_at(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (8, 16)
