"""Per-arch reduced-config smoke tests (deliverable f): one forward/train
step on CPU asserting output shapes + no NaNs, plus a decode step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro import models
from repro.data import make_pipeline
from repro.parallel import ParallelPlan

PLAN = ParallelPlan()
B, S = 2, 32


def _batch(cfg, key):
    pipe = make_pipeline(cfg, seq=S, global_batch=B, seed=0)
    b = pipe.batch_at(0)
    return {k: jnp.asarray(v) for k, v in b.items()}


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_arch_train_step(arch):
    cfg = configs.get_smoke(arch)
    key = jax.random.PRNGKey(0)
    params = models.init_params(key, cfg, PLAN)
    batch = _batch(cfg, key)
    loss, grads = jax.value_and_grad(
        lambda p: models.loss_fn(p, batch, cfg, PLAN)
    )(params)
    assert jnp.isfinite(loss), arch
    assert 2.0 < float(loss) < 20.0, (arch, float(loss))
    gnorm = sum(
        float(jnp.sum(g.astype(jnp.float32) ** 2)) for g in jax.tree.leaves(grads)
    )
    assert np.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_arch_decode_step(arch):
    cfg = configs.get_smoke(arch)
    key = jax.random.PRNGKey(1)
    params = models.init_params(key, cfg, PLAN)
    batch = _batch(cfg, key)
    cache = models.init_cache(
        params, cfg, PLAN, B, 16, enc_frames=batch.get("enc_frames")
    )
    tok = jnp.zeros((B, 1), jnp.int32)
    for _ in range(3):
        logits, cache = models.decode_step(params, cache, tok, cfg, PLAN)
        tok = jnp.argmax(logits, -1, keepdims=True).astype(jnp.int32)
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), arch


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_full_config_values(arch):
    """The exact assigned config is instantiable (metadata only, no alloc)."""
    cfg = configs.get(arch)
    assert cfg.n_layers >= 12 and cfg.d_model >= 768
    assert cfg.padded_vocab % cfg.vocab_pad_to == 0
    assert cfg.n_flop_params() > 1e8
    kinds = cfg.block_kinds()
    if cfg.family == "hybrid":
        assert "shared_attn" in kinds and "ssm" in kinds


def test_exact_assigned_dims():
    c = configs.get("nemotron-4-340b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == (
        96, 18432, 96, 8, 73728, 256000,
    )
    c = configs.get("deepseek-moe-16b")
    assert (c.n_experts, c.top_k, c.n_shared_experts, c.moe_d_ff) == (64, 6, 2, 1408)
    c = configs.get("qwen3-moe-30b-a3b")
    assert (c.n_experts, c.top_k, c.head_dim) == (128, 8, 128)
    c = configs.get("mamba2-2.7b")
    assert (c.n_layers, c.d_model, c.ssm_state) == (64, 2560, 128)
    c = configs.get("zamba2-7b")
    assert (c.n_layers, c.d_model, c.ssm_state) == (81, 3584, 64)
    c = configs.get("h2o-danube-1.8b")
    assert c.sliding_window is not None


def test_prefill_matches_decode_chain():
    """prefill logits at position t == decode-step logits after consuming
    t tokens (cache correctness)."""
    cfg = configs.get_smoke("granite-3-8b")
    key = jax.random.PRNGKey(2)
    params = models.init_params(key, cfg, PLAN)
    toks = jax.random.randint(key, (1, 6), 0, cfg.vocab)
    pre = models.prefill_logits(params, {"tokens": toks}, cfg, PLAN)
    cache = models.init_cache(params, cfg, PLAN, 1, 16)
    for t in range(6):
        logits, cache = models.decode_step(params, cache, toks[:, t : t + 1], cfg, PLAN)
    np.testing.assert_allclose(
        np.asarray(pre), np.asarray(logits), rtol=2e-3, atol=2e-3
    )


def test_swa_decode_ring_wraps():
    """Sliding-window cache must evict old tokens but keep exact recent ones."""
    cfg = configs.get_smoke("h2o-danube-1.8b")  # window 16
    key = jax.random.PRNGKey(3)
    params = models.init_params(key, cfg, PLAN)
    toks = jax.random.randint(key, (1, 24), 0, cfg.vocab)
    cache = models.init_cache(params, cfg, PLAN, 1, 24)
    W = cache.k.shape[2]
    assert W == cfg.sliding_window  # ring sized to the window
    for t in range(24):
        logits, cache = models.decode_step(params, cache, toks[:, t : t + 1], cfg, PLAN)
    assert bool(jnp.isfinite(logits).all())
    pre = models.prefill_logits(params, {"tokens": toks}, cfg, PLAN)
    np.testing.assert_allclose(np.asarray(pre), np.asarray(logits), rtol=3e-3, atol=3e-3)


def test_int8_kv_cache_close_to_bf16():
    import dataclasses

    cfg = configs.get_smoke("granite-3-8b")
    key = jax.random.PRNGKey(4)
    params = models.init_params(key, cfg, PLAN)
    toks = jax.random.randint(key, (2, 8), 0, cfg.vocab)
    outs = {}
    for dt in ["bf16", "int8"]:
        plan = dataclasses.replace(PLAN, kv_cache_dtype=dt)
        cache = models.init_cache(params, cfg, plan, 2, 16)
        for t in range(8):
            logits, cache = models.decode_step(params, cache, toks[:, t : t + 1], cfg, plan)
        outs[dt] = np.asarray(jax.nn.log_softmax(logits))
    # int8 per-token quantization: small logprob drift
    drift = np.abs(outs["bf16"] - outs["int8"]).max()
    assert drift < 0.3, drift
