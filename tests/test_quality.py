"""Quality-targeted rate controller: PSNR/ratio/bitrate targets, the
per-chunk achieved records, and the integrations that consume them."""
import numpy as np
import pytest

from repro.core import (
    CompressionConfig,
    ErrorBoundMode,
    PIPELINES,
    QualityCompressor,
    QualityTarget,
    achieved_quality,
    decompress,
    metrics,
    sz3_quality,
)


def smooth_field(shape, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=shape)
    for ax in range(len(shape)):
        x = np.cumsum(x, axis=ax) / np.sqrt(shape[ax])
    return x.astype(dtype)


def test_target_validation():
    with pytest.raises(ValueError):
        QualityTarget()
    with pytest.raises(ValueError):
        QualityTarget(psnr=60.0, ratio=10.0)
    with pytest.raises(ValueError):
        QualityTarget(psnr=-3.0)
    assert QualityTarget(psnr=60.0).kind == "psnr"
    assert QualityTarget(bitrate=2.0).kind == "bitrate"


@pytest.mark.parametrize("target", [45.0, 60.0, 75.0])
def test_psnr_target_within_one_db(target):
    """The acceptance band: achieved within +-1 dB, never below target-1."""
    data = smooth_field((160, 96, 16), seed=3) * 10.0
    res = sz3_quality(target_psnr=target, chunk_bytes=1 << 18).compress(data)
    xhat = decompress(res.blob)
    achieved = metrics.psnr(data, xhat)
    assert target - 1.0 <= achieved <= target + 1.0, achieved
    # the recorded summary must match the independent measurement closely
    assert abs(res.meta["quality"]["achieved_psnr"] - achieved) < 0.05


def test_higher_psnr_costs_more_bits():
    data = smooth_field((128, 64, 16), seed=4)
    r40 = sz3_quality(target_psnr=40.0, chunk_bytes=1 << 18).compress(data)
    r70 = sz3_quality(target_psnr=70.0, chunk_bytes=1 << 18).compress(data)
    assert r40.ratio > r70.ratio


def test_ratio_target_tracks():
    data = smooth_field((128, 64, 16), seed=5)
    res = sz3_quality(target_ratio=8.0, chunk_bytes=1 << 18).compress(data)
    # estimator + one-step correction control: generous +-30% envelope
    assert 0.7 * 8.0 <= res.meta["quality"]["achieved_ratio"] <= 1.3 * 8.0


def test_bitrate_target_tracks():
    data = smooth_field((128, 64, 16), seed=6)
    res = sz3_quality(target_bitrate=3.0, chunk_bytes=1 << 18).compress(data)
    assert 0.7 * 3.0 <= res.meta["quality"]["achieved_bits"] <= 1.3 * 3.0


def test_per_chunk_records_in_container():
    data = smooth_field((96, 64), seed=7)
    res = sz3_quality(target_psnr=55.0, chunk_bytes=4096).compress(data)
    q = achieved_quality(res.blob)
    assert q["target"] == {"kind": "psnr", "value": 55.0}
    chunks = res.meta["chunks"]
    assert len(chunks) > 1
    for c in chunks:
        rec = c["q"]
        assert rec["eb"] > 0
        assert rec["psnr"] >= 55.0  # every chunk honours the floor
        assert rec["bits"] > 0
    # non-quality containers expose no record
    v1 = PIPELINES["sz3_lorenzo"]().compress(data, CompressionConfig(eb=1e-3))
    assert achieved_quality(v1.blob) is None


def test_constant_array_is_exact():
    const = np.full((64, 64), 1.25, np.float32)
    res = sz3_quality(target_psnr=60.0, chunk_bytes=4096).compress(const)
    assert np.array_equal(decompress(res.blob), const)
    assert res.meta["quality"]["achieved_psnr"] == float("inf")


def test_registered_and_default_target():
    comp = PIPELINES["sz3_quality"]()
    assert isinstance(comp, QualityCompressor)
    assert comp.target.kind == "psnr" and comp.target.psnr == 60.0


def test_workers_give_identical_container():
    data = smooth_field((128, 64, 8), seed=8)
    b1 = QualityCompressor(target_psnr=55.0, chunk_bytes=1 << 17, workers=1).compress(data).blob
    b4 = QualityCompressor(target_psnr=55.0, chunk_bytes=1 << 17, workers=4).compress(data).blob
    assert b1 == b4


def test_quality_container_decodes_via_plain_v2_path():
    """The quality container is kind "chunked" v2 — a reader that knows
    nothing about quality records decodes it."""
    from repro.core import parse_header
    from repro.core.chunking import decompress_chunked

    data = smooth_field((96, 32), seed=9)
    res = sz3_quality(target_psnr=50.0, chunk_bytes=4096).compress(data)
    header, off = parse_header(res.blob)
    assert header["kind"] == "chunked" and header["v"] == 2
    out = decompress_chunked(res.blob, header, off)
    assert out.shape == data.shape
    assert metrics.psnr(data, out) >= 49.0


def test_checkpoint_psnr_codec_roundtrip(tmp_path):
    jax = pytest.importorskip("jax")
    del jax
    from repro.ft.checkpoint import LeafPolicy, decode_leaf, encode_leaf

    arr = smooth_field((64, 256), seed=10)
    blob, meta = encode_leaf(arr, LeafPolicy(mode="psnr", target_psnr=65.0))
    assert meta["codec"] == "sz3_psnr"
    assert meta["achieved_psnr"] >= 64.0
    out = decode_leaf(blob, meta)
    assert out.shape == arr.shape and out.dtype == arr.dtype
    assert metrics.psnr(arr, out) >= 64.0
