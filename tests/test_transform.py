"""Transform-coding subsystem invariants (core/transform.py):

  * absolute error bound holds across shapes/dtypes/distributions/modes;
  * the v3 container self-describes (parse_header tag, decompress dispatch);
  * select_pipeline picks the transform coder on oscillatory data and the
    hybrid sz3_auto engine mixes families per chunk;
  * device (Pallas, force mode) and host paths both honour the bound;
  * non-finite values, empty/0-d arrays, and frame streams survive.
"""
import numpy as np
import pytest

from repro.core import (
    AUTO_CANDIDATES,
    ChunkedCompressor,
    CompressionConfig,
    ErrorBoundMode,
    PIPELINES,
    TransformCompressor,
    decompress,
    metrics,
    parse_header,
    select_pipeline,
    sz3_auto,
    sz3_transform,
)
from repro.core.chunking import decompress_chunk, frames_to_blob, compress_stream


def _osc(n, dtype=np.float32):
    t = np.arange(n, dtype=np.float64)
    return (np.sin(0.93 * np.pi * t) + 0.1 * np.sin(2e-4 * t)).astype(dtype)


def _smooth(shape, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(shape)
    for ax in range(len(shape)):
        x = np.cumsum(x, axis=ax)
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# error bound + container round trip
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(4096,), (61, 67), (17, 9, 23)])
@pytest.mark.parametrize("eb", [1e-1, 1e-3, 1e-5])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_transform_bound_abs(shape, eb, dtype):
    x = _smooth(shape, seed=hash(shape) % 100, dtype=dtype)
    res = sz3_transform().compress(x, CompressionConfig(mode=ErrorBoundMode.ABS, eb=eb))
    xhat = decompress(res.blob)
    assert xhat.shape == x.shape and xhat.dtype == x.dtype
    assert metrics.max_abs_error(x, xhat) <= eb * (1 + 1e-9)


@pytest.mark.parametrize("eb", [1e-2, 1e-4])
def test_transform_bound_rel(eb):
    x = _osc(20000) * 37.0
    res = sz3_transform().compress(x, CompressionConfig(mode=ErrorBoundMode.REL, eb=eb))
    xhat = decompress(res.blob)
    assert metrics.max_abs_error(x, xhat) <= eb * float(x.max() - x.min()) * (1 + 1e-9)


def test_transform_header_tag_and_dispatch():
    x = _smooth((512,))
    res = sz3_transform().compress(x, CompressionConfig(eb=1e-3))
    header, _ = parse_header(res.blob)
    assert header["v"] == 3
    assert header["kind"] == "transform"
    assert header["spec"]["kind"] == "transform"
    assert header["spec"]["block"] == 4
    # the generic entry point must auto-detect the v3 container
    assert decompress(res.blob).shape == x.shape


def test_transform_registered_pipeline():
    assert "sz3_transform" in PIPELINES and "sz3_auto" in PIPELINES
    comp = PIPELINES["sz3_transform"]()
    assert isinstance(comp, TransformCompressor)


@pytest.mark.parametrize(
    "arr",
    [
        np.zeros(0, np.float32),
        np.float32(3.25),
        np.full((5, 5), 7.0, np.float32),
        np.array([np.nan, 1.0, np.inf, -2.0] * 10, np.float32),
    ],
    ids=["empty", "scalar", "constant", "nonfinite"],
)
def test_transform_edge_inputs(arr):
    a = np.asarray(arr)
    res = sz3_transform().compress(a, CompressionConfig(eb=1e-3))
    back = decompress(res.blob)
    assert back.shape == a.shape
    fin = np.isfinite(a)
    if a.size:
        assert np.allclose(np.asarray(back)[fin], a[fin], atol=1e-3)
        # non-finite points ride the fail channel exactly
        assert np.array_equal(np.asarray(back)[~fin], a[~fin], equal_nan=True)


def test_transform_bound_survives_output_dtype_rounding():
    """Regression: when the error bound is below the float32 ulp of the data,
    the cast back onto the storage grid is itself a bound hazard — compress
    must verify the POST-cast reconstruction and fail-channel the rest."""
    rng = np.random.default_rng(0)
    x = np.clip(192 + rng.standard_normal(8192) * 20, 129, 255).astype(np.float32)
    eb = 1.5e-5  # < float32 ulp (1.526e-5) in [128, 256)
    res = sz3_transform().compress(x, CompressionConfig(mode=ErrorBoundMode.ABS, eb=eb))
    xhat = decompress(res.blob)
    assert metrics.max_abs_error(x, xhat) <= eb * (1 + 1e-9)


def test_transform_integer_input_casts():
    x = np.arange(4096, dtype=np.int32)
    res = sz3_transform().compress(x, CompressionConfig(eb=1e-2))
    back = decompress(res.blob)
    assert back.dtype == np.float32
    assert np.abs(back.astype(np.float64) - x).max() <= 1e-2 * (1 + 1e-9)


# ---------------------------------------------------------------------------
# online prediction-vs-transform selection (the SZ/ZFP criterion)
# ---------------------------------------------------------------------------

def test_select_pipeline_prefers_transform_on_oscillatory():
    conf = CompressionConfig(mode=ErrorBoundMode.ABS, eb=1e-3)
    winner, scores = select_pipeline(_osc(32768), 1e-3, conf, AUTO_CANDIDATES)
    assert winner == "sz3_transform", scores
    # and prediction keeps winning its home turf (a very smooth field, where
    # interpolation/Lorenzo residuals are near-zero but every transform
    # coefficient still spans several bitplanes)
    verysmooth = (np.sin(2e-4 * np.arange(32768)) * 10).astype(np.float32)
    winner2, scores2 = select_pipeline(verysmooth, 1e-3, conf, AUTO_CANDIDATES)
    assert winner2 != "sz3_transform", scores2


def test_auto_chunked_mixes_families_and_bounds():
    """The acceptance fixture: a smooth+oscillatory concatenation must route
    at least one chunk to the transform coder and stay in bound."""
    data = np.concatenate([_smooth((32768,), seed=5), _osc(32768)])
    conf = CompressionConfig(mode=ErrorBoundMode.REL, eb=1e-4)
    res = sz3_auto(chunk_bytes=32768 * 4).compress(data, conf, with_stats=True)
    picked = [c["pipeline"] for c in res.meta["chunks"]]
    assert "sz3_transform" in picked, picked
    assert any(p != "sz3_transform" for p in picked), picked
    xhat = decompress(res.blob)
    bound = 1e-4 * float(data.max() - data.min())
    assert np.abs(xhat.astype(np.float64) - data).max() <= bound * (1 + 1e-9)


def test_auto_container_random_access_and_frames():
    data = np.concatenate([_smooth((16384,), seed=7), _osc(16384)])
    conf = CompressionConfig(mode=ErrorBoundMode.ABS, eb=1e-3)
    eng = sz3_auto(chunk_bytes=16384 * 4)
    blob = eng.compress(data, conf).blob
    header, _ = parse_header(blob)
    # per-chunk random access decodes transform chunks standalone
    parts = [decompress_chunk(blob, i) for i in range(len(header["chunks"]))]
    np.testing.assert_array_equal(np.concatenate(parts), decompress(blob))
    # frame streams recover the transform pipeline name from the v3 spec
    frames = list(compress_stream(data, conf, candidates=AUTO_CANDIDATES, chunk_bytes=16384 * 4))
    re_blob = frames_to_blob(frames)
    h2, _ = parse_header(re_blob)
    assert [c["pipeline"] for c in h2["chunks"]] == [c["pipeline"] for c in header["chunks"]]
    assert re_blob == blob


def test_transform_estimate_error_currency():
    """The cost model returns bits/element comparable across families: near
    zero on trivially compressible data, large on incompressible noise."""
    conf = CompressionConfig()
    comp = sz3_transform()
    low = comp.estimate_error(np.zeros(4096, np.float32), 1e-3, conf)
    rng = np.random.default_rng(0)
    high = comp.estimate_error(rng.standard_normal(4096).astype(np.float32), 1e-6, conf)
    assert 0.0 <= low < 1.0
    assert high > 5.0


# ---------------------------------------------------------------------------
# device path (Pallas kernels, interpret mode via device="force")
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(8192,), (64, 256)])
def test_transform_device_force_bound_and_selfdescribing(shape):
    x = _smooth(shape, seed=11)
    comp = TransformCompressor(device="force")
    res = comp.compress(x, CompressionConfig(mode=ErrorBoundMode.ABS, eb=1e-3))
    header, _ = parse_header(res.blob)
    assert header["meta"].get("device") == 1, "kernel path not engaged"
    xhat = decompress(res.blob)  # fresh entry point, host inverse on CPU
    assert metrics.max_abs_error(x, xhat) <= 1e-3 * (1 + 1e-9)


def test_transform_device_off_matches_host_bound():
    x = _osc(8192)
    conf = CompressionConfig(mode=ErrorBoundMode.ABS, eb=1e-4)
    b_off = TransformCompressor(device="off").compress(x, conf).blob
    h, _ = parse_header(b_off)
    assert "device" not in (h["meta"] or {})
    assert metrics.max_abs_error(x, decompress(b_off)) <= 1e-4 * (1 + 1e-9)
