"""Tests for the telemetry spine (``repro.core.telemetry``).

Covers the tracing core (span nesting, deterministic merge of parallel
worker span trees, disabled-path no-op semantics), the streaming histogram
(percentile accuracy against a numpy reference within the bucket-width
bound), the pinned selection-decision record schema (every multi-candidate
engine must emit schema-valid records, from the live trace AND recovered
from the blob alone via ``explain``), the metrics registry / Prometheus
exposition, and the structured key=value logger.
"""
import concurrent.futures as cf
import json
import logging

import numpy as np
import pytest

from repro.core import (
    CompressionConfig,
    ErrorBoundMode,
    decompress,
    sz3_auto,
    sz3_chunked,
    sz3_fast,
    sz3_hybrid,
    sz3_lorenzo,
    sz3_quality,
    telemetry,
)


def _smooth(n, seed=0):
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.standard_normal(n)).astype(np.float32)


REL3 = CompressionConfig(mode=ErrorBoundMode.REL, eb=1e-3)


# ---------------------------------------------------------------------------
# span nesting + deterministic merge
# ---------------------------------------------------------------------------

def test_span_nesting_tree():
    with telemetry.trace("t") as tr:
        with telemetry.span("outer"):
            with telemetry.span("inner", bytes=4):
                pass
            with telemetry.span("inner2"):
                pass
    (outer,) = tr.root.children
    assert outer.name == "outer"
    assert [c.name for c in outer.children] == ["inner", "inner2"]
    assert outer.children[0].attrs["bytes"] == 4
    assert outer.seconds >= sum(c.seconds for c in outer.children) >= 0.0


def test_parallel_worker_spans_merge_deterministically():
    """Worker-thread spans land under the root and serialize in ``order``
    attr order, independent of completion order."""

    def work(i):
        with telemetry.span("chunk", order=i):
            with telemetry.span("predict"):
                pass
        return i

    trees = []
    for attempt in range(3):
        with telemetry.trace("t") as tr:
            with cf.ThreadPoolExecutor(max_workers=4) as pool:
                # reversed submission order: completion order != index order
                list(pool.map(telemetry.propagate(work), range(8)))
        trees.append(tr.to_dict()["spans"])
    orders = [s["attrs"]["order"] for s in trees[0]]
    assert orders == list(range(8))
    names = [s["name"] for s in trees[0]]
    assert names == ["chunk"] * 8
    # structurally identical across runs (timings differ, structure must not)
    def strip(spans):
        return [
            {
                "name": s["name"],
                "attrs": s.get("attrs"),
                "children": strip(s.get("children", [])),
            }
            for s in spans
        ]
    assert strip(trees[0]) == strip(trees[1]) == strip(trees[2])


def test_contextvar_does_not_leak_without_propagate():
    """A worker task NOT wrapped in propagate() records nothing — the trace
    is context-scoped, not global."""
    def work(_):
        telemetry.count("leaked")
        with telemetry.span("leaked_span"):
            pass

    with telemetry.trace("t") as tr:
        with cf.ThreadPoolExecutor(max_workers=2) as pool:
            list(pool.map(work, range(4)))
    assert tr.counters == {}
    assert tr.root.children == []


def test_nested_traces_innermost_wins():
    with telemetry.trace("outer") as outer:
        telemetry.count("outer_only")
        with telemetry.trace("inner") as inner:
            telemetry.count("inner_only")
    assert "inner_only" in inner.counters
    assert "inner_only" not in outer.counters
    assert "outer_only" in outer.counters


# ---------------------------------------------------------------------------
# disabled-path no-op semantics
# ---------------------------------------------------------------------------

def test_disabled_path_is_noop():
    assert telemetry.current() is None
    assert not telemetry.enabled()
    s = telemetry.span("predict", bytes=10)
    with s as sp:
        sp.set(extra=1)  # must not raise
    # the no-op span is a shared singleton: nothing allocated, nothing kept
    assert telemetry.span("huffman") is s
    telemetry.count("x")
    telemetry.observe("y", 1.0)
    telemetry.record_decision(telemetry.make_decision("e", "w"))
    assert telemetry.current() is None


def test_untraced_compress_deterministic_and_traced_roundtrips():
    """With no trace active the selection info is never computed and the
    container is byte-identical run to run (the pinned frame-stream identity
    relies on this); under a trace, ``sel`` entries embed in the chunk table
    (bytes may differ) but the reconstruction must stay identical."""
    data = _smooth(1 << 14)
    comp = sz3_chunked(chunk_bytes=1 << 14)
    plain = comp.compress(data, REL3).blob
    assert comp.compress(data, REL3).blob == plain
    with telemetry.trace("t"):
        traced = comp.compress(data, REL3).blob
    np.testing.assert_array_equal(decompress(plain), decompress(traced))
    # untraced containers carry no sel entries — nothing paid when off
    from repro.core import parse_header

    header, _ = parse_header(plain)
    assert all("sel" not in c for c in header["chunks"])
    traced_header, _ = parse_header(traced)
    assert any("sel" in c for c in traced_header["chunks"])


def test_serial_parallel_traces_structurally_identical():
    data = _smooth(1 << 15)
    trees = []
    blobs = []
    for workers in (1, 4):
        comp = sz3_chunked(chunk_bytes=1 << 13, workers=workers)
        with telemetry.trace("t") as tr:
            blobs.append(comp.compress(data, REL3).blob)
        trees.append(tr.to_dict()["spans"])

    def strip(spans):
        return [
            {"name": s["name"], "children": strip(s.get("children", []))}
            for s in spans
        ]

    assert blobs[0] == blobs[1]
    assert strip(trees[0]) == strip(trees[1])


# ---------------------------------------------------------------------------
# streaming histogram
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dist", ["lognormal", "uniform", "exponential"])
def test_histogram_percentiles_vs_numpy(dist):
    rng = np.random.default_rng(7)
    vals = {
        "lognormal": rng.lognormal(0.0, 2.0, 20_000),
        "uniform": rng.uniform(1e-3, 1e3, 20_000),
        "exponential": rng.exponential(5.0, 20_000),
    }[dist]
    h = telemetry.StreamingHistogram()
    for v in vals:
        h.observe(v)
    # bucket width is 2**(1/16)-1 (~4.4%) relative — assert within 5%
    for q in (0.5, 0.9, 0.99):
        ref = float(np.quantile(vals, q))
        got = h.quantile(q)
        assert abs(got - ref) / ref < 0.05, (q, got, ref)
    snap = h.snapshot()
    assert snap["count"] == vals.size
    assert snap["min"] == pytest.approx(vals.min())
    assert snap["max"] == pytest.approx(vals.max())
    assert snap["sum"] == pytest.approx(vals.sum(), rel=1e-9)


def test_histogram_zero_and_negative_bucket():
    h = telemetry.StreamingHistogram()
    for v in [0.0, -1.0, 0.0, 5.0]:
        h.observe(v)
    assert h.n == 4
    assert h.quantile(0.0) <= 0.0
    assert h.quantile(1.0) == pytest.approx(5.0, rel=0.05)


def test_histogram_merge_equals_combined():
    rng = np.random.default_rng(11)
    a, b = rng.lognormal(0, 1, 5000), rng.lognormal(1, 1, 5000)
    ha, hb, hc = (telemetry.StreamingHistogram() for _ in range(3))
    for v in a:
        ha.observe(v)
        hc.observe(v)
    for v in b:
        hb.observe(v)
        hc.observe(v)
    ha.merge(hb)
    assert ha.n == hc.n
    assert ha.quantile(0.5) == pytest.approx(hc.quantile(0.5))
    assert ha.snapshot()["max"] == hc.snapshot()["max"]


# ---------------------------------------------------------------------------
# pinned decision-record schema, every engine
# ---------------------------------------------------------------------------

def _engines():
    rng = np.random.default_rng(3)
    smooth = np.cumsum(rng.standard_normal((64, 256)).astype(np.float32), 0)
    return [
        ("sz3_chunked", sz3_chunked(chunk_bytes=1 << 14), smooth, REL3),
        ("sz3_auto", sz3_auto(chunk_bytes=1 << 14), smooth, REL3),
        ("sz3_hybrid", sz3_hybrid(), smooth, REL3),
        ("sz3_fast", sz3_fast(), smooth,
         CompressionConfig(mode=ErrorBoundMode.ABS, eb=1e-3)),
    ]


@pytest.mark.parametrize("name,comp,data,conf", _engines(),
                         ids=[e[0] for e in _engines()])
def test_decision_records_trace_and_blob(name, comp, data, conf):
    with telemetry.trace("t") as tr:
        res = comp.compress(data, conf)
    assert tr.decisions, f"{name}: no decision records in trace"
    for rec in tr.decisions:
        telemetry.validate_decision(rec)
        assert rec["engine"] == name
        assert rec["winner"] in rec["candidates"]
        assert json.loads(json.dumps(rec)) == rec  # JSON-serializable
    # recovered from the container alone (no trace): same engine + winners
    from_blob = telemetry.explain(res.blob)
    assert from_blob, f"{name}: explain(blob) returned nothing"
    for rec in from_blob:
        telemetry.validate_decision(rec)
        assert rec["engine"] == name
    assert [r["winner"] for r in from_blob] == [
        r["winner"] for r in tr.decisions
    ]


def test_quality_decision_records():
    data = np.cumsum(
        np.random.default_rng(5).standard_normal((48, 128)).astype(np.float32), 0
    )
    q = sz3_quality(target_psnr=55.0, chunk_bytes=1 << 14)
    with telemetry.trace("t") as tr:
        res = q.compress(data)
    assert tr.decisions
    for rec in tr.decisions:
        telemetry.validate_decision(rec)
        assert rec["engine"] == "sz3_quality"
        # achieved-quality record rides along in extra
        assert rec["extra"] and "quality" in rec["extra"]
    from_blob = telemetry.explain(res.blob)
    assert from_blob and all(
        r["engine"] == "sz3_quality" for r in from_blob
    )
    for rec in from_blob:
        telemetry.validate_decision(rec)


def test_explain_single_pipeline_blob():
    data = _smooth(4096)
    res = sz3_lorenzo().compress(data, REL3)
    recs = telemetry.explain(res.blob)
    assert len(recs) == 1
    telemetry.validate_decision(recs[0])
    assert recs[0]["scope"] == "array"


def test_validate_decision_rejects_bad_records():
    good = telemetry.make_decision("e", "w", candidates=["w"])
    telemetry.validate_decision(good)
    with pytest.raises(ValueError):
        telemetry.validate_decision({**good, "unknown_field": 1})
    with pytest.raises(ValueError):
        bad = dict(good)
        del bad["engine"]
        telemetry.validate_decision(bad)
    with pytest.raises(ValueError):
        telemetry.validate_decision({**good, "winner": "not-a-candidate"})


def test_trial_runoffs_do_not_pollute_decision_stream():
    """The chunked contest trial-compresses candidates and the winning
    sub-engine may itself be multi-candidate (hybrid inside a chunk):
    exactly one record per chunk, all from the outer engine."""
    data = _smooth(1 << 15)
    comp = sz3_auto(chunk_bytes=1 << 13)
    with telemetry.trace("t") as tr:
        res = comp.compress(data, REL3)
    n_chunks = len(
        [r for r in telemetry.explain(res.blob) if r["scope"] == "chunk"]
    )
    assert len(tr.decisions) == n_chunks
    assert {r["engine"] for r in tr.decisions} == {"sz3_auto"}
    assert [r["index"] for r in tr.decisions] == list(range(n_chunks))


# ---------------------------------------------------------------------------
# stage spans on the engine paths + summary rendering
# ---------------------------------------------------------------------------

def test_compress_emits_stage_spans():
    data = _smooth(1 << 14)
    with telemetry.trace("t") as tr:
        sz3_chunked(chunk_bytes=1 << 13).compress(data, REL3)
    totals = tr.stage_totals()
    for stage in ("chunk", "select", "predict", "huffman", "lossless",
                  "integrity"):
        assert stage in totals, f"missing stage span: {stage}"
        assert totals[stage]["calls"] >= 1
    text = telemetry.trace_summary(tr)
    assert "predict" in text and "calls" in text


def test_trace_json_roundtrip(tmp_path):
    data = _smooth(1 << 13)
    with telemetry.trace("t") as tr:
        sz3_fast().compress(
            data, CompressionConfig(mode=ErrorBoundMode.ABS, eb=1e-3)
        )
    p = tmp_path / "trace.json"
    tr.save_json(str(p))
    doc = json.loads(p.read_text())
    assert doc["name"] == "t"
    assert doc["decisions"] and doc["spans"]
    assert doc["seconds"] >= 0


# ---------------------------------------------------------------------------
# metrics registry + Prometheus exposition
# ---------------------------------------------------------------------------

def test_metrics_registry_and_prometheus_text():
    telemetry.reset_metrics()
    try:
        telemetry.metric_count("sz3_requests_total")
        telemetry.metric_count("sz3_requests_total", 2)
        for v in (0.01, 0.02, 0.04):
            telemetry.metric_observe("sz3_decode_step_seconds", v)
        text = telemetry.prometheus_text()
        assert 'sz3_requests_total 3' in text
        assert "# TYPE sz3_requests_total counter" in text
        assert "# TYPE sz3_decode_step_seconds summary" in text
        assert 'sz3_decode_step_seconds{quantile="0.5"}' in text
        assert "sz3_decode_step_seconds_count 3" in text
    finally:
        telemetry.reset_metrics()


# ---------------------------------------------------------------------------
# structured logger
# ---------------------------------------------------------------------------

class _ListHandler(logging.Handler):
    def __init__(self):
        super().__init__(level=logging.DEBUG)
        self.records = []

    def emit(self, record):
        self.records.append(record)


def _capture(name):
    """The telemetry namespace manages its own handler (propagate=False so
    app-level root handlers never double-print), so capture by attaching a
    handler to the named logger directly rather than via caplog/root."""
    log = telemetry.get_logger(name)
    h = _ListHandler()
    py = logging.getLogger(f"repro.telemetry.{name}")
    old = py.level
    py.addHandler(h)
    py.setLevel(logging.DEBUG)
    return log, h, (py, old)


def test_kv_logger_format():
    log, h, (py, old) = _capture("testmod")
    try:
        log.info("thing_done", n=3, rate=1234.5678, note="two words")
    finally:
        py.removeHandler(h)
        py.setLevel(old)
    assert len(h.records) == 1
    msg = h.records[0].getMessage()
    assert msg.startswith("thing_done ")
    assert "n=3" in msg
    assert "rate=1234.57" in msg
    assert 'note="two words"' in msg


def test_kv_logger_single_record_per_event():
    """One event == one logging call == one atomic line (the fix for
    interleaved multi-print status output from worker threads)."""
    log, h, (py, old) = _capture("atomic")
    try:
        log.info("ev", a=1, b=2, c=3)
    finally:
        py.removeHandler(h)
        py.setLevel(old)
    assert len(h.records) == 1
    assert "\n" not in h.records[0].getMessage()
