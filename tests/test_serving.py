"""Async KV-offload service + cached decode state (PR 9).

Covers the serving tentpole and its satellites: O(chunk) random access with
per-chunk CRC isolation, the reusable Huffman decode-table handle, the
bounded decode-state LRU, gauge metrics, the coalescing async service
(concurrent byte-identity, eviction, typed fault isolation), and the offload
accounting fixes in ``launch/serve``.
"""
import asyncio

import numpy as np
import pytest

from repro.core import (
    CompressionConfig,
    ErrorBoundMode,
    IntegrityError,
    decompress,
    decompress_chunk,
    encoders,
    parse_chunked_index,
    sz3_chunked,
    telemetry,
)
from repro.serve.offload import (
    DecodeStateCache,
    OffloadError,
    OffloadService,
    blob_key,
)


def _field(shape=(96, 96), seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(shape)
    for ax in range(x.ndim):
        x = np.cumsum(x, axis=ax) / np.sqrt(x.shape[ax])
    return x.astype(np.float32)


@pytest.fixture(scope="module")
def container():
    data = _field()
    conf = CompressionConfig(mode=ErrorBoundMode.ABS, eb=1e-3)
    blob = sz3_chunked(chunk_bytes=4096).compress(data, conf).blob
    return data, blob


def _corrupt_chunk(blob, idx, chunk):
    off, ln = idx.bounds[chunk]
    lo = idx.body_off + off + ln // 2
    return blob[:lo] + bytes([blob[lo] ^ 0xFF]) + blob[lo + 1 :]


# ---------------------------------------------------------------------------
# parse split + O(chunk) strict random access
# ---------------------------------------------------------------------------

class TestChunkedIndex:
    def test_parsed_reads_equal_unparsed(self, container):
        _, blob = container
        idx = parse_chunked_index(blob)
        assert idx.n_chunks > 4
        assert idx.chunk_crcs is not None and idx.header_ok
        for c in (0, 1, idx.n_chunks - 1):
            a = decompress_chunk(blob, c)
            b = decompress_chunk(blob, c, parsed=idx)
            assert np.array_equal(a, b)

    def test_chunks_reassemble_to_full_decode(self, container):
        data, blob = container
        idx = parse_chunked_index(blob)
        parts = [decompress_chunk(blob, c, parsed=idx) for c in range(idx.n_chunks)]
        whole = np.concatenate(parts, axis=0).reshape(data.shape)
        assert np.array_equal(whole, decompress(blob))

    def test_corrupt_other_chunk_does_not_fail_read(self, container):
        """THE satellite pin: strict random access is O(chunk) — a corrupt
        sibling chunk must not fail the requested read."""
        _, blob = container
        idx = parse_chunked_index(blob)
        bad = _corrupt_chunk(blob, idx, chunk=2)
        # the undamaged chunk reads fine, byte-identical, under strict verify
        assert np.array_equal(
            decompress_chunk(bad, 0, verify="strict"),
            decompress_chunk(blob, 0),
        )
        # the damaged chunk itself raises, localized to its index
        with pytest.raises(IntegrityError) as ei:
            decompress_chunk(bad, 2, verify="strict")
        assert ei.value.chunk_index == 2
        # and the whole-container strict decode still refuses the blob
        with pytest.raises(IntegrityError):
            decompress(bad, verify="strict")

    def test_header_damage_fails_every_read(self, container):
        _, blob = container
        bad = blob[:22] + bytes([blob[22] ^ 0xFF]) + blob[23:]
        with pytest.raises(ValueError):
            decompress_chunk(bad, 0, verify="strict")

    def test_verify_off_skips_crc(self, container):
        _, blob = container
        idx = parse_chunked_index(blob)
        bad = _corrupt_chunk(blob, idx, chunk=1)
        # verify="off" reaches the nested decode; it may raise a decode error
        # or return garbage, but must not raise on the UNDAMAGED chunk
        out = decompress_chunk(bad, 0, verify="off")
        assert np.array_equal(out, decompress_chunk(blob, 0))

    def test_rejects_non_chunked_blob(self):
        with pytest.raises(ValueError):
            parse_chunked_index(b"garbage not a container")


# ---------------------------------------------------------------------------
# huffman decode-table handle + LRU
# ---------------------------------------------------------------------------

class TestHuffmanHandle:
    def test_handle_decode_equals_plain(self):
        rng = np.random.default_rng(2)
        codes = rng.integers(0, 200, 5000)
        enc = encoders.HuffmanEncoder()
        buf = enc.encode(codes)
        h = encoders.huffman_decode_handle(buf)
        assert h is not None
        a = enc.decode(buf, codes.size)
        b = enc.decode(buf, codes.size, handle=h)
        c = enc.decode(buf, codes.size, handle=h)  # reuse
        assert np.array_equal(a, codes) and np.array_equal(b, codes)
        assert np.array_equal(c, codes)

    def test_empty_stream_handle_is_none(self):
        enc = encoders.HuffmanEncoder()
        buf = enc.encode(np.zeros(0, np.int64))
        assert encoders.huffman_decode_handle(buf) is None
        assert enc.decode(buf, 0).size == 0

    def test_table_cache_lru_bound_and_stats(self):
        encoders.clear_table_cache()
        rng = np.random.default_rng(3)
        enc = encoders.HuffmanEncoder()
        # distinct alphabets -> distinct length signatures -> distinct entries
        bufs = []
        for k in range(5):
            codes = rng.integers(0, 10 + 17 * k, 2000)
            bufs.append((enc.encode(codes), codes))
        old_max = encoders._TABLE_CACHE_MAX
        encoders._TABLE_CACHE_MAX = 3
        try:
            encoders.clear_table_cache()
            for buf, codes in bufs:
                assert np.array_equal(enc.decode(buf, codes.size), codes)
            stats = encoders.table_cache_stats()
            assert stats["size"] <= 3
            assert stats["evictions"] >= 2
            # hot entry hits
            enc.decode(bufs[-1][0], bufs[-1][1].size)
            assert encoders.table_cache_stats()["hits"] > stats["hits"] - 1
        finally:
            encoders._TABLE_CACHE_MAX = old_max
            encoders.clear_table_cache()


# ---------------------------------------------------------------------------
# telemetry gauges
# ---------------------------------------------------------------------------

class TestGauges:
    def test_gauge_set_add_snapshot_prometheus(self):
        reg = telemetry.MetricsRegistry()
        reg.gauge("sz3_serve_queue_depth", 3)
        assert reg.gauge_add("sz3_serve_queue_depth", 2) == 5.0
        assert reg.gauge_add("sz3_serve_queue_depth", -5) == 0.0
        reg.gauge("sz3_serve_pages", 7)
        snap = reg.snapshot()
        assert snap["gauges"]["sz3_serve_pages"] == 7.0
        text = reg.prometheus_text()
        assert "# TYPE sz3_serve_pages gauge" in text
        assert "sz3_serve_pages 7" in text
        reg.reset()
        assert reg.snapshot()["gauges"] == {}


# ---------------------------------------------------------------------------
# decode-state cache
# ---------------------------------------------------------------------------

class TestDecodeStateCache:
    def test_index_identity_and_hit(self, container):
        _, blob = container
        cache = DecodeStateCache(max_entries=4)
        i1 = cache.index_for(blob)
        i2 = cache.index_for(blob)
        assert i1 is i2
        s = cache.stats()
        assert s["hits"] == 1 and s["misses"] == 1

    def test_lru_eviction_under_bound(self):
        conf = CompressionConfig(mode=ErrorBoundMode.ABS, eb=1e-3)
        comp = sz3_chunked(chunk_bytes=4096)
        blobs = [comp.compress(_field(seed=s), conf).blob for s in range(4)]
        assert len({blob_key(b) for b in blobs}) == 4
        cache = DecodeStateCache(max_entries=2)
        for b in blobs:
            cache.index_for(b)
        s = cache.stats()
        assert s["entries"] == 2 and s["evictions"] == 2
        # most-recent two are resident, oldest two were evicted
        cache.index_for(blobs[-1])
        assert cache.stats()["hits"] == 1
        cache.index_for(blobs[0])
        assert cache.stats()["misses"] == 5

    def test_chunk_result_cache_budget(self, container):
        _, blob = container
        idx = parse_chunked_index(blob)
        arrs = [decompress_chunk(blob, c, parsed=idx) for c in range(3)]
        budget = arrs[0].nbytes * 2  # room for two chunks, not three
        cache = DecodeStateCache(max_entries=4, max_chunk_bytes=budget)
        for c, a in enumerate(arrs):
            cache.put_chunk(blob, c, a)
        s = cache.stats()
        assert s["chunk_entries"] == 2 and s["chunk_evictions"] == 1
        assert s["chunk_bytes"] <= budget
        # LRU: chunk 0 was evicted, chunk 2 is hot
        assert cache.get_chunk(blob, 0) is None
        hot = cache.get_chunk(blob, 2)
        assert hot is not None and np.array_equal(hot, arrs[2])
        assert not hot.flags.writeable

    def test_invalidate_drops_index_and_chunks(self, container):
        _, blob = container
        cache = DecodeStateCache()
        cache.index_for(blob)
        cache.put_chunk(blob, 0, decompress_chunk(blob, 0))
        cache.invalidate(blob)
        s = cache.stats()
        assert s["entries"] == 0 and s["chunk_entries"] == 0


# ---------------------------------------------------------------------------
# the async service
# ---------------------------------------------------------------------------

class TestOffloadService:
    def test_put_fetch_roundtrip_and_report(self):
        data = _field(seed=5)

        async def run():
            async with OffloadService(workers=2, chunk_bytes=4096) as svc:
                rep = await svc.put("t", "p", data)
                assert rep["n_in"] == data.nbytes and rep["chunks"] > 1
                assert rep["ratio"] == pytest.approx(
                    data.nbytes / rep["n_out"]
                )
                whole = await svc.fetch("t", "p")
                np.testing.assert_allclose(whole, data, atol=1e-3)

        asyncio.run(run())

    def test_concurrent_fetches_byte_identical_to_serial(self, container):
        """Acceptance criterion: 4-worker concurrent fetch == serial."""
        _, blob = container
        n = parse_chunked_index(blob).n_chunks
        serial = [decompress_chunk(blob, c) for c in range(n)]

        async def run():
            async with OffloadService(workers=4, coalesce_ms=1.0) as svc:
                await svc.put_compressed("t", "p", blob)
                outs = await asyncio.gather(
                    *[svc.fetch("t", "p", c) for c in range(n)]
                )
                for a, b in zip(outs, serial):
                    assert a.dtype == b.dtype and np.array_equal(a, b)

        asyncio.run(run())

    def test_coalesced_equals_unbatched(self, container):
        _, blob = container
        n = parse_chunked_index(blob).n_chunks
        order = list(np.random.default_rng(7).integers(0, n, 24))

        async def run():
            telemetry.reset_metrics()
            async with OffloadService(workers=2, coalesce_ms=3.0) as svc:
                await svc.put_compressed("t", "p", blob)
                batched = await asyncio.gather(
                    *[svc.fetch("t", "p", int(c)) for c in order]
                )
            async with OffloadService(workers=2, coalesce_ms=0.0) as svc0:
                await svc0.put_compressed("t", "p", blob)
                unbatched = await asyncio.gather(
                    *[svc0.fetch("t", "p", int(c)) for c in order]
                )
            for a, b in zip(batched, unbatched):
                assert np.array_equal(a, b)
            counters = telemetry.METRICS.snapshot()["counters"]
            # the 3 ms window must actually coalesce: fewer batches than
            # requests on the batching service
            assert counters["sz3_serve_batches_total"] < 2 * len(order)
            assert counters["sz3_serve_batched_requests_total"] >= 2 * len(order)

        asyncio.run(run())

    def test_fault_isolated_to_owning_request(self, container):
        """Acceptance criterion: a fault-injected frame surfaces a typed
        error to exactly the owning request; siblings complete."""
        _, blob = container
        idx = parse_chunked_index(blob)
        bad = _corrupt_chunk(blob, idx, chunk=3)

        async def run():
            async with OffloadService(workers=2, coalesce_ms=2.0) as svc:
                await svc.put_compressed("t", "bad", bad)
                results = await asyncio.gather(
                    *[svc.fetch("t", "bad", c) for c in range(5)],
                    return_exceptions=True,
                )
                for c, r in enumerate(results):
                    if c == 3:
                        assert isinstance(r, OffloadError)
                        assert r.cause_type == "IntegrityError"
                        assert r.chunk == 3 and r.chunk_index == 3
                        assert r.tenant == "t" and r.page == "bad"
                    else:
                        assert isinstance(r, np.ndarray)
                        assert np.array_equal(r, decompress_chunk(blob, c))

        asyncio.run(run())

    def test_service_lru_eviction_under_bound(self):
        conf = CompressionConfig(mode=ErrorBoundMode.ABS, eb=1e-3)
        comp = sz3_chunked(chunk_bytes=4096)
        blobs = [comp.compress(_field(seed=10 + s), conf).blob for s in range(3)]

        async def run():
            async with OffloadService(workers=2, cache_entries=2) as svc:
                for i, b in enumerate(blobs):
                    await svc.put_compressed("t", f"p{i}", b)
                s = svc.cache.stats()
                assert s["entries"] == 2 and s["evictions"] >= 1
                # evicted page still FETCHES fine (cache re-parses on miss)
                out = await svc.fetch("t", "p0", 0)
                assert np.array_equal(out, decompress_chunk(blobs[0], 0))

        asyncio.run(run())

    def test_evict_and_unknown_page(self, container):
        _, blob = container

        async def run():
            async with OffloadService(workers=1) as svc:
                await svc.put_compressed("t", "p", blob)
                assert await svc.evict("t", "p") is True
                assert await svc.evict("t", "p") is False
                with pytest.raises(OffloadError):
                    await svc.fetch("t", "p", 0)

        asyncio.run(run())

    def test_queue_depth_gauge_returns_to_zero(self, container):
        _, blob = container

        async def run():
            telemetry.reset_metrics()
            async with OffloadService(workers=2, coalesce_ms=1.0) as svc:
                await svc.put_compressed("t", "p", blob)
                await asyncio.gather(*[svc.fetch("t", "p", c) for c in range(6)])
            assert telemetry.METRICS.gauge_value("sz3_serve_queue_depth") == 0.0
            hist = telemetry.METRICS.snapshot()["histograms"]
            assert hist["sz3_serve_request_seconds"]["count"] == 6

        asyncio.run(run())

    @pytest.mark.slow
    def test_process_executor_smoke(self, container):
        _, blob = container

        async def run():
            async with OffloadService(
                workers=2, executor="process", coalesce_ms=1.0
            ) as svc:
                await svc.put_compressed("t", "p", blob)
                outs = await asyncio.gather(
                    *[svc.fetch("t", "p", c) for c in range(3)]
                )
                for c, a in enumerate(outs):
                    assert np.array_equal(a, decompress_chunk(blob, c))

        asyncio.run(run())

    def test_service_survives_two_event_loops(self, container):
        _, blob = container
        svc = OffloadService(workers=1, coalesce_ms=0.5)

        async def put():
            await svc.put_compressed("t", "p", blob)

        async def fetch():
            out = await svc.fetch("t", "p", 0)
            assert np.array_equal(out, decompress_chunk(blob, 0))
            await svc.close()

        asyncio.run(put())
        asyncio.run(fetch())


# ---------------------------------------------------------------------------
# offload accounting fixes (launch/serve satellites)
# ---------------------------------------------------------------------------

class TestOffloadAccounting:
    @pytest.fixture(scope="class")
    def jnp(self):
        jnp = pytest.importorskip("jax.numpy")
        return jnp

    def _cache(self, jnp, seed=0):
        rng = np.random.default_rng(seed)
        k = np.cumsum(rng.standard_normal((64, 256)), axis=0)
        return {
            "k_bf16": jnp.asarray(k, jnp.bfloat16),
            "v_f32": jnp.asarray(rng.standard_normal((64, 256)), jnp.float32),
            "pos_i32": jnp.zeros((4,), jnp.int32),  # skipped: not float
            "tiny": jnp.zeros((8, 8), jnp.float32),  # skipped: < 1024 elems
        }

    def test_n_in_counts_source_dtype_bytes(self, jnp):
        from repro.launch.serve import offload_cache

        telemetry.reset_metrics()
        n_in, n_out = offload_cache(
            self._cache(jnp), eb=1e-3, chunk_bytes=1 << 14, verify=False
        )
        # bf16 leaf at 2 B/elem + f32 leaf at 4 B/elem — NOT 4 B for both
        assert n_in == 64 * 256 * 2 + 64 * 256 * 4
        assert n_out > 0
        counters = telemetry.METRICS.snapshot()["counters"]
        assert counters["sz3_offload_leaves_skipped_total"] == 2
        assert counters["sz3_offload_bytes_in_total"] == n_in

    def test_quality_mode_all_skipped_no_inf_psnr(self, jnp, caplog):
        import logging

        from repro.launch.serve import offload_cache

        telemetry.reset_metrics()
        empty = {"pos": jnp.zeros((4,), jnp.int32)}
        with caplog.at_level(logging.INFO, logger="repro.telemetry.serve"):
            n_in, n_out = offload_cache(empty, target_psnr=60.0)
        assert (n_in, n_out) == (0, 0)
        text = " ".join(r.getMessage() for r in caplog.records)
        assert "worst_leaf_psnr_db" not in text
        assert "inf" not in text
        counters = telemetry.METRICS.snapshot()["counters"]
        assert counters["sz3_offload_leaves_skipped_total"] == 1

    def test_async_service_offload_matches_accounting(self, jnp):
        from repro.launch.serve import offload_cache_async

        telemetry.reset_metrics()
        n_in, n_out = offload_cache_async(
            self._cache(jnp), eb=1e-3, chunk_bytes=1 << 14, workers=2
        )
        assert n_in == 64 * 256 * 2 + 64 * 256 * 4
        assert 0 < n_out < n_in
