"""Differential fuzzing: the block-hybrid engine against the per-chunk ones.

Every engine under test round-trips the SAME array under the SAME config;
the properties asserted are the cross-engine contracts a format refactor can
silently break:

  (a) every engine honours its error bound POINTWISE per mode definition
      (ABS / REL / PW_REL — see test_error_modes.py for the definitions);
  (b) on mixed-regime fixtures the hybrid's payload is never more than 5%
      larger than the best single-predictor engine (per-block selection must
      never lose badly to any one of its own candidates);
  (c) worker-count byte-identity holds for containers that route chunks
      through the new engine (parallel output == serial output, bit for bit).

Engines: ``sz3_hybrid`` (v5), ``sz3_chunked`` (v2), ``sz3_auto`` (v2 with
the full candidate set incl. hybrid), ``sz3_pwr`` (v4, PW_REL only) and
``sz3_fast`` (v6, the fixed-length ultra-fast tier — it trades ratio for
speed but must honour exactly the same pointwise bounds).
"""
import numpy as np
import pytest

try:  # the fuzz property needs hypothesis; the deterministic differential
    # sweep below must keep running even where it is not installed
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised in minimal environments
    HAVE_HYPOTHESIS = False

from repro.core import (
    CompressionConfig,
    ErrorBoundMode,
    PIPELINES,
    decompress,
    sz3_auto,
    sz3_chunked,
    sz3_fast,
    sz3_hybrid,
    sz3_pwr,
)

#: single-predictor engines the hybrid must stay within 5% of (property b)
SINGLE_PREDICTOR = ("sz3_lorenzo", "sz3_lr", "sz3_interp")


def _build(regime: str, dims, seed: int, dtype) -> np.ndarray:
    rng = np.random.default_rng(seed)
    n = int(np.prod(dims))
    if regime == "smooth":
        x = rng.standard_normal(dims)
        for ax in range(len(dims)):
            x = np.cumsum(x, axis=ax) / np.sqrt(dims[ax])
    elif regime == "oscillatory":
        t = np.arange(n, dtype=np.float64).reshape(dims)
        x = np.sin(0.91 * np.pi * t) + 0.01 * rng.standard_normal(dims)
    elif regime == "constant":
        x = np.full(dims, float(rng.normal()))
    elif regime == "sparse":
        x = np.zeros(dims)
        mask = rng.random(dims) < 0.05
        x[mask] = rng.standard_normal(int(mask.sum())) * 100.0
    elif regime == "lognormal":
        x = np.exp(rng.normal(0.0, 3.0, dims))
        x[rng.random(dims) < 0.3] *= -1.0
        x[rng.random(dims) < 0.02] = 0.0
    else:  # mixed: smooth first half, oscillatory second half (leading axis)
        x = rng.standard_normal(dims)
        for ax in range(len(dims)):
            x = np.cumsum(x, axis=ax) / np.sqrt(dims[ax])
        half = dims[0] // 2
        t = np.arange(int(np.prod((dims[0] - half,) + tuple(dims[1:]))))
        x[half:] = (
            np.sin(0.91 * np.pi * t).reshape((dims[0] - half,) + tuple(dims[1:]))
        )
    return np.ascontiguousarray(x.astype(dtype))


if HAVE_HYPOTHESIS:

    @st.composite
    def cases(draw, max_elems=3000):
        ndim = draw(st.integers(1, 2))
        dims = tuple(
            draw(
                st.lists(st.integers(2, 64), min_size=ndim, max_size=ndim).filter(
                    lambda d: int(np.prod(d)) <= max_elems
                )
            )
        )
        regime = draw(
            st.sampled_from(
                ["smooth", "oscillatory", "constant", "sparse", "mixed", "lognormal"]
            )
        )
        mode = draw(
            st.sampled_from(
                [ErrorBoundMode.ABS, ErrorBoundMode.REL, ErrorBoundMode.PW_REL]
            )
        )
        eb = draw(st.sampled_from([1e-2, 1e-3, 1e-4]))
        dtype = draw(st.sampled_from([np.float32, np.float64]))
        seed = draw(st.integers(0, 2**31 - 1))
        return _build(regime, dims, seed, dtype), mode, eb, regime


def _assert_bound(mode: ErrorBoundMode, eb: float, x, xhat, label: str):
    x64 = np.asarray(x, np.float64)
    xh64 = np.asarray(xhat, np.float64)
    assert xhat.shape == x.shape and xhat.dtype == x.dtype, label
    fin = np.isfinite(x64)
    slack = 1 + 1e-6
    if mode == ErrorBoundMode.ABS:
        assert np.abs(x64[fin] - xh64[fin]).max(initial=0.0) <= eb * slack, label
    elif mode == ErrorBoundMode.REL:
        rng = x64[fin].max() - x64[fin].min() if fin.any() else 0.0
        tol = eb * rng * slack if rng > 0 else 1e-300
        assert np.abs(x64[fin] - xh64[fin]).max(initial=0.0) <= tol, label
    else:  # PW_REL, pointwise
        nz = fin & (x64 != 0)
        rel = np.abs(x64[nz] - xh64[nz]) / np.abs(x64[nz])
        assert rel.max(initial=0.0) <= eb * slack, label
        zeros = fin & (x64 == 0)
        assert np.all(xh64[zeros] == 0.0), f"{label}: zeros must stay exact"


def _differential_case(x, mode, eb):
    """One differential round: every engine, same array, pointwise bounds."""
    conf = CompressionConfig(mode=mode, eb=eb)
    engines = {
        "sz3_hybrid": sz3_hybrid(),
        "sz3_chunked": sz3_chunked(chunk_bytes=1 << 13),
        "sz3_auto": sz3_auto(chunk_bytes=1 << 13),
        "sz3_fast": sz3_fast(),
    }
    if mode == ErrorBoundMode.PW_REL:
        engines["sz3_pwr"] = sz3_pwr(eb=eb, chunk_bytes=1 << 13)
    else:
        with pytest.raises(ValueError):  # sz3_pwr refuses non-PW_REL configs
            sz3_pwr(eb=eb).compress(x, conf)
    blobs = {}
    for name, eng in engines.items():
        blob = eng.compress(x, conf).blob
        blobs[name] = blob
        _assert_bound(mode, eb, x, decompress(blob), f"{name}/{mode.value}")
    # cross-engine payload sanity: all containers carry the same array, so a
    # zero-length body means an engine fell off its format
    assert min(len(v) for v in blobs.values()) > 0


if HAVE_HYPOTHESIS:

    @settings(max_examples=30, deadline=None)
    @given(case=cases())
    def test_differential_bound_all_engines(case):
        """(a): the same array through every engine, bound per mode."""
        x, mode, eb, _regime = case
        _differential_case(x, mode, eb)


@pytest.mark.parametrize(
    "regime", ["smooth", "oscillatory", "constant", "sparse", "mixed", "lognormal"]
)
@pytest.mark.parametrize(
    "mode", [ErrorBoundMode.ABS, ErrorBoundMode.REL, ErrorBoundMode.PW_REL]
)
def test_differential_bound_fixed_grid(regime, mode):
    """Deterministic slice of the fuzz space — runs even without hypothesis
    so the differential contract is never silently unexercised."""
    for dims, dtype, seed in [((41, 23), np.float32, 11), ((700,), np.float64, 12)]:
        x = _build(regime, dims, seed, dtype)
        _differential_case(x, mode, 1e-3)


def _mixed_fixture_2d(seed=3, shape=(96, 64)):
    """Four-regime quadrant fixture: smooth / quadratic / oscillatory / zero.

    Each quadrant has a clear per-block winner (lorenzo1 / lorenzo2 /
    zero-predictor / any), so per-block selection must beat every
    single-predictor engine and certainly never trail one by >5%.
    """
    rng = np.random.default_rng(seed)
    h, w = shape
    x = np.zeros(shape, np.float64)
    x[: h // 2, : w // 2] = np.cumsum(
        rng.standard_normal((h // 2, w // 2)), axis=0
    )
    i, j = np.meshgrid(
        np.arange(h - h // 2, dtype=np.float64),
        np.arange(w // 2, dtype=np.float64),
        indexing="ij",
    )
    x[h // 2 :, : w // 2] = 0.01 * (i * i + j * j)
    t = np.arange((h // 2) * (w - w // 2), dtype=np.float64)
    x[: h // 2, w // 2 :] = np.sin(0.93 * np.pi * t).reshape(
        h // 2, w - w // 2
    ) + 0.01 * rng.standard_normal((h // 2, w - w // 2))
    return x.astype(np.float32)


def _mixed_fixture_1d(seed=5, n=4096):
    rng = np.random.default_rng(seed)
    x = np.empty(n, np.float64)
    q = n // 4
    x[:q] = np.cumsum(rng.standard_normal(q)) * 0.3
    t = np.arange(q, dtype=np.float64)
    x[q : 2 * q] = 1e-4 * t * t
    x[2 * q : 3 * q] = np.sin(0.93 * np.pi * t) + 0.01 * rng.standard_normal(q)
    x[3 * q :] = 0.0
    return x.astype(np.float32)


@pytest.mark.parametrize(
    "fixture",
    [_mixed_fixture_1d(), _mixed_fixture_2d(), None],
    ids=["mixed1d", "mixed2d", "hybrid_turf"],
)
def test_hybrid_payload_never_trails_best_single_predictor(fixture):
    """(b): per-block selection must not lose >5% to any of its candidates."""
    if fixture is None:
        fixture = _hybrid_turf_1d()
    conf = CompressionConfig(mode=ErrorBoundMode.ABS, eb=1e-3)
    hybrid_len = len(sz3_hybrid().compress(fixture, conf).blob)
    singles = {
        name: len(PIPELINES[name]().compress(fixture, conf).blob)
        for name in SINGLE_PREDICTOR
    }
    best = min(singles.values())
    assert hybrid_len <= 1.05 * best, (hybrid_len, singles)


def _hybrid_turf_1d(n=8192, seed=0):
    """Regime mix the per-block contest wins outright: piecewise-constant
    steps (Lorenzo-exact, DCT rings), sparse spikes on a zero background
    (DCT spreads them across every band), a quadratic ramp (order-2 Lorenzo
    exact) and a broadband chirp (no sparse band for the transform)."""
    rng = np.random.default_rng(seed)
    x = np.empty(n, np.float64)
    q = n // 4
    x[:q] = np.repeat(rng.standard_normal(q // 64), 64)[:q] * 5
    s = np.zeros(q)
    m = rng.random(q) < 0.03
    s[m] = rng.standard_normal(int(m.sum())) * 50
    x[q : 2 * q] = s
    t = np.arange(q, dtype=np.float64)
    x[2 * q : 3 * q] = 1e-4 * t * t
    x[3 * q :] = np.sin(2e-4 * t * t) * 2
    return x.astype(np.float32)


@pytest.mark.parametrize("workers", [2, 3])
def test_worker_byte_identity_with_hybrid_chunks(workers):
    """(c): containers routing chunks through the new engine must be
    byte-identical across worker counts (selection is a pure function of the
    chunk; assembly is submission-ordered)."""
    x = np.concatenate([_hybrid_turf_1d(seed=s) for s in range(4)])
    conf = CompressionConfig(mode=ErrorBoundMode.ABS, eb=1e-3)
    serial = sz3_auto(chunk_bytes=8192 * 4, workers=1).compress(
        x, conf, with_stats=True
    )
    parallel = sz3_auto(chunk_bytes=8192 * 4, workers=workers).compress(x, conf)
    assert serial.blob == parallel.blob
    # the fixture's chunks are mixed-regime, so the contest must actually
    # route at least one chunk through the new engine for (c) to mean much
    picked = [c["pipeline"] for c in serial.meta["chunks"]]
    assert "sz3_hybrid" in picked, picked


@pytest.mark.parametrize("workers", [2, 3])
def test_fast_only_chunked_worker_identity(workers):
    """(c) for the fast tier: a chunked container restricted to ``sz3_fast``
    must be byte-identical across worker counts, every chunk must carry a v6
    body, and the fixed-length payload must stay sane — smaller than raw on a
    smooth fixture, never more than marginally larger than the entropy-coded
    chunked engine would allow on the same data times a generous factor."""
    from repro.core import parse_header

    x = np.concatenate([_mixed_fixture_1d(seed=s, n=8192) for s in range(3)])
    conf = CompressionConfig(mode=ErrorBoundMode.ABS, eb=1e-3)
    eng1 = sz3_chunked(candidates=("sz3_fast",), chunk_bytes=8192 * 4, workers=1)
    engN = sz3_chunked(
        candidates=("sz3_fast",), chunk_bytes=8192 * 4, workers=workers
    )
    b1 = eng1.compress(x, conf).blob
    assert b1 == engN.compress(x, conf).blob
    header, _ = parse_header(b1)
    assert all(c["pipeline"] == "sz3_fast" for c in header["chunks"])
    _assert_bound(ErrorBoundMode.ABS, 1e-3, x, decompress(b1), "fast_chunked")
    # payload sanity: fixed-length coding beats raw on smooth data, and the
    # ratio sacrificed vs the entropy-coded engine stays bounded (format
    # still block-structured, not degenerate)
    assert len(b1) < x.nbytes
    chunked_len = len(sz3_chunked(chunk_bytes=8192 * 4).compress(x, conf).blob)
    assert len(b1) <= 4.0 * chunked_len, (len(b1), chunked_len)


def test_hybrid_only_chunked_worker_identity():
    """A chunked container restricted to the new engine: byte-identity and
    per-chunk hybrid blobs that decode through the v5 path."""
    from repro.core import parse_header

    x = np.concatenate([_mixed_fixture_1d(seed=s, n=8192) for s in range(3)])
    conf = CompressionConfig(mode=ErrorBoundMode.REL, eb=1e-4)
    eng1 = sz3_chunked(candidates=("sz3_hybrid",), chunk_bytes=8192 * 4, workers=1)
    eng3 = sz3_chunked(candidates=("sz3_hybrid",), chunk_bytes=8192 * 4, workers=3)
    b1 = eng1.compress(x, conf).blob
    assert b1 == eng3.compress(x, conf).blob
    header, _ = parse_header(b1)
    assert all(c["pipeline"] == "sz3_hybrid" for c in header["chunks"])
    xhat = decompress(b1)
    bound = 1e-4 * float(x.max() - x.min())
    assert np.abs(xhat.astype(np.float64) - x).max() <= bound * (1 + 1e-9)
