"""Property tests for the pure-jax codec facade (core/jitmode).

Two contracts, checked over arbitrary float32 inputs:

  * the decoded error obeys ``BlockCodes.bound()`` (resp. ``GridCodes``)
    both eagerly and under ``jax.jit``;
  * the jit path is BIT-identical to the host (numpy) mirror — codes,
    side channels, and decoded values — so a gradient encoded on device
    and decoded on a host (or vice versa, as elastic restore does) never
    disagrees.

Hypothesis drives the sweep when installed (CI test extras have it); a
deterministic adversarial corpus — subnormals, huge offsets, constants,
ragged tails, sign flips — covers the same properties where it is not.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import jitmode

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    from hypothesis.extra import numpy as hnp

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised locally, not in CI
    HAVE_HYPOTHESIS = False


POLICIES = [
    "int8:bs=256",
    "int4:bs=64",
    "int8:mode=abs:eb=1e-3:bs=128",
    "grid:eb=1e-3:bs=256",
    "grid:eb=1e-4:mode=abs:bs=128",
]

#: deterministic fallback corpus: the shapes of data that have actually
#: broken quantizers in this repo's history
_CORPUS = [
    np.zeros(300, np.float32),
    np.full(511, 7.25, np.float32),
    np.linspace(-1e4, 1e4, 1000).astype(np.float32),
    (np.logspace(-40, 30, 777, dtype=np.float64)).astype(np.float32),
    np.array([1e-39, -1e-39, 5e-38, 0.0, 1.0], np.float32),  # subnormals
    np.cumsum(np.ones(2048, np.float32)) + 1e6,  # huge offset, lorenzo regime
    np.where(np.arange(513) % 2 == 0, 1.0, -1.0).astype(np.float32),
    np.repeat(np.float32(3.0), 64) * np.float32(2.0) ** -120,
]


def _fit_policy(x: np.ndarray, spec: str) -> jitmode.JitPolicy:
    """The grid tier's ABS bound is only meaningful inside its documented
    domain (``|x - base|/(2*eb) < 2**23``: int32 codes on a fixed grid),
    so for grid policies the property scales ``eb`` to the data range —
    exactly how a caller picks an ABS bound for known data."""
    import dataclasses

    pol = jitmode.JitPolicy.parse(spec)
    if pol.tier == "grid" and x.size:
        rng = float(np.max(np.abs(x)))
        if rng > 0:
            pol = dataclasses.replace(pol, eb=max(pol.eb, rng * 2.0**-20))
    return pol


def _check_bound(x: np.ndarray, spec: str):
    pol = _fit_policy(x, spec)
    c = jitmode.encode(jnp.asarray(x), pol)
    back = np.asarray(jitmode.decode(c))
    bound = np.asarray(c.bound())
    nb = bound.shape[0]
    err = np.pad(np.abs(back - x), (0, nb * pol.bs - x.size)).reshape(nb, pol.bs)
    assert (err.max(axis=1) <= bound).all(), (spec, err.max(), bound.max())


def _check_jit_vs_eager_vs_host(x: np.ndarray, spec: str):
    pol = _fit_policy(x, spec)
    c_e = jitmode.encode(jnp.asarray(x), pol)
    c_j = jax.jit(jitmode.encode, static_argnums=1)(jnp.asarray(x), pol)
    fields = ("codes", "scale", "tags", "base") if pol.tier != "grid" else (
        "codes", "tags", "base")
    for f in fields:
        a, b = np.asarray(getattr(c_e, f)), np.asarray(getattr(c_j, f))
        np.testing.assert_array_equal(a, b, err_msg=f"{spec}:{f} jit!=eager")
    d_e = np.asarray(jitmode.decode(c_e))
    d_j = np.asarray(jax.jit(jitmode.decode)(c_j))
    if pol.tier == "grid":
        # the 2*eb grid is an arbitrary float, so decode's base + grid*q may
        # contract into an fma under jit, shifting the result by up to one
        # ulp of the PRODUCT grid*q: the grid tier pins bit identity for
        # ENCODE (the wire format) and product-ulp closeness for decode —
        # the same representation-slack term GridCodes.bound() budgets for
        q = np.asarray(c_e.codes, np.int64)
        lor = np.cumsum(q, axis=-1)
        sel = np.where(
            (np.asarray(c_e.tags) == jitmode.PREDICTOR_TAGS["lorenzo1"])[
                :, None],
            lor, q)
        grid = np.float32(2.0 * pol.eb)
        slack = (np.abs(np.asarray(c_e.base))[:, None]
                 + grid * np.abs(sel)) * np.float32(2.0**-22)
        diff = np.abs(d_e - d_j)
        diff = np.pad(diff, (0, slack.size - diff.size)).reshape(slack.shape)
        assert (diff <= slack).all(), (spec, diff.max(), slack.max())
        return
    np.testing.assert_array_equal(d_e, d_j, err_msg=f"{spec} decode jit!=eager")
    # host (numpy) mirror covers the fixed tier end to end
    c_h = jitmode.encode_host(x, pol)
    for f in fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(c_e, f)), np.asarray(getattr(c_h, f)),
            err_msg=f"{spec}:{f} jax!=host")
    np.testing.assert_array_equal(
        d_e, jitmode.decode_host(c_h), err_msg=f"{spec} decode jax!=host")


@pytest.mark.parametrize("spec", POLICIES)
def test_bound_holds_corpus(spec):
    for x in _CORPUS:
        _check_bound(x, spec)


@pytest.mark.parametrize("spec", POLICIES)
def test_jit_bit_identical_corpus(spec):
    for x in _CORPUS:
        _check_jit_vs_eager_vs_host(x, spec)


def test_bound_holds_inside_jit():
    """The bound contract survives jit end to end: encode, decode, and the
    bound computation itself all traced into one program."""
    pol = jitmode.JitPolicy.parse("int8:bs=128")

    @jax.jit
    def roundtrip_err(x):
        c = jitmode.encode(x, pol)
        back = jitmode.decode(c)
        nb = c.bound().shape[0]
        err = jnp.abs(back - x)
        err = jnp.pad(err, (0, nb * pol.bs - x.shape[0]))
        return err.reshape(nb, pol.bs).max(axis=1) - c.bound()

    rng = np.random.default_rng(11)
    for x in [rng.standard_normal(5000).astype(np.float32) * 100, _CORPUS[5]]:
        slack = np.asarray(roundtrip_err(jnp.asarray(x)))
        assert (slack <= 0).all(), slack.max()


if HAVE_HYPOTHESIS:

    _arrays = hnp.arrays(
        np.float32,
        st.integers(1, 3000),
        elements=st.floats(
            -1e30, 1e30, width=32, allow_nan=False, allow_infinity=False
        ),
    )

    @settings(max_examples=40, deadline=None)
    @given(x=_arrays, spec=st.sampled_from(POLICIES))
    def test_bound_holds_property(x, spec):
        _check_bound(x, spec)

    @settings(max_examples=25, deadline=None)
    @given(x=_arrays, spec=st.sampled_from(POLICIES))
    def test_jit_bit_identical_property(x, spec):
        _check_jit_vs_eager_vs_host(x, spec)
