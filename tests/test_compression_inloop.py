"""In-loop compression integrations: grad quant + error feedback, opt-state
8-bit moments, KV-cache quantization quality."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compression import kvcache, opt_state
from repro.compression.grad import (
    BLOCK,
    dequantize_shard,
    quantize_shard,
)


@pytest.mark.parametrize("bits", [8, 4])
def test_grad_quant_roundtrip_bounded(bits):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(5000).astype(np.float32) * 10)
    codes, scale = quantize_shard(x, bits)
    back = dequantize_shard(codes, scale, x.shape[0], bits)
    radius = 127 if bits == 8 else 7
    # per-block bound: scale/2
    xp = np.pad(np.asarray(x), (0, (-x.shape[0]) % BLOCK)).reshape(-1, BLOCK)
    bound = np.repeat(np.asarray(scale) * 0.5001, BLOCK)[: x.shape[0]]
    assert np.all(np.abs(np.asarray(back) - np.asarray(x)) <= bound)


@pytest.mark.parametrize("bits", [8, 4])
def test_error_feedback_unbiased(bits):
    """with feedback, the time-average of dequantized grads converges to the
    true gradient (the SZ bound applied temporally)."""
    rng = np.random.default_rng(1)
    g_true = jnp.asarray(rng.standard_normal(2048).astype(np.float32))
    fb = jnp.zeros_like(g_true)
    acc = np.zeros_like(np.asarray(g_true))
    steps = 50
    for _ in range(steps):
        v = g_true + fb
        codes, scale = quantize_shard(v, bits)
        d = dequantize_shard(codes, scale, v.shape[0], bits)
        fb = v - d
        acc += np.asarray(d)
    err = np.abs(acc / steps - np.asarray(g_true)).max()
    assert err < (0.01 if bits == 8 else 0.05), err


def test_opt_state_compress_roundtrip():
    rng = np.random.default_rng(2)
    for shape in [(100,), (64, 300), (4, 8, 1000), ()]:
        x = jnp.asarray(rng.standard_normal(shape).astype(np.float32))
        c = opt_state.compress(x)
        back = opt_state.decompress(c)
        xa = np.asarray(x).reshape(back.shape)
        scale_rep = np.asarray(c.scale)
        assert np.abs(np.asarray(back) - xa).max() <= float(scale_rep.max()) * 0.5001
    assert opt_state.compression_ratio(np.zeros((512, 512))) > 3.5


def test_adamw_with_compressed_moments_converges():
    from repro.optim import AdamWConfig, init_state, update

    dim = 64
    rng = np.random.default_rng(3)
    target = jnp.asarray(rng.standard_normal(dim).astype(np.float32))
    params = {"w": jnp.zeros(dim)}
    cfg = AdamWConfig(lr=5e-2, weight_decay=0.0, compress_moments=True)
    st = init_state(params, cfg)
    for _ in range(200):
        g = {"w": params["w"] - target}
        params, st, _ = update(params, g, st, cfg)
    assert float(jnp.abs(params["w"] - target).max()) < 0.05


def test_kv_quant_bound_and_snr():
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((256, 4, 64)).astype(np.float32) * 5)
    q, s = kvcache.quantize_tokens(x)
    back = kvcache.dequantize_tokens(q, s)
    assert np.all(
        np.abs(np.asarray(back) - np.asarray(x)) <= np.asarray(s)[..., None] * 0.5001
    )
    assert kvcache.quantization_snr_db(x) > 40.0


def test_kv_cache_bytes_model():
    bf16 = kvcache.cache_bytes(32768, 8, 128, "bf16")
    int8 = kvcache.cache_bytes(32768, 8, 128, "int8")
    assert int8 < bf16 * 0.55  # ~1.94x saving


def test_int4_packing_exact():
    from repro.compression.grad import quantize_shard, dequantize_shard

    x = jnp.asarray(np.linspace(-1, 1, BLOCK, dtype=np.float32))
    codes, scale = quantize_shard(x, 4)
    assert codes.size == BLOCK // 2  # two nibbles per byte
    back = dequantize_shard(codes, scale, BLOCK, 4)
    assert np.abs(np.asarray(back) - np.asarray(x)).max() <= float(scale[0]) * 0.5001
