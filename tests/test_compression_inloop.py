"""In-loop compression integrations: grad quant + error feedback, opt-state
8-bit moments, KV-cache quantization quality."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compression import kvcache, opt_state
from repro.compression.grad import (
    BLOCK,
    dequantize_shard,
    quantize_shard,
)


@pytest.mark.parametrize("bits", [8, 4])
def test_grad_quant_roundtrip_bounded(bits):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(5000).astype(np.float32) * 10)
    codes, scale = quantize_shard(x, bits)
    back = dequantize_shard(codes, scale, x.shape[0], bits)
    radius = 127 if bits == 8 else 7
    # per-block bound: scale/2
    xp = np.pad(np.asarray(x), (0, (-x.shape[0]) % BLOCK)).reshape(-1, BLOCK)
    bound = np.repeat(np.asarray(scale) * 0.5001, BLOCK)[: x.shape[0]]
    assert np.all(np.abs(np.asarray(back) - np.asarray(x)) <= bound)


@pytest.mark.parametrize("bits", [8, 4])
def test_error_feedback_unbiased(bits):
    """with feedback, the time-average of dequantized grads converges to the
    true gradient (the SZ bound applied temporally)."""
    rng = np.random.default_rng(1)
    g_true = jnp.asarray(rng.standard_normal(2048).astype(np.float32))
    fb = jnp.zeros_like(g_true)
    acc = np.zeros_like(np.asarray(g_true))
    steps = 50
    for _ in range(steps):
        v = g_true + fb
        codes, scale = quantize_shard(v, bits)
        d = dequantize_shard(codes, scale, v.shape[0], bits)
        fb = v - d
        acc += np.asarray(d)
    err = np.abs(acc / steps - np.asarray(g_true)).max()
    assert err < (0.01 if bits == 8 else 0.05), err


def test_opt_state_compress_roundtrip():
    rng = np.random.default_rng(2)
    for shape in [(100,), (64, 300), (4, 8, 1000), ()]:
        x = jnp.asarray(rng.standard_normal(shape).astype(np.float32))
        c = opt_state.compress(x)
        back = opt_state.decompress(c)
        xa = np.asarray(x).reshape(back.shape)
        scale_rep = np.asarray(c.scale)
        assert np.abs(np.asarray(back) - xa).max() <= float(scale_rep.max()) * 0.5001
    assert opt_state.compression_ratio(np.zeros((512, 512))) > 3.5


def test_adamw_with_compressed_moments_converges():
    from repro.optim import AdamWConfig, init_state, update

    dim = 64
    rng = np.random.default_rng(3)
    target = jnp.asarray(rng.standard_normal(dim).astype(np.float32))
    params = {"w": jnp.zeros(dim)}
    cfg = AdamWConfig(lr=5e-2, weight_decay=0.0, compress_moments=True)
    st = init_state(params, cfg)
    for _ in range(200):
        g = {"w": params["w"] - target}
        params, st, _ = update(params, g, st, cfg)
    assert float(jnp.abs(params["w"] - target).max()) < 0.05


def test_kv_quant_bound_and_snr():
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((256, 4, 64)).astype(np.float32) * 5)
    q, s = kvcache.quantize_tokens(x)
    back = kvcache.dequantize_tokens(q, s)
    assert np.all(
        np.abs(np.asarray(back) - np.asarray(x)) <= np.asarray(s)[..., None] * 0.5001
    )
    assert kvcache.quantization_snr_db(x) > 40.0


def test_kv_cache_bytes_model():
    bf16 = kvcache.cache_bytes(32768, 8, 128, "bf16")
    int8 = kvcache.cache_bytes(32768, 8, 128, "int8")
    assert int8 < bf16 * 0.55  # ~1.94x saving


def test_int4_packing_exact():
    from repro.compression.grad import quantize_shard, dequantize_shard

    x = jnp.asarray(np.linspace(-1, 1, BLOCK, dtype=np.float32))
    codes, scale = quantize_shard(x, 4)
    assert codes.size == BLOCK // 2  # two nibbles per byte
    back = dequantize_shard(codes, scale, BLOCK, 4)
    assert np.abs(np.asarray(back) - np.asarray(x)).max() <= float(scale[0]) * 0.5001


# ---------------------------------------------------------------------------
# bound contracts of the jit codec facade across the in-loop consumers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits", [8, 4])
def test_int4_and_int8_edge_cases(bits):
    """Ragged lengths, constant blocks, all-zero data, mixed magnitudes:
    the per-block bound holds and the wire sizes follow the packing rule."""
    from repro.core import jitmode

    pol = jitmode.JitPolicy(tier=f"int{bits}", bs=64)
    cases = [
        np.zeros(64, np.float32),
        np.full(200, -3.25, np.float32),  # ragged + constant
        np.where(np.arange(130) % 2 == 0, 1e4, -1e-4).astype(np.float32),
        np.concatenate([np.zeros(64), np.ones(64) * 7]).astype(np.float32),
    ]
    for x in cases:
        c = jitmode.encode(jnp.asarray(x), pol)
        back = np.asarray(jitmode.decode(c))
        bound = np.asarray(c.bound())
        nb = bound.shape[0]
        per = np.pad(np.abs(back - x), (0, nb * 64 - x.size)).reshape(nb, 64)
        assert (per.max(axis=1) <= bound).all(), (x[:4], per.max(), bound)
        expect_cols = 32 if bits == 4 else 64
        assert np.asarray(c.codes).shape == (nb, expect_cols)


def test_kv_prefill_jit_tier_bound():
    """Bulk prompt-KV through the predictor contest: per-token bound holds,
    and structured (near-constant) head vectors win a tighter scale than the
    plain absmax quantizer gives them."""
    rng = np.random.default_rng(5)
    hd = 64
    flat = rng.standard_normal((128, 4, hd)).astype(np.float32)
    offset = flat * 0.01 + 3.0  # near-constant heads: mean predictor regime
    for x in (flat, offset):
        c = kvcache.quantize_prefill(jnp.asarray(x))
        back = np.asarray(kvcache.dequantize_prefill(c))
        bound = np.asarray(c.bound())  # (..., nb)
        err = np.abs(back - x).reshape(x.shape[:-1] + (1, hd))
        assert (err.max(axis=-1) <= bound).all()
    c_off = kvcache.quantize_prefill(jnp.asarray(offset))
    q, s = kvcache.quantize_tokens(jnp.asarray(offset))
    # midrange-based scales beat absmax-based scales on offset data
    assert float(np.asarray(c_off.scale).mean()) < 0.5 * float(np.asarray(s).mean())


def test_opt_state_nonneg_pointwise_relative_bound():
    """The log2-domain second-moment path: multiplicative bound
    v_hat/v in [2**-d, 2**d] with d the per-block bound on log2 v, and
    exact zeros survive the roundtrip as zeros."""
    rng = np.random.default_rng(6)
    v = (rng.standard_normal(4096).astype(np.float32) ** 2) * np.logspace(
        -12, 2, 4096, dtype=np.float32
    )
    v[::97] = 0.0
    c = opt_state.compress_nonneg(jnp.asarray(v))
    assert c.domain == "log2"
    back = np.asarray(opt_state.decompress(c))
    assert (back >= 0).all()
    assert (back[v == 0.0] == 0.0).all()
    nz = (v > 0) & (back > 0)
    # per-element log error against the worst per-block bound
    d = float(np.asarray(c.scale).max()) * 0.5 + 1e-3
    ratio = np.abs(np.log2(back[nz] / v[nz]))
    assert ratio.max() <= d, (ratio.max(), d)
    # a tiny element in a block of large ones keeps its magnitude (the
    # failure mode of the linear block-REL bound)
    mixed = np.asarray([1.0] * 255 + [1e-9], np.float32)
    mb = np.asarray(opt_state.decompress(opt_state.compress_nonneg(jnp.asarray(mixed))))
    assert 0 < mb[-1] < 1e-7


def test_compressed_reduce_tree_preserves_dtypes():
    """Single-device mesh exercise of the full reduce schedule in-process:
    leaf dtypes (incl. bf16) survive, values stay within the codec bound of
    grads/dp + feedback."""
    from jax.sharding import PartitionSpec as P

    from repro.compression import grad as gradc
    from repro.parallel import compat

    mesh = compat.make_mesh((1,), ("data",))
    rng = np.random.default_rng(7)
    grads = {
        "a": jnp.asarray(rng.standard_normal((33, 5)).astype(np.float32)),
        "b": jnp.asarray(rng.standard_normal(700).astype(np.float32)).astype(
            jnp.bfloat16
        ),
    }
    n = sum(int(np.size(l)) for l in jax.tree.leaves(grads))
    fb = gradc.init_feedback(grads, 1)

    def body(g, f):
        return gradc.compressed_reduce_tree(g, f, ("data",), "int8:bs=128")

    out, new_fb = compat.shard_map(
        body,
        mesh,
        axis_names={"data"},
        in_specs=(jax.tree.map(lambda _: P(), grads), P("data")),
        out_specs=(jax.tree.map(lambda _: P(), grads), P("data")),
        check_vma=False,
    )(grads, fb)
    assert out["a"].dtype == jnp.float32 and out["b"].dtype == jnp.bfloat16
    # dp=1: reduction is identity, so out ~= grads within the codec bound
    # (bf16 cast noise for the bf16 leaf) and feedback carries the residual
    ref = np.asarray(grads["a"]).reshape(-1)
    got = np.asarray(out["a"], np.float32).reshape(-1)
    assert np.abs(ref - got).max() < 0.05
    assert float(jnp.abs(new_fb).max()) > 0.0  # residual is being carried


def test_collective_bytes_model():
    from repro.compression.grad import collective_bytes

    acc = collective_bytes(1 << 20, dp=8, policy=8)
    assert acc["cut_vs_bf16_allreduce"] >= 1.3
    acc4 = collective_bytes(1 << 20, dp=8, policy=4)
    assert acc4["cut_vs_bf16_allreduce"] > acc["cut_vs_bf16_allreduce"]
