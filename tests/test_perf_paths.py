"""PR2 throughput-path invariants (no hypothesis dependency):

  * word-packed v2 Huffman streams round-trip; v1 streams (minted by the
    retained legacy implementation) still decode; the word packer is
    byte-identical to the legacy bit-matrix packer at stream level;
  * chunked containers/frame streams are byte-identical across worker counts;
  * the device-fused Lorenzo path honours the error bound and its containers
    decode through the ordinary self-describing entry point;
  * parse_header rejects truncated/corrupt blobs with ValueError;
  * RawEncoder round-trips uint8 codes.
"""
import numpy as np
import pytest

from repro.core import (
    ChunkedCompressor,
    CompressionConfig,
    ErrorBoundMode,
    SZ3Compressor,
    compress_stream,
    decompress,
    decompress_stream,
    encoders,
    parse_header,
)
from repro.core.chunking import frames_to_blob
from repro.core.predictors import LorenzoPredictor


def _codes(rng, n, spread=3.0, outlier=0.01):
    c = (32768 + np.rint(rng.standard_normal(n) * spread)).astype(np.uint16)
    if n > 10:
        c[rng.random(n) < outlier] = 0
    return c


# ---------------------------------------------------------------------------
# encoder v2 / v1 compatibility
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [0, 1, 1023, 1024, 1025, 4097, 60000])
def test_huffman_v2_roundtrip(n):
    rng = np.random.default_rng(n)
    codes = _codes(rng, n)
    enc = encoders.HuffmanEncoder()
    assert np.array_equal(enc.decode(enc.encode(codes), n), codes.astype(np.int64))


@pytest.mark.parametrize("n", [1, 1023, 1024, 4097, 60000])
def test_huffman_v1_streams_still_decode(n):
    """Blobs minted by the pre-PR2 implementation decode via the new one."""
    rng = np.random.default_rng(n)
    codes = _codes(rng, n)
    legacy = encoders.LegacyHuffmanEncoder()
    new = encoders.HuffmanEncoder()
    assert np.array_equal(new.decode(legacy.encode(codes), n), codes.astype(np.int64))
    # and the other direction: old decoder reads v2 blobs (same table walk)
    assert np.array_equal(legacy.decode(new.encode(codes), n), codes.astype(np.int64))


def test_word_packer_matches_legacy_bitstream():
    """v1-layout output of the word packer is byte-identical to the old
    bit-matrix + packbits implementation (same payload, same head)."""
    rng = np.random.default_rng(0)
    codes = _codes(rng, 50000)
    vals, freqs, inv = encoders._alphabet_of(codes)
    lens, _ = encoders._huffman_code_lengths(freqs)
    table = encoders._cached_table(lens)
    assert encoders._encode_stream(inv, table, version=1) == encoders._encode_stream_legacy(inv, table)


def test_huffman_wide_alphabet_and_negative_values():
    rng = np.random.default_rng(1)
    enc = encoders.HuffmanEncoder()
    wide = rng.integers(0, 60000, 200000).astype(np.uint16)  # 16-bit length cap
    assert np.array_equal(enc.decode(enc.encode(wide), wide.size), wide.astype(np.int64))
    signed = rng.integers(-500, 500, 10000)  # exercises the unique fallback
    assert np.array_equal(enc.decode(enc.encode(signed), signed.size), signed)


def test_fixed_huffman_v1_stream_decodes():
    rng = np.random.default_rng(2)
    codes = (32768 + np.rint(rng.standard_normal(4000) * 50)).astype(np.int64)
    codes[:4] = [0, 1, 99999, 32768]
    v1 = encoders.FixedHuffmanEncoder(stream_version=1)
    v2 = encoders.FixedHuffmanEncoder()
    assert np.array_equal(v2.decode(v1.encode(codes), codes.size), codes)
    assert np.array_equal(v2.decode(v2.encode(codes), codes.size), codes)


def test_raw_encoder_uint8_roundtrip():
    rng = np.random.default_rng(3)
    codes = rng.integers(0, 256, 4096).astype(np.uint8)
    enc = encoders.RawEncoder()
    out = enc.decode(enc.encode(codes), codes.size)
    assert out.dtype == np.uint8
    assert np.array_equal(out, codes)


# ---------------------------------------------------------------------------
# parallel chunk workers
# ---------------------------------------------------------------------------

def test_chunked_workers_byte_identical():
    rng = np.random.default_rng(4)
    x = np.cumsum(rng.standard_normal((64, 96, 16)).astype(np.float32), axis=0)
    conf = CompressionConfig(mode=ErrorBoundMode.REL, eb=1e-3)
    blob1 = ChunkedCompressor(chunk_bytes=1 << 16, workers=1).compress(x, conf).blob
    blob4 = ChunkedCompressor(chunk_bytes=1 << 16, workers=4).compress(x, conf).blob
    assert blob1 == blob4
    xhat = decompress(blob4)
    rng_ = float(x.max() - x.min())
    assert np.abs(xhat.astype(np.float64) - x).max() <= rng_ * 1e-3 * (1 + 1e-6)


def test_stream_workers_byte_identical_and_parallel_decode():
    rng = np.random.default_rng(5)
    x = np.cumsum(rng.standard_normal((48, 64, 16)).astype(np.float32), axis=1)
    conf = CompressionConfig(mode=ErrorBoundMode.ABS, eb=1e-2)
    f1 = list(compress_stream(x, conf, chunk_bytes=1 << 16, workers=1))
    f4 = list(compress_stream(x, conf, chunk_bytes=1 << 16, workers=4))
    assert f1 == f4
    assert frames_to_blob(f4) == ChunkedCompressor(chunk_bytes=1 << 16).compress(x, conf).blob
    parts_serial = list(decompress_stream(f4, workers=1))
    parts_parallel = list(decompress_stream(f4, workers=4))
    assert len(parts_serial) == len(parts_parallel)
    for a, b in zip(parts_serial, parts_parallel):
        assert np.array_equal(a, b)


def test_decompress_chunked_workers_match():
    import repro.core.chunking as chunking

    rng = np.random.default_rng(6)
    x = np.cumsum(rng.standard_normal((32, 2048)).astype(np.float32), axis=0)
    conf = CompressionConfig(mode=ErrorBoundMode.ABS, eb=1e-3)
    blob = ChunkedCompressor(chunk_bytes=1 << 15).compress(x, conf).blob
    serial = decompress(blob)
    header, off = parse_header(blob)
    parallel = chunking.decompress_chunked(blob, header, off, workers=4)
    assert np.array_equal(serial, parallel)


# ---------------------------------------------------------------------------
# device-fused Lorenzo fast path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(8192,), (64, 256)])
@pytest.mark.parametrize("eb", [1e-2, 1e-4])
def test_device_lorenzo_bound_and_self_describing(shape, eb):
    rng = np.random.default_rng(7)
    x = np.cumsum(rng.standard_normal(shape).astype(np.float32), axis=-1).astype(np.float32)
    comp = SZ3Compressor(predictor=LorenzoPredictor(device="force"))
    res = comp.compress(x, CompressionConfig(mode=ErrorBoundMode.ABS, eb=eb))
    header, _ = parse_header(res.blob)
    assert header["pred_meta"].get("device") == 1, "kernel path not engaged"
    # standard entry point (fresh pipeline, numpy decode route on CPU)
    xhat = decompress(res.blob)
    assert np.abs(xhat.astype(np.float64) - x.astype(np.float64)).max() <= eb * (1 + 1e-12)


def test_device_lorenzo_out_of_range_codes():
    """Spikes that overflow the quantizer radius ride the unpredictable-int
    channel exactly as on the numpy route."""
    x = np.zeros(16384, np.float32)
    x[5000], x[9000], x[12000] = 100.0, -200.0, 3000.0
    comp = SZ3Compressor(predictor=LorenzoPredictor(device="force"))
    res = comp.compress(x, CompressionConfig(mode=ErrorBoundMode.ABS, eb=1e-3))
    header, _ = parse_header(res.blob)
    assert header["pred_meta"].get("device") == 1
    xhat = decompress(res.blob)
    assert np.abs(xhat.astype(np.float64) - x).max() <= 1e-3 * (1 + 1e-12)


def test_device_guard_falls_back_to_numpy():
    """Outside the PIPELINE_SAFE guard the numpy route must be taken."""
    x = (np.arange(8192, dtype=np.float32) * 1e3).reshape(64, 128)
    comp = SZ3Compressor(predictor=LorenzoPredictor(device="force"))
    res = comp.compress(x, CompressionConfig(mode=ErrorBoundMode.ABS, eb=1e-7))
    header, _ = parse_header(res.blob)
    assert "device" not in header["pred_meta"]
    xhat = decompress(res.blob)
    assert np.abs(xhat.astype(np.float64) - x.astype(np.float64)).max() <= 1e-7 * (1 + 1e-12)


# ---------------------------------------------------------------------------
# container hardening
# ---------------------------------------------------------------------------

def test_parse_header_rejects_truncated_and_corrupt():
    x = np.linspace(0, 1, 4096, dtype=np.float32)
    from repro.core import sz3_lorenzo

    blob = sz3_lorenzo().compress(x, CompressionConfig(eb=1e-3)).blob
    with pytest.raises(ValueError):
        parse_header(b"")
    with pytest.raises(ValueError):
        parse_header(blob[:10])  # shorter than the fixed prologue
    with pytest.raises(ValueError):
        parse_header(b"XXXX" + blob[4:])  # bad magic
    with pytest.raises(ValueError):
        parse_header(blob[:40])  # header length points past the buffer
    # corrupt length fields must not raise numpy index errors
    bad = bytearray(blob)
    bad[4:12] = (1 << 40).to_bytes(8, "little")
    with pytest.raises(ValueError):
        parse_header(bytes(bad))


def test_decompress_rejects_truncated_blob():
    x = np.linspace(0, 1, 4096, dtype=np.float32)
    from repro.core import sz3_lorenzo

    blob = sz3_lorenzo().compress(x, CompressionConfig(eb=1e-3)).blob
    with pytest.raises(ValueError):
        decompress(blob[: len(blob) // 2])
