"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.compat import HAS_PALLAS_TPU

# missing CompilerParams is NOT a skip: the compat shim passes None and the
# interpret-mode path these tests use accepts that
if not HAS_PALLAS_TPU:
    pytest.skip(
        "jax.experimental.pallas.tpu is not importable in this JAX build",
        allow_module_level=True,
    )

from repro.kernels.bitplane import (
    bitplane_decode,
    bitplane_encode,
    ref_encode as bp_ref_encode,
)
from repro.kernels.kvquant import (
    kv_dequant_matmul,
    kv_quantize,
    ref_dequant_matmul,
    ref_quantize,
)
from repro.kernels.lorenzo import (
    lorenzo_decode,
    lorenzo_encode,
    ref_decode,
    ref_encode,
)
from repro.kernels.transform import (
    ref_fwd as tf_ref_fwd,
    ref_inv as tf_ref_inv,
    transform_fwd,
    transform_inv,
)


@pytest.mark.parametrize(
    "shape", [(100, 300), (256, 512), (7, 50), (1, 1000), (513, 129), (8, 128)]
)
@pytest.mark.parametrize("mode", ["1d", "2d"])
@pytest.mark.parametrize("eb", [1e-1, 1e-3])
def test_lorenzo_kernel_equals_ref(shape, mode, eb):
    rng = np.random.default_rng(abs(hash((shape, mode))) % 1000)
    x = np.cumsum(rng.normal(size=shape).astype(np.float32), axis=1)
    c_k, d_k = lorenzo_encode(jnp.asarray(x), eb=eb, mode=mode)
    c_r, d_r = ref_encode(x, eb, mode=mode)
    np.testing.assert_array_equal(np.asarray(c_k), np.asarray(c_r))
    np.testing.assert_array_equal(np.asarray(d_k), np.asarray(d_r))
    xh_k = lorenzo_decode(d_k, eb=eb, mode=mode)
    xh_r = ref_decode(d_r, eb, mode=mode)
    np.testing.assert_array_equal(np.asarray(xh_k), np.asarray(xh_r))
    tol = eb + np.abs(x).max() * 3e-7  # f32 reciprocal-grid tolerance
    assert np.max(np.abs(np.asarray(xh_k) - x)) <= tol


@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_lorenzo_kernel_dtypes(dtype):
    rng = np.random.default_rng(0)
    x = np.cumsum(rng.normal(size=(64, 256)).astype(dtype), axis=1)
    c_k, d_k = lorenzo_encode(jnp.asarray(x, jnp.float32), eb=1e-2, mode="2d")
    xh = lorenzo_decode(d_k, eb=1e-2, mode="2d")
    assert np.max(np.abs(np.asarray(xh) - x.astype(np.float32))) <= 1e-2 + 1e-4


@pytest.mark.parametrize("n", [5, 100, 16384, 40000])
def test_bitplane_kernel(n):
    rng = np.random.default_rng(n)
    vals = rng.integers(0, 2**32, size=n, dtype=np.uint32)
    w_k = np.asarray(bitplane_encode(jnp.asarray(vals)))
    w_r = np.asarray(bp_ref_encode(vals))
    np.testing.assert_array_equal(w_k[:, : w_r.shape[1]], w_r)
    back = np.asarray(bitplane_decode(jnp.asarray(w_k), n))
    np.testing.assert_array_equal(back, vals)


def test_bitplane_sparsity_structure():
    """Small-magnitude values must leave significant planes all-zero (the
    §4.2 compressibility property)."""
    vals = np.arange(4096, dtype=np.uint32) % 16  # only 4 low bits used
    w = np.asarray(bitplane_encode(jnp.asarray(vals)))
    assert np.all(w[4:, :] == 0)


# ---------------------------------------------------------------------------
# host codec vs device kernel parity (the two bitplane implementations must
# agree on plane CONTENT: the unpred-aware quantizer serializes with the host
# codec today and may hand the same integers to the kernel on TPU)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [0, 1, 31, 32, 33, 1000, 16384, 40009])
def test_bitplane_host_kernel_parity(n):
    """Host ``quantizers.bitplane_encode/decode`` and ``kernels/bitplane``
    (interpret mode) round-trip the same values AND store identical bits per
    plane, including tail/partial-word and empty inputs."""
    from repro.core.quantizers import (
        bitplane_decode as host_decode,
        bitplane_encode as host_encode,
    )

    rng = np.random.default_rng(n)
    # magnitudes within uint32 so both codecs can represent them
    vals = rng.integers(0, 1 << 32, size=n, dtype=np.uint64).astype(np.uint32)

    # round-trips
    host_back, consumed = host_decode(host_encode(vals.astype(np.int64)))
    assert consumed == len(host_encode(vals.astype(np.int64)))
    np.testing.assert_array_equal(host_back, vals.astype(np.int64))
    kern_back = np.asarray(bitplane_decode(bitplane_encode(jnp.asarray(vals)), n))
    np.testing.assert_array_equal(kern_back, vals)
    if n == 0:
        return

    # plane-content parity: both codecs must store exactly ((vals >> p) & 1)
    # for every plane p (the host packs big-endian bits MSB-plane-first, the
    # kernel packs little-endian words plane-row-major — same content)
    blob = host_encode(vals.astype(np.int64))
    header = np.frombuffer(blob, np.int64, count=2)
    nplanes = int(header[1])
    assert nplanes == max(1, int(vals.max()).bit_length())
    nbytes_plane = (n + 7) // 8
    pos = 16 + nbytes_plane  # skip header + sign bitmap (all zero here)
    words = np.asarray(bitplane_encode(jnp.asarray(vals)))
    for i, p in enumerate(range(nplanes - 1, -1, -1)):  # host is MSB-first
        host_bits = np.unpackbits(
            np.frombuffer(blob, np.uint8, count=nbytes_plane, offset=pos + i * nbytes_plane),
            count=n,
        )
        expect = ((vals >> np.uint32(p)) & np.uint32(1)).astype(np.uint8)
        np.testing.assert_array_equal(host_bits, expect)
        kern_bits = (
            (words[p][np.arange(n) // 32] >> (np.arange(n) % 32).astype(np.uint32)) & 1
        ).astype(np.uint8)
        np.testing.assert_array_equal(kern_bits, expect)


def test_bitplane_host_kernel_parity_signed_tail():
    """Signed host values: the kernel codec sees magnitudes; the host sign
    bitmap must round-trip alongside (tail length 3 exercises partial bytes
    AND partial words)."""
    from repro.core.quantizers import bitplane_decode as host_decode
    from repro.core.quantizers import bitplane_encode as host_encode

    vals = np.asarray([5, -1, (1 << 31), -(1 << 20), 0, -7, 123456789, -3, 9, 2, -2], np.int64)
    back, _ = host_decode(host_encode(vals))
    np.testing.assert_array_equal(back, vals)
    mags = np.abs(vals).astype(np.uint32)
    kern = np.asarray(bitplane_decode(bitplane_encode(jnp.asarray(mags)), mags.size))
    np.testing.assert_array_equal(kern, mags)


# ---------------------------------------------------------------------------
# blockwise transform kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(8, 128), (64, 256), (12, 132), (4, 640)])
@pytest.mark.parametrize("mode", ["1d", "2d"])
def test_transform_kernel_equals_ref(shape, mode):
    rng = np.random.default_rng(abs(hash((shape, mode))) % 1000)
    x = rng.normal(size=shape).astype(np.float32)
    c_k = np.asarray(transform_fwd(jnp.asarray(x), mode=mode))
    c_r = np.asarray(tf_ref_fwd(x, mode=mode))
    np.testing.assert_allclose(c_k, c_r, rtol=1e-6, atol=1e-6)
    b_k = np.asarray(transform_inv(jnp.asarray(c_k), mode=mode))
    b_r = np.asarray(tf_ref_inv(c_r, mode=mode))
    np.testing.assert_allclose(b_k, b_r, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(b_k, x, rtol=1e-5, atol=1e-5)


def test_transform_kernel_orthonormal():
    """The shared basis must be orthonormal — the error-bound analysis in
    core/transform.py (L_inf amplification of the inverse) depends on it."""
    from repro.kernels.transform.ref import AMP_1AXIS, MAT

    np.testing.assert_allclose(MAT @ MAT.T, np.eye(4), atol=1e-15)
    assert abs(AMP_1AXIS - np.abs(MAT).sum(axis=0).max()) < 1e-15


@pytest.mark.parametrize("shape", [(300, 96), (512, 128), (64, 64), (33, 200)])
def test_kvquant_kernel(shape):
    rng = np.random.default_rng(abs(hash(shape)) % 997)
    T, C = shape
    x = rng.normal(0, 2, size=shape).astype(np.float32) * (1 + np.arange(C))[None, :]
    q_k, s_k = kv_quantize(jnp.asarray(x))
    q_r, s_r = ref_quantize(x)
    np.testing.assert_array_equal(np.asarray(q_k), np.asarray(q_r))
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), rtol=1e-6)
    deq = np.asarray(q_k).astype(np.float32) * np.asarray(s_k)[None, :]
    assert np.all(np.abs(deq - x) <= np.asarray(s_k)[None, :] * 0.5001)
    a = rng.normal(size=(48, T)).astype(np.float32)
    o_k = np.asarray(kv_dequant_matmul(jnp.asarray(a), q_k, s_k))
    o_r = np.asarray(ref_dequant_matmul(a, q_r, s_r))
    bound = 1e-6 * (np.abs(a) @ np.abs(deq)) + 1e-4
    assert np.all(np.abs(o_k - o_r) <= bound)
