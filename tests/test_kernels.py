"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.compat import HAS_PALLAS_TPU

# missing CompilerParams is NOT a skip: the compat shim passes None and the
# interpret-mode path these tests use accepts that
if not HAS_PALLAS_TPU:
    pytest.skip(
        "jax.experimental.pallas.tpu is not importable in this JAX build",
        allow_module_level=True,
    )

from repro.kernels.bitplane import (
    bitplane_decode,
    bitplane_encode,
    ref_encode as bp_ref_encode,
)
from repro.kernels.kvquant import (
    kv_dequant_matmul,
    kv_quantize,
    ref_dequant_matmul,
    ref_quantize,
)
from repro.kernels.lorenzo import (
    lorenzo_decode,
    lorenzo_encode,
    ref_decode,
    ref_encode,
)


@pytest.mark.parametrize(
    "shape", [(100, 300), (256, 512), (7, 50), (1, 1000), (513, 129), (8, 128)]
)
@pytest.mark.parametrize("mode", ["1d", "2d"])
@pytest.mark.parametrize("eb", [1e-1, 1e-3])
def test_lorenzo_kernel_equals_ref(shape, mode, eb):
    rng = np.random.default_rng(abs(hash((shape, mode))) % 1000)
    x = np.cumsum(rng.normal(size=shape).astype(np.float32), axis=1)
    c_k, d_k = lorenzo_encode(jnp.asarray(x), eb=eb, mode=mode)
    c_r, d_r = ref_encode(x, eb, mode=mode)
    np.testing.assert_array_equal(np.asarray(c_k), np.asarray(c_r))
    np.testing.assert_array_equal(np.asarray(d_k), np.asarray(d_r))
    xh_k = lorenzo_decode(d_k, eb=eb, mode=mode)
    xh_r = ref_decode(d_r, eb, mode=mode)
    np.testing.assert_array_equal(np.asarray(xh_k), np.asarray(xh_r))
    tol = eb + np.abs(x).max() * 3e-7  # f32 reciprocal-grid tolerance
    assert np.max(np.abs(np.asarray(xh_k) - x)) <= tol


@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_lorenzo_kernel_dtypes(dtype):
    rng = np.random.default_rng(0)
    x = np.cumsum(rng.normal(size=(64, 256)).astype(dtype), axis=1)
    c_k, d_k = lorenzo_encode(jnp.asarray(x, jnp.float32), eb=1e-2, mode="2d")
    xh = lorenzo_decode(d_k, eb=1e-2, mode="2d")
    assert np.max(np.abs(np.asarray(xh) - x.astype(np.float32))) <= 1e-2 + 1e-4


@pytest.mark.parametrize("n", [5, 100, 16384, 40000])
def test_bitplane_kernel(n):
    rng = np.random.default_rng(n)
    vals = rng.integers(0, 2**32, size=n, dtype=np.uint32)
    w_k = np.asarray(bitplane_encode(jnp.asarray(vals)))
    w_r = np.asarray(bp_ref_encode(vals))
    np.testing.assert_array_equal(w_k[:, : w_r.shape[1]], w_r)
    back = np.asarray(bitplane_decode(jnp.asarray(w_k), n))
    np.testing.assert_array_equal(back, vals)


def test_bitplane_sparsity_structure():
    """Small-magnitude values must leave significant planes all-zero (the
    §4.2 compressibility property)."""
    vals = np.arange(4096, dtype=np.uint32) % 16  # only 4 low bits used
    w = np.asarray(bitplane_encode(jnp.asarray(vals)))
    assert np.all(w[4:, :] == 0)


@pytest.mark.parametrize("shape", [(300, 96), (512, 128), (64, 64), (33, 200)])
def test_kvquant_kernel(shape):
    rng = np.random.default_rng(abs(hash(shape)) % 997)
    T, C = shape
    x = rng.normal(0, 2, size=shape).astype(np.float32) * (1 + np.arange(C))[None, :]
    q_k, s_k = kv_quantize(jnp.asarray(x))
    q_r, s_r = ref_quantize(x)
    np.testing.assert_array_equal(np.asarray(q_k), np.asarray(q_r))
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), rtol=1e-6)
    deq = np.asarray(q_k).astype(np.float32) * np.asarray(s_k)[None, :]
    assert np.all(np.abs(deq - x) <= np.asarray(s_k)[None, :] * 0.5001)
    a = rng.normal(size=(48, T)).astype(np.float32)
    o_k = np.asarray(kv_dequant_matmul(jnp.asarray(a), q_k, s_k))
    o_r = np.asarray(ref_dequant_matmul(a, q_r, s_r))
    bound = 1e-6 * (np.abs(a) @ np.abs(deq)) + 1e-4
    assert np.all(np.abs(o_k - o_r) <= bound)
