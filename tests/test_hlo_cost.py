"""The while-trip-corrected HLO cost parser vs analytic ground truth."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import hlo_cost


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_scan_trip_correction():
    def body(x, w):
        return jnp.tanh(x @ w), None

    def f(x, ws):
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 256, 256), jnp.float32)
    c = _compile(f, x, ws)
    cost = hlo_cost.analyze(c.as_text())
    expect = 10 * 2 * 128 * 256 * 256
    assert abs(cost.dot_flops - expect) / expect < 0.01
    assert 10 in cost.while_trips.values()


def test_nested_scan():
    def inner(x, w):
        return x @ w, None

    def outer(x, ws):
        def ob(x, _):
            y, _ = jax.lax.scan(inner, x, ws)
            return y, None

        y, _ = jax.lax.scan(ob, x, None, length=3)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
    c = _compile(outer, x, ws)
    cost = hlo_cost.analyze(c.as_text())
    expect = 3 * 5 * 2 * 64 * 64 * 64
    assert abs(cost.dot_flops - expect) / expect < 0.05


def test_dot_flops_batched():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)

    a = jax.ShapeDtypeStruct((4, 32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 64, 16), jnp.float32)
    c = _compile(f, a, b)
    cost = hlo_cost.analyze(c.as_text())
    expect = 2 * 4 * 32 * 16 * 64
    assert abs(cost.dot_flops - expect) / expect < 0.01


def test_memory_bytes_sane():
    def f(a):
        return a * 2.0 + 1.0

    a = jax.ShapeDtypeStruct((1 << 20,), jnp.float32)
    c = _compile(f, a)
    cost = hlo_cost.analyze(c.as_text())
    # one fused read + one write = 8MB +- fusion details
    assert 4e6 <= cost.hbm_bytes <= 2e7
