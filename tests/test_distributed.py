"""Multi-device semantics on 8 fake CPU devices (subprocess: the fake device
count must be set before jax initializes, and the main test process keeps 1
device per the harness contract).

Covers: sharded-vs-single-device train-step parity, MoE expert-parallel
parity, compressed-gradient DP reduction, and elastic restore onto a
different mesh.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import pytest

SRC = str(Path(__file__).resolve().parent.parent / "src")

# The sharded-training path (parallel/plan.py, launch/mesh.py) uses the
# explicit-sharding APIs (jax.sharding.AxisType, get_abstract_mesh) that
# landed after jax 0.4.x; on older installs the subprocess harness dies at
# import time, which is an environment limitation, not a code regression.
_NEEDS = ("AxisType", "get_abstract_mesh")
_HAVE_EXPLICIT_SHARDING = all(hasattr(jax.sharding, a) for a in _NEEDS)
requires_explicit_sharding = pytest.mark.skipif(
    not _HAVE_EXPLICIT_SHARDING,
    reason=(
        "installed jax lacks jax.sharding.{AxisType,get_abstract_mesh} "
        "(explicit-sharding API); the sharded train/restore paths cannot "
        "run — upgrade jax to re-enable these 3 distributed tests"
    ),
)


def _run(body: str, timeout=600):
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        import repro.configs as configs
        from repro import models
        from repro.data import make_pipeline
        from repro.optim import AdamWConfig
        from repro.parallel import ParallelPlan
        from repro.parallel.specs import param_specs
        from repro.train.step import init_train_state, make_train_step, jit_train_step
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        """
    ) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=timeout, env=env,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


@pytest.mark.slow
@requires_explicit_sharding
def test_sharded_train_matches_single_device():
    out = _run(
        """
        cfg = configs.get_smoke("qwen1.5-0.5b")  # kv divides tp: same param shapes
        opt = AdamWConfig(lr=1e-3)
        pipe = make_pipeline(cfg, seq=16, global_batch=4)
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}

        plan1 = ParallelPlan()
        s1 = init_train_state(jax.random.PRNGKey(0), cfg, plan1, opt)
        st1 = make_train_step(cfg, plan1, opt)
        s1b, m1 = st1(s1, batch)

        plan8 = ParallelPlan(mesh=mesh, batch_axes=("data",), fsdp_axes=("data",))
        s8 = init_train_state(jax.random.PRNGKey(0), cfg, plan8, opt)
        st8 = make_train_step(cfg, plan8, opt)
        j8 = jit_train_step(st8, s8, cfg, plan8, opt, batch)
        s8b, m8 = j8(s8, batch)
        print("loss1", float(m1["loss"]), "loss8", float(m8["loss"]))
        assert abs(float(m1["loss"]) - float(m8["loss"])) < 5e-3
        w1 = np.asarray(jax.tree.leaves(s1b["params"])[0], np.float32)
        w8 = np.asarray(jax.tree.leaves(s8b["params"])[0], np.float32)
        np.testing.assert_allclose(w1, w8, atol=3e-3)
        print("PARITY OK")
        """
    )
    assert "PARITY OK" in out


@pytest.mark.slow
@requires_explicit_sharding
def test_moe_expert_parallel_parity():
    out = _run(
        """
        cfg = configs.get_smoke("deepseek-moe-16b")
        plan1 = ParallelPlan()
        plan8 = ParallelPlan(mesh=mesh, batch_axes=("data",))
        params = models.init_params(jax.random.PRNGKey(0), cfg, plan1)
        pipe = make_pipeline(cfg, seq=16, global_batch=4)
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
        l1 = float(models.loss_fn(params, batch, cfg, plan1))
        l8 = float(models.loss_fn(params, batch, cfg, plan8))
        print("l1", l1, "l8", l8)
        # EP capacity is per-shard in the 8-device run; small drop differences
        assert abs(l1 - l8) < 0.1  # capacity-drop differences per shard
        print("MOE PARITY OK")
        """
    )
    assert "MOE PARITY OK" in out


@pytest.mark.slow
@pytest.mark.xfail(
    reason="XLA-CPU SPMD bug: partial-manual shard_map (dp manual, model "
    "auto) around remat+scan train bodies aborts with 'Invalid binary "
    "instruction opcode copy' (hlo_instruction.cc:1558). The compressed-DP "
    "algorithm itself is validated in tests/test_compression_inloop.py and "
    "benchmarks/bench_integrations.py; re-enable on TPU/Shardy backends.",
    run=False,
)
def test_grad_compressed_train_step_runs_and_converges():
    out = _run(
        """
        cfg = configs.get_smoke("qwen1.5-0.5b")
        opt = AdamWConfig(lr=3e-3, weight_decay=0.0)
        plan = ParallelPlan(mesh=mesh, batch_axes=("data",), grad_compress_bits=8)
        state = init_train_state(jax.random.PRNGKey(0), cfg, plan, opt)
        step = make_train_step(cfg, plan, opt, total_steps=40)
        pipe = make_pipeline(cfg, seq=16, global_batch=4)
        losses = []
        for k in range(12):
            batch = {k2: jnp.asarray(v) for k2, v in pipe.batch_at(k % 3).items()}
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
        print("losses", losses[0], losses[-1])
        assert losses[-1] < losses[0] - 0.2
        print("COMPRESSED DP OK")
        """
    )
    assert "COMPRESSED DP OK" in out


@pytest.mark.slow
@requires_explicit_sharding
def test_elastic_restore_to_different_mesh(tmp_path):
    out = _run(
        f"""
        import numpy as np
        from repro.ft import CheckpointManager, CheckpointPolicy, LeafPolicy
        from repro.ft.elastic import make_elastic_mesh, reshard_state
        cfg = configs.get_smoke("granite-3-8b")
        opt = AdamWConfig(lr=1e-3)
        plan8 = ParallelPlan(mesh=mesh, batch_axes=("data",), fsdp_axes=("data",))
        s8 = init_train_state(jax.random.PRNGKey(0), cfg, plan8, opt)
        mgr = CheckpointManager(r"{tmp_path}", CheckpointPolicy(rules=(("", LeafPolicy("lossless")),)), use_async=False)
        mgr.save(1, s8)
        mgr.wait()
        # restore onto a 4-device mesh (simulating 4 lost devices)
        mesh4 = jax.sharding.Mesh(np.asarray(jax.devices()[:4]).reshape(2, 2), ("data", "model"))
        template = jax.tree.map(np.asarray, s8)
        host, _ = mgr.restore(template)
        from repro.parallel.specs import param_specs
        import dataclasses
        plan4 = dataclasses.replace(plan8, mesh=mesh4)
        pspecs = param_specs(host["params"], cfg, plan4)
        resharded = reshard_state(host["params"], pspecs, mesh4)
        l0 = jax.tree.leaves(resharded)[0]
        assert len(l0.sharding.device_set) in (2, 4)
        # and the values survived
        np.testing.assert_array_equal(
            np.asarray(l0, np.float32),
            np.asarray(jax.tree.leaves(s8["params"])[0], np.float32))
        print("ELASTIC OK")
        """
    )
    assert "ELASTIC OK" in out
