"""Multi-device semantics on 8 fake CPU devices (subprocess: the fake device
count must be set before jax initializes, and the main test process keeps 1
device per the harness contract).

Covers: sharded-vs-single-device train-step parity, MoE expert-parallel
parity, compressed-gradient DP reduction (including >=20-step loss-trajectory
parity against the uncompressed schedule), and elastic restore onto a
different mesh (full-leaf and chunk-range paths).

The mesh preamble goes through repro.parallel.compat, which bridges the
explicit-sharding API gap between jax releases (jax.sharding.AxisType /
get_abstract_mesh on new jax, jax.experimental.shard_map on 0.4.x) — these
tests run on either, so there is no version skip.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parent.parent / "src")


def _run(body: str, timeout=600):
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        import repro.configs as configs
        from repro import models
        from repro.data import make_pipeline
        from repro.optim import AdamWConfig
        from repro.parallel import ParallelPlan, compat
        from repro.parallel.specs import param_specs
        from repro.train.step import init_train_state, make_train_step, jit_train_step
        mesh = compat.make_mesh((2, 4), ("data", "model"), auto_axis_types=True)
        """
    ) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=timeout, env=env,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


@pytest.mark.slow
def test_sharded_train_matches_single_device():
    out = _run(
        """
        cfg = configs.get_smoke("qwen1.5-0.5b")  # kv divides tp: same param shapes
        opt = AdamWConfig(lr=1e-3)
        pipe = make_pipeline(cfg, seq=16, global_batch=4)
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}

        plan1 = ParallelPlan()
        s1 = init_train_state(jax.random.PRNGKey(0), cfg, plan1, opt)
        st1 = make_train_step(cfg, plan1, opt)
        s1b, m1 = st1(s1, batch)

        plan8 = ParallelPlan(mesh=mesh, batch_axes=("data",), fsdp_axes=("data",))
        s8 = init_train_state(jax.random.PRNGKey(0), cfg, plan8, opt)
        st8 = make_train_step(cfg, plan8, opt)
        j8 = jit_train_step(st8, s8, cfg, plan8, opt, batch)
        s8b, m8 = j8(s8, batch)
        print("loss1", float(m1["loss"]), "loss8", float(m8["loss"]))
        assert abs(float(m1["loss"]) - float(m8["loss"])) < 5e-3
        w1 = np.asarray(jax.tree.leaves(s1b["params"])[0], np.float32)
        w8 = np.asarray(jax.tree.leaves(s8b["params"])[0], np.float32)
        np.testing.assert_allclose(w1, w8, atol=3e-3)
        print("PARITY OK")
        """
    )
    assert "PARITY OK" in out


@pytest.mark.slow
def test_moe_expert_parallel_parity():
    out = _run(
        """
        from repro.models import moe as moe_mod
        cfg = configs.get_smoke("deepseek-moe-16b")
        plan1 = ParallelPlan()
        plan8 = ParallelPlan(mesh=mesh, batch_axes=("data",))
        params = models.init_params(jax.random.PRNGKey(0), cfg, plan1)
        pipe = make_pipeline(cfg, seq=16, global_batch=4)
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
        # Expert capacity is computed from the LOCAL token count inside each
        # shard, so with the default factor the two layouts drop different
        # tokens and their losses legitimately differ.  The parity invariant
        # is drop-free routing: with ample capacity both layouts compute the
        # same function and must agree to numerical noise.
        ld1 = float(models.loss_fn(params, batch, cfg, plan1))
        ld8 = float(models.loss_fn(params, batch, cfg, plan8))
        print("default-capacity l1", ld1, "l8", ld8)
        assert abs(ld1 - ld8) < 0.5  # per-shard capacity drops: loose band
        moe_mod.CAPACITY_FACTOR = 16.0  # drop-free on both layouts
        l1 = float(models.loss_fn(params, batch, cfg, plan1))
        l8 = float(models.loss_fn(params, batch, cfg, plan8))
        print("drop-free l1", l1, "l8", l8)
        assert abs(l1 - l8) < 5e-3
        print("MOE PARITY OK")
        """
    )
    assert "MOE PARITY OK" in out


@pytest.mark.slow
def test_grad_compressed_train_step_runs_and_converges():
    """The compressed-DP region compiles and trains on a real (fake-device)
    mesh: full-manual shard_map, psum_scatter -> error-feedback jit-codec
    encode -> all_gather.  Historic note: the partial-manual (dp manual,
    model auto) formulation aborted XLA-CPU's SPMD partitioner; the region
    is manual over ALL axes now, which compiles everywhere."""
    out = _run(
        """
        cfg = configs.get_smoke("qwen1.5-0.5b")
        opt = AdamWConfig(lr=3e-3, weight_decay=0.0)
        plan = ParallelPlan(mesh=mesh, batch_axes=("data",), grad_compress_bits=8)
        state = init_train_state(jax.random.PRNGKey(0), cfg, plan, opt)
        step = make_train_step(cfg, plan, opt, total_steps=40)
        pipe = make_pipeline(cfg, seq=16, global_batch=4)
        losses = []
        for k in range(12):
            batch = {k2: jnp.asarray(v) for k2, v in pipe.batch_at(k % 3).items()}
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
        print("losses", losses[0], losses[-1])
        assert losses[-1] < losses[0] - 0.2
        print("COMPRESSED DP OK")
        """
    )
    assert "COMPRESSED DP OK" in out


@pytest.mark.slow
def test_compressed_trajectory_matches_uncompressed():
    """>=20 sharded steps with --compress-grads-style int8 policy: the loss
    trajectory must track the uncompressed schedule within a small band
    (error feedback keeps the compression error zero-mean, so trajectories
    stay close rather than drifting)."""
    out = _run(
        """
        cfg = configs.get_smoke("qwen1.5-0.5b")
        opt = AdamWConfig(lr=1e-3, weight_decay=0.0)
        pipe = make_pipeline(cfg, seq=16, global_batch=4)
        N = 20

        def run(plan):
            state = init_train_state(jax.random.PRNGKey(0), cfg, plan, opt)
            step = make_train_step(cfg, plan, opt, total_steps=N)
            losses = []
            for k in range(N):
                batch = {k2: jnp.asarray(v)
                         for k2, v in pipe.batch_at(k % 4).items()}
                state, m = step(state, batch)
                losses.append(float(m["loss"]))
            return losses

        base = run(ParallelPlan(mesh=mesh, batch_axes=("data",)))
        comp = run(ParallelPlan(mesh=mesh, batch_axes=("data",),
                                grad_policy="int8:bs=512"))
        worst = max(abs(a - b) for a, b in zip(base, comp))
        print("worst |delta loss| over", len(base), "steps:", worst)
        assert len(base) >= 20
        assert worst < 0.05, (base, comp)
        # and both actually trained
        assert base[-1] < base[0] - 0.2 and comp[-1] < comp[0] - 0.2
        print("TRAJECTORY OK")
        """
    )
    assert "TRAJECTORY OK" in out


@pytest.mark.slow
def test_elastic_restore_to_different_mesh(tmp_path):
    out = _run(
        f"""
        import numpy as np
        from repro.ft import CheckpointManager, CheckpointPolicy, LeafPolicy
        from repro.ft.elastic import make_elastic_mesh, reshard_state
        cfg = configs.get_smoke("granite-3-8b")
        opt = AdamWConfig(lr=1e-3)
        plan8 = ParallelPlan(mesh=mesh, batch_axes=("data",), fsdp_axes=("data",))
        s8 = init_train_state(jax.random.PRNGKey(0), cfg, plan8, opt)
        mgr = CheckpointManager(r"{tmp_path}", CheckpointPolicy(rules=(("", LeafPolicy("lossless")),)), use_async=False)
        mgr.save(1, s8)
        mgr.wait()
        # restore onto a 4-device mesh (simulating 4 lost devices)
        mesh4 = jax.sharding.Mesh(np.asarray(jax.devices()[:4]).reshape(2, 2), ("data", "model"))
        template = jax.tree.map(np.asarray, s8)
        host, _ = mgr.restore(template)
        from repro.parallel.specs import param_specs
        import dataclasses
        plan4 = dataclasses.replace(plan8, mesh=mesh4)
        pspecs = param_specs(host["params"], cfg, plan4)
        resharded = reshard_state(host["params"], pspecs, mesh4)
        l0 = jax.tree.leaves(resharded)[0]
        assert len(l0.sharding.device_set) in (2, 4)
        # and the values survived
        np.testing.assert_array_equal(
            np.asarray(l0, np.float32),
            np.asarray(jax.tree.leaves(s8["params"])[0], np.float32))
        print("ELASTIC OK")
        """
    )
    assert "ELASTIC OK" in out


@pytest.mark.slow
def test_elastic_chunk_range_restore_on_new_mesh(tmp_path):
    """restore_resharded decodes compressed leaves straight onto a CHANGED
    mesh: chunk-range reads for the big lossy leaves, value-identical to a
    full decompress + device_put."""
    out = _run(
        f"""
        import numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.ft import CheckpointManager
        from repro.ft import elastic
        rng = np.random.default_rng(0)
        state = {{
            "opt": {{"m": {{"w": np.cumsum(
                rng.normal(size=(4096, 512)).astype(np.float32), 0) * 1e-3}}}},
            "params": {{"w": rng.normal(size=(256, 64)).astype(np.float32)}},
        }}
        mgr = CheckpointManager(r"{tmp_path}", use_async=False)
        mgr.save(3, state)
        mesh4 = jax.sharding.Mesh(
            np.asarray(jax.devices()[:4]).reshape(4, 1), ("data", "model"))
        specs = {{"opt": {{"m": {{"w": P("data", None)}}}}, "params": {{"w": P()}}}}
        tpl = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        out, extra, rep = elastic.restore_resharded(mgr, tpl, specs, mesh4, 3)
        print(rep.summary())
        assert rep.leaves["opt/m/w"].mode == "chunk-range", rep.leaves
        assert rep.leaves["opt/m/w"].bytes_read < rep.leaves["opt/m/w"].bytes_full
        # differential: identical to full decode + device_put on the new mesh
        host, _ = mgr.restore(jax.tree.map(
            lambda x: np.zeros(x.shape, x.dtype), state))
        ref = jax.tree.map(
            lambda h, s: jax.device_put(h, NamedSharding(mesh4, s)),
            host, specs, is_leaf=lambda x: isinstance(x, np.ndarray))
        for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        print("CHUNK RANGE RESHARD OK")
        """
    )
    assert "CHUNK RANGE RESHARD OK" in out
