"""Train-step factory: microbatched, remat'd, pjit-sharded, optionally with
error-bounded gradient compression on the DP reduction.

Two modes:
  * baseline  — plain pjit: XLA inserts the DP all-reduce (bf16/f32).
  * compressed (plan.grad_policy / plan.grad_compress_bits) — the step body
    runs inside a shard_map that is MANUAL over ALL mesh axes, so the DP
    reduction is OUR schedule: reduce-scatter bf16 -> error-feedback encode
    with the jit codec facade (per-block predictor contest, core/jitmode) ->
    all-gather codes + side channels (repro/compression/grad.py).  Full
    manual (not dp-only) both sidesteps an XLA-CPU partial-manual
    partitioner crash (parallel/compat.py) and keeps model compute purely
    local — params are replicated inside the region, so the model axis just
    duplicates work on CPU test meshes.

State = {params, opt{m,v,step}, feedback?}.  All specs are derived from
parallel/specs.py so launch/dryrun.py and examples share one source of truth.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .. import models
from ..compression import grad as gradc
from ..models.common import ModelConfig
from ..optim import AdamWConfig, init_state, update, warmup_cosine
from ..parallel import compat
from ..parallel.plan import ParallelPlan
from ..parallel.specs import batch_specs, param_specs

#: Compressed-moment side channels: trailing path names with the parameter's
#: leading spec and an unsharded blocks dim (codes keeps the full rank)
_SIDE_CHANNELS = ("scale", "tags", "base")


def _moment_spec(pspec: P, leaf_ndim: int, compressed: bool):
    if not compressed:
        return pspec
    entries = tuple(pspec) + (None,) * (leaf_ndim - len(tuple(pspec)))
    side = P(*entries[:-1], None)
    return {"codes": P(*entries), **{k: side for k in _SIDE_CHANNELS}}


def state_specs(state, cfg: ModelConfig, plan: ParallelPlan, opt_cfg: AdamWConfig):
    params = state["params"] if isinstance(state, dict) and "params" in state else state
    pspecs = param_specs(params, cfg, plan)
    flat_pspecs = {
        _pstr(path): spec
        for path, spec in jax.tree_util.tree_flatten_with_path(pspecs)[0]
    }

    def moment_tree(moments):
        """Spec tree structurally identical to the actual moment pytree."""

        def leaf_spec(path, leaf):
            names = [_key(p) for p in path]
            # strip trailing Compressed field names; all have the parameter's
            # rank (side channels swap the last dim for n_blocks)
            if names and names[-1] in ("codes",) + _SIDE_CHANNELS:
                pstr = "/".join(names[:-1])
                base = flat_pspecs.get(pstr, P())
                nd = leaf.ndim
                entries = tuple(base) + (None,) * (nd - len(tuple(base)))
                if names[-1] == "codes":
                    return P(*entries)
                return P(*entries[:-1], None)
            pstr = "/".join(names)
            return flat_pspecs.get(pstr, P())

        return jax.tree_util.tree_map_with_path(leaf_spec, moments)

    if plan.grad_compression() is not None and plan.mesh is not None:
        # compressed mode: the step body is manual over the whole mesh with
        # params/opt replicated inside (no FSDP there) — the AOT shardings
        # must match the region's view or jit inserts reshards every step
        specs = {
            "params": jax.tree.map(lambda _: P(), state["params"]),
            "opt": jax.tree.map(lambda _: P(), state["opt"]),
        }
    else:
        specs = {
            "params": pspecs,
            "opt": {
                "m": moment_tree(state["opt"]["m"]),
                "v": moment_tree(state["opt"]["v"]),
                "step": P(),
            },
        }
    if plan.grad_compression() is not None:
        b = plan.batch_axes if len(plan.batch_axes) > 1 else plan.batch_axes[0]
        specs["feedback"] = P(b)
    return specs


def _key(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def _pstr(path) -> str:
    return "/".join(_key(p) for p in path)


def init_train_state(key, cfg: ModelConfig, plan: ParallelPlan, opt_cfg: AdamWConfig):
    params = models.init_params(key, cfg, plan)
    state = {"params": params, "opt": init_state(params, opt_cfg)}
    if plan.grad_compression() is not None:
        state["feedback"] = gradc.init_feedback(params, plan.dp)
    return state


def _microbatched_grads(loss_fn, params, batch, n_micro: int, accum_dtype=jnp.float32):
    if n_micro <= 1:
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        return loss, grads

    def reshape(x):
        return x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:])

    mbatch = jax.tree.map(reshape, batch)
    zero = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), params)

    def body(carry, mb):
        loss_acc, g_acc = carry
        loss, g = jax.value_and_grad(loss_fn)(params, mb)
        g_acc = jax.tree.map(lambda a, b: a + b.astype(accum_dtype), g_acc, g)
        return (loss_acc + loss, g_acc), None

    (loss, grads), _ = jax.lax.scan(body, (jnp.float32(0.0), zero), mbatch)
    inv = 1.0 / n_micro
    return loss * inv, jax.tree.map(lambda g: (g.astype(jnp.float32) * inv), grads)


def make_train_step(
    cfg: ModelConfig,
    plan: ParallelPlan,
    opt_cfg: AdamWConfig = AdamWConfig(),
    total_steps: int = 10000,
    attn_mode: str = "blocked",
) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_fn(params, batch):
        return models.loss_fn(params, batch, cfg, plan, attn_mode=attn_mode)

    dp_axes = tuple(plan.batch_axes)
    grad_pol = plan.grad_compression()

    def step_core(state, batch, *, inner_plan: ParallelPlan):
        def lf(params, b):
            return models.loss_fn(params, b, cfg, inner_plan, attn_mode=attn_mode)

        loss, grads = _microbatched_grads(
            lf,
            state["params"],
            batch,
            plan.microbatches,
            accum_dtype=jnp.dtype(plan.grad_accum_dtype),
        )
        new_state = dict(state)
        if grad_pol is not None:
            grads, fb = gradc.compressed_reduce_tree(
                grads, state["feedback"], dp_axes, grad_pol
            )
            loss = jax.lax.pmean(loss, dp_axes)
            new_state["feedback"] = fb
        lr_scale = warmup_cosine(state["opt"]["step"], total=total_steps)
        params, opt, metrics = update(
            state["params"], grads, state["opt"], opt_cfg, lr_scale
        )
        new_state["params"] = params
        new_state["opt"] = opt
        metrics["loss"] = loss
        return new_state, metrics

    if grad_pol is not None and plan.mesh is not None:
        # manual over ALL mesh axes (see module docstring); the body sees
        # purely local arrays, so the inner plan drops the mesh entirely —
        # sharding constraints elide and model compute runs the local path
        inner_plan = dataclasses.replace(plan, mesh=None, batch_axes=())

        def train_step(state, batch):
            sspecs = state_specs_cached(state)
            b = dp_axes if len(dp_axes) > 1 else dp_axes[0]

            def body(state, batch):
                return step_core(state, batch, inner_plan=inner_plan)

            bspec = jax.tree.map(
                lambda x: P(*((b,) + (None,) * (x.ndim - 1))), batch
            )
            out = compat.shard_map(
                body,
                plan.mesh,
                axis_names=set(plan.mesh.axis_names),
                in_specs=(sspecs, bspec),
                out_specs=(sspecs, {"grad_norm": P(), "loss": P()}),
                check_vma=False,
            )(state, batch)
            return out

        def state_specs_cached(state):
            # inside the manual region params are replicated over dp (no
            # FSDP in compressed mode); feedback is dp-sharded.
            def rep(x):
                return P()

            sp = {
                "params": jax.tree.map(rep, state["params"]),
                "opt": jax.tree.map(rep, state["opt"]),
            }
            b = dp_axes if len(dp_axes) > 1 else dp_axes[0]
            sp["feedback"] = P(b)
            return sp

        # the manual region can't run eagerly (closed_call under shard_map is
        # jit-only on 0.4.x), so the factory's contract — a callable that
        # just works — needs the jit here.  jit_train_step may wrap this
        # again with explicit shardings; nested jit is inlined at trace time.
        return jax.jit(train_step)

    def train_step(state, batch):
        return step_core(state, batch, inner_plan=plan)

    return train_step


def jit_train_step(
    train_step,
    state,
    cfg: ModelConfig,
    plan: ParallelPlan,
    opt_cfg: AdamWConfig,
    batch_shapes: Dict[str, jax.ShapeDtypeStruct],
):
    """AOT-jit with explicit in/out shardings (the dry-run entry point)."""
    if plan.mesh is None:
        return jax.jit(train_step)
    sspecs = state_specs(state, cfg, plan, opt_cfg)
    bspecs = batch_specs(batch_shapes, plan)
    shard = lambda tree: jax.tree.map(
        lambda s: jax.sharding.NamedSharding(plan.mesh, s) if isinstance(s, P) else s,
        tree,
        is_leaf=lambda s: isinstance(s, P),
    )
    metric_specs = {"grad_norm": P(), "loss": P()}
    return jax.jit(
        train_step,
        in_shardings=(shard(sspecs), shard(bspecs)),
        out_shardings=(shard(sspecs), shard(metric_specs)),
        donate_argnums=(0,),
    )
