"""qwen1.5-0.5b [hf:Qwen/Qwen1.5-0.5B; hf] — MHA with QKV bias, tied embeds.
24L d_model=1024 16H (kv=16) d_ff=2816 vocab=151936.
Full attention => long_500k SKIPPED."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=2816,
    vocab=151936,
    qkv_bias=True,
    tie_embeddings=True,
    mlp_act="swiglu",
)

SMOKE = ModelConfig(
    name="qwen1.5-smoke",
    family="dense",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=256,
    vocab=512,
    qkv_bias=True,
    tie_embeddings=True,
    mlp_act="swiglu",
    dtype="float32",
)
