"""pixtral-12b [hf:mistralai/Pixtral-12B-2409; unverified] — pixtral-ViT
frontend (STUB: input_specs provides patch+token embeddings (B,S,d)) on a
mistral-nemo decoder.  40L d_model=5120 32H (GQA kv=8, head_dim=128)
d_ff=14336 vocab=131072.  Full attention => long_500k SKIPPED."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=131072,
    mlp_act="swiglu",
)

SMOKE = ModelConfig(
    name="pixtral-smoke",
    family="vlm",
    n_layers=2,
    d_model=128,
    n_heads=8,
    n_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab=512,
    mlp_act="swiglu",
    dtype="float32",
)
