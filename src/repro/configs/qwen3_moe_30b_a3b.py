"""qwen3-moe-30b-a3b [hf:Qwen/Qwen3-30B-A3B; hf] — 128 experts top-8, no
shared expert; head_dim=128 explicit.  48L d_model=2048 32H (GQA kv=4)
expert d_ff=768 vocab=151936.  Full attention => long_500k SKIPPED."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=6144,  # unused (all layers MoE); kept for completeness
    vocab=151936,
    n_experts=128,
    top_k=8,
    moe_d_ff=768,
    n_shared_experts=0,
    dense_prefix_layers=0,
    mlp_act="swiglu",
)

SMOKE = ModelConfig(
    name="qwen3-moe-smoke",
    family="moe",
    n_layers=2,
    d_model=128,
    n_heads=8,
    n_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab=512,
    n_experts=16,
    top_k=4,
    moe_d_ff=32,
    n_shared_experts=0,
    mlp_act="swiglu",
    dtype="float32",
)
