"""Architecture registry: the 10 assigned configs + reduced smoke variants.

``get(name)`` / ``get_smoke(name)`` / ``ARCHS`` — names use the assignment
ids (dashes), module files use underscores.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.common import ModelConfig

from .shapes import SHAPES, ShapeCell, cell_skip_reason, input_specs

_MODULES = {
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "granite-3-8b": "granite_3_8b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "nemotron-4-340b": "nemotron_4_340b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "whisper-small": "whisper_small",
    "zamba2-7b": "zamba2_7b",
    "mamba2-2.7b": "mamba2_2_7b",
    "pixtral-12b": "pixtral_12b",
}

ARCHS: List[str] = list(_MODULES)


def _mod(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {ARCHS}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get(name: str) -> ModelConfig:
    return _mod(name).CONFIG


def get_smoke(name: str) -> ModelConfig:
    return _mod(name).SMOKE


__all__ = [
    "ARCHS",
    "get",
    "get_smoke",
    "SHAPES",
    "ShapeCell",
    "cell_skip_reason",
    "input_specs",
]
