"""granite-3-8b [hf:ibm-granite/granite-3.0-2b-base; hf] — GQA dense.
40L d_model=4096 32H (GQA kv=8) d_ff=12800 vocab=49155 (padded to 49408).
Full attention => long_500k SKIPPED."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=12800,
    vocab=49155,
    mlp_act="swiglu",
)

SMOKE = ModelConfig(
    name="granite-smoke",
    family="dense",
    n_layers=2,
    d_model=128,
    n_heads=8,
    n_kv_heads=2,
    d_ff=320,
    vocab=515,  # odd vocab exercises padding
    mlp_act="swiglu",
    dtype="float32",
)
