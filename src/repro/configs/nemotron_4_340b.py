"""nemotron-4-340b [arXiv:2402.16819; unverified] — GQA, squared-ReLU MLP.
96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000.
The flagship scale cell: relies on remat + FSDP + (optionally) compressed
optimizer state and int8 KV cache to fit v5e HBM (EXPERIMENTS.md §Dry-run).
Full attention => long_500k SKIPPED."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73728,
    vocab=256000,
    mlp_act="relu2",
)

SMOKE = ModelConfig(
    name="nemotron-smoke",
    family="dense",
    n_layers=2,
    d_model=192,
    n_heads=8,
    n_kv_heads=2,
    d_ff=768,
    vocab=512,
    mlp_act="relu2",
    dtype="float32",
)
