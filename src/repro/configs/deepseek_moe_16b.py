"""deepseek-moe-16b [arXiv:2401.06066; hf] — fine-grained MoE: 2 shared +
64 routed experts top-6; layer 0 dense.  28L d_model=2048 16H (kv=16)
expert d_ff=1408 vocab=102400.  Full attention => long_500k SKIPPED."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,  # dense layer-0 FFN (per the HF reference config)
    vocab=102400,
    n_experts=64,
    top_k=6,
    moe_d_ff=1408,
    n_shared_experts=2,
    dense_prefix_layers=1,
    mlp_act="swiglu",
)

SMOKE = ModelConfig(
    name="deepseek-moe-smoke",
    family="moe",
    n_layers=3,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=320,
    vocab=512,
    n_experts=8,
    top_k=2,
    moe_d_ff=64,
    n_shared_experts=2,
    dense_prefix_layers=1,
    mlp_act="swiglu",
    dtype="float32",
)
