"""mamba2-2.7b [arXiv:2405.21060; unverified] — pure SSD, attention-free.
64L d_model=2560 (d_inner=5120, 80 heads of 64) vocab=50280 ssm_state=128.
KV-cache compression is inapplicable (no KV) — the SSM state is compressed
with the same quantizer module instead (DESIGN.md §Arch-applicability).
long_500k RUNS (O(1) decode state)."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
)

SMOKE = ModelConfig(
    name="mamba2-smoke",
    family="ssm",
    n_layers=3,
    d_model=128,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=512,
    ssm_state=16,
    ssm_head_dim=32,
    ssm_chunk=16,
    dtype="float32",
)
