"""h2o-danube-1.8b [arXiv:2401.16818; hf] — llama+mistral mix with sliding-
window attention.  24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000.
SWA window 4096 (mistral-style); sub-quadratic => long_500k RUNS."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6912,
    vocab=32000,
    sliding_window=4096,
    mlp_act="swiglu",
)

SMOKE = ModelConfig(
    name="h2o-danube-smoke",
    family="dense",
    n_layers=2,
    d_model=128,
    n_heads=8,
    n_kv_heads=2,
    d_ff=256,
    vocab=512,
    sliding_window=16,
    mlp_act="swiglu",
    dtype="float32",
)
