"""zamba2-7b [arXiv:2411.15242; unverified] — Mamba2 backbone + ONE shared
attention+MLP block applied every 6 SSM layers (weights shared across the 13
applications).  81L d_model=3584 attn 32H (kv=32) d_ff=14336 vocab=32000
ssm_state=64.  SSM/hybrid => long_500k RUNS.
Structural note: the Zamba2 concat-skip into the shared block is simplified
to a standard residual block (DESIGN.md §Arch-applicability)."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    hybrid_attn_every=6,
    mlp_act="swiglu",
)

SMOKE = ModelConfig(
    name="zamba2-smoke",
    family="hybrid",
    n_layers=5,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=256,
    vocab=512,
    ssm_state=16,
    ssm_head_dim=32,
    ssm_chunk=16,
    hybrid_attn_every=2,
    mlp_act="swiglu",
    dtype="float32",
)
