"""whisper-small [arXiv:2212.04356; unverified] — enc-dec; conv/mel frontend
is a STUB (input_specs provides precomputed frame embeddings, enc_seq=1500).
12L enc + 12L dec, d_model=768 12H (kv=12) d_ff=3072 vocab=51865.
GELU + LayerNorm.  Full attention => long_500k SKIPPED; decode shapes
exercise the decoder + cross-KV (structural at 32k per the brief)."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,
    n_enc_layers=12,
    enc_seq=1500,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    mlp_act="gelu",
    norm="layernorm",
)

SMOKE = ModelConfig(
    name="whisper-smoke",
    family="encdec",
    n_layers=2,
    n_enc_layers=2,
    enc_seq=32,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=256,
    vocab=512,
    mlp_act="gelu",
    norm="layernorm",
    dtype="float32",
)
