"""Assigned input-shape cells and their ShapeDtypeStruct specs.

LM transformer shapes are seq_len x global_batch.  ``decode_*`` / ``long_*``
lower ``serve_step`` (one new token with a KV cache of seq_len), NOT
``train_step``.  ``long_500k`` needs sub-quadratic attention — it runs for
SSM / hybrid / SWA archs and is SKIPPED for pure full-attention archs
(recorded per-cell; see DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str  # train | prefill | decode
    seq: int
    batch: int


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}


def cell_skip_reason(cfg: ModelConfig, cell: ShapeCell) -> Optional[str]:
    """None = run; otherwise the documented reason this cell is skipped."""
    if cell.name == "long_500k":
        sub_quadratic = (
            cfg.family in ("ssm", "hybrid") or cfg.sliding_window is not None
        )
        if not sub_quadratic:
            return (
                "pure full-attention arch: O(L^2) attention at 524k is "
                "intentionally unsupported (DESIGN.md §6)"
            )
    return None


def input_specs(cfg: ModelConfig, cell: ShapeCell, dtype=jnp.int32) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    B, S = cell.batch, cell.seq
    tok = lambda shape: jax.ShapeDtypeStruct(shape, jnp.int32)
    emb = lambda shape: jax.ShapeDtypeStruct(shape, cfg.param_dtype)

    if cell.kind == "decode":
        return {"tokens": tok((B, 1))}

    if cfg.family == "encdec":
        specs = {
            "enc_frames": emb((B, cfg.enc_seq, cfg.d_model)),
            "tokens": tok((B, S)),
        }
    elif cfg.family == "vlm":
        specs = {"embeds": emb((B, S, cfg.d_model))}
    else:
        specs = {"tokens": tok((B, S))}
    if cell.kind == "train":
        specs["labels"] = tok((B, S))
    return specs
