"""Lossless-compressor module (paper §3.2 "Lossless Compressor", Appendix A.5).

The module acts as a proxy around state-of-the-art lossless backends; SZ3
integrates ZSTD / GZIP / BLOSC — here we bind the offline-available analogues
(zstandard, zlib, lzma) behind the same two-method interface so new backends
plug in without touching the pipeline driver.
"""
from __future__ import annotations

import abc
import lzma
import warnings
import zlib
from typing import Dict, Type

from .integrity import ContainerError

try:
    import zstandard as _zstd

    _HAVE_ZSTD = True
except Exception:  # pragma: no cover - exercised where zstandard is absent
    _zstd = None
    _HAVE_ZSTD = False

_warned_no_zstd = False


def _warn_no_zstd() -> None:
    global _warned_no_zstd
    if not _warned_no_zstd:
        warnings.warn(
            "zstandard is not installed; the 'zstd' lossless backend falls "
            "back to zlib (containers will record lossless='gzip'). Install "
            "the [test] extra for the full environment.",
            RuntimeWarning,
            stacklevel=3,
        )
        _warned_no_zstd = True


def _bomb(limit: int, name: str) -> ContainerError:
    return ContainerError(
        f"decompression bomb: {name} stream inflates past the "
        f"header-declared {limit} bytes"
    )


def _zlib_bounded(data: bytes, max_out: int) -> bytes:
    """zlib-decompress at most ``max_out`` bytes; never allocates more than
    ``max_out + 1`` regardless of what the stream claims to inflate to."""
    d = zlib.decompressobj()
    out = d.decompress(data, max_out + 1)
    if len(out) > max_out:
        raise _bomb(max_out, "zlib")
    # returned < max_length => zlib consumed all input; out is complete
    return out + d.flush()


def _lzma_bounded(data: bytes, max_out: int) -> bytes:
    d = lzma.LZMADecompressor()
    out = d.decompress(data, max_out + 1)
    while len(out) <= max_out and not d.eof and not d.needs_input:
        more = d.decompress(b"", max_out + 1 - len(out))
        if not more:
            break
        out += more
    if len(out) > max_out:
        raise _bomb(max_out, "lzma")
    return out


class LosslessBackend(abc.ABC):
    """Paper Appendix A.5: compress(bytes)->bytes / decompress(bytes)->bytes."""

    name: str = "abstract"

    @abc.abstractmethod
    def compress(self, data: bytes) -> bytes: ...

    @abc.abstractmethod
    def decompress(self, data: bytes) -> bytes: ...

    def decompress_bounded(self, data: bytes, max_out: int) -> bytes:
        """Decompress with a hard output ceiling: raise
        :class:`~repro.core.integrity.ContainerError` instead of allocating
        more than ``max_out`` bytes when a (corrupt or hostile) stream
        inflates past the size its container header declared.  Backends
        override this with a streaming-bounded path; the fallback decompresses
        eagerly and only then checks — safe for trusted in-memory use, not a
        bomb guard."""
        out = self.decompress(data)
        if len(out) > max_out:
            raise _bomb(max_out, self.name)
        return out


class Passthrough(LosslessBackend):
    """Module bypass (paper §1: "speed-ratio tradeoffs (module bypass)")."""

    name = "none"

    def compress(self, data: bytes) -> bytes:
        return bytes(data)

    def decompress(self, data: bytes) -> bytes:
        return bytes(data)


class Zstd(LosslessBackend):
    """zstd when available; degrades to zlib (with a one-time warning) so
    environments without ``zstandard`` still import, compress, and round-trip.
    The instance reports ``name='gzip'`` in fallback mode, keeping containers
    self-describing: blobs written by a fallback instance decode anywhere."""

    name = "zstd"

    def __init__(self, level: int = 3):
        self.level = level
        if _HAVE_ZSTD:
            self._c = _zstd.ZstdCompressor(level=level)
            self._d = _zstd.ZstdDecompressor()
        else:
            _warn_no_zstd()
            self.name = "gzip"  # shadow the class attr: spec stays truthful
            self._c = self._d = None

    def compress(self, data: bytes) -> bytes:
        if self._c is None:
            return zlib.compress(data, min(9, max(1, self.level)))
        return self._c.compress(data)

    def decompress(self, data: bytes) -> bytes:
        if self._d is None:
            try:
                return zlib.decompress(data)
            except zlib.error as e:
                raise RuntimeError(
                    "cannot decompress this blob: it was written with zstd "
                    "but zstandard is not installed in this environment"
                ) from e
        return self._d.decompress(data)

    def decompress_bounded(self, data: bytes, max_out: int) -> bytes:
        if self._d is None:
            return _zlib_bounded(data, max_out)
        try:
            return self._d.decompress(data, max_output_size=max_out)
        except _zstd.ZstdError as e:
            if "output" in str(e).lower():
                raise _bomb(max_out, "zstd") from e
            raise


class Gzip(LosslessBackend):
    name = "gzip"

    def __init__(self, level: int = 6):
        self.level = level

    def compress(self, data: bytes) -> bytes:
        return zlib.compress(data, self.level)

    def decompress(self, data: bytes) -> bytes:
        return zlib.decompress(data)

    def decompress_bounded(self, data: bytes, max_out: int) -> bytes:
        return _zlib_bounded(data, max_out)


class Lzma(LosslessBackend):
    name = "lzma"

    def __init__(self, preset: int = 1):
        self.preset = preset

    def compress(self, data: bytes) -> bytes:
        return lzma.compress(data, preset=self.preset)

    def decompress(self, data: bytes) -> bytes:
        return lzma.decompress(data)

    def decompress_bounded(self, data: bytes, max_out: int) -> bytes:
        return _lzma_bounded(data, max_out)


_REGISTRY: Dict[str, Type[LosslessBackend]] = {
    "none": Passthrough,
    "zstd": Zstd,
    "gzip": Gzip,
    "lzma": Lzma,
}


def register(name: str, cls: Type[LosslessBackend]) -> None:
    """Extension point: integrate a new lossless routine (paper §3.2)."""
    _REGISTRY[name] = cls


def make(name: str, **kw) -> LosslessBackend:
    if name not in _REGISTRY:
        raise KeyError(f"unknown lossless backend {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name](**kw)


def effective_backend(name: str = "zstd") -> str:
    """The backend ``make(name)`` will ACTUALLY bind in this process.

    ``Zstd`` degrades to zlib when ``zstandard`` is missing (one warning per
    process); benchmarks record this so throughput rows are attributable to
    the real codec, not the requested one.
    """
    return "gzip" if (name == "zstd" and not _HAVE_ZSTD) else name
