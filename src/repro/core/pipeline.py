"""Compression-pipeline composition (paper §3.3, Algorithm 1).

A compressor is a 5-tuple of module instances.  The driver below is the
paper's Algorithm 1, array-vectorized: it never names a concrete module —
composition is data ("spec"), mirroring SZ3's compile-time template
polymorphism with trace/construction-time polymorphism (DESIGN.md §4.1).

The container format is self-describing: the header records the module spec,
so ``decompress(blob)`` rebuilds the exact pipeline.  Named factory pipelines:

  sz3_lr          — composite(Lorenzo+regression) + linear quant + Huffman + zstd   (= SZ2 [8])
  sz3_interp      — interpolation + linear quant + Huffman + zstd                   ([17])
  sz3_truncation  — byte truncation, all other stages bypassed
  sz3_pastri      — pattern + UNPRED-AWARE quant + Huffman + zstd                   (paper §4)
  sz_pastri       — pattern + linear quant + fixed Huffman (no lossless)            (baseline [19])
  sz3_aps         — error-bound-adaptive APS pipeline                               (paper §5)
  sz3_lorenzo     — pure dual-quant Lorenzo (TPU-native fast path)
  sz3_chunked     — streaming chunked engine, per-chunk pipeline selection
                    (registered by chunking.py; emits the v2 container)
  sz3_transform   — blockwise decorrelating transform + exponent-aligned
                    bitplane coding (registered by transform.py; v3 header)
  sz3_auto        — chunked engine whose candidate set spans BOTH coder
                    families (prediction + transform; transform.py)
  sz3_pwr         — first-class pointwise-relative engine: log-composed
                    chunk pipelines, v4 container (chunking.py)
  sz3_quality     — closed-loop quality-targeted rate controller
                    (target PSNR / ratio / bitrate; quality.py)
  sz3_hybrid      — block-level multi-predictor hybrid engine: per-block
                    zero/Lorenzo-1/Lorenzo-2/regression contest feeding one
                    shared entropy stream (blockwise.py; v5 container)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import msgpack
import numpy as np

from . import encoders as enc_mod
from . import integrity
from . import lossless as ll_mod
from . import telemetry as tel
from . import predictors as pred_mod
from . import preprocess as pre_mod
from . import quantizers as quant_mod
from .config import CompressionConfig, ErrorBoundMode
from .integrity import (
    ContainerError,
    IntegrityError,
    SalvageReport,
    decode_errors,
    guard_alloc,
    guard_count,
    guard_shape,
)

_MAGIC = b"SZ3J"
_VERSION = 1

#: accepted values for the ``verify=`` policy on the decode entry points
VERIFY_MODES = ("strict", "salvage", "off")


def _finite_stats(data: np.ndarray) -> Tuple[float, float]:
    """(value range, abs max) over FINITE elements — a stray nan/inf must
    not blow a REL bound up to nan for every other point.  Cheap common
    path: one min/max pass; the masked pass only runs when needed."""
    if not data.size:
        return 0.0, 0.0
    mn, mx = float(data.min()), float(data.max())
    if not (np.isfinite(mn) and np.isfinite(mx)):
        fin = data[np.isfinite(data)]
        if not fin.size:
            return 0.0, 0.0
        mn, mx = float(fin.min()), float(fin.max())
    return mx - mn, max(abs(mn), abs(mx))


def _clean_meta(meta: Dict[str, Any]) -> Dict[str, Any]:
    """Coerce numpy scalars so msgpack accepts the header."""
    out = {}
    for k, v in meta.items():
        if isinstance(v, (np.integer,)):
            out[k] = int(v)
        elif isinstance(v, (np.floating,)):
            out[k] = float(v)
        elif isinstance(v, np.ndarray):
            out[k] = v.tolist()
        else:
            out[k] = v
    return out


def pack_container(
    header: Dict[str, Any],
    body: bytes,
    chunk_bounds: Optional[Any] = None,
) -> bytes:
    """The container wire format: magic + int64 (header, body) lengths +
    msgpack header + body + integrity trailer.  Single authority — every
    writer (v1 pipelines, truncation, v2 chunked, transform, hybrid, fast)
    must frame through here so readers stay compatible.

    The trailer (see :mod:`.integrity`) sits BEYOND the declared body length,
    so readers that honour the declared lengths skip it: old readers decode
    new blobs, and pre-trailer blobs keep decoding here.  ``chunk_bounds``
    lists body-relative ``(off, len)`` of independently decodable chunks for
    per-chunk checksums (multi-chunk writers pass their chunk table); None
    checksums the whole body as one chunk.  The header gains an ``itg`` flag
    under the header checksum so strict verification can detect a stripped
    trailer.  ``integrity.trailers_disabled()`` suppresses both (overhead
    benchmarking, legacy-fixture generation)."""
    if integrity.WRITE_TRAILERS:
        header = dict(header)
        header["itg"] = 1
    hbytes = msgpack.packb(header, use_bin_type=True)
    head = _MAGIC + np.asarray([len(hbytes), len(body)], np.int64).tobytes() + hbytes
    if not integrity.WRITE_TRAILERS:
        return head + body
    with tel.span("integrity", bytes=len(body)):
        trailer = integrity.build_trailer(head, body, chunk_bounds)
    return head + body + trailer


def container_body(blob: bytes, body_off: int) -> bytes:
    """The body slice DECLARED by the prologue — never the raw tail, which
    may carry the integrity trailer (or attacker-appended bytes)."""
    blen = int.from_bytes(blob[12:20], "little", signed=True)
    return blob[body_off : body_off + blen]


@dataclasses.dataclass
class CompressionResult:
    blob: bytes
    ratio: float
    codes: Optional[np.ndarray] = None  # quantization integers (paper Fig 3)
    meta: Optional[Dict[str, Any]] = None


class SZ3Compressor:
    """The general compressor of paper Algorithm 1."""

    kind = "sz3"

    def __init__(
        self,
        preprocessor: pre_mod.Preprocessor = None,
        predictor: pred_mod.Predictor = None,
        quantizer: quant_mod.QuantizerBase = None,
        encoder: enc_mod.Encoder = None,
        lossless: ll_mod.LosslessBackend = None,
        conf: CompressionConfig = None,
    ):
        self.preprocessor = preprocessor or pre_mod.Identity()
        self.predictor = predictor or pred_mod.LorenzoPredictor()
        self.quantizer = quantizer or quant_mod.LinearScaleQuantizer()
        self.encoder = encoder or enc_mod.HuffmanEncoder()
        self.lossless = lossless or ll_mod.Zstd()
        self.conf = conf or CompressionConfig()

    # -- spec (for the self-describing container) ---------------------------
    def spec(self) -> Dict[str, Any]:
        return {
            "kind": self.kind,
            "preprocessor": self.preprocessor.name,
            "predictor": self.predictor.name,
            "quantizer": self.quantizer.name,
            "quant_radius": self.quantizer.radius,
            "encoder": self.encoder.name,
            "lossless": self.lossless.name,
        }

    @staticmethod
    def from_spec(spec: Dict[str, Any]) -> "SZ3Compressor":
        return SZ3Compressor(
            preprocessor=pre_mod.make(spec["preprocessor"]),
            predictor=pred_mod.make(spec["predictor"]),
            quantizer=quant_mod.make(spec["quantizer"], radius=spec["quant_radius"]),
            encoder=enc_mod.make(spec["encoder"]),
            lossless=ll_mod.make(spec["lossless"]),
        )

    # -- Algorithm 1 ---------------------------------------------------------
    def compress(
        self, data: np.ndarray, conf: CompressionConfig = None, with_stats: bool = False
    ) -> CompressionResult:
        conf = conf or self.conf
        data = np.asarray(data)
        if data.dtype not in (np.float32, np.float64):
            data = data.astype(np.float32)
        pdata, conf2, pre_meta = self.preprocessor.forward(data, conf)  # line 1
        rng, absmax = _finite_stats(pdata)
        abs_eb = conf2.resolve_abs_eb(rng, absmax)
        if abs_eb <= 0:
            abs_eb = np.finfo(np.float64).tiny
        self.quantizer.begin(abs_eb, pdata.dtype)
        with tel.span("predict", bytes=pdata.nbytes):  # predict+quantize fused
            codes, pred_meta = self.predictor.compress(pdata, self.quantizer, conf2)  # 2-5
        with tel.span("huffman", bytes=codes.nbytes):
            enc_bytes = self.encoder.encode(codes)  # lines 9-10
        q_bytes = self.quantizer.save()  # line 8
        header = {
            "v": _VERSION,
            "spec": self.spec(),
            "shape": list(data.shape),
            "pshape": list(pdata.shape),
            "dtype": data.dtype.str,
            "pdtype": pdata.dtype.str,
            "mode": conf.mode.value,
            "eb": float(conf.eb),
            "abs_eb": float(abs_eb),
            "block_size": int(conf2.block_size),
            **(
                {"eb_rel": float(conf.eb_rel)}
                if conf.eb_rel is not None
                else {}
            ),
            "interp_kind": conf2.interp_kind,
            "lorenzo_order": int(conf2.lorenzo_order),
            "n_codes": int(codes.size),
            "enc_len": len(enc_bytes),
            "q_len": len(q_bytes),
            "pre_meta": _clean_meta(pre_meta),
            "pred_meta": _clean_meta(pred_meta),
        }
        with tel.span("lossless", bytes=len(enc_bytes) + len(q_bytes)):
            body = self.lossless.compress(enc_bytes + q_bytes)  # line 11
        blob = pack_container(header, body)
        ratio = data.nbytes / max(1, len(blob))
        return CompressionResult(
            blob=blob,
            ratio=ratio,
            codes=codes if with_stats else None,
            meta=pred_meta if with_stats else None,
        )


def parse_header(blob: bytes) -> Tuple[Dict[str, Any], int]:
    """Parse the container prologue; rejects truncated/corrupt blobs with
    :class:`~repro.core.integrity.ContainerError` (a ``ValueError``) instead
    of surfacing numpy index errors from the body.  Every length field is
    bounded by the actual buffer BEFORE any slice or allocation, so a hostile
    prologue cannot direct reads outside the blob or declare absurd sizes."""
    if len(blob) < 20:
        raise ContainerError(
            f"truncated SZ3J container: {len(blob)} bytes, need at least 20"
        )
    if blob[:4] != _MAGIC:
        raise ContainerError("not an SZ3J container")
    lens = np.frombuffer(blob, np.int64, count=2, offset=4)
    hlen, blen = int(lens[0]), int(lens[1])
    if hlen < 0 or blen < 0 or 20 + hlen + blen > len(blob):
        raise ContainerError(
            f"corrupt SZ3J container: header={hlen} body={blen} bytes do not "
            f"fit the {len(blob)}-byte buffer"
        )
    try:
        header = msgpack.unpackb(blob[20 : 20 + hlen], raw=False)
    except Exception as e:
        raise ContainerError(f"corrupt SZ3J container header: {e}") from e
    if not isinstance(header, dict):
        raise ContainerError("corrupt SZ3J container header: not a map")
    return header, 20 + hlen


def decompress(
    blob: bytes, workers: Optional[int] = None, verify: str = "strict"
):
    """Self-describing decompression — rebuilds the pipeline from the header.

    Handles every container generation: v1 single-pipeline blobs, v2
    multi-chunk blobs (per-chunk spec + offsets; see chunking.py), v3
    blockwise-transform blobs, v4 pointwise-relative multi-chunk blobs
    (kind "pwr": chunk blobs carry log-domain side channels in their
    pre_meta), v5 block-hybrid blobs (kind "hybrid": per-block predictor
    tags + coefficient side channels; see blockwise.py), and v6 fast-tier
    blobs (kind "fast": fixed-length truncated-bitplane blocks; see
    fastmode.py).
    ``workers`` parallelizes multi-chunk decode (ignored for
    single-pipeline blobs).

    ``verify`` is the integrity policy (see :mod:`.integrity`):

    * ``"strict"`` (default) — verify the trailer's checksums before decode;
      raise :class:`IntegrityError` naming the first damaged chunk.  Blobs
      written before the trailer era carry no checksums and pass unverified.
    * ``"salvage"`` — decode every intact chunk, fill damaged ones with
      zeros, and return ``(data, SalvageReport)`` instead of the bare array.
    * ``"off"`` — skip checksum verification (malformed-structure errors
      still raise).

    Every malformed-input failure raises a ``ValueError`` subclass
    (:class:`ContainerError` / :class:`IntegrityError`) — never a raw
    ``struct.error`` / ``KeyError`` / ``IndexError`` from the internals.
    """
    if verify not in VERIFY_MODES:
        raise ValueError(f"verify must be one of {VERIFY_MODES}, got {verify!r}")
    blob = bytes(blob)
    with decode_errors("container"):
        header, body_off = parse_header(blob)
        if verify == "salvage":
            return _decompress_salvage(blob, header, body_off, workers)
        if verify == "strict":
            try:
                with tel.span("integrity", bytes=len(blob)):
                    integrity.verify_container(blob, header, body_off)
            except IntegrityError:
                # one counter in the global serving registry, one in the
                # active trace (if any) — failures stay visible either way
                tel.metric_count("sz3_verify_failures_total")
                tel.count("verify_failures")
                raise
        return _decompress_dispatch(blob, header, body_off, workers, verify)


def _decompress_dispatch(
    blob: bytes,
    header: Dict[str, Any],
    body_off: int,
    workers: Optional[int],
    verify: str,
) -> np.ndarray:
    """Route a parsed container to its generation's decoder (checksum policy
    already applied by the caller; ``verify`` propagates to nested chunk
    blobs so a chunked decode verifies — or skips — uniformly)."""
    if header.get("v", _VERSION) >= 2 and header.get("kind") in ("chunked", "pwr"):
        from .chunking import decompress_chunked  # local: avoids import cycle

        return decompress_chunked(
            blob, header, body_off, workers=workers, verify=verify
        )
    spec = header["spec"]
    if not isinstance(spec, dict):
        raise ContainerError("corrupt container: spec is not a map")
    kind = spec.get("kind")
    if kind == "truncation":
        return TruncationCompressor._decompress_body(blob, header, body_off)
    if kind == "transform":  # v3 blockwise-transform containers
        from .transform import TransformCompressor  # local: avoids import cycle

        return TransformCompressor._decompress_body(blob, header, body_off)
    if kind == "hybrid":  # v5 block-level multi-predictor containers
        from .blockwise import BlockHybridCompressor  # local: avoids import cycle

        return BlockHybridCompressor._decompress_body(blob, header, body_off)
    if kind == "fast":  # v6 SZx-style fixed-length containers
        from .fastmode import FastModeCompressor  # local: avoids import cycle

        return FastModeCompressor._decompress_body(blob, header, body_off)
    return _decompress_v1(blob, header, body_off)


def _decompress_v1(
    blob: bytes, header: Dict[str, Any], body_off: int
) -> np.ndarray:
    """The v1 single-pipeline decode path, with every header-declared size
    bounded before allocation (hostile length fields cannot trigger
    decompression bombs or absurd numpy allocations)."""
    spec = header["spec"]
    comp = SZ3Compressor.from_spec(spec)
    dtype = np.dtype(header["dtype"])
    pdtype = np.dtype(header["pdtype"])
    shape = guard_shape(header["shape"], dtype.itemsize, "shape")
    pshape = guard_shape(header["pshape"], pdtype.itemsize, "pshape")
    enc_len = guard_alloc(header["enc_len"], "enc_len")
    q_len = guard_alloc(header["q_len"], "q_len")
    plain_len = guard_alloc(enc_len + q_len, "enc_len+q_len")
    with tel.span("lossless", bytes=plain_len):
        body = comp.lossless.decompress_bounded(
            container_body(blob, body_off), plain_len
        )
    if len(body) != plain_len:
        raise ContainerError(
            f"v1 body decompressed to {len(body)} bytes; header declares "
            f"{plain_len} (enc_len={enc_len} + q_len={q_len})"
        )
    enc_bytes = body[:enc_len]
    q_bytes = body[enc_len:]
    n_elems = int(np.prod(pshape, dtype=np.int64)) if pshape else 1
    n_codes = guard_count(
        header["n_codes"], 2 * n_elems + 4096, "n_codes"
    )
    comp.quantizer.begin(header["abs_eb"], pdtype)
    comp.quantizer.load(q_bytes)
    with tel.span("huffman", bytes=len(enc_bytes)):
        codes = comp.encoder.decode(enc_bytes, n_codes)
    conf = CompressionConfig(
        mode=ErrorBoundMode(header["mode"]),
        eb=header["eb"],
        block_size=header["block_size"],
        interp_kind=header["interp_kind"],
        lorenzo_order=header["lorenzo_order"],
        quant_radius=spec["quant_radius"],
    )
    with tel.span("predict", bytes=n_elems * pdtype.itemsize):
        pdata = comp.predictor.decompress(
            np.asarray(codes),
            pshape,
            pdtype,
            comp.quantizer,
            conf,
            header["pred_meta"],
        )
    data = comp.preprocessor.inverse(pdata, conf, header["pre_meta"])
    return data.astype(dtype).reshape(shape)


def _decompress_salvage(
    blob: bytes, header: Dict[str, Any], body_off: int, workers: Optional[int]
):
    """``verify="salvage"``: recover what the damage spares.

    Multi-chunk containers (v2 "chunked" / v4 "pwr") localize loss to the
    chunk level: every chunk whose checksum passes — or, without a trailer,
    whose decode succeeds — is recovered byte-exact; damaged chunks are
    zero-filled and named in the report.  Single-body generations
    (v1/v3/v5/v6) are all-or-nothing: one entropy stream, so a failed decode
    loses the whole array (zero-filled, one damage record).  A damaged
    HEADER is not salvageable — shape/dtype/chunk table are untrustworthy —
    and raises :class:`IntegrityError`.
    """
    res = integrity.inspect(blob, header, body_off)
    if res.has_trailer and not res.header_ok:
        raise IntegrityError(
            "container header bytes fail their checksum — shape, dtype and "
            "chunk table are untrustworthy, nothing can be salvaged",
            region="header",
        )
    if header.get("v", _VERSION) >= 2 and header.get("kind") in ("chunked", "pwr"):
        from .chunking import salvage_chunked  # local: avoids import cycle

        return salvage_chunked(
            blob, header, body_off, workers=workers, inspect_result=res
        )
    dtype = np.dtype(header["dtype"])
    shape = guard_shape(header["shape"], dtype.itemsize, "shape")
    n = int(np.prod(shape, dtype=np.int64)) if shape else 1
    report = SalvageReport(total_chunks=1, checksummed=res.has_trailer)
    reason = None
    if res.has_trailer and not res.whole_ok:
        reason = "checksum"
    else:
        try:
            with decode_errors("container"):
                data = _decompress_dispatch(blob, header, body_off, workers, "off")
            report.recovered.append(0)
            return data, report
        except ValueError:
            reason = "decode-error"
    report.damage.append(integrity.ChunkDamage(0, 0, n, reason))
    return np.zeros(shape, dtype), report


class TruncationCompressor:
    """SZ3-Truncation (paper §6.2): keep the k most-significant bytes of each
    value, bypass every other stage.  ~1 GB/s-class throughput in the paper;
    unbounded absolute error (bounded relative error per exponent)."""

    kind = "truncation"

    def __init__(self, keep_bytes: int = 2, lossless: str = "none"):
        self.keep_bytes = keep_bytes
        self.lossless = ll_mod.make(lossless)

    def compress(self, data, conf=None, with_stats=False) -> CompressionResult:
        data = np.asarray(data)
        itemsize = data.dtype.itemsize
        k = min(self.keep_bytes, itemsize)
        # big-endian view so byte 0 is the most significant
        be = data.astype(data.dtype.newbyteorder(">"))
        raw = be.view(np.uint8).reshape(-1, itemsize)
        kept = np.ascontiguousarray(raw[:, :k]).tobytes()
        body = self.lossless.compress(kept)
        header = {
            "v": _VERSION,
            "spec": {"kind": "truncation", "k": k, "lossless": self.lossless.name},
            "shape": list(data.shape),
            "dtype": data.dtype.str,
        }
        blob = pack_container(header, body)
        return CompressionResult(blob=blob, ratio=data.nbytes / max(1, len(blob)))

    @staticmethod
    def _decompress_body(blob, header, body_off):
        spec = header["spec"]
        dt = np.dtype(header["dtype"])
        k = guard_count(spec["k"], dt.itemsize, "truncation keep_bytes")
        if k < 1:
            raise ContainerError("corrupt container: truncation keep_bytes < 1")
        shape = guard_shape(header["shape"], dt.itemsize, "shape")
        n = int(np.prod(shape, dtype=np.int64)) if shape else 1
        kept = ll_mod.make(spec["lossless"]).decompress_bounded(
            container_body(blob, body_off), n * k
        )
        if len(kept) != n * k:
            raise ContainerError(
                f"truncation body holds {len(kept)} bytes; header declares "
                f"{n}x{k}"
            )
        raw = np.zeros((n, dt.itemsize), np.uint8)
        raw[:, :k] = np.frombuffer(kept, np.uint8).reshape(n, k)
        be = raw.reshape(-1).view(dt.newbyteorder(">"))
        return be.astype(dt).reshape(shape)


class AdaptiveAPSCompressor:
    """The APS adaptive pipeline (paper §5.2, Fig 5).

    error bound >= threshold : 3-D multialgorithm (Lorenzo+regression) pipeline
    error bound <  threshold : transpose so time is innermost, 1-D Lorenzo,
                               unpred-aware quantizer with the restricted bin
                               (eb clamped to 0.5 => exact for integer counts),
                               fixed Huffman, zstd.
    """

    kind = "aps"

    def __init__(self, threshold: float = 0.5, time_axis: int = 0):
        self.threshold = threshold
        self.time_axis = time_axis

    def _low_pipeline(self, ndim: int) -> SZ3Compressor:
        perm = tuple(i for i in range(ndim) if i != self.time_axis) + (self.time_axis,)
        return SZ3Compressor(
            preprocessor=pre_mod.Transpose(perm=perm, flatten=True),
            predictor=pred_mod.LorenzoPredictor(order=1),
            quantizer=quant_mod.UnpredAwareQuantizer(),
            encoder=enc_mod.FixedHuffmanEncoder(),
            lossless=ll_mod.Zstd(),
        )

    def _high_pipeline(self) -> SZ3Compressor:
        return SZ3Compressor(
            predictor=pred_mod.CompositePredictor(),
            quantizer=quant_mod.LinearScaleQuantizer(),
            encoder=enc_mod.HuffmanEncoder(),
            lossless=ll_mod.Zstd(),
        )

    def compress(self, data, conf: CompressionConfig = None, with_stats=False):
        conf = conf or CompressionConfig()
        data = np.asarray(data)
        rng, absmax = _finite_stats(data)
        abs_eb = conf.resolve_abs_eb(rng, absmax)
        if abs_eb < self.threshold:
            # restricted quantization bin: integer-valued data becomes
            # lossless (paper: "SZ3-APS turns out to be lossless in this case")
            is_integral = bool(np.all(np.rint(data) == data))
            eff = conf.replace(
                mode=ErrorBoundMode.ABS, eb=0.5 if is_integral else abs_eb
            )
            return self._low_pipeline(data.ndim).compress(data, eff, with_stats)
        eff = conf.replace(mode=ErrorBoundMode.ABS, eb=abs_eb)
        return self._high_pipeline().compress(data, eff, with_stats)


# ---------------------------------------------------------------------------
# named pipeline factories (paper §6.2 + §4 + §5)
# ---------------------------------------------------------------------------

def sz3_lr(**kw) -> SZ3Compressor:
    return SZ3Compressor(
        predictor=pred_mod.CompositePredictor(),
        quantizer=quant_mod.LinearScaleQuantizer(),
        encoder=enc_mod.HuffmanEncoder(),
        lossless=ll_mod.Zstd(),
        **kw,
    )


def sz3_interp(kind: str = "cubic", **kw) -> SZ3Compressor:
    return SZ3Compressor(
        predictor=pred_mod.InterpolationPredictor(kind=kind),
        quantizer=quant_mod.LinearScaleQuantizer(),
        encoder=enc_mod.HuffmanEncoder(),
        lossless=ll_mod.Zstd(),
        **kw,
    )


def sz3_lorenzo(order: int = 1, **kw) -> SZ3Compressor:
    return SZ3Compressor(
        predictor=pred_mod.LorenzoPredictor(order=order),
        quantizer=quant_mod.LinearScaleQuantizer(),
        encoder=enc_mod.HuffmanEncoder(),
        lossless=ll_mod.Zstd(),
        **kw,
    )


def sz3_truncation(keep_bytes: int = 2) -> TruncationCompressor:
    return TruncationCompressor(keep_bytes=keep_bytes)


def sz_pastri(pattern_size: int = None) -> SZ3Compressor:
    """Baseline SZ-Pastri [19]: linear quantizer (raw unpredictables), fixed
    Huffman, NO lossless stage."""
    return SZ3Compressor(
        predictor=pred_mod.PatternPredictor(pattern_size=pattern_size),
        quantizer=quant_mod.LinearScaleQuantizer(),
        encoder=enc_mod.FixedHuffmanEncoder(),
        lossless=ll_mod.Passthrough(),
    )


def sz_pastri_zstd(pattern_size: int = None) -> SZ3Compressor:
    """SZ-Pastri-with-zstd (paper Table 1 middle rows)."""
    return SZ3Compressor(
        predictor=pred_mod.PatternPredictor(pattern_size=pattern_size),
        quantizer=quant_mod.LinearScaleQuantizer(),
        encoder=enc_mod.FixedHuffmanEncoder(),
        lossless=ll_mod.Zstd(),
    )


def sz3_pastri(pattern_size: int = None) -> SZ3Compressor:
    """SZ3-Pastri (paper §4.2): unpred-aware quantizer + lossless stage."""
    return SZ3Compressor(
        predictor=pred_mod.PatternPredictor(pattern_size=pattern_size),
        quantizer=quant_mod.UnpredAwareQuantizer(),
        encoder=enc_mod.HuffmanEncoder(),
        lossless=ll_mod.Zstd(),
    )


def sz3_aps(threshold: float = 0.5, time_axis: int = 0) -> AdaptiveAPSCompressor:
    return AdaptiveAPSCompressor(threshold=threshold, time_axis=time_axis)


PIPELINES = {
    "sz3_lr": sz3_lr,
    "sz3_interp": sz3_interp,
    "sz3_lorenzo": sz3_lorenzo,
    "sz3_truncation": sz3_truncation,
    "sz_pastri": sz_pastri,
    "sz_pastri_zstd": sz_pastri_zstd,
    "sz3_pastri": sz3_pastri,
    "sz3_aps": sz3_aps,
}
