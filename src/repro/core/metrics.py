"""Compression quality / rate metrics used throughout the paper's evaluation.

Bit-rate, compression ratio, PSNR, NRMSE, max error — matching the paper's
definitions (§4.3: ``bitrate = bits / cr``; PSNR w.r.t. value range).
"""
from __future__ import annotations

from typing import Union

import numpy as np

Array = np.ndarray


def _np(x) -> Array:
    return np.asarray(x)


def compression_ratio(original_nbytes: int, compressed_nbytes: int) -> float:
    if compressed_nbytes <= 0:
        return float("inf")
    return original_nbytes / compressed_nbytes


def bit_rate(original: Union[Array, int], compressed_nbytes: int) -> float:
    """Bits per value after compression.

    The paper (§4.3) defines bit-rate as ``itemsize * 8 / cr`` with
    ``cr = original_nbytes / compressed_nbytes``; since ``original_nbytes =
    n * itemsize`` this reduces to ``compressed_nbytes * 8 / n`` — the
    itemsize cancels, so only the element count matters.  ``original`` is
    either the array itself or its element count.
    """
    if isinstance(original, (int, np.integer)):
        n = int(original)
    else:
        n = _np(original).size
    if n == 0:
        return 0.0
    return compressed_nbytes * 8.0 / n


def max_abs_error(original, decompressed) -> float:
    a, b = _np(original), _np(decompressed)
    if a.size == 0:
        return 0.0
    return float(np.max(np.abs(a.astype(np.float64) - b.astype(np.float64))))


def max_pw_rel_error(original, decompressed, eps: float = 0.0) -> float:
    a, b = _np(original).astype(np.float64), _np(decompressed).astype(np.float64)
    denom = np.abs(a)
    mask = denom > eps
    if not mask.any():
        return 0.0
    return float(np.max(np.abs(a[mask] - b[mask]) / denom[mask]))


def mse(original, decompressed) -> float:
    a, b = _np(original).astype(np.float64), _np(decompressed).astype(np.float64)
    if a.size == 0:
        return 0.0  # vacuous: no points, no error (np.mean would warn + nan)
    return float(np.mean((a - b) ** 2))


def psnr(original, decompressed) -> float:
    """Peak signal-to-noise ratio w.r.t. the data value range (SZ convention).

    Degenerate inputs stay warning-free and nan-free: empty or exactly
    reconstructed data is ``inf``; a constant array (value range 0) that is
    NOT exactly reconstructed has no meaningful range-referenced PSNR, so the
    error power alone is reported (``-10 log10(mse)``), still finite.
    """
    a = _np(original).astype(np.float64)
    if a.size == 0:
        return float("inf")
    m = mse(original, decompressed)
    if m == 0:
        return float("inf")
    rng = float(a.max() - a.min())
    if rng == 0:
        return -10.0 * float(np.log10(m))
    return 20.0 * float(np.log10(rng)) - 10.0 * float(np.log10(m))


def nrmse(original, decompressed) -> float:
    """Range-normalized RMSE; 0.0 for empty or constant-and-exact inputs
    (the range-0 normalization would otherwise emit a divide warning + nan)."""
    a = _np(original).astype(np.float64)
    if a.size == 0:
        return 0.0
    m = mse(original, decompressed)
    rng = float(a.max() - a.min())
    if rng == 0:
        return 0.0 if m == 0 else float("inf")
    return float(np.sqrt(m) / rng)


def value_range(x) -> float:
    a = _np(x)
    if a.size == 0:
        return 0.0
    return float(a.max() - a.min())
