"""Streaming chunked compression with per-chunk adaptive pipeline selection.

The paper composes ONE pipeline for a whole array (§3.3).  This module lifts
the composite predictor's estimate-and-pick contest (§3.2) one level up: the
array is split into fixed-byte-budget chunks along the leading axis, and for
EACH chunk the best-fit *pipeline spec* is chosen by the paper's sampled
error-estimation criterion — a contiguous sample of the chunk is scored by
every candidate's ``Predictor.estimate_error`` (falling back to trial
compression of the sample when a candidate has no cheap estimator, e.g. the
Pastri pattern pipeline).  This is the chunk-granular analogue of Tao et
al.'s automatic SZ/ZFP selection (arXiv:1806.08901) and the substrate for
sharded / async execution.

Two I/O shapes:

  * one-shot — ``ChunkedCompressor.compress`` returns a self-describing v2
    container: the header records per-chunk (pipeline, offset, length) and
    the body concatenates ordinary v1 blobs, so every chunk is independently
    decodable (random access) and v1 blobs keep decoding unchanged.
  * streaming — ``compress_stream`` / ``decompress_stream`` iterate frames
    (a prologue + one v1 blob per chunk) with bounded memory: at no point is
    more than one chunk of raw data plus its blob resident.  ``frames_to_blob``
    reassembles the exact one-shot container from a frame stream.

Error-bound semantics: REL/PW_REL bounds are resolved to an ABS bound against
the GLOBAL array statistics before chunking (so chunked output honours the
same bound as one-shot compression).  When compressing an unbounded iterator
of slabs the global range is unknown; REL then resolves per-slab, which is
strictly tighter on low-range slabs (documented, still error-bounded).

Parallelism: chunks are independent after the global bound is resolved, so
both select+compress and decompress fan out over a ``ThreadPoolExecutor``
(``workers=`` on every entry point; numpy kernels and zlib/zstd release the
GIL).  Results are reassembled in submission order, so parallel containers
and frame streams are byte-identical to serial ones.
"""
from __future__ import annotations

import collections
import dataclasses
import os
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import TimeoutError as FuturesTimeoutError
from typing import (
    Any,
    Callable,
    Dict,
    Iterable,
    Iterator,
    List,
    Optional,
    Sequence,
    Tuple,
    TypeVar,
    Union,
)

import msgpack
import numpy as np

from . import integrity
from . import pipeline as pl_mod
from . import preprocess as pre_mod
from . import telemetry as tel
from .config import CompressionConfig, ErrorBoundMode
from .integrity import (
    ChunkDamage,
    ContainerError,
    IntegrityError,
    SalvageReport,
    decode_errors,
    guard_count,
    guard_shape,
)
from .pipeline import CompressionResult, pack_container

_STREAM_MAGIC = b"SZ3S"
_VERSION2 = 2
_VERSION4 = 4  # pointwise-relative multi-chunk container (kind "pwr")

#: default contest entrants: the three §6.2 pipelines with distinct strengths
DEFAULT_CANDIDATES: Tuple[str, ...] = ("sz3_lorenzo", "sz3_lr", "sz3_interp")

#: elements drawn from each chunk for candidate scoring
SAMPLE_BUDGET = 4096

#: strided probe blocks per chunk sample: a single centred block sees only
#: the middle regime of piecewise data and mis-ranks candidates for the rest
SAMPLE_PROBES = 3

_T = TypeVar("_T")
_R = TypeVar("_R")


def _parallel_map_ordered(
    fn: Callable[[_T], _R],
    items: Iterable[_T],
    workers: int,
    timeout: Optional[float] = None,
) -> Iterator[_R]:
    """Apply ``fn`` across worker threads, yielding results in input order.

    The per-chunk work (numpy kernels, zlib/zstd) releases the GIL, so
    threads buy real parallelism without pickling chunk arrays the way a
    process pool would.  At most ``2*workers`` tasks are in flight — the
    streaming callers keep their bounded-memory guarantee (one raw chunk is
    a view, but its compressed blob is retained until yielded).  Order is
    deterministic by construction (a result deque, not as-completed), so
    parallel output is byte-identical to serial output.

    ``timeout`` (seconds) bounds the wait for each task's result.  A task
    that blows the budget trips DEGRADED mode: its item — and every item not
    yet submitted — is recomputed serially in the calling thread, queued
    futures are cancelled, and the pool is abandoned without joining (a
    worker thread wedged in a C extension cannot be interrupted; waiting on
    it would turn one slow chunk into a hung pipeline).  Results and their
    order are identical either way because ``fn`` is pure per item — only
    the execution strategy degrades, never the output.
    """
    if workers <= 1:
        for item in items:
            yield fn(item)
        return
    # worker threads start with an empty contextvars context, so an active
    # telemetry trace must be explicitly re-bound inside each task (no-op
    # wrapper-free passthrough when tracing is off)
    fn = tel.propagate(fn)
    # CPU-bound tasks: more threads than cores is pure contention, so the
    # pool is clamped (the in-flight window still honours ``workers``)
    pool_size = max(1, min(workers, os.cpu_count() or workers))
    pool = ThreadPoolExecutor(max_workers=pool_size)
    degraded = False
    pending: "collections.deque" = collections.deque()

    def _drain_one() -> _R:
        nonlocal degraded
        fut, item = pending.popleft()
        try:
            return fut.result(timeout)
        except FuturesTimeoutError:
            degraded = True
            fut.cancel()
            return fn(item)

    try:
        items_iter = iter(items)
        while not degraded:
            try:
                item = next(items_iter)
            except StopIteration:
                break
            pending.append((pool.submit(fn, item), item))
            if len(pending) >= 2 * workers:
                yield _drain_one()
        while pending:
            yield _drain_one()
        for item in items_iter:  # non-empty only in degraded mode
            yield fn(item)
    finally:
        pool.shutdown(wait=not degraded, cancel_futures=degraded)


# ---------------------------------------------------------------------------
# chunk geometry
# ---------------------------------------------------------------------------

def chunk_slices(
    shape: Sequence[int], itemsize: int, chunk_bytes: int
) -> List[slice]:
    """Split the leading axis into slabs of at most ``chunk_bytes`` each.

    Returns slices over axis 0.  Inner axes stay whole so every chunk keeps
    the array's dimensionality (predictors see real N-d neighbourhoods).
    """
    if not shape or int(np.prod(shape)) == 0:
        return [slice(0, shape[0] if shape else 0)]
    row_bytes = int(np.prod(shape[1:], dtype=np.int64)) * itemsize
    rows = max(1, int(chunk_bytes) // max(1, row_bytes))
    n0 = int(shape[0])
    return [slice(i, min(i + rows, n0)) for i in range(0, n0, rows)]


def _sample_block(
    chunk: np.ndarray, budget: int = SAMPLE_BUDGET, probes: int = SAMPLE_PROBES
) -> np.ndarray:
    """2-3 strided contiguous probe blocks with ~budget elements in total.

    Contiguity WITHIN each probe keeps neighbour statistics intact (stencil
    predictors are not penalized relative to fit-based ones), while spreading
    the probes along the chunk's longest axis keeps piecewise-regime chunks
    represented: the old single centred block saw only the middle regime and
    biased selection toward whatever predictor wins there.  The probe seams
    inject one junk stencil row each — ~2 rows out of budget/probes per
    probe, negligible.  Budget unused by short axes is redistributed to the
    long ones (smallest axis first), so skinny chunks like (1, 4M) still
    yield a ~budget-sized sample.  Fully deterministic (no RNG): the same
    chunk always yields the same sample, which is what keeps parallel
    containers byte-identical to serial ones.
    """
    if chunk.size <= budget:
        return chunk
    takes = [1] * chunk.ndim
    rem = budget
    for i, ax in enumerate(np.argsort(chunk.shape)):
        axes_left = chunk.ndim - i
        side = max(1, int(rem ** (1.0 / axes_left) + 1e-9))
        takes[ax] = min(chunk.shape[ax], side)
        rem = max(1, rem // takes[ax])
    axl = int(np.argmax(chunk.shape))
    k = max(1, int(probes))
    per = max(1, takes[axl] // k)
    if k <= 1 or chunk.shape[axl] < k * per + k:
        # probes would overlap — the chunk is barely bigger than the sample
        # along its longest axis, so the centred block already covers it
        sl = tuple(
            slice((dim - t) // 2, (dim - t) // 2 + t)
            for dim, t in zip(chunk.shape, takes)
        )
        return chunk[sl]
    base = [
        slice((dim - t) // 2, (dim - t) // 2 + t)
        for dim, t in zip(chunk.shape, takes)
    ]
    # probe 0 flush with the start, probe k-1 flush with the end, the rest
    # evenly strided between — piecewise regimes at either edge are seen
    step = (chunk.shape[axl] - per) // (k - 1)
    pieces = []
    for i in range(k):
        sl = list(base)
        sl[axl] = slice(i * step, i * step + per)
        pieces.append(chunk[tuple(sl)])
    return np.concatenate(pieces, axis=axl)


# ---------------------------------------------------------------------------
# per-chunk pipeline selection (paper §3.2 estimate_error, lifted to pipelines)
# ---------------------------------------------------------------------------

def _make_pipeline(name: str):
    try:
        factory = pl_mod.PIPELINES[name]
    except KeyError:
        raise KeyError(
            f"unknown pipeline {name!r}; have {sorted(pl_mod.PIPELINES)}"
        ) from None
    return factory()

#: estimate scores within this factor of the best are "too close to call" and
#: go to a trial-compression runoff on the sample
RUNOFF_MARGIN = 1.3

#: nominal compress throughput per pipeline (MB/s, bench box, BENCH_PR5/PR6
#: order of magnitude) — the ``speed_tier="throughput"`` cost model's price
#: list.  Only RATIOS between entries matter, so the table survives machine
#: differences; unknown candidates get the conservative default.
PIPELINE_MBPS = {
    "sz3_fast": 200.0,
    "sz3_lorenzo": 25.0,
    "sz3_transform": 25.0,
    "sz3_chunked": 20.0,
    "sz3_lr": 12.0,
    "sz3_interp": 12.0,
    "sz3_hybrid": 9.0,
}
_MBPS_DEFAULT = 12.0

#: assumed downstream bandwidth (MB/s) the compressed bytes must traverse —
#: the exchange rate between code-bits and compute seconds in throughput
#: mode: total cost/MB = compress time + transfer time of the coded bytes
LINK_MBPS = 100.0

#: below this many estimated bits/element the data is trivially compressible
#: by every close candidate — estimates alone decide, skipping the runoff
TRIVIAL_BITS = 0.05


def _trial_bits(comp, sample: np.ndarray, eff: CompressionConfig) -> float:
    try:
        with tel.suppress_decisions():  # runoff trials are not real outputs
            return 8.0 * len(comp.compress(sample, eff).blob) / max(1, sample.size)
    except Exception:
        return float("inf")


def select_pipeline(
    chunk: np.ndarray,
    abs_eb: float,
    conf: CompressionConfig,
    candidates: Sequence[str] = DEFAULT_CANDIDATES,
    pipelines: Optional[Dict[str, Any]] = None,
    speed_tier: str = "ratio",
) -> Tuple[str, Dict[str, float]]:
    """Pick the candidate pipeline with the lowest estimated cost on a sample.

    Two-stage contest, all scores in estimated bits/element:

      1. every candidate's ``Predictor.estimate_error`` scores the sample
         (paper §3.2 criterion, generalized); candidates scoring beyond
         ``RUNOFF_MARGIN`` x best are eliminated.
      2. if several finalists remain (the estimators' fidelity is ~tens of
         percent, not single digits), the sample itself is trial-compressed
         by each finalist and measured bytes decide.  Skipped when the best
         estimate is under ``TRIVIAL_BITS`` — near-free data makes every
         candidate a "finalist" and the runoff would burn time to pick
         between equivalents.

    Candidates without a cheap estimator (e.g. the Pastri pattern pipeline)
    go straight to the trial stage.  ``pipelines`` lets callers pass
    pre-built instances keyed by name (avoids per-chunk reconstruction).
    Returns (winner, stage-1 scores).

    ``speed_tier="throughput"`` changes the objective from bits/element to
    estimated wall seconds per MB end-to-end: each candidate is priced at
    ``1/PIPELINE_MBPS[name]`` (compute) plus the estimated coded size
    transferred over a ``LINK_MBPS`` downstream link — so a fixed-length
    coder like ``sz3_fast`` wins unless an entropy-coded candidate buys
    enough ratio to pay back its slower pass.  No trial runoff in this mode
    (a trial compression would cost more than it saves at throughput-tier
    priorities); estimator-less candidates are priced at raw size.
    """
    if len(candidates) == 1:
        return candidates[0], {candidates[0]: 0.0}
    if pipelines is None:
        pipelines = {name: _make_pipeline(name) for name in candidates}
    sample = _sample_block(np.asarray(chunk))
    eff = conf.replace(mode=ErrorBoundMode.ABS, eb=abs_eb)
    ests: Dict[str, Optional[float]] = {}
    for name in candidates:
        # pipeline-level estimator first (whole-pipeline coders, e.g. the
        # transform family), else the predictor's (Algorithm-1 pipelines)
        est_fn = getattr(pipelines[name], "estimate_error", None)
        if est_fn is None:
            pred = getattr(pipelines[name], "predictor", None)
            est_fn = pred.estimate_error if pred is not None else None
        ests[name] = est_fn(sample, abs_eb, conf) if est_fn is not None else None
    if speed_tier == "throughput":
        itembits = 8.0 * np.asarray(chunk).dtype.itemsize
        costs = {}
        for name in candidates:
            bits = ests[name] if ests[name] is not None else itembits
            ratio_frac = min(1.0, float(bits) / itembits)  # coded MB per raw MB
            mbps = PIPELINE_MBPS.get(name, _MBPS_DEFAULT)
            costs[name] = 1.0 / mbps + ratio_frac / LINK_MBPS
        winner = min(candidates, key=lambda n: (costs[n], candidates.index(n)))
        return winner, costs
    estimated = {k: float(v) for k, v in ests.items() if v is not None}
    finalists = [k for k, v in ests.items() if v is None]  # no estimator -> runoff
    if estimated:
        best = min(estimated.values())
        if best <= TRIVIAL_BITS and not finalists:
            return min(estimated, key=lambda n: (estimated[n], candidates.index(n))), estimated
        finalists += [
            k for k, v in estimated.items() if v <= best * RUNOFF_MARGIN + 1e-12
        ]
    if len(finalists) == 1:
        return finalists[0], estimated
    runoff = {name: _trial_bits(pipelines[name], sample, eff) for name in finalists}
    winner = min(finalists, key=lambda n: (runoff[n], candidates.index(n)))
    return winner, estimated or runoff


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ChunkRecord:
    """Header entry for one chunk of a v2/v4 container."""

    off: int  # byte offset of the chunk's v1 blob within the body
    length: int
    n0: int  # extent along the chunk axis
    pipeline: str  # winning candidate name (observability; blob self-describes)
    extra: Optional[Dict[str, Any]] = None  # e.g. the quality controller's
    # per-chunk achieved record; readers that predate it ignore the key
    sel: Optional[Dict[str, Any]] = None  # compact selection-decision record
    # (telemetry.sel_header_entry) — present only when a trace was active at
    # compress time, so default-path containers stay byte-identical to the
    # frame-stream reassembly; telemetry.explain() reads it back

    def to_header(self) -> Dict[str, Any]:
        h = {
            "off": int(self.off),
            "len": int(self.length),
            "n0": int(self.n0),
            "pipeline": self.pipeline,
        }
        if self.extra:
            h["q"] = pl_mod._clean_meta(self.extra)
        if self.sel:
            h["sel"] = pl_mod._clean_meta(self.sel)
        return h


class ChunkedCompressor:
    """Fixed-budget chunking + per-chunk adaptive pipeline selection.

    Drives each chunk through the existing Algorithm-1 driver of the winning
    candidate; emits the v2 multi-chunk container (or a frame stream).
    PW_REL configs are honoured natively (log-composed chunk pipelines).
    """

    kind = "chunked"
    container_version = _VERSION2

    def __init__(
        self,
        candidates: Sequence[str] = DEFAULT_CANDIDATES,
        chunk_bytes: int = 1 << 22,
        conf: Optional[CompressionConfig] = None,
        workers: int = 1,
        speed_tier: str = "ratio",
        chunk_timeout: Optional[float] = None,
    ):
        if speed_tier not in ("ratio", "throughput"):
            raise ValueError(f"unknown speed_tier {speed_tier!r}")
        candidates = tuple(candidates)
        if speed_tier == "throughput" and "sz3_fast" not in candidates:
            # the throughput tier prices encode speed, so the fixed-length
            # coder always belongs in the contest — a candidate list that
            # predates the fast tier would otherwise make the knob a no-op
            candidates += ("sz3_fast",)
        self.candidates = candidates
        self.chunk_bytes = int(chunk_bytes)
        self.conf = conf or CompressionConfig()
        self.workers = max(1, int(workers))
        self.speed_tier = speed_tier
        #: seconds each parallel chunk task may take before the engine
        #: degrades to serial compression in the calling thread (None: wait
        #: forever — the pre-timeout behaviour)
        self.chunk_timeout = chunk_timeout

    # -- shared per-chunk path ----------------------------------------------
    def _pwr_candidates(self) -> Tuple[str, ...]:
        """Candidates usable under PW_REL: Algorithm-1 pipelines only (they
        accept a preprocessor slot to compose LogTransform into; whole-
        pipeline coders like the transform family and truncation have no
        log-domain composition and are dropped from the contest).  The
        filter depends only on the candidate names, so it is computed once
        per engine, not per chunk."""
        cached = getattr(self, "_pwr_cands", None)
        if cached is None:
            cached = tuple(
                n
                for n in self.candidates
                if hasattr(_make_pipeline(n), "preprocessor")
            ) or ("sz3_lorenzo",)
            self._pwr_cands = cached
        return cached

    def _compress_chunk(
        self, chunk: np.ndarray, abs_eb: float, eff: CompressionConfig
    ) -> Tuple[bytes, str, int, Optional[Dict[str, Any]]]:
        """Select + compress ONE chunk.  Self-contained per call: pipeline
        instances hold quantizer state across their compress() internals, so
        each task builds its own (construction is a few object allocations —
        the expensive per-chunk state, e.g. Huffman decode tables, is cached
        at module level in encoders.py).  This is what makes parallel output
        byte-identical to serial: the function is pure in (chunk, eff).

        PW_REL chunks compose ``preprocess.LogTransform`` into the winning
        Algorithm-1 pipeline: selection scores the log-domain view of the
        chunk against the log-domain ABS bound (exactly what the predictor
        will see), and the emitted v1 blob carries the chunk's sign / zero /
        non-finite side channels in its ``pre_meta`` — every chunk stays
        independently decodable through the ordinary v1 path.

        The 4th element is the selection-decision info (who contested, the
        stage-1 scores, fail-channel count, device routing) — computed only
        while a telemetry trace is active, None otherwise, so the traced-off
        path does no extra work and emits byte-identical containers."""
        n0 = int(chunk.shape[0] if chunk.ndim else chunk.size)
        if eff.mode == ErrorBoundMode.PW_REL:
            cands = self._pwr_candidates()
            pipelines = {name: _make_pipeline(name) for name in cands}
            sel_conf = eff.replace(mode=ErrorBoundMode.ABS, eb=abs_eb)
            with tel.span("select"):
                name, scores = select_pipeline(
                    pre_mod.log_domain_view(chunk), abs_eb, sel_conf, cands,
                    pipelines=pipelines, speed_tier=self.speed_tier,
                )
            comp = pipelines[name]
            comp.preprocessor = pre_mod.LogTransform()
        else:
            cands = self.candidates
            pipelines = {name: _make_pipeline(name) for name in cands}
            with tel.span("select"):
                name, scores = select_pipeline(
                    chunk, abs_eb, eff, cands, pipelines=pipelines,
                    speed_tier=self.speed_tier,
                )
            comp = pipelines[name]
        if not tel.enabled():
            return comp.compress(chunk, eff).blob, name, n0, None
        with tel.suppress_decisions():  # one authoritative record per chunk,
            # emitted chunk-ordered by _chunk_frames — a nested engine winner
            # (hybrid/fast) must not race its own record in from this thread
            res = comp.compress(chunk, eff, with_stats=True)
        meta = res.meta or {}
        sel = tel.sel_header_entry(
            cands, scores, name,
            nfail=int(meta.get("nfail", 0)),
            device="device" if meta.get("device") else "host",
        )
        sel["n"] = int(chunk.size)  # trace-only; stripped before the header
        return res.blob, name, n0, sel

    def _chunk_frames(
        self, data: np.ndarray, conf: CompressionConfig
    ) -> Iterator[Tuple[bytes, str, int, Optional[Dict[str, Any]]]]:
        """Yield (v1 blob, pipeline name, axis-0 extent, selection info) per
        chunk.  Under an active trace, each chunk's work runs inside a
        ``chunk`` span tagged ``order=i`` (exporters sort siblings by it, so
        parallel traces merge deterministically) and a schema-pinned
        selection-decision record is emitted in chunk order from the ordered
        consumer side — never from racing worker threads."""
        data = np.asarray(data)
        if data.dtype not in (np.float32, np.float64):
            data = data.astype(np.float32)
        if conf.mode == ErrorBoundMode.PW_REL:
            # the pointwise bound needs no global statistics: the log-domain
            # ABS bound depends only on eb, so chunked PW_REL output honours
            # the bound identically for arrays and unbounded slab iterators
            abs_eb = pre_mod.pw_rel_log_eb(conf.eb)
            eff = conf
        else:
            rng, absmax = pl_mod._finite_stats(data)
            abs_eb = conf.resolve_abs_eb(rng, absmax)
            if abs_eb <= 0:
                abs_eb = float(np.finfo(np.float64).tiny)
            eff = conf.replace(mode=ErrorBoundMode.ABS, eb=abs_eb)
        flat_leading = data.reshape(-1) if data.ndim == 0 else data
        chunks = (
            flat_leading[sl]
            for sl in chunk_slices(
                flat_leading.shape, flat_leading.dtype.itemsize, self.chunk_bytes
            )
        )

        def _one(args: Tuple[int, np.ndarray]):
            i, chunk = args
            with tel.span("chunk", order=i, bytes=chunk.nbytes):
                return self._compress_chunk(chunk, abs_eb, eff)

        engine = tel.chunked_engine_name(self.kind, self.candidates)
        results = _parallel_map_ordered(
            _one, enumerate(chunks), self.workers, timeout=self.chunk_timeout
        )
        for i, (blob, name, n0, sel) in enumerate(results):
            if sel is not None:
                tel.record_decision(tel.make_decision(
                    engine,
                    name,
                    index=i,
                    candidates=sel["cands"],
                    estimates=sel.get("est") or None,
                    est_bits=sel.get("est_bits"),
                    realized_bits=8.0 * len(blob) / max(1, sel["n"]),
                    margin=sel.get("margin"),
                    n_elems=sel["n"],
                    fallbacks=sel["nfail"],
                    device=sel["dev"],
                ))
            yield blob, name, n0, sel

    # -- one-shot v2 container ----------------------------------------------
    def compress(
        self,
        data: np.ndarray,
        conf: Optional[CompressionConfig] = None,
        with_stats: bool = False,
    ) -> CompressionResult:
        conf = conf or self.conf
        data = np.asarray(data)
        stored_dtype = (
            data.dtype if data.dtype in (np.float32, np.float64) else np.dtype(np.float32)
        )
        records: List[ChunkRecord] = []
        body_parts: List[bytes] = []
        off = 0
        for blob, name, n0, sel in self._chunk_frames(data, conf):
            sel_hdr = (
                {k: v for k, v in sel.items() if k != "n"} if sel else None
            )
            records.append(ChunkRecord(off, len(blob), n0, name, sel=sel_hdr))
            body_parts.append(blob)
            off += len(blob)
        blob = _assemble_v2(
            tuple(data.shape), stored_dtype, records, body_parts, conf,
            kind=self.kind, version=self.container_version,
        )
        meta = {"chunks": [r.to_header() for r in records]}
        # ratio against POST-cast bytes, matching the v1 driver's accounting
        nbytes = data.size * np.dtype(stored_dtype).itemsize
        return CompressionResult(
            blob=blob,
            ratio=nbytes / max(1, len(blob)),
            meta=meta if with_stats else None,
        )


def _assemble_v2(
    shape: Tuple[int, ...],
    dtype: np.dtype,
    records: Sequence[ChunkRecord],
    body_parts: Sequence[bytes],
    conf: CompressionConfig,
    kind: str = "chunked",
    version: int = _VERSION2,
    header_extra: Optional[Dict[str, Any]] = None,
) -> bytes:
    """Assemble a multi-chunk container.  ``kind``/``version`` distinguish the
    generations sharing this layout: v2 "chunked" (ABS/REL) and v4 "pwr"
    (pointwise-relative, log-composed chunk blobs).  ``header_extra`` merges
    additional top-level header fields (e.g. the quality controller's
    achieved-quality summary); unknown fields are ignored by readers."""
    header = {
        "v": int(version),
        "kind": kind,
        "shape": list(shape),
        "dtype": np.dtype(dtype).str,
        "axis": 0,
        "mode": conf.mode.value,
        "eb": float(conf.eb),
        "chunks": [r.to_header() for r in records],
    }
    if conf.eb_rel is not None:
        header["eb_rel"] = float(conf.eb_rel)
    if header_extra:
        header.update(pl_mod._clean_meta(header_extra))
    # per-chunk checksums in the trailer mirror the header chunk table, so
    # verification can name the damaged chunk and salvage can skip only it
    return pack_container(
        header,
        b"".join(body_parts),
        chunk_bounds=[(r.off, r.length) for r in records],
    )


#: default worker count for v2-container decompression via the generic
#: ``pipeline.decompress`` entry point (which has no workers parameter);
#: explicit callers pass ``workers=`` instead
DECOMPRESS_WORKERS = 1


def decompress_chunked(
    blob: bytes,
    header: Dict[str, Any],
    body_off: int,
    workers: Optional[int] = None,
    verify: str = "strict",
) -> np.ndarray:
    """Decode a v2 multi-chunk container (called from pipeline.decompress).

    Chunks are independent blobs, so they decode on ``workers`` threads
    (default: module-level ``DECOMPRESS_WORKERS``); output ordering is
    positional and unaffected by completion order.  The chunk table is
    validated against the real body size before any slice (hostile offsets
    or lengths cannot direct reads outside the body), and ``verify``
    propagates to the nested per-chunk decode.
    """
    workers = DECOMPRESS_WORKERS if workers is None else max(1, int(workers))
    body = pl_mod.container_body(blob, body_off)
    bounds = integrity.chunk_bounds_of(header, len(body))
    nested = "off" if verify == "off" else "strict"
    parts = list(
        _parallel_map_ordered(
            lambda b: pl_mod.decompress(body[b[0] : b[0] + b[1]], verify=nested),
            bounds,
            workers,
        )
    )
    dtype = np.dtype(header["dtype"])
    shape = guard_shape(header["shape"], dtype.itemsize, "shape")
    if not parts:
        return np.zeros(shape, dtype)
    if parts[0].ndim == 0 or not shape:
        out = np.concatenate([np.atleast_1d(p) for p in parts])
        return out.astype(dtype).reshape(shape)
    return np.concatenate(parts, axis=0).astype(dtype).reshape(shape)


def salvage_chunked(
    blob: bytes,
    header: Dict[str, Any],
    body_off: int,
    workers: Optional[int] = None,
    inspect_result: Optional[integrity.VerifyResult] = None,
) -> Tuple[np.ndarray, SalvageReport]:
    """``verify="salvage"`` for v2/v4 containers: decode every intact chunk
    byte-exact, zero-fill the damaged ones, and report both sets.

    A chunk is damaged when the trailer's per-chunk checksum says so (reason
    ``"checksum"`` — its decode is not even attempted) or, absent a usable
    trailer, when its nested decode raises a ``ValueError`` (reason
    ``"decode-error"``).  The header itself must be intact — shape, dtype and
    the chunk table are the map the salvage is drawn on — which the caller
    (``pipeline._decompress_salvage``) has already enforced.
    """
    res = inspect_result
    if res is None:
        res = integrity.inspect(blob, header, body_off)
    workers = DECOMPRESS_WORKERS if workers is None else max(1, int(workers))
    body = pl_mod.container_body(blob, body_off)
    with decode_errors("chunked container"):
        dtype = np.dtype(header["dtype"])
        shape = guard_shape(header["shape"], dtype.itemsize, "shape")
        bounds = integrity.chunk_bounds_of(header, len(body))
        lead = int(shape[0]) if shape else 1
        inner = tuple(shape[1:])
        n0s: List[int] = []
        budget = lead
        for i, c in enumerate(header["chunks"] if bounds else []):
            n0 = guard_count(
                c.get("n0") if isinstance(c, dict) else None,
                budget,
                f"chunk {i} n0",
            )
            n0s.append(n0)
            budget -= n0
    row = int(np.prod(inner, dtype=np.int64)) if inner else 1
    bad = set(res.bad_chunks or []) if res.has_trailer else set()
    report = SalvageReport(
        total_chunks=len(bounds), checksummed=res.has_trailer
    )

    def _decode_one(args):
        i, (off, ln) = args
        if i in bad:
            return None, "checksum"
        try:
            with decode_errors(f"chunk {i}"):
                part = pl_mod.decompress(body[off : off + ln], verify="strict")
            return np.asarray(part), None
        except ValueError:
            return None, "decode-error"

    results = list(
        _parallel_map_ordered(_decode_one, enumerate(bounds), workers)
    )
    out = np.zeros((lead,) + inner, dtype)
    r0 = 0
    for i, ((part, reason), n0) in enumerate(zip(results, n0s)):
        if part is not None and reason is None:
            try:
                out[r0 : r0 + n0] = part.astype(dtype).reshape((n0,) + inner)
                report.recovered.append(i)
            except ValueError:
                reason = "decode-error"
        if reason is not None:
            report.damage.append(
                ChunkDamage(i, r0 * row, (r0 + n0) * row, reason)
            )
        r0 += n0
    return out.reshape(shape), report


@dataclasses.dataclass(frozen=True)
class ChunkedIndex:
    """Parsed random-access state for one v2/v4 container.

    Everything :func:`decompress_chunk` needs that is a pure function of the
    blob bytes: the msgpack header, validated chunk bounds, and the trailer's
    per-chunk CRCs (when present).  Build once with
    :func:`parse_chunked_index`, then pass to repeated ``decompress_chunk``
    calls — the serving layer's LRU holds these so a fetch touches only the
    requested chunk's bytes.
    """

    header: Dict[str, Any]
    body_off: int
    body_len: int
    bounds: Tuple[Tuple[int, int], ...]
    kind: str
    algo: Optional[str]  # trailer checksum algorithm, None without trailer
    chunk_crcs: Optional[Tuple[int, ...]]
    header_ok: bool  # header CRC verified (True when no trailer to check)

    @property
    def n_chunks(self) -> int:
        return len(self.bounds)


def parse_chunked_index(blob: bytes, verify: str = "strict") -> ChunkedIndex:
    """Parse the header + chunk table + trailer CRCs of a v2/v4 container.

    Under ``verify="strict"`` the header CRC is checked here, once — a
    damaged chunk table must not direct reads at the wrong bytes — and a
    container whose header advertises a trailer (``itg``) that is missing
    raises (stripped-trailer downgrade).  Per-chunk CRCs are carried in the
    returned index but NOT checked here; :func:`decompress_chunk` checks
    only the requested chunk's, keeping random access O(chunk).
    """
    if verify not in pl_mod.VERIFY_MODES:
        raise ValueError(f"verify must be one of {pl_mod.VERIFY_MODES}")
    with decode_errors("chunked container"):
        header, body_off = pl_mod.parse_header(blob)
        if header.get("v", 1) < _VERSION2 or header.get("kind") not in (
            "chunked",
            "pwr",
        ):
            raise ContainerError("not a chunked (v2) or pwr (v4) container")
        body_len = len(pl_mod.container_body(blob, body_off))
        bounds = tuple(integrity.chunk_bounds_of(header, body_len))
        tr = integrity.read_trailer(blob)
        algo: Optional[str] = None
        crcs: Optional[Tuple[int, ...]] = None
        header_ok = True
        if tr is not None and tr.start == body_off + body_len:
            algo = tr.algo
            header_ok = (
                integrity.checksum(blob[:body_off], algo=tr.algo) == tr.header_crc
            )
            if len(tr.chunk_crcs) == len(bounds):
                crcs = tr.chunk_crcs
        elif header.get("itg") and verify == "strict":
            raise IntegrityError(
                "header advertises an integrity trailer but none is present "
                "(trailer stripped or truncated)",
                region="trailer",
            )
        if verify == "strict" and not header_ok:
            raise IntegrityError(
                "container header fails its checksum", region="header"
            )
        return ChunkedIndex(
            header=header,
            body_off=body_off,
            body_len=body_len,
            bounds=bounds,
            kind=header.get("kind"),
            algo=algo,
            chunk_crcs=crcs,
            header_ok=header_ok,
        )


def decompress_chunk(
    blob: bytes,
    index: int,
    verify: str = "strict",
    parsed: Optional[ChunkedIndex] = None,
) -> np.ndarray:
    """Random access: decode only chunk ``index`` of a v2/v4 container.

    O(chunk), not O(container): under ``verify="strict"`` only the header
    CRC (checked at parse time) and the *requested* chunk's CRC are
    validated — a corrupt sibling chunk does not fail the read.  When the
    outer per-chunk CRC matches, the nested blob's own verification is
    skipped (the outer CRC just covered every nested byte, trailer
    included); legacy trailer-less containers fall back to the nested
    blob's strict path.

    ``parsed`` lets callers amortize header/trailer parsing across many
    reads of the same container (see :func:`parse_chunked_index`).
    """
    if parsed is None:
        parsed = parse_chunked_index(blob, verify=verify)
    with decode_errors("chunked container"):
        off, ln = parsed.bounds[index]  # IndexError -> ContainerError
        lo = parsed.body_off + off
        chunk = blob[lo : lo + ln]
        nested = verify
        if verify == "strict" and parsed.chunk_crcs is not None:
            if not parsed.header_ok:
                raise IntegrityError(
                    "container header fails its checksum", region="header"
                )
            if integrity.checksum(chunk, algo=parsed.algo) != parsed.chunk_crcs[index]:
                raise IntegrityError(
                    f"container chunk {index} fails its checksum",
                    chunk_index=index,
                )
            nested = "off"
        return pl_mod.decompress(chunk, verify=nested)


# ---------------------------------------------------------------------------
# streaming API (bounded memory)
# ---------------------------------------------------------------------------

def compress_stream(
    data: Union[np.ndarray, Iterable[np.ndarray]],
    conf: Optional[CompressionConfig] = None,
    candidates: Sequence[str] = DEFAULT_CANDIDATES,
    chunk_bytes: int = 1 << 22,
    workers: int = 1,
) -> Iterator[bytes]:
    """Yield a prologue frame, then one self-describing v1 blob per chunk.

    ``data`` may be an ndarray (re-chunked by byte budget, bound resolved
    globally — the stream then reassembles bit-identically into the one-shot
    v2 container via :func:`frames_to_blob`) or an iterable of slabs (each
    slab is chunked independently as it arrives; REL bounds resolve per slab).
    ``workers`` > 1 compresses chunks on a thread pool (frame order, and
    therefore the byte stream, is unchanged).
    """
    conf = conf or CompressionConfig()
    eng = ChunkedCompressor(
        candidates=candidates, chunk_bytes=chunk_bytes, conf=conf, workers=workers
    )
    prologue = _STREAM_MAGIC + msgpack.packb(
        {"v": _VERSION2, "axis": 0, "mode": conf.mode.value, "eb": float(conf.eb)},
        use_bin_type=True,
    )
    yield prologue
    slabs = [data] if isinstance(data, np.ndarray) else data
    for slab in slabs:
        for blob, _name, _n0, _sel in eng._chunk_frames(np.asarray(slab), conf):
            yield blob


def decompress_stream(
    frames: Iterable[bytes], workers: int = 1, verify: str = "strict"
) -> Iterator[np.ndarray]:
    """Inverse of :func:`compress_stream`: yield one decoded array per chunk.

    Tolerates a missing prologue (a bare sequence of v1/v2 blobs works too);
    memory stays bounded by one chunk (times the in-flight window when
    ``workers`` > 1 decodes frames on a thread pool; order is preserved).
    ``verify`` is applied per frame; ``"salvage"`` yields
    ``(data, SalvageReport)`` pairs instead of bare arrays, so a damaged
    frame zero-fills and reports rather than killing the stream.
    """
    payload = (f for f in frames if f[:4] != _STREAM_MAGIC)
    yield from _parallel_map_ordered(
        lambda f: pl_mod.decompress(f, verify=verify),
        payload,
        max(1, int(workers)),
    )


def frames_to_blob(frames: Iterable[bytes]) -> bytes:
    """Assemble a frame stream into the one-shot v2 container.

    Only compressed blobs are held; raw data is never materialized.  The
    result is byte-identical to ``ChunkedCompressor.compress(x).blob`` when
    the stream came from the same array/config with the DEFAULT candidate
    set; exotic candidates whose factory cannot be recovered from a blob's
    spec (e.g. ``sz3_aps``, which emits a composite/lorenzo spec) decode
    identically but may name the winner differently in the chunk table.
    Frames carry no rank information, so a 0-d input reassembles (and
    decodes) as shape ``(1,)``; use the one-shot container for scalars.
    """
    records: List[ChunkRecord] = []
    parts: List[bytes] = []
    off = 0
    mode, eb = ErrorBoundMode.ABS.value, None
    shape0 = 0
    inner: Optional[Tuple[int, ...]] = None
    dtype = np.dtype(np.float32)
    for frame in frames:
        if frame[:4] == _STREAM_MAGIC:
            meta = msgpack.unpackb(frame[4:], raw=False)
            mode = meta.get("mode", mode)
            if meta.get("eb") is not None:
                eb = float(meta["eb"])
            continue
        h, _ = pl_mod.parse_header(frame)
        cshape = tuple(h["shape"])
        n0 = int(cshape[0]) if cshape else 1
        if inner is None:
            inner = cshape[1:]
            dtype = np.dtype(h["dtype"])
        elif cshape[1:] != inner:
            raise ValueError(
                f"inconsistent chunk shapes in stream: {cshape[1:]} vs {inner}"
            )
        records.append(ChunkRecord(off, len(frame), n0, _pipeline_name_from_spec(h["spec"])))
        parts.append(frame)
        off += len(frame)
        shape0 += n0
    conf = CompressionConfig(mode=ErrorBoundMode(mode), eb=1e-3 if eb is None else eb)
    pwr = conf.mode == ErrorBoundMode.PW_REL
    return _assemble_v2(
        (shape0,) + (inner or ()), dtype, records, parts, conf,
        kind="pwr" if pwr else "chunked",
        version=_VERSION4 if pwr else _VERSION2,
    )


def _pipeline_name_from_spec(spec: Dict[str, Any]) -> str:
    """Recover the factory name a v1 blob was produced by (best effort)."""
    if spec.get("kind") == "truncation":
        return "sz3_truncation"
    if spec.get("kind") == "transform":
        return "sz3_transform"
    if spec.get("kind") == "hybrid":
        return "sz3_hybrid"
    if spec.get("kind") == "fast":
        return "sz3_fast"
    pred = spec.get("predictor")
    if pred == "composite":
        return "sz3_lr"
    if pred == "interp":
        return "sz3_interp"
    if pred == "lorenzo":
        return "sz3_lorenzo"
    if pred == "pattern":
        if spec.get("quantizer") == "unpred_aware":
            return "sz3_pastri"
        return "sz_pastri" if spec.get("lossless") == "none" else "sz_pastri_zstd"
    return str(spec.get("kind", "sz3"))


def write_frames(frames: Iterable[bytes], fp) -> int:
    """Length-prefix frames onto a binary file object; returns bytes written."""
    total = 0
    for frame in frames:
        fp.write(np.asarray([len(frame)], np.int64).tobytes())
        fp.write(frame)
        total += 8 + len(frame)
    return total


def read_frames(fp) -> Iterator[bytes]:
    """Inverse of :func:`write_frames`.  Hostile length prefixes are rejected
    before the read: a negative count would make ``fp.read`` slurp the whole
    stream, an absurd one would declare an unbounded allocation."""
    while True:
        head = fp.read(8)
        if len(head) < 8:
            return
        n = int(np.frombuffer(head, np.int64)[0])
        if n < 0 or n > integrity.MAX_OUTPUT_BYTES:
            raise ContainerError(f"corrupt frame stream: frame length {n}")
        frame = fp.read(n)
        if len(frame) != n:
            raise ContainerError("truncated frame stream")
        yield frame


def sz3_chunked(
    candidates: Sequence[str] = DEFAULT_CANDIDATES,
    chunk_bytes: int = 1 << 22,
    workers: int = 1,
    **kw,
) -> ChunkedCompressor:
    """Named factory, registered alongside the paper pipelines."""
    return ChunkedCompressor(
        candidates=candidates, chunk_bytes=chunk_bytes, workers=workers, **kw
    )


# ---------------------------------------------------------------------------
# first-class pointwise-relative pipeline (v4 container)
# ---------------------------------------------------------------------------

class PWRelChunkedCompressor(ChunkedCompressor):
    """Pointwise-relative chunked engine: ``|x_i - x_hat_i| <= eb * |x_i|``
    holds for every finite nonzero element, zeros reconstruct exactly, and
    non-finite values round-trip bit-exact — NOT the conservative
    ``eb * absmax`` over-bound the bare pipelines used to degrade to.

    Each chunk is compressed by the winning Algorithm-1 pipeline composed
    with ``preprocess.LogTransform`` (per-chunk sign / zero / non-finite side
    channels travel in the chunk blob's ``pre_meta``), and the container
    carries the v4 "pwr" tag so ``pipeline.decompress`` can route it; v1-v3
    containers decode unchanged."""

    kind = "pwr"
    container_version = _VERSION4

    def __init__(
        self,
        candidates: Sequence[str] = DEFAULT_CANDIDATES,
        chunk_bytes: int = 1 << 22,
        conf: Optional[CompressionConfig] = None,
        workers: int = 1,
    ):
        super().__init__(
            candidates=candidates,
            chunk_bytes=chunk_bytes,
            conf=conf or CompressionConfig(mode=ErrorBoundMode.PW_REL, eb=1e-3),
            workers=workers,
        )

    def compress(
        self,
        data: np.ndarray,
        conf: Optional[CompressionConfig] = None,
        with_stats: bool = False,
    ) -> CompressionResult:
        conf = conf or self.conf
        if conf.mode != ErrorBoundMode.PW_REL:
            raise ValueError(
                "sz3_pwr compresses pointwise-relative bounds only; got mode "
                f"{conf.mode.value!r} (use sz3_chunked/sz3_auto for ABS/REL)"
            )
        return super().compress(data, conf, with_stats)


def sz3_pwr(
    eb: float = 1e-3,
    candidates: Sequence[str] = DEFAULT_CANDIDATES,
    chunk_bytes: int = 1 << 22,
    workers: int = 1,
    **kw,
) -> PWRelChunkedCompressor:
    """First-class pointwise-relative pipeline (v4 "pwr" container)."""
    return PWRelChunkedCompressor(
        candidates=candidates,
        chunk_bytes=chunk_bytes,
        workers=workers,
        conf=kw.pop("conf", None)
        or CompressionConfig(mode=ErrorBoundMode.PW_REL, eb=eb),
        **kw,
    )


# register with the named-pipeline table (PIPELINES lives in pipeline.py;
# chunking imports pipeline, so registration happens here to avoid a cycle)
pl_mod.PIPELINES["sz3_chunked"] = sz3_chunked
pl_mod.PIPELINES["sz3_pwr"] = sz3_pwr
