"""SZ3 core: modular prediction-based error-bounded lossy compression.

The paper's five-module abstraction (preprocessor -> predictor -> quantizer ->
encoder -> lossless) composed per §3.3, plus the customized pipelines of §4
(GAMESS / SZ3-Pastri), §5 (APS adaptive) and §6.2 (LR / Interp / Truncation).
"""
from . import telemetry  # noqa: I001  (stdlib-only; must import first so
# every other core module can use it without cycles)
from .telemetry import Trace, explain, trace_summary
from . import encoders, lossless, metrics, predictors, preprocess, quantizers
from . import faults, integrity
from .config import CompressionConfig, ErrorBoundMode
from .integrity import (
    ChunkDamage,
    ContainerError,
    IntegrityError,
    SalvageReport,
    verify_blob,
)
from .pipeline import (  # noqa: I001  (chunking must import after pipeline)
    PIPELINES,
    AdaptiveAPSCompressor,
    CompressionResult,
    SZ3Compressor,
    TruncationCompressor,
    decompress,
    parse_header,
    sz3_aps,
    sz3_interp,
    sz3_lorenzo,
    sz3_lr,
    sz3_pastri,
    sz3_truncation,
    sz_pastri,
    sz_pastri_zstd,
)
from . import chunking
from .chunking import (
    ChunkedCompressor,
    ChunkedIndex,
    PWRelChunkedCompressor,
    compress_stream,
    decompress_chunk,
    parse_chunked_index,
    decompress_stream,
    frames_to_blob,
    read_frames,
    select_pipeline,
    sz3_chunked,
    sz3_pwr,
    write_frames,
)
from . import transform
from . import blockwise  # noqa: I001  (blockwise must import after transform:
# it registers sz3_hybrid and appends it to transform.AUTO_CANDIDATES)
from . import fastmode  # noqa: I001  (fastmode must import after transform:
# it registers sz3_fast and appends it to transform.AUTO_CANDIDATES)
from .fastmode import (
    FastModeCompressor,
    sz3_fast,
)
from .transform import (  # noqa: I001  (re-export AFTER blockwise extends it)
    AUTO_CANDIDATES,
    TransformCompressor,
    sz3_auto,
    sz3_transform,
)
from .blockwise import (
    BlockHybridCompressor,
    sz3_hybrid,
)
from . import quality
from .quality import (  # noqa: I001  (quality must import after transform)
    QualityCompressor,
    QualityTarget,
    achieved_quality,
    sz3_quality,
)

__all__ = [
    "telemetry",
    "Trace",
    "explain",
    "trace_summary",
    "CompressionConfig",
    "ErrorBoundMode",
    "ContainerError",
    "IntegrityError",
    "SalvageReport",
    "ChunkDamage",
    "verify_blob",
    "integrity",
    "faults",
    "SZ3Compressor",
    "TruncationCompressor",
    "AdaptiveAPSCompressor",
    "CompressionResult",
    "decompress",
    "parse_header",
    "PIPELINES",
    "sz3_lr",
    "sz3_interp",
    "sz3_lorenzo",
    "sz3_truncation",
    "sz_pastri",
    "sz_pastri_zstd",
    "sz3_pastri",
    "sz3_aps",
    "ChunkedCompressor",
    "PWRelChunkedCompressor",
    "sz3_chunked",
    "sz3_pwr",
    "QualityCompressor",
    "QualityTarget",
    "achieved_quality",
    "sz3_quality",
    "quality",
    "TransformCompressor",
    "sz3_transform",
    "sz3_auto",
    "AUTO_CANDIDATES",
    "transform",
    "BlockHybridCompressor",
    "sz3_hybrid",
    "blockwise",
    "FastModeCompressor",
    "sz3_fast",
    "fastmode",
    "compress_stream",
    "decompress_stream",
    "decompress_chunk",
    "parse_chunked_index",
    "ChunkedIndex",
    "frames_to_blob",
    "write_frames",
    "read_frames",
    "select_pipeline",
    "chunking",
    "encoders",
    "lossless",
    "metrics",
    "predictors",
    "preprocess",
    "quantizers",
]
