"""SZ3 core: modular prediction-based error-bounded lossy compression.

The paper's five-module abstraction (preprocessor -> predictor -> quantizer ->
encoder -> lossless) composed per §3.3, plus the customized pipelines of §4
(GAMESS / SZ3-Pastri), §5 (APS adaptive) and §6.2 (LR / Interp / Truncation).
"""
from . import encoders, lossless, metrics, predictors, preprocess, quantizers
from .config import CompressionConfig, ErrorBoundMode
from .pipeline import (
    PIPELINES,
    AdaptiveAPSCompressor,
    CompressionResult,
    SZ3Compressor,
    TruncationCompressor,
    decompress,
    parse_header,
    sz3_aps,
    sz3_interp,
    sz3_lorenzo,
    sz3_lr,
    sz3_pastri,
    sz3_truncation,
    sz_pastri,
    sz_pastri_zstd,
)

__all__ = [
    "CompressionConfig",
    "ErrorBoundMode",
    "SZ3Compressor",
    "TruncationCompressor",
    "AdaptiveAPSCompressor",
    "CompressionResult",
    "decompress",
    "parse_header",
    "PIPELINES",
    "sz3_lr",
    "sz3_interp",
    "sz3_lorenzo",
    "sz3_truncation",
    "sz_pastri",
    "sz_pastri_zstd",
    "sz3_pastri",
    "sz3_aps",
    "encoders",
    "lossless",
    "metrics",
    "predictors",
    "preprocess",
    "quantizers",
]
