"""Compression configuration — the SZ3 ``conf`` object.

Mirrors the paper's compression configuration: an error-bound mode + value,
quantizer geometry, and per-module knobs. Every module receives the config so
pipelines stay composable (the driver in ``pipeline.py`` never reads
module-specific fields).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Mapping, Optional, Tuple


class ErrorBoundMode(enum.Enum):
    """How the user-specified error bound is interpreted.

    ABS         : max |x - x_hat| <= eb
    REL         : max |x - x_hat| <= eb * (max(x) - min(x))   (value-range relative)
    PW_REL      : |x_i - x_hat_i| <= eb * |x_i|  for every i  (point-wise relative,
                  realized via the logarithmic-transform preprocessor, paper §3.2)
    ABS_AND_REL : both bounds hold — resolves to min(eb, eb_rel * range)
    ABS_OR_REL  : the looser bound suffices — resolves to max(eb, eb_rel * range)

    The composite modes (SZ convention: ``errorBoundMode = ABS_AND_REL`` /
    ``ABS_OR_REL``) carry the absolute bound in ``eb`` and the range-relative
    fraction in ``eb_rel``.
    """

    ABS = "abs"
    REL = "rel"
    PW_REL = "pw_rel"
    ABS_AND_REL = "abs-and-rel"
    ABS_OR_REL = "abs-or-rel"


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    """Configuration threaded through every SZ3 module.

    Attributes
    ----------
    mode:         error bound interpretation (see :class:`ErrorBoundMode`).
    eb:           the user error bound in the units implied by ``mode``.  For
                  the composite modes this is the ABSOLUTE half of the pair.
    eb_rel:       the range-relative fraction for the composite modes
                  (``abs-and-rel`` / ``abs-or-rel``); ignored elsewhere.
    quant_radius: half-width of the quantization code range.  Codes live in
                  ``[1, 2*quant_radius - 1]`` with ``quant_radius`` = "diff 0";
                  code 0 is reserved for unpredictable points (SZ convention).
    block_size:   side length of the cubic blocks used by block-local
                  predictors (regression / composite selection), SZ2 default 6
                  for 3-D.  TPU note: device kernels retile internally to
                  (8,128)-aligned VMEM blocks regardless of this value.
    pattern_size: pattern length for the Pastri predictor (None = auto-detect
                  via autocorrelation, see predictors.PatternPredictor).
    interp_kind:  "linear" | "cubic" for the interpolation predictor.
    lorenzo_order: 1 or 2 (second-order Lorenzo uses the wider stencil).
    sample_stride: stride used when sampling points for composite-predictor
                  error estimation (paper §3.2 Predictor: estimate_error).
    extras:       free-form per-module options (kept in a mapping so new
                  modules never require touching this dataclass — the paper's
                  extensibility claim).
    """

    mode: ErrorBoundMode = ErrorBoundMode.ABS
    eb: float = 1e-3
    eb_rel: Optional[float] = None
    quant_radius: int = 32768
    block_size: int = 6
    pattern_size: Optional[int] = None
    interp_kind: str = "cubic"
    lorenzo_order: int = 1
    sample_stride: int = 3
    extras: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    def resolve_abs_eb(
        self,
        value_range: float,
        value_absmax: float,
        allow_conservative: bool = False,
    ) -> float:
        """Translate the configured bound into an absolute bound.

        REL bounds scale by value range (SZ convention).  PW_REL has no
        faithful single absolute bound — it is realized by the log-transform
        preprocessor, which converts the problem into an ABS problem in the
        log domain (PW_REL-native pipelines: ``sz3_pwr``, the chunked/auto
        engines, or any ``SZ3Compressor`` composed with
        ``preprocess.LogTransform``).  Resolving PW_REL here therefore raises,
        unless the caller explicitly opts into the conservative ``eb * absmax``
        over-bound with ``allow_conservative=True`` (every point then satisfies
        an ABS bound that only equals the pointwise-relative bound at the
        largest-magnitude value — far looser everywhere else).
        """
        if self.mode == ErrorBoundMode.ABS:
            return float(self.eb)
        if self.mode == ErrorBoundMode.REL:
            return float(self.eb) * float(value_range)
        if self.mode in (ErrorBoundMode.ABS_AND_REL, ErrorBoundMode.ABS_OR_REL):
            if self.eb_rel is None:
                raise ValueError(
                    f"mode {self.mode.value!r} needs both bounds: set eb to "
                    "the absolute bound and eb_rel to the range-relative "
                    "fraction"
                )
            rel = float(self.eb_rel) * float(value_range)
            if self.mode == ErrorBoundMode.ABS_AND_REL:
                return min(float(self.eb), rel)
            return max(float(self.eb), rel)
        if self.mode == ErrorBoundMode.PW_REL:
            if not allow_conservative:
                raise ValueError(
                    "PW_REL cannot be resolved to a single absolute bound; "
                    "use a PW_REL-native pipeline (sz3_pwr, sz3_chunked, "
                    "sz3_auto, or compose preprocess.LogTransform into an "
                    "SZ3Compressor), or opt into the conservative eb*absmax "
                    "fallback with allow_conservative=True"
                )
            return float(self.eb) * float(value_absmax)
        raise ValueError(f"unknown error bound mode {self.mode}")

    def replace(self, **kw: Any) -> "CompressionConfig":
        return dataclasses.replace(self, **kw)


# Canonical shorthand used across the codebase.
Shape = Tuple[int, ...]
