"""Preprocessor module (paper §3.2 "Preprocessor", Appendix A.1).

Instances:
  * Identity        — module bypass.
  * LogTransform    — pointwise-relative-bound -> absolute-bound conversion in
                      the log domain (paper ref [20]); signs/zeros side-channel.
  * Transpose       — layout alteration; the APS pipeline's "treat the 3-D
                      stack as 256x256 1-D time series" preprocessor (paper §5.2).
  * Linearize       — collapse to 1-D (unstructured-grid support, paper §1).

``forward`` transforms data in a separate buffer (the paper's note about
keeping original data intact) and returns updated config + serializable meta;
``inverse`` reverses it during decompression.
"""
from __future__ import annotations

import abc
from typing import Any, Dict, Tuple

import numpy as np

from .config import CompressionConfig, ErrorBoundMode


class Preprocessor(abc.ABC):
    name: str = "abstract"

    @abc.abstractmethod
    def forward(
        self, data: np.ndarray, conf: CompressionConfig
    ) -> Tuple[np.ndarray, CompressionConfig, Dict[str, Any]]: ...

    @abc.abstractmethod
    def inverse(
        self, data: np.ndarray, conf: CompressionConfig, meta: Dict[str, Any]
    ) -> np.ndarray: ...


class Identity(Preprocessor):
    name = "identity"

    def forward(self, data, conf):
        return data, conf, {}

    def inverse(self, data, conf, meta):
        return data


class Transpose(Preprocessor):
    """Permute axes (optionally flattening) before compression.

    The APS pipeline (paper §5.2) moves the time axis innermost so a 1-D
    Lorenzo predictor follows the high-correlation direction.
    """

    name = "transpose"

    def __init__(self, perm: Tuple[int, ...] = None, flatten: bool = False):
        self.perm = perm
        self.flatten = flatten

    def forward(self, data, conf):
        perm = self.perm if self.perm is not None else tuple(range(data.ndim))[::-1]
        out = np.ascontiguousarray(np.transpose(data, perm))
        meta = {"perm": list(perm), "shape": list(out.shape)}
        if self.flatten:
            out = out.reshape(-1)
        return out, conf, meta

    def inverse(self, data, conf, meta):
        perm = tuple(meta["perm"])
        shape = tuple(meta["shape"])
        out = data.reshape(shape)
        inv = np.argsort(perm)
        return np.ascontiguousarray(np.transpose(out, inv))


class Linearize(Preprocessor):
    """Rearrange to a 1-D array (unstructured-grid support, paper §1)."""

    name = "linearize"

    def forward(self, data, conf):
        return data.reshape(-1), conf, {"shape": list(data.shape)}

    def inverse(self, data, conf, meta):
        return data.reshape(tuple(meta["shape"]))


def pw_rel_log_eb(eb: float) -> float:
    """The ABS bound in the log2 domain equivalent to pointwise-relative ``eb``.

    A log-domain error of delta reconstructs x * 2**delta; keeping
    ``|delta| <= min(log2(1+eb), -log2(1-eb))`` keeps the multiplier inside
    ``[1-eb, 1+eb]`` in BOTH directions (log2(1-eb) is the tighter side).
    """
    eb = float(eb)
    if not (0.0 < eb < 1.0):
        raise ValueError("pointwise-relative eb must be in (0, 1)")
    return float(min(np.log2(1.0 + eb), -np.log2(1.0 - eb)))


def log_domain_view(data: np.ndarray) -> np.ndarray:
    """log2|x| with zeros / non-finite values mapped to 0.0 (= log2(1)).

    The selection-time view of what :class:`LogTransform` will feed the
    predictor: cheap pipeline contests for PW_REL chunks score THIS array
    (side channels carry the masked points, so predictors never see them).
    """
    flat = np.asarray(data, np.float64)
    mag = np.abs(flat)
    safe = np.where(np.isfinite(flat) & (mag > 0), mag, 1.0)
    return np.log2(safe)


class LogTransform(Preprocessor):
    """Pointwise-relative error bounds via the logarithmic domain (ref [20]).

    x -> log2|x| in float64, compressed with the ABS bound
    :func:`pw_rel_log_eb` (so the reconstructed ratio x_hat/x stays within
    [1-eb, 1+eb] pointwise); signs are stored as a packed bitmap, exact zeros /
    sub-threshold values as an exact-positions bitmap (reconstructed as 0,
    which satisfies any pointwise-relative bound), and non-finite values
    (nan/inf — log-undefined) ride an exact raw side channel, so the bound
    definition holds for every finite nonzero point and everything else
    round-trips exactly.

    Subnormal magnitudes also ride the raw channel: near the bottom of the
    subnormal range the storage dtype's representable quantum is relatively
    enormous (up to 50% of the value), so no log-domain bound survives the
    ``exp2`` + cast back — storing the handful of denormals exactly is the
    only way the pointwise-relative contract can hold for them.
    """

    name = "log"

    def __init__(self, zero_threshold: float = 0.0):
        self.zero_threshold = zero_threshold

    def forward(self, data, conf):
        if conf.mode != ErrorBoundMode.PW_REL:
            raise ValueError("LogTransform requires ErrorBoundMode.PW_REL")
        flat = np.asarray(data, np.float64).reshape(-1)
        thr = self.zero_threshold
        dt = data.dtype if data.dtype.kind == "f" else np.dtype(np.float32)
        finite = np.isfinite(flat)
        zero_mask = finite & (np.abs(flat) <= thr)
        # subnormals of the STORAGE dtype cannot honour a relative bound
        # through exp2 + cast (their representable quantum is relatively
        # huge) — they join nan/inf on the exact raw side channel
        subnormal = (
            finite & ~zero_mask & (np.abs(flat) < float(np.finfo(dt).tiny))
        )
        nonfinite_mask = ~finite | subnormal
        sign_mask = finite & (flat < 0)
        masked = zero_mask | nonfinite_mask
        safe = np.where(masked, 1.0, np.abs(flat))
        # float64 log domain regardless of input dtype: |log2| reaches ~1024,
        # where float32 resolution (~6e-5) would eat tight bounds
        logged = np.log2(safe).reshape(data.shape)
        # reserve headroom for the float rounding the log domain cannot see:
        # decompression casts the float64 reconstruction back to the storage
        # dtype (half-ulp relative error) and exp2 itself rounds once in
        # float64 — without the reservation a reconstruction sitting exactly
        # on the bound lands just past it after the cast
        eps = float(np.finfo(dt).eps) / 2 + 2.0**-52
        eb = float(conf.eb)
        eb_adj = (eb - eps) / (1.0 + eps)
        if eb_adj <= 0:
            raise ValueError(
                f"pointwise-relative eb={eb:g} is below the {dt.name} "
                f"rounding floor ({eps:.2e}); the bound cannot survive the "
                "cast back to the storage dtype"
            )
        abs_eb = pw_rel_log_eb(eb_adj)
        new_conf = conf.replace(mode=ErrorBoundMode.ABS, eb=abs_eb)
        meta = {
            "signs": np.packbits(sign_mask).tobytes(),
            "zeros": np.packbits(zero_mask).tobytes(),
            "n": int(flat.size),
            "orig_mode": conf.mode.value,
            "orig_eb": float(conf.eb),
        }
        if nonfinite_mask.any():
            meta["nonfinite"] = np.packbits(nonfinite_mask).tobytes()
            meta["nonfinite_vals"] = flat[nonfinite_mask].tobytes()
        return logged, new_conf, meta

    def inverse(self, data, conf, meta):
        n = int(meta["n"])
        signs = np.unpackbits(np.frombuffer(meta["signs"], np.uint8), count=n).astype(bool)
        zeros = np.unpackbits(np.frombuffer(meta["zeros"], np.uint8), count=n).astype(bool)
        flat = np.exp2(data.reshape(-1).astype(np.float64))
        flat = np.where(signs, -flat, flat)
        flat = np.where(zeros, 0.0, flat)
        if meta.get("nonfinite"):
            nf = np.unpackbits(
                np.frombuffer(meta["nonfinite"], np.uint8), count=n
            ).astype(bool)
            flat[nf] = np.frombuffer(meta["nonfinite_vals"], np.float64)
        return flat.astype(data.dtype).reshape(data.shape)


_REGISTRY = {
    "identity": Identity,
    "transpose": Transpose,
    "linearize": Linearize,
    "log": LogTransform,
}


def register(name: str, cls) -> None:
    _REGISTRY[name] = cls


def make(name: str, **kw) -> Preprocessor:
    return _REGISTRY[name](**kw)
