"""Preprocessor module (paper §3.2 "Preprocessor", Appendix A.1).

Instances:
  * Identity        — module bypass.
  * LogTransform    — pointwise-relative-bound -> absolute-bound conversion in
                      the log domain (paper ref [20]); signs/zeros side-channel.
  * Transpose       — layout alteration; the APS pipeline's "treat the 3-D
                      stack as 256x256 1-D time series" preprocessor (paper §5.2).
  * Linearize       — collapse to 1-D (unstructured-grid support, paper §1).

``forward`` transforms data in a separate buffer (the paper's note about
keeping original data intact) and returns updated config + serializable meta;
``inverse`` reverses it during decompression.
"""
from __future__ import annotations

import abc
from typing import Any, Dict, Tuple

import numpy as np

from .config import CompressionConfig, ErrorBoundMode


class Preprocessor(abc.ABC):
    name: str = "abstract"

    @abc.abstractmethod
    def forward(
        self, data: np.ndarray, conf: CompressionConfig
    ) -> Tuple[np.ndarray, CompressionConfig, Dict[str, Any]]: ...

    @abc.abstractmethod
    def inverse(
        self, data: np.ndarray, conf: CompressionConfig, meta: Dict[str, Any]
    ) -> np.ndarray: ...


class Identity(Preprocessor):
    name = "identity"

    def forward(self, data, conf):
        return data, conf, {}

    def inverse(self, data, conf, meta):
        return data


class Transpose(Preprocessor):
    """Permute axes (optionally flattening) before compression.

    The APS pipeline (paper §5.2) moves the time axis innermost so a 1-D
    Lorenzo predictor follows the high-correlation direction.
    """

    name = "transpose"

    def __init__(self, perm: Tuple[int, ...] = None, flatten: bool = False):
        self.perm = perm
        self.flatten = flatten

    def forward(self, data, conf):
        perm = self.perm if self.perm is not None else tuple(range(data.ndim))[::-1]
        out = np.ascontiguousarray(np.transpose(data, perm))
        meta = {"perm": list(perm), "shape": list(out.shape)}
        if self.flatten:
            out = out.reshape(-1)
        return out, conf, meta

    def inverse(self, data, conf, meta):
        perm = tuple(meta["perm"])
        shape = tuple(meta["shape"])
        out = data.reshape(shape)
        inv = np.argsort(perm)
        return np.ascontiguousarray(np.transpose(out, inv))


class Linearize(Preprocessor):
    """Rearrange to a 1-D array (unstructured-grid support, paper §1)."""

    name = "linearize"

    def forward(self, data, conf):
        return data.reshape(-1), conf, {"shape": list(data.shape)}

    def inverse(self, data, conf, meta):
        return data.reshape(tuple(meta["shape"]))


class LogTransform(Preprocessor):
    """Pointwise-relative error bounds via the logarithmic domain (ref [20]).

    x -> log2|x|, compressed with abs bound eb' = log2(1 + eb) (so the
    reconstructed ratio x_hat/x is within [1-eb, 1+eb]); signs are stored as a
    packed bitmap and exact zeros / denormal-tiny values as an exact-positions
    bitmap (reconstructed as 0, which satisfies any pointwise-relative bound).
    """

    name = "log"

    def __init__(self, zero_threshold: float = 0.0):
        self.zero_threshold = zero_threshold

    def forward(self, data, conf):
        if conf.mode != ErrorBoundMode.PW_REL:
            raise ValueError("LogTransform requires ErrorBoundMode.PW_REL")
        flat = data.reshape(-1)
        thr = self.zero_threshold
        zero_mask = np.abs(flat) <= thr
        sign_mask = flat < 0
        safe = np.where(zero_mask, 1.0, np.abs(flat))
        logged = np.log2(safe).astype(data.dtype).reshape(data.shape)
        # log2(1 - eb) is the tighter side; use it so both directions hold.
        eb = float(conf.eb)
        if not (0.0 < eb < 1.0):
            raise ValueError("pointwise-relative eb must be in (0, 1)")
        abs_eb = min(np.log2(1.0 + eb), -np.log2(1.0 - eb))
        new_conf = conf.replace(mode=ErrorBoundMode.ABS, eb=float(abs_eb))
        meta = {
            "signs": np.packbits(sign_mask).tobytes(),
            "zeros": np.packbits(zero_mask).tobytes(),
            "n": int(flat.size),
            "orig_mode": conf.mode.value,
            "orig_eb": float(conf.eb),
        }
        return logged, new_conf, meta

    def inverse(self, data, conf, meta):
        n = int(meta["n"])
        signs = np.unpackbits(np.frombuffer(meta["signs"], np.uint8), count=n).astype(bool)
        zeros = np.unpackbits(np.frombuffer(meta["zeros"], np.uint8), count=n).astype(bool)
        flat = np.exp2(data.reshape(-1).astype(np.float64))
        flat = np.where(signs, -flat, flat)
        flat = np.where(zeros, 0.0, flat)
        return flat.astype(data.dtype).reshape(data.shape)


_REGISTRY = {
    "identity": Identity,
    "transpose": Transpose,
    "linearize": Linearize,
    "log": LogTransform,
}


def register(name: str, cls) -> None:
    _REGISTRY[name] = cls


def make(name: str, **kw) -> Preprocessor:
    return _REGISTRY[name](**kw)
