"""Quantizer module (paper §3.2 "Quantizer", Appendix A.3).

The quantizer is the only lossy stage.  Two families:

  * LinearScaleQuantizer — SZ's classic linear-scaling quantizer: equal bins of
    width 2*eb; prediction errors become bin indices; out-of-range points are
    "unpredictable" and stored exactly (raw IEEE bytes), as in SZ1.4/SZ2.
  * UnpredAwareQuantizer — the paper's §4.2 contribution (SZ3-Pastri): the
    same binning, but unpredictable points are exponent-aligned to the error
    bound, converted to integers, and stored in BITPLANE order (MSB plane
    first).  The significant planes are runs of zeros, so the downstream
    lossless stage compresses them well (+20-40% ratio on GAMESS, Table 1).

Vectorization note (TPU adaptation): the paper's ``quantize(data, pred)`` is a
scalar call inside Algorithm 1's loop; here every method is array-at-a-time.
The element order of unpredictable side-storage is the flattened scan order of
each quantize() call, which compression and decompression share, so the
sequential save()/load() semantics of the paper are preserved exactly.

Both an IEEE-float interface (``quantize``/``recover``) and an integer
interface (``prequantize``/``quantize_int_diff``/...) are provided; the latter
serves the dual-quantization Lorenzo path (cuSZ-style, see DESIGN.md §3).
"""
from __future__ import annotations

import abc
from typing import List, Optional, Tuple

import numpy as np

_INT64_MAX = np.iinfo(np.int64).max


# ---------------------------------------------------------------------------
# Bitplane codec (the unpred-aware quantizer's storage format; the device
# analogue is kernels/bitplane — a Pallas 32-lane transpose).
# ---------------------------------------------------------------------------

def bitplane_encode(vals: np.ndarray) -> bytes:
    """Encode int64 values as sign bitmap + MSB->LSB magnitude bitplanes."""
    vals = np.asarray(vals, np.int64).reshape(-1)
    n = vals.size
    header = np.empty(2, np.int64)
    if n == 0:
        header[:] = (0, 0)
        return header.tobytes()
    signs = vals < 0
    mags = np.abs(vals).astype(np.uint64)
    maxmag = int(mags.max())
    nplanes = max(1, maxmag.bit_length())
    header[:] = (n, nplanes)
    chunks = [header.tobytes(), np.packbits(signs).tobytes()]
    # MSB plane first: long zero-runs land together for the lossless stage.
    for p in range(nplanes - 1, -1, -1):
        plane = ((mags >> np.uint64(p)) & np.uint64(1)).astype(np.uint8)
        chunks.append(np.packbits(plane).tobytes())
    return b"".join(chunks)


def bitplane_decode(buf: bytes, offset: int = 0) -> Tuple[np.ndarray, int]:
    """Inverse of :func:`bitplane_encode`; returns (values, bytes_consumed)."""
    header = np.frombuffer(buf, np.int64, count=2, offset=offset)
    n, nplanes = int(header[0]), int(header[1])
    pos = offset + 16
    if n == 0:
        return np.zeros(0, np.int64), pos - offset
    nbytes_plane = (n + 7) // 8
    signs = np.unpackbits(
        np.frombuffer(buf, np.uint8, count=nbytes_plane, offset=pos), count=n
    ).astype(bool)
    pos += nbytes_plane
    mags = np.zeros(n, np.uint64)
    for p in range(nplanes - 1, -1, -1):
        plane = np.unpackbits(
            np.frombuffer(buf, np.uint8, count=nbytes_plane, offset=pos), count=n
        )
        mags |= plane.astype(np.uint64) << np.uint64(p)
        pos += nbytes_plane
    vals = mags.astype(np.int64)
    vals[signs] = -vals[signs]
    return vals, pos - offset


# ---------------------------------------------------------------------------
# Quantizers
# ---------------------------------------------------------------------------

class QuantizerBase(abc.ABC):
    """Array-at-a-time analogue of the paper's QuantizerInterface."""

    name = "abstract"

    def __init__(self, radius: int = 32768):
        self.radius = int(radius)
        self._eb: Optional[float] = None
        self._dtype: Optional[np.dtype] = None
        # compression-side accumulation / decompression-side cursor state
        self._unpred_int: List[np.ndarray] = []
        self._unpred_raw: List[np.ndarray] = []
        self._escape_bits: List[np.ndarray] = []
        self._dec_int: Optional[np.ndarray] = None
        self._dec_raw: Optional[np.ndarray] = None
        self._dec_escape: Optional[np.ndarray] = None
        self._cursor_int = 0
        self._cursor_raw = 0
        self._cursor_esc = 0

    # -- lifecycle ---------------------------------------------------------
    def begin(self, abs_eb: float, dtype) -> None:
        """Reset state for one (de)compression run with a resolved ABS bound."""
        if not np.isfinite(abs_eb) or abs_eb <= 0:
            raise ValueError(f"absolute error bound must be positive, got {abs_eb}")
        self._eb = float(abs_eb)
        self._dtype = np.dtype(dtype)
        self._unpred_int, self._unpred_raw, self._escape_bits = [], [], []
        self._dec_int = self._dec_raw = self._dec_escape = None
        self._cursor_int = self._cursor_raw = self._cursor_esc = 0

    @property
    def eb(self) -> float:
        assert self._eb is not None, "quantizer used before begin()"
        return self._eb

    @property
    def code_dtype(self):
        return np.uint16 if self.radius <= (1 << 15) else np.uint32

    # -- float-domain interface (classic SZ predict->quantize loop) ---------
    def quantize(self, x: np.ndarray, pred: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Quantize prediction errors; returns (codes, reconstruction).

        codes == 0 marks unpredictable points whose payload is accumulated for
        save(); reconstruction is what the decompressor will also compute, so
        feedback predictors can consume it directly.
        """
        eb, r = self.eb, self.radius
        x64 = np.asarray(x, np.float64)
        p64 = np.asarray(pred, np.float64)
        d = x64 - p64
        q = np.rint(d / (2.0 * eb))
        in_range = np.abs(q) < r
        qi = np.where(in_range, q, 0.0).astype(np.int64)
        recon = (p64 + qi.astype(np.float64) * (2.0 * eb)).astype(self._dtype)
        ok = in_range & (np.abs(recon.astype(np.float64) - x64) <= eb)
        codes = np.where(ok, qi + r, 0).astype(self.code_dtype)
        if not ok.all():
            mask = ~ok
            recon = self._store_unpred_float(x64, p64, mask, recon)
        return codes, recon

    def recover(self, pred: np.ndarray, codes: np.ndarray) -> np.ndarray:
        """Reverse of quantize() (paper's ``recover``)."""
        eb, r = self.eb, self.radius
        p64 = np.asarray(pred, np.float64)
        q = codes.astype(np.int64) - r
        recon = (p64 + q.astype(np.float64) * (2.0 * eb)).astype(self._dtype)
        mask = codes == 0
        if mask.any():
            recon = self._load_unpred_float(p64, mask, recon)
        return recon

    # -- integer-domain interface (dual-quantization Lorenzo path) ----------
    def prequantize(self, x: np.ndarray) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """x -> nearest multiple of 2*eb as int64; the only lossy step.

        Returns (qint, recon, fail_mask) where fail positions (bound broken by
        dtype-cast rounding or int64 overflow — pathological eb) must be
        patched with exact values by the caller.
        """
        eb = self.eb
        x64 = np.asarray(x, np.float64)
        scaled = x64 / (2.0 * eb)
        # non-finite inputs have no grid point; routing them through the fail
        # channel stores them exactly (nan/inf round-trip bit-stable) instead
        # of the nan->int64 cast clobbering them
        overflow = ~np.isfinite(scaled) | (np.abs(scaled) >= float(_INT64_MAX // 2))
        q = np.rint(np.where(overflow, 0.0, scaled)).astype(np.int64)
        recon = (q.astype(np.float64) * (2.0 * eb)).astype(self._dtype)
        fail = overflow | (np.abs(recon.astype(np.float64) - x64) > eb)
        return q, recon, fail

    def dequantize_int(self, q: np.ndarray) -> np.ndarray:
        return (q.astype(np.float64) * (2.0 * self.eb)).astype(self._dtype)

    def quantize_int_diff(self, d: np.ndarray) -> np.ndarray:
        """Quantize integer Lorenzo differences; overflow -> unpredictable."""
        r = self.radius
        ok = np.abs(d) < r
        codes = np.where(ok, d + r, 0).astype(self.code_dtype)
        if not ok.all():
            self._store_unpred_int(d[~ok])
        return codes

    def recover_int_diff(self, codes: np.ndarray) -> np.ndarray:
        d = codes.astype(np.int64) - self.radius
        mask = codes == 0
        if mask.any():
            d[mask] = self._load_unpred_int(int(mask.sum()))
        return d

    # -- unpredictable-point storage policy (subclass hook) -----------------
    @abc.abstractmethod
    def _store_unpred_float(self, x64, p64, mask, recon) -> np.ndarray: ...

    @abc.abstractmethod
    def _load_unpred_float(self, p64, mask, recon) -> np.ndarray: ...

    def _store_unpred_int(self, d: np.ndarray) -> None:
        self._unpred_int.append(np.asarray(d, np.int64))

    # -- direct registration/emission for wavefront (scan) predictors -------
    def absorb_unpred(self, x64: np.ndarray, p64: np.ndarray) -> None:
        """Register unpredictable (x, pred) pairs discovered inside a scan.

        The scan applied the reconstruction policy itself; this records the
        payload so save() emits it (positions follow scan order)."""
        mask = np.ones(x64.shape, bool)
        recon = np.zeros(x64.shape, self._dtype)
        self._store_unpred_float(
            np.asarray(x64, np.float64), np.asarray(p64, np.float64), mask, recon
        )

    def emit_unpred_channels(
        self, count: int
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Decompression-side: (q_aligned, escape_mask, raw_values) channels.

        For each unpredictable point in scan order the decoder reconstructs
        ``raw`` where ``escape`` else ``pred + q * 2*eb`` (pred is only known
        inside the decode scan, hence the channel split)."""
        if isinstance(self, LinearScaleQuantizer):
            raw = self._dec_raw[self._cursor_raw : self._cursor_raw + count]
            self._cursor_raw += count
            return (
                np.zeros(count, np.float64),
                np.ones(count, bool),
                np.asarray(raw, np.float64),
            )
        esc = self._dec_escape[self._cursor_esc : self._cursor_esc + count]
        self._cursor_esc += count
        esc = np.asarray(esc, bool)
        n_int = int((~esc).sum())
        n_raw = int(esc.sum())
        q_small = self._load_unpred_int(n_int)
        raw_small = self._dec_raw[self._cursor_raw : self._cursor_raw + n_raw]
        self._cursor_raw += n_raw
        q = np.zeros(count, np.float64)
        raw = np.zeros(count, np.float64)
        q[~esc] = q_small.astype(np.float64)
        raw[esc] = raw_small
        return q, esc, raw

    def _load_unpred_int(self, count: int) -> np.ndarray:
        out = self._dec_int[self._cursor_int : self._cursor_int + count]
        if out.size != count:
            raise ValueError("unpredictable stream exhausted — corrupt payload")
        self._cursor_int += count
        return out

    # -- save/load (paper Appendix A.3) --------------------------------------
    def save(self) -> bytes:
        """Serialize unpredictable payload (+ any subclass metadata)."""
        ints = (
            np.concatenate(self._unpred_int)
            if self._unpred_int
            else np.zeros(0, np.int64)
        )
        raws = (
            np.concatenate(self._unpred_raw)
            if self._unpred_raw
            else np.zeros(0, np.float64)
        )
        escs = (
            np.concatenate(self._escape_bits)
            if self._escape_bits
            else np.zeros(0, np.uint8)
        )
        int_payload = self._encode_int_stream(ints)
        raw_payload = raws.astype(np.float64).tobytes()
        esc_payload = np.packbits(escs).tobytes() if escs.size else b""
        head = np.asarray(
            [len(int_payload), raws.size, escs.size], np.int64
        ).tobytes()
        return head + int_payload + raw_payload + esc_payload

    def load(self, buf: bytes) -> None:
        head = np.frombuffer(buf, np.int64, count=3)
        int_len, n_raw, n_esc = int(head[0]), int(head[1]), int(head[2])
        pos = 24
        self._dec_int = self._decode_int_stream(buf[pos : pos + int_len])
        pos += int_len
        self._dec_raw = np.frombuffer(buf, np.float64, count=n_raw, offset=pos)
        pos += n_raw * 8
        if n_esc:
            nb = (n_esc + 7) // 8
            self._dec_escape = np.unpackbits(
                np.frombuffer(buf, np.uint8, count=nb, offset=pos), count=n_esc
            ).astype(bool)
        else:
            self._dec_escape = np.zeros(0, bool)
        self._cursor_int = self._cursor_raw = self._cursor_esc = 0

    # how the int64 unpredictable stream is laid out — THE subclass difference
    def _encode_int_stream(self, ints: np.ndarray) -> bytes:
        return ints.tobytes()

    def _decode_int_stream(self, payload: bytes) -> np.ndarray:
        return np.frombuffer(payload, np.int64).copy()


class LinearScaleQuantizer(QuantizerBase):
    """SZ1.4/SZ2 linear-scaling quantizer: unpredictables stored as raw IEEE
    values (exact reconstruction, zero further compressibility — the behaviour
    the paper's Fig 3/§4.2 identifies as the ratio bottleneck on GAMESS)."""

    name = "linear"

    def _store_unpred_float(self, x64, p64, mask, recon):
        self._unpred_raw.append(x64[mask])
        recon = recon.copy()
        recon[mask] = x64[mask].astype(self._dtype)
        return recon

    def _load_unpred_float(self, p64, mask, recon):
        count = int(mask.sum())
        vals = self._dec_raw[self._cursor_raw : self._cursor_raw + count]
        if vals.size != count:
            raise ValueError("unpredictable stream exhausted — corrupt payload")
        self._cursor_raw += count
        recon = recon.copy()
        recon[mask] = vals.astype(self._dtype)
        return recon


class UnpredAwareQuantizer(QuantizerBase):
    """Paper §4.2: exponent-align unpredictable prediction errors to the error
    bound, store the resulting integers in MSB->LSB bitplane order.

    Float-domain unpredictables become q = rint((x - pred)/(2*eb)) (error
    <= eb); the rare points where a dtype cast would still break the bound
    escape to raw storage via a 1-bit side channel.  Integer-domain
    unpredictables (dual-quant path) are bitplane-coded directly.
    """

    name = "unpred_aware"

    def _store_unpred_float(self, x64, p64, mask, recon):
        eb = self.eb
        d = x64[mask] - p64[mask]
        scaled = d / (2.0 * eb)
        overflow = np.abs(scaled) >= float(_INT64_MAX // 2)
        q = np.rint(np.where(overflow, 0.0, scaled)).astype(np.int64)
        cand = (p64[mask] + q.astype(np.float64) * (2.0 * eb)).astype(self._dtype)
        bad = overflow | (np.abs(cand.astype(np.float64) - x64[mask]) > eb)
        # escape channel: 1 = raw IEEE value, 0 = bitplane integer
        self._escape_bits.append(bad.astype(np.uint8))
        self._unpred_int.append(q[~bad])
        if bad.any():
            self._unpred_raw.append(x64[mask][bad])
            cand = cand.copy()
            cand[bad] = x64[mask][bad].astype(self._dtype)
        recon = recon.copy()
        recon[mask] = cand
        return recon

    def _load_unpred_float(self, p64, mask, recon):
        count = int(mask.sum())
        esc = self._dec_escape[self._cursor_esc : self._cursor_esc + count]
        if esc.size != count:
            raise ValueError("escape stream exhausted — corrupt payload")
        self._cursor_esc += count
        n_int = int((~esc).sum())
        q = self._load_unpred_int(n_int)
        vals = np.empty(count, np.float64)
        preds = p64[mask]
        vals[~esc] = preds[~esc] + q.astype(np.float64) * (2.0 * self.eb)
        if esc.any():
            n_raw = int(esc.sum())
            raw = self._dec_raw[self._cursor_raw : self._cursor_raw + n_raw]
            self._cursor_raw += n_raw
            vals[esc] = raw
        recon = recon.copy()
        recon[mask] = vals.astype(self._dtype)
        return recon

    def _encode_int_stream(self, ints: np.ndarray) -> bytes:
        return bitplane_encode(ints)

    def _decode_int_stream(self, payload: bytes) -> np.ndarray:
        vals, _ = bitplane_decode(payload)
        return vals


_REGISTRY = {
    "linear": LinearScaleQuantizer,
    "unpred_aware": UnpredAwareQuantizer,
}


def register(name: str, cls) -> None:
    _REGISTRY[name] = cls


def make(name: str, **kw) -> QuantizerBase:
    return _REGISTRY[name](**kw)
