"""Closed-loop quality-targeted rate controller (the "give me 60 dB" mode).

The paper's pipelines consume an error *bound*; users usually hold a quality
*requirement* — a PSNR floor, a compression-ratio target, or a bits-per-value
budget (cf. Liu et al.'s dynamic quality-metric-oriented compression,
arXiv:2310.14133, which searches the error bound online to hit a PSNR/ratio
target).  :class:`QualityCompressor` closes that loop per chunk:

  1. a monotone bisection over the absolute error bound, driven by CHEAP
     models — the analytic uniform-quantization-noise law ``mse ~ eb^2 / 3``
     seeded and then corrected by trial compression of the chunk's ~4k-element
     sample (PSNR targets), or the candidates' ``estimate_error`` code-bits
     entropy model (ratio / bitrate targets; paper §3.2 generalized);
  2. the winning pipeline from ``chunking.select_pipeline`` (prediction AND
     transform families contest) compresses the full chunk at the found
     bound, and the result is CONFIRMED by trial decompression — a chunk that
     misses its quality budget tightens the bound and recompresses (bounded
     retries), so the PSNR floor is guaranteed by measurement, not by model;
  3. each chunk's achieved record (eb, mse, chunk PSNR, coded bits/value,
     iterations) is written into the container's chunk table (``"q"`` key)
     and the global achieved summary into the header (``"quality"`` key).

The emitted container is an ordinary v2 multi-chunk blob — old readers decode
it unchanged and simply ignore the quality records.

PSNR control law: with the global value range R and target P dB, the MSE
budget is ``R^2 * 10^(-P/10)``; holding every chunk's MSE inside
``[AIM_LO, 1.0] x budget`` keeps the global (size-weighted) MSE inside the
same band, i.e. achieved PSNR in ``[P, P - 10*log10(AIM_LO)]`` — with
``AIM_LO = 0.85`` at most ~0.7 dB above target and never below it.  Coders
with step-quantized error (the transform family: power-of-two steps, ~4x MSE
jumps) cannot always park inside that band; for them the confirm loop keeps
the fewest-bits encoding that satisfies the floor, so any quality surplus
above the band is strictly free (never paid for in bits).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import pipeline as pl_mod
from . import telemetry as tel
from .chunking import (
    ChunkRecord,
    _assemble_v2,
    _make_pipeline,
    _parallel_map_ordered,
    _sample_block,
    chunk_slices,
    select_pipeline,
)
from .config import CompressionConfig, ErrorBoundMode
from .pipeline import CompressionResult

# ensure the block-hybrid engine is registered before the candidate set is
# read: the quality controller's contest spans ALL families (prediction,
# transform, block-hybrid)
from . import blockwise as _blockwise  # noqa: F401
from . import transform as _transform


def _auto_candidates() -> Sequence[str]:
    """Late-bound AUTO_CANDIDATES (blockwise.py extends it at import time)."""
    return _transform.AUTO_CANDIDATES

#: chunk-MSE aim band as a fraction of the per-chunk MSE budget: the upper
#: edge is the hard budget (never exceeded after confirmation), the lower
#: edge stops the bisection from over-spending bits on needless accuracy
AIM_LO = 0.85

#: sample-level bisection iterations (each is a ~4k-element trial round trip)
MAX_SAMPLE_ITERS = 14

#: full-chunk confirm-and-tighten retries after the sample bisection
MAX_CONFIRM_ITERS = 4

#: bisection iterations for the code-bits entropy model (ratio/bitrate)
MAX_BITS_ITERS = 18


@dataclasses.dataclass(frozen=True)
class QualityTarget:
    """Exactly one of the three targets must be set.

    psnr:    floor in dB w.r.t. the global value range (SZ convention).
    ratio:   compression ratio vs the stored dtype's raw bytes.
    bitrate: coded bits per value.
    """

    psnr: Optional[float] = None
    ratio: Optional[float] = None
    bitrate: Optional[float] = None

    def __post_init__(self):
        set_ = [k for k in ("psnr", "ratio", "bitrate") if getattr(self, k) is not None]
        if len(set_) != 1:
            raise ValueError(
                f"exactly one quality target must be set, got {set_ or 'none'}"
            )
        if float(getattr(self, set_[0])) <= 0:
            raise ValueError(f"quality target {set_[0]} must be positive")

    @property
    def kind(self) -> str:
        if self.psnr is not None:
            return "psnr"
        return "ratio" if self.ratio is not None else "bitrate"

    def to_header(self) -> Dict[str, Any]:
        return {"kind": self.kind, "value": float(getattr(self, self.kind))}


def _geo_mid(lo: Optional[float], hi: Optional[float], cur: float) -> float:
    """Next bisection point in log space; doubles/halves until bracketed."""
    if lo is not None and hi is not None:
        return math.sqrt(lo * hi)
    return cur * 2.0 if hi is None else cur * 0.5


def _finite_mse(a: np.ndarray, b: np.ndarray) -> float:
    """MSE over the finite positions of ``a`` (the controller's currency).

    Non-finite inputs have no meaningful squared error; the quality guarantee
    (like the REL bound's range statistics) speaks for finite positions.
    """
    a = np.asarray(a, np.float64).reshape(-1)
    b = np.asarray(b, np.float64).reshape(-1)
    fin = np.isfinite(a)
    if not fin.all():
        a, b = a[fin], b[fin]
    if a.size == 0:
        return 0.0
    d = a - b
    return float(np.mean(d * d))


def _psnr_from_mse(rng: float, m: float) -> float:
    """PSNR against a fixed (global) value range, degenerate-safe."""
    if m == 0:
        return float("inf")
    if rng == 0:
        return -10.0 * float(np.log10(m))
    return 20.0 * float(np.log10(rng)) - 10.0 * float(np.log10(m))


class QualityCompressor:
    """Quality-targeted chunked compression (see module docstring).

    Emits a v2 multi-chunk container whose chunk table carries per-chunk
    achieved-quality records and whose header carries the global summary;
    ``CompressionResult.meta`` always exposes both (no ``with_stats`` needed —
    the records are the product of this mode).
    """

    kind = "quality"

    def __init__(
        self,
        target_psnr: Optional[float] = None,
        target_ratio: Optional[float] = None,
        target_bitrate: Optional[float] = None,
        candidates: Optional[Sequence[str]] = None,
        chunk_bytes: int = 1 << 22,
        conf: Optional[CompressionConfig] = None,
        workers: int = 1,
    ):
        self.target = QualityTarget(target_psnr, target_ratio, target_bitrate)
        self.candidates = tuple(
            _auto_candidates() if candidates is None else candidates
        )
        self.chunk_bytes = int(chunk_bytes)
        self.conf = conf or CompressionConfig()
        self.workers = max(1, int(workers))

    # -- per-chunk controller ------------------------------------------------

    def _trial_mse(
        self, comp, sample: np.ndarray, eb: float, base_conf: CompressionConfig
    ) -> float:
        """Measured round-trip MSE of the sample at bound ``eb``."""
        eff = base_conf.replace(mode=ErrorBoundMode.ABS, eb=eb)
        try:
            blob = comp.compress(sample, eff).blob
            return _finite_mse(sample, pl_mod.decompress(blob))
        except Exception:
            return float("inf")  # treated as "too lossy": bisection tightens

    def _eb_for_mse(
        self, chunk: np.ndarray, mse_budget: float, base_conf: CompressionConfig
    ) -> Tuple[float, int]:
        """Sample-level bisection: the largest eb whose measured sample MSE
        sits inside ``[AIM_LO, 1] x mse_budget`` (monotone: MSE grows with
        eb).  Seeded by the uniform-quantization-noise law mse = eb^2/3."""
        if mse_budget <= 0:
            return float(np.finfo(np.float64).tiny), 0
        sample = _sample_block(chunk)
        trial = _make_pipeline("sz3_lorenzo")  # cheapest Algorithm-1 pipeline
        eb = math.sqrt(3.0 * mse_budget * 0.9 * (1 + AIM_LO) / 2)
        lo: Optional[float] = None  # largest eb known too accurate
        hi: Optional[float] = None  # smallest eb known too lossy
        iters = 0
        for iters in range(1, MAX_SAMPLE_ITERS + 1):
            m = self._trial_mse(trial, sample, eb, base_conf)
            if m > mse_budget:
                hi = eb
            elif m < AIM_LO * mse_budget:
                lo = eb
            else:
                break
            nxt = _geo_mid(lo, hi, eb)
            if nxt == eb or nxt <= 0 or not math.isfinite(nxt):
                break
            eb = nxt
            # a chunk can be unreachable from above (e.g. unpredictables
            # stored exactly keep MSE below budget at ANY bound) — stop
            # growing once eb dwarfs the data scale
            if lo is not None and hi is None and eb > 1e6 * math.sqrt(mse_budget):
                break
        return eb, iters

    def _eb_for_bits(
        self, chunk: np.ndarray, bits_target: float, base_conf: CompressionConfig
    ) -> Tuple[float, int]:
        """Bisection over eb against the candidates' code-bits entropy model
        (monotone: estimated bits fall as eb grows)."""
        sample = _sample_block(chunk)
        fin = sample[np.isfinite(sample)]
        scale = float(np.abs(fin).max()) if fin.size else 1.0
        scale = scale or 1.0
        eb_lo, eb_hi = scale * 1e-12, scale * 2.0

        est_fns = []
        for name in self.candidates:
            comp = _make_pipeline(name)
            est_fn = getattr(comp, "estimate_error", None)
            if est_fn is None:
                pred = getattr(comp, "predictor", None)
                est_fn = getattr(pred, "estimate_error", None)
            if est_fn is not None:
                est_fns.append(est_fn)

        def est_bits(eb: float) -> float:
            eff = base_conf.replace(mode=ErrorBoundMode.ABS, eb=eb)
            best = float("inf")
            for est_fn in est_fns:
                try:
                    best = min(best, float(est_fn(sample, eb, eff)))
                except Exception:
                    pass
            return best

        iters = 0
        for iters in range(1, MAX_BITS_ITERS + 1):
            eb = math.sqrt(eb_lo * eb_hi)
            b = est_bits(eb)
            if not math.isfinite(b):
                break
            if abs(b - bits_target) <= 0.05 * bits_target:
                return eb, iters
            if b > bits_target:  # too many bits -> loosen the bound
                eb_lo = eb
            else:
                eb_hi = eb
        return math.sqrt(eb_lo * eb_hi), iters

    def _compress_chunk(
        self,
        chunk: np.ndarray,
        mse_budget: Optional[float],
        bits_target: Optional[float],
        global_rng: float,
        base_conf: CompressionConfig,
    ) -> Tuple[bytes, str, int, Dict[str, Any]]:
        """Controller for ONE chunk: bisect -> select -> compress -> confirm."""
        with tel.suppress_decisions():
            return self._compress_chunk_inner(
                chunk, mse_budget, bits_target, global_rng, base_conf
            )

    def _compress_chunk_inner(
        self,
        chunk: np.ndarray,
        mse_budget: Optional[float],
        bits_target: Optional[float],
        global_rng: float,
        base_conf: CompressionConfig,
    ) -> Tuple[bytes, str, int, Dict[str, Any]]:
        """Body of the controller; decision recording is muted for the whole
        scope — the bisection probes and confirm retries compress the chunk
        repeatedly through contest engines, and only the driver's single
        achieved-quality record per chunk is authoritative."""
        if chunk.size == 0:
            eb, iters = float(np.finfo(np.float64).tiny), 0
        elif mse_budget is not None:
            eb, iters = self._eb_for_mse(chunk, mse_budget, base_conf)
        else:
            eb, iters = self._eb_for_bits(chunk, bits_target, base_conf)
        pipelines = {name: _make_pipeline(name) for name in self.candidates}

        def _compress_at(eb_):
            eff = base_conf.replace(mode=ErrorBoundMode.ABS, eb=eb_)
            name_, _ = select_pipeline(chunk, eb_, eff, self.candidates, pipelines)
            blob_ = pipelines[name_].compress(chunk, eff).blob
            xhat_ = pl_mod.decompress(blob_)
            return name_, blob_, xhat_, _finite_mse(chunk, xhat_)

        name, blob, xhat, m = _compress_at(eb)
        confirms = 0
        if mse_budget is not None and chunk.size:
            # trial-decompress confirmation, BOTH directions.  The bisection
            # trials run the cheap Lorenzo pipeline; the contest winner can
            # be far more accurate at the same bound (transform's power-of-
            # two step quantization moves its MSE in ~4x jumps), so the loop
            # walks eb through the aim band [AIM_LO, 1] x budget and keeps
            # the FEWEST-BITS encoding among those satisfying the floor —
            # surplus quality is only kept when it costs nothing.  The hard
            # floor (m <= budget) is restored unconditionally at the end.
            best = (len(blob), eb, name, blob, xhat, m) if m <= mse_budget else None
            cont = tuple(
                n for n in self.candidates if hasattr(pipelines[n], "preprocessor")
            )
            if cont and not hasattr(pipelines[name], "preprocessor"):
                # a step-quantized winner (transform family) was chosen from
                # sample ESTIMATES; measure the best continuous-eb pipeline
                # at the bisected in-band bound too — when the estimate was
                # optimistic, the continuous coder is both in band and
                # cheaper, and min-bits tracking picks it up
                eff0 = base_conf.replace(mode=ErrorBoundMode.ABS, eb=eb)
                cname, _ = select_pipeline(chunk, eb, eff0, cont, pipelines)
                cblob = pipelines[cname].compress(chunk, eff0).blob
                cxhat = pl_mod.decompress(cblob)
                cm = _finite_mse(chunk, cxhat)
                if cm <= mse_budget and (best is None or len(cblob) < best[0]):
                    best = (len(cblob), eb, cname, cblob, cxhat, cm)
            for _ in range(MAX_CONFIRM_ITERS):
                if m > mse_budget:
                    eb *= math.sqrt(max(mse_budget, 1e-300) * AIM_LO / m)
                elif m < AIM_LO * mse_budget:
                    grow = math.sqrt(0.92 * mse_budget / max(m, mse_budget * 1e-6))
                    eb *= min(8.0, grow)
                else:
                    break
                confirms += 1
                prev_m = m
                name, blob, xhat, m = _compress_at(eb)
                if m <= mse_budget and (best is None or len(blob) < best[0]):
                    best = (len(blob), eb, name, blob, xhat, m)
                if m == prev_m and m < AIM_LO * mse_budget:
                    break  # insensitive to eb (constant / exactly-stored data)
            if best is not None:
                _, eb, name, blob, xhat, m = best
            while m > mse_budget and confirms < MAX_CONFIRM_ITERS + 3:
                confirms += 1
                eb *= math.sqrt(max(mse_budget, 1e-300) * AIM_LO / m)
                name, blob, xhat, m = _compress_at(eb)
        elif bits_target is not None and chunk.size:
            # correction steps from measured bits: each halving of eb costs
            # ~1 coded bit/value on the entropy stage, so jump by the gap
            while confirms < MAX_CONFIRM_ITERS:
                achieved = 8.0 * len(blob) / max(1, chunk.size)
                delta = achieved - bits_target
                if abs(delta) <= 0.12 * bits_target or abs(delta) <= 0.05:
                    break
                confirms += 1
                eb = float(np.clip(eb * 2.0 ** delta, eb / 16, eb * 16))
                eff = base_conf.replace(mode=ErrorBoundMode.ABS, eb=eb)
                name, _ = select_pipeline(chunk, eb, eff, self.candidates, pipelines)
                blob = pipelines[name].compress(chunk, eff).blob
                xhat = pl_mod.decompress(blob)
                m = _finite_mse(chunk, xhat)
        record = {
            "eb": float(eb),
            "mse": float(m),
            "psnr": _psnr_from_mse(global_rng, float(m)),
            "bits": 8.0 * len(blob) / max(1, chunk.size),
            "iters": int(iters),
            "confirms": int(confirms),
        }
        return blob, name, int(chunk.shape[0] if chunk.ndim else chunk.size), record

    # -- driver ---------------------------------------------------------------

    def compress(
        self,
        data: np.ndarray,
        conf: Optional[CompressionConfig] = None,
        with_stats: bool = False,
    ) -> CompressionResult:
        """``conf`` supplies module knobs (block size, interp kind, ...); the
        error bound fields are controller outputs here, so ``conf.mode`` /
        ``conf.eb`` are ignored — the target was fixed at construction."""
        return self._compress(data, conf or self.conf)

    def _compress(self, data: np.ndarray, base_conf: CompressionConfig) -> CompressionResult:
        data = np.asarray(data)
        if data.dtype not in (np.float32, np.float64):
            data = data.astype(np.float32)
        flat_leading = data.reshape(-1) if data.ndim == 0 else data
        fin = flat_leading[np.isfinite(flat_leading)] if flat_leading.size else flat_leading
        global_rng = float(fin.max() - fin.min()) if fin.size else 0.0
        dtype_bits = data.dtype.itemsize * 8
        mse_budget = bits_target = None
        if self.target.kind == "psnr":
            mse_budget = global_rng**2 * 10.0 ** (-float(self.target.psnr) / 10.0)
        elif self.target.kind == "ratio":
            bits_target = dtype_bits / float(self.target.ratio)
        else:
            bits_target = float(self.target.bitrate)

        slices = chunk_slices(
            flat_leading.shape, flat_leading.dtype.itemsize, self.chunk_bytes
        )

        def _one(args):
            i, sl = args
            chunk = flat_leading[sl]
            with tel.span("chunk", order=i, bytes=chunk.nbytes):
                return self._compress_chunk(
                    chunk, mse_budget, bits_target, global_rng, base_conf
                )

        results = list(
            _parallel_map_ordered(_one, enumerate(slices), self.workers)
        )
        records: List[ChunkRecord] = []
        body_parts: List[bytes] = []
        off = 0
        total_se = 0.0
        total_n = 0
        row = (
            int(np.prod(flat_leading.shape[1:], dtype=np.int64))
            if flat_leading.ndim > 1
            else 1
        )
        for i, (blob, name, n0, rec) in enumerate(results):
            records.append(ChunkRecord(off, len(blob), n0, name, extra=rec))
            body_parts.append(blob)
            off += len(blob)
            if tel.enabled():
                # the achieved-quality record (eb/mse/psnr/bits/iters) rides
                # the same decision stream as every other engine's selections
                tel.record_decision(tel.make_decision(
                    "sz3_quality",
                    name,
                    index=i,
                    candidates=list(self.candidates),
                    realized_bits=float(rec["bits"]),
                    n_elems=int(n0) * row,
                    extra={"quality": rec},
                ))
        # size-weighted global achieved quality
        sizes = [
            int(np.prod((r.n0,) + tuple(flat_leading.shape[1:]), dtype=np.int64))
            for r in records
        ]
        for r, n in zip(records, sizes):
            total_se += r.extra["mse"] * n
            total_n += n
        global_mse = total_se / max(1, total_n)
        if global_mse == 0 or total_n == 0:
            achieved_psnr = float("inf")
        elif global_rng == 0:
            achieved_psnr = -10.0 * float(np.log10(global_mse))
        else:
            achieved_psnr = 20.0 * float(np.log10(global_rng)) - 10.0 * float(
                np.log10(global_mse)
            )
        quality = {
            "target": self.target.to_header(),
            "achieved_psnr": float(achieved_psnr),
            "achieved_mse": float(global_mse),
            # placeholders sized like the real values (msgpack float64 is
            # fixed-width), so the container length measured below is final
            "achieved_bits": 0.0,
            "achieved_ratio": 0.0,
            "value_range": float(global_rng),
        }
        conf = base_conf.replace(mode=ErrorBoundMode.ABS, eb=0.0)

        def _assemble():
            return _assemble_v2(
                tuple(data.shape),
                data.dtype,
                records,
                body_parts,
                conf,
                header_extra={"quality": quality},
            )

        # two-pass assembly so the recorded bits/ratio count the WHOLE
        # container (header + chunk table + body), not just the body — at
        # small chunk sizes the per-chunk records are a material share
        total_len = len(_assemble())
        quality["achieved_bits"] = 8.0 * total_len / max(1, total_n)
        quality["achieved_ratio"] = (total_n * data.dtype.itemsize) / max(
            1, total_len
        )
        blob = _assemble()
        assert len(blob) == total_len  # fixed-width floats keep this exact
        meta = {"quality": quality, "chunks": [r.to_header() for r in records]}
        nbytes = data.size * data.dtype.itemsize
        return CompressionResult(
            blob=blob, ratio=nbytes / max(1, len(blob)), meta=meta
        )


def achieved_quality(blob: bytes) -> Optional[Dict[str, Any]]:
    """Read the achieved-quality record back out of a quality container
    (None for containers written by other pipelines)."""
    header, _ = pl_mod.parse_header(blob)
    return header.get("quality")


def sz3_quality(
    target_psnr: Optional[float] = None,
    target_ratio: Optional[float] = None,
    target_bitrate: Optional[float] = None,
    candidates: Optional[Sequence[str]] = None,
    chunk_bytes: int = 1 << 22,
    workers: int = 1,
    **kw,
) -> QualityCompressor:
    """Named factory; a bare ``sz3_quality()`` targets 60 dB PSNR."""
    if target_psnr is None and target_ratio is None and target_bitrate is None:
        target_psnr = 60.0
    return QualityCompressor(
        target_psnr=target_psnr,
        target_ratio=target_ratio,
        target_bitrate=target_bitrate,
        candidates=candidates,
        chunk_bytes=chunk_bytes,
        workers=workers,
        **kw,
    )


# registration (quality imports pipeline/chunking/transform, never vice versa)
pl_mod.PIPELINES["sz3_quality"] = sz3_quality
