"""Deterministic fault injection for container resilience testing.

Mutations model the faults a production ingest path actually sees — storage
bit rot (bit flips), torn/partial writes (truncation, zeroed pages), buffer
mix-ups (spliced bytes from another blob), and hostile/corrupt metadata
(length-field inflation) — applied to REAL containers from every generation.
``tests/test_faults.py`` drives :func:`mutation_grid` across v1–v6 blobs and
enforces the decode contract: correct decode, a typed ``ValueError``
subclass, or a salvage report — never a hang, an unbounded allocation, a raw
``struct.error``/``KeyError``/``IndexError``, or silently wrong bytes when
checksums are on.

Everything here is seeded and pure: ``mutation_grid(blob, seed=0)`` yields
the same mutations for the same blob forever, so a failing grid entry is a
reproducible regression, not a flaky fuzz case.  (The hypothesis fuzz lane
in the test file explores beyond the grid; this module is the deterministic
floor CI always runs.)
"""
from __future__ import annotations

import struct
from typing import Iterator, Tuple

import numpy as np

from . import integrity
from . import pipeline as pl_mod

# ---------------------------------------------------------------------------
# primitive mutations (all pure: bytes in, bytes out)
# ---------------------------------------------------------------------------


def bit_flip(blob: bytes, pos: int, bit: int = 0) -> bytes:
    """Flip one bit at byte ``pos``."""
    if not blob:
        return blob
    pos %= len(blob)
    out = bytearray(blob)
    out[pos] ^= 1 << (bit & 7)
    return bytes(out)


def truncate(blob: bytes, keep: int) -> bytes:
    """Keep only the first ``keep`` bytes (a torn write)."""
    return blob[: max(0, min(len(blob), keep))]


def zero_range(blob: bytes, off: int, length: int) -> bytes:
    """Zero ``length`` bytes starting at ``off`` (a lost page)."""
    if not blob:
        return blob
    off %= len(blob)
    out = bytearray(blob)
    out[off : off + length] = b"\x00" * len(out[off : off + length])
    return bytes(out)


def splice(blob: bytes, off: int, src_off: int, length: int) -> bytes:
    """Overwrite ``length`` bytes at ``off`` with bytes copied from
    ``src_off`` of the SAME blob (a buffer mix-up: plausible-looking but
    wrong content, the case raw structure checks cannot catch)."""
    if len(blob) < 2:
        return blob
    off %= len(blob)
    src_off %= len(blob)
    length = min(length, len(blob) - off, len(blob) - src_off)
    out = bytearray(blob)
    out[off : off + length] = blob[src_off : src_off + length]
    return bytes(out)


def inflate_length(blob: bytes, which: str = "body", factor: int = 1 << 20) -> bytes:
    """Multiply a prologue length field (``"header"`` or ``"body"``) — the
    decompression-bomb / overflow shape: structure intact, size claims
    hostile."""
    if len(blob) < 20:
        return blob
    hlen, blen = struct.unpack_from("<qq", blob, 4)
    if which == "header":
        hlen = max(1, hlen) * factor
    else:
        blen = max(1, blen) * factor
    out = bytearray(blob)
    struct.pack_into("<qq", out, 4, hlen, blen)
    return bytes(out)


def corrupt_chunk(blob: bytes, index: int) -> bytes:
    """Flip a byte in the MIDDLE of chunk ``index``'s body slice — damages
    exactly one chunk of a multi-chunk container, leaving every other chunk
    (and the header, and the trailer) untouched.  The salvage-mode fixture
    generator uses this to pin recovered/lost chunk sets."""
    header, body_off = pl_mod.parse_header(blob)
    body_len = len(pl_mod.container_body(blob, body_off))
    bounds = integrity.chunk_bounds_of(header, body_len)
    off, ln = bounds[index]
    if ln == 0:
        return blob
    return bit_flip(blob, body_off + off + ln // 2, 2)


# ---------------------------------------------------------------------------
# the deterministic grid
# ---------------------------------------------------------------------------

def _regions(blob: bytes) -> dict:
    """(start, stop) of each structural region, best effort."""
    n = len(blob)
    try:
        _, body_off = pl_mod.parse_header(blob)
    except ValueError:
        body_off = min(20, n)
    blen = len(pl_mod.container_body(blob, body_off)) if n >= 20 else 0
    core = body_off + blen
    return {
        "prologue": (0, min(20, n)),
        "header": (min(20, n), body_off),
        "body": (body_off, core),
        "trailer": (core, n),
    }


def mutation_grid(
    blob: bytes, seed: int = 0, flips_per_region: int = 3
) -> Iterator[Tuple[str, bytes]]:
    """Yield ``(name, mutated_blob)`` pairs covering every structural region
    with every mutation class.  Deterministic in (blob, seed).  Mutations
    that happen to be identity (e.g. zeroing an already-zero range) are
    skipped, so every yielded blob really differs from the original."""
    rng = np.random.default_rng(seed)
    regions = _regions(blob)
    for rname, (lo, hi) in regions.items():
        if hi <= lo:
            continue
        for i in range(flips_per_region):
            pos = int(rng.integers(lo, hi))
            bit = int(rng.integers(0, 8))
            yield f"bitflip-{rname}-{i}@{pos}.{bit}", bit_flip(blob, pos, bit)
        span = max(1, (hi - lo) // 4)
        off = int(rng.integers(lo, max(lo + 1, hi - span + 1)))
        mut = zero_range(blob, off, span)
        if mut != blob:
            yield f"zero-{rname}@{off}+{span}", mut
    # torn writes at structurally meaningful cut points
    for rname, (lo, hi) in regions.items():
        if 0 < hi < len(blob):
            yield f"truncate-at-{rname}-end", truncate(blob, hi)
    mid = len(blob) // 2
    if 0 < mid < len(blob):
        yield "truncate-mid", truncate(blob, mid)
    # buffer mix-ups: body bytes overwritten with header bytes and vice versa
    hlo, hhi = regions["header"]
    blo, bhi = regions["body"]
    if hhi > hlo and bhi > blo:
        ln = max(1, min(hhi - hlo, bhi - blo) // 2)
        mut = splice(blob, blo + (bhi - blo) // 3, hlo, ln)
        if mut != blob:
            yield "splice-header-into-body", mut
        mut = splice(blob, hlo + (hhi - hlo) // 3, blo, ln)
        if mut != blob:
            yield "splice-body-into-header", mut
    # hostile length fields
    yield "inflate-body-len", inflate_length(blob, "body")
    yield "inflate-header-len", inflate_length(blob, "header")
    yield "negate-body-len", _negate_len(blob)


def _negate_len(blob: bytes) -> bytes:
    if len(blob) < 20:
        return blob
    out = bytearray(blob)
    hlen, blen = struct.unpack_from("<qq", blob, 4)
    struct.pack_into("<qq", out, 4, hlen, -max(1, blen))
    return bytes(out)
