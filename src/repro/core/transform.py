"""Transform-based coding subsystem: blockwise decorrelation + bitplane coding.

The paper's pipelines are all prediction-based; this module adds the OTHER
coder family of the lossy-compression literature (ZFP-style transform coding,
cf. Tao et al., arXiv:1806.08901 — automatic online selection between SZ and
ZFP), so the per-chunk contest in ``chunking.select_pipeline`` can choose
between prediction and transform per data region:

  1. the array is padded (edge replication) to 4-point blocks per axis and
     each 4^d block is rotated by the orthonormal 4-point DCT-II basis
     (``kernels/transform/ref.MAT``) — smooth or oscillatory content
     concentrates into few low/high-frequency bands;
  2. coefficients are quantized on an EXPONENT-ALIGNED grid: the step is the
     largest power of two such that the worst-case L_inf amplification of the
     inverse basis (``AMP_1AXIS ** ndim``) keeps every reconstructed value
     within the absolute error bound — so integer bitplanes line up with
     absolute error thresholds;
  3. integer coefficients are regrouped band-major (all DC together, etc.;
     the DC band is additionally delta-coded across blocks) and stored as
     MSB-first embedded bitplane streams via ``quantizers.bitplane_encode``
     — per-band truncation: planes above the band's max magnitude are never
     emitted, planes below the error bound never exist;
  4. the rare points where float rounding still breaks the bound (or
     non-finite inputs) are patched through a raw fail channel, exactly like
     the device Lorenzo path — the bound holds unconditionally.

Host path: numpy float64.  Device path (1-D/2-D float32, ``device="auto"`` on
real TPUs / ``"force"`` in tests): the forward/inverse Pallas kernels in
``kernels/transform``; compression verifies reconstruction against the host
inverse AND the kernel inverse and patches stragglers, and decode only takes
the kernel route on the backend whose arithmetic was verified (any other
backend gets the always-verified host inverse) — so the bound is
route-independent.

Containers carry the v3 header tag (``kind: "transform"``); ``pipeline.
decompress`` auto-detects it, and v1/v2 blobs decode unchanged.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import numpy as np

from . import lossless as ll_mod
from . import pipeline as pl_mod
from . import telemetry as tel
from .chunking import DEFAULT_CANDIDATES, ChunkedCompressor
from .config import CompressionConfig, ErrorBoundMode
from .integrity import ContainerError, guard_alloc, guard_count, guard_shape
from .pipeline import CompressionResult, container_body, pack_container
from .predictors import _int_code_bits, _pack_mask, _unpack_mask
from .quantizers import bitplane_decode, bitplane_encode

_VERSION3 = 3
_BLOCK = 4

#: orthonormal 4-point DCT-II basis (rows = frequencies) — the MASTER copy,
#: defined here (pure numpy) so the host path imports without jax; the device
#: kernels (kernels/transform/ref.py) import it from here, keeping all three
#: implementations on one basis so the error-bound analysis transfers.
MAT = np.array(
    [
        [
            (np.sqrt(1.0 / 4.0) if k == 0 else np.sqrt(2.0 / 4.0))
            * np.cos(np.pi * (2 * j + 1) * k / 8.0)
            for j in range(4)
        ]
        for k in range(4)
    ],
    np.float64,
)

#: L_inf error amplification of the 1-axis inverse: max_i sum_k |MAT[k, i]|
AMP_1AXIS = float(np.abs(MAT).sum(axis=0).max())

_INT_SAFE = float(1 << 62)

#: cost-model calibration: the bitplane+generic-lossless stage lands farther
#: from the empirical entropy than the Huffman+zstd stage the prediction
#: pipelines are scored with (sign planes and plane framing are only partly
#: recovered by the lossless pass), so raw band entropies flatter the
#: transform coder in the cross-family contest.  Measured on the bench
#: fixtures the gap is 10-40% depending on plane density; scores carry the
#: low end and ambiguity is resolved by select_pipeline's trial runoff.
_BITPLANE_OVERHEAD = 1.15


# ---------------------------------------------------------------------------
# blockwise separable transform (host path, float64)
# ---------------------------------------------------------------------------

def _apply_axis(x: np.ndarray, m: np.ndarray, ax: int) -> np.ndarray:
    xm = np.moveaxis(x, ax, -1)
    shp = xm.shape
    b = xm.reshape(shp[:-1] + (shp[-1] // _BLOCK, _BLOCK))
    return np.moveaxis((b @ m.T).reshape(shp), -1, ax)


def _fwd_host(x64: np.ndarray) -> np.ndarray:
    out = x64
    for ax in range(out.ndim - 1, -1, -1):  # last axis first (kernel order)
        out = _apply_axis(out, MAT, ax)
    return out


def _inv_host(c64: np.ndarray) -> np.ndarray:
    out = c64
    for ax in range(out.ndim - 1, -1, -1):
        out = _apply_axis(out, MAT.T, ax)
    return out


def _pad_blocks(x: np.ndarray) -> np.ndarray:
    """Edge-replicate to multiples of the block size (keeps edge-block
    coefficients small; zero padding would inject an artificial step)."""
    pads = [(0, (-s) % _BLOCK) for s in x.shape]
    if any(p for _, p in pads):
        x = np.pad(x, pads, mode="edge")
    return x


def _blockify(kp: np.ndarray) -> np.ndarray:
    """Padded grid -> (4^d, nblocks) band-major (all DC together, ...)."""
    d = kp.ndim
    inter = []
    for s in kp.shape:
        inter += [s // _BLOCK, _BLOCK]
    t = kp.reshape(inter)
    order = list(range(1, 2 * d, 2)) + list(range(0, 2 * d, 2))
    return t.transpose(order).reshape(_BLOCK**d, -1)


def _unblockify(bands: np.ndarray, pshape: Tuple[int, ...]) -> np.ndarray:
    d = len(pshape)
    t = bands.reshape((_BLOCK,) * d + tuple(s // _BLOCK for s in pshape))
    order = []
    for i in range(d):
        order += [d + i, i]
    return t.transpose(order).reshape(pshape)


def _step_exponent(abs_eb: float, ndim: int) -> int:
    """Largest power-of-two step with amp^ndim * step/2 <= abs_eb (the
    exponent alignment of the quantization grid)."""
    target = 2.0 * abs_eb / (AMP_1AXIS ** max(1, ndim))
    e = int(np.floor(np.log2(target)))
    return max(-1022, min(1023, e))


def _quantize_coeffs(c: np.ndarray, step: float) -> np.ndarray:
    """Coefficients -> int64 on the aligned grid; overflow positions -> 0
    (they surface as fail-channel points after verification)."""
    with np.errstate(over="ignore", invalid="ignore"):
        scaled = c / step
    bad = ~np.isfinite(scaled) | (np.abs(scaled) >= _INT_SAFE)
    return np.rint(np.where(bad, 0.0, scaled)).astype(np.int64)


def _encode_bands(bands: np.ndarray) -> bytes:
    """Band-major int64 -> concatenated embedded bitplane streams (DC band
    delta-coded across blocks first: neighbouring blocks share their local
    mean, so the DC stream's significant planes become zero-runs too)."""
    parts = []
    for i in range(bands.shape[0]):
        vals = np.diff(bands[i], prepend=0) if i == 0 else bands[i]
        parts.append(bitplane_encode(vals))
    return b"".join(parts)


def _decode_bands(payload: bytes, nbands: int, nblocks: int) -> np.ndarray:
    bands = np.empty((nbands, nblocks), np.int64)
    pos = 0
    for i in range(nbands):
        vals, consumed = bitplane_decode(payload, pos)
        pos += consumed
        if vals.size != nblocks:
            raise ValueError("corrupt transform payload: band size mismatch")
        bands[i] = np.cumsum(vals) if i == 0 else vals
    return bands


# ---------------------------------------------------------------------------
# the compressor
# ---------------------------------------------------------------------------

class TransformCompressor:
    """Blockwise transform coder (the fourth coder family; see module doc)."""

    kind = "transform"

    #: below this many elements the kernel dispatch overhead dominates
    _DEVICE_MIN_SIZE = 4096

    def __init__(
        self,
        lossless: str = "zstd",
        device: str = "auto",
        conf: Optional[CompressionConfig] = None,
    ):
        self.lossless = ll_mod.make(lossless)
        self.device = device
        self.conf = conf or CompressionConfig()

    def spec(self) -> Dict[str, Any]:
        return {"kind": self.kind, "block": _BLOCK, "lossless": self.lossless.name}

    # -- cost model (the select_pipeline criterion) --------------------------
    def estimate_error(
        self, sample: np.ndarray, abs_eb: float, conf: CompressionConfig
    ) -> float:
        """Estimated coded bits/element on a sample — same currency as the
        predictors' ``estimate_error`` (empirical entropy), so the chunked
        engine can contest transform vs prediction pipelines directly."""
        x64 = np.asarray(sample, np.float64)
        if x64.size == 0:
            return 0.0
        if x64.ndim == 0:
            x64 = x64.reshape(1)
        x64 = np.where(np.isfinite(x64), x64, 0.0)
        step = 2.0 ** _step_exponent(abs_eb, x64.ndim)
        bands = _blockify(_quantize_coeffs(_fwd_host(_pad_blocks(x64)), step))
        bits = 0.0
        for i in range(bands.shape[0]):
            vals = np.diff(bands[i], prepend=0) if i == 0 else bands[i]
            bits += _int_code_bits(vals, int(_INT_SAFE))
        return bits / bands.shape[0] * _BITPLANE_OVERHEAD

    # -- device routing ------------------------------------------------------
    def _device_ok(self, x: np.ndarray) -> bool:
        if self.device == "off" or x.ndim not in (1, 2):
            return False
        if x.dtype != np.float32 or x.size < self._DEVICE_MIN_SIZE:
            return False
        try:
            from ..kernels.transform import ops as tops
        except Exception:  # jax/pallas unavailable -> host route
            return False
        return True if self.device == "force" else tops.device_default()

    # -- compress ------------------------------------------------------------
    def compress(
        self,
        data: np.ndarray,
        conf: Optional[CompressionConfig] = None,
        with_stats: bool = False,
    ) -> CompressionResult:
        conf = conf or self.conf
        data = np.asarray(data)
        if data.dtype not in (np.float32, np.float64):
            data = data.astype(np.float32)
        shape = data.shape
        x = data.reshape(1) if data.ndim == 0 else data
        x64 = np.asarray(x, np.float64)
        finite = np.isfinite(x64)
        rng = float(x64[finite].max() - x64[finite].min()) if finite.any() else 0.0
        absmax = float(np.abs(x64[finite]).max()) if finite.any() else 0.0
        abs_eb = conf.resolve_abs_eb(rng, absmax)
        if abs_eb <= 0:
            abs_eb = float(np.finfo(np.float64).tiny)
        meta: Dict[str, Any] = {}
        if x.size == 0:
            header = self._header(shape, x.shape, data.dtype, conf, abs_eb, 0, 0, 0, meta)
            blob = pack_container(header, b"")
            return CompressionResult(blob=blob, ratio=data.nbytes / max(1, len(blob)))
        xc = np.where(finite, x64, 0.0)
        xp = _pad_blocks(xc)
        e = _step_exponent(abs_eb, xp.ndim)
        step = 2.0**e

        device = self._device_ok(np.asarray(x))
        if device:
            from ..kernels.transform import ops as tops

            with tel.span("device_transfer", bytes=xp.nbytes):
                c = np.asarray(tops.fwd_pipeline(xp.astype(np.float32)), np.float64)
        else:
            with tel.span("predict", bytes=xp.nbytes):  # decorrelating stage
                c = _fwd_host(xp)
        with tel.span("quantize", bytes=c.nbytes):
            k = _quantize_coeffs(c, step)

        # verify against every decode route — POST output-dtype cast, since
        # decode rounds the float64 reconstruction onto the storage grid and
        # that rounding alone can push a value past the bound (fail-channel
        # patches survive the cast exactly: they carry the original values);
        # stragglers ride the fail channel
        crop = tuple(slice(0, s) for s in x.shape)
        recon = _inv_host(k.astype(np.float64) * step)[crop]
        recon_cast = recon.astype(data.dtype).astype(np.float64)
        fail = ~finite | (np.abs(recon_cast - x64) > abs_eb)
        if device:
            from ..kernels.transform import ops as tops

            recon_dev = np.asarray(
                tops.inv_pipeline((k.astype(np.float64) * step).astype(np.float32)),
                np.float64,
            )[crop].astype(data.dtype).astype(np.float64)
            fail |= np.abs(recon_dev - x64) > abs_eb
            meta["device"] = 1
            # the kernel-inverse verification above only covers THIS
            # backend's arithmetic; decode takes the device route only when
            # it runs on the same backend, else the (always-verified) host
            # float64 inverse
            meta["device_backend"] = _jax_backend()
        meta["nfail"] = int(fail.sum())
        if meta["nfail"]:
            meta["fail_mask"] = _pack_mask(fail)
            meta["fail_vals"] = x64[fail].tobytes()

        bands = _blockify(k)
        with tel.span("huffman", bytes=bands.nbytes):  # bitplane coding stage
            payload = _encode_bands(bands)
        with tel.span("lossless", bytes=len(payload)):
            body = self.lossless.compress(payload)
        header = self._header(
            shape, xp.shape, data.dtype, conf, abs_eb, e, bands.shape[0],
            bands.shape[1], meta,
        )
        # declared plaintext size: lets decode bound the lossless inflation
        # (decompression-bomb guard); absent on pre-integrity v3 blobs
        header["payload_len"] = len(payload)
        blob = pack_container(header, body)
        return CompressionResult(
            blob=blob,
            ratio=data.nbytes / max(1, len(blob)),
            codes=bands if with_stats else None,
            meta=meta if with_stats else None,
        )

    def _header(
        self, shape, pshape, dtype, conf, abs_eb, step_exp, nbands, nblocks, meta
    ) -> Dict[str, Any]:
        return {
            "v": _VERSION3,
            "kind": self.kind,
            "spec": self.spec(),
            "shape": list(shape),
            "pshape": list(pshape),
            "dtype": np.dtype(dtype).str,
            "mode": conf.mode.value,
            "eb": float(conf.eb),
            "abs_eb": float(abs_eb),
            "step_exp": int(step_exp),
            "nbands": int(nbands),
            "nblocks": int(nblocks),
            "meta": pl_mod._clean_meta(meta),
        }

    # -- decompress ----------------------------------------------------------
    @staticmethod
    def _decompress_body(blob: bytes, header: Dict[str, Any], body_off: int) -> np.ndarray:
        spec = header["spec"]
        dtype = np.dtype(header["dtype"])
        shape = guard_shape(header["shape"], dtype.itemsize, "shape")
        pshape = guard_shape(header["pshape"], 8, "pshape")
        meta = header.get("meta") or {}
        nbands = guard_count(header["nbands"], 1 << 20, "nbands")
        nblocks = guard_count(header["nblocks"], 1 << 40, "nblocks")
        guard_alloc(nbands * nblocks * 8, "band grid")
        if nblocks == 0:
            return np.zeros(shape, dtype)
        backend = ll_mod.make(spec["lossless"])
        raw = container_body(blob, body_off)
        payload_len = header.get("payload_len")
        if payload_len is not None:
            payload_len = guard_alloc(payload_len, "payload_len")
            payload = backend.decompress_bounded(raw, payload_len)
            if len(payload) != payload_len:
                raise ContainerError(
                    f"transform body decompressed to {len(payload)} bytes; "
                    f"header declares {payload_len}"
                )
        else:  # pre-integrity v3 blob: no declared plaintext size
            payload = backend.decompress(raw)
        bands = _decode_bands(payload, nbands, nblocks)
        k = _unblockify(bands, pshape)
        step = 2.0 ** int(header["step_exp"])
        crop = tuple(slice(0, s) for s in (shape if shape else (1,)))
        if (
            meta.get("device")
            and meta.get("device_backend") == _jax_backend()
            and _decode_device_ok(pshape)
        ):
            from ..kernels.transform import ops as tops

            out = np.asarray(
                tops.inv_pipeline((k.astype(np.float64) * step).astype(np.float32)),
                np.float64,
            )[crop]
        else:
            out = _inv_host(k.astype(np.float64) * step)[crop]
        if meta.get("nfail"):
            n = int(np.prod(shape)) if shape else 1
            mask = _unpack_mask(meta["fail_mask"], n).reshape(out.shape)
            out = out.copy()
            out[mask] = np.frombuffer(meta["fail_vals"], np.float64)
        return out.astype(dtype).reshape(shape)


def _jax_backend() -> Optional[str]:
    """The active jax backend name, or None when jax is unavailable."""
    try:
        import jax

        return str(jax.default_backend())
    except Exception:
        return None


def _decode_device_ok(pshape: Tuple[int, ...]) -> bool:
    """Fused inverse on decode: real-TPU backends only, and only for blobs
    whose compress-time verification ran the same backend's kernel
    arithmetic (the caller checks ``device_backend``); every other blob
    takes the host float64 inverse, which compress always verifies."""
    if len(pshape) not in (1, 2):
        return False
    try:
        from ..kernels.transform import ops as tops
    except Exception:
        return False
    return tops.device_default()


# ---------------------------------------------------------------------------
# named pipelines: the transform family + the hybrid auto candidate set
# ---------------------------------------------------------------------------

def sz3_transform(lossless: str = "zstd", device: str = "auto") -> TransformCompressor:
    """Pure transform coder (ZFP-family analogue)."""
    return TransformCompressor(lossless=lossless, device=device)


#: prediction AND transform entrants — the online SZ/ZFP selection criterion.
#: blockwise.py appends "sz3_hybrid" at import time, so consumers must read
#: this at CALL time (late binding), never capture it in a default argument.
AUTO_CANDIDATES: Tuple[str, ...] = DEFAULT_CANDIDATES + ("sz3_transform",)


def sz3_auto(
    candidates=None,
    chunk_bytes: int = 1 << 22,
    workers: int = 1,
    **kw,
) -> ChunkedCompressor:
    """Chunked engine contesting prediction vs transform (vs block-hybrid)
    per chunk.  ``candidates=None`` resolves ``AUTO_CANDIDATES`` at call
    time so late-registered engines join the contest."""
    return ChunkedCompressor(
        candidates=AUTO_CANDIDATES if candidates is None else candidates,
        chunk_bytes=chunk_bytes,
        workers=workers,
        **kw,
    )


# registration happens here (transform imports pipeline, not vice versa)
pl_mod.PIPELINES["sz3_transform"] = sz3_transform
pl_mod.PIPELINES["sz3_auto"] = sz3_auto
