"""Pure-jax codec facade: the SZ3 block-predictor contest inside jit/shard_map.

The host engines (``sz3_chunked``/``sz3_hybrid``/``sz3_fast``…) buy ratio with
entropy coding and self-describing byte containers — neither traces under
``jax.jit``, so the in-training compression paths (gradient all-gather,
optimizer moments, KV-cache prefill) historically hand-rolled their own int8
block quantizer and never saw the paper's composability.  This module is the
jit-friendly face of the framework: the same per-block predictor contest as
``sz3_hybrid`` (zero / Lorenzo-1 / mean-centered), priced by the same
code-bits currency, emitting SZx-style fixed-length codes (``core/fastmode``'s
coding discipline) as plain arrays that compose with ``shard_map`` collectives
and ``jit`` donation.

Two tiers:

  * **fixed tier** (:func:`encode` / :func:`decode`) — fixed ``bits``-wide
    codes (int8, or int4 packed two-per-byte) with a per-block scale adapted
    to the selected predictor's residual range.  The bound contract is
    per-block: ``|x - x̂| <= BlockCodes.bound()`` with the scale
    ``snap(max(absmax_resid_b / radius, 2*eb, SCALE_FLOOR))`` — the
    paper's value-range-relative (REL) mode at block granularity, with
    ``eb`` acting as an absolute grid floor and the mantissa-grid snap
    buying exact decode arithmetic (see :func:`_snap_scale`).  Codes never
    clip (the scale absorbs the range), so the bound is unconditional for
    finite inputs.
    This is the wire tier: bytes on the all-gather are
    ``bits/8`` per element plus three small per-block side channels.
  * **grid tier** (:func:`encode_grid` / :func:`decode_grid`) — int32 codes
    on the fixed ``2*eb`` grid: the exact ABS bound of the host engines,
    for consumers that need ``eb`` honored pointwise rather than per-block
    REL.  Exact while ``|x - base| / (2*eb) < 2**23`` (float32 integer
    window); the host engines remain the fallback beyond it.

Predictor selection: per block, the winner minimizes the fixed-length coded
bits on the quantization grid — ``bs * (bitlength(max|q_p|) + 1)`` — which is
the fixed-length analog of ``sz3_hybrid``'s ``_int_code_bits`` pricing (an
entropy coder prices the bin population; a fixed-length coder's price IS its
width).  All predictors carry the same side-channel cost (base + scale +
tag), so the argmin reduces to the smallest radius-normalized residual range.
The three predictors mirror the hybrid engine's in-graph-representable
subset:

  * ``zero``     — codes the value itself (``base = 0``);
  * ``lorenzo1`` — order-1 Lorenzo along the block, dual-quantized via the
    integer-grid trick (``q_i = t_i - t_{i-1}``, ``t = rint((x-x_0)/scale)``)
    so decode's integer cumsum reconstructs ``t`` exactly; ``base`` stores
    the block's first element;
  * ``mean``     — mean-centered coding with the center stored per block.
    The center is the block *midrange* ``(min+max)/2`` rather than the
    arithmetic mean: it strictly minimizes the residual absmax (what the
    scale — and therefore the bound — is built from), and min/max reductions
    are order-exact in floating point, which keeps the whole encoder
    bit-deterministic across jit / eager / the numpy host path (a float sum
    is not reassociation-stable, so an arithmetic mean would break the
    jit-vs-host bit-identity contract that tests pin).

Every reduction used (max, min, abs-max) is order-exact and every elementwise
op is correctly rounded, so ``jit(encode)``, eager ``encode``, and the numpy
reference ``encode_host`` produce bit-identical codes — pinned by
``tests/test_jitmode.py``.

Host fallback: anything outside a jit region that wants the *prediction*
engines (entropy-coded containers, integrity trailers, random access) should
route through the registry — :func:`host_compress` / :func:`host_decompress`
are the facade's thin door to ``pipeline.PIPELINES`` for exactly that
(``ft/checkpoint.py`` is the house consumer).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

SCALE_FLOOR = 1e-12

#: predictor name -> tag (the 2-bit side-channel vocabulary, hybrid's idiom)
PREDICTOR_TAGS = {"zero": 0, "lorenzo1": 1, "mean": 2}
_TAG_NAMES = {v: k for k, v in PREDICTOR_TAGS.items()}

#: grid-tier codes are clipped here (same guard as fastmode's ``_Q_CLIP``)
_GRID_CLIP = 1 << 30


# ---------------------------------------------------------------------------
# policy
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class JitPolicy:
    """In-loop compression policy: (mode, eb, tier) as one parseable knob.

    ``tier`` picks the container width of the fixed tier (``int8`` /
    ``int4``) or the exact-grid tier (``grid``).  ``mode`` names the bound
    semantics: ``rel`` (per-block REL, ``eb`` only floors the grid) or
    ``abs`` (``eb`` is the grid: fixed tier floors the scale at ``2*eb``,
    grid tier honors it pointwise).
    """

    tier: str = "int8"  # "int8" | "int4" | "grid"
    mode: str = "rel"  # "rel" | "abs"
    eb: float = 0.0
    bs: int = 512
    predictors: Tuple[str, ...] = ("zero", "lorenzo1", "mean")

    def __post_init__(self):
        if self.tier not in ("int8", "int4", "grid"):
            raise ValueError(f"unknown jit codec tier {self.tier!r}")
        if self.mode not in ("rel", "abs"):
            raise ValueError(f"unknown bound mode {self.mode!r}")
        if self.tier == "grid" and self.eb <= 0:
            raise ValueError("grid tier needs a positive eb")
        if self.bs < 2:
            raise ValueError("block size must be >= 2")
        if self.bs > 8192:
            # _snap_scale's exact-product budget: 3 + bits(bs*radius) <= 24
            raise ValueError("block size above 8192 breaks exact decode")
        if self.tier == "int4" and self.bs % 2:
            raise ValueError("int4 packing needs an even block size")
        bad = set(self.predictors) - set(PREDICTOR_TAGS)
        if bad or not self.predictors:
            raise ValueError(f"unknown predictors {sorted(bad)}")

    @property
    def bits(self) -> int:
        return {"int8": 8, "int4": 4, "grid": 32}[self.tier]

    @property
    def radius(self) -> int:
        return 127 if self.tier == "int8" else 7

    @classmethod
    def parse(cls, spec: str) -> "JitPolicy":
        """Parse ``"int8"``, ``"int4:eb=1e-5"``,
        ``"int8:mode=abs:eb=1e-3:bs=256:pred=zero+lorenzo1"``."""
        parts = [p for p in str(spec).split(":") if p]
        if not parts:
            raise ValueError("empty compression policy")
        kw: Dict[str, Any] = {"tier": parts[0]}
        for part in parts[1:]:
            if "=" not in part:
                raise ValueError(f"policy field {part!r} is not key=value")
            k, v = part.split("=", 1)
            if k == "eb":
                kw["eb"] = float(v)
            elif k == "bs":
                kw["bs"] = int(v)
            elif k == "mode":
                kw["mode"] = v
            elif k == "pred":
                kw["predictors"] = tuple(v.split("+"))
            else:
                raise ValueError(f"unknown policy field {k!r}")
        return cls(**kw)


# ---------------------------------------------------------------------------
# code containers (pytrees: compose with shard_map collectives / donation)
# ---------------------------------------------------------------------------

@partial(
    jax.tree_util.register_dataclass,
    data_fields=["codes", "scale", "tags", "base"],
    meta_fields=["n", "bits", "bs"],
)
@dataclasses.dataclass
class BlockCodes:
    """Fixed-tier codes for one flat vector (all leaves gatherable arrays).

    ``codes`` is int8 ``(nb, bs)``, or uint8 ``(nb, bs//2)`` when
    ``bits == 4`` (two two's-complement nibbles per byte, low nibble first).
    """

    codes: jnp.ndarray
    scale: jnp.ndarray  # f32 (nb,)
    tags: jnp.ndarray  # uint8 (nb,), PREDICTOR_TAGS values
    base: jnp.ndarray  # f32 (nb,): 0 / first element / midrange
    n: int  # valid elements (tail block padding cropped on decode)
    bits: int
    bs: int

    def wire_bytes(self) -> int:
        """Bytes this shard contributes to a code all-gather."""
        return sum(
            int(np.prod(a.shape)) * np.dtype(a.dtype).itemsize
            for a in (self.codes, self.scale, self.tags, self.base)
        )

    def bound(self) -> jnp.ndarray:
        """Per-block error bound: ``scale/2`` plus float32 representation
        slack.

        The reconstruction is assembled as ``base + scale*q`` in float32, so
        a few ulps of the addends sit on top of the quantization half-grid —
        when the Lorenzo predictor codes fine structure riding a large
        offset (its prime case), the half-ulp of ``|base|`` can exceed the
        half-grid itself and is physically unavoidable (the true value and
        its reconstruction are both float32 near ``base``).  The slack term
        is ``2**-22 * (|base| + scale*max|q_sum|)`` per block: four ulps of
        each addend, computed from the actual codes.  Zero-predictor blocks
        (``base == 0``) pay essentially none.
        """
        mag = _sel_magnitude(self.codes, self.tags, self.bits)
        slack = (jnp.abs(self.base) + self.scale * mag) * jnp.float32(2.0**-22)
        return self.scale * 0.5 + slack


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["codes", "tags", "base"],
    meta_fields=["n", "eb", "bs"],
)
@dataclasses.dataclass
class GridCodes:
    """Grid-tier codes: int32 on the fixed ``2*eb`` grid (ABS bound)."""

    codes: jnp.ndarray  # int32 (nb, bs)
    tags: jnp.ndarray  # uint8 (nb,)
    base: jnp.ndarray  # f32 (nb,)
    n: int
    eb: float
    bs: int

    def bound(self) -> jnp.ndarray:
        """Per-block ``eb`` plus the same float32 representation slack as
        :meth:`BlockCodes.bound` (see there) — the grid value is exact but
        its float32 assembly ``base + 2*eb*q`` is not."""
        mag = _sel_magnitude(self.codes, self.tags, 32)
        grid = jnp.float32(2.0 * self.eb)
        slack = (jnp.abs(self.base) + grid * mag) * jnp.float32(2.0**-22)
        return jnp.float32(self.eb) + slack


# ---------------------------------------------------------------------------
# block plumbing
# ---------------------------------------------------------------------------

def _snap_scale(x: jnp.ndarray) -> jnp.ndarray:
    """Snap x > 0 up to the 3-bit-mantissa grid ``(k/8) * 2**e``, k in 4..8.

    The fixed tier snaps its scale onto this grid so the decode product
    ``scale * q`` is EXACT in float32: ``k * q`` needs at most 3 + 21 bits
    for any admissible block (|q_sum| <= bs * radius <= 8192 * 127 < 2**21),
    and a small-integer times a power of two never rounds.  Then whether the
    compiler contracts ``base + scale*q`` into an fma or not, the result is
    bit-identical by IEEE semantics — which is what makes the jit/eager/
    numpy bit-identity contract robust rather than a property of one XLA
    version's fusion choices (XLA elides optimization_barrier on CPU and
    LLVM contracts mul+add even through a select, so there is no reliable
    compiler-level hammer).  Cost: the snapped scale is at most 8/7 of the
    tightest admissible one (~0.2 bits of bound looseness), reflected
    honestly in ``bound()`` which is defined off the stored scale.
    """
    m, e = jnp.frexp(x)  # x = m * 2**e, m in [0.5, 1)
    k = jnp.ceil(m * 8.0)  # 4..8; exact (pow2 multiply, integral ceil)
    return jnp.ldexp(k.astype(jnp.float32), e - 3)


def _sel_magnitude(codes, tags, bits) -> jnp.ndarray:
    """Per-block max integer magnitude of the reconstruction term
    (``max|q|`` direct, ``max|cumsum q|`` under Lorenzo) — feeds the
    representation-slack term of the bound helpers."""
    q = _unpack_int4(codes) if bits == 4 else codes.astype(jnp.int32)
    lor = jnp.cumsum(q, axis=-1)
    sel = jnp.where((tags == PREDICTOR_TAGS["lorenzo1"])[..., None], lor, q)
    if sel.shape[-1] == 0:
        return jnp.zeros(sel.shape[:-1], jnp.float32)
    return jnp.max(jnp.abs(sel), axis=-1).astype(jnp.float32)

def _block_view(x: jnp.ndarray, bs: int) -> Tuple[jnp.ndarray, int]:
    """(nb, bs) f32 view of a flat vector, tail padded with the edge value
    (the pad rides the tail block's statistics and is cropped on decode)."""
    n = x.shape[0]
    nb = -(-n // bs) if n else 0
    pad = nb * bs - n
    x = x.astype(jnp.float32)
    if pad:
        x = jnp.pad(x, (0, pad), mode="edge")
    return x.reshape(nb, bs), nb


def _block_stats(xb: jnp.ndarray):
    """Order-exact per-block statistics all three predictors price from."""
    prev = jnp.concatenate([xb[..., :1] * 0, xb[..., :-1]], axis=-1)
    d = (xb - prev).at[..., 0].set(0.0)  # first code is 0 under lorenzo1
    a_lor = jnp.max(jnp.abs(d), axis=-1)
    a_zero = jnp.max(jnp.abs(xb), axis=-1)
    mx = jnp.max(xb, axis=-1)
    mn = jnp.min(xb, axis=-1)
    a_mean = (mx - mn) * 0.5
    center = (mx + mn) * 0.5
    return a_zero, a_lor, a_mean, center


def _select(
    a_zero, a_lor, a_mean, predictors: Sequence[str], radius: int
) -> jnp.ndarray:
    """argmin of radius-normalized residual range == argmin fixed-length
    code bits (all side channels cost the same, see module docstring).

    The normalization multiplies by a float32 reciprocal instead of
    dividing: XLA strength-reduces division by a non-power-of-two constant
    differently inside a fused jit graph than in eager dispatch, which
    would put jit and eager one ulp apart on the selected scale — an
    explicit reciprocal multiply is the same op everywhere (including the
    numpy host mirror), keeping the encoder bit-deterministic.
    """
    cost = {
        # lorenzo keeps one code of headroom: |t_i - t_{i-1}| can exceed
        # |d_i|/scale by the two rints' crossterm, so its scale normalizes
        # by radius-1 — priced identically so selection sees the true bound
        "zero": a_zero * np.float32(1.0 / radius),
        "lorenzo1": a_lor * np.float32(1.0 / (radius - 1)),
        "mean": a_mean * np.float32(1.0 / radius),
    }
    enabled = [(PREDICTOR_TAGS[p], cost[p]) for p in predictors]
    stack = jnp.stack([c for _, c in enabled], axis=-1)
    # the floor makes subnormal-range blocks tie exactly: XLA flushes
    # subnormal intermediates to zero inconsistently between jit and eager,
    # so comparing raw sub-1e-38 costs would let the argmin disagree across
    # paths; floored ties resolve to the first enabled predictor everywhere
    stack = jnp.maximum(stack, jnp.float32(SCALE_FLOOR))
    pick = jnp.argmin(stack, axis=-1)  # first min wins: deterministic ties
    tag_map = jnp.asarray([t for t, _ in enabled], jnp.uint8)
    return tag_map[pick], jnp.min(stack, axis=-1)


def _pack_int4(codes: jnp.ndarray) -> jnp.ndarray:
    """int8 codes in [-8, 7] -> uint8 nibbles, low nibble = even element."""
    u = codes.astype(jnp.uint8)
    lo = u[..., 0::2] & 0xF
    hi = u[..., 1::2] & 0xF
    return lo | (hi << 4)


def _unpack_int4(packed: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`_pack_int4` -> int32 codes."""
    lo = (packed & 0xF).astype(jnp.int32)
    hi = ((packed >> 4) & 0xF).astype(jnp.int32)
    lo = jnp.where(lo > 7, lo - 16, lo)
    hi = jnp.where(hi > 7, hi - 16, hi)
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(out.shape[:-2] + (-1,))


# ---------------------------------------------------------------------------
# fixed tier
# ---------------------------------------------------------------------------

def encode_blocks(
    xb: jnp.ndarray, policy: JitPolicy
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Core fixed-tier encoder on pre-blocked data ``(..., nb, bs)``.

    Returns ``(codes, scale, tags, base)`` with leading dims preserved —
    the flat-vector :func:`encode` and the shaped consumers
    (``compression/opt_state.py``) both sit on top of this.
    """
    radius = policy.radius
    xb = xb.astype(jnp.float32)
    a_zero, a_lor, a_mean, center = _block_stats(xb)
    tags, a_eff = _select(a_zero, a_lor, a_mean, policy.predictors, radius)
    scale = _snap_scale(
        jnp.maximum(a_eff, jnp.float32(max(2.0 * policy.eb, SCALE_FLOOR)))
    )
    base = jnp.where(
        tags == PREDICTOR_TAGS["lorenzo1"],
        xb[..., 0],
        jnp.where(tags == PREDICTOR_TAGS["mean"], center, 0.0),
    )
    t = jnp.rint((xb - base[..., None]) / scale[..., None])
    prev_t = jnp.concatenate([t[..., :1] * 0, t[..., :-1]], axis=-1)
    codes = jnp.where(
        (tags == PREDICTOR_TAGS["lorenzo1"])[..., None], t - prev_t, t
    )
    codes = jnp.clip(codes, -radius, radius).astype(jnp.int8)
    if policy.bits == 4:
        codes = _pack_int4(codes)
    return codes, scale, tags, base


def decode_blocks(
    codes: jnp.ndarray,
    scale: jnp.ndarray,
    tags: jnp.ndarray,
    base: jnp.ndarray,
    bits: int,
) -> jnp.ndarray:
    """Inverse of :func:`encode_blocks` -> f32 blocks ``(..., nb, bs)``."""
    q = _unpack_int4(codes) if bits == 4 else codes.astype(jnp.int32)
    lor = jnp.cumsum(q, axis=-1)  # integer cumsum: reconstructs t exactly
    sel = jnp.where((tags == PREDICTOR_TAGS["lorenzo1"])[..., None], lor, q)
    # scale is on the 3-bit mantissa grid (see _snap_scale), so this product
    # is exact and the sum single-rounded whether or not XLA contracts to fma
    return base[..., None] + scale[..., None] * sel.astype(jnp.float32)


def encode(x: jnp.ndarray, policy: JitPolicy):
    """Encode a flat vector (jit/shard_map-safe); dispatches on tier."""
    if policy.tier == "grid":
        return encode_grid(x, policy)
    flat = x.reshape(-1)
    xb, _nb = _block_view(flat, policy.bs)
    codes, scale, tags, base = encode_blocks(xb, policy)
    return BlockCodes(
        codes=codes,
        scale=scale,
        tags=tags,
        base=base,
        n=int(flat.shape[0]),
        bits=policy.bits,
        bs=policy.bs,
    )


def decode(c) -> jnp.ndarray:
    """Flat f32 reconstruction, tail padding cropped."""
    if isinstance(c, GridCodes):
        return decode_grid(c)
    xb = decode_blocks(c.codes, c.scale, c.tags, c.base, c.bits)
    return xb.reshape(-1)[: c.n]


def encode_lastaxis(x: jnp.ndarray, policy: JitPolicy):
    """Block the LAST axis of a shaped array and encode each block.

    Returns ``(codes, scale, tags, base, orig_last)`` with leading dims
    preserved (codes ``(*lead, nb, bs_or_packed)``, side channels
    ``(*lead, nb)``) — the shaped-consumer entry point (optimizer moments
    keep the parameter's leading shape so PartitionSpecs apply unchanged;
    KV prefill keeps ``(..., tokens)`` leading dims).
    """
    x = x.astype(jnp.float32)
    last = x.shape[-1]
    pad = (-last) % policy.bs
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)], mode="edge")
    nb = x.shape[-1] // policy.bs
    xb = x.reshape(x.shape[:-1] + (nb, policy.bs))
    codes, scale, tags, base = encode_blocks(xb, policy)
    return codes, scale, tags, base, last


def decode_lastaxis(codes, scale, tags, base, orig_last: int, bits: int):
    """Inverse of :func:`encode_lastaxis` -> ``(*lead, orig_last)`` f32."""
    xb = decode_blocks(codes, scale, tags, base, bits)
    return xb.reshape(xb.shape[:-2] + (-1,))[..., :orig_last]


# ---------------------------------------------------------------------------
# grid tier (exact ABS bound)
# ---------------------------------------------------------------------------

def encode_grid(x: jnp.ndarray, policy: JitPolicy) -> GridCodes:
    """Int32 codes on the fixed ``2*eb`` grid: ``|x - x̂| <= eb`` pointwise
    while ``|x - base|/(2*eb) < 2**23`` (see module docstring)."""
    if policy.eb <= 0:
        raise ValueError("grid tier needs a positive eb")
    flat = x.reshape(-1)
    xb, _nb = _block_view(flat, policy.bs)
    a_zero, a_lor, a_mean, center = _block_stats(xb)
    # same argmin, unnormalized: grid width is common so code bits are
    # monotone in the residual range
    tags, _ = _select(a_zero, a_lor, a_mean, policy.predictors, 2)
    base = jnp.where(
        tags == PREDICTOR_TAGS["lorenzo1"],
        xb[..., 0],
        jnp.where(tags == PREDICTOR_TAGS["mean"], center, 0.0),
    )
    inv = jnp.float32(1.0 / (2.0 * policy.eb))
    t = jnp.rint((xb - base[..., None]) * inv)
    t = jnp.clip(t, -_GRID_CLIP, _GRID_CLIP).astype(jnp.int32)
    prev_t = jnp.concatenate([t[..., :1] * 0, t[..., :-1]], axis=-1)
    codes = jnp.where(
        (tags == PREDICTOR_TAGS["lorenzo1"])[..., None], t - prev_t, t
    )
    return GridCodes(
        codes=codes,
        tags=tags,
        base=base,
        n=int(flat.shape[0]),
        eb=float(policy.eb),
        bs=policy.bs,
    )


def decode_grid(c: GridCodes) -> jnp.ndarray:
    q = c.codes
    lor = jnp.cumsum(q, axis=-1)
    sel = jnp.where((c.tags == PREDICTOR_TAGS["lorenzo1"])[..., None], lor, q)
    # unlike the fixed tier, the 2*eb grid is an arbitrary float, so the
    # product can round and a contracted fma may differ from the eager /
    # numpy path by one ulp — the grid tier therefore guarantees bit
    # identity for ENCODE (the wire format) and the bound for decode, not
    # cross-path decode bit identity (tests pin exactly that asymmetry)
    xb = c.base[..., None] + jnp.float32(2.0 * c.eb) * sel.astype(jnp.float32)
    return xb.reshape(-1)[: c.n]


def grid_code_bits(c: GridCodes) -> float:
    """Fixed-length coded size of a grid-tier result in bits/element — the
    accounting the bench rows report (per-block width = bitlength(max|q|),
    plus the base/tag/width side channels)."""
    q = np.asarray(c.codes)
    if q.size == 0:
        return 0.0
    m = np.abs(q).max(axis=-1).astype(np.int64)
    w = np.zeros(m.shape, np.float64)
    nz = m > 0
    w[nz] = np.floor(np.log2(m[nz].astype(np.float64))) + 1.0
    per_block = c.bs * (w + 1.0) + 32.0 + 8.0 + 2.0
    return float(per_block.sum() / max(1, c.n))


# ---------------------------------------------------------------------------
# numpy host reference (bit-identical to the traced path; tests pin this)
# ---------------------------------------------------------------------------

def encode_host(x: np.ndarray, policy: JitPolicy) -> BlockCodes:
    """Numpy mirror of :func:`encode` — same op order, same reductions."""
    flat = np.asarray(x, np.float32).reshape(-1)
    n = flat.size
    nb = -(-n // policy.bs) if n else 0
    pad = nb * policy.bs - n
    if pad:
        flat = np.pad(flat, (0, pad), mode="edge")
    xb = flat.reshape(nb, policy.bs)
    radius = policy.radius
    d = np.diff(xb, axis=-1, prepend=xb[..., :1])
    d[..., 0] = 0.0
    a_lor = np.abs(d).max(axis=-1) if xb.size else np.zeros(nb, np.float32)
    a_zero = np.abs(xb).max(axis=-1) if xb.size else np.zeros(nb, np.float32)
    mx = xb.max(axis=-1) if xb.size else np.zeros(nb, np.float32)
    mn = xb.min(axis=-1) if xb.size else np.zeros(nb, np.float32)
    a_mean = ((mx - mn) * np.float32(0.5)).astype(np.float32)
    center = ((mx + mn) * np.float32(0.5)).astype(np.float32)
    cost = {
        "zero": a_zero * np.float32(1.0 / radius),
        "lorenzo1": a_lor * np.float32(1.0 / (radius - 1)),
        "mean": a_mean * np.float32(1.0 / radius),
    }
    enabled = [(PREDICTOR_TAGS[p], cost[p]) for p in policy.predictors]
    stack = np.stack([c for _, c in enabled], axis=-1)
    stack = np.maximum(stack, np.float32(SCALE_FLOOR))  # mirrors _select
    pick = np.argmin(stack, axis=-1)
    tag_map = np.asarray([t for t, _ in enabled], np.uint8)
    tags = tag_map[pick]
    a_eff = np.min(stack, axis=-1)
    scale = np.maximum(
        a_eff, np.float32(max(2.0 * policy.eb, SCALE_FLOOR))
    ).astype(np.float32)
    m, e = np.frexp(scale)  # mantissa-grid snap, mirrors _snap_scale
    scale = np.ldexp(np.ceil(m * 8.0).astype(np.float32), e - 3).astype(
        np.float32
    )
    base = np.where(
        tags == PREDICTOR_TAGS["lorenzo1"],
        xb[..., 0] if xb.size else np.zeros(nb, np.float32),
        np.where(tags == PREDICTOR_TAGS["mean"], center, np.float32(0.0)),
    ).astype(np.float32)
    t = np.rint((xb - base[..., None]) / scale[..., None]).astype(np.float32)
    prev_t = np.concatenate([t[..., :1] * 0, t[..., :-1]], axis=-1)
    codes = np.where(
        (tags == PREDICTOR_TAGS["lorenzo1"])[..., None], t - prev_t, t
    )
    codes = np.clip(codes, -radius, radius).astype(np.int8)
    if policy.bits == 4:
        u = codes.astype(np.uint8)
        codes = (u[..., 0::2] & 0xF) | ((u[..., 1::2] & 0xF) << 4)
    return BlockCodes(
        codes=codes, scale=scale, tags=tags, base=base,
        n=n, bits=policy.bits, bs=policy.bs,
    )


def decode_host(c: BlockCodes) -> np.ndarray:
    """Numpy mirror of :func:`decode`."""
    codes = np.asarray(c.codes)
    if c.bits == 4:
        lo = (codes & 0xF).astype(np.int32)
        hi = ((codes >> 4) & 0xF).astype(np.int32)
        lo = np.where(lo > 7, lo - 16, lo)
        hi = np.where(hi > 7, hi - 16, hi)
        q = np.stack([lo, hi], axis=-1).reshape(codes.shape[:-1] + (-1,))
    else:
        q = codes.astype(np.int32)
    lor = np.cumsum(q, axis=-1)
    sel = np.where(
        (np.asarray(c.tags) == PREDICTOR_TAGS["lorenzo1"])[..., None], lor, q
    )
    xb = np.asarray(c.base)[..., None] + np.asarray(c.scale)[..., None] * sel.astype(
        np.float32
    )
    return xb.reshape(-1)[: c.n].astype(np.float32)


# ---------------------------------------------------------------------------
# host fallback: the registered prediction engines
# ---------------------------------------------------------------------------

def host_compress(arr: np.ndarray, engine: str = "sz3_auto", conf=None):
    """Route a host-side array through a REGISTERED pipeline (the facade's
    door to the entropy-coded engines for non-jit contexts)."""
    from . import pipeline as pl_mod
    from .transform import sz3_auto  # noqa: F401 (registers sz3_auto)

    if engine not in pl_mod.PIPELINES:
        raise KeyError(
            f"unknown engine {engine!r}; registered: {sorted(pl_mod.PIPELINES)}"
        )
    comp = pl_mod.PIPELINES[engine]()
    return comp.compress(np.asarray(arr), conf)


def host_decompress(blob: bytes) -> np.ndarray:
    from . import pipeline as pl_mod

    return pl_mod.decompress(blob)
