"""Predictor module (paper §3.2 "Predictor", Appendix A.2).

Instances (paper Fig 1 right column):

  * LorenzoPredictor          — N-D Lorenzo [34] in *dual-quantization* form
                                (cuSZ, arXiv:2007.09625): data are prequantized
                                onto the 2*eb grid once, then the Lorenzo
                                stencil runs on exact integers.  Fully parallel
                                on TPU lanes (DESIGN.md §3.1); inverse is a
                                cumulative sum.  Error bound identical to SZ.
  * LorenzoSequentialPredictor— the paper-faithful SZ1.4 semantics (predict
                                from *decompressed* neighbours, lock-step);
                                realized as nested ``jax.lax.scan`` wavefronts.
                                Used as the fidelity oracle in tests.
  * RegressionPredictor       — SZ2 [8] block-wise hyperplane fit; coefficient
                                streams are themselves quantized (as in SZ2) so
                                they ride the same entropy stage.
  * InterpolationPredictor    — SZ3-Interp [17]: multi-level linear/cubic
                                spline interpolation with per-level feedback.
  * PatternPredictor          — SZ-Pastri [19]: periodic pattern + per-block
                                scaling for GAMESS ERI data.
  * CompositePredictor        — SZ2's multi-algorithm block selection (Lorenzo
                                vs regression via sampled error estimation,
                                generalized per paper §3.2 "composite
                                predictor").
  * ZeroPredictor             — predicts 0 (baseline / bypass).

All predictors drive the quantizer through its array-at-a-time interface; the
traversal strategy (global stencil / level order / block order) is the
predictor's own, which is exactly the paper's Algorithm-1-stays-generic claim.
"""
from __future__ import annotations

import abc
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import telemetry as tel
from .config import CompressionConfig
from .quantizers import QuantizerBase


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

def lorenzo_filter(q: np.ndarray, order: int = 1) -> np.ndarray:
    """N-D Lorenzo difference filter on integers (zero-padded boundaries).

    Successive first differences along each axis == inclusion-exclusion
    Lorenzo stencil; applying it ``order`` times gives the higher-order
    variant [7].  Exact on int64.
    """
    d = q
    for _ in range(order):
        for ax in range(d.ndim):
            d = np.diff(d, axis=ax, prepend=0)
    return d


def lorenzo_inverse(d: np.ndarray, order: int = 1) -> np.ndarray:
    """Inverse filter: cumulative sums (the parallel-decode win of dual-quant)."""
    q = d
    for _ in range(order):
        for ax in range(q.ndim - 1, -1, -1):
            q = np.cumsum(q, axis=ax)
    return q


# -- per-block entry points (blockwise hybrid engine; paper §3.2 per-block
#    best-fit selection).  Axis 0 indexes blocks: the caller tiles ONCE via
#    pad_to_blocks/blockify and every candidate below runs batched over the
#    whole block set — no per-block re-padding or per-block python calls. ----

def pad_to_blocks(data: np.ndarray, b: int) -> Tuple[np.ndarray, Tuple[int, ...]]:
    """Replicate-pad every axis up to a multiple of ``b``; returns
    (padded, original_shape)."""
    pads = [(0, (-s) % b) for s in data.shape]
    return np.pad(data, pads, mode="edge"), data.shape


def blockify(x: np.ndarray, b: int) -> np.ndarray:
    """(n1, n2, ...) -> (nblocks, b, b, ...); all axes must divide by ``b``."""
    nd = x.ndim
    shape = []
    for s in x.shape:
        shape += [s // b, b]
    y = x.reshape(shape)
    perm = list(range(0, 2 * nd, 2)) + list(range(1, 2 * nd, 2))
    return y.transpose(perm).reshape((-1,) + (b,) * nd)


def unblockify(blocks: np.ndarray, padded_shape: Sequence[int], b: int) -> np.ndarray:
    """Inverse of :func:`blockify`."""
    nd = len(padded_shape)
    grid = [s // b for s in padded_shape]
    y = blocks.reshape(grid + [b] * nd)
    perm = []
    for i in range(nd):
        perm += [i, nd + i]
    return y.transpose(perm).reshape(tuple(padded_shape))


def block_coords(b: int, nd: int) -> List[np.ndarray]:
    """Centred per-axis coordinates, broadcast-ready against (nb, b, ..., b)."""
    cs = []
    for ax in range(nd):
        c = np.arange(b, dtype=np.float64) - (b - 1) / 2.0
        shape = [1] * nd
        shape[ax] = b
        cs.append(c.reshape(shape))
    return cs


def block_lorenzo_filter(qblocks: np.ndarray, order: int = 1) -> np.ndarray:
    """Block-local Lorenzo filter, batched: axis 0 indexes blocks, the stencil
    runs over axes 1..nd only (zero-padded block boundaries, as in SZ2's
    block-wise candidate)."""
    d = qblocks
    for _ in range(order):
        for ax in range(1, qblocks.ndim):
            d = np.diff(d, axis=ax, prepend=0)
    return d


def block_lorenzo_inverse(dblocks: np.ndarray, order: int = 1) -> np.ndarray:
    """Inverse of :func:`block_lorenzo_filter` (per-block cumulative sums)."""
    q = dblocks
    for _ in range(order):
        for ax in range(q.ndim - 1, 0, -1):
            q = np.cumsum(q, axis=ax)
    return q


def block_plane_fit(
    blocks: np.ndarray, b: int, eb: float
) -> Tuple[List[np.ndarray], np.ndarray, np.ndarray]:
    """Batched SZ2 hyperplane fit on pre-blockified data.

    Returns ``(coef_q, pred, bad)``: per-block quantized coefficient integers
    (nd+1 streams, SZ2 bounds — eb/2 intercept, eb/(2b) slopes), the
    prediction every decoder will rebuild from those quantized coefficients,
    and a per-block mask of non-finite fits (nan/inf inputs) whose
    coefficients were zeroed — callers must not let such blocks win a
    selection contest (their points belong on the unpredictable fail path).
    """
    nd = blocks.ndim - 1
    nb = blocks.shape[0]
    axes = tuple(range(1, nd + 1))
    cs = block_coords(b, nd)
    denom = (b**nd) * ((b * b - 1) / 12.0)
    with np.errstate(invalid="ignore", over="ignore"):
        raw = [blocks.mean(axis=axes)]
        raw += [(blocks * cs[k]).sum(axis=axes) / denom for k in range(nd)]
    bad = np.zeros(nb, bool)
    coef_q: List[np.ndarray] = []
    qhat: List[np.ndarray] = []
    for k, vals in enumerate(raw):
        ceb = eb / 2.0 if k == 0 else eb / (2.0 * b)
        scaled = vals / (2.0 * ceb)
        finite = np.isfinite(scaled) & (np.abs(scaled) < float(2**62))
        bad |= ~finite
        q = np.rint(np.where(finite, scaled, 0.0)).astype(np.int64)
        coef_q.append(q)
        qhat.append(q.astype(np.float64) * (2.0 * ceb))
    pred = qhat[0].reshape((nb,) + (1,) * nd)
    for k in range(nd):
        pred = pred + qhat[1 + k].reshape((nb,) + (1,) * nd) * cs[k]
    return coef_q, pred, bad


def code_bits(
    abs_errors: np.ndarray, abs_eb: float, radius: int = 32768
) -> float:
    """Mean estimated coded bits/element for given |prediction errors|.

    Errors become quantization-bin indices (e/(2*eb)); the entropy stage pays
    the empirical entropy of that bin population, and out-of-range points are
    stored raw (~64 bits).  This is the common currency the chunked engine
    contests whole pipelines in — mean |error| (the composite predictor's
    intra-pipeline criterion) cannot see that e.g. an all-zeros bin population
    costs almost nothing, and over-weights a few unpredictable outliers.
    """
    e = np.asarray(abs_errors, np.float64).reshape(-1)
    if e.size == 0:
        return 0.0
    return _int_code_bits(np.rint(e / (2.0 * abs_eb)), radius)


def _int_code_bits(q: np.ndarray, radius: int) -> float:
    """Entropy of integer bin indices + raw-storage cost of out-of-range ones."""
    q = np.abs(np.asarray(q).reshape(-1))
    if q.size == 0:
        return 0.0
    out = q >= radius
    inr = q[~out]
    bits = 64.0 * float(out.mean())
    if inr.size:
        _, counts = np.unique(inr, return_counts=True)
        p = counts / inr.size
        bits += float(-(p * np.log2(p)).sum()) * float((~out).mean())
    return bits


def lorenzo_residuals(
    sample: np.ndarray, abs_eb: float, order: int = 1, radius: int = 32768
) -> np.ndarray:
    """|Lorenzo prediction error| per sample point (paper: estimate_error).

    Same statistic the composite predictor scores Lorenzo blocks with: the
    magnitude of the prequantized stencil output, clipped at the code range.
    """
    x64 = np.asarray(sample, np.float64)
    if x64.size == 0:
        return np.zeros(0)
    q = np.rint(x64 / (2.0 * abs_eb))
    d = lorenzo_filter(q, order)
    est = np.abs(d) * (2.0 * abs_eb)
    return np.minimum(est, 2.0 * abs_eb * radius)


def regression_residuals(
    sample: np.ndarray, abs_eb: float, block_size: int
) -> np.ndarray:
    """|hyperplane-fit residual| per sample point, block-wise as in SZ2."""
    res, _ = _regression_fit(sample, block_size)
    return res


def _regression_fit(
    sample: np.ndarray, block_size: int
) -> Tuple[np.ndarray, List[np.ndarray]]:
    """(per-point |residual|, per-stream coefficient values) of the SZ2 fit."""
    b = max(2, int(block_size))
    x = np.asarray(sample, np.float64)
    if x.size == 0:
        return np.zeros(0), []
    if x.ndim == 0:
        x = x.reshape(1)
    nd = x.ndim
    reg = RegressionPredictor()
    xp, _ = reg._pad(x, b)
    blocks = reg._blockify(xp, b)
    axes = tuple(range(1, nd + 1))
    cs = reg._coords(b, nd)
    denom = (b**nd) * ((b * b - 1) / 12.0)
    # nan/inf blocks produce nan residuals/coefficients by design (estimation
    # only — such points ride the unpredictable fail path when coding)
    with np.errstate(invalid="ignore", over="ignore"):
        coeffs = [blocks.mean(axis=axes)]
        pred = coeffs[0].reshape((-1,) + (1,) * nd)
        for k in range(nd):
            beta = (blocks * cs[k]).sum(axis=axes) / denom
            coeffs.append(beta)
            pred = pred + beta.reshape((-1,) + (1,) * nd) * cs[k]
        return np.abs(blocks - pred).reshape(-1), coeffs


def regression_bits(
    sample: np.ndarray, abs_eb: float, block_size: int, radius: int = 32768
) -> float:
    """Estimated bits/element for the SZ2 regression stage INCLUDING the
    quantized, delta-coded coefficient streams — on small blocks the
    coefficients are a material share of the coded payload, so ranking
    regression by residuals alone flatters it."""
    b = max(2, int(block_size))
    res, coeffs = _regression_fit(sample, block_size)
    if res.size == 0:
        return 0.0
    bits = code_bits(res, abs_eb, radius)
    n = res.size
    for k, vals in enumerate(coeffs):
        ceb = abs_eb / 2.0 if k == 0 else abs_eb / (2.0 * b)
        q = np.rint(vals / (2.0 * ceb))
        bits += _int_code_bits(np.diff(q, prepend=0), radius) * vals.size / n
    return bits


def interp_residuals(sample: np.ndarray) -> np.ndarray:
    """|linear-interpolation residual| pooled over ALL levels, per axis.

    Mirrors the interpolation predictor's code population: each point is
    predicted once, at the level that fills it — fine levels are cheap on
    smooth data but coarse levels pay near-full amplitude, which a
    finest-level-only estimate would hide (and then mis-rank the pipeline on
    e.g. periodic data whose period exceeds the fine strides).
    """
    x = np.asarray(sample, np.float64)
    if x.size == 0:
        return np.zeros(0)
    errs = []
    for ax in range(x.ndim):
        dim = x.shape[ax]
        if dim < 3:
            continue
        s = 1
        while s < dim:
            mid = [slice(None)] * x.ndim
            left = [slice(None)] * x.ndim
            right = [slice(None)] * x.ndim
            mid[ax] = slice(s, None, 2 * s)
            n_mid = len(range(s, dim, 2 * s))
            left[ax] = slice(0, 2 * s * n_mid, 2 * s)
            right_idx = np.minimum(np.arange(n_mid) * 2 * s + 2 * s, dim - 1)
            xl = x[tuple(left)]
            xr = np.take(x, right_idx, axis=ax)
            pred = 0.5 * (xl + xr)
            errs.append(np.abs(x[tuple(mid)] - pred).reshape(-1))
            s *= 2
    if not errs:
        flat = x.reshape(-1)
        return np.abs(np.diff(flat, prepend=0.0))
    return np.concatenate(errs)


def _pack_mask(mask: np.ndarray) -> bytes:
    return np.packbits(mask.reshape(-1)).tobytes()


def _unpack_mask(buf: bytes, n: int) -> np.ndarray:
    return np.unpackbits(np.frombuffer(buf, np.uint8), count=n).astype(bool)


class Predictor(abc.ABC):
    name: str = "abstract"

    def estimate_error(
        self, sample: np.ndarray, abs_eb: float, conf: CompressionConfig
    ) -> Optional[float]:
        """Estimated entropy-coded bits/element this predictor would incur.

        The paper's ``estimate_error`` (§3.2), lifted from the composite
        predictor's block-wise Lorenzo-vs-regression contest to a first-class
        predictor capability so *whole pipelines* can be contested per data
        region (chunking.py).  Scores are comparable across predictors (see
        :func:`code_bits`).  ``None`` means "no cheap estimator" — callers
        fall back to trial compression of the sample.
        """
        return None

    @abc.abstractmethod
    def compress(
        self, data: np.ndarray, quantizer: QuantizerBase, conf: CompressionConfig
    ) -> Tuple[np.ndarray, Dict[str, Any]]:
        """Return (flat quantization codes, serializable meta)."""

    @abc.abstractmethod
    def decompress(
        self,
        codes: np.ndarray,
        shape: Tuple[int, ...],
        dtype: np.dtype,
        quantizer: QuantizerBase,
        conf: CompressionConfig,
        meta: Dict[str, Any],
    ) -> np.ndarray: ...


# ---------------------------------------------------------------------------
# Zero predictor
# ---------------------------------------------------------------------------

class ZeroPredictor(Predictor):
    name = "zero"

    def estimate_error(self, sample, abs_eb, conf):
        return code_bits(
            np.abs(np.asarray(sample, np.float64)), abs_eb, conf.quant_radius
        )

    def compress(self, data, quantizer, conf):
        codes, _ = quantizer.quantize(data.reshape(-1), np.zeros(data.size))
        return codes, {}

    def decompress(self, codes, shape, dtype, quantizer, conf, meta):
        recon = quantizer.recover(np.zeros(codes.size), codes)
        return recon.reshape(shape).astype(dtype)


# ---------------------------------------------------------------------------
# Dual-quantization Lorenzo (parallel; the TPU-native default)
# ---------------------------------------------------------------------------

class LorenzoPredictor(Predictor):
    """Parallel N-D Lorenzo via dual-quantization (DESIGN.md §3.1).

    Two execution routes behind the same codes/meta contract:

      * numpy (default on CPU) — ``prequantize`` + ``lorenzo_filter`` on
        int64, any ndim/order.
      * device — the fused Pallas prequant+Lorenzo kernels
        (``kernels/lorenzo``), for order-1 float32 1-D/2-D data whose
        prequantized magnitudes pass the ``PIPELINE_SAFE`` int32 guard.
        The kernel computes q in float32, so after encoding, reconstruction
        is re-derived EXACTLY as both decode routes will compute it and any
        bound-breaking point is patched into the fail channel — the error
        bound is therefore identical to the numpy route's.  ``device="auto"``
        engages on real TPUs only (interpret-mode Pallas on CPU is far
        slower than numpy); ``"force"`` engages everywhere (tests);
        ``"off"`` never.
    """

    name = "lorenzo"

    def __init__(self, order: Optional[int] = None, device: str = "auto"):
        self.order = order
        self.device = device

    #: below this many elements the kernel dispatch overhead dominates
    _DEVICE_MIN_SIZE = 4096

    def estimate_error(self, sample, abs_eb, conf):
        return code_bits(
            lorenzo_residuals(
                sample, abs_eb, self.order or conf.lorenzo_order, conf.quant_radius
            ),
            abs_eb,
            conf.quant_radius,
        )

    # -- device routing -----------------------------------------------------
    def _device_ok(self, data: np.ndarray, eb: float, order: int) -> bool:
        if self.device == "off" or order != 1:
            return False
        if (
            data.ndim not in (1, 2)
            or data.dtype != np.float32
            or data.size < self._DEVICE_MIN_SIZE
        ):
            return False
        try:
            from ..kernels.lorenzo import ops as lops
        except Exception:  # jax/pallas unavailable -> numpy route
            return False
        absmax = float(np.abs(data).max())
        if not np.isfinite(absmax) or absmax / (2.0 * eb) >= lops.PIPELINE_SAFE:
            return False
        return True if self.device == "force" else lops.device_default()

    def _compress_device(self, data, quantizer):
        from ..kernels.lorenzo import ops as lops

        eb = quantizer.eb
        with tel.span("device_transfer", bytes=data.nbytes):
            codes32, draw = lops.encode_pipeline(data, eb=eb, radius=quantizer.radius)
        d = draw.astype(np.int64)
        x64 = np.asarray(data, np.float64)
        # The kernel prequantizes in float32 (vs float64 on the numpy route);
        # verify the bound against BOTH decode routes' exact arithmetic and
        # divert any straggler through the fail channel (raw values).
        q = lorenzo_inverse(d, 1)
        recon_np = quantizer.dequantize_int(q)
        fail = np.abs(recon_np.astype(np.float64) - x64) > eb
        recon_dev = lops.decode_pipeline(draw, eb=eb)
        fail |= np.abs(recon_dev.astype(np.float64) - x64) > eb
        flat = d.reshape(-1)
        oor = np.abs(flat) >= quantizer.radius
        if oor.any():
            quantizer._store_unpred_int(flat[oor])
        codes = codes32.reshape(-1).astype(quantizer.code_dtype)
        meta: Dict[str, Any] = {"order": 1, "nfail": int(fail.sum()), "device": 1}
        if meta["nfail"]:
            meta["fail_mask"] = _pack_mask(fail)
            meta["fail_vals"] = x64[fail].tobytes()
        return codes, meta

    def _decode_device_ok(self, shape, dtype, eb: float) -> bool:
        if self.device == "off" or len(shape) not in (1, 2):
            return False
        if np.dtype(dtype) != np.float32:
            return False
        try:
            from ..kernels.lorenzo import ops as lops
        except Exception:
            return False
        return True if self.device == "force" else lops.device_default()

    # -- the two directions --------------------------------------------------
    def compress(self, data, quantizer, conf):
        order = self.order or conf.lorenzo_order
        if self._device_ok(np.asarray(data), quantizer.eb, order):
            return self._compress_device(np.asarray(data), quantizer)
        q, recon, fail = quantizer.prequantize(data)
        d = lorenzo_filter(q, order)
        codes = quantizer.quantize_int_diff(d.reshape(-1))
        meta: Dict[str, Any] = {"order": order, "nfail": int(fail.sum())}
        if meta["nfail"]:
            meta["fail_mask"] = _pack_mask(fail)
            meta["fail_vals"] = np.asarray(data, np.float64)[fail].tobytes()
        return codes, meta

    def decompress(self, codes, shape, dtype, quantizer, conf, meta):
        order = int(meta["order"])
        d = quantizer.recover_int_diff(codes).reshape(shape)
        if meta.get("device") and self._decode_device_ok(shape, dtype, quantizer.eb):
            # compress verified this blob against the kernel decode's float32
            # arithmetic, so the fused route is bound-exact here
            from ..kernels.lorenzo import ops as lops

            out = lops.decode_pipeline(d.astype(np.int32), eb=quantizer.eb).astype(dtype)
        else:
            q = lorenzo_inverse(d, order)
            out = quantizer.dequantize_int(q).astype(dtype)
        if meta.get("nfail"):
            mask = _unpack_mask(meta["fail_mask"], int(np.prod(shape))).reshape(shape)
            out[mask] = np.frombuffer(meta["fail_vals"], np.float64).astype(dtype)
        return out


# ---------------------------------------------------------------------------
# Sequential Lorenzo (paper-faithful SZ1.4 semantics; jax.lax.scan wavefront)
# ---------------------------------------------------------------------------

class LorenzoSequentialPredictor(Predictor):
    """Predict each point from *decompressed* neighbours, in raster scan order.

    This is the paper-faithful SZ1.4/SZ2 Lorenzo semantics: the value used for
    prediction is the reconstruction the decompressor will have, so the
    quantization-error feedback travels through the scan.  The data dependence
    is a wavefront; we express it as ONE ``jax.lax.scan`` over the flattened
    array with a ring buffer carrying the trailing reconstruction window
    (size = sum of strides + 1), gathering the 2^ndim - 1 inclusion-exclusion
    neighbours by modular index.  Out-of-range neighbours read as 0 (SZ
    convention), enforced with precomputed validity masks.

    Used as the fidelity oracle for the parallel dual-quant variant and as the
    ``fidelity="paper"`` path for host-side compression.  Any ndim >= 1.
    """

    name = "lorenzo_seq"

    def estimate_error(self, sample, abs_eb, conf):
        # same stencil statistics as the parallel dual-quant variant
        return code_bits(
            lorenzo_residuals(sample, abs_eb, 1, conf.quant_radius),
            abs_eb,
            conf.quant_radius,
        )

    @staticmethod
    def _stencil(shape: Tuple[int, ...]):
        """Inclusion-exclusion neighbour set: (flat_offset, sign, valid_mask)."""
        nd = len(shape)
        strides = np.ones(nd, np.int64)
        for k in range(nd - 2, -1, -1):
            strides[k] = strides[k + 1] * shape[k + 1]
        idx = np.indices(shape).reshape(nd, -1)
        subsets = []
        for bits in range(1, 1 << nd):
            axes = [k for k in range(nd) if bits & (1 << k)]
            off = int(sum(strides[k] for k in axes))
            sign = 1.0 if (len(axes) % 2 == 1) else -1.0
            valid = np.ones(idx.shape[1], bool)
            for k in axes:
                valid &= idx[k] >= 1
            subsets.append((off, sign, valid))
        return subsets

    def _run_scan(self, shape, eb, radius, dtype, mode, xs_arrays):
        """mode: 'compress_linear' | 'compress_aligned' | 'decompress'."""
        import jax
        import jax.numpy as jnp

        try:  # moved across jax versions (top-level alias added post-0.4)
            from jax import enable_x64
        except ImportError:
            from jax.experimental import enable_x64

        subsets = self._stencil(shape)
        L = max(off for off, _, _ in subsets) + 1
        two_eb = 2.0 * eb
        out_dtype = np.dtype(dtype)

        def cast(v):
            if out_dtype == np.float64:
                return v
            return v.astype(jnp.dtype(out_dtype)).astype(jnp.float64)

        with enable_x64():

            def predict(buf, i, masks):
                pred = 0.0
                for s, (off, sign, _) in enumerate(subsets):
                    v = buf[(i - off) % L] * masks[s]
                    pred = pred + sign * v
                return pred

            if mode.startswith("compress"):
                aligned = mode.endswith("aligned")

                def step(carry, xin):
                    buf, i = carry
                    x = xin[0]
                    masks = xin[1:]
                    pred = predict(buf, i, masks)
                    d = x - pred
                    q = jnp.rint(d / two_eb)
                    in_range = jnp.abs(q) < radius
                    recon_try = cast(pred + q * two_eb)
                    ok = in_range & (jnp.abs(recon_try - x) <= eb)
                    if aligned:
                        cand = cast(pred + q * two_eb)
                        bad = jnp.abs(cand - x) > eb
                        recon_un = jnp.where(bad, x, cand)
                    else:
                        recon_un = x
                    recon = jnp.where(ok, recon_try, recon_un)
                    code = jnp.where(ok, q.astype(jnp.int64) + radius, 0)
                    buf = buf.at[i % L].set(recon)
                    return (buf, i + 1), (code, recon, pred)

                carry = (jnp.zeros(L), jnp.asarray(0))
                _, (codes, recon, pred) = jax.lax.scan(step, carry, xs_arrays)
                return np.asarray(codes), np.asarray(recon), np.asarray(pred)

            def dstep(carry, xin):
                buf, i = carry
                code, un_q, un_esc, un_raw = xin[0], xin[1], xin[2], xin[3]
                masks = xin[4:]
                pred = predict(buf, i, masks)
                q = code.astype(jnp.float64) - radius
                recon_pred = cast(pred + q * two_eb)
                recon_un = jnp.where(un_esc, un_raw, cast(pred + un_q * two_eb))
                recon = jnp.where(code == 0, recon_un, recon_pred)
                buf = buf.at[i % L].set(recon)
                return (buf, i + 1), recon

            carry = (jnp.zeros(L), jnp.asarray(0))
            _, recon = jax.lax.scan(dstep, carry, xs_arrays)
            return np.asarray(recon)

    def compress(self, data, quantizer, conf):
        x64 = np.ascontiguousarray(data, np.float64)
        shape = x64.shape
        subsets = self._stencil(shape)
        masks = tuple(m.astype(np.float64) for _, _, m in subsets)
        mode = (
            "compress_aligned"
            if quantizer.name == "unpred_aware"
            else "compress_linear"
        )
        codes, recon, pred = self._run_scan(
            shape,
            quantizer.eb,
            quantizer.radius,
            np.dtype(data.dtype),
            mode,
            (x64.reshape(-1),) + masks,
        )
        un = codes == 0
        if un.any():
            quantizer.absorb_unpred(x64.reshape(-1)[un], pred[un])
        return codes.astype(quantizer.code_dtype), {}

    def decompress(self, codes, shape, dtype, quantizer, conf, meta):
        n = int(np.prod(shape))
        subsets = self._stencil(tuple(shape))
        masks = tuple(m.astype(np.float64) for _, _, m in subsets)
        un = codes == 0
        un_q = np.zeros(n, np.float64)
        un_esc = np.zeros(n, bool)
        un_raw = np.zeros(n, np.float64)
        cnt = int(un.sum())
        if cnt:
            q, esc, raw = quantizer.emit_unpred_channels(cnt)
            pos = np.flatnonzero(un)
            un_q[pos] = q
            un_esc[pos] = esc
            un_raw[pos] = raw
        recon = self._run_scan(
            tuple(shape),
            quantizer.eb,
            quantizer.radius,
            np.dtype(dtype),
            "decompress",
            (codes.astype(np.int64), un_q, un_esc, un_raw) + masks,
        )
        return recon.reshape(shape).astype(dtype)


# ---------------------------------------------------------------------------
# Regression predictor (SZ2)
# ---------------------------------------------------------------------------

class RegressionPredictor(Predictor):
    """Block-wise hyperplane fit (SZ2 [8]).

    For each b^d block the least-squares plane  f(i) = beta0 + sum_k beta_k*i_k
    is fitted (closed form — centred coordinates make the normal equations
    diagonal, i.e. a batched reduction instead of a solve: MXU/VPU friendly).
    Coefficients are quantized (eb/2b per slope, eb/2 for the intercept, as in
    SZ2) and their codes ride the shared entropy stage.  Edge blocks are
    handled by replicate-padding; the original extent is restored on decode.
    """

    name = "regression"

    def estimate_error(self, sample, abs_eb, conf):
        return regression_bits(sample, abs_eb, conf.block_size, conf.quant_radius)

    # thin wrappers over the module-level block helpers (kept as methods for
    # API stability; the hybrid engine calls the module functions directly)
    def _pad(self, data: np.ndarray, b: int) -> Tuple[np.ndarray, Tuple[int, ...]]:
        return pad_to_blocks(data, b)

    def _blockify(self, x: np.ndarray, b: int) -> np.ndarray:
        return blockify(x, b)

    def _unblockify(self, blocks: np.ndarray, padded_shape, b: int) -> np.ndarray:
        return unblockify(blocks, tuple(padded_shape), b)

    def _coords(self, b: int, nd: int) -> List[np.ndarray]:
        return block_coords(b, nd)

    def compress(self, data, quantizer, conf):
        b = int(conf.block_size)
        nd = data.ndim
        x, orig_shape = self._pad(np.asarray(data, np.float64), b)
        blocks = self._blockify(x, b)  # (nb, b, ..., b)
        nb = blocks.shape[0]
        axes = tuple(range(1, nd + 1))
        cs = self._coords(b, nd)
        denom = (b**nd) * ((b * b - 1) / 12.0)  # sum of centred c^2 per axis
        beta0 = blocks.mean(axis=axes)
        betas = [
            (blocks * cs[k]).sum(axis=axes) / denom for k in range(nd)
        ]
        # Quantize coefficients (SZ2: slopes at eb/2b, intercept at eb/2) so
        # the decompressor sees identical planes.
        coef_codes: List[np.ndarray] = []
        eb = quantizer.eb
        qhat = []
        for vals, ceb in [(beta0, eb / 2.0)] + [(bt, eb / (2.0 * b)) for bt in betas]:
            q = np.rint(vals / (2.0 * ceb)).astype(np.int64)
            qhat.append(q.astype(np.float64) * (2.0 * ceb))
            coef_codes.append(q)
        # delta-encode coefficient streams (adjacent blocks correlate)
        cc = []
        for q in coef_codes:
            cc.append(quantizer.quantize_int_diff(np.diff(q, prepend=0)))
        pred = qhat[0].reshape((nb,) + (1,) * nd)
        for k in range(nd):
            pred = pred + qhat[1 + k].reshape((nb,) + (1,) * nd) * cs[k]
        dcodes, _ = quantizer.quantize(blocks.reshape(-1), pred.reshape(-1))
        codes = np.concatenate([c.astype(dcodes.dtype) for c in cc] + [dcodes])
        meta = {
            "orig_shape": list(orig_shape),
            "padded_shape": list(x.shape),
            "nb": int(nb),
            "b": b,
        }
        return codes, meta

    def decompress(self, codes, shape, dtype, quantizer, conf, meta):
        b = int(meta["b"])
        nb = int(meta["nb"])
        padded_shape = tuple(meta["padded_shape"])
        nd = len(padded_shape)
        eb = quantizer.eb
        pos = 0
        qhat = []
        for k in range(nd + 1):
            dq = quantizer.recover_int_diff(codes[pos : pos + nb])
            pos += nb
            q = np.cumsum(dq)
            ceb = eb / 2.0 if k == 0 else eb / (2.0 * b)
            qhat.append(q.astype(np.float64) * (2.0 * ceb))
        cs = self._coords(b, nd)
        pred = qhat[0].reshape((nb,) + (1,) * nd)
        for k in range(nd):
            pred = pred + qhat[1 + k].reshape((nb,) + (1,) * nd) * cs[k]
        recon = quantizer.recover(pred.reshape(-1), codes[pos:])
        blocks = recon.reshape((nb,) + (b,) * nd)
        out = self._unblockify(blocks, padded_shape, b)
        sl = tuple(slice(0, s) for s in meta["orig_shape"])
        return out[sl].astype(dtype)


# ---------------------------------------------------------------------------
# Interpolation predictor (SZ3-Interp)
# ---------------------------------------------------------------------------

class InterpolationPredictor(Predictor):
    """Multi-level spline interpolation [17] with per-level feedback.

    Levels run coarse->fine; within a level each axis pass predicts the
    odd-stride points from already-reconstructed neighbours via linear or
    cubic interpolation.  Every point within a pass is independent →
    log2(max_dim) * ndim fully-parallel passes (DESIGN.md §3 item 5).
    """

    name = "interp"

    def __init__(self, kind: Optional[str] = None):
        self.kind = kind

    def estimate_error(self, sample, abs_eb, conf):
        return code_bits(interp_residuals(sample), abs_eb, conf.quant_radius)

    # -- pass geometry -------------------------------------------------------
    def _passes(self, shape: Tuple[int, ...]):
        """Yield (axis, stride, coords_per_axis) for every pass, coarse->fine."""
        max_dim = max(shape)
        level = max(1, int(np.ceil(np.log2(max(2, max_dim)))))
        for lev in range(level, 0, -1):
            s = 1 << (lev - 1)
            if s >= max_dim:
                continue
            for ax in range(len(shape)):
                if s >= shape[ax] and not any(
                    2 * s < shape[j] for j in range(len(shape))
                ):
                    pass
                targets = np.arange(s, shape[ax], 2 * s)
                if targets.size == 0:
                    continue
                other: List[np.ndarray] = []
                for j in range(len(shape)):
                    if j == ax:
                        other.append(targets)
                    elif j < ax:
                        other.append(np.arange(0, shape[j], s))
                    else:
                        other.append(np.arange(0, shape[j], 2 * s))
                yield ax, s, other

    def _predict_pass(
        self, xhat: np.ndarray, ax: int, s: int, coords: Sequence[np.ndarray], kind: str
    ) -> Tuple[np.ndarray, Tuple[np.ndarray, ...]]:
        """Compute predictions for one pass; returns (pred, index tuple)."""
        shape = xhat.shape
        ts = coords[ax]
        dim = shape[ax]

        def grab(offsets: np.ndarray) -> np.ndarray:
            cs = list(coords)
            cs[ax] = offsets
            return xhat[np.ix_(*cs)]

        left = grab(ts - s)
        has_r = ts + s < dim
        right_idx = np.where(has_r, ts + s, ts - s)
        right = grab(right_idx)
        lin = 0.5 * (left + right)
        copy = left
        shape_bc = [1] * xhat.ndim
        shape_bc[ax] = ts.size
        has_r_bc = has_r.reshape(shape_bc)
        pred = np.where(has_r_bc, lin, copy)
        if kind == "cubic":
            has_ll = ts - 3 * s >= 0
            has_rr = ts + 3 * s < dim
            full = has_ll & has_rr & has_r
            if full.any():
                ll = grab(np.where(has_ll, ts - 3 * s, ts - s))
                rr = grab(np.where(has_rr, ts + 3 * s, ts - s))
                cubic = (-ll + 9.0 * left + 9.0 * right - rr) / 16.0
                pred = np.where(full.reshape(shape_bc), cubic, pred)
        return pred, np.ix_(*coords)

    def compress(self, data, quantizer, conf):
        kind = self.kind or conf.interp_kind
        x64 = np.asarray(data, np.float64)
        shape = x64.shape
        xhat = np.zeros_like(x64)
        all_codes: List[np.ndarray] = []
        # anchor point: origin, predicted as 0
        origin = (0,) * x64.ndim
        c0, r0 = quantizer.quantize(x64[origin].reshape(1), np.zeros(1))
        xhat[origin] = r0[0]
        all_codes.append(c0)
        for ax, s, coords in self._passes(shape):
            pred, idx = self._predict_pass(xhat, ax, s, coords, kind)
            codes, recon = quantizer.quantize(x64[idx].reshape(-1), pred.reshape(-1))
            xhat[idx] = recon.reshape(pred.shape)
            all_codes.append(codes)
        return np.concatenate(all_codes), {"kind": kind}

    def decompress(self, codes, shape, dtype, quantizer, conf, meta):
        kind = meta["kind"]
        xhat = np.zeros(shape, np.float64)
        pos = 0
        origin = (0,) * len(shape)
        r0 = quantizer.recover(np.zeros(1), codes[pos : pos + 1])
        xhat[origin] = r0[0]
        pos += 1
        for ax, s, coords in self._passes(tuple(shape)):
            pred, idx = self._predict_pass(xhat, ax, s, coords, kind)
            n = pred.size
            recon = quantizer.recover(pred.reshape(-1), codes[pos : pos + n])
            xhat[idx] = recon.reshape(pred.shape)
            pos += n
        return xhat.astype(dtype)


# ---------------------------------------------------------------------------
# Pattern predictor (SZ-Pastri)
# ---------------------------------------------------------------------------

class PatternPredictor(Predictor):
    """Periodic pattern + per-block scaling (SZ-Pastri [19]).

    GAMESS ERI blocks repeat a template scaled per block; the template is
    chosen as the max-energy window, itself quantized and sent first, then a
    per-block least-squares scale (delta-quantized), then the residual codes.
    The three code populations are exactly paper Fig 3's data/pattern/scale
    split (the benchmark slices them by the offsets in meta).
    """

    name = "pattern"

    def __init__(self, pattern_size: Optional[int] = None):
        self.pattern_size = pattern_size

    @staticmethod
    def detect_period(x: np.ndarray, lo: int = 4, hi: int = 4096) -> int:
        """Autocorrelation peak via FFT (preprocessing step of SZ-Pastri)."""
        n = min(x.size, 1 << 16)
        v = np.asarray(x[:n], np.float64)
        v = v - v.mean()
        f = np.fft.rfft(v, n=2 * n)
        ac = np.fft.irfft(f * np.conj(f))[: n // 2]
        hi = min(hi, ac.size - 1)
        if hi <= lo:
            return max(2, min(64, x.size))
        seg = ac[lo : hi + 1]
        return int(lo + np.argmax(seg))

    def compress(self, data, quantizer, conf):
        flat = np.asarray(data, np.float64).reshape(-1)
        n = flat.size
        P = self.pattern_size or conf.pattern_size or self.detect_period(flat)
        P = max(2, min(P, n))
        nb = n // P
        tail = n - nb * P
        body = flat[: nb * P].reshape(nb, P)
        # template: max-energy block, quantized through the shared quantizer
        t_idx = int(np.argmax((body * body).sum(axis=1))) if nb else 0
        template = body[t_idx] if nb else flat[:P]
        tcodes, that = quantizer.quantize(template, np.zeros(P))
        that = that.astype(np.float64)
        tt = float((that * that).sum())
        if tt <= 0:
            scales = np.zeros(nb)
        else:
            scales = body @ that / tt
        # quantize scales (delta, integer stream)
        s_eb = quantizer.eb / (max(1.0, float(np.max(np.abs(that))) ) )
        sq = np.rint(scales / (2.0 * s_eb)).astype(np.int64)
        scodes = quantizer.quantize_int_diff(np.diff(sq, prepend=0))
        shat = sq.astype(np.float64) * (2.0 * s_eb)
        pred = shat[:, None] * that[None, :]
        dcodes, _ = quantizer.quantize(body.reshape(-1), pred.reshape(-1))
        parts = [tcodes, scodes.astype(tcodes.dtype), dcodes]
        if tail:
            # tail: predict with the template prefix scaled by the last scale
            tp = (shat[-1] if nb else 0.0) * that[:tail]
            tl_codes, _ = quantizer.quantize(flat[nb * P :], tp)
            parts.append(tl_codes)
        codes = np.concatenate(parts)
        meta = {
            "P": int(P),
            "nb": int(nb),
            "tail": int(tail),
            "s_eb": float(s_eb),
            "sections": [int(tcodes.size), int(scodes.size), int(dcodes.size)],
        }
        return codes, meta

    def decompress(self, codes, shape, dtype, quantizer, conf, meta):
        P, nb, tail = int(meta["P"]), int(meta["nb"]), int(meta["tail"])
        s_eb = float(meta["s_eb"])
        pos = 0
        that = quantizer.recover(np.zeros(P), codes[pos : pos + P]).astype(np.float64)
        pos += P
        dsq = quantizer.recover_int_diff(codes[pos : pos + nb])
        pos += nb
        shat = np.cumsum(dsq).astype(np.float64) * (2.0 * s_eb)
        pred = shat[:, None] * that[None, :]
        body = quantizer.recover(pred.reshape(-1), codes[pos : pos + nb * P])
        pos += nb * P
        out = np.empty(int(np.prod(shape)), np.float64)
        out[: nb * P] = body
        if tail:
            tp = (shat[-1] if nb else 0.0) * that[:tail]
            out[nb * P :] = quantizer.recover(tp, codes[pos : pos + tail])
        return out.reshape(shape).astype(dtype)


# ---------------------------------------------------------------------------
# Composite predictor (SZ2 multi-algorithm selection)
# ---------------------------------------------------------------------------

class CompositePredictor(Predictor):
    """Block-wise best-of selection between Lorenzo and regression (SZ2 [8]).

    Per block the expected absolute error of each candidate is estimated on a
    strided sample (paper: ``estimate_error``); the winner's codes are kept.
    Lorenzo runs block-locally on prequantized integers (dual-quant) so the
    decoder never needs cross-candidate reconstructions; see DESIGN.md §3.
    Selection flags are packed into meta (1 bit per block).
    """

    name = "composite"

    def estimate_error(self, sample, abs_eb, conf):
        # best-of its two candidates, mirroring the block-wise contest below,
        # plus the 1-bit-per-block selection flag it must also code
        flag_bits = 1.0 / float(max(2, conf.block_size)) ** max(1, sample.ndim)
        return flag_bits + min(
            code_bits(
                lorenzo_residuals(sample, abs_eb, 1, conf.quant_radius),
                abs_eb,
                conf.quant_radius,
            ),
            regression_bits(sample, abs_eb, conf.block_size, conf.quant_radius),
        )

    def compress(self, data, quantizer, conf):
        b = int(conf.block_size)
        nd = data.ndim
        reg = RegressionPredictor()
        x64 = np.asarray(data, np.float64)
        x, orig_shape = reg._pad(x64, b)
        blocks = reg._blockify(x, b)  # (nb, b^d)
        nb = blocks.shape[0]
        axes = tuple(range(1, nd + 1))
        eb = quantizer.eb

        # --- candidate 1: block-local dual-quant Lorenzo ---
        qfull, recon_pre, fail = quantizer.prequantize(blocks)
        d_lor = qfull
        for ax in axes:
            d_lor = np.diff(d_lor, axis=ax, prepend=0)

        # --- candidate 2: regression plane from quantized coefficients ---
        cs = reg._coords(b, nd)
        denom = (b**nd) * ((b * b - 1) / 12.0)
        beta0 = blocks.mean(axis=axes)
        betas = [(blocks * cs[k]).sum(axis=axes) / denom for k in range(nd)]
        qhat, coef_q = [], []
        # non-finite block means (nan/inf inputs) quantize to garbage here by
        # design — those blocks lose the contest or their points ride the
        # unpredictable fail path, so the cast is safe and warning-worthless
        with np.errstate(invalid="ignore", over="ignore"):
            for vals, ceb in [(beta0, eb / 2.0)] + [(bt, eb / (2.0 * b)) for bt in betas]:
                qc = np.rint(vals / (2.0 * ceb)).astype(np.int64)
                coef_q.append(qc)
                qhat.append(qc.astype(np.float64) * (2.0 * ceb))
        pred_reg = qhat[0].reshape((nb,) + (1,) * nd)
        for k in range(nd):
            pred_reg = pred_reg + qhat[1 + k].reshape((nb,) + (1,) * nd) * cs[k]

        # --- estimation on strided samples (paper: estimate_error) ---
        stride = max(1, int(conf.sample_stride))
        sample = (slice(None),) + (slice(0, b, stride),) * nd
        est_lor = (np.abs(d_lor[sample]) * (2.0 * eb)).clip(max=2.0 * eb * quantizer.radius)
        est_lor = est_lor.reshape(nb, -1).sum(axis=1)
        est_reg = np.abs(blocks[sample] - pred_reg[sample]).reshape(nb, -1).sum(axis=1)
        use_reg = est_reg < est_lor

        # --- emit codes: per-block winner, streams interleaved block-major ---
        # regression coefficient streams are only kept for winning blocks
        coef_codes = []
        for qc in coef_q:
            kept = qc[use_reg]
            coef_codes.append(quantizer.quantize_int_diff(np.diff(kept, prepend=0)))
        lor_codes = quantizer.quantize_int_diff(d_lor[~use_reg].reshape(-1))
        dcodes, _ = quantizer.quantize(
            blocks[use_reg].reshape(-1), pred_reg[use_reg].reshape(-1)
        )
        codes = np.concatenate(
            [c.astype(lor_codes.dtype) for c in coef_codes] + [lor_codes, dcodes]
        )
        meta = {
            "orig_shape": list(orig_shape),
            "padded_shape": list(x.shape),
            "b": b,
            "nb": int(nb),
            "flags": _pack_mask(use_reg),
            "n_reg": int(use_reg.sum()),
            "nfail": int(fail.sum()),
        }
        if meta["nfail"]:
            fail_full = np.zeros_like(fail, bool)
            fail_full = fail
            meta["fail_mask"] = _pack_mask(fail_full[~use_reg])
            meta["fail_vals"] = blocks[~use_reg][fail_full[~use_reg]].tobytes()
        return codes, meta

    def decompress(self, codes, shape, dtype, quantizer, conf, meta):
        b = int(meta["b"])
        nb = int(meta["nb"])
        padded_shape = tuple(meta["padded_shape"])
        nd = len(padded_shape)
        eb = quantizer.eb
        use_reg = _unpack_mask(meta["flags"], nb)
        n_reg = int(meta["n_reg"])
        n_lor = nb - n_reg
        reg = RegressionPredictor()
        cs = reg._coords(b, nd)
        pos = 0
        qhat = []
        for k in range(nd + 1):
            dq = quantizer.recover_int_diff(codes[pos : pos + n_reg])
            pos += n_reg
            ceb = eb / 2.0 if k == 0 else eb / (2.0 * b)
            qhat.append(np.cumsum(dq).astype(np.float64) * (2.0 * ceb))
        blk_elems = b**nd
        d_lor = quantizer.recover_int_diff(codes[pos : pos + n_lor * blk_elems])
        pos += n_lor * blk_elems
        d_lor = d_lor.reshape((n_lor,) + (b,) * nd)
        qfull = d_lor
        for ax in range(nd, 0, -1):
            qfull = np.cumsum(qfull, axis=ax)
        lor_blocks = quantizer.dequantize_int(qfull).astype(np.float64)
        if meta.get("nfail"):
            fl = _unpack_mask(meta["fail_mask"], n_lor * blk_elems).reshape(
                (n_lor,) + (b,) * nd
            )
            lor_blocks[fl] = np.frombuffer(meta["fail_vals"], np.float64)
        pred_reg = qhat[0].reshape((n_reg,) + (1,) * nd)
        for k in range(nd):
            pred_reg = pred_reg + qhat[1 + k].reshape((n_reg,) + (1,) * nd) * cs[k]
        reg_recon = quantizer.recover(pred_reg.reshape(-1), codes[pos:])
        blocks = np.empty((nb,) + (b,) * nd, np.float64)
        blocks[~use_reg] = lor_blocks
        blocks[use_reg] = reg_recon.reshape((n_reg,) + (b,) * nd)
        out = reg._unblockify(blocks, padded_shape, b)
        sl = tuple(slice(0, s) for s in meta["orig_shape"])
        return out[sl].astype(dtype)


_REGISTRY = {
    "zero": ZeroPredictor,
    "lorenzo": LorenzoPredictor,
    "lorenzo_seq": LorenzoSequentialPredictor,
    "regression": RegressionPredictor,
    "interp": InterpolationPredictor,
    "pattern": PatternPredictor,
    "composite": CompositePredictor,
}


def register(name: str, cls) -> None:
    _REGISTRY[name] = cls


def make(name: str, **kw) -> Predictor:
    return _REGISTRY[name](**kw)
