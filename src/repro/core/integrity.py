"""Container integrity: checksummed trailers, typed errors, and salvage decode.

Six container generations (v1 single-pipeline, v2 chunked, v3 transform,
v4 pointwise-relative, v5 block-hybrid, v6 fast-tier) share one
``pipeline.decompress`` entry point but — before this module — carried no
integrity verification: a flipped bit in a Huffman stream silently corrupted
output or died deep inside numpy.  Error-bounded compression only earns its
bound on data that survives the round trip, so every writer now appends an
integrity TRAILER and every reader can verify it:

  ``... prologue | header | body | [payload | len u32 | ver u8 | b"SZ3T"]``

The trailer sits BEYOND the body length declared in the prologue, so any
reader that honours the declared lengths (all in-repo readers slice the body
by its declared length) skips it: pre-trailer blobs keep decoding unchanged,
and trailer-carrying blobs decode under pre-trailer readers.  The msgpack
payload carries fixed-width fields only — ``a`` (checksum algorithm), ``h``
(checksum of prologue+header), ``w`` (whole-container digest over everything
before the trailer) and ``c`` (one 4-byte checksum per chunk of the body) —
so trailer length is a pure function of the chunk count and containers stay
byte-deterministic.

Checksum algorithm: CRC32C (Castagnoli) via ``google_crc32c`` when the C
extension is importable (~2 GB/s measured), else ``zlib.crc32``; the trailer
records which (``a``), so blobs verify wherever they land.

Threat model — what the checksums DO defend: accidental corruption (storage
bit rot, truncated writes, torn reads, bad NICs) is detected before decode
can propagate it, and damage is localized to the chunk level so salvage
decode recovers everything else.  What they DON'T defend: a deliberate
attacker can recompute CRCs after tampering (they are not MACs), and
stripping the whole trailer from a container downgrades it to unverified
legacy framing — readers that must reject that case check the header's
``itg`` flag, which travels under the header checksum.  Hostile length
fields are handled separately: every header-declared size/count/offset is
bounded against the actual blob before any allocation (see ``guard_*`` and
``LosslessBackend.decompress_bounded``).

Error contract: every malformed-input failure raises :class:`ContainerError`
(a ``ValueError``) or its checksum-specific subclass :class:`IntegrityError`
— never a raw ``struct.error`` / ``KeyError`` / ``IndexError`` from the
decode internals (``decode_errors`` converts them at the dispatch boundary).
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import struct
import zlib
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

import msgpack

try:  # CRC32C (Castagnoli): hardware-accelerated C extension when present
    import google_crc32c as _crc32c_mod

    _HAVE_CRC32C = True
except Exception:  # pragma: no cover - exercised where the wheel is absent
    _crc32c_mod = None
    _HAVE_CRC32C = False


# ---------------------------------------------------------------------------
# typed error contract
# ---------------------------------------------------------------------------

class ContainerError(ValueError):
    """A malformed or hostile container: bad framing, inconsistent lengths,
    unparseable headers, or decode state that cannot be reconciled with the
    header's claims.  Subclasses ``ValueError`` so pre-existing callers that
    catch ``ValueError`` keep working."""


class IntegrityError(ContainerError):
    """A checksum mismatch: the container parsed, but its bytes are not the
    bytes that were written.  ``chunk_index`` names the first damaged chunk
    when the per-chunk checksums localize it; ``region`` names the damaged
    area otherwise ("header", "container", "trailer")."""

    def __init__(
        self,
        message: str,
        *,
        chunk_index: Optional[int] = None,
        region: str = "container",
    ):
        super().__init__(message)
        self.chunk_index = chunk_index
        self.region = region


#: exception types the decode internals may leak on hostile input; converted
#: to ContainerError at the dispatch boundary.  MemoryError is deliberately
#: NOT here — the allocation guards exist to prevent it, and masking one
#: would hide a guard gap.
_LEAKY_ERRORS = (
    KeyError,
    IndexError,
    TypeError,
    AttributeError,
    struct.error,
    zlib.error,
    OverflowError,
    msgpack.exceptions.ExtraData,
    msgpack.exceptions.FormatError,
    msgpack.exceptions.StackError,
)


@contextlib.contextmanager
def decode_errors(what: str = "container") -> Iterator[None]:
    """Normalize the error contract at a decode boundary: ``ValueError``
    (including our typed subclasses) passes through; the leaky exception
    types malformed input can trigger inside numpy/struct/msgpack/zlib are
    re-raised as :class:`ContainerError`."""
    try:
        yield
    except ValueError:
        raise
    except _LEAKY_ERRORS as e:
        raise ContainerError(
            f"malformed {what}: {type(e).__name__}: {e}"
        ) from e
    except lzma_error() as e:  # lzma.LZMAError lazily resolved
        raise ContainerError(f"malformed {what}: {e}") from e


def lzma_error():
    import lzma

    return lzma.LZMAError


# ---------------------------------------------------------------------------
# allocation guards (decompression-bomb / overflow defense)
# ---------------------------------------------------------------------------

#: hard ceiling on any single header-driven allocation during decode; a
#: container legitimately bigger than this is outside the supported envelope
#: (override via the environment for archival restores of huge arrays)
MAX_OUTPUT_BYTES = int(os.environ.get("SZ3J_MAX_OUTPUT_BYTES", str(1 << 34)))


def guard_alloc(nbytes: int, what: str) -> int:
    """Bound a header-declared allocation BEFORE making it."""
    nbytes = int(nbytes)
    if nbytes < 0 or nbytes > MAX_OUTPUT_BYTES:
        raise ContainerError(
            f"hostile or corrupt container: {what} declares {nbytes} bytes "
            f"(allowed 0..{MAX_OUTPUT_BYTES}; raise SZ3J_MAX_OUTPUT_BYTES "
            "for legitimately larger arrays)"
        )
    return nbytes


def guard_count(n: Any, limit: int, what: str) -> int:
    """Bound a header-declared count by a limit derived from real bytes."""
    try:
        n = int(n)
    except (TypeError, ValueError) as e:
        raise ContainerError(f"corrupt container: {what} is not an integer") from e
    if n < 0 or n > limit:
        raise ContainerError(
            f"hostile or corrupt container: {what}={n} outside 0..{limit}"
        )
    return n


def guard_shape(shape: Any, itemsize: int, what: str = "shape") -> Tuple[int, ...]:
    """Validate a header-declared shape and bound its total allocation."""
    if not isinstance(shape, (list, tuple)):
        raise ContainerError(f"corrupt container: {what} is not a sequence")
    dims: List[int] = []
    total = 1
    for d in shape:
        d = guard_count(d, MAX_OUTPUT_BYTES, f"{what} dim")
        dims.append(d)
        total *= d
        if total * itemsize > MAX_OUTPUT_BYTES:
            raise ContainerError(
                f"hostile or corrupt container: {what} {dims}... declares more "
                f"than {MAX_OUTPUT_BYTES} bytes"
            )
    return tuple(dims)


# ---------------------------------------------------------------------------
# checksums
# ---------------------------------------------------------------------------

def _crc32c(data, value: int = 0) -> int:
    return int(_crc32c_mod.extend(value, bytes(data)))


def _crc32(data, value: int = 0) -> int:
    return zlib.crc32(data, value) & 0xFFFFFFFF


_ALGOS = {"crc32c": _crc32c, "crc32": _crc32}

#: the algorithm new trailers are written with in THIS process
CHECKSUM_ALGO = "crc32c" if _HAVE_CRC32C else "crc32"


def checksum(data, value: int = 0, algo: Optional[str] = None) -> int:
    """Running 32-bit checksum of ``data`` (CRC32C when available)."""
    fn = _ALGOS.get(algo or CHECKSUM_ALGO)
    if fn is None:
        raise ContainerError(f"unknown checksum algorithm {algo!r} in trailer")
    return fn(data, value)


# ---------------------------------------------------------------------------
# the trailer
# ---------------------------------------------------------------------------

TRAILER_MAGIC = b"SZ3T"
TRAILER_VERSION = 1
_FOOTER = struct.Struct("<IB4s")  # payload length, version, magic — 9 bytes


@dataclasses.dataclass(frozen=True)
class Trailer:
    """Parsed integrity trailer."""

    algo: str
    header_crc: int
    whole_crc: int
    chunk_crcs: Tuple[int, ...]
    start: int  # byte offset where the trailer begins (== verified length)


def build_trailer(
    head: bytes, body: bytes, chunk_bounds: Optional[Sequence[Tuple[int, int]]]
) -> bytes:
    """Integrity trailer for a container whose pre-trailer bytes are
    ``head + body``.  ``chunk_bounds`` lists body-relative ``(off, len)`` of
    each independently decodable chunk (multi-chunk containers pass their
    chunk table; single-body containers pass None for one whole-body chunk).
    """
    if chunk_bounds is None:
        chunk_bounds = ((0, len(body)),) if body else ()
    algo = CHECKSUM_ALGO
    chunk_crcs = b"".join(
        struct.pack("<I", checksum(body[off : off + ln], algo=algo))
        for off, ln in chunk_bounds
    )
    whole = checksum(body, checksum(head, algo=algo), algo=algo)
    payload = msgpack.packb(
        {
            "a": algo,
            "h": struct.pack("<I", checksum(head, algo=algo)),
            "w": struct.pack("<I", whole),
            "c": chunk_crcs,
        },
        use_bin_type=True,
    )
    return payload + _FOOTER.pack(len(payload), TRAILER_VERSION, TRAILER_MAGIC)


def read_trailer(blob: bytes) -> Optional[Trailer]:
    """Parse the trailer at the end of ``blob``; None when absent/unreadable.

    Absence is not an error at this layer — pre-trailer blobs are legitimate.
    Callers that must distinguish "legacy blob" from "trailer stripped" check
    the header's ``itg`` flag (which travels under the header checksum).
    """
    if len(blob) < _FOOTER.size or blob[-4:] != TRAILER_MAGIC:
        return None
    plen, ver, _magic = _FOOTER.unpack(blob[-_FOOTER.size :])
    if ver != TRAILER_VERSION or plen > len(blob) - _FOOTER.size:
        return None
    start = len(blob) - _FOOTER.size - plen
    try:
        payload = msgpack.unpackb(blob[start : len(blob) - _FOOTER.size], raw=False)
        algo = payload["a"]
        hdr = struct.unpack("<I", payload["h"])[0]
        whole = struct.unpack("<I", payload["w"])[0]
        crcs_raw = payload["c"]
        if len(crcs_raw) % 4:
            return None
        chunk_crcs = struct.unpack(f"<{len(crcs_raw) // 4}I", crcs_raw)
    except Exception:
        return None
    if not isinstance(algo, str):
        return None
    return Trailer(algo, hdr, whole, chunk_crcs, start)


# ---------------------------------------------------------------------------
# verification
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class VerifyResult:
    """Outcome of inspecting a container's integrity trailer."""

    has_trailer: bool
    header_ok: bool = True
    whole_ok: bool = True
    #: indices of chunks whose checksum mismatched; None when unknown (no
    #: trailer, or trailer/table disagree on the chunk count)
    bad_chunks: Optional[List[int]] = None

    @property
    def ok(self) -> bool:
        return self.header_ok and self.whole_ok


def chunk_bounds_of(header: Dict[str, Any], body_len: int) -> List[Tuple[int, int]]:
    """Body-relative (off, len) of each independently decodable chunk, from
    the header chunk table for v2+ multi-chunk containers, else one
    whole-body chunk.  Bounds are validated against ``body_len`` — a hostile
    chunk table cannot direct reads outside the body."""
    chunks = header.get("chunks")
    if header.get("v", 1) >= 2 and isinstance(chunks, list):
        # a chunk's framing alone needs >= 21 bytes (magic + lengths + a
        # 1-byte header), so the table length is bounded by the real body
        guard_count(len(chunks), body_len // 21 + 1, "chunk-table entries")
        out = []
        for i, c in enumerate(chunks):
            if not isinstance(c, dict):
                raise ContainerError(f"corrupt chunk table: entry {i} not a map")
            off = guard_count(c.get("off"), body_len, f"chunk {i} offset")
            ln = guard_count(c.get("len"), body_len - off, f"chunk {i} length")
            out.append((off, ln))
        return out
    return [(0, body_len)] if body_len else []


def inspect(blob: bytes, header: Dict[str, Any], body_off: int) -> VerifyResult:
    """Check every checksum the trailer carries; never raises on mismatch
    (that policy belongs to :func:`verify_container` / salvage decode)."""
    tr = read_trailer(blob)
    body_len = _declared_body_len(blob)
    core_len = body_off + body_len
    if tr is None or tr.start != core_len:
        # no trailer, or a "trailer" that does not sit flush with the
        # declared body — either way there is nothing trustworthy to verify
        return VerifyResult(has_trailer=False)
    res = VerifyResult(has_trailer=True)
    res.header_ok = checksum(blob[:body_off], algo=tr.algo) == tr.header_crc
    res.whole_ok = checksum(blob[:core_len], algo=tr.algo) == tr.whole_crc
    if not res.whole_ok and res.header_ok:
        # localize: the header (and so the chunk table) is trustworthy
        try:
            bounds = chunk_bounds_of(header, body_len)
        except ContainerError:
            bounds = None
        if bounds is not None and len(bounds) == len(tr.chunk_crcs):
            res.bad_chunks = [
                i
                for i, (off, ln) in enumerate(bounds)
                if checksum(
                    blob[body_off + off : body_off + off + ln], algo=tr.algo
                )
                != tr.chunk_crcs[i]
            ]
    return res


def _declared_body_len(blob: bytes) -> int:
    """Body length from the prologue (callers have already parse_header'd)."""
    return int.from_bytes(blob[12:20], "little", signed=True)


def verify_container(blob: bytes, header: Dict[str, Any], body_off: int) -> VerifyResult:
    """Strict-mode policy: raise :class:`IntegrityError` naming the first
    damaged chunk (or region) on any mismatch; blobs written before the
    trailer era pass un-verified unless their header claims a trailer."""
    res = inspect(blob, header, body_off)
    if not res.has_trailer:
        if header.get("itg"):
            raise IntegrityError(
                "container header declares an integrity trailer but none is "
                "attached — trailer stripped or container truncated",
                region="trailer",
            )
        return res
    if not res.header_ok:
        raise IntegrityError(
            "container header bytes fail their checksum — header damaged",
            region="header",
        )
    if not res.whole_ok:
        if res.bad_chunks:
            first = res.bad_chunks[0]
            raise IntegrityError(
                f"container chunk {first} fails its checksum "
                f"({len(res.bad_chunks)} of {_nchunks(header)} chunks damaged)",
                chunk_index=first,
            )
        raise IntegrityError(
            "container fails its whole-blob digest (damage outside any "
            "chunk: padding, chunk table, or trailer bytes)",
        )
    return res


def _nchunks(header: Dict[str, Any]) -> int:
    chunks = header.get("chunks")
    return len(chunks) if isinstance(chunks, list) else 1


def verify_blob(blob: bytes) -> bool:
    """One-call integrity check (no decode): True when a trailer was present
    and every checksum passed, False for legacy trailer-less blobs; raises
    :class:`IntegrityError` / :class:`ContainerError` on damage."""
    from . import pipeline as pl_mod  # local: integrity is imported by pipeline

    with decode_errors():
        header, body_off = pl_mod.parse_header(blob)
        return verify_container(blob, header, body_off).has_trailer


# ---------------------------------------------------------------------------
# salvage reporting
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ChunkDamage:
    """One damaged chunk: flat element range [start, stop) filled/lost."""

    index: int
    start: int
    stop: int
    reason: str  # "checksum" | "decode-error" | "missing"


@dataclasses.dataclass
class SalvageReport:
    """What salvage decode recovered and what it had to give up on."""

    total_chunks: int = 0
    recovered: List[int] = dataclasses.field(default_factory=list)
    damage: List[ChunkDamage] = dataclasses.field(default_factory=list)
    fill_value: float = 0.0
    checksummed: bool = False  # a trailer drove the per-chunk verdicts

    @property
    def ok(self) -> bool:
        return not self.damage

    @property
    def lost_elements(self) -> int:
        return sum(d.stop - d.start for d in self.damage)

    def lost_ranges(self) -> List[Tuple[int, int]]:
        return [(d.start, d.stop) for d in self.damage]

    def recovered_ranges(
        self, chunk_ranges: Sequence[Tuple[int, int]]
    ) -> List[Tuple[int, int]]:
        return [chunk_ranges[i] for i in self.recovered]

    def summary(self) -> str:
        if self.ok:
            return f"salvage: all {self.total_chunks} chunks recovered"
        lost = ", ".join(
            f"#{d.index}[{d.start}:{d.stop}] ({d.reason})" for d in self.damage
        )
        return (
            f"salvage: {len(self.recovered)}/{self.total_chunks} chunks "
            f"recovered, {self.lost_elements} elements lost: {lost}"
        )


# ---------------------------------------------------------------------------
# writer switch (benchmarks measure integrity-off vs -on; tests pin legacy)
# ---------------------------------------------------------------------------

WRITE_TRAILERS = True


@contextlib.contextmanager
def trailers_disabled() -> Iterator[None]:
    """Write pre-trailer (legacy-framed) containers inside the block — for
    overhead benchmarking and legacy-fixture generation only."""
    global WRITE_TRAILERS
    prev = WRITE_TRAILERS
    WRITE_TRAILERS = False
    try:
        yield
    finally:
        WRITE_TRAILERS = prev
