"""Block-level multi-predictor hybrid engine (paper §3.2, v5 container).

The chunked engine (chunking.py) contests whole pipelines per CHUNK; the
paper's second headline contribution is finer: *per-block* best-fit predictor
selection via an error-estimation criterion (SZ3 §3.2 — the same idea behind
SZ2's block-granular Lorenzo/regression contest and the online SZ-vs-ZFP
selector of Tao et al. 2018).  A chunk mixing regimes (smooth region next to
an oscillatory one) pays for whichever single predictor wins on the sampled
sub-block; this module closes that gap.

:class:`BlockHybridCompressor` (factory ``sz3_hybrid``) tiles the array into
fixed-size blocks (256 for 1-D, 16x16 for 2-D, 8x8x8 for 3-D), scores FOUR
candidates per block with the code-bits criterion, and keeps the per-block
winner:

  tag 0  zero        — predict 0 on the prequantized grid (the constant /
                       zero-block fast path; also the least-bad fallback on
                       oscillatory data, where differencing doubles noise)
  tag 1  lorenzo1    — block-local order-1 dual-quant Lorenzo
  tag 2  lorenzo2    — order-2 Lorenzo (wins on polynomial trends whose first
                       differences still carry a ramp)
  tag 3  regression  — SZ2 hyperplane fit, quantized coefficients

Every block's quantization indices feed ONE shared stream — a single Huffman
table and a single lossless pass, exactly the paper's amortization — while a
2-bit/block tag array and the delta-coded regression-coefficient streams for
regression-winning blocks ride as compact side channels inside the same
lossless body.  Prediction stays locally optimal; entropy coding stays
global.

Container: v5, kind "hybrid", auto-detected by ``pipeline.decompress``
(v1–v4 decode unchanged).  Error modes: ABS natively; REL resolves against
global finite stats; PW_REL composes :class:`preprocess.LogTransform`
automatically (sign/zero/non-finite side channels in ``pre_meta``), so the
engine is PW_REL-native and usable as a per-chunk candidate under every mode.
The bound is exact and unconditional: integer-grid candidates inherit the
``prequantize`` fail channel, regression rides ``quantize``'s raw-storage
path.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from . import encoders as enc_mod
from . import lossless as ll_mod
from . import pipeline as pl_mod
from . import predictors as pred_mod
from . import preprocess as pre_mod
from . import quantizers as quant_mod
from . import telemetry as tel
from . import transform as tr_mod
from .config import CompressionConfig, ErrorBoundMode
from .integrity import ContainerError, guard_alloc, guard_count, guard_shape
from .pipeline import CompressionResult, container_body, pack_container
from .predictors import (
    _int_code_bits,
    _pack_mask,
    _unpack_mask,
    block_coords,
    block_lorenzo_filter,
    block_lorenzo_inverse,
    block_plane_fit,
    blockify,
    pad_to_blocks,
    unblockify,
)

_VERSION5 = 5

#: block side length by dimensionality: ~256-4096 elements per block, so the
#: 2-bit tag costs <0.01 bits/value and the shared Huffman table amortizes,
#: while blocks stay small enough to isolate a regime change
BLOCK_SIDES = {1: 256, 2: 16, 3: 8}

#: side length for ndim >= 4 (4^d elements keep the coefficient overhead sane)
DEFAULT_SIDE = 4

#: tag values — also the tie-break priority (argmin keeps the lowest tag)
TAG_ZERO, TAG_LOR1, TAG_LOR2, TAG_REG = 0, 1, 2, 3
TAG_NAMES = ("zero", "lorenzo1", "lorenzo2", "regression")


def block_side_for(ndim: int, override: Optional[int] = None) -> int:
    if override:
        return max(2, int(override))
    return BLOCK_SIDES.get(int(ndim), DEFAULT_SIDE)


def _gamma_bits(q: np.ndarray) -> np.ndarray:
    """Per-code length proxy: Elias-gamma-style ``2*log2(1+|q|) + 1``.

    Monotone in |q|, zero-centred, and fully vectorizable across blocks —
    the per-block specialization of the ``code_bits`` entropy model (a true
    per-block empirical entropy would need one histogram per block per
    candidate; the gamma length ranks candidates identically on the
    populations that matter: near-zero vs wide).
    """
    return 2.0 * np.log2(1.0 + np.abs(np.asarray(q, np.float64))) + 1.0


def _pack_tags(tags: np.ndarray) -> bytes:
    """2 bits per block, 4 blocks per byte (little-endian within the byte)."""
    n = tags.size
    padded = np.zeros(((n + 3) // 4) * 4, np.uint8)
    padded[:n] = tags
    packed = (
        padded[0::4]
        | (padded[1::4] << 2)
        | (padded[2::4] << 4)
        | (padded[3::4] << 6)
    )
    return packed.tobytes()


def _unpack_tags(buf: bytes, n: int) -> np.ndarray:
    raw = np.frombuffer(buf, np.uint8)
    out = np.empty(raw.size * 4, np.uint8)
    out[0::4] = raw & 3
    out[1::4] = (raw >> 2) & 3
    out[2::4] = (raw >> 4) & 3
    out[3::4] = (raw >> 6) & 3
    return out[:n]


def _select_tags(
    qfull: np.ndarray,
    d1: np.ndarray,
    d2: np.ndarray,
    qres: np.ndarray,
    coef_q: List[np.ndarray],
    reg_bad: np.ndarray,
) -> np.ndarray:
    """Per-block winner by estimated coded bits (paper: estimate_error).

    All four candidates are scored in the same currency (gamma-length bits of
    their integer codes); regression additionally pays its delta-coded
    coefficient streams.  Blocks whose fit is non-finite never win regression
    (their points belong on the int-grid fail path).
    """
    nb = qfull.shape[0]
    if nb == 0:
        return np.zeros(0, np.uint8)
    axes = tuple(range(1, qfull.ndim))
    cost = np.empty((4, nb))
    cost[TAG_ZERO] = _gamma_bits(qfull).sum(axis=axes)
    cost[TAG_LOR1] = _gamma_bits(d1).sum(axis=axes)
    cost[TAG_LOR2] = _gamma_bits(d2).sum(axis=axes)
    reg_cost = _gamma_bits(qres).sum(axis=axes)
    for qc in coef_q:
        # the real stream delta-codes coefficients against the PREVIOUS
        # REGRESSION WINNER (unknown until selection completes), so price
        # the cheaper of delta-vs-neighbour and coding the value fresh —
        # charging the raw neighbour delta would overbill blocks whose
        # global-order predecessor sits in a different regime
        reg_cost = reg_cost + np.minimum(
            _gamma_bits(np.diff(qc, prepend=0)), _gamma_bits(qc)
        )
    cost[TAG_REG] = np.where(reg_bad, np.inf, reg_cost)
    return np.argmin(cost, axis=0).astype(np.uint8)


def _candidate_codes(
    blocks: np.ndarray, qfull: np.ndarray, eb: float
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, List[np.ndarray], np.ndarray, np.ndarray]:
    """All candidate code estimates for a pre-blockified array.

    Returns (d1, d2, qres, coef_q, pred_reg, reg_bad): the order-1/order-2
    Lorenzo differences of the prequantized grid, the regression residual
    bin indices, the quantized coefficient streams, the regression
    prediction, and the bad-fit block mask.
    """
    b = blocks.shape[1] if blocks.ndim > 1 else 1
    d1 = block_lorenzo_filter(qfull, 1)
    d2 = block_lorenzo_filter(d1, 1)  # second application == order 2
    coef_q, pred_reg, reg_bad = block_plane_fit(blocks, b, eb)
    with np.errstate(invalid="ignore", over="ignore"):
        qres = np.rint((blocks - pred_reg) / (2.0 * eb))
    qres = np.where(np.isfinite(qres), qres, 0.0)
    return d1, d2, qres, coef_q, pred_reg, reg_bad


class BlockHybridCompressor:
    """Block-level multi-predictor hybrid engine (module docstring above).

    Follows the :class:`pipeline.SZ3Compressor` module protocol (preprocessor
    slot, quantizer/encoder/lossless stages, ``compress``/``spec``), so the
    chunked engines can contest it per chunk and compose ``LogTransform``
    into it for PW_REL, and ``pipeline.decompress`` rebuilds it from the
    self-describing v5 header.
    """

    kind = "hybrid"

    def __init__(
        self,
        preprocessor: Optional[pre_mod.Preprocessor] = None,
        quantizer: Optional[quant_mod.QuantizerBase] = None,
        encoder: Optional[enc_mod.Encoder] = None,
        lossless: Optional[ll_mod.LosslessBackend] = None,
        conf: Optional[CompressionConfig] = None,
        block_side: Optional[int] = None,
    ):
        self.preprocessor = preprocessor or pre_mod.Identity()
        self.quantizer = quantizer or quant_mod.LinearScaleQuantizer()
        self.encoder = encoder or enc_mod.HuffmanEncoder()
        self.lossless = lossless or ll_mod.Zstd()
        self.conf = conf or CompressionConfig()
        self.block_side = block_side

    # -- spec (self-describing container) ------------------------------------
    def spec(self) -> Dict[str, Any]:
        return {
            "kind": self.kind,
            "preprocessor": self.preprocessor.name,
            "quantizer": self.quantizer.name,
            "quant_radius": self.quantizer.radius,
            "encoder": self.encoder.name,
            "lossless": self.lossless.name,
        }

    # -- selection-contest hook (chunking.select_pipeline) -------------------
    def estimate_error(
        self, sample: np.ndarray, abs_eb: float, conf: CompressionConfig
    ) -> float:
        """Estimated coded bits/element on ``sample`` — the chunk-level
        analogue of ``Predictor.estimate_error``, so ``select_pipeline`` can
        contest the hybrid engine against whole pipelines.

        Runs the real per-block contest on the sample's estimated codes and
        prices the winning population (plus coefficient and tag side
        channels) with the shared ``code_bits`` entropy model, normalized by
        the UNPADDED element count so tiling overhead on awkward shapes is
        visible to the contest.
        """
        x = np.asarray(sample, np.float64)
        if x.size == 0:
            return 0.0
        if x.ndim == 0:
            x = x.reshape(1)
        b = block_side_for(x.ndim, self.block_side)
        xp, _ = pad_to_blocks(x, b)
        blocks = blockify(xp, b)
        nb = blocks.shape[0]
        with np.errstate(invalid="ignore", over="ignore"):
            scaled = blocks / (2.0 * abs_eb)
        qfull = np.where(np.isfinite(scaled), scaled, 0.0)
        qfull = np.rint(np.clip(qfull, -(2.0**62), 2.0**62))
        d1, d2, qres, coef_q, _pred, reg_bad = _candidate_codes(
            blocks, qfull, abs_eb
        )
        tags = _select_tags(qfull, d1, d2, qres, coef_q, reg_bad)
        cand = np.stack(
            [c.reshape(nb, -1) for c in (qfull, d1, d2, qres)]
        )
        win = np.take_along_axis(
            cand, tags.reshape(1, nb, 1).astype(np.int64), axis=0
        )[0]
        pooled = [win.reshape(-1)]
        use_reg = tags == TAG_REG
        for qc in coef_q:
            pooled.append(np.diff(qc[use_reg], prepend=0))
        allq = np.concatenate(pooled)
        bits_per_code = _int_code_bits(allq, conf.quant_radius)
        return (bits_per_code * allq.size + 2.0 * nb) / x.size

    # -- compression ----------------------------------------------------------
    def compress(
        self,
        data: np.ndarray,
        conf: Optional[CompressionConfig] = None,
        with_stats: bool = False,
    ) -> CompressionResult:
        conf = conf or self.conf
        data = np.asarray(data)
        if data.dtype not in (np.float32, np.float64):
            data = data.astype(np.float32)
        pre = self.preprocessor
        if conf.mode == ErrorBoundMode.PW_REL and isinstance(pre, pre_mod.Identity):
            # PW_REL-native: auto-compose the log-domain conversion so the
            # pointwise bound holds by construction (no eb*absmax degradation)
            pre = pre_mod.LogTransform()
        pdata, conf2, pre_meta = pre.forward(data, conf)
        rng, absmax = pl_mod._finite_stats(pdata)
        abs_eb = conf2.resolve_abs_eb(rng, absmax)
        if abs_eb <= 0:
            abs_eb = float(np.finfo(np.float64).tiny)
        self.quantizer.begin(abs_eb, pdata.dtype)
        with tel.span("predict", bytes=pdata.nbytes):  # per-block contest
            codes, tag_bytes, hmeta = self._compress_blocks(pdata, conf2)
        with tel.span("huffman", bytes=codes.nbytes):
            enc_bytes = self.encoder.encode(codes)
        q_bytes = self.quantizer.save()
        spec = self.spec()
        spec["preprocessor"] = pre.name  # the EFFECTIVE preprocessor (PW_REL
        # auto-composes LogTransform even when the slot holds Identity)
        header = {
            "v": _VERSION5,
            "kind": "hybrid",
            "spec": spec,
            "shape": list(data.shape),
            "pshape": list(pdata.shape),
            "dtype": data.dtype.str,
            "pdtype": pdata.dtype.str,
            "mode": conf.mode.value,
            "eb": float(conf.eb),
            "abs_eb": float(abs_eb),
            "n_codes": int(codes.size),
            **(
                {"eb_rel": float(conf.eb_rel)}
                if conf.eb_rel is not None
                else {}
            ),
            "enc_len": len(enc_bytes),
            "q_len": len(q_bytes),
            "tag_len": len(tag_bytes),
            "pre_meta": pl_mod._clean_meta(pre_meta),
            "hyb_meta": pl_mod._clean_meta(hmeta),
        }
        with tel.span("lossless", bytes=len(enc_bytes) + len(q_bytes) + len(tag_bytes)):
            body = self.lossless.compress(enc_bytes + q_bytes + tag_bytes)
        blob = pack_container(header, body)
        if tel.enabled():
            counts = {TAG_NAMES[t]: int(hmeta["counts"][t]) for t in range(4)}
            tel.record_decision(tel.make_decision(
                "sz3_hybrid",
                max(counts, key=counts.get),
                scope="block-summary",
                candidates=list(TAG_NAMES),
                estimates={k: float(v) for k, v in counts.items()},
                realized_bits=8.0 * len(blob) / max(1, data.size),
                n_elems=int(data.size),
                fallbacks=int(hmeta["nfail"]),
                extra={"counts": counts, "n_reg": int(hmeta["n_reg"]),
                       "nb": int(hmeta["nb"])},
            ))
        meta = None
        if with_stats:
            meta = dict(hmeta)
            meta.pop("fail_mask", None)
            meta.pop("fail_vals", None)
            meta["tag_shares"] = {
                TAG_NAMES[t]: hmeta["counts"][t] / max(1, hmeta["nb"])
                for t in range(4)
            }
        return CompressionResult(
            blob=blob,
            ratio=data.nbytes / max(1, len(blob)),
            codes=codes if with_stats else None,
            meta=meta,
        )

    def _compress_blocks(
        self, pdata: np.ndarray, conf: CompressionConfig
    ) -> Tuple[np.ndarray, bytes, Dict[str, Any]]:
        """Tile, contest, and emit the shared code stream + side channels."""
        quantizer = self.quantizer
        x64 = np.asarray(pdata, np.float64)
        if x64.ndim == 0:
            x64 = x64.reshape(1)
        nd = x64.ndim
        b = block_side_for(nd, self.block_side)
        xp, work_shape = pad_to_blocks(x64, b)
        blocks = blockify(xp, b)  # (nb,) + (b,)*nd
        nb = blocks.shape[0]
        eb = quantizer.eb
        # prequantize once for all integer-grid candidates; fail marks points
        # (non-finite / cast-rounding) the grid cannot represent in bound
        with tel.span("quantize", bytes=blocks.nbytes):
            qfull, _recon, fail = quantizer.prequantize(blocks)
        d1, d2, qres, coef_q, pred_reg, reg_bad = _candidate_codes(
            blocks, qfull, eb
        )
        tags = _select_tags(qfull, d1, d2, qres, coef_q, reg_bad)
        use_reg = tags == TAG_REG
        # shared code stream, in decode order: the delta-coded coefficient
        # streams of regression-winning blocks, then the integer-grid data
        # codes grouped by tag (block order within each group), then the
        # float-domain regression residual codes
        parts: List[np.ndarray] = []
        for qc in coef_q:
            kept = qc[use_reg]
            parts.append(quantizer.quantize_int_diff(np.diff(kept, prepend=0)))
        for tag, d in ((TAG_ZERO, qfull), (TAG_LOR1, d1), (TAG_LOR2, d2)):
            parts.append(quantizer.quantize_int_diff(d[tags == tag].reshape(-1)))
        dcodes, _ = quantizer.quantize(
            blocks[use_reg].reshape(-1), pred_reg[use_reg].reshape(-1)
        )
        codes = np.concatenate([p.astype(dcodes.dtype) for p in parts] + [dcodes])
        meta: Dict[str, Any] = {
            "bs": int(b),
            "padded_shape": list(xp.shape),
            "work_shape": list(work_shape),
            "nb": int(nb),
            "n_reg": int(use_reg.sum()),
            "counts": [int((tags == t).sum()) for t in range(4)],
        }
        int_fail = fail[~use_reg]
        nfail = int(int_fail.sum())
        meta["nfail"] = nfail
        if nfail:
            meta["fail_mask"] = _pack_mask(int_fail)
            meta["fail_vals"] = blocks[~use_reg][int_fail].tobytes()
        return codes, _pack_tags(tags), meta

    # -- decompression (pipeline.decompress dispatch target) ------------------
    @staticmethod
    def _decompress_body(blob: bytes, header: Dict[str, Any], body_off: int) -> np.ndarray:
        spec = header["spec"]
        quantizer = quant_mod.make(spec["quantizer"], radius=spec["quant_radius"])
        encoder = enc_mod.make(spec["encoder"])
        enc_len = guard_alloc(header["enc_len"], "enc_len")
        q_len = guard_alloc(header["q_len"], "q_len")
        tag_len = guard_alloc(header["tag_len"], "tag_len")
        total = guard_alloc(enc_len + q_len + tag_len, "hybrid body")
        body = ll_mod.make(spec["lossless"]).decompress_bounded(
            container_body(blob, body_off), total
        )
        if len(body) != total:
            raise ContainerError(
                f"hybrid body decompressed to {len(body)} bytes; header "
                f"declares {total} (enc+q+tag)"
            )
        enc_bytes = body[:enc_len]
        q_bytes = body[enc_len : enc_len + q_len]
        tag_bytes = body[enc_len + q_len : enc_len + q_len + tag_len]
        pdtype = np.dtype(header["pdtype"])
        quantizer.begin(header["abs_eb"], pdtype)
        quantizer.load(q_bytes)
        hm = header["hyb_meta"]
        b = guard_count(hm["bs"], 1 << 12, "hybrid block side")
        if b < 1:
            raise ContainerError("corrupt hybrid container: block side < 1")
        padded_shape = guard_shape(hm["padded_shape"], 8, "padded_shape")
        work_shape = guard_shape(hm["work_shape"], 8, "work_shape")
        nd = len(padded_shape)
        blk_elems = b**nd
        nb_limit = int(np.prod(padded_shape, dtype=np.int64)) // max(1, blk_elems) + 1
        nb = guard_count(hm["nb"], nb_limit, "hybrid block count")
        n_reg = guard_count(hm["n_reg"], nb, "hybrid regression count")
        guard_alloc(nb * blk_elems * 8, "hybrid block grid")
        n_codes = guard_count(
            header["n_codes"], 2 * nb * blk_elems + 4096, "n_codes"
        )
        codes = np.asarray(encoder.decode(enc_bytes, n_codes))
        if tag_len != (nb + 3) // 4:
            raise ContainerError(
                f"corrupt hybrid container: tag channel holds {tag_len} "
                f"bytes, {(nb + 3) // 4} expected for {nb} blocks"
            )
        eb = quantizer.eb
        tags = _unpack_tags(tag_bytes, nb)
        use_reg = tags == TAG_REG
        blk = b**nd
        pos = 0
        # 1. regression coefficient streams (delta-coded, winning blocks only)
        qhat: List[np.ndarray] = []
        for k in range(nd + 1):
            dq = quantizer.recover_int_diff(codes[pos : pos + n_reg])
            pos += n_reg
            ceb = eb / 2.0 if k == 0 else eb / (2.0 * b)
            qhat.append(np.cumsum(dq).astype(np.float64) * (2.0 * ceb))
        # 2. integer-grid groups: zero (identity), lorenzo order 1 / order 2
        n_int = nb - n_reg
        int_blocks = np.empty((n_int,) + (b,) * nd, np.float64)
        int_tags = tags[~use_reg]
        for tag, order in ((TAG_ZERO, 0), (TAG_LOR1, 1), (TAG_LOR2, 2)):
            cnt = int((tags == tag).sum())
            d = quantizer.recover_int_diff(codes[pos : pos + cnt * blk])
            pos += cnt * blk
            d = d.reshape((cnt,) + (b,) * nd)
            q = block_lorenzo_inverse(d, order) if order else d
            int_blocks[int_tags == tag] = quantizer.dequantize_int(q).astype(
                np.float64
            )
        if hm.get("nfail"):
            fl = _unpack_mask(hm["fail_mask"], n_int * blk).reshape(
                (n_int,) + (b,) * nd
            )
            int_blocks[fl] = np.frombuffer(hm["fail_vals"], np.float64)
        # 3. regression residuals against the coefficient-rebuilt planes
        cs = block_coords(b, nd)
        pred = qhat[0].reshape((n_reg,) + (1,) * nd)
        for k in range(nd):
            pred = pred + qhat[1 + k].reshape((n_reg,) + (1,) * nd) * cs[k]
        reg_recon = quantizer.recover(pred.reshape(-1), codes[pos:])
        blocks = np.empty((nb,) + (b,) * nd, np.float64)
        blocks[~use_reg] = int_blocks
        blocks[use_reg] = np.asarray(reg_recon, np.float64).reshape(
            (n_reg,) + (b,) * nd
        )
        out = unblockify(blocks, padded_shape, b)
        out = out[tuple(slice(0, s) for s in work_shape)]
        pdata = out.astype(pdtype).reshape(tuple(header["pshape"]))
        conf = CompressionConfig(
            mode=ErrorBoundMode(header["mode"]),
            eb=header["eb"],
            quant_radius=spec["quant_radius"],
        )
        data = pre_mod.make(spec["preprocessor"]).inverse(
            pdata, conf, header["pre_meta"]
        )
        return data.astype(np.dtype(header["dtype"])).reshape(
            tuple(header["shape"])
        )


def sz3_hybrid(block_side: Optional[int] = None, **kw) -> BlockHybridCompressor:
    """Named factory: block-level multi-predictor hybrid engine (v5)."""
    return BlockHybridCompressor(block_side=block_side, **kw)


# registration (blockwise imports pipeline/transform, never vice versa); the
# hybrid engine also joins the auto contest — sz3_auto / sz3_quality resolve
# AUTO_CANDIDATES at call time, so they pick this up
pl_mod.PIPELINES["sz3_hybrid"] = sz3_hybrid
if "sz3_hybrid" not in tr_mod.AUTO_CANDIDATES:
    tr_mod.AUTO_CANDIDATES = tr_mod.AUTO_CANDIDATES + ("sz3_hybrid",)
