"""SZx-style ultra-fast fixed-length coder (v6 container, factory ``sz3_fast``).

The prediction pipelines buy ratio with an entropy stage (Huffman + lossless)
whose encode cost dominates end-to-end throughput (BENCH_PR5: ~18-22 MB/s
chunked compress).  SZx ("An Ultra-fast Error-bounded Lossy Compressor",
PAPERS.md) shows the other end of the speed-ratio frontier: fixed-length
coding with NO entropy pass at all.  This module is that tier.

Format (all offsets derivable from the header — no in-band markers):

  * the flattened array is partitioned into fixed ``bs``-element blocks
    (256 default, 128 supported); the tail block is padded with its own edge
    value and cropped on decode.
  * each block stores its mean in the storage dtype.  A block is CONSTANT
    when every |x_i - mean| <= eb — 1 tag bit + the mean is its entire
    payload (the SZx constant-block fast path).
  * NONCONSTANT blocks quantize the mean-subtracted residuals on the 2*eb
    grid (``q = rint((x - mean) / (2 eb))``) and store them FIXED-LENGTH:
    the block's required bit
    count ``w = bitlength(max|q|)`` rides a 1-byte side channel, and blocks
    sharing a width are pooled into one truncated-bitplane group (``w + 1``
    planes of offset-binary ``q + 2^w``, MSB-invariant planar layout, packed
    8 values/byte).  No Huffman, no lossless pass (the ``lossless`` slot
    defaults to Passthrough; the spec records whatever is composed in).
  * points the grid cannot represent in bound — non-finite values, residuals
    beyond the 2^30 code clip, cast-rounding stragglers — ride the exact
    fail channel (indices + raw storage-dtype values), so the bound is
    unconditional, same idiom as the quantizer's ``prequantize`` fail mask.

Throughput comes from doing ALL block arithmetic in the storage dtype
(float32 data never touches a float64 temp — half the memory traffic of the
prediction pipelines) with in-place ufuncs.  The decoder reconstructs with
the exact same dtype and operation order, so the encoder can verify every
coded point against the decoder's bit-identical reconstruction and fail the
stragglers — work-dtype rounding costs a few extra fail-channel entries
(the verify threshold keeps a 1e-6 relative margin inside eb), never the
bound.

Error modes: ABS natively; REL / ABS_AND_REL / ABS_OR_REL resolve against
global finite stats; PW_REL composes :class:`preprocess.LogTransform`
automatically (side channels in ``pre_meta``), so the engine is usable as a
per-chunk candidate under every mode.  Container: v6, kind "fast",
auto-detected by ``pipeline.decompress`` (v1-v5 decode unchanged).

Device path: ``kernels/fastmode`` fuses the per-block classify+reduce stage
(mean + max-deviation) into one Pallas pass.  The kernel only produces the
classification hint — constant blocks are re-verified on the host against
the STORED mean and the residual coding always closes with the host-side
reconstruction check feeding the fail channel, so the bound holds on both
routes regardless of device rounding (both-routes verification, same policy
as kernels/lorenzo and kernels/transform).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from . import lossless as ll_mod
from . import pipeline as pl_mod
from . import preprocess as pre_mod
from . import telemetry as tel
from . import transform as tr_mod
from .config import CompressionConfig, ErrorBoundMode
from .integrity import ContainerError, guard_alloc, guard_count, guard_shape
from .pipeline import CompressionResult, container_body, pack_container

_VERSION6 = 6

#: fixed block length (elements); 128 also supported — both are whole VPU
#: lane multiples so the device classify+reduce kernel tiles them natively
DEFAULT_BS = 256
VALID_BS = (128, 256)

#: residual codes are clipped to +-2^30 (clipped points go to the fail
#: channel) so offset-binary values stay well inside uint32
_Q_CLIP = 1 << 30

#: below this many elements the device round-trip costs more than it saves
_DEVICE_MIN_SIZE = 1 << 16


# ---------------------------------------------------------------------------
# fixed-width planar bit packing (the truncated-bitplane storage)
# ---------------------------------------------------------------------------

def _pack_planes(u: np.ndarray, nplanes: int) -> bytes:
    """Pack unsigned values (< 2^nplanes) as ``nplanes`` planar bitplanes.

    Planar (one plane of all values, then the next) rather than interleaved:
    each plane is a single vectorized mask+packbits pass, and the layout is
    byte-aligned per plane so decode needs no bit cursor.  Planes are pulled
    8 at a time from a contiguous uint8 byte lane of the values (1-byte
    traffic instead of 4-byte), and ``np.packbits`` packs NONZERO-ness, so a
    single masked AND per plane replaces the shift-to-bit-0 dance.
    """
    u = np.ascontiguousarray(u, np.uint32)
    uv = u.view(np.uint8)
    parts = []
    tmp = np.empty(u.size, np.uint8)
    for base in range(0, nplanes, 8):
        lane = base // 8 if np.little_endian else 3 - base // 8
        ub = np.ascontiguousarray(uv[lane::4])
        for p in range(base, min(nplanes, base + 8)):
            np.bitwise_and(ub, np.uint8(1 << (p - base)), out=tmp)
            parts.append(np.packbits(tmp))
    return b"".join(part.tobytes() for part in parts)


def _unpack_planes(buf: bytes, offset: int, n: int, nplanes: int) -> Tuple[np.ndarray, int]:
    """Inverse of :func:`_pack_planes`; returns (values, bytes consumed)."""
    nbytes_plane = (n + 7) // 8
    u = np.zeros(n, np.uint32)
    pos = offset
    for p in range(nplanes):
        plane = np.unpackbits(
            np.frombuffer(buf, np.uint8, count=nbytes_plane, offset=pos),
            count=n,
        )
        u |= plane.astype(np.uint32) << np.uint32(p)
        pos += nbytes_plane
    return u, pos - offset


def _required_bits(maxmag: np.ndarray) -> np.ndarray:
    """Per-block magnitude bit count: bitlength(max|q|), 0 for all-zero."""
    m = np.asarray(maxmag, np.int64)
    w = np.zeros(m.shape, np.uint8)
    nz = m > 0
    if nz.any():
        # float log2 is exact for the powers of two that sit on the boundary
        # (|q| <= 2^30 keeps the mantissa honest)
        w[nz] = (np.floor(np.log2(m[nz].astype(np.float64))).astype(np.int64) + 1).astype(np.uint8)
    return w


class FastModeCompressor:
    """SZx-style fixed-length block coder (module docstring above).

    Exposes the same module protocol as the Algorithm-1 pipelines
    (``preprocessor`` slot, ``compress``/``spec``/``estimate_error``), so the
    chunked engines can contest it per chunk — including under PW_REL via the
    LogTransform composition — and ``pipeline.decompress`` rebuilds it from
    the self-describing v6 header.
    """

    kind = "fast"

    def __init__(
        self,
        bs: int = DEFAULT_BS,
        preprocessor: Optional[pre_mod.Preprocessor] = None,
        lossless: Optional[ll_mod.LosslessBackend] = None,
        conf: Optional[CompressionConfig] = None,
        device: str = "auto",
    ):
        if int(bs) not in VALID_BS:
            raise ValueError(f"fast-mode block size must be one of {VALID_BS}")
        self.bs = int(bs)
        self.preprocessor = preprocessor or pre_mod.Identity()
        # Passthrough by default: a lossless pass would reintroduce the very
        # latency this tier exists to shed (compose Zstd explicitly if the
        # extra ratio is worth it)
        self.lossless = lossless or ll_mod.Passthrough()
        self.conf = conf or CompressionConfig()
        self.device = device

    # -- spec (self-describing container) ------------------------------------
    def spec(self) -> Dict[str, Any]:
        return {
            "kind": self.kind,
            "bs": self.bs,
            "preprocessor": self.preprocessor.name,
            "lossless": self.lossless.name,
        }

    # -- selection-contest hook (chunking.select_pipeline) -------------------
    def estimate_error(
        self, sample: np.ndarray, abs_eb: float, conf: CompressionConfig
    ) -> float:
        """Estimated coded bits/element on ``sample`` — same currency as the
        other pipelines' estimators.  Fixed-length coding makes this almost
        exact: constant blocks pay the mean + tag, nonconstant blocks pay
        ``w + 1`` bits/element plus the mean/width side channels."""
        x64 = np.asarray(sample, np.float64).reshape(-1)
        if x64.size == 0:
            return 0.0
        bs = self.bs
        itembits = 8.0 * np.dtype(
            sample.dtype if sample.dtype in (np.float32, np.float64) else np.float32
        ).itemsize
        eb = max(float(abs_eb), float(np.finfo(np.float64).tiny))
        xb, _n = _pad_blocks_1d(x64, bs)
        means = xb.mean(axis=1)
        means = np.where(np.isfinite(means), means, 0.0)
        resid = xb - means[:, None]
        with np.errstate(invalid="ignore", over="ignore"):
            dev = np.abs(resid).max(axis=1)
        const = dev <= eb
        q = np.where(np.isfinite(resid), resid, 0.0) / (2.0 * eb)
        mq = np.abs(np.rint(np.clip(q, -_Q_CLIP, _Q_CLIP))).max(axis=1)
        w = _required_bits(mq[~const].astype(np.int64))
        bits = (
            # every block: 1 tag bit + the stored mean
            xb.shape[0] * (1.0 + itembits)
            # nonconstant blocks: width byte + (w+1) bits per element
            + (w.astype(np.float64) + 1.0).sum() * bs
            + w.size * 8.0
        )
        return bits / x64.size

    # -- device routing -------------------------------------------------------
    def _device_stats(self, xb: np.ndarray) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """(means in storage dtype, max-deviation hint) via the Pallas
        classify+reduce kernel, or None when the host route should run."""
        if self.device == "off":
            return None
        if self.device != "force" and xb.size < _DEVICE_MIN_SIZE:
            return None
        try:
            from ..kernels.fastmode import ops as fops
        except Exception:  # jax/pallas unavailable -> host route
            return None
        if self.device != "force" and not fops.device_default():
            return None
        with tel.span("device_transfer", bytes=xb.nbytes):
            means32, dev32 = fops.block_stats(xb.astype(np.float32, copy=False))
        return means32, dev32.astype(np.float64)

    # -- compression ----------------------------------------------------------
    def compress(
        self,
        data: np.ndarray,
        conf: Optional[CompressionConfig] = None,
        with_stats: bool = False,
    ) -> CompressionResult:
        conf = conf or self.conf
        data = np.asarray(data)
        if data.dtype not in (np.float32, np.float64):
            data = data.astype(np.float32)
        pre = self.preprocessor
        if conf.mode == ErrorBoundMode.PW_REL and isinstance(pre, pre_mod.Identity):
            # PW_REL-native: compose the log-domain conversion so the
            # pointwise bound holds by construction
            pre = pre_mod.LogTransform()
        pdata, conf2, pre_meta = pre.forward(data, conf)
        rng, absmax = pl_mod._finite_stats(pdata)
        abs_eb = conf2.resolve_abs_eb(rng, absmax)
        if abs_eb <= 0:
            abs_eb = float(np.finfo(np.float64).tiny)
        with tel.span("quantize", bytes=pdata.nbytes):
            body_parts, fmeta = self._encode_blocks(pdata, abs_eb)
        spec = self.spec()
        spec["preprocessor"] = pre.name  # the EFFECTIVE preprocessor
        header = {
            "v": _VERSION6,
            "kind": "fast",
            "spec": spec,
            "shape": list(data.shape),
            "pshape": list(pdata.shape),
            "dtype": data.dtype.str,
            "pdtype": pdata.dtype.str,
            "mode": conf.mode.value,
            "eb": float(conf.eb),
            "abs_eb": float(abs_eb),
            **(
                {"eb_rel": float(conf.eb_rel)}
                if conf.eb_rel is not None
                else {}
            ),
            "pre_meta": pl_mod._clean_meta(pre_meta),
            "fast_meta": pl_mod._clean_meta(fmeta),
        }
        with tel.span("lossless", bytes=sum(len(p) for p in body_parts)):
            body = self.lossless.compress(b"".join(body_parts))
        blob = pack_container(header, body)
        if tel.enabled():
            nb, n_const = int(fmeta["nb"]), int(fmeta["n_const"])
            tel.record_decision(tel.make_decision(
                "sz3_fast",
                "constant" if n_const * 2 > nb else "fixed_length",
                scope="block-summary",
                candidates=["constant", "fixed_length"],
                estimates={"constant": float(n_const),
                           "fixed_length": float(nb - n_const)},
                realized_bits=8.0 * len(blob) / max(1, data.size),
                n_elems=int(data.size),
                fallbacks=int(fmeta["nfail"]),
                device="device" if fmeta.get("device") else "host",
            ))
        meta = None
        if with_stats:
            meta = {k: v for k, v in fmeta.items() if not isinstance(v, bytes)}
        return CompressionResult(
            blob=blob, ratio=data.nbytes / max(1, len(blob)), meta=meta
        )

    def _encode_blocks(
        self, pdata: np.ndarray, abs_eb: float
    ) -> Tuple[List[bytes], Dict[str, Any]]:
        bs = self.bs
        pdtype = pdata.dtype
        wd = pdtype.type  # ALL block arithmetic runs in the storage dtype
        flat = np.asarray(pdata).reshape(-1)
        n = int(flat.size)
        if n == 0:
            return [b""], {
                "n": 0, "nb": 0, "n_const": 0, "nfail": 0,
                "const_len": 0, "means_len": 0, "w_len": 0, "planes_len": 0,
            }
        xb, nb = _pad_blocks_1d(flat, bs)
        # the verify threshold keeps a relative margin inside eb: work-dtype
        # rounding in the residual/verify passes can under-report a true
        # error by a few ulps, and 1e-6 >> eps for both float32 and float64 —
        # points inside the margin fail to exact storage instead
        eb_strict = float(abs_eb) * (1.0 - 1e-6)
        dev_stats = self._device_stats(xb)
        if dev_stats is not None:
            means_st = dev_stats[0].astype(pdtype, copy=False)
            dev_hint = dev_stats[1]
        else:
            with np.errstate(invalid="ignore", over="ignore"):
                # f64 accumulator: one read pass either way, and block sums
                # can overflow a float32 accumulator for extreme data
                means_st = xb.mean(axis=1, dtype=np.float64).astype(pdtype)
            dev_hint = None
        # blocks whose mean is non-finite (an inf/nan inside) restart from a
        # masked mean so the REST of the block still codes cheaply; the
        # non-finite points themselves go to the fail channel
        bad = ~np.isfinite(means_st)
        if bad.any():
            xbad = xb[bad].astype(np.float64)
            fin = np.isfinite(xbad)
            cnt = np.maximum(fin.sum(axis=1), 1)
            means_st = means_st.copy()
            means_st[bad] = (
                np.where(fin, xbad, 0.0).sum(axis=1) / cnt
            ).astype(pdtype)
            dev_hint = None  # hint no longer matches the stored means
        resid = xb - means_st[:, None]  # storage dtype, the only big temp
        if dev_hint is not None:
            # device hint classifies; constant blocks are then re-VERIFIED on
            # the host against the stored mean (float32 kernel rounding must
            # never widen the bound)
            const = dev_hint <= eb_strict
            if const.any():
                with np.errstate(invalid="ignore"):
                    exact = np.abs(resid[const]).max(axis=1) <= eb_strict
                idx = np.flatnonzero(const)
                const[idx[~exact]] = False
            gmin = gmax = None  # hint is approximate; probe exactly below
        else:
            with np.errstate(invalid="ignore"):
                # max(resid), -min(resid): the deviation without an |resid|
                # temp; nan devs compare False -> nonconstant
                rmax = resid.max(axis=1)
                rmin = resid.min(axis=1)
                dev = np.maximum(rmax, -rmin)
            const = dev <= eb_strict
            gmin, gmax = rmin.min(), rmax.max()  # nan-propagating
        nonconst = ~const
        n_nc = int(nonconst.sum())
        fail_idx = np.zeros(0, np.int64)
        q = np.zeros((0, bs), np.int32)
        w = np.zeros(0, np.uint8)
        if n_nc:
            twoeb = wd(2.0 * float(abs_eb))
            inv = wd(1.0 / (2.0 * float(abs_eb)))
            np.multiply(resid, inv, out=resid)
            if gmin is None:
                with np.errstate(invalid="ignore"):
                    lo, hi = float(resid.min()), float(resid.max())
            else:
                # the block reductions already scanned resid — scale them
                # instead of two more full passes (probe only; an off-by-ulp
                # vs the elementwise scaling still leaves |q| <= 2^30 + 1,
                # well inside the uint32 packing headroom)
                lo, hi = float(gmin) * float(inv), float(gmax) * float(inv)
            if not (lo >= -float(_Q_CLIP) and hi <= float(_Q_CLIP)):
                # non-finite or beyond the code clip — rare, so the sanitize
                # passes only run when the cheap min/max probe trips (the
                # affected points land in the fail channel via the verify)
                np.nan_to_num(resid, copy=False, nan=0.0, posinf=0.0, neginf=0.0)
                np.clip(resid, -float(_Q_CLIP), float(_Q_CLIP), out=resid)
            np.rint(resid, out=resid)
            all_nc = n_nc == nb
            q = (resid if all_nc else resid[nonconst]).astype(np.int32)
            # verify against the decoder's exact reconstruction — same dtype,
            # same operation order — built in place; whatever lands out of
            # bound is stored raw.  After rint, resid == q in the work dtype
            # (both sides of the int32 round trip are exact), so the all-
            # nonconstant case reuses the resid buffer outright.
            if all_nc:
                err, x_nc, means_nc = resid, xb, means_st
            else:
                err = q.astype(pdtype)
                x_nc, means_nc = xb[nonconst], means_st[nonconst]
            np.multiply(err, twoeb, out=err)
            np.add(means_nc[:, None], err, out=err)
            np.subtract(x_nc, err, out=err)  # err is now the coding error
            np.abs(err, out=err)
            with np.errstate(invalid="ignore"):
                fail_mask = ~(err <= eb_strict)
            if fail_mask.any():
                # fail positions in the ORIGINAL flat index space (row-major
                # nonzero keeps them sorted; padding cropped)
                block_idx = np.flatnonzero(nonconst)
                rows, cols = np.nonzero(fail_mask)
                ff = block_idx[rows] * bs + cols
                fail_idx = ff[ff < n].astype(np.int64)
            w = _required_bits(np.maximum(q.max(axis=1), -q.min(axis=1)))
        const_bytes = np.packbits(const).tobytes()
        means_bytes = means_st.tobytes()
        w_bytes = w.tobytes()
        plane_parts: List[bytes] = []
        for width in np.unique(w):
            width = int(width)
            if width == 0:
                continue  # all-zero residuals: the mean is the payload
            vals = q[w == width].reshape(-1)
            # offset-binary q + 2^w via two's-complement wraparound (the true
            # value is in [0, 2^31], so the low 32 bits ARE the value)
            plane_parts.append(
                _pack_planes(
                    vals.view(np.uint32) + np.uint32(1 << width), width + 1
                )
            )
        planes_bytes = b"".join(plane_parts)
        fmeta: Dict[str, Any] = {
            "n": n,
            "nb": int(nb),
            "n_const": int(const.sum()),
            "nfail": int(fail_idx.size),
            "const_len": len(const_bytes),
            "means_len": len(means_bytes),
            "w_len": len(w_bytes),
            "planes_len": len(planes_bytes),
            "device": 1 if dev_stats is not None else 0,  # routing taken
        }
        if fail_idx.size:
            fmeta["fail_idx"] = fail_idx.tobytes()
            fmeta["fail_vals"] = flat[fail_idx].tobytes()
        return [const_bytes, means_bytes, w_bytes, planes_bytes], fmeta

    # -- decompression (pipeline.decompress dispatch target) ------------------
    @staticmethod
    def _decompress_body(
        blob: bytes, header: Dict[str, Any], body_off: int
    ) -> np.ndarray:
        spec = header["spec"]
        pdtype = np.dtype(header["pdtype"])
        bs = guard_count(spec["bs"], 1 << 20, "fast block size")
        if bs < 1:
            raise ContainerError("corrupt fast container: block size < 1")
        fm = header["fast_meta"]
        # header claims are internally over-determined — recompute the
        # derivable ones and reject any inconsistency before allocating
        n = int(fm["n"])
        if n < 0:
            raise ContainerError("corrupt fast container: negative n")
        guard_alloc(n * pdtype.itemsize, "fast element count")
        nb = int(fm["nb"])
        if nb != (n + bs - 1) // bs:
            raise ContainerError(
                f"corrupt fast container: nb={nb} inconsistent with "
                f"n={n}, bs={bs}"
            )
        conf = CompressionConfig(
            mode=ErrorBoundMode(header["mode"]),
            eb=header["eb"],
            eb_rel=header.get("eb_rel"),
        )
        if n == 0:
            flat = np.zeros(0, pdtype)
        else:
            const_len, means_len = int(fm["const_len"]), int(fm["means_len"])
            w_len = int(fm["w_len"])
            n_const = guard_count(fm["n_const"], nb, "n_const")
            n_nc = nb - n_const
            if const_len != (nb + 7) // 8 or means_len != nb * pdtype.itemsize:
                raise ContainerError(
                    "corrupt fast container: const/means channel lengths "
                    "inconsistent with block count"
                )
            if w_len != n_nc:
                raise ContainerError(
                    "corrupt fast container: width channel length "
                    f"{w_len} != nonconstant block count {n_nc}"
                )
            planes_len = guard_alloc(fm["planes_len"], "planes_len")
            total = const_len + means_len + w_len + planes_len
            body = ll_mod.make(spec["lossless"]).decompress_bounded(
                container_body(blob, body_off), guard_alloc(total, "fast body")
            )
            if len(body) != total:
                raise ContainerError(
                    f"fast body decompressed to {len(body)} bytes; header "
                    f"declares {total}"
                )
            pos = 0
            const = np.unpackbits(
                np.frombuffer(body, np.uint8, count=const_len), count=nb
            ).astype(bool)
            pos += const_len
            means = np.frombuffer(body, pdtype, count=nb, offset=pos)
            pos += means_len
            w = np.frombuffer(body, np.uint8, count=w_len, offset=pos)
            pos += w_len
            abs_eb = float(header["abs_eb"])
            guard_alloc(n_nc * bs * 8, "fast residual grid")
            q = np.zeros((n_nc, bs), np.int64)
            for width in np.unique(w):
                width = int(width)
                sel = w == width
                if width == 0:
                    continue
                cnt = int(sel.sum())
                u, used = _unpack_planes(body, pos, cnt * bs, width + 1)
                pos += used
                q[sel] = u.astype(np.int64).reshape(cnt, bs) - (1 << width)
            # reconstruction runs in the STORAGE dtype with the same
            # operation order the encoder verified against — bit-identical
            # by IEEE determinism, so the encoder-side bound check covers
            # exactly these values
            out = np.empty((nb, bs), pdtype)
            out[:] = means[:, None]
            if n_nc:
                qe = q.astype(pdtype)
                np.multiply(qe, pdtype.type(2.0 * abs_eb), out=qe)
                out[~const] += qe
            flat = out.reshape(-1)[:n]
            if fm.get("nfail"):
                idx = np.frombuffer(fm["fail_idx"], np.int64)
                # explicit bounds check: a negative corrupt index would
                # silently wrap via numpy fancy indexing, an out-of-range one
                # would raise a raw IndexError — both must be ContainerError
                if idx.size and (int(idx.min()) < 0 or int(idx.max()) >= n):
                    raise ContainerError(
                        "corrupt fast container: fail-channel index outside "
                        f"[0, {n})"
                    )
                vals = np.frombuffer(fm["fail_vals"], pdtype)
                if vals.size != idx.size:
                    raise ContainerError(
                        "corrupt fast container: fail-channel index/value "
                        "counts differ"
                    )
                flat[idx] = vals
        dtype = np.dtype(header["dtype"])
        shape = guard_shape(header["shape"], dtype.itemsize, "shape")
        pshape = guard_shape(header["pshape"], pdtype.itemsize, "pshape")
        pdata = flat.reshape(pshape)
        data = pre_mod.make(spec["preprocessor"]).inverse(
            pdata, conf, header["pre_meta"]
        )
        return data.astype(dtype).reshape(shape)


def _pad_blocks_1d(x: np.ndarray, bs: int) -> Tuple[np.ndarray, int]:
    """(nb, bs) view of the flat array (a VIEW when no tail pad is needed —
    callers must not write through it), tail padded with its edge value (the
    pad rides the tail block's own statistics and is cropped on decode)."""
    n = x.size
    nb = (n + bs - 1) // bs
    pad = nb * bs - n
    if pad:
        edge = x[-1] if np.isfinite(x[-1]) else x.dtype.type(0)
        x = np.concatenate([x, np.full(pad, edge, x.dtype)])
    return x.reshape(nb, bs), nb


def sz3_fast(
    bs: int = DEFAULT_BS, lossless: str = "none", device: str = "auto", **kw
) -> FastModeCompressor:
    """Named factory: the SZx-style ultra-fast fixed-length tier (v6)."""
    return FastModeCompressor(
        bs=bs, lossless=ll_mod.make(lossless), device=device, **kw
    )


# registration (fastmode imports pipeline/transform, never vice versa); the
# fast tier also joins the auto contest — sz3_auto / sz3_quality resolve
# AUTO_CANDIDATES at call time, so they pick this up
pl_mod.PIPELINES["sz3_fast"] = sz3_fast
if "sz3_fast" not in tr_mod.AUTO_CANDIDATES:
    tr_mod.AUTO_CANDIDATES = tr_mod.AUTO_CANDIDATES + ("sz3_fast",)
