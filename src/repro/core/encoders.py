"""Encoder module (paper §3.2 "Encoder", Appendix A.4).

Instances:
  * HuffmanEncoder      — canonical Huffman [36] over the quantization codes.
  * FixedHuffmanEncoder — SZ-Pastri's predefined-tree variant [19]: a static
                          two-sided-geometric code model centred on the zero
                          bin eliminates tree construction + storage cost.
  * BitpackEncoder      — fixed-width bit packing (fast path / small alphabets).
  * RawEncoder          — passthrough (module bypass).

Vectorization (TPU-era adaptation, DESIGN.md §3): encode emits one bitstream
with *sync points* every ``SYNC`` symbols (a bit-offset each, ~0.06 bit/sym
overhead).  Decode then advances all sync lanes in lock-step with numpy
gathers — the same interleaved-entropy-coder trick production codecs use —
instead of a pointer-chasing per-symbol loop.  Code lengths are capped at 16
bits (zlib-style frequency scaling) so one 2^16 table drives decode.

Stream formats (the payload bit layout is identical in both):

  v1 — head [n, total_bits, n_sync] int64, sync offsets int64.  Written by
       the pre-word-packed encoder; still decoded (and still writable via
       ``stream_version=1`` for compatibility testing).
  v2 — head [-2, n, total_bits, n_sync] int64, sync offsets uint32 (half the
       sync overhead; total_bits must fit 32 bits, else v1 layout is used).

The encode hot path ORs codes into 64-bit words at cumulative bit offsets
(no n x maxlen bit-matrix intermediate); the decode hot path gathers one
64-bit window per lane and peels several symbols from it before the next
gather.  The pre-PR2 reference implementations are kept as
``*_legacy`` for byte-compat tests and before/after benchmarks.
"""
from __future__ import annotations

import abc
import heapq
import threading
from collections import OrderedDict
from typing import Dict, Optional, Tuple

import numpy as np

_MAXLEN = 16
_SYNC = 1024
_V2_MARK = -2  # first head int64 of a v2 stream (v1 stores n >= 0 there)

#: histogram fast path applies when codes are non-negative and bounded by
#: this (quantization codes live in [0, 2*radius], far below it)
_HIST_MAX = 1 << 22


# ---------------------------------------------------------------------------
# canonical Huffman machinery
# ---------------------------------------------------------------------------

def _huffman_code_lengths(freqs: np.ndarray) -> np.ndarray:
    """Code length per symbol with freq > 0 (classic greedy heap [36])."""
    sym = np.flatnonzero(freqs)
    if sym.size == 0:
        return np.zeros(0, np.uint8), sym
    if sym.size == 1:
        return np.ones(1, np.uint8), sym
    f = freqs[sym].astype(np.int64)
    while True:
        heap = [(int(fi), i, None) for i, fi in enumerate(f)]
        heapq.heapify(heap)
        nodes = {}
        counter = len(heap)
        while len(heap) > 1:
            a = heapq.heappop(heap)
            b = heapq.heappop(heap)
            nodes[counter] = (a[1], b[1])
            heapq.heappush(heap, (a[0] + b[0], counter, None))
            counter += 1
        lengths = np.zeros(counter, np.uint8)
        root = heap[0][1]
        stack = [(root, 0)]
        while stack:
            node, depth = stack.pop()
            if node in nodes:
                l, r = nodes[node]
                stack.append((l, depth + 1))
                stack.append((r, depth + 1))
            else:
                lengths[node] = max(1, depth)
        lens = lengths[: sym.size]
        if lens.max() <= _MAXLEN:
            return lens, sym
        # cap: flatten the distribution and rebuild (zlib heuristic)
        f = (f + 1) // 2


def _canonical_codes(lens_sorted: np.ndarray) -> np.ndarray:
    """Canonical codes for symbols already sorted by (len, symbol)."""
    codes = np.zeros(lens_sorted.size, np.uint32)
    # code_i = (code_{i-1} + 1) << (len_i - len_{i-1}); alphabet is small so a
    # python recurrence is fine (the data-sized paths are all vectorized)
    shifted = np.zeros(lens_sorted.size, np.int64)
    shifted[1:] = (lens_sorted[1:] - lens_sorted[:-1]).astype(np.int64)
    c = 0
    for i in range(lens_sorted.size):
        if i:
            c = (c + 1) << int(shifted[i])
        codes[i] = c
    return codes


class _HuffTable:
    """Built codec state: per-symbol (code, len) + 2^16 decode table."""

    def __init__(self, symbols: np.ndarray, lengths: np.ndarray):
        order = np.lexsort((symbols, lengths))
        self.sym_sorted = symbols[order]
        self.len_sorted = lengths[order].astype(np.uint8)
        self.codes_sorted = _canonical_codes(self.len_sorted)
        # encode-side lookup: dense over max symbol value
        top = int(symbols.max()) + 1 if symbols.size else 1
        self.enc_code = np.zeros(top, np.uint32)
        self.enc_len = np.zeros(top, np.uint8)
        self.enc_code[self.sym_sorted] = self.codes_sorted
        self.enc_len[self.sym_sorted] = self.len_sorted
        # decode-side: canonical codes tile [0, 2^MAXLEN) contiguously
        reps = (1 << (_MAXLEN - self.len_sorted.astype(np.int64)))
        self.dec_sym = np.repeat(self.sym_sorted, reps)
        self.dec_len = np.repeat(self.len_sorted, reps)
        full = 1 << _MAXLEN
        if 0 < self.dec_sym.size < full:
            # incomplete tree only happens for the 1-symbol alphabet; any
            # window then decodes to that symbol, so padding is safe.
            pad = full - self.dec_sym.size
            self.dec_sym = np.concatenate([self.dec_sym, np.full(pad, self.dec_sym[-1])])
            self.dec_len = np.concatenate([self.dec_len, np.full(pad, self.dec_len[-1], np.uint8)])
        self.maxlen = int(self.len_sorted.max()) if self.len_sorted.size else 1
        # (symbol << 8 | length) as uint64: the batched decode pays ONE gather
        # per symbol and splits with register shifts instead of gathering two
        # parallel tables
        self.dec_packed = (self.dec_sym.astype(np.uint64) << np.uint64(8)) | (
            self.dec_len.astype(np.uint64)
        )


#: built tables keyed by code-length signature — the chunked engine emits one
#: Huffman stream per chunk and identical chunks (or identical length
#: profiles, which is all a canonical table depends on) are common, so
#: rebuilding the 2^16 decode table per chunk is pure waste.  A proper LRU
#: (not clear-on-full): the serving layer interleaves fetches across many
#: containers, and one pathological stream of unique signatures must not
#: flush every hot tenant's table at once.  Lock-guarded: the async service
#: decodes on a thread pool.
_TABLE_CACHE: "OrderedDict[bytes, _HuffTable]" = OrderedDict()
_TABLE_CACHE_MAX = 128
_TABLE_LOCK = threading.Lock()
_TABLE_STATS = {"hits": 0, "misses": 0, "evictions": 0}


def _cached_table(lengths: np.ndarray) -> _HuffTable:
    """Canonical table over symbols ``0..k-1`` with the given code lengths.

    Keyed by the length signature (canonical codes are a pure function of
    it), LRU-bounded at ``_TABLE_CACHE_MAX`` entries.
    """
    key = np.asarray(lengths, np.uint8).tobytes()
    with _TABLE_LOCK:
        table = _TABLE_CACHE.get(key)
        if table is not None:
            _TABLE_CACHE.move_to_end(key)
            _TABLE_STATS["hits"] += 1
            return table
        _TABLE_STATS["misses"] += 1
    # build outside the lock (the 2^16 np.repeat is the expensive part);
    # concurrent misses on the same signature build twice, last write wins
    table = _HuffTable(
        np.arange(lengths.size, dtype=np.int64), np.asarray(lengths, np.uint8).copy()
    )
    with _TABLE_LOCK:
        _TABLE_CACHE[key] = table
        _TABLE_CACHE.move_to_end(key)
        while len(_TABLE_CACHE) > _TABLE_CACHE_MAX:
            _TABLE_CACHE.popitem(last=False)
            _TABLE_STATS["evictions"] += 1
    return table


def table_cache_stats() -> Dict[str, int]:
    """Hit/miss/eviction counts plus current size of the decode-table LRU."""
    with _TABLE_LOCK:
        out = dict(_TABLE_STATS)
        out["size"] = len(_TABLE_CACHE)
    return out


def clear_table_cache(reset_stats: bool = True) -> None:
    with _TABLE_LOCK:
        _TABLE_CACHE.clear()
        if reset_stats:
            for k in _TABLE_STATS:
                _TABLE_STATS[k] = 0


def _bits_of_codes(codes: np.ndarray, lens: np.ndarray) -> np.ndarray:
    """MSB-first bits of each code, concatenated (legacy bit-matrix path)."""
    n = codes.size
    if n == 0:
        return np.zeros(0, np.uint8)
    maxlen = int(lens.max())
    # bit matrix (n, maxlen): bit j of code i = (code >> (len-1-j)) & 1
    j = np.arange(maxlen, dtype=np.int64)[None, :]
    shift = lens.astype(np.int64)[:, None] - 1 - j
    valid = shift >= 0
    bits = (codes[:, None].astype(np.uint64) >> np.where(valid, shift, 0).astype(np.uint64)) & 1
    return bits[valid].astype(np.uint8)


def _windows_at(buf: np.ndarray, pos: np.ndarray) -> np.ndarray:
    """16-bit big-endian windows starting at arbitrary bit positions."""
    byte = (pos >> 3).astype(np.int64)
    b0 = buf[byte].astype(np.uint32)
    b1 = buf[byte + 1].astype(np.uint32)
    b2 = buf[byte + 2].astype(np.uint32)
    v = (b0 << 16) | (b1 << 8) | b2
    return (v >> (8 - (pos & 7)).astype(np.uint32)) & np.uint32(0xFFFF)


def _windows64_at(buf: np.ndarray, pos: np.ndarray) -> np.ndarray:
    """64-bit MSB-aligned windows starting at arbitrary bit positions.

    One contiguous 8-byte gather per lane reinterpreted as a big-endian
    uint64 (a byteswap, no shift-accumulate), plus a ninth byte for the
    sub-byte phase.  ``buf`` must be padded with >= 16 zero bytes past the
    last stream byte.
    """
    byte = (pos >> 3).astype(np.int64)
    idx = byte[:, None] + np.arange(8, dtype=np.int64)[None, :]
    v = buf[idx].view(">u8").astype(np.uint64).reshape(-1)
    sh = (pos & 7).astype(np.uint64)
    tail = buf[byte + 8].astype(np.uint64) >> (np.uint64(8) - sh)
    return np.where(sh > 0, (v << sh) | tail, v)


def _pack_codes(
    codes: np.ndarray, lens: np.ndarray, offsets: np.ndarray, total_bits: int
) -> bytes:
    """OR variable-length MSB-first codes into big-endian uint64 words.

    Each code occupies bits [offsets[i], offsets[i]+lens[i]) of the stream
    (bit 0 = MSB of byte 0).  A <=16-bit code spans at most two 64-bit words;
    within a word the bit ranges are disjoint, so per-word accumulation is a
    grouped bitwise-OR (``np.bitwise_or.reduceat`` over runs of equal word
    index — offsets are monotonic, so both the low- and the high-word index
    sequences are sorted and need no sort).  No n x maxlen intermediate.
    """
    nbytes = (total_bits + 7) >> 3
    if codes.size == 0:
        return b""
    nwords = (total_bits + 63) >> 6
    words = np.zeros(nwords + 1, np.uint64)  # +1 absorbs the last spill
    starts = offsets[:-1]
    widx = starts >> 6
    c64 = codes.astype(np.uint64)
    rsh = 64 - (starts & 63) - lens.astype(np.int64)  # in [-15, 63]
    lo = np.where(
        rsh >= 0,
        c64 << np.maximum(rsh, 0).astype(np.uint64),
        c64 >> np.where(rsh < 0, -rsh, 0).astype(np.uint64),
    )
    run = np.flatnonzero(np.r_[True, widx[1:] != widx[:-1]])
    words[widx[run]] = np.bitwise_or.reduceat(lo, run)
    spill = rsh < 0
    if spill.any():
        hi = c64[spill] << (64 + rsh[spill]).astype(np.uint64)
        hidx = widx[spill] + 1
        run = np.flatnonzero(np.r_[True, hidx[1:] != hidx[:-1]])
        words[hidx[run]] |= np.bitwise_or.reduceat(hi, run)
    return words.astype(">u8").tobytes()[:nbytes]


def _encode_stream(syms: np.ndarray, table: _HuffTable, version: int = 2) -> bytes:
    """Word-packed encode; emits the v2 head unless told (or forced) to v1."""
    lens = table.enc_len[syms]
    codes = table.enc_code[syms]
    if syms.size and int(lens.min()) == 0:
        raise ValueError("symbol outside Huffman alphabet")
    offsets = np.zeros(syms.size + 1, np.int64)
    np.cumsum(lens, out=offsets[1:])
    sync = offsets[:-1:_SYNC]
    total_bits = int(offsets[-1])
    payload = _pack_codes(codes, lens, offsets, total_bits)
    if version == 1 or total_bits >= (1 << 32):
        # v1 layout (also the >=4-Gbit fallback: sync must fit uint32 in v2)
        head = np.asarray([syms.size, total_bits, sync.size], np.int64).tobytes()
        return head + sync.astype(np.int64).tobytes() + payload
    head = np.asarray(
        [_V2_MARK, syms.size, total_bits, sync.size], np.int64
    ).tobytes()
    return head + sync.astype(np.uint32).tobytes() + payload


def _encode_stream_legacy(syms: np.ndarray, table: _HuffTable) -> bytes:
    """Pre-PR2 bit-matrix encoder (v1 head).  Kept as the byte-compat oracle
    for the word packer and as the before/after benchmark baseline."""
    lens = table.enc_len[syms]
    codes = table.enc_code[syms]
    if syms.size and int(lens.min()) == 0:
        raise ValueError("symbol outside Huffman alphabet")
    offsets = np.zeros(syms.size + 1, np.int64)
    np.cumsum(lens, out=offsets[1:])
    sync = offsets[:-1:_SYNC].astype(np.int64)
    total_bits = int(offsets[-1])
    chunks = []
    step = 1 << 20
    for s in range(0, syms.size, step):
        chunks.append(_bits_of_codes(codes[s : s + step], lens[s : s + step]))
    bits = np.concatenate(chunks) if chunks else np.zeros(0, np.uint8)
    payload = np.packbits(bits).tobytes()
    head = np.asarray([syms.size, total_bits, sync.size], np.int64).tobytes()
    return head + sync.tobytes() + payload


def _parse_stream_head(
    buf: bytes, offset: int
) -> Tuple[int, int, np.ndarray, int]:
    """Common v1/v2 head parsing: (n, total_bits, sync, payload_pos)."""
    first = int(np.frombuffer(buf, np.int64, count=1, offset=offset)[0])
    if first == _V2_MARK:
        head = np.frombuffer(buf, np.int64, count=4, offset=offset)
        n, total_bits, n_sync = int(head[1]), int(head[2]), int(head[3])
        pos = offset + 32
        sync = np.frombuffer(buf, np.uint32, count=n_sync, offset=pos).astype(np.int64)
        pos += n_sync * 4
    else:
        head = np.frombuffer(buf, np.int64, count=3, offset=offset)
        n, total_bits, n_sync = int(head[0]), int(head[1]), int(head[2])
        pos = offset + 24
        sync = np.frombuffer(buf, np.int64, count=n_sync, offset=pos).copy()
        pos += n_sync * 8
    return n, total_bits, sync, pos


def _decode_stream(buf: bytes, offset: int, table: _HuffTable) -> Tuple[np.ndarray, int]:
    """Batched lane decode (v1 and v2 streams).

    Each outer round gathers ONE 64-bit window per lane and peels up to
    ``K = 48 // maxlen + 1`` symbols from it with in-register shifts (every
    lookup is guaranteed >= 16 valid bits while the bits consumed stay <= 48),
    so the expensive stream gather is amortized over K symbols.  Lanes run
    unconditionally into per-lane padding (clamped to the stream end) and the
    over-decoded tail is dropped by one final mask — no per-symbol boolean
    bookkeeping.
    """
    n, total_bits, sync, pos = _parse_stream_head(buf, offset)
    nbytes = (total_bits + 7) // 8
    stream = np.frombuffer(buf, np.uint8, count=nbytes, offset=pos)
    pos += nbytes
    if n == 0:
        return np.zeros(0, np.int64), pos - offset
    stream = np.concatenate([stream, np.zeros(16, np.uint8)])
    n_lanes = sync.size
    lanes = sync.astype(np.int64)
    # symbol k of lane l lands in out_t[k, l]: every store is a CONTIGUOUS
    # row write (the lane-strided layout would scatter across cache lines),
    # and only the LAST lane is ever partial (sync points are every _SYNC
    # symbols), so the lane-major transpose trimmed to n is the answer — no
    # per-symbol active-mask bookkeeping at all.
    steps = min(_SYNC, n)
    out_t = np.empty((steps, n_lanes), np.int64)
    dec_packed = table.dec_packed
    K = max(1, min(steps, 48 // table.maxlen + 1))
    limit = np.int64(total_bits)
    k = 0
    while k < steps:
        kk = min(K, steps - k)
        w = _windows64_at(stream, lanes)
        consumed = np.zeros(n_lanes, np.uint64)
        for j in range(kk):
            v = dec_packed[(w >> np.uint64(48)).astype(np.int64)]
            out_t[k + j] = v >> np.uint64(8)  # symbol (assignment casts)
            ln = v & np.uint64(0xFF)
            w <<= ln
            consumed += ln
        lanes += consumed.astype(np.int64)
        np.minimum(lanes, limit, out=lanes)  # finished lanes idle at the end
        k += kk
    return out_t.T.reshape(-1)[:n], pos - offset


def _decode_stream_legacy(
    buf: bytes, offset: int, table: _HuffTable
) -> Tuple[np.ndarray, int]:
    """Pre-PR2 one-symbol-per-gather decode (benchmark baseline; v1+v2 heads)."""
    n, total_bits, sync, pos = _parse_stream_head(buf, offset)
    nbytes = (total_bits + 7) // 8
    stream = np.frombuffer(buf, np.uint8, count=nbytes, offset=pos)
    pos += nbytes
    if n == 0:
        return np.zeros(0, np.int64), pos - offset
    stream = np.concatenate([stream, np.zeros(3, np.uint8)])
    out = np.empty(n, np.int64)
    lanes = sync
    n_lanes = lanes.size
    lane_base = np.arange(n_lanes, dtype=np.int64) * _SYNC
    remaining = np.minimum(n - lane_base, _SYNC)
    for k in range(_SYNC):
        active = k < remaining
        if not active.any():
            break
        w = _windows_at(stream, lanes[active])
        syms = table.dec_sym[w]
        out[lane_base[active] + k] = syms
        lanes[active] += table.dec_len[w]
    return out, pos - offset


# ---------------------------------------------------------------------------
# Encoder interface + instances
# ---------------------------------------------------------------------------

class Encoder(abc.ABC):
    """Paper Appendix A.4: encode(bins)->bytes / decode(bytes,len)->bins.

    save()/load() (tree metadata) is folded into the byte stream each encoder
    emits, which keeps the pipeline driver generic."""

    name = "abstract"

    @abc.abstractmethod
    def encode(self, codes: np.ndarray) -> bytes: ...

    @abc.abstractmethod
    def decode(self, buf: bytes, n: int) -> np.ndarray: ...


class RawEncoder(Encoder):
    name = "raw"

    def encode(self, codes):
        arr = np.ascontiguousarray(codes)
        head = np.asarray([arr.itemsize], np.int64).tobytes()
        return head + arr.tobytes()

    def decode(self, buf, n):
        itemsize = int(np.frombuffer(buf, np.int64, count=1)[0])
        dt = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.int64}[itemsize]
        return np.frombuffer(buf, dt, count=n, offset=8).copy()


class BitpackEncoder(Encoder):
    """Fixed-width packing; width = bits needed for the max code present."""

    name = "bitpack"

    def encode(self, codes):
        arr = np.ascontiguousarray(codes).astype(np.uint32).reshape(-1)
        width = max(1, int(arr.max()).bit_length()) if arr.size else 1
        shifts = np.arange(width - 1, -1, -1, dtype=np.uint32)
        bits = ((arr[:, None] >> shifts[None, :]) & 1).astype(np.uint8)
        payload = np.packbits(bits.reshape(-1)).tobytes()
        head = np.asarray([arr.size, width], np.int64).tobytes()
        return head + payload

    def decode(self, buf, n):
        head = np.frombuffer(buf, np.int64, count=2)
        count, width = int(head[0]), int(head[1])
        nbits = count * width
        raw = np.frombuffer(buf, np.uint8, count=(nbits + 7) // 8, offset=16)
        bits = np.unpackbits(raw, count=nbits).reshape(count, width)
        shifts = np.arange(width - 1, -1, -1, dtype=np.uint32)
        return (bits.astype(np.uint32) << shifts[None, :]).sum(axis=1)


def _alphabet_of(arr: np.ndarray) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(distinct values, frequencies, rank indices) of an int array.

    Quantization codes are non-negative and bounded by ``2*radius``, so the
    common case is a bounded ``np.bincount`` histogram + an O(n) rank gather
    instead of the O(n log n) sort ``np.unique`` pays per call.
    """
    lo = int(arr.min()) if arr.size else 0
    hi = int(arr.max()) if arr.size else 0
    if 0 <= lo and hi < _HIST_MAX:
        freqs_full = np.bincount(arr)
        vals = np.flatnonzero(freqs_full)
        rank = np.zeros(hi + 1, np.int64)
        rank[vals] = np.arange(vals.size, dtype=np.int64)
        return vals.astype(np.int64), freqs_full[vals], rank[arr]
    vals, inv = np.unique(arr, return_inverse=True)
    return vals, np.bincount(inv), inv.astype(np.int64)


class HuffmanDecodeHandle:
    """Parsed, reusable decode state for one Huffman blob.

    Holds everything :meth:`HuffmanEncoder.decode` derives from the blob
    prefix — alphabet values, the built canonical table, and the stream
    offset — so a caller that decodes the same blob repeatedly (the serving
    layer's random-access reads) pays the header parse and table build once.
    The handle pins its table, so it stays valid even if the signature is
    evicted from the module LRU.
    """

    __slots__ = ("vals", "table", "stream_pos")

    def __init__(self, vals: np.ndarray, table: _HuffTable, stream_pos: int):
        self.vals = vals
        self.table = table
        self.stream_pos = stream_pos


def huffman_decode_handle(buf: bytes) -> Optional[HuffmanDecodeHandle]:
    """Build a :class:`HuffmanDecodeHandle` for a ``HuffmanEncoder`` blob.

    Returns ``None`` for the empty-stream blob (k == 0), which decodes
    without any table.
    """
    k = int(np.frombuffer(buf, np.int64, count=1)[0])
    if k == 0:
        return None
    pos = 8
    vals = np.frombuffer(buf, np.int64, count=k, offset=pos)
    pos += k * 8
    lens = np.frombuffer(buf, np.uint8, count=k, offset=pos)
    pos += k
    return HuffmanDecodeHandle(vals, _cached_table(lens), pos)


class HuffmanEncoder(Encoder):
    """Canonical Huffman built from the observed code frequencies [36].

    ``stream_version=2`` (default) emits the word-packed v2 stream; ``1``
    emits the pre-PR2 layout (the decoder reads both).
    """

    name = "huffman"

    def __init__(self, stream_version: int = 2):
        self.stream_version = int(stream_version)

    def encode(self, codes):
        arr = np.ascontiguousarray(codes).reshape(-1)
        if arr.dtype.kind not in "iu":
            arr = arr.astype(np.int64)
        if arr.size == 0:
            return np.asarray([0], np.int64).tobytes()
        vals, freqs, inv = _alphabet_of(arr)
        lens, present = _huffman_code_lengths(freqs)
        # alphabet header: K, symbol values (int64), lengths (uint8)
        table = _cached_table(lens)
        stream = _encode_stream(inv, table, self.stream_version)
        head = np.asarray([vals.size], np.int64).tobytes()
        return head + vals.astype(np.int64).tobytes() + lens.tobytes() + stream

    def decode(self, buf, n, handle: Optional[HuffmanDecodeHandle] = None):
        if handle is None:
            handle = huffman_decode_handle(buf)
        if handle is None:  # empty stream (k == 0)
            return np.zeros(0, np.int64)
        idx, _ = _decode_stream(buf, handle.stream_pos, handle.table)
        if idx.size != n:
            raise ValueError(f"huffman stream length mismatch {idx.size} != {n}")
        return handle.vals[idx]


class LegacyHuffmanEncoder(HuffmanEncoder):
    """The pre-PR2 Huffman implementation, end to end: ``np.unique`` alphabet,
    bit-matrix v1 stream, per-symbol lane decode, no table cache.

    Same wire format family (``name`` stays "huffman"; blobs are
    interchangeable with :class:`HuffmanEncoder`).  Exists so tests can mint
    genuine v1 streams and benchmarks can measure the before/after delta on
    identical data.
    """

    def encode(self, codes):
        arr = np.ascontiguousarray(codes).reshape(-1).astype(np.int64)
        if arr.size == 0:
            return np.asarray([0], np.int64).tobytes()
        vals, inv = np.unique(arr, return_inverse=True)
        freqs = np.bincount(inv)
        lens, present = _huffman_code_lengths(freqs)
        table = _HuffTable(np.arange(vals.size, dtype=np.int64), lens)
        stream = _encode_stream_legacy(inv.astype(np.int64), table)
        head = np.asarray([vals.size], np.int64).tobytes()
        return head + vals.tobytes() + lens.tobytes() + stream

    def decode(self, buf, n):
        k = int(np.frombuffer(buf, np.int64, count=1)[0])
        if k == 0:
            return np.zeros(0, np.int64)
        pos = 8
        vals = np.frombuffer(buf, np.int64, count=k, offset=pos)
        pos += k * 8
        lens = np.frombuffer(buf, np.uint8, count=k, offset=pos)
        pos += k
        table = _HuffTable(np.arange(k, dtype=np.int64), lens.copy())
        idx, _ = _decode_stream_legacy(buf, pos, table)
        if idx.size != n:
            raise ValueError(f"huffman stream length mismatch {idx.size} != {n}")
        return vals[idx]


class FixedHuffmanEncoder(Encoder):
    """Predefined tree (SZ-Pastri [19]): no build or storage cost.

    Model: two-sided geometric over the distance from the zero bin (symbol
    ``radius``), with code 0 (unpredictable) and far tails folded into an
    escape class that is followed by a raw 32-bit value.
    """

    name = "fixed_huffman"
    _cache: Dict[Tuple[int, float], "_HuffTable"] = {}

    def __init__(
        self,
        radius: int = 32768,
        decay: float = 0.7,
        span: int = 256,
        stream_version: int = 2,
    ):
        self.radius = radius
        self.decay = decay
        self.span = span  # symbols within [radius-span, radius+span] get codes
        self.stream_version = int(stream_version)

    def _table(self) -> _HuffTable:
        key = (self.radius, self.decay, self.span)
        if key not in FixedHuffmanEncoder._cache:
            # alphabet: 0 (unpred), [radius-span, radius+span], escape symbol
            core = np.arange(self.radius - self.span, self.radius + self.span + 1)
            symbols = np.concatenate([[0], core, [-1]])  # -1 = escape
            dist = np.abs(core - self.radius).astype(np.float64)
            w = np.power(self.decay, np.minimum(dist, 96.0))  # clamp underflow
            freqs = np.concatenate([[w.sum() * 0.01], w, [w.sum() * 0.001]])
            scaled = np.maximum(1, (freqs / freqs.max() * (1 << 30)).astype(np.int64))
            lens, present = _huffman_code_lengths(scaled)
            FixedHuffmanEncoder._cache[key] = (
                _HuffTable(np.arange(symbols.size, dtype=np.int64), lens),
                symbols,
            )
        return FixedHuffmanEncoder._cache[key]

    def encode(self, codes):
        table, symbols = self._table()
        arr = np.ascontiguousarray(codes).reshape(-1).astype(np.int64)
        lo, hi = self.radius - self.span, self.radius + self.span
        in_core = (arr >= lo) & (arr <= hi)
        is_zero = arr == 0
        escape = ~(in_core | is_zero)
        # map to alphabet indices: 0->0, core->1.., escape->last
        idx = np.where(is_zero, 0, np.where(in_core, arr - lo + 1, symbols.size - 1))
        stream = _encode_stream(idx.astype(np.int64), table, self.stream_version)
        esc_vals = arr[escape].astype(np.int64)
        head = np.asarray(
            [self.radius, self.span, int(esc_vals.size)], np.int64
        ).tobytes()
        head += np.asarray([self.decay], np.float64).tobytes()
        return head + esc_vals.tobytes() + stream

    def decode(self, buf, n):
        head = np.frombuffer(buf, np.int64, count=3)
        radius, span, n_esc = int(head[0]), int(head[1]), int(head[2])
        decay = float(np.frombuffer(buf, np.float64, count=1, offset=24)[0])
        pos = 32
        esc_vals = np.frombuffer(buf, np.int64, count=n_esc, offset=pos)
        pos += n_esc * 8
        enc = FixedHuffmanEncoder(radius=radius, span=span, decay=decay)
        table, symbols = enc._table()
        idx, _ = _decode_stream(buf, pos, table)
        if idx.size != n:
            raise ValueError("fixed huffman stream length mismatch")
        lo = radius - span
        out = np.where(idx == 0, 0, idx - 1 + lo)
        esc_mask = idx == symbols.size - 1
        out[esc_mask] = esc_vals
        return out


_REGISTRY = {
    "raw": RawEncoder,
    "bitpack": BitpackEncoder,
    "huffman": HuffmanEncoder,
    "fixed_huffman": FixedHuffmanEncoder,
}


def register(name: str, cls) -> None:
    _REGISTRY[name] = cls


def make(name: str, **kw) -> Encoder:
    return _REGISTRY[name](**kw)
