"""Encoder module (paper §3.2 "Encoder", Appendix A.4).

Instances:
  * HuffmanEncoder      — canonical Huffman [36] over the quantization codes.
  * FixedHuffmanEncoder — SZ-Pastri's predefined-tree variant [19]: a static
                          two-sided-geometric code model centred on the zero
                          bin eliminates tree construction + storage cost.
  * BitpackEncoder      — fixed-width bit packing (fast path / small alphabets).
  * RawEncoder          — passthrough (module bypass).

Vectorization (TPU-era adaptation, DESIGN.md §3): encode emits one bitstream
with *sync points* every ``SYNC`` symbols (a 64-bit bit-offset each, ~0.06
bit/sym overhead).  Decode then advances all sync lanes in lock-step with
numpy gathers — the same interleaved-entropy-coder trick production codecs
use — instead of a pointer-chasing per-symbol loop.  Code lengths are capped
at 16 bits (zlib-style frequency scaling) so one 2^16 table drives decode.
"""
from __future__ import annotations

import abc
import heapq
from typing import Dict, Tuple

import numpy as np

_MAXLEN = 16
_SYNC = 1024


# ---------------------------------------------------------------------------
# canonical Huffman machinery
# ---------------------------------------------------------------------------

def _huffman_code_lengths(freqs: np.ndarray) -> np.ndarray:
    """Code length per symbol with freq > 0 (classic greedy heap [36])."""
    sym = np.flatnonzero(freqs)
    if sym.size == 0:
        return np.zeros(0, np.uint8), sym
    if sym.size == 1:
        return np.ones(1, np.uint8), sym
    f = freqs[sym].astype(np.int64)
    while True:
        heap = [(int(fi), i, None) for i, fi in enumerate(f)]
        heapq.heapify(heap)
        nodes = {}
        counter = len(heap)
        while len(heap) > 1:
            a = heapq.heappop(heap)
            b = heapq.heappop(heap)
            nodes[counter] = (a[1], b[1])
            heapq.heappush(heap, (a[0] + b[0], counter, None))
            counter += 1
        lengths = np.zeros(counter, np.uint8)
        root = heap[0][1]
        stack = [(root, 0)]
        while stack:
            node, depth = stack.pop()
            if node in nodes:
                l, r = nodes[node]
                stack.append((l, depth + 1))
                stack.append((r, depth + 1))
            else:
                lengths[node] = max(1, depth)
        lens = lengths[: sym.size]
        if lens.max() <= _MAXLEN:
            return lens, sym
        # cap: flatten the distribution and rebuild (zlib heuristic)
        f = (f + 1) // 2


def _canonical_codes(lens_sorted: np.ndarray) -> np.ndarray:
    """Canonical codes for symbols already sorted by (len, symbol)."""
    codes = np.zeros(lens_sorted.size, np.uint32)
    # code_i = (code_{i-1} + 1) << (len_i - len_{i-1}); alphabet is small so a
    # python recurrence is fine (the data-sized paths are all vectorized)
    shifted = np.zeros(lens_sorted.size, np.int64)
    shifted[1:] = (lens_sorted[1:] - lens_sorted[:-1]).astype(np.int64)
    c = 0
    for i in range(lens_sorted.size):
        if i:
            c = (c + 1) << int(shifted[i])
        codes[i] = c
    return codes


class _HuffTable:
    """Built codec state: per-symbol (code, len) + 2^16 decode table."""

    def __init__(self, symbols: np.ndarray, lengths: np.ndarray):
        order = np.lexsort((symbols, lengths))
        self.sym_sorted = symbols[order]
        self.len_sorted = lengths[order].astype(np.uint8)
        self.codes_sorted = _canonical_codes(self.len_sorted)
        # encode-side lookup: dense over max symbol value
        top = int(symbols.max()) + 1 if symbols.size else 1
        self.enc_code = np.zeros(top, np.uint32)
        self.enc_len = np.zeros(top, np.uint8)
        self.enc_code[self.sym_sorted] = self.codes_sorted
        self.enc_len[self.sym_sorted] = self.len_sorted
        # decode-side: canonical codes tile [0, 2^MAXLEN) contiguously
        reps = (1 << (_MAXLEN - self.len_sorted.astype(np.int64)))
        self.dec_sym = np.repeat(self.sym_sorted, reps)
        self.dec_len = np.repeat(self.len_sorted, reps)
        full = 1 << _MAXLEN
        if 0 < self.dec_sym.size < full:
            # incomplete tree only happens for the 1-symbol alphabet; any
            # window then decodes to that symbol, so padding is safe.
            pad = full - self.dec_sym.size
            self.dec_sym = np.concatenate([self.dec_sym, np.full(pad, self.dec_sym[-1])])
            self.dec_len = np.concatenate([self.dec_len, np.full(pad, self.dec_len[-1], np.uint8)])


def _bits_of_codes(codes: np.ndarray, lens: np.ndarray) -> np.ndarray:
    """MSB-first bits of each code, concatenated (vectorized)."""
    n = codes.size
    if n == 0:
        return np.zeros(0, np.uint8)
    maxlen = int(lens.max())
    shifts = np.arange(maxlen - 1, -1, -1, dtype=np.uint32)
    # bit matrix (n, maxlen): bit j of code i = (code >> (len-1-j)) & 1
    j = np.arange(maxlen, dtype=np.int64)[None, :]
    shift = lens.astype(np.int64)[:, None] - 1 - j
    valid = shift >= 0
    bits = (codes[:, None].astype(np.uint64) >> np.where(valid, shift, 0).astype(np.uint64)) & 1
    return bits[valid].astype(np.uint8)


def _windows_at(buf: np.ndarray, pos: np.ndarray) -> np.ndarray:
    """16-bit big-endian windows starting at arbitrary bit positions."""
    byte = (pos >> 3).astype(np.int64)
    b0 = buf[byte].astype(np.uint32)
    b1 = buf[byte + 1].astype(np.uint32)
    b2 = buf[byte + 2].astype(np.uint32)
    v = (b0 << 16) | (b1 << 8) | b2
    return (v >> (8 - (pos & 7)).astype(np.uint32)) & np.uint32(0xFFFF)


def _encode_stream(syms: np.ndarray, table: _HuffTable) -> bytes:
    lens = table.enc_len[syms]
    codes = table.enc_code[syms]
    if syms.size and int(lens.min()) == 0:
        raise ValueError("symbol outside Huffman alphabet")
    offsets = np.zeros(syms.size + 1, np.int64)
    np.cumsum(lens, out=offsets[1:])
    sync = offsets[:-1:_SYNC].astype(np.int64)
    total_bits = int(offsets[-1])
    # chunked bit materialization keeps peak memory ~ n x maxlen / nchunks
    chunks = []
    step = 1 << 20
    for s in range(0, syms.size, step):
        chunks.append(_bits_of_codes(codes[s : s + step], lens[s : s + step]))
    bits = np.concatenate(chunks) if chunks else np.zeros(0, np.uint8)
    payload = np.packbits(bits).tobytes()
    head = np.asarray([syms.size, total_bits, sync.size], np.int64).tobytes()
    return head + sync.tobytes() + payload


def _decode_stream(buf: bytes, offset: int, table: _HuffTable) -> Tuple[np.ndarray, int]:
    head = np.frombuffer(buf, np.int64, count=3, offset=offset)
    n, total_bits, n_sync = int(head[0]), int(head[1]), int(head[2])
    pos = offset + 24
    sync = np.frombuffer(buf, np.int64, count=n_sync, offset=pos).copy()
    pos += n_sync * 8
    nbytes = (total_bits + 7) // 8
    stream = np.frombuffer(buf, np.uint8, count=nbytes, offset=pos)
    pos += nbytes
    if n == 0:
        return np.zeros(0, np.int64), pos - offset
    stream = np.concatenate([stream, np.zeros(3, np.uint8)])
    out = np.empty(n, np.int64)
    lanes = sync  # current bit position per lane
    n_lanes = lanes.size
    lane_base = np.arange(n_lanes, dtype=np.int64) * _SYNC
    remaining = np.minimum(n - lane_base, _SYNC)
    for k in range(_SYNC):
        active = k < remaining
        if not active.any():
            break
        w = _windows_at(stream, lanes[active])
        syms = table.dec_sym[w]
        out[lane_base[active] + k] = syms
        lanes[active] += table.dec_len[w]
    return out, pos - offset


# ---------------------------------------------------------------------------
# Encoder interface + instances
# ---------------------------------------------------------------------------

class Encoder(abc.ABC):
    """Paper Appendix A.4: encode(bins)->bytes / decode(bytes,len)->bins.

    save()/load() (tree metadata) is folded into the byte stream each encoder
    emits, which keeps the pipeline driver generic."""

    name = "abstract"

    @abc.abstractmethod
    def encode(self, codes: np.ndarray) -> bytes: ...

    @abc.abstractmethod
    def decode(self, buf: bytes, n: int) -> np.ndarray: ...


class RawEncoder(Encoder):
    name = "raw"

    def encode(self, codes):
        arr = np.ascontiguousarray(codes)
        head = np.asarray([arr.itemsize], np.int64).tobytes()
        return head + arr.tobytes()

    def decode(self, buf, n):
        itemsize = int(np.frombuffer(buf, np.int64, count=1)[0])
        dt = {2: np.uint16, 4: np.uint32, 8: np.int64}[itemsize]
        return np.frombuffer(buf, dt, count=n, offset=8).copy()


class BitpackEncoder(Encoder):
    """Fixed-width packing; width = bits needed for the max code present."""

    name = "bitpack"

    def encode(self, codes):
        arr = np.ascontiguousarray(codes).astype(np.uint32).reshape(-1)
        width = max(1, int(arr.max()).bit_length()) if arr.size else 1
        shifts = np.arange(width - 1, -1, -1, dtype=np.uint32)
        bits = ((arr[:, None] >> shifts[None, :]) & 1).astype(np.uint8)
        payload = np.packbits(bits.reshape(-1)).tobytes()
        head = np.asarray([arr.size, width], np.int64).tobytes()
        return head + payload

    def decode(self, buf, n):
        head = np.frombuffer(buf, np.int64, count=2)
        count, width = int(head[0]), int(head[1])
        nbits = count * width
        raw = np.frombuffer(buf, np.uint8, count=(nbits + 7) // 8, offset=16)
        bits = np.unpackbits(raw, count=nbits).reshape(count, width)
        shifts = np.arange(width - 1, -1, -1, dtype=np.uint32)
        return (bits.astype(np.uint32) << shifts[None, :]).sum(axis=1)


class HuffmanEncoder(Encoder):
    """Canonical Huffman built from the observed code frequencies [36]."""

    name = "huffman"

    def encode(self, codes):
        arr = np.ascontiguousarray(codes).reshape(-1).astype(np.int64)
        if arr.size == 0:
            return np.asarray([0], np.int64).tobytes()
        vals, inv = np.unique(arr, return_inverse=True)
        freqs = np.bincount(inv)
        lens, present = _huffman_code_lengths(freqs)
        # alphabet header: K, symbol values (int64), lengths (uint8)
        symbols = np.arange(vals.size, dtype=np.int64)
        table = _HuffTable(symbols, lens)
        stream = _encode_stream(inv.astype(np.int64), table)
        head = np.asarray([vals.size], np.int64).tobytes()
        return head + vals.tobytes() + lens.tobytes() + stream

    def decode(self, buf, n):
        k = int(np.frombuffer(buf, np.int64, count=1)[0])
        if k == 0:
            return np.zeros(0, np.int64)
        pos = 8
        vals = np.frombuffer(buf, np.int64, count=k, offset=pos)
        pos += k * 8
        lens = np.frombuffer(buf, np.uint8, count=k, offset=pos)
        pos += k
        table = _HuffTable(np.arange(k, dtype=np.int64), lens.copy())
        idx, _ = _decode_stream(buf, pos, table)
        if idx.size != n:
            raise ValueError(f"huffman stream length mismatch {idx.size} != {n}")
        return vals[idx]


class FixedHuffmanEncoder(Encoder):
    """Predefined tree (SZ-Pastri [19]): no build or storage cost.

    Model: two-sided geometric over the distance from the zero bin (symbol
    ``radius``), with code 0 (unpredictable) and far tails folded into an
    escape class that is followed by a raw 32-bit value.
    """

    name = "fixed_huffman"
    _cache: Dict[Tuple[int, float], "_HuffTable"] = {}

    def __init__(self, radius: int = 32768, decay: float = 0.7, span: int = 256):
        self.radius = radius
        self.decay = decay
        self.span = span  # symbols within [radius-span, radius+span] get codes

    def _table(self) -> _HuffTable:
        key = (self.radius, self.decay, self.span)
        if key not in FixedHuffmanEncoder._cache:
            # alphabet: 0 (unpred), [radius-span, radius+span], escape symbol
            core = np.arange(self.radius - self.span, self.radius + self.span + 1)
            symbols = np.concatenate([[0], core, [-1]])  # -1 = escape
            dist = np.abs(core - self.radius).astype(np.float64)
            w = np.power(self.decay, np.minimum(dist, 96.0))  # clamp underflow
            freqs = np.concatenate([[w.sum() * 0.01], w, [w.sum() * 0.001]])
            scaled = np.maximum(1, (freqs / freqs.max() * (1 << 30)).astype(np.int64))
            lens, present = _huffman_code_lengths(scaled)
            FixedHuffmanEncoder._cache[key] = (
                _HuffTable(np.arange(symbols.size, dtype=np.int64), lens),
                symbols,
            )
        return FixedHuffmanEncoder._cache[key]

    def encode(self, codes):
        table, symbols = self._table()
        arr = np.ascontiguousarray(codes).reshape(-1).astype(np.int64)
        lo, hi = self.radius - self.span, self.radius + self.span
        in_core = (arr >= lo) & (arr <= hi)
        is_zero = arr == 0
        escape = ~(in_core | is_zero)
        # map to alphabet indices: 0->0, core->1.., escape->last
        idx = np.where(is_zero, 0, np.where(in_core, arr - lo + 1, symbols.size - 1))
        stream = _encode_stream(idx.astype(np.int64), table)
        esc_vals = arr[escape].astype(np.int64)
        head = np.asarray(
            [self.radius, self.span, int(esc_vals.size)], np.int64
        ).tobytes()
        head += np.asarray([self.decay], np.float64).tobytes()
        return head + esc_vals.tobytes() + stream

    def decode(self, buf, n):
        head = np.frombuffer(buf, np.int64, count=3)
        radius, span, n_esc = int(head[0]), int(head[1]), int(head[2])
        decay = float(np.frombuffer(buf, np.float64, count=1, offset=24)[0])
        pos = 32
        esc_vals = np.frombuffer(buf, np.int64, count=n_esc, offset=pos)
        pos += n_esc * 8
        enc = FixedHuffmanEncoder(radius=radius, span=span, decay=decay)
        table, symbols = enc._table()
        idx, _ = _decode_stream(buf, pos, table)
        if idx.size != n:
            raise ValueError("fixed huffman stream length mismatch")
        lo = radius - span
        out = np.where(idx == 0, 0, idx - 1 + lo)
        esc_mask = idx == symbols.size - 1
        out[esc_mask] = esc_vals
        return out


_REGISTRY = {
    "raw": RawEncoder,
    "bitpack": BitpackEncoder,
    "huffman": HuffmanEncoder,
    "fixed_huffman": FixedHuffmanEncoder,
}


def register(name: str, cls) -> None:
    _REGISTRY[name] = cls


def make(name: str, **kw) -> Encoder:
    return _REGISTRY[name](**kw)
