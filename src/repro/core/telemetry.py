"""Telemetry spine: stage spans, counters, streaming histograms, decisions.

Zero-dependency (stdlib-only), thread-safe observability substrate for every
engine in the repo.  Three layers, cheapest first:

  * **Stage spans** — nestable timed scopes named after the pipeline stages
    (``predict``, ``quantize``, ``huffman``, ``lossless``, ``integrity``,
    ``device_transfer``) recorded into a context-var-scoped :class:`Trace`.
    When no trace is active, :func:`span` returns a module-level no-op
    singleton: the disabled path is one ``ContextVar.get`` plus a comparison,
    so instrumented hot loops pay well under 1% (gated in CI by
    ``benchmarks/check_regression.py``).
  * **Selection-decision records** — every engine that runs a contest
    (per-chunk pipeline selection, per-block predictor tags, constant-vs-
    fixed-length) emits a schema-pinned record of who contested, who won,
    estimated vs realized code-bits, margin, fallback counts and
    device-vs-host routing.  :func:`explain` retrieves them from a live
    :class:`Trace` or reconstructs them from a container blob's header.
  * **Global serving metrics** — always-on monotonic counters and streaming
    histograms (p50/p90/p99 without storing samples) in a process-wide
    registry, exported as a Prometheus text page for the serving layer.

Parallel chunk workers record into the same trace: worker threads start with
an empty ``contextvars`` context, so :func:`propagate` captures the active
trace at submit time and re-binds it inside the worker.  Per-chunk spans
carry an ``order`` attribute and the exporters sort siblings by it, so a
parallel run's trace tree is deterministic and identical to the serial one.
"""
from __future__ import annotations

import contextvars
import json
import logging
import math
import os
import threading
import time
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Union

__all__ = [
    "Span",
    "Trace",
    "StreamingHistogram",
    "trace",
    "current",
    "enabled",
    "span",
    "count",
    "observe",
    "record_decision",
    "suppress_decisions",
    "propagate",
    "make_decision",
    "validate_decision",
    "explain",
    "trace_summary",
    "metric_count",
    "metric_observe",
    "metric_gauge",
    "metric_gauge_add",
    "prometheus_text",
    "reset_metrics",
    "get_logger",
    "STAGES",
]

#: canonical stage-span names (engines may add engine-specific ones, e.g.
#: "chunk"/"select"/"leaf"; exporters treat any name uniformly)
STAGES = (
    "predict", "quantize", "huffman", "lossless", "integrity", "device_transfer",
)

LOG_LEVEL_ENV = "SZ3J_LOG_LEVEL"


# ---------------------------------------------------------------------------
# streaming histogram (p50/p90/p99 without storing samples)
# ---------------------------------------------------------------------------

class StreamingHistogram:
    """Log-bucketed histogram: quantiles without retaining samples.

    Buckets are sub-octaves of powers of two — ``BUCKETS_PER_OCTAVE``
    sub-buckets per factor-of-2, i.e. bucket ``i`` covers
    ``[2**(i/16), 2**((i+1)/16))`` — so any quantile is recovered to within
    a relative error of ``2**(1/16) - 1`` (~4.4%) regardless of the value
    range, and the bucket table stays sparse (a dict keyed by index).
    Non-positive observations land in a dedicated zero bucket.  All methods
    are thread-safe.
    """

    BUCKETS_PER_OCTAVE = 16
    _LOG2_SCALE = BUCKETS_PER_OCTAVE  # index = floor(16 * log2(v))

    def __init__(self):
        self._lock = threading.Lock()
        self._buckets: Dict[int, int] = {}
        self._zero = 0  # observations <= 0
        self.n = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def observe(self, value: float) -> None:
        v = float(value)
        with self._lock:
            self.n += 1
            self.total += v
            if v < self.vmin:
                self.vmin = v
            if v > self.vmax:
                self.vmax = v
            if v <= 0.0:
                self._zero += 1
                return
            idx = math.floor(self._LOG2_SCALE * math.log2(v))
            self._buckets[idx] = self._buckets.get(idx, 0) + 1

    def merge(self, other: "StreamingHistogram") -> None:
        with other._lock:
            buckets = dict(other._buckets)
            zero, n, total = other._zero, other.n, other.total
            vmin, vmax = other.vmin, other.vmax
        with self._lock:
            for idx, c in buckets.items():
                self._buckets[idx] = self._buckets.get(idx, 0) + c
            self._zero += zero
            self.n += n
            self.total += total
            self.vmin = min(self.vmin, vmin)
            self.vmax = max(self.vmax, vmax)

    def quantile(self, q: float) -> float:
        """Approximate ``q``-quantile (0 <= q <= 1); NaN when empty."""
        with self._lock:
            if self.n == 0:
                return math.nan
            rank = q * (self.n - 1)
            seen = self._zero
            if rank < seen:
                return max(0.0, self.vmin)
            for idx in sorted(self._buckets):
                seen += self._buckets[idx]
                if rank < seen:
                    # geometric bucket midpoint, clamped to the observed range
                    mid = 2.0 ** ((idx + 0.5) / self._LOG2_SCALE)
                    return min(max(mid, self.vmin), self.vmax)
            return self.vmax

    def snapshot(self) -> Dict[str, float]:
        empty = self.n == 0
        return {
            "count": self.n,
            "sum": self.total,
            "min": None if empty else self.vmin,
            "max": None if empty else self.vmax,
            "p50": None if empty else self.quantile(0.50),
            "p90": None if empty else self.quantile(0.90),
            "p99": None if empty else self.quantile(0.99),
        }


# ---------------------------------------------------------------------------
# spans and traces
# ---------------------------------------------------------------------------

class Span:
    """One timed scope.  Created via :func:`span`; use as a context manager."""

    __slots__ = ("name", "attrs", "children", "seconds", "_trace", "_t0", "_token")

    def __init__(self, name: str, attrs: Dict[str, Any], trace: "Trace"):
        self.name = name
        self.attrs = attrs
        self.children: List["Span"] = []
        self.seconds: float = 0.0
        self._trace = trace
        self._t0 = 0.0
        self._token = None

    def set(self, **attrs: Any) -> "Span":
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        tr = self._trace
        parent = tr._span_var.get() or tr.root
        with tr._lock:
            parent.children.append(self)
        self._token = tr._span_var.set(self)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.seconds = time.perf_counter() - self._t0
        self._trace._span_var.reset(self._token)
        return False

    def to_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {"name": self.name, "seconds": self.seconds}
        if self.attrs:
            d["attrs"] = dict(self.attrs)
        if self.children:
            d["children"] = [c.to_dict() for c in _ordered(self.children)]
        return d


def _ordered(children: Sequence[Span]) -> List[Span]:
    """Deterministic sibling order: spans carrying an ``order`` attribute
    (parallel chunk workers) sort by it; the rest keep insertion order after
    them.  A serial run and a parallel run therefore export the same tree."""
    return sorted(
        children,
        key=lambda s: (0, s.attrs["order"]) if "order" in s.attrs else (1, 0),
    )


class _NoopSpan:
    """Singleton returned by :func:`span` when no trace is active."""

    __slots__ = ()
    seconds = 0.0

    def set(self, **attrs: Any) -> "_NoopSpan":
        return self

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False


_NOOP_SPAN = _NoopSpan()


class Trace:
    """A tree of stage spans plus counters, histograms and decision records.

    Activate with ``with telemetry.trace() as tr:`` — every :func:`span`,
    :func:`count`, :func:`observe` and :func:`record_decision` inside the
    block (including worker threads entered via :func:`propagate`) lands in
    ``tr``.  Traces may nest; the innermost active trace receives events.
    """

    def __init__(self, name: str = "trace"):
        self.name = name
        self.root = Span("root", {}, self)
        self._lock = threading.Lock()
        # current open span, per thread/context — worker threads start fresh
        # (empty context), so their spans parent onto the root
        self._span_var: contextvars.ContextVar[Optional[Span]] = (
            contextvars.ContextVar(f"sz3j_span_{id(self)}", default=None)
        )
        self.counters: Dict[str, float] = {}
        self.histograms: Dict[str, StreamingHistogram] = {}
        self.decisions: List[Dict[str, Any]] = []
        self.seconds = 0.0
        self._t0 = 0.0

    # -- recording ----------------------------------------------------------
    def span(self, name: str, **attrs: Any) -> Span:
        return Span(name, attrs, self)

    def count(self, name: str, inc: Union[int, float] = 1) -> None:
        with self._lock:
            self.counters[name] = self.counters.get(name, 0) + inc

    def observe(self, name: str, value: float) -> None:
        with self._lock:
            hist = self.histograms.get(name)
            if hist is None:
                hist = self.histograms[name] = StreamingHistogram()
        hist.observe(value)

    def record_decision(self, rec: Dict[str, Any]) -> None:
        with self._lock:
            self.decisions.append(rec)

    # -- export -------------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "seconds": self.seconds,
            "spans": [c.to_dict() for c in _ordered(self.root.children)],
            "counters": dict(sorted(self.counters.items())),
            "histograms": {
                k: self.histograms[k].snapshot() for k in sorted(self.histograms)
            },
            "decisions": list(self.decisions),
        }

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=False)

    def save_json(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json(indent=2))

    def stage_totals(self) -> Dict[str, Dict[str, float]]:
        """Aggregate spans by name across the whole tree: calls, seconds,
        bytes (where spans carry a ``bytes`` attribute) and MB/s."""
        agg: Dict[str, Dict[str, float]] = {}

        def walk(s: Span) -> None:
            for c in s.children:
                row = agg.setdefault(c.name, {"calls": 0, "seconds": 0.0, "bytes": 0})
                row["calls"] += 1
                row["seconds"] += c.seconds
                row["bytes"] += int(c.attrs.get("bytes", 0))
                walk(c)

        walk(self.root)
        for row in agg.values():
            row["MBps"] = (
                row["bytes"] / 1e6 / row["seconds"]
                if row["bytes"] and row["seconds"] > 0
                else 0.0
            )
        return agg

    def summary(self) -> str:
        """Human-readable per-stage table (see :func:`trace_summary`)."""
        agg = self.stage_totals()
        total = self.seconds or sum(r["seconds"] for r in agg.values()) or 1e-12
        lines = [
            f"trace {self.name!r}: {self.seconds * 1e3:.2f} ms, "
            f"{len(self.decisions)} decisions",
            f"{'stage':<16s} {'calls':>6s} {'total ms':>10s} {'share':>7s} {'MB/s':>9s}",
        ]
        for name in sorted(agg, key=lambda n: -agg[n]["seconds"]):
            row = agg[name]
            mbps = f"{row['MBps']:.1f}" if row["MBps"] else "-"
            lines.append(
                f"{name:<16s} {row['calls']:>6d} {row['seconds'] * 1e3:>10.2f} "
                f"{100.0 * row['seconds'] / total:>6.1f}% {mbps:>9s}"
            )
        for cname in sorted(self.counters):
            lines.append(f"counter {cname} = {self.counters[cname]:g}")
        return "\n".join(lines)


_trace_var: contextvars.ContextVar[Optional[Trace]] = contextvars.ContextVar(
    "sz3j_trace", default=None
)
_suppress_var: contextvars.ContextVar[bool] = contextvars.ContextVar(
    "sz3j_suppress_decisions", default=False
)


class _SuppressScope:
    __slots__ = ("_token",)

    def __enter__(self):
        self._token = _suppress_var.set(True)
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        _suppress_var.reset(self._token)
        return False


def suppress_decisions() -> _SuppressScope:
    """Mute :func:`record_decision` inside the scope (spans still record).

    Engines wrap *internal* compressions — selection trial runoffs, the
    quality controller's bisection probes, a chunk winner's nested engine —
    so the decision stream carries exactly one authoritative record per
    contest, emitted in deterministic (chunk) order by the driver, never
    from racing worker threads."""
    return _SuppressScope()


class _TraceScope:
    """Context manager returned by :func:`trace`."""

    __slots__ = ("_trace", "_token")

    def __init__(self, tr: Trace):
        self._trace = tr
        self._token = None

    def __enter__(self) -> Trace:
        self._token = _trace_var.set(self._trace)
        self._trace._t0 = time.perf_counter()
        return self._trace

    def __exit__(self, exc_type, exc, tb) -> bool:
        self._trace.seconds = time.perf_counter() - self._trace._t0
        _trace_var.reset(self._token)
        return False


def trace(name: str = "trace") -> _TraceScope:
    """``with telemetry.trace("compress") as tr:`` — activate a new trace."""
    return _TraceScope(Trace(name))


def current() -> Optional[Trace]:
    """The active trace in this context, or None."""
    return _trace_var.get()


def enabled() -> bool:
    """True when a trace is active (call sites guard non-trivial work on it)."""
    return _trace_var.get() is not None


def span(name: str, **attrs: Any):
    """Open a stage span on the active trace; no-op singleton when disabled."""
    tr = _trace_var.get()
    if tr is None:
        return _NOOP_SPAN
    return Span(name, attrs, tr)


def count(name: str, inc: Union[int, float] = 1) -> None:
    tr = _trace_var.get()
    if tr is not None:
        tr.count(name, inc)


def observe(name: str, value: float) -> None:
    tr = _trace_var.get()
    if tr is not None:
        tr.observe(name, value)


def record_decision(rec: Dict[str, Any]) -> None:
    tr = _trace_var.get()
    if tr is not None and not _suppress_var.get():
        tr.record_decision(rec)


def propagate(fn: Callable) -> Callable:
    """Bind the caller's active trace into worker threads.

    ``contextvars`` do NOT flow into ``ThreadPoolExecutor`` workers (each
    thread starts with an empty context), so a pool would silently drop all
    telemetry.  Wrap the task function with this at submit time; when no
    trace is active the function is returned unchanged (zero overhead)."""
    tr = _trace_var.get()
    if tr is None:
        return fn

    def wrapped(*args, **kw):
        token = _trace_var.set(tr)
        try:
            return fn(*args, **kw)
        finally:
            _trace_var.reset(token)

    return wrapped


def trace_summary(tr: Optional[Trace] = None) -> str:
    """Human table for ``tr`` (default: the active trace)."""
    tr = tr or _trace_var.get()
    if tr is None:
        return "no active trace"
    return tr.summary()


# ---------------------------------------------------------------------------
# selection-decision records (schema-pinned; see tests/test_telemetry.py)
# ---------------------------------------------------------------------------

#: field -> (accepted types, required).  ``None`` is additionally accepted
#: for every non-required field.  The schema is PINNED by a test: adding a
#: field means updating the test, the README and any downstream reader.
DECISION_SCHEMA: Dict[str, tuple] = {
    "engine": ((str,), True),
    "scope": ((str,), True),       # "chunk" | "block-summary" | "array" | "leaf"
    "index": ((int,), True),
    "candidates": ((list, tuple), True),
    "winner": ((str,), True),
    "estimates": ((dict,), False),    # candidate -> stage-1 score (bits/elem
    #                                   or cost s/MB in throughput mode)
    "est_bits": ((int, float), False),  # winner's estimated bits/element
    "realized_bits": ((int, float), False),  # 8*len(blob)/n_elems, measured
    "margin": ((int, float), False),  # runner-up score / winner score (>= 1)
    "n_elems": ((int,), True),
    "fallbacks": ((int,), True),   # fail-channel / unpredictable count
    "device": ((str,), True),      # "host" | "device"
    "extra": ((dict,), False),     # engine-specific payload (e.g. quality rec)
}


def make_decision(
    engine: str,
    winner: str,
    *,
    scope: str = "chunk",
    index: int = 0,
    candidates: Sequence[str] = (),
    estimates: Optional[Dict[str, float]] = None,
    est_bits: Optional[float] = None,
    realized_bits: Optional[float] = None,
    margin: Optional[float] = None,
    n_elems: int = 0,
    fallbacks: int = 0,
    device: str = "host",
    extra: Optional[Dict[str, Any]] = None,
) -> Dict[str, Any]:
    """Build a schema-complete selection-decision record."""
    return {
        "engine": str(engine),
        "scope": str(scope),
        "index": int(index),
        "candidates": [str(c) for c in candidates] or [str(winner)],
        "winner": str(winner),
        "estimates": (
            {str(k): float(v) for k, v in estimates.items()} if estimates else None
        ),
        "est_bits": None if est_bits is None else float(est_bits),
        "realized_bits": None if realized_bits is None else float(realized_bits),
        "margin": None if margin is None else float(margin),
        "n_elems": int(n_elems),
        "fallbacks": int(fallbacks),
        "device": str(device),
        "extra": dict(extra) if extra else None,
    }


def validate_decision(rec: Dict[str, Any]) -> Dict[str, Any]:
    """Raise ``ValueError`` unless ``rec`` matches the pinned schema exactly
    (no missing required fields, no unknown fields, right types)."""
    unknown = set(rec) - set(DECISION_SCHEMA)
    if unknown:
        raise ValueError(f"unknown decision fields: {sorted(unknown)}")
    for field, (types, required) in DECISION_SCHEMA.items():
        if field not in rec or rec[field] is None:
            if required:
                raise ValueError(f"decision missing required field {field!r}")
            continue
        if not isinstance(rec[field], types):
            raise ValueError(
                f"decision field {field!r}: expected {types}, got "
                f"{type(rec[field]).__name__}"
            )
    if rec["winner"] not in rec["candidates"]:
        raise ValueError(
            f"winner {rec['winner']!r} not among candidates {rec['candidates']}"
        )
    return rec


def margin_of(scores: Dict[str, float], winner: str) -> Optional[float]:
    """Runner-up score / winner score (>= 1: how contested the win was)."""
    if winner not in scores or len(scores) < 2:
        return None
    w = scores[winner]
    runner = min(v for k, v in scores.items() if k != winner)
    if not math.isfinite(runner) or not math.isfinite(w):
        return None
    return runner / w if w > 0 else None


def sel_header_entry(
    candidates: Sequence[str],
    scores: Dict[str, float],
    winner: str,
    nfail: int,
    device: str,
) -> Dict[str, Any]:
    """Compact, msgpack-clean form of a decision embedded in a v2/v4 chunk
    table (key ``"sel"``).  Written only when a trace is active at compress
    time, so default-path containers stay byte-identical to the frame-stream
    reassembly (pinned by tests)."""
    entry: Dict[str, Any] = {
        "cands": [str(c) for c in candidates],
        "est": {k: round(float(v), 4) for k, v in scores.items()
                if math.isfinite(float(v))},
        "nfail": int(nfail),
        "dev": str(device),
    }
    m = margin_of(scores, winner)
    if m is not None:
        entry["margin"] = round(m, 4)
    if winner in scores and math.isfinite(float(scores[winner])):
        entry["est_bits"] = round(float(scores[winner]), 4)
    return entry


# ---------------------------------------------------------------------------
# explain(): decision records from a live trace or a container blob
# ---------------------------------------------------------------------------

def explain(obj: Union[Trace, bytes, bytearray, memoryview]) -> List[Dict[str, Any]]:
    """Selection-decision records for a trace or a compressed container.

    * :class:`Trace` — the records captured live (every engine, full detail:
      estimates, margins, realized bits).
    * container bytes — records reconstructed from the header alone, no body
      decode: v2/v4 chunk tables (including embedded ``"sel"`` entries and
      the quality controller's ``"q"`` records), v5 hybrid block-tag counts,
      v6 fast-tier constant/fixed-length stats, and single-pipeline v1/v3
      containers.  Blob-derived records carry whatever the header preserved;
      fields the header never stored come back ``None``.
    """
    if isinstance(obj, Trace):
        return [validate_decision(dict(r)) for r in obj.decisions]
    blob = bytes(obj)
    from . import pipeline as pl_mod  # lazy: telemetry must stay zero-dep

    header, _ = pl_mod.parse_header(blob)
    kind = header.get("kind", header.get("spec", {}).get("kind", "sz3"))
    shape = [int(s) for s in header.get("shape", [])]
    n_total = 1
    for s in shape:
        n_total *= s
    recs: List[Dict[str, Any]] = []
    if "chunks" in header:  # v2 chunked / v4 pwr (incl. quality-controlled)
        if "quality" in header:
            engine = "sz3_quality"
        else:
            # the candidate lists in embedded sel entries (or, failing
            # those, the winners actually used) reveal an auto-style
            # contest; a plain prediction-only container stays sz3_chunked
            used: set = set()
            for c in header["chunks"]:
                used.update((c.get("sel") or {}).get("cands") or ())
                used.add(str(c.get("pipeline", "")))
            engine = chunked_engine_name(kind, used)
        row = 1
        for s in shape[1:]:
            row *= s
        for i, c in enumerate(header["chunks"]):
            sel = c.get("sel") or {}
            q = c.get("q")
            n_elems = int(c.get("n0", 0)) * row
            extra = dict(sel.get("extra") or {})
            if q:
                extra["quality"] = q
            recs.append(make_decision(
                engine,
                c["pipeline"],
                index=i,
                candidates=sel.get("cands") or [c["pipeline"]],
                estimates=sel.get("est") or None,
                est_bits=sel.get("est_bits"),
                realized_bits=8.0 * int(c["len"]) / max(1, n_elems),
                margin=sel.get("margin"),
                n_elems=n_elems,
                fallbacks=int(sel.get("nfail", 0)),
                device=sel.get("dev", "host"),
                extra=extra or None,
            ))
    elif kind == "hybrid":  # v5: per-block tag contest, summarized
        meta = header.get("hyb_meta") or {}
        tag_names = ("zero", "lorenzo1", "lorenzo2", "regression")
        raw = meta.get("counts") or []
        counts = {tag_names[i]: int(c) for i, c in enumerate(raw[:4])}
        winner = max(counts, key=counts.get) if counts else "lorenzo1"
        recs.append(make_decision(
            "sz3_hybrid",
            winner,
            scope="block-summary",
            candidates=list(tag_names),
            estimates={k: float(v) for k, v in counts.items()} or None,
            realized_bits=8.0 * len(blob) / max(1, n_total),
            n_elems=n_total,
            fallbacks=int(meta.get("nfail", 0)),
            extra={"counts": counts, "n_reg": int(meta.get("n_reg", 0)),
                   "nb": int(meta.get("nb", 0))} if counts else None,
        ))
    elif kind == "fast":  # v6: constant vs fixed-length per block
        meta = header.get("fast_meta") or {}
        nb = int(meta.get("nb", 0))
        n_const = int(meta.get("n_const", 0))
        winner = "constant" if n_const * 2 > nb else "fixed_length"
        recs.append(make_decision(
            "sz3_fast",
            winner,
            scope="block-summary",
            candidates=["constant", "fixed_length"],
            estimates={"constant": float(n_const),
                       "fixed_length": float(nb - n_const)},
            realized_bits=8.0 * len(blob) / max(1, n_total),
            n_elems=n_total,
            fallbacks=int(meta.get("nfail", 0)),
            device="device" if meta.get("device") else "host",
        ))
    else:  # single-pipeline v1/v3 container
        meta = header.get("meta") or {}
        spec = header.get("spec") or {}
        name = _engine_name(kind, spec)
        recs.append(make_decision(
            name,
            name,
            scope="array",
            realized_bits=8.0 * len(blob) / max(1, n_total),
            n_elems=n_total,
            fallbacks=int(meta.get("nfail", 0)),
            device="device" if meta.get("device") else "host",
        ))
    return [validate_decision(r) for r in recs]


#: candidate families beyond Algorithm-1 prediction: their presence in a
#: chunked contest is what distinguishes the ``sz3_auto`` configuration
_WIDE_FAMILIES = frozenset(
    {"sz3_transform", "sz3_hybrid", "sz3_fast", "sz3_truncation"}
)


def chunked_engine_name(kind: str, candidates: Iterable[str]) -> str:
    """Engine label for a chunked contest: ``sz3_auto`` when whole-pipeline
    coder families (transform/hybrid/fast) contest alongside the prediction
    pipelines, ``sz3_<kind>`` otherwise.  Deterministic in (kind,
    candidates), so the live record and the blob-side reconstruction (which
    reads the candidate list from the embedded ``sel`` entries) agree."""
    if kind == "chunked" and any(c in _WIDE_FAMILIES for c in candidates):
        return "sz3_auto"
    return f"sz3_{kind}"


def _engine_name(kind: str, spec: Dict[str, Any]) -> str:
    if kind in ("transform", "truncation", "fast", "hybrid"):
        return f"sz3_{kind}"
    pred = spec.get("predictor")
    return {
        "composite": "sz3_lr", "interp": "sz3_interp", "lorenzo": "sz3_lorenzo",
    }.get(pred, f"sz3_{pred or kind}")


# ---------------------------------------------------------------------------
# global serving metrics (always-on; Prometheus text exposition)
# ---------------------------------------------------------------------------

class MetricsRegistry:
    """Process-wide counters and latency histograms for the serving layer."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}
        self._hists: Dict[str, StreamingHistogram] = {}

    def count(self, name: str, inc: Union[int, float] = 1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + inc

    def gauge(self, name: str, value: float) -> None:
        """Set a gauge to an absolute value (last-write-wins)."""
        with self._lock:
            self._gauges[name] = float(value)

    def gauge_add(self, name: str, delta: Union[int, float]) -> float:
        """Adjust a gauge by ``delta`` (e.g. queue depth +1/-1); returns the
        new value so callers can assert monotone invariants in tests."""
        with self._lock:
            val = self._gauges.get(name, 0.0) + delta
            self._gauges[name] = val
            return val

    def gauge_value(self, name: str) -> float:
        with self._lock:
            return self._gauges.get(name, 0.0)

    def observe(self, name: str, value: float) -> None:
        with self._lock:
            hist = self._hists.get(name)
            if hist is None:
                hist = self._hists[name] = StreamingHistogram()
        hist.observe(value)

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            hists = dict(self._hists)
        return {
            "counters": counters,
            "gauges": gauges,
            "histograms": {k: h.snapshot() for k, h in hists.items()},
        }

    def prometheus_text(self) -> str:
        """Prometheus text exposition: counters as ``counter``, histograms as
        ``summary`` (quantile series + ``_sum``/``_count``)."""
        with self._lock:
            counters = sorted(self._counters.items())
            gauges = sorted(self._gauges.items())
            hists = sorted(self._hists.items())
        lines: List[str] = []
        for name, val in counters:
            n = _prom_name(name)
            lines.append(f"# TYPE {n} counter")
            lines.append(f"{n} {val:g}")
        for name, val in gauges:
            n = _prom_name(name)
            lines.append(f"# TYPE {n} gauge")
            lines.append(f"{n} {val:g}")
        for name, hist in hists:
            n = _prom_name(name)
            lines.append(f"# TYPE {n} summary")
            for q in (0.5, 0.9, 0.99):
                v = hist.quantile(q)
                if not math.isnan(v):
                    lines.append(f'{n}{{quantile="{q:g}"}} {v:.9g}')
            lines.append(f"{n}_sum {hist.total:.9g}")
            lines.append(f"{n}_count {hist.n}")
        return "\n".join(lines) + ("\n" if lines else "")


def _prom_name(name: str) -> str:
    out = "".join(c if c.isalnum() or c == "_" else "_" for c in name)
    return out if not out[:1].isdigit() else "_" + out


METRICS = MetricsRegistry()


def metric_count(name: str, inc: Union[int, float] = 1) -> None:
    METRICS.count(name, inc)


def metric_observe(name: str, value: float) -> None:
    METRICS.observe(name, value)


def metric_gauge(name: str, value: float) -> None:
    METRICS.gauge(name, value)


def metric_gauge_add(name: str, delta: Union[int, float]) -> float:
    return METRICS.gauge_add(name, delta)


def prometheus_text() -> str:
    return METRICS.prometheus_text()


def reset_metrics() -> None:
    METRICS.reset()


# ---------------------------------------------------------------------------
# structured logging (repro.telemetry namespace, key=value lines)
# ---------------------------------------------------------------------------

_LOG_ROOT = "repro.telemetry"
_log_lock = threading.Lock()
_log_configured = False


def _fmt_value(v: Any) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    s = str(v)
    return f'"{s}"' if (" " in s or "=" in s) else s


class KVLogger:
    """Thin wrapper emitting structured ``event key=value ...`` lines.

    Each record is a single ``logging`` call, so the stdlib handler lock
    guarantees whole-line atomicity — messages from concurrent offload /
    heartbeat threads can no longer interleave mid-line the way bare
    ``print()`` (two writes: text then newline) did.
    """

    __slots__ = ("_log",)

    def __init__(self, logger: logging.Logger):
        self._log = logger

    def _emit(self, level: int, event: str, fields: Dict[str, Any]) -> None:
        if not self._log.isEnabledFor(level):
            return
        parts = [event] + [f"{k}={_fmt_value(v)}" for k, v in fields.items()]
        self._log.log(level, " ".join(parts))

    def debug(self, event: str, **fields: Any) -> None:
        self._emit(logging.DEBUG, event, fields)

    def info(self, event: str, **fields: Any) -> None:
        self._emit(logging.INFO, event, fields)

    def warning(self, event: str, **fields: Any) -> None:
        self._emit(logging.WARNING, event, fields)

    def error(self, event: str, **fields: Any) -> None:
        self._emit(logging.ERROR, event, fields)


def get_logger(name: str = "") -> KVLogger:
    """A ``repro.telemetry``-namespaced structured logger.

    The namespace root gets one stream handler (idempotent) at the level
    named by ``$SZ3J_LOG_LEVEL`` (default INFO); child loggers propagate to
    it, so the whole subsystem is tuned with a single env var.
    """
    global _log_configured
    with _log_lock:
        if not _log_configured:
            root = logging.getLogger(_LOG_ROOT)
            if not root.handlers:
                handler = logging.StreamHandler()
                handler.setFormatter(logging.Formatter(
                    "%(asctime)s %(levelname)s %(name)s %(message)s"
                ))
                root.addHandler(handler)
            level = os.environ.get(LOG_LEVEL_ENV, "INFO").upper()
            root.setLevel(getattr(logging, level, logging.INFO))
            root.propagate = False
            _log_configured = True
    full = f"{_LOG_ROOT}.{name}" if name else _LOG_ROOT
    return KVLogger(logging.getLogger(full))
