from .ops import bitplane_decode, bitplane_encode, ref_decode, ref_encode

__all__ = ["bitplane_encode", "bitplane_decode", "ref_encode", "ref_decode"]
