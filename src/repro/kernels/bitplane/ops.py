"""Jit'd wrappers for the bitplane transpose kernel (padding + flat API)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import kernel as _k
from . import ref as _ref


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitplane_encode(vals: jnp.ndarray, *, interpret: bool = True) -> jnp.ndarray:
    """Flat uint32 values -> (32, ceil(n/32)) plane words (plane p = row p)."""
    n = vals.shape[0]
    # empty input still pads to one tile: the kernel grid needs >= 1 step
    # (decode crops back to n values, so the zero words are never observed)
    pad = (-n) % (32 * 512) or (32 * 512 if n == 0 else 0)
    v = jnp.pad(vals.astype(jnp.uint32), (0, pad)).reshape(-1, 32)
    return _k.encode(v, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("n", "interpret"))
def bitplane_decode(words: jnp.ndarray, n: int, *, interpret: bool = True) -> jnp.ndarray:
    v = _k.decode(words, interpret=interpret).reshape(-1)
    return v[:n]


def ref_encode(vals):
    n = vals.shape[0]
    pad = (-n) % 32
    v = jnp.pad(jnp.asarray(vals, jnp.uint32), (0, pad)).reshape(-1, 32)
    return _ref.encode(v)


def ref_decode(words, n):
    return _ref.decode(jnp.asarray(words, jnp.uint32)).reshape(-1)[:n]
