"""Pallas TPU kernel: 32x32 bitplane transpose (unpred-aware quantizer core).

The paper's §4.2 embedded encoding stores unpredictable integers plane-by-
plane so significant planes become zero-runs for the lossless stage.  On TPU
this is a pure lane-shuffle-free integer op: each (128, 32) VMEM tile of
values produces a (32, 128) tile of plane words via shift/mask/reduce on the
VPU — no gather, no scalar loop (contrast with the byte-oriented CPU
implementation in SZ3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import tpu_compiler_params


def _encode_kernel(v_ref, w_ref):
    v = v_ref[...]  # (bt, 32) uint32
    p = jnp.arange(32, dtype=jnp.uint32)[:, None, None]
    k = jnp.arange(32, dtype=jnp.uint32)[None, None, :]
    bits = (v[None, :, :] >> p) & jnp.uint32(1)
    w_ref[...] = (bits << k).sum(axis=2, dtype=jnp.uint32)  # (32, bt)


def _decode_kernel(w_ref, v_ref):
    w = w_ref[...]  # (32, bt) uint32
    k = jnp.arange(32, dtype=jnp.uint32)[None, None, :]
    p = jnp.arange(32, dtype=jnp.uint32)[:, None, None]
    bits = (w[:, :, None] >> k) & jnp.uint32(1)
    v_ref[...] = (bits << p).sum(axis=0, dtype=jnp.uint32)  # (bt, 32)


def encode(v, *, bt=512, interpret=True):
    R = v.shape[0]
    return pl.pallas_call(
        _encode_kernel,
        out_shape=jax.ShapeDtypeStruct((32, R), jnp.uint32),
        grid=(R // bt,),
        in_specs=[pl.BlockSpec((bt, 32), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((32, bt), lambda i: (0, i)),
        compiler_params=tpu_compiler_params(("parallel",)),
        interpret=interpret,
    )(v)


def decode(w, *, bt=512, interpret=True):
    R = w.shape[1]
    return pl.pallas_call(
        _decode_kernel,
        out_shape=jax.ShapeDtypeStruct((R, 32), jnp.uint32),
        grid=(R // bt,),
        in_specs=[pl.BlockSpec((32, bt), lambda i: (0, i))],
        out_specs=pl.BlockSpec((bt, 32), lambda i: (i, 0)),
        compiler_params=tpu_compiler_params(("parallel",)),
        interpret=interpret,
    )(w)
