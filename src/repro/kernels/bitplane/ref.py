"""Pure-jnp oracle for the bitplane transpose kernel.

Layout contract: values are viewed as (R, 32) uint32 where R = n/32 (the k
axis indexes 32 consecutive values); the transpose emits words
``out[p, r] = sum_k ((v[r, k] >> p) & 1) << k`` — i.e. plane p's bits for the
r-th group of 32 values, packed little-endian into one uint32 word.  Planes
are emitted MSB-first by the caller slicing ``out[::-1]`` when serializing
(the unpred-aware quantizer's order).
"""
from __future__ import annotations

import jax.numpy as jnp


def encode(v: jnp.ndarray) -> jnp.ndarray:
    """v: (R, 32) uint32 -> (32, R) uint32 plane words."""
    assert v.ndim == 2 and v.shape[1] == 32
    p = jnp.arange(32, dtype=jnp.uint32)[:, None, None]
    k = jnp.arange(32, dtype=jnp.uint32)[None, None, :]
    bits = (v[None, :, :] >> p) & jnp.uint32(1)
    return (bits << k).sum(axis=2, dtype=jnp.uint32)


def decode(w: jnp.ndarray) -> jnp.ndarray:
    """w: (32, R) uint32 plane words -> (R, 32) uint32 values."""
    assert w.ndim == 2 and w.shape[0] == 32
    k = jnp.arange(32, dtype=jnp.uint32)[None, None, :]
    p = jnp.arange(32, dtype=jnp.uint32)[:, None, None]
    bits = (w[:, :, None] >> k) & jnp.uint32(1)
    return (bits << p).sum(axis=0, dtype=jnp.uint32)
