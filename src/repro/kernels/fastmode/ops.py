"""Jit'd public wrappers around the fast-tier classify+reduce kernel.

Handles row padding to tile multiples (pad rows are all-zero blocks whose
stats are cropped before the coder sees them), backend selection
(interpret=True on CPU, compiled on TPU), and the host-array boundary for
core/fastmode.py's device path.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import kernel as _k
from . import ref as _ref


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def device_default() -> bool:
    """Route the fast coder's stats stage through Pallas by default?

    True on real TPUs only — interpret-mode Pallas on CPU is far slower than
    the numpy host path (same policy as kernels/lorenzo and transform)."""
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def _stats_padded(x: jnp.ndarray, *, bm: int, interpret: bool):
    means, devs = _k.block_stats(x, bm=bm, interpret=interpret)
    return means[:, 0], devs[:, 0]


def block_stats(
    x: np.ndarray, *, interpret: bool = None
) -> Tuple[np.ndarray, np.ndarray]:
    """Per-block (mean, max |x - mean|) for a host (nb, bs) float32 array."""
    interpret = _interpret_default() if interpret is None else interpret
    x = np.asarray(x, np.float32)
    nb = x.shape[0]
    bm = 256 if nb >= 256 else 8
    pad = (-nb) % bm
    xj = jnp.asarray(np.pad(x, ((0, pad), (0, 0))) if pad else x)
    means, devs = _stats_padded(xj, bm=bm, interpret=interpret)
    return np.asarray(means)[:nb], np.asarray(devs)[:nb]


def ref_block_stats(x) -> Tuple[np.ndarray, np.ndarray]:
    means, devs = _ref.block_stats(jnp.asarray(x, jnp.float32))
    return np.asarray(means), np.asarray(devs)
