"""Pallas TPU kernel: fused per-block mean + max-deviation (classify+reduce).

The fast tier's only device-worthy stage: one VMEM pass per (bm, bs) tile
computes, for each of the tile's bm blocks, the block mean AND the maximum
absolute deviation from that mean — the constant-block classification signal
— without re-reading the block (the host path reads the array twice).  bs is
the coder's fixed block length (128/256), already a whole lane multiple, so
a tile holds bm independent blocks and both reductions run along the lane
axis; no cross-tile dependency, the grid is embarrassingly parallel.

Outputs are (nb, 128) lane-broadcast columns (TPU tiles want 128-lane last
dims); the ops.py wrapper takes column 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..compat import tpu_compiler_params

_PAR = tpu_compiler_params(("parallel",))


def _kernel(x_ref, mean_ref, dev_ref, *, bs):
    t = x_ref[...].astype(jnp.float32)  # (bm, bs)
    mean = jnp.sum(t, axis=1, keepdims=True) / float(bs)  # (bm, 1)
    dev = jnp.max(jnp.abs(t - mean), axis=1, keepdims=True)
    mean_ref[...] = jnp.broadcast_to(mean, mean_ref.shape)
    dev_ref[...] = jnp.broadcast_to(dev, dev_ref.shape)


def block_stats(x, *, bm=8, interpret=True):
    """(nb, bs) float32, nb % bm == 0 -> (means, devs), each (nb, 128)."""
    nb, bs = x.shape
    kern = functools.partial(_kernel, bs=bs)
    out = jax.ShapeDtypeStruct((nb, 128), jnp.float32)
    return pl.pallas_call(
        kern,
        out_shape=(out, out),
        grid=(nb // bm,),
        in_specs=[pl.BlockSpec((bm, bs), lambda i: (i, 0))],
        out_specs=(
            pl.BlockSpec((bm, 128), lambda i: (i, 0)),
            pl.BlockSpec((bm, 128), lambda i: (i, 0)),
        ),
        compiler_params=_PAR,
        interpret=interpret,
    )(x)
