"""Reference oracle for the fast-tier classify+reduce stage.

Pure jax.numpy, no Pallas: per 256/128-element block, the mean and the
maximum absolute deviation FROM THAT MEAN — the two reductions the SZx-style
coder classifies constant blocks with.  The kernel in ``kernel.py`` must
match this to float32 rounding; the host numpy path in core/fastmode.py is
the float64 ground truth both approximate (and which re-verifies every
constant classification, so oracle drift can cost ratio but never the bound).
"""
from __future__ import annotations

import jax.numpy as jnp


def block_stats(x: jnp.ndarray):
    """(nb, bs) float32 -> (means (nb,), max |x - mean| (nb,)) in float32."""
    x = jnp.asarray(x, jnp.float32)
    means = jnp.mean(x, axis=1)
    dev = jnp.max(jnp.abs(x - means[:, None]), axis=1)
    return means, dev
