"""Fused classify+reduce kernel for the SZx-style fast tier (core/fastmode).

``kernel.py`` holds the Pallas TPU kernel, ``ref.py`` the pure-jnp oracle the
kernel is verified against, ``ops.py`` the jit'd host-array wrappers with
padding and backend selection (same layout as kernels/lorenzo and
kernels/transform).
"""
