"""Pure-jnp oracle for the blockwise decorrelating-transform kernel.

Layout contract: the array is tiled into 4-point blocks along the transformed
axes (last axis for "1d", last two for "2d"; shapes must be pre-padded to
multiples of 4).  Each block is rotated by the orthonormal 4-point DCT-II
basis ``MAT`` — forward ``c = M b`` per axis, inverse ``b = M^T c`` — so the
coefficient grid has the same shape as the input and every 4-block is
independent (crop-safe: tile padding only ever adds whole blocks).

The basis master copy lives in ``core/transform.py`` (pure numpy, so the
host path imports without jax); this module re-exports it so the kernel,
the oracle, and the host coder provably share one basis — the error-bound
analysis (the L_inf amplification of ``M^T``) transfers only then.
"""
from __future__ import annotations

import jax.numpy as jnp

from ...core.transform import AMP_1AXIS, MAT  # noqa: F401  (shared basis)


def _blocked(x: jnp.ndarray, axis: int) -> jnp.ndarray:
    """(..., 4n, ...) -> (..., n, 4, ...) with the 4-axis appended last."""
    return jnp.moveaxis(
        x.reshape(x.shape[:axis] + (x.shape[axis] // 4, 4) + x.shape[axis + 1 :]),
        axis + 1,
        -1,
    )


def _unblocked(b: jnp.ndarray, axis: int, shape) -> jnp.ndarray:
    return jnp.moveaxis(b, -1, axis + 1).reshape(shape)


def _apply(x: jnp.ndarray, m: jnp.ndarray, axes) -> jnp.ndarray:
    out = x
    for ax in axes:
        b = _blocked(out, ax)
        out = _unblocked(b @ m.T.astype(out.dtype), ax, out.shape)
    return out


def fwd(x: jnp.ndarray, mode: str = "2d") -> jnp.ndarray:
    """x: (R, C) with transformed axes multiples of 4 -> coefficients.

    Last axis first, matching the kernel's rotation order bit-for-bit in
    float32 (separable rotations commute exactly only in exact arithmetic).
    """
    assert x.ndim == 2
    axes = (1,) if mode == "1d" else (1, 0)
    return _apply(x, jnp.asarray(MAT, x.dtype), axes)


def inv(c: jnp.ndarray, mode: str = "2d") -> jnp.ndarray:
    """Inverse rotation (transpose of the orthonormal basis)."""
    assert c.ndim == 2
    axes = (1,) if mode == "1d" else (1, 0)
    return _apply(c, jnp.asarray(MAT.T, c.dtype), axes)
