"""Pallas TPU kernel: blockwise 4-point decorrelating transform (fwd/inv).

The transform stage of the ZFP-style coder (core/transform.py §device path):
each (4, 4) block of a 2-D field is rotated by the orthonormal DCT-II basis,
``c = M b M^T`` (or along the last axis only in "1d" mode).  On TPU this is a
pure VPU problem: a (bm, bn) VMEM tile holds bm/4 x bn/4 independent blocks,
and the per-axis rotation is four shifted multiply-accumulates over the lane
dimension — no MXU, no gathers, no cross-tile dependency (contrast with the
Lorenzo kernels' carry ring: blocks never straddle tiles because bm, bn are
multiples of 4).

Grid conventions: grid (R/bm, C/bn), both dimensions parallel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..compat import tpu_compiler_params
from .ref import MAT

_PAR = tpu_compiler_params(("parallel", "parallel"))


def _rotate_last(t: jnp.ndarray, m) -> jnp.ndarray:
    """Apply the 4-point basis along the last axis of a (bm, bn) tile.

    The tile is viewed as (bm, bn/4, 4); out[..., k] = sum_j m[k, j] t[..., j]
    is unrolled into 4 lane-aligned scaled adds (static 4x4 coefficients).
    """
    bm, bn = t.shape
    b = t.reshape(bm, bn // 4, 4)
    out = [
        sum(float(m[k][j]) * b[:, :, j] for j in range(4)) for k in range(4)
    ]
    return jnp.stack(out, axis=-1).reshape(bm, bn)


def _rotate_rows(t: jnp.ndarray, m) -> jnp.ndarray:
    """Apply the basis along the first (sublane) axis of a (bm, bn) tile."""
    bm, bn = t.shape
    b = t.reshape(bm // 4, 4, bn)
    out = [
        sum(float(m[k][j]) * b[:, j, :] for j in range(4)) for k in range(4)
    ]
    return jnp.stack(out, axis=1).reshape(bm, bn)


def _kernel(x_ref, o_ref, *, m, mode):
    t = x_ref[...].astype(jnp.float32)
    t = _rotate_last(t, m)
    if mode == "2d":
        t = _rotate_rows(t, m)
    o_ref[...] = t


def _call(x, *, m, mode, bm, bn, interpret):
    R, C = x.shape
    kern = functools.partial(_kernel, m=m, mode=mode)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((R, C), jnp.float32),
        grid=(R // bm, C // bn),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        compiler_params=_PAR,
        interpret=interpret,
    )(x)


_M_FWD = tuple(tuple(row) for row in MAT.tolist())
_M_INV = tuple(tuple(row) for row in MAT.T.tolist())


def fwd(x, *, mode="2d", bm=8, bn=128, interpret=True):
    """(R, C) float32, R % bm == 0 and C % bn == 0 -> coefficient grid."""
    return _call(x, m=_M_FWD, mode=mode, bm=bm, bn=bn, interpret=interpret)


def inv(c, *, mode="2d", bm=8, bn=128, interpret=True):
    return _call(c, m=_M_INV, mode=mode, bm=bm, bn=bn, interpret=interpret)
