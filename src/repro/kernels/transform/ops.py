"""Jit'd public wrappers around the blockwise-transform kernels.

Handles padding to tile multiples (zero padding is crop-safe: tiles and
4-blocks nest, so padding only appends whole independent blocks), backend
selection (interpret=True on CPU, compiled on TPU), and the host array
boundary for the transform coder (core/transform.py device path).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import kernel as _k
from . import ref as _ref

AMP_1AXIS = _ref.AMP_1AXIS


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def device_default() -> bool:
    """Route the transform coder through the Pallas kernels by default?

    True on real TPUs only — interpret-mode Pallas on CPU is far slower than
    the numpy host path (same policy as kernels/lorenzo)."""
    return jax.default_backend() == "tpu"


def _pad2d(x: jnp.ndarray, bm: int, bn: int) -> Tuple[jnp.ndarray, Tuple[int, int]]:
    R, C = x.shape
    pr, pc = (-R) % bm, (-C) % bn
    if pr or pc:
        x = jnp.pad(x, ((0, pr), (0, pc)))
    return x, (R, C)


def _tiles(shape: Tuple[int, int]) -> Tuple[int, int]:
    bm = 256 if shape[0] >= 256 else max(8, 8 * (shape[0] // 8) or 8)
    bn = 512 if shape[1] >= 512 else 128
    return bm, bn


@functools.partial(jax.jit, static_argnames=("mode", "interpret"))
def transform_fwd(x: jnp.ndarray, *, mode: str = "2d", interpret: bool = True) -> jnp.ndarray:
    """(R, C) float32, transformed axes multiples of 4 -> coefficient grid."""
    assert x.ndim == 2
    bm, bn = _tiles(x.shape)
    xp, (R, C) = _pad2d(x, bm, bn)
    return _k.fwd(xp, mode=mode, bm=bm, bn=bn, interpret=interpret)[:R, :C]


@functools.partial(jax.jit, static_argnames=("mode", "interpret"))
def transform_inv(c: jnp.ndarray, *, mode: str = "2d", interpret: bool = True) -> jnp.ndarray:
    assert c.ndim == 2
    bm, bn = _tiles(c.shape)
    cp, (R, C) = _pad2d(c, bm, bn)
    return _k.inv(cp, mode=mode, bm=bm, bn=bn, interpret=interpret)[:R, :C]


def fwd_pipeline(x: np.ndarray, *, interpret: bool = None) -> np.ndarray:
    """Forward transform for the REAL coder (host arrays, 1-D or 2-D).

    Shapes must already be padded to multiples of 4 along the transformed
    axes (core/transform.py owns the edge padding policy — zero padding here
    would leak into real blocks' coefficients, tile padding cannot)."""
    interpret = _interpret_default() if interpret is None else interpret
    x2d = jnp.asarray(x if x.ndim == 2 else x.reshape(1, -1), jnp.float32)
    mode = "2d" if x.ndim == 2 else "1d"
    out = transform_fwd(x2d, mode=mode, interpret=interpret)
    return np.asarray(out).reshape(x.shape)


def inv_pipeline(c: np.ndarray, *, interpret: bool = None) -> np.ndarray:
    """Inverse transform for the REAL coder (host arrays, 1-D or 2-D)."""
    interpret = _interpret_default() if interpret is None else interpret
    c2d = jnp.asarray(c if c.ndim == 2 else c.reshape(1, -1), jnp.float32)
    mode = "2d" if c.ndim == 2 else "1d"
    out = transform_inv(c2d, mode=mode, interpret=interpret)
    return np.asarray(out).reshape(c.shape)


def ref_fwd(x, mode="2d"):
    return _ref.fwd(jnp.asarray(x, jnp.float32), mode=mode)


def ref_inv(c, mode="2d"):
    return _ref.inv(jnp.asarray(c, jnp.float32), mode=mode)
