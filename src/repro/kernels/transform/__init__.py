from .ops import (
    AMP_1AXIS,
    device_default,
    fwd_pipeline,
    inv_pipeline,
    ref_fwd,
    ref_inv,
    transform_fwd,
    transform_inv,
)

__all__ = [
    "AMP_1AXIS",
    "device_default",
    "fwd_pipeline",
    "inv_pipeline",
    "ref_fwd",
    "ref_inv",
    "transform_fwd",
    "transform_inv",
]
