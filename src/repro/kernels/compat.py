"""Version compatibility for the Pallas TPU API surface.

The Mosaic compiler-params class was renamed across JAX releases
(``pltpu.TPUCompilerParams`` -> ``pltpu.CompilerParams``).  Kernels go through
this shim so they compile against either name; when neither exists (very old
or stripped-down JAX builds) ``tpu_compiler_params`` returns ``None``, which
``pallas_call`` accepts as "no TPU-specific options" — fine for the
interpret-mode CPU path used in tests.
"""
from __future__ import annotations

from typing import Optional, Sequence

try:
    from jax.experimental.pallas import tpu as _pltpu

    _PARAMS_CLS = getattr(
        _pltpu, "CompilerParams", getattr(_pltpu, "TPUCompilerParams", None)
    )
    HAS_PALLAS_TPU = True
except Exception:  # pragma: no cover - pallas missing entirely
    _pltpu = None
    _PARAMS_CLS = None
    HAS_PALLAS_TPU = False

HAS_COMPILER_PARAMS = _PARAMS_CLS is not None


def tpu_compiler_params(dimension_semantics: Sequence[str]) -> Optional[object]:
    """Build TPU compiler params naming grid-dimension semantics, if supported."""
    if _PARAMS_CLS is None:
        return None
    return _PARAMS_CLS(dimension_semantics=tuple(dimension_semantics))


def pallas_unavailable_reason() -> Optional[str]:
    """Human-readable reason the Pallas TPU kernels cannot be used, or None."""
    if not HAS_PALLAS_TPU:
        return "jax.experimental.pallas.tpu is not importable in this JAX build"
    if not HAS_COMPILER_PARAMS:
        return (
            "installed JAX lacks pltpu.CompilerParams/TPUCompilerParams; "
            "Pallas kernels are version-gated off"
        )
    return None
