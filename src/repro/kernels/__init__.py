# Pallas TPU kernels for the compression hot-spots the paper optimizes
# (predict+quantize, bitplane encode) plus the serving-path KV quantization.
# Each package: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit wrapper),
# ref.py (pure-jnp oracle).  Validated in interpret mode on CPU; compiled on
# TPU (ops.py selects by backend).
from . import bitplane, kvquant, lorenzo, transform  # noqa: F401
