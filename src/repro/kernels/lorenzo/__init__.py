from .ops import (
    lorenzo_decode,
    lorenzo_encode,
    lorenzo_roundtrip_check,
    ref_decode,
    ref_encode,
)

__all__ = [
    "lorenzo_encode",
    "lorenzo_decode",
    "lorenzo_roundtrip_check",
    "ref_encode",
    "ref_decode",
]
