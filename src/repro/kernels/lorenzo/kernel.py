"""Pallas TPU kernels: fused prequantize + Lorenzo filter (encode/decode).

TPU adaptation of SZ's predict+quantize hot loop (DESIGN.md §3):
  * dual-quantization (cuSZ) removes the sequential decompressed-value
    feedback, so the filter is a pure integer stencil on VPU lanes;
  * tiles are (block_rows, lane-multiple) VMEM blocks; the cross-tile
    dependency (last row / last column of the previous tile) is carried in a
    VMEM scratch ring across the sequential grid dimension — no halo re-reads
    and no extra HBM traffic;
  * encode fuses prequant -> stencil -> code clipping in one pass; decode
    fuses cumulative-sum reconstruction -> dequant.

Grid conventions (TPU executes the last grid axis sequentially):
  encode_1d / decode_1d : grid (R/bm, C/bn); carry is the (bm, 1) last column.
  encode_2d / decode_2d : grid (R/bm,); blocks span full (padded) row width;
                          carry is the (1, C) last row.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import tpu_compiler_params


# ---------------------------------------------------------------------------
# encode
# ---------------------------------------------------------------------------

def _encode1d_kernel(x_ref, codes_ref, draw_ref, carry_ref, *, inv_two_eb, radius):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    x = x_ref[...].astype(jnp.float32)
    q = jnp.rint(x * inv_two_eb).astype(jnp.int32)
    left = jnp.concatenate([carry_ref[...], q[:, :-1]], axis=1)
    carry_ref[...] = q[:, -1:]
    d = q - left
    codes_ref[...] = jnp.where(jnp.abs(d) < radius, d + radius, 0).astype(jnp.int32)
    draw_ref[...] = d


def _encode2d_kernel(x_ref, codes_ref, draw_ref, carry_ref, *, inv_two_eb, radius):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    x = x_ref[...].astype(jnp.float32)
    q = jnp.rint(x * inv_two_eb).astype(jnp.int32)
    up = jnp.concatenate([carry_ref[...], q[:-1, :]], axis=0)
    carry_ref[...] = q[-1:, :]
    dr = q - up
    left = jnp.pad(dr[:, :-1], ((0, 0), (1, 0)))
    d = dr - left
    codes_ref[...] = jnp.where(jnp.abs(d) < radius, d + radius, 0).astype(jnp.int32)
    draw_ref[...] = d


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def _decode1d_kernel(d_ref, out_ref, carry_ref, *, two_eb):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    d = d_ref[...]
    q = jnp.cumsum(d, axis=1, dtype=jnp.int32) + carry_ref[...]
    carry_ref[...] = q[:, -1:]
    out_ref[...] = q.astype(jnp.float32) * two_eb


def _decode2d_kernel(d_ref, out_ref, carry_ref, *, two_eb):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    d = d_ref[...]
    q1 = jnp.cumsum(d, axis=1, dtype=jnp.int32)
    q = jnp.cumsum(q1, axis=0, dtype=jnp.int32) + carry_ref[...]
    carry_ref[...] = q[-1:, :]
    out_ref[...] = q.astype(jnp.float32) * two_eb


# ---------------------------------------------------------------------------
# pallas_call wrappers (shapes must be pre-padded by ops.py)
# ---------------------------------------------------------------------------

_SEQ = tpu_compiler_params(("arbitrary", "arbitrary"))
_SEQ1 = tpu_compiler_params(("arbitrary",))


def encode_1d(x, eb, radius, *, bm=256, bn=512, interpret=True):
    R, C = x.shape
    grid = (R // bm, C // bn)
    kern = functools.partial(
        _encode1d_kernel, inv_two_eb=1.0 / (2.0 * float(eb)), radius=int(radius)
    )
    return pl.pallas_call(
        kern,
        out_shape=(
            jax.ShapeDtypeStruct((R, C), jnp.int32),
            jax.ShapeDtypeStruct((R, C), jnp.int32),
        ),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=(
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ),
        scratch_shapes=[pltpu.VMEM((bm, 1), jnp.int32)],
        compiler_params=_SEQ,
        interpret=interpret,
    )(x)


def encode_2d(x, eb, radius, *, bm=256, interpret=True):
    R, C = x.shape
    grid = (R // bm,)
    kern = functools.partial(
        _encode2d_kernel, inv_two_eb=1.0 / (2.0 * float(eb)), radius=int(radius)
    )
    return pl.pallas_call(
        kern,
        out_shape=(
            jax.ShapeDtypeStruct((R, C), jnp.int32),
            jax.ShapeDtypeStruct((R, C), jnp.int32),
        ),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, C), lambda i: (i, 0))],
        out_specs=(
            pl.BlockSpec((bm, C), lambda i: (i, 0)),
            pl.BlockSpec((bm, C), lambda i: (i, 0)),
        ),
        scratch_shapes=[pltpu.VMEM((1, C), jnp.int32)],
        compiler_params=_SEQ1,
        interpret=interpret,
    )(x)


def decode_1d(d, eb, *, bm=256, bn=512, interpret=True):
    R, C = d.shape
    grid = (R // bm, C // bn)
    kern = functools.partial(_decode1d_kernel, two_eb=2.0 * float(eb))
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((R, C), jnp.float32),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, 1), jnp.int32)],
        compiler_params=_SEQ,
        interpret=interpret,
    )(d)


def decode_2d(d, eb, *, bm=256, interpret=True):
    R, C = d.shape
    grid = (R // bm,)
    kern = functools.partial(_decode2d_kernel, two_eb=2.0 * float(eb))
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((R, C), jnp.float32),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, C), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, C), lambda i: (i, 0)),
        scratch_shapes=[pltpu.VMEM((1, C), jnp.int32)],
        compiler_params=_SEQ1,
        interpret=interpret,
    )(d)
