"""Jit'd public wrappers around the fused Lorenzo kernels.

Handles padding to tile multiples, the int32 fast-path guard
(|x| / (2*eb) must stay below 2^30; otherwise callers use the core numpy
int64 path), backend selection (interpret=True on CPU, compiled on TPU), and
unpredictable-point bookkeeping for the device compression path.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import kernel as _k
from . import ref as _ref

INT32_SAFE = float(1 << 30)

#: guard for the BOUND-EXACT pipeline fast path (predictors.LorenzoPredictor):
#: beyond int32 range safety, prequantized magnitudes must stay small enough
#: that float32 kernel arithmetic cannot round reconstructions past the error
#: bound before the host-side verification patches the stragglers.
PIPELINE_SAFE = float(1 << 22)


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def device_default() -> bool:
    """Should the main pipeline route through the fused kernels by default?

    True on real TPUs (compiled Pallas).  On CPU the kernels only run in
    interpret mode — orders of magnitude slower than the numpy path — so the
    pipeline keeps numpy unless a caller forces the kernel path (tests do,
    on small arrays).
    """
    return jax.default_backend() == "tpu"


def encode_pipeline(
    x: np.ndarray, *, eb: float, radius: int = 32768, interpret: bool = None
) -> Tuple[np.ndarray, np.ndarray]:
    """Fused prequant+Lorenzo encode for the REAL pipeline (host arrays).

    Accepts 1-D (row-independent "1d" stencil) or 2-D ("2d" stencil) float32
    and returns host (codes, raw_diffs) int32 in the input's shape.  Callers
    are responsible for the PIPELINE_SAFE guard and for verifying/patching
    reconstruction against the error bound (predictors.LorenzoPredictor).
    """
    interpret = _interpret_default() if interpret is None else interpret
    x2d = jnp.asarray(x if x.ndim == 2 else x.reshape(1, -1), jnp.float32)
    mode = "2d" if x.ndim == 2 else "1d"
    codes, draw = lorenzo_encode(
        x2d, eb=float(eb), radius=int(radius), mode=mode, interpret=interpret
    )
    shape = x.shape
    return (
        np.asarray(codes).reshape(shape),
        np.asarray(draw).reshape(shape),
    )


def _pad2d(x: jnp.ndarray, bm: int, bn: int) -> Tuple[jnp.ndarray, Tuple[int, int]]:
    R, C = x.shape
    pr, pc = (-R) % bm, (-C) % bn
    if pr or pc:
        x = jnp.pad(x, ((0, pr), (0, pc)))
    return x, (R, C)


@functools.partial(jax.jit, static_argnames=("eb", "radius", "mode", "interpret"))
def lorenzo_encode(
    x: jnp.ndarray,
    *,
    eb: float,
    radius: int = 32768,
    mode: str = "2d",
    interpret: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused prequant+Lorenzo encode. Returns (codes, raw_diffs), both int32,
    cropped to the input shape.  mode: "1d" (row-independent) | "2d"."""
    assert x.ndim == 2, "reshape to 2-D before calling (rows, fastest-axis)"
    bm = 256 if x.shape[0] >= 256 else max(8, 8 * (x.shape[0] // 8) or 8)
    if mode == "1d":
        bn = 512 if x.shape[1] >= 512 else 128
        xp, (R, C) = _pad2d(x, bm, bn)
        codes, draw = _k.encode_1d(xp, eb, radius, bm=bm, bn=bn, interpret=interpret)
    else:
        xp, (R, C) = _pad2d(x, bm, 128)
        codes, draw = _k.encode_2d(xp, eb, radius, bm=bm, interpret=interpret)
    return codes[:R, :C], draw[:R, :C]


@functools.partial(jax.jit, static_argnames=("eb", "mode", "interpret"))
def lorenzo_decode(
    d: jnp.ndarray, *, eb: float, mode: str = "2d", interpret: bool = True
) -> jnp.ndarray:
    """Inverse (cumsum) + dequant.  ``d`` must contain the raw diffs with
    unpredictable positions already substituted."""
    assert d.ndim == 2
    bm = 256 if d.shape[0] >= 256 else max(8, 8 * (d.shape[0] // 8) or 8)
    if mode == "1d":
        bn = 512 if d.shape[1] >= 512 else 128
        dp, (R, C) = _pad2d(d, bm, bn)
        out = _k.decode_1d(dp, eb, bm=bm, bn=bn, interpret=interpret)
    else:
        dp, (R, C) = _pad2d(d, bm, 128)
        out = _k.decode_2d(dp, eb, bm=bm, interpret=interpret)
    return out[:R, :C]


def decode_pipeline(
    d: np.ndarray, *, eb: float, interpret: bool = None
) -> np.ndarray:
    """Fused cumsum+dequant decode for the REAL pipeline (host arrays).

    Inverse of :func:`encode_pipeline`: 1-D or 2-D int32 raw diffs (with
    unpredictable positions already substituted) -> float32 reconstruction.
    """
    interpret = _interpret_default() if interpret is None else interpret
    d2 = jnp.asarray(d if d.ndim == 2 else d.reshape(1, -1), jnp.int32)
    mode = "2d" if d.ndim == 2 else "1d"
    out = lorenzo_decode(d2, eb=float(eb), mode=mode, interpret=interpret)
    return np.asarray(out).reshape(d.shape)


def lorenzo_roundtrip_check(x: np.ndarray, eb: float) -> dict:
    """Convenience: encode+decode through the kernel path, report bound/ratio
    stats (used by tests and the device checkpoint path)."""
    x = jnp.asarray(x, jnp.float32)
    assert float(jnp.max(jnp.abs(x))) / (2 * eb) < INT32_SAFE, "int32 fast path"
    codes, draw = lorenzo_encode(x, eb=eb, interpret=_interpret_default())
    xhat = lorenzo_decode(draw, eb=eb, interpret=_interpret_default())
    err = float(jnp.max(jnp.abs(xhat - x)))
    return {"max_err": err, "codes": np.asarray(codes), "draw": np.asarray(draw)}


def ref_encode(x, eb, radius=32768, mode="2d"):
    fn = _ref.encode_1d if mode == "1d" else _ref.encode_2d
    return fn(jnp.asarray(x, jnp.float32), eb, radius)


def ref_decode(d, eb, mode="2d"):
    fn = _ref.decode_1d if mode == "1d" else _ref.decode_2d
    return fn(jnp.asarray(d, jnp.int32), eb)
