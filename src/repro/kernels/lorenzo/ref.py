"""Pure-jnp oracle for the fused prequantize+Lorenzo kernels.

Semantics contract shared with kernel.py / ops.py:

  encode: q = rint(x / (2*eb)) as int32
          1-D rows mode : d[r, c] = q[r, c] - q[r, c-1]           (q[., -1] = 0)
          2-D mode      : d = diff_rows(diff_cols(q))             (zero-padded)
          codes = d + radius where |d| < radius else 0  (int32)
          draw  = d                                     (int32, raw diffs)

  decode: inverse cumulative sums, xhat = q * 2*eb (f32)

The int32 fast path requires |x|/(2*eb) < 2^30; ops.py enforces/falls back.
"""
from __future__ import annotations

import jax.numpy as jnp


def prequant(x: jnp.ndarray, eb: float) -> jnp.ndarray:
    # multiply by the reciprocal — bit-identical to the kernel (which avoids
    # the slower VPU divide); the contract is reciprocal-multiply semantics.
    inv = 1.0 / (2.0 * eb)
    return jnp.rint(x.astype(jnp.float32) * inv).astype(jnp.int32)


def encode_1d(x: jnp.ndarray, eb: float, radius: int):
    """Row-independent 1-D Lorenzo; a (1, N) input is a global 1-D series."""
    q = prequant(x, eb)
    left = jnp.pad(q[:, :-1], ((0, 0), (1, 0)))
    d = q - left
    codes = jnp.where(jnp.abs(d) < radius, d + radius, 0).astype(jnp.int32)
    return codes, d


def encode_2d(x: jnp.ndarray, eb: float, radius: int):
    q = prequant(x, eb)
    up = jnp.pad(q[:-1, :], ((1, 0), (0, 0)))
    dr = q - up
    left = jnp.pad(dr[:, :-1], ((0, 0), (1, 0)))
    d = dr - left
    codes = jnp.where(jnp.abs(d) < radius, d + radius, 0).astype(jnp.int32)
    return codes, d


def decode_1d(d: jnp.ndarray, eb: float) -> jnp.ndarray:
    q = jnp.cumsum(d, axis=1, dtype=jnp.int32)
    return q.astype(jnp.float32) * (2.0 * eb)


def decode_2d(d: jnp.ndarray, eb: float) -> jnp.ndarray:
    q = jnp.cumsum(d, axis=1, dtype=jnp.int32)
    q = jnp.cumsum(q, axis=0, dtype=jnp.int32)
    return q.astype(jnp.float32) * (2.0 * eb)
