"""Pallas TPU kernels for quantized-KV serving (beyond-paper integration).

Two kernels:
  * quantize  — two-phase per-channel absmax + int8 cast, fused in one pass
                over row tiles (the absmax recurrence rides the sequential
                grid axis in VMEM scratch; codes are emitted on a second
                sweep).  Used when appending prefill KV blocks to the cache.
  * dequant_matmul — MXU-tiled matmul with the int8->f32 dequant fused into
                the VMEM load and the per-column scale folded into the
                epilogue: C[i,j] = sum_k A[i,k] * Q[k,j] * s[j].  Saves HBM
                bandwidth 2-4x vs bf16 KV — the memory-roofline lever for
                decode shapes (EXPERIMENTS.md §Perf).

All matmul block dims are 128-multiples so the MXU tiles are fully populated.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import tpu_compiler_params

SCALE_FLOOR = 1e-8


# ---------------------------------------------------------------------------
# per-channel absmax (phase 1 of quantize)
# ---------------------------------------------------------------------------

def _absmax_kernel(x_ref, amax_ref, acc_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = jnp.abs(x_ref[...].astype(jnp.float32))
    acc_ref[...] = jnp.maximum(acc_ref[...], jnp.max(x, axis=0, keepdims=True))

    @pl.when(i == pl.num_programs(0) - 1)
    def _():
        amax_ref[...] = acc_ref[...]


def _quant_kernel(x_ref, scale_ref, q_ref):
    x = x_ref[...].astype(jnp.float32)
    s = scale_ref[...]  # (1, bn)
    q = jnp.clip(jnp.rint(x / s), -127, 127)
    q_ref[...] = q.astype(jnp.int8)


def absmax(x, *, bm=256, interpret=True):
    T, C = x.shape
    return pl.pallas_call(
        _absmax_kernel,
        out_shape=jax.ShapeDtypeStruct((1, C), jnp.float32),
        grid=(T // bm,),
        in_specs=[pl.BlockSpec((bm, C), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, C), lambda i: (0, 0)),
        scratch_shapes=[pltpu.VMEM((1, C), jnp.float32)],
        compiler_params=tpu_compiler_params(("arbitrary",)),
        interpret=interpret,
    )(x)


def quantize_with_scale(x, scale, *, bm=256, bn=128, interpret=True):
    T, C = x.shape
    return pl.pallas_call(
        _quant_kernel,
        out_shape=jax.ShapeDtypeStruct((T, C), jnp.int8),
        grid=(T // bm, C // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        compiler_params=tpu_compiler_params(("parallel", "parallel")),
        interpret=interpret,
    )(x, scale)


# ---------------------------------------------------------------------------
# fused dequant matmul
# ---------------------------------------------------------------------------

def _dequant_matmul_kernel(a_ref, q_ref, scale_ref, o_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.float32)
    b = q_ref[...].astype(jnp.float32)  # int8 -> f32 in VMEM
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _():
        o_ref[...] = acc_ref[...] * scale_ref[...]  # per-column epilogue


def dequant_matmul(a, q, scale, *, bm=128, bn=128, bk=128, interpret=True):
    M, K = a.shape
    K2, N = q.shape
    assert K == K2 and scale.shape == (1, N)
    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        _dequant_matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=tpu_compiler_params(("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, q, scale)
