from .ops import kv_dequant_matmul, kv_quantize, ref_dequant_matmul, ref_quantize

__all__ = ["kv_quantize", "kv_dequant_matmul", "ref_quantize", "ref_dequant_matmul"]
