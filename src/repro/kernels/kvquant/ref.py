"""Pure-jnp oracle for the KV-cache quantization kernels.

Contract:
  quantize : per-channel symmetric int8.  scale[c] = absmax(x[:, c]) / 127
             (clamped to a tiny floor), q = clip(rint(x / scale), -127, 127).
             This is the SZ linear-scaling quantizer specialized to a fixed
             radius of 127 with per-channel bounds — the paper's quantizer
             module re-instantiated for the serving path (DESIGN.md §2).
  dequant_matmul : C = A @ (Q.astype(f32) * scale[None, :]) with f32
             accumulation — the attention read path (scores @ dequant(V) or
             q @ dequant(K)^T after layout prep).
"""
from __future__ import annotations

import jax.numpy as jnp

SCALE_FLOOR = 1e-8


def quantize(x: jnp.ndarray):
    """x: (T, C) f32/bf16 -> (q int8 (T, C), scale f32 (C,))."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=0)
    scale = jnp.maximum(absmax / 127.0, SCALE_FLOOR)
    q = jnp.clip(jnp.rint(x.astype(jnp.float32) / scale[None, :]), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale[None, :]


def dequant_matmul(a: jnp.ndarray, q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """a: (M, K) f32; q: (K, N) int8; scale: (N,) -> (M, N) f32."""
    b = q.astype(jnp.float32) * scale[None, :]
    return jnp.dot(a.astype(jnp.float32), b, preferred_element_type=jnp.float32)
