"""Jit'd wrappers for KV quantization kernels (padding + backend select)."""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from . import kernel as _k
from . import ref as _ref

SCALE_FLOOR = _k.SCALE_FLOOR


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x, m0, m1):
    p0, p1 = (-x.shape[0]) % m0, (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


@functools.partial(jax.jit, static_argnames=("interpret",))
def kv_quantize(x: jnp.ndarray, *, interpret: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(T, C) -> (int8 codes (T, C), per-channel scale (C,))."""
    T, C = x.shape
    xp = _pad_to(x.astype(jnp.float32), 256, 128)
    amax = _k.absmax(xp, interpret=interpret)  # (1, Cp)
    scale = jnp.maximum(amax / 127.0, SCALE_FLOOR)
    q = _k.quantize_with_scale(xp, scale, interpret=interpret)
    return q[:T, :C], scale[0, :C]


@functools.partial(jax.jit, static_argnames=("interpret",))
def kv_dequant_matmul(
    a: jnp.ndarray, q: jnp.ndarray, scale: jnp.ndarray, *, interpret: bool = True
) -> jnp.ndarray:
    """a (M, K) @ dequant(q (K, N), scale (N,)) -> (M, N) f32."""
    M, K = a.shape
    _, N = q.shape
    ap = _pad_to(a.astype(jnp.float32), 128, 128)
    qp = _pad_to(q, 128, 128)
    sp = jnp.pad(scale, (0, (-N) % 128)).reshape(1, -1)
    out = _k.dequant_matmul(ap, qp, sp, interpret=interpret)
    return out[:M, :N]


def ref_quantize(x):
    return _ref.quantize(jnp.asarray(x))


def ref_dequant_matmul(a, q, scale):
    return _ref.dequant_matmul(jnp.asarray(a), jnp.asarray(q), jnp.asarray(scale))
