"""Decoder-only LM covering the dense / MoE / SSM / hybrid / VLM families.

One implementation, configured per arch (repro/configs/*):
  * scan-over-layers keeps HLO size O(1) in depth (96-layer Nemotron compiles
    at 512 devices);
  * remat policy wraps the scanned block;
  * losses are computed with a seq-chunked fused logits+xent (the (B,S,V)
    logits tensor never materializes — the memory-roofline lever for the
    256k-vocab archs);
  * serving uses a KV cache that is optionally int8-quantized per token+head
    via the paper's linear-scaling quantizer (repro/compression/kvcache.py
    holds the quantize/dequantize policy) — SSM layers carry their O(1) state
    instead (attention-free archs: see DESIGN.md §6 arch-applicability).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..parallel.plan import ParallelPlan
from .common import ModelConfig
from .layers import (
    apply_mlp,
    apply_norm,
    apply_rope,
    attention_block,
    attention_core,
    attn_dims,
    dense_init,
    init_attention,
    init_mlp,
    init_norm,
)
from .mamba2 import (
    apply_mamba2,
    init_mamba2,
    init_ssm_state,
    mamba2_decode_step,
)
from .moe import apply_moe, init_moe


def _stack_init(fn, key, n: int):
    return jax.vmap(fn)(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_lm(key, cfg: ModelConfig, plan: ParallelPlan) -> Dict[str, Any]:
    ks = jax.random.split(key, 8)
    Vp, d = cfg.padded_vocab, cfg.d_model
    params: Dict[str, Any] = {
        "embed": dense_init(ks[0], (Vp, d), cfg.param_dtype, scale=0.02),
        "final_norm": init_norm(cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[1], (d, Vp), cfg.param_dtype)

    if cfg.family in ("dense", "vlm"):
        params["blocks"] = _stack_init(
            lambda k: _init_attn_block(k, cfg, plan, moe=False), ks[2], cfg.n_layers
        )
    elif cfg.family == "moe":
        pre = cfg.dense_prefix_layers
        if pre:
            params["dense_blocks"] = _stack_init(
                lambda k: _init_attn_block(k, cfg, plan, moe=False), ks[2], pre
            )
        params["blocks"] = _stack_init(
            lambda k: _init_attn_block(k, cfg, plan, moe=True),
            ks[3],
            cfg.n_layers - pre,
        )
    elif cfg.family == "ssm":
        params["blocks"] = _stack_init(
            lambda k: _init_ssm_block(k, cfg), ks[2], cfg.n_layers
        )
    elif cfg.family == "hybrid":
        params["blocks"] = _stack_init(
            lambda k: _init_ssm_block(k, cfg), ks[2], cfg.n_layers
        )
        params["shared_attn"] = _init_attn_block(ks[3], cfg, plan, moe=False)
    else:
        raise ValueError(f"init_lm does not handle family {cfg.family}")
    return params


def _init_attn_block(key, cfg, plan, *, moe: bool):
    ks = jax.random.split(key, 4)
    p = {
        "ln1": init_norm(cfg),
        "attn": init_attention(ks[0], cfg, plan),
        "ln2": init_norm(cfg),
    }
    if moe:
        p["moe"] = init_moe(ks[1], cfg)
    else:
        p["mlp"] = init_mlp(ks[1], cfg)
    return p


def _init_ssm_block(key, cfg):
    return {"ln": init_norm(cfg), "ssm": init_mamba2(key, cfg)}


# ---------------------------------------------------------------------------
# blocks (train)
# ---------------------------------------------------------------------------

def _attn_block(p, x, cfg, plan, attn_mode, moe: bool):
    x = plan.grad_barrier(x)
    h = apply_norm(p["ln1"], x)
    x = x + attention_block(
        p["attn"],
        h,
        cfg,
        plan,
        causal=True,
        window=cfg.sliding_window,
        attn_mode=attn_mode,
    )
    h = apply_norm(p["ln2"], x)
    if moe:
        y, aux = apply_moe(p["moe"], h, cfg, plan)
        return x + y, aux
    return x + apply_mlp(p["mlp"], h, cfg, plan), jnp.float32(0.0)


def _ssm_block(p, x, cfg, plan):
    x = plan.grad_barrier(x)
    h = apply_norm(p["ln"], x)
    return x + apply_mamba2(p["ssm"], h, cfg, plan), jnp.float32(0.0)


def _maybe_remat(fn, plan: ParallelPlan):
    if plan.remat == "none":
        return fn
    if plan.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def _scan_blocks(x, stacked, block_fn, plan):
    fn = _maybe_remat(block_fn, plan)

    def body(carry, lp):
        x, aux = carry
        x, aux_i = fn(lp, x)
        return (x, aux + aux_i), None

    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), stacked)
    return x, aux


def lm_backbone(
    params,
    x: jnp.ndarray,  # (B, S, d) embedded inputs
    cfg: ModelConfig,
    plan: ParallelPlan,
    attn_mode: str = "blocked",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Run the layer stack; returns (hidden, aux_loss)."""
    aux_total = jnp.float32(0.0)
    if cfg.family in ("dense", "vlm"):
        x, aux = _scan_blocks(
            x,
            params["blocks"],
            lambda p, h: _attn_block(p, h, cfg, plan, attn_mode, moe=False),
            plan,
        )
        aux_total += aux
    elif cfg.family == "moe":
        if cfg.dense_prefix_layers:
            x, aux = _scan_blocks(
                x,
                params["dense_blocks"],
                lambda p, h: _attn_block(p, h, cfg, plan, attn_mode, moe=False),
                plan,
            )
            aux_total += aux
        x, aux = _scan_blocks(
            x,
            params["blocks"],
            lambda p, h: _attn_block(p, h, cfg, plan, attn_mode, moe=True),
            plan,
        )
        aux_total += aux
    elif cfg.family == "ssm":
        x, aux = _scan_blocks(
            x, params["blocks"], lambda p, h: _ssm_block(p, h, cfg, plan), plan
        )
        aux_total += aux
    elif cfg.family == "hybrid":
        k = cfg.hybrid_attn_every or 6
        L = cfg.n_layers
        n_groups, rem = L // k, L % k
        stacked = params["blocks"]
        shared = params["shared_attn"]
        group_leaves = jax.tree.map(
            lambda t: t[: n_groups * k].reshape((n_groups, k) + t.shape[1:]), stacked
        )
        shared_fn = _maybe_remat(
            lambda p, h: _attn_block(p, h, cfg, plan, attn_mode, moe=False), plan
        )

        def group_body(carry, gp):
            h, aux = carry
            h, aux_i = _scan_blocks(
                h, gp, lambda p, hh: _ssm_block(p, hh, cfg, plan), plan
            )
            h, _ = shared_fn(shared, h)
            return (h, aux + aux_i), None

        (x, aux), _ = jax.lax.scan(group_body, (x, aux_total), group_leaves)
        aux_total = aux
        if rem:
            tail = jax.tree.map(lambda t: t[n_groups * k :], stacked)
            x, aux = _scan_blocks(
                x, tail, lambda p, h: _ssm_block(p, h, cfg, plan), plan
            )
            aux_total += aux
    else:
        raise ValueError(cfg.family)
    return apply_norm(params["final_norm"], x), aux_total


# ---------------------------------------------------------------------------
# embedding + loss
# ---------------------------------------------------------------------------

def embed_tokens(params, tokens, cfg: ModelConfig, plan: ParallelPlan):
    x = params["embed"][tokens]
    return plan.act_btd(x)


def unembed_matrix(params, cfg):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def chunked_xent(
    hidden: jnp.ndarray,  # (B, S, d)
    w_unembed: jnp.ndarray,  # (d, Vp)
    labels: jnp.ndarray,  # (B, S) int32; < 0 = ignore
    cfg: ModelConfig,
    plan: ParallelPlan,
    chunk: int = 512,
) -> jnp.ndarray:
    """Fused logits+softmax-xent over sequence chunks; (B,S,V) never lives."""
    B, S, d = hidden.shape
    c = min(chunk, S)
    assert S % c == 0
    nck = S // c

    def body(carry, i):
        tot, cnt = carry
        h = jax.lax.dynamic_slice_in_dim(hidden, i * c, c, axis=1)
        y = jax.lax.dynamic_slice_in_dim(labels, i * c, c, axis=1)
        logits = (h @ w_unembed).astype(jnp.float32)
        logits = plan.constrain(
            logits, plan.ps(plan.b, None, plan.model_axis)
        )
        mask = (y >= 0) & (y < cfg.vocab)
        ysafe = jnp.where(mask, y, 0)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ysafe[..., None], axis=-1)[..., 0]
        nll = jnp.where(mask, lse - gold, 0.0)
        return (tot + nll.sum(), cnt + mask.sum()), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.int32(0)), jnp.arange(nck)
    )
    return tot / jnp.maximum(cnt, 1)


def lm_loss(
    params,
    batch: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    plan: ParallelPlan,
    attn_mode: str = "blocked",
    aux_coeff: float = 0.01,
) -> jnp.ndarray:
    if "embeds" in batch:  # vlm / stubbed-frontend path
        x = plan.act_btd(batch["embeds"].astype(cfg.param_dtype))
    else:
        x = embed_tokens(params, batch["tokens"], cfg, plan)
    hidden, aux = lm_backbone(params, x, cfg, plan, attn_mode)
    loss = chunked_xent(
        hidden, unembed_matrix(params, cfg), batch["labels"], cfg, plan
    )
    return loss + aux_coeff * aux


# ---------------------------------------------------------------------------
# serving: KV cache + decode step
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DecodeCache:
    """Per-layer-stacked decode state.

    Attention layers: k/v (L, B, W, KV, hd) (+ per-token scales if int8),
    pos (B, W) absolute position per ring slot.  SSM layers: (ssm, conv)
    states.  ``length`` counts tokens already absorbed."""

    k: Optional[jnp.ndarray] = None
    v: Optional[jnp.ndarray] = None
    k_scale: Optional[jnp.ndarray] = None
    v_scale: Optional[jnp.ndarray] = None
    pos: Optional[jnp.ndarray] = None
    ssm: Optional[Any] = None
    conv: Optional[Any] = None
    length: jnp.ndarray = dataclasses.field(
        default_factory=lambda: jnp.zeros((), jnp.int32)
    )


def _n_attn_layers(cfg: ModelConfig) -> int:
    if cfg.family in ("dense", "vlm", "moe"):
        return cfg.n_layers
    if cfg.family == "hybrid":
        return cfg.n_layers // (cfg.hybrid_attn_every or 6)
    return 0


def _n_ssm_layers(cfg: ModelConfig) -> int:
    return cfg.n_layers if cfg.family in ("ssm", "hybrid") else 0


def cache_window(cfg: ModelConfig, max_len: int) -> int:
    return min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len


def init_decode_cache(
    cfg: ModelConfig, plan: ParallelPlan, batch: int, max_len: int
) -> DecodeCache:
    La, Ls = _n_attn_layers(cfg), _n_ssm_layers(cfg)
    W = cache_window(cfg, max_len)
    dims = attn_dims(cfg, plan)
    kv_dtype = jnp.int8 if plan.kv_cache_dtype == "int8" else cfg.param_dtype
    c = DecodeCache()
    if La:
        shp = (La, batch, W, dims.n_kv, dims.hd)
        c.k = jnp.zeros(shp, kv_dtype)
        c.v = jnp.zeros(shp, kv_dtype)
        if plan.kv_cache_dtype == "int8":
            c.k_scale = jnp.zeros((La, batch, W, dims.n_kv), jnp.float32)
            c.v_scale = jnp.zeros((La, batch, W, dims.n_kv), jnp.float32)
        c.pos = jnp.full((batch, W), -1, jnp.int32)
    if Ls:
        ssm0, conv0 = init_ssm_state(cfg, batch)
        c.ssm = jnp.zeros((Ls,) + ssm0.shape, ssm0.dtype)
        c.conv = jnp.zeros((Ls,) + conv0.shape, conv0.dtype)
    return c


def _quantize_token(x):
    """Per-token-per-head int8 (paper's linear-scaling quantizer, radius 127).

    x: (B, 1, KV, hd) -> (codes int8, scale (B, 1, KV))."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(absmax / 127.0, 1e-8)
    q = jnp.clip(jnp.rint(x.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def _decode_attn(
    p,
    x,  # (B, 1, d)
    layer_cache,
    length,
    pos_slot,
    cfg,
    plan,
):
    """Single-token attention against the (possibly int8) ring cache."""
    B = x.shape[0]
    dims = attn_dims(cfg, plan)
    k_c, v_c, ks_c, vs_c, pos_c = layer_cache
    W = k_c.shape[1]
    q = (x @ p["wq"]).reshape(B, 1, dims.n_q, dims.hd)
    k = (x @ p["wk"]).reshape(B, 1, dims.n_kv, dims.hd)
    v = (x @ p["wv"]).reshape(B, 1, dims.n_kv, dims.hd)
    if "bq" in p:
        q = q + p["bq"].reshape(1, 1, dims.n_q, dims.hd)
        k = k + p["bk"].reshape(1, 1, dims.n_kv, dims.hd)
        v = v + p["bv"].reshape(1, 1, dims.n_kv, dims.hd)
    posv = length.reshape(1, 1)
    q = apply_rope(q, posv, cfg.rope_theta)
    k = apply_rope(k, posv, cfg.rope_theta)
    slot = pos_slot  # length % W
    if plan.kv_cache_dtype == "int8":
        kq, ks = _quantize_token(k)
        vq, vs = _quantize_token(v)
        k_c = jax.lax.dynamic_update_slice_in_dim(k_c, kq, slot, axis=1)
        v_c = jax.lax.dynamic_update_slice_in_dim(v_c, vq, slot, axis=1)
        ks_c = jax.lax.dynamic_update_slice_in_dim(ks_c, ks, slot, axis=1)
        vs_c = jax.lax.dynamic_update_slice_in_dim(vs_c, vs, slot, axis=1)
        # dequantize to bf16, accumulate in f32 (the Pallas kvquant kernel
        # does this in VMEM on TPU): int8 x bf16-scale products carry the
        # full 8 quantized bits; halves the dequant HBM traffic vs f32
        kf = k_c.astype(jnp.bfloat16) * ks_c[..., None].astype(jnp.bfloat16)
        vf = v_c.astype(jnp.bfloat16) * vs_c[..., None].astype(jnp.bfloat16)
    else:
        k_c = jax.lax.dynamic_update_slice_in_dim(
            k_c, k.astype(k_c.dtype), slot, axis=1
        )
        v_c = jax.lax.dynamic_update_slice_in_dim(
            v_c, v.astype(v_c.dtype), slot, axis=1
        )
        kf, vf = k_c, v_c
    # mask: valid slots only (pos >= 0 and within window of current pos)
    new_pos = jax.lax.dynamic_update_slice_in_dim(
        pos_c, jnp.broadcast_to(length[None, None], (B, 1)).astype(jnp.int32), slot, axis=1
    )
    valid = new_pos >= 0
    if cfg.sliding_window:
        valid &= (length - new_pos) < cfg.sliding_window
    G = dims.group
    qg = (
        q.reshape(B, dims.n_kv, G, dims.hd).astype(jnp.float32)
        / jnp.sqrt(jnp.float32(dims.hd))
    ).astype(kf.dtype)
    s = jnp.einsum(
        "bkgh,bwkh->bkgw", qg, kf, preferred_element_type=jnp.float32
    )
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum(
        "bkgw,bwkh->bkgh",
        w.astype(kf.dtype),
        vf,
        preferred_element_type=jnp.float32,
    )
    o = o.reshape(B, 1, dims.n_q * dims.hd).astype(x.dtype)
    out = o @ p["wo"]
    return out, (k_c, v_c, ks_c, vs_c, new_pos)


def lm_decode_step(
    params,
    cache: DecodeCache,
    tokens: jnp.ndarray,  # (B, 1) int32
    cfg: ModelConfig,
    plan: ParallelPlan,
) -> Tuple[jnp.ndarray, DecodeCache]:
    """One serve step: consume one token per sequence, emit next-token logits."""
    B = tokens.shape[0]
    x = embed_tokens(params, tokens, cfg, plan)
    length = cache.length
    W = cache.k.shape[2] if cache.k is not None else 0
    slot = (length % W).astype(jnp.int32) if W else jnp.int32(0)

    def attn_layer(carry, inp):
        h = carry
        lp, lc = inp
        hn = apply_norm(lp["ln1"], h)
        o, lc_new = _decode_attn(lp["attn"], hn, lc, length, slot, cfg, plan)
        h = h + o
        hn = apply_norm(lp["ln2"], h)
        if "moe" in lp:
            y, _ = apply_moe(lp["moe"], hn, cfg, plan)
            h = h + y
        else:
            h = h + apply_mlp(lp["mlp"], hn, cfg, plan)
        return h, lc_new

    def ssm_layer(carry, inp):
        h = carry
        lp, st = inp
        hn = apply_norm(lp["ln"], h)
        o, st_new = mamba2_decode_step(lp["ssm"], hn, st, cfg, plan)
        return h + o, st_new

    new = DecodeCache(length=length + 1)
    if cfg.family in ("dense", "vlm", "moe"):
        # unified: scan over the stacked attn layers with their cache slices
        def run_stack(x, blocks, k, v, ks, vs):
            dummy = jnp.zeros((k.shape[0],), jnp.float32)
            ks_in = ks if ks is not None else dummy
            vs_in = vs if vs is not None else dummy

            def body2(h, inp):
                lp, kk, vv, kss, vss = inp
                sc = (kss, vss) if ks is not None else (None, None)
                h, (k2, v2, ks2, vs2, _) = attn_layer(
                    h, (lp, (kk, vv, sc[0], sc[1], cache.pos))
                )
                return h, (k2, v2, ks2 if ks is not None else kss, vs2 if vs is not None else vss)

            h, (k2, v2, ks2, vs2) = jax.lax.scan(
                body2, x, (blocks, k, v, ks_in, vs_in)
            )
            return h, k2, v2, (ks2 if ks is not None else None), (vs2 if vs is not None else None)

        pre = cfg.dense_prefix_layers if cfg.family == "moe" else 0
        h = x
        if pre:
            h, k2a, v2a, ks2a, vs2a = run_stack(
                h,
                params["dense_blocks"],
                cache.k[:pre],
                cache.v[:pre],
                cache.k_scale[:pre] if cache.k_scale is not None else None,
                cache.v_scale[:pre] if cache.v_scale is not None else None,
            )
        h, k2, v2, ks2, vs2 = run_stack(
            h,
            params["blocks"],
            cache.k[pre:],
            cache.v[pre:],
            cache.k_scale[pre:] if cache.k_scale is not None else None,
            cache.v_scale[pre:] if cache.v_scale is not None else None,
        )
        if pre:
            k2 = jnp.concatenate([k2a, k2], axis=0)
            v2 = jnp.concatenate([v2a, v2], axis=0)
            if ks2 is not None:
                ks2 = jnp.concatenate([ks2a, ks2], axis=0)
                vs2 = jnp.concatenate([vs2a, vs2], axis=0)
        new.k, new.v, new.k_scale, new.v_scale = k2, v2, ks2, vs2
        new.pos = jax.lax.dynamic_update_slice_in_dim(
            cache.pos,
            jnp.broadcast_to(length[None, None], (B, 1)).astype(jnp.int32),
            slot,
            axis=1,
        )
    elif cfg.family == "ssm":
        h, (ssm2, conv2) = jax.lax.scan(
            lambda hh, inp: ssm_layer(hh, inp),
            x,
            (params["blocks"], (cache.ssm, cache.conv)),
        )
        new.ssm, new.conv = ssm2, conv2
    elif cfg.family == "hybrid":
        k = cfg.hybrid_attn_every or 6
        L = cfg.n_layers
        n_groups, rem = L // k, L % k
        shared = params["shared_attn"]
        h = x
        ssm_states, conv_states = [], []
        ks_list, vs_list = [], []
        k_list, v_list = [], []
        for g in range(n_groups):
            blocks_g = jax.tree.map(
                lambda t: t[g * k : (g + 1) * k], params["blocks"]
            )
            h, (ssm2, conv2) = jax.lax.scan(
                lambda hh, inp: ssm_layer(hh, inp),
                h,
                (blocks_g, (cache.ssm[g * k : (g + 1) * k], cache.conv[g * k : (g + 1) * k])),
            )
            ssm_states.append(ssm2)
            conv_states.append(conv2)
            lc = (
                cache.k[g],
                cache.v[g],
                cache.k_scale[g] if cache.k_scale is not None else None,
                cache.v_scale[g] if cache.v_scale is not None else None,
                cache.pos,
            )
            hn = apply_norm(shared["ln1"], h)
            o, lc2 = _decode_attn(shared["attn"], hn, lc, length, slot, cfg, plan)
            h = h + o
            hn = apply_norm(shared["ln2"], h)
            h = h + apply_mlp(shared["mlp"], hn, cfg, plan)
            k_list.append(lc2[0])
            v_list.append(lc2[1])
            if cache.k_scale is not None:
                ks_list.append(lc2[2])
                vs_list.append(lc2[3])
            new.pos = lc2[4]
        if rem:
            tail = jax.tree.map(lambda t: t[n_groups * k :], params["blocks"])
            h, (ssm2, conv2) = jax.lax.scan(
                lambda hh, inp: ssm_layer(hh, inp),
                h,
                (tail, (cache.ssm[n_groups * k :], cache.conv[n_groups * k :])),
            )
            ssm_states.append(ssm2)
            conv_states.append(conv2)
        new.ssm = jnp.concatenate(ssm_states, axis=0)
        new.conv = jnp.concatenate(conv_states, axis=0)
        new.k = jnp.stack(k_list, axis=0)
        new.v = jnp.stack(v_list, axis=0)
        if ks_list:
            new.k_scale = jnp.stack(ks_list, axis=0)
            new.v_scale = jnp.stack(vs_list, axis=0)
    else:
        raise ValueError(cfg.family)

    h = apply_norm(params["final_norm"], h)
    logits = (h @ unembed_matrix(params, cfg)).astype(jnp.float32)
    logits = plan.constrain(logits, plan.ps(plan.b, None, plan.model_axis))
    return logits[:, 0, : cfg.vocab], new
