"""Fine-grained MoE with shared experts (DeepSeekMoE / Qwen3-MoE style).

Expert parallelism: routed-expert weights are sharded over the ``model`` mesh
axis; tokens are replicated across that axis (they already are, post
attention-TP), so each rank gathers the tokens routed to ITS experts, runs a
batched expert FFN at static capacity, scatter-adds its partial outputs and
psums over the model axis.  No all-to-all is needed under this layout — the
combine ride-shares the same collective slot as the dense TP MLP's psum
(DESIGN.md §5).  Implemented with shard_map so the gather/scatter indices are
local (pjit would force global index semantics).

Top-k routing with renormalized gates + the standard load-balance aux loss.
Over-capacity tokens are dropped (capacity_factor, GShard-style); the drop
rate is returned for monitoring.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..parallel import compat
from ..parallel.plan import ParallelPlan
from .common import ModelConfig
from .layers import dense_init

CAPACITY_FACTOR = 1.25


def init_moe(key, cfg: ModelConfig):
    d, f, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, E), jnp.float32, scale=0.02),
        "w1": dense_init(ks[1], (E, d, f), cfg.param_dtype),
        "w3": dense_init(ks[2], (E, d, f), cfg.param_dtype),
        "w2": dense_init(ks[3], (E, f, d), cfg.param_dtype),
    }
    if cfg.n_shared_experts:
        fs = cfg.n_shared_experts * f
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w1": dense_init(kk[0], (d, fs), cfg.param_dtype),
            "w3": dense_init(kk[1], (d, fs), cfg.param_dtype),
            "w2": dense_init(kk[2], (fs, d), cfg.param_dtype),
        }
    return p


def _expert_ffn(w1, w3, w2, x):
    """Batched per-expert SwiGLU: x (E, C, d) -> (E, C, d)."""
    h = jnp.einsum("ecd,edf->ecf", x, w1)
    g = jnp.einsum("ecd,edf->ecf", x, w3)
    h = jax.nn.silu(h) * g
    return jnp.einsum("ecf,efd->ecd", h, w2)


def _moe_local(
    x,  # (T, d) local tokens
    router,
    w1,
    w3,
    w2,  # local expert shards (E_loc, ...)
    *,
    top_k: int,
    n_experts: int,
    axis_name: Optional[str],
):
    T, d = x.shape
    E_loc = w1.shape[0]
    rank = jax.lax.axis_index(axis_name) if axis_name else 0
    e0 = rank * E_loc

    logits = x.astype(jnp.float32) @ router  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, top_k)  # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch): E * sum_e f_e * p_e
    me = probs.mean(0)
    ce = jnp.zeros(n_experts).at[idx.reshape(-1)].add(1.0) / (T * top_k)
    aux = n_experts * jnp.sum(me * ce)

    C = max(1, math.ceil(CAPACITY_FACTOR * T * top_k / n_experts))
    flat_e = idx.reshape(-1)  # (T*k,)
    local = (flat_e >= e0) & (flat_e < e0 + E_loc)
    key = jnp.where(local, flat_e - e0, E_loc)  # E_loc = discard bucket
    order = jnp.argsort(key, stable=True)
    sorted_key = key[order]
    starts = jnp.searchsorted(sorted_key, jnp.arange(E_loc + 1))
    pos = jnp.arange(T * top_k) - starts[sorted_key]
    keep = (sorted_key < E_loc) & (pos < C)
    slot = jnp.where(keep, sorted_key * C + pos, E_loc * C)  # last = trash

    token_row = jnp.full(E_loc * C + 1, T, jnp.int32)  # T = zero-pad row
    token_row = token_row.at[slot].set((order // top_k).astype(jnp.int32))
    gate_val = jnp.zeros(E_loc * C + 1, jnp.float32)
    gate_val = gate_val.at[slot].set(gates.reshape(-1)[order])
    token_row, gate_val = token_row[:-1], gate_val[:-1]

    xp = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)
    gx = xp[token_row].reshape(E_loc, C, d)
    ye = _expert_ffn(w1, w3, w2, gx).reshape(E_loc * C, d)
    ye = ye * gate_val[:, None].astype(ye.dtype)

    # combine in the model dtype so the EP psum runs at half width (bf16) —
    # §Perf: the f32 combine was the dominant MoE collective
    ye = ye.astype(x.dtype)
    y = jnp.zeros((T + 1, d), x.dtype).at[token_row].add(ye)[:T]
    if axis_name:
        y = jax.lax.psum(y, axis_name)
        aux = jax.lax.pmean(aux, axis_name)
    dropped = 1.0 - (keep.sum() / (T * top_k))
    return y.astype(x.dtype), aux, dropped


def apply_moe(
    p, x: jnp.ndarray, cfg: ModelConfig, plan: ParallelPlan
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (y, aux_loss)."""
    B, S, d = x.shape
    k, E = cfg.top_k, cfg.n_experts

    if plan.mesh is not None and plan.model_axis in plan.mesh.shape:
        # manual over (batch axes + model): dispatch indices and capacity are
        # LOCAL per device.  Inside the dp-manual grad-compression region
        # (train/step.py) plan.batch_axes is empty, so this nests cleanly —
        # tokens arrive already dp-local and only 'model' goes manual here.
        mspec = plan.model_axis
        bspec = plan.b
        manual = {mspec} | set(plan.batch_axes)
        fn = partial(
            _moe_local, top_k=k, n_experts=E, axis_name=plan.model_axis
        )

        def shard_fn(xl, router, w1, w3, w2):
            T = xl.shape[0] * xl.shape[1]
            y, aux, _ = fn(xl.reshape(T, d), router, w1, w3, w2)
            if plan.batch_axes:
                aux = jax.lax.pmean(aux, tuple(plan.batch_axes))
            return y.reshape(xl.shape), aux

        y, aux = compat.shard_map(
            shard_fn,
            plan.smap_mesh(),
            axis_names=manual,
            in_specs=(
                jax.sharding.PartitionSpec(bspec, None, None),
                jax.sharding.PartitionSpec(),  # router replicated
                jax.sharding.PartitionSpec(mspec, None, None),
                jax.sharding.PartitionSpec(mspec, None, None),
                jax.sharding.PartitionSpec(mspec, None, None),
            ),
            out_specs=(
                jax.sharding.PartitionSpec(bspec, None, None),
                jax.sharding.PartitionSpec(),
            ),
            check_vma=False,
        )(x, p["router"], p["w1"], p["w3"], p["w2"])
    else:
        y, aux, _ = _moe_local(
            x.reshape(B * S, d),
            p["router"],
            p["w1"],
            p["w3"],
            p["w2"],
            top_k=k,
            n_experts=E,
            axis_name=None,
        )
        y = y.reshape(B, S, d)

    if "shared" in p:
        sh = p["shared"]
        h = x @ sh["w1"]
        h = (jax.nn.silu(h) * (x @ sh["w3"])).astype(x.dtype)
        y = y + h @ sh["w2"]
    return y, aux
