"""Mamba2 / SSD block (state-space duality, arXiv:2405.21060).

Chunked SSD: the sequence is split into chunks; within a chunk the dual
(attention-like) quadratic form runs fully parallel, and a lax.scan carries
the (H, P, N) state across chunks — compact HLO, O(T·L) memory instead of
O(T^2).  The depthwise causal conv is expressed as k shifted adds (no conv
HLO, keeps the roofline parser trivial).  Decode is the O(1) recurrence; its
state is the entire "KV cache" of an SSM — which is why long_500k decode runs
for SSM/hybrid archs while pure full-attention archs skip it (DESIGN.md §6).

Tensor parallelism: heads (and the inner width) shard over the model axis;
groups=1 keeps B/C replicated per rank (they are small).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..parallel.plan import ParallelPlan
from .common import ModelConfig
from .layers import apply_norm, dense_init


def init_mamba2(key, cfg: ModelConfig):
    d, di = cfg.d_model, cfg.d_inner
    G, N, H = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    K = cfg.ssm_conv
    ks = jax.random.split(key, 6)
    d_in_proj = 2 * di + 2 * G * N + H  # z, x, B, C, dt
    conv_dim = di + 2 * G * N
    dt = jnp.exp(
        jax.random.uniform(ks[2], (H,)) * (math.log(0.1) - math.log(0.001))
        + math.log(0.001)
    )
    return {
        "in_proj": dense_init(ks[0], (d, d_in_proj), cfg.param_dtype),
        "conv_w": dense_init(ks[1], (K, conv_dim), cfg.param_dtype, scale=0.5),
        "conv_b": jnp.zeros((conv_dim,), cfg.param_dtype),
        "dt_bias": (dt + jnp.log(-jnp.expm1(-dt))).astype(jnp.float32),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "norm_w": jnp.ones((di,), cfg.param_dtype),
        "out_proj": dense_init(ks[3], (di, d), cfg.param_dtype),
    }


def _causal_conv(x, w, b, state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv as K shifted adds.  x: (B, T, C), w: (K, C).

    state: (B, K-1, C) trailing context for decode; returns (y, new_state)."""
    K = w.shape[0]
    if state is not None:
        x = jnp.concatenate([state, x], axis=1)
    else:  # training: causal same-length (zero left pad)
        x = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    T_out = x.shape[1] - (K - 1)
    y = jnp.zeros((x.shape[0], T_out, x.shape[2]), jnp.float32)
    for j in range(K):
        y = y + x[:, j : j + T_out].astype(jnp.float32) * w[j].astype(jnp.float32)
    y = jax.nn.silu(y + b.astype(jnp.float32))
    new_state = x[:, -(K - 1) :] if K > 1 else None
    return y, new_state


def _ssd_chunk_scan(xh, Bc, Cc, dt, A, chunk: int):
    """Chunked SSD.  xh: (B,T,H,P); Bc/Cc: (B,T,G,N) with G=1 squeezed to
    (B,T,N); dt: (B,T,H) (post-softplus); A: (H,) negative.
    Returns y: (B,T,H,P) and final state (B,H,P,N)."""
    Bsz, T, H, P = xh.shape
    N = Bc.shape[-1]
    L = min(chunk, T)
    assert T % L == 0, f"seq {T} % chunk {L} != 0"
    nc = T // L

    def to_chunks(t, extra):
        return t.reshape((Bsz, nc, L) + extra)

    xc = to_chunks(xh, (H, P)).astype(jnp.float32)
    bc = to_chunks(Bc, (N,)).astype(jnp.float32)
    cc = to_chunks(Cc, (N,)).astype(jnp.float32)
    dtc = to_chunks(dt, (H,)).astype(jnp.float32)

    lc = dtc * A[None, None, None, :]  # log decay per step, (B,nc,L,H)
    cum = jnp.cumsum(lc, axis=2)  # inclusive cumsum

    dt_chunks = dtc

    def step(h_prev, inp):
        xk, bk, ck, cumk, dtk = inp  # (B,L,H,P),(B,L,N),(B,L,N),(B,L,H),(B,L,H)
        li = jnp.arange(L)
        mask = (li[:, None] >= li[None, :])[None, :, :, None]
        seg = cumk[:, :, None, :] - cumk[:, None, :, :]  # (B,L,L,H)
        decay = jnp.where(mask, jnp.exp(seg), 0.0)
        scores = jnp.einsum("bin,bjn->bij", ck, bk)  # (B,L,L)
        w = scores[:, :, :, None] * decay * dtk[:, None, :, :]  # (B,L,L,H)
        y_intra = jnp.einsum("bijh,bjhp->bihp", w, xk)
        # inter-chunk: contribution of the carried state
        y_inter = jnp.einsum(
            "bin,bhpn,bih->bihp", ck, h_prev, jnp.exp(cumk)
        )
        # state update: decay to end of chunk
        tail = jnp.exp(cumk[:, -1:, :] - cumk)  # (B,L,H)
        s_new = jnp.einsum("bjn,bjhp,bjh->bhpn", bk, xk, tail * dtk)
        h_new = h_prev * jnp.exp(cumk[:, -1])[:, :, None, None] + s_new
        return h_new, y_intra + y_inter

    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    xs = (
        xc.transpose(1, 0, 2, 3, 4),
        bc.transpose(1, 0, 2, 3),
        cc.transpose(1, 0, 2, 3),
        cum.transpose(1, 0, 2, 3),
        dt_chunks.transpose(1, 0, 2, 3),
    )
    h_final, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bsz, T, H, P)
    return y, h_final


def apply_mamba2(
    p,
    x: jnp.ndarray,  # (B, T, d)
    cfg: ModelConfig,
    plan: ParallelPlan,
) -> jnp.ndarray:
    B, T, d = x.shape
    di, G, N, H, P = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = x @ p["in_proj"]
    z, xs, Bc, Cc, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + G * N, 2 * di + 2 * G * N], axis=-1
    )
    conv_in = jnp.concatenate([xs, Bc, Cc], axis=-1)
    conv_out, _ = _causal_conv(conv_in, p["conv_w"], p["conv_b"])
    xs, Bc, Cc = jnp.split(conv_out, [di, di + G * N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = xs.reshape(B, T, H, P)
    xh = plan.constrain(xh, plan.ps(plan.b, None, plan.model_axis, None))
    assert G == 1, "groups>1 not needed for assigned archs"
    y, _ = _ssd_chunk_scan(xh, Bc, Cc, dt, A, cfg.ssm_chunk)
    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B, T, di)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = apply_norm({"w": p["norm_w"]}, y.astype(x.dtype))
    return plan.act_btd(y @ p["out_proj"])


def mamba2_decode_step(
    p,
    x: jnp.ndarray,  # (B, 1, d)
    state: Tuple[jnp.ndarray, jnp.ndarray],  # (ssm_state (B,H,P,N), conv (B,K-1,C))
    cfg: ModelConfig,
    plan: ParallelPlan,
):
    B, _, d = x.shape
    di, G, N, H, P = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    h_prev, conv_state = state
    zxbcdt = x @ p["in_proj"]
    z, xs, Bc, Cc, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + G * N, 2 * di + 2 * G * N], axis=-1
    )
    conv_in = jnp.concatenate([xs, Bc, Cc], axis=-1)
    conv_out, conv_state = _causal_conv(conv_in, p["conv_w"], p["conv_b"], conv_state)
    xs, Bc, Cc = jnp.split(conv_out, [di, di + G * N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]  # (B,H)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A[None, :])  # (B,H)
    xh = xs.reshape(B, H, P).astype(jnp.float32)
    bk = Bc.reshape(B, N).astype(jnp.float32)
    ck = Cc.reshape(B, N).astype(jnp.float32)
    h_new = h_prev * a[:, :, None, None] + jnp.einsum(
        "bn,bhp,bh->bhpn", bk, xh, dt
    )
    y = jnp.einsum("bn,bhpn->bhp", ck, h_new) + xh * p["D"][None, :, None]
    y = y.reshape(B, 1, di) * jax.nn.silu(z.astype(jnp.float32))
    y = apply_norm({"w": p["norm_w"]}, y.astype(x.dtype))
    return y @ p["out_proj"], (h_new, conv_state)


def init_ssm_state(cfg: ModelConfig, batch: int):
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    K = cfg.ssm_conv
    conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return (
        jnp.zeros((batch, H, P, N), jnp.float32),
        jnp.zeros((batch, K - 1, conv_dim), jnp.float32),
    )
