"""Shared transformer layers: norms, RoPE, chunked GQA attention, MLPs.

Attention is memory-efficient (flash-style online softmax over KV chunks) in
pure JAX so every (arch x shape) cell lowers on any backend:

  * mode "scan"    — one lax.scan over KV chunks; compact HLO (O(1) in S).
  * mode "blocked" — python loop over Q chunks, each attending only the KV
    chunks its causal/SWA mask allows; skips fully-masked chunk pairs (the
    §Perf compute-term optimization; ~2x FLOPs saving for causal).

GQA with n_kv < TP is handled by *virtual KV-head duplication* (kv_repeat):
mathematically identical, makes the kv-head axis shard evenly (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..parallel.plan import ParallelPlan
from .common import ModelConfig

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# initialization helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) >= 1 else 1
    s = scale if scale is not None else 1.0 / math.sqrt(max(1, fan_in))
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, with_bias: bool = None):
    d = cfg.d_model
    if (with_bias is None and cfg.norm == "layernorm") or with_bias:
        return {"w": jnp.ones((d,), cfg.param_dtype), "b": jnp.zeros((d,), cfg.param_dtype)}
    return {"w": jnp.ones((d,), cfg.param_dtype)}


def apply_norm(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if "b" in p:  # layernorm
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        return (y * p["w"].astype(jnp.float32) + p["b"].astype(jnp.float32)).astype(x.dtype)
    ms = (xf * xf).mean(-1, keepdims=True)
    y = xf * jax.lax.rsqrt(ms + eps)
    return (y * p["w"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(hd: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., : hd // 2], x[..., hd // 2 :]
    rot = jnp.concatenate(
        [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1
    )
    return rot.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnDims:
    n_q: int  # query heads (global)
    n_kv: int  # effective kv heads after duplication (global)
    hd: int

    @property
    def group(self) -> int:
        return self.n_q // self.n_kv


def attn_dims(cfg: ModelConfig, plan: ParallelPlan) -> AttnDims:
    rep = plan.kv_repeat(cfg.n_kv_heads, cfg.n_heads)
    return AttnDims(n_q=cfg.n_heads, n_kv=cfg.n_kv_heads * rep, hd=cfg.hd)


def init_attention(key, cfg: ModelConfig, plan: ParallelPlan):
    dims = attn_dims(cfg, plan)
    d, hd = cfg.d_model, dims.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, dims.n_q * hd), cfg.param_dtype),
        "wk": dense_init(ks[1], (d, dims.n_kv * hd), cfg.param_dtype),
        "wv": dense_init(ks[2], (d, dims.n_kv * hd), cfg.param_dtype),
        "wo": dense_init(ks[3], (dims.n_q * hd, d), cfg.param_dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((dims.n_q * hd,), cfg.param_dtype)
        p["bk"] = jnp.zeros((dims.n_kv * hd,), cfg.param_dtype)
        p["bv"] = jnp.zeros((dims.n_kv * hd,), cfg.param_dtype)
    return p


def _chunk_mask(q_pos, k_pos, causal: bool, window: Optional[int], kv_len=None):
    """(Sq, Sk) additive mask for one chunk pair from absolute positions."""
    m = jnp.zeros((q_pos.shape[0], k_pos.shape[0]), jnp.float32)
    if causal:
        m = jnp.where(k_pos[None, :] > q_pos[:, None], NEG_INF, m)
    if window is not None:
        m = jnp.where(q_pos[:, None] - k_pos[None, :] >= window, NEG_INF, m)
    if kv_len is not None:
        m = jnp.where(k_pos[None, :] >= kv_len, NEG_INF, m)
    return m


def _attend_chunk(q, k, v, mask, state):
    """Online-softmax update.  q:(B,Sq,KV,G,hd) k/v:(B,Sk,KV,hd)."""
    m_prev, l_prev, acc = state
    s = jnp.einsum("bqkgh,bskh->bkgqs", q, k, preferred_element_type=jnp.float32)
    s = s + mask[None, None, None, :, :]
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(-1)
    pv = jnp.einsum("bkgqs,bskh->bkgqh", p, v.astype(jnp.float32))
    acc_new = acc * corr[..., None] + pv
    return m_new, l_new, acc_new


def attention_core(
    q: jnp.ndarray,  # (B, Sq, Hq, hd)
    k: jnp.ndarray,  # (B, Sk, KV, hd)
    v: jnp.ndarray,
    *,
    causal: bool,
    window: Optional[int] = None,
    q_offset=0,  # absolute position of q[0] (decode: kv_len-Sq)
    kv_len=None,  # valid prefix of k/v (decode with padded cache)
    chunk_k: int = 1024,
    mode: str = "blocked",
    k_scale: Optional[jnp.ndarray] = None,  # (B, Sk, KV) int8-dequant scales
    v_scale: Optional[jnp.ndarray] = None,
    out_dtype=None,
) -> jnp.ndarray:
    B, Sq, Hq, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = Hq // KV
    qg = q.reshape(B, Sq, KV, G, hd).astype(jnp.float32) / math.sqrt(hd)
    nck = max(1, math.ceil(Sk / chunk_k))
    ck = Sk // nck if Sk % nck == 0 else chunk_k
    # pad Sk to chunk multiple (mask handles the tail via kv_len)
    pad = (-Sk) % ck
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if k_scale is not None:
            k_scale = jnp.pad(k_scale, ((0, 0), (0, pad), (0, 0)))
            v_scale = jnp.pad(v_scale, ((0, 0), (0, pad), (0, 0)))
        if kv_len is None:
            kv_len = Sk
    nck = k.shape[1] // ck

    q_pos = q_offset + jnp.arange(Sq)

    def dequant(kc, sc):
        if sc is None:
            return kc
        return kc.astype(jnp.float32) * sc[..., None]

    def kv_chunk(i):
        sl = lambda t: jax.lax.dynamic_slice_in_dim(t, i * ck, ck, axis=1)
        kc = dequant(sl(k), sl(k_scale) if k_scale is not None else None)
        vc = dequant(sl(v), sl(v_scale) if v_scale is not None else None)
        return kc.astype(jnp.float32), vc.astype(jnp.float32)

    init = (
        jnp.full((B, KV, G, Sq), NEG_INF, jnp.float32),
        jnp.zeros((B, KV, G, Sq), jnp.float32),
        jnp.zeros((B, KV, G, Sq, hd), jnp.float32),
    )

    out_dtype = out_dtype or jnp.float32

    def finalize(m, l, acc, sq):
        o = acc / jnp.maximum(l[..., None], 1e-30)
        return o.transpose(0, 3, 1, 2, 4).reshape(B, sq, Hq, hd).astype(out_dtype)

    if mode == "scan" or Sq == 1 or nck == 1:
        def body(state, i):
            kc, vc = kv_chunk(i)
            k_pos = i * ck + jnp.arange(ck)
            mask = _chunk_mask(q_pos, k_pos, causal, window, kv_len)
            return _attend_chunk(qg, kc, vc, mask, state), None

        (m, l, acc), _ = jax.lax.scan(body, init, jnp.arange(nck))
        return finalize(m, l, acc, Sq)

    # blocked: per Q chunk, visit only the KV chunks its mask allows; each
    # chunk is normalized + cast immediately so the f32 accumulator never
    # exceeds one (B, KV, G, cq, hd) tile.
    cq = min(Sq, 1024)
    assert Sq % cq == 0, "blocked mode needs Sq % chunk == 0"
    outs = []
    for qi in range(Sq // cq):
        qc = qg[:, qi * cq : (qi + 1) * cq]
        qp = q_pos[qi * cq : (qi + 1) * cq]
        lo_pos = 0 if window is None else max(0, (qi * cq) - window - ck + 1)
        lo = lo_pos // ck
        hi = nck if not causal else min(nck, ((qi + 1) * cq + ck - 1) // ck)
        st = (
            jnp.full((B, KV, G, cq), NEG_INF, jnp.float32),
            jnp.zeros((B, KV, G, cq), jnp.float32),
            jnp.zeros((B, KV, G, cq, hd), jnp.float32),
        )

        def body(state, i, qc=qc, qp=qp):
            kc, vc = kv_chunk(i)
            k_pos = i * ck + jnp.arange(ck)
            mask = _chunk_mask(qp, k_pos, causal, window, kv_len)
            return _attend_chunk(qc, kc, vc, mask, state), None

        st, _ = jax.lax.scan(body, st, jnp.arange(lo, hi))
        outs.append(finalize(*st, cq))
    return jnp.concatenate(outs, axis=1)


def attention_block(
    p,
    x: jnp.ndarray,  # (B, S, d)
    cfg: ModelConfig,
    plan: ParallelPlan,
    *,
    positions=None,
    causal: bool = True,
    window: Optional[int] = None,
    attn_mode: str = "blocked",
    kv_from: Optional[jnp.ndarray] = None,  # cross-attention source
) -> jnp.ndarray:
    B, S, d = x.shape
    dims = attn_dims(cfg, plan)
    src = x if kv_from is None else kv_from
    q = x @ p["wq"]
    k = src @ p["wk"]
    v = src @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, dims.n_q, dims.hd)
    k = k.reshape(B, src.shape[1], dims.n_kv, dims.hd)
    v = v.reshape(B, src.shape[1], dims.n_kv, dims.hd)
    q = plan.act_heads(q)
    if positions is None:
        positions = jnp.arange(S)[None, :]
    if kv_from is None:  # self-attention: rotary on q and k
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    out = attention_core(
        q, k, v, causal=causal, window=window, mode=attn_mode, out_dtype=x.dtype
    )
    out = out.reshape(B, S, dims.n_q * dims.hd)
    from ..parallel.specs import heads_shardable

    proj = plan.tp_project(out, p["wo"], shardable=heads_shardable(cfg, plan))
    return plan.act_btd(proj)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_act == "swiglu":
        return {
            "w1": dense_init(ks[0], (d, f), cfg.param_dtype),
            "w3": dense_init(ks[1], (d, f), cfg.param_dtype),
            "w2": dense_init(ks[2], (f, d), cfg.param_dtype),
        }
    return {
        "w1": dense_init(ks[0], (d, f), cfg.param_dtype),
        "w2": dense_init(ks[2], (f, d), cfg.param_dtype),
    }


def apply_mlp(p, x, cfg: ModelConfig, plan: ParallelPlan):
    h = x @ p["w1"]
    h = plan.constrain(h, plan.ps(plan.b, None, plan.model_axis))
    if cfg.mlp_act == "swiglu":
        h = jax.nn.silu(h) * plan.constrain(
            x @ p["w3"], plan.ps(plan.b, None, plan.model_axis)
        )
    elif cfg.mlp_act == "relu2":
        r = jax.nn.relu(h)
        h = r * r
    else:  # gelu
        h = jax.nn.gelu(h)
    return plan.act_btd(plan.tp_project(h.astype(x.dtype), p["w2"]))
