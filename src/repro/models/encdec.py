"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

Per the assignment the conv/mel frontend is a STUB: ``input_specs()`` feeds
precomputed frame embeddings (B, enc_seq, d_model) straight into the encoder
stack.  Encoder: bidirectional attention + GELU MLP + LayerNorm.  Decoder:
causal self-attention + cross-attention to the encoder output + GELU MLP.
Positional encoding uses RoPE on self-attention (structural deviation from
Whisper's learned absolute embeddings — the backbone dims/stack are what the
shape cells exercise; noted in DESIGN.md).

Serving: cross-K/V is computed once at prefill and cached; the self-attention
cache follows the same (optionally int8) policy as lm.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..parallel.plan import ParallelPlan
from .common import ModelConfig
from .layers import (
    apply_mlp,
    apply_norm,
    apply_rope,
    attention_block,
    attn_dims,
    dense_init,
    init_attention,
    init_mlp,
    init_norm,
)
from .lm import (
    DecodeCache,
    _decode_attn,
    _maybe_remat,
    chunked_xent,
    unembed_matrix,
)


def _stack_init(fn, key, n):
    return jax.vmap(fn)(jax.random.split(key, n))


def init_encdec(key, cfg: ModelConfig, plan: ParallelPlan) -> Dict[str, Any]:
    ks = jax.random.split(key, 6)
    Vp, d = cfg.padded_vocab, cfg.d_model

    def enc_block(k):
        kk = jax.random.split(k, 2)
        return {
            "ln1": init_norm(cfg),
            "attn": init_attention(kk[0], cfg, plan),
            "ln2": init_norm(cfg),
            "mlp": init_mlp(kk[1], cfg),
        }

    def dec_block(k):
        kk = jax.random.split(k, 3)
        return {
            "ln1": init_norm(cfg),
            "self_attn": init_attention(kk[0], cfg, plan),
            "lnx": init_norm(cfg),
            "cross_attn": init_attention(kk[1], cfg, plan),
            "ln2": init_norm(cfg),
            "mlp": init_mlp(kk[2], cfg),
        }

    return {
        "embed": dense_init(ks[0], (Vp, d), cfg.param_dtype, scale=0.02),
        "lm_head": dense_init(ks[1], (d, Vp), cfg.param_dtype),
        "enc_blocks": _stack_init(enc_block, ks[2], cfg.n_enc_layers),
        "enc_norm": init_norm(cfg),
        "dec_blocks": _stack_init(dec_block, ks[3], cfg.n_layers),
        "final_norm": init_norm(cfg),
    }


def encode(params, frames: jnp.ndarray, cfg: ModelConfig, plan: ParallelPlan,
           attn_mode: str = "scan") -> jnp.ndarray:
    """frames: (B, enc_seq, d) stub embeddings -> encoder hidden states."""
    x = plan.act_btd(frames.astype(cfg.param_dtype))

    def block(p, h):
        hh = apply_norm(p["ln1"], h)
        h = h + attention_block(
            p["attn"], hh, cfg, plan, causal=False, attn_mode=attn_mode
        )
        hh = apply_norm(p["ln2"], h)
        return h + apply_mlp(p["mlp"], hh, cfg, plan), jnp.float32(0.0)

    fn = _maybe_remat(block, plan)

    def body(carry, lp):
        h, _ = fn(lp, carry)
        return h, None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return apply_norm(params["enc_norm"], x)


def decode_train(
    params,
    tokens: jnp.ndarray,
    enc_out: jnp.ndarray,
    cfg: ModelConfig,
    plan: ParallelPlan,
    attn_mode: str = "blocked",
) -> jnp.ndarray:
    x = plan.act_btd(params["embed"][tokens])

    def block(p, h):
        hh = apply_norm(p["ln1"], h)
        h = h + attention_block(
            p["self_attn"], hh, cfg, plan, causal=True, attn_mode=attn_mode
        )
        hh = apply_norm(p["lnx"], h)
        h = h + attention_block(
            p["cross_attn"], hh, cfg, plan, causal=False, attn_mode="scan",
            kv_from=enc_out,
        )
        hh = apply_norm(p["ln2"], h)
        return h + apply_mlp(p["mlp"], hh, cfg, plan), jnp.float32(0.0)

    fn = _maybe_remat(block, plan)

    def body(carry, lp):
        h, _ = fn(lp, carry)
        return h, None

    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    return apply_norm(params["final_norm"], x)


def encdec_loss(
    params,
    batch: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    plan: ParallelPlan,
    attn_mode: str = "blocked",
) -> jnp.ndarray:
    enc_out = encode(params, batch["enc_frames"], cfg, plan)
    hidden = decode_train(params, batch["tokens"], enc_out, cfg, plan, attn_mode)
    return chunked_xent(hidden, params["lm_head"], batch["labels"], cfg, plan)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class EncDecCache:
    self_cache: DecodeCache
    cross_k: jnp.ndarray  # (L, B, S_enc, KV, hd)
    cross_v: jnp.ndarray


def init_encdec_cache(
    params, enc_frames, cfg: ModelConfig, plan: ParallelPlan, batch: int, max_len: int
) -> EncDecCache:
    """Prefill: run the encoder and precompute cross-attention K/V."""
    from .lm import init_decode_cache

    enc_out = encode(params, enc_frames, cfg, plan)
    dims = attn_dims(cfg, plan)
    B, Se, _ = enc_out.shape

    def cross_kv(p):
        k = (enc_out @ p["cross_attn"]["wk"]).reshape(B, Se, dims.n_kv, dims.hd)
        v = (enc_out @ p["cross_attn"]["wv"]).reshape(B, Se, dims.n_kv, dims.hd)
        if "bk" in p["cross_attn"]:
            k = k + p["cross_attn"]["bk"].reshape(1, 1, dims.n_kv, dims.hd)
            v = v + p["cross_attn"]["bv"].reshape(1, 1, dims.n_kv, dims.hd)
        return k, v

    ck, cv = jax.vmap(cross_kv)(params["dec_blocks"])
    sc = init_decode_cache(
        dataclasses.replace(cfg, family="dense"), plan, batch, max_len
    )
    return EncDecCache(self_cache=sc, cross_k=ck, cross_v=cv)


def encdec_decode_step(
    params,
    cache: EncDecCache,
    tokens: jnp.ndarray,  # (B, 1)
    cfg: ModelConfig,
    plan: ParallelPlan,
) -> Tuple[jnp.ndarray, EncDecCache]:
    B = tokens.shape[0]
    x = plan.act_btd(params["embed"][tokens])
    sc = cache.self_cache
    length = sc.length
    W = sc.k.shape[2]
    slot = (length % W).astype(jnp.int32)
    dims = attn_dims(cfg, plan)

    def body(h, inp):
        lp, kk, vv, kss, vss, ck, cv = inp
        hn = apply_norm(lp["ln1"], h)
        o, lc2 = _decode_attn(
            lp["self_attn"], hn, (kk, vv, kss, vss, sc.pos), length, slot, cfg, plan
        )
        h = h + o
        # cross attention (dense over encoder frames)
        hn = apply_norm(lp["lnx"], h)
        q = (hn @ lp["cross_attn"]["wq"]).reshape(B, 1, dims.n_q, dims.hd)
        if "bq" in lp["cross_attn"]:
            q = q + lp["cross_attn"]["bq"].reshape(1, 1, dims.n_q, dims.hd)
        G = dims.group
        qg = q.reshape(B, dims.n_kv, G, dims.hd).astype(jnp.float32) / jnp.sqrt(
            jnp.float32(dims.hd)
        )
        s = jnp.einsum("bkgh,bskh->bkgs", qg, ck.astype(jnp.float32))
        w = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgs,bskh->bkgh", w, cv.astype(jnp.float32))
        o = o.reshape(B, 1, dims.n_q * dims.hd).astype(h.dtype)
        h = h + o @ lp["cross_attn"]["wo"]
        hn = apply_norm(lp["ln2"], h)
        h = h + apply_mlp(lp["mlp"], hn, cfg, plan)
        return h, (lc2[0], lc2[1], lc2[2], lc2[3])

    dummy = jnp.zeros((sc.k.shape[0],), jnp.float32)
    ks_in = sc.k_scale if sc.k_scale is not None else dummy
    vs_in = sc.v_scale if sc.v_scale is not None else dummy

    def body2(h, inp):
        lp, kk, vv, kss, vss, ck, cv = inp
        scales = (kss, vss) if sc.k_scale is not None else (None, None)
        h, (k2, v2, ks2, vs2) = body(h, (lp, kk, vv, scales[0], scales[1], ck, cv))
        return h, (
            k2,
            v2,
            ks2 if sc.k_scale is not None else kss,
            vs2 if sc.v_scale is not None else vss,
        )

    h, (k2, v2, ks2, vs2) = jax.lax.scan(
        body2,
        x,
        (params["dec_blocks"], sc.k, sc.v, ks_in, vs_in, cache.cross_k, cache.cross_v),
    )
    new_pos = jax.lax.dynamic_update_slice_in_dim(
        sc.pos,
        jnp.broadcast_to(length[None, None], (B, 1)).astype(jnp.int32),
        slot,
        axis=1,
    )
    new_sc = DecodeCache(
        k=k2,
        v=v2,
        k_scale=ks2 if sc.k_scale is not None else None,
        v_scale=vs2 if sc.v_scale is not None else None,
        pos=new_pos,
        length=length + 1,
    )
    h = apply_norm(params["final_norm"], h)
    logits = (h @ unembed_matrix(params, cfg)).astype(jnp.float32)
    return logits[:, 0, : cfg.vocab], EncDecCache(
        self_cache=new_sc, cross_k=cache.cross_k, cross_v=cache.cross_v
    )
