"""Model configuration covering all assigned architecture families.

One frozen dataclass describes dense / MoE / SSM / hybrid / enc-dec / VLM
backbones; family-specific fields are simply unused elsewhere.  Exact
per-arch values live in ``repro/configs/<id>.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: Optional[int] = None
    qkv_bias: bool = False
    mlp_act: str = "swiglu"  # swiglu | gelu | relu2
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    sliding_window: Optional[int] = None  # SWA width (h2o-danube)
    rope_theta: float = 10000.0
    tie_embeddings: bool = False

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    # layers with index < dense_prefix_layers use the dense MLP (deepseek-moe
    # keeps layer 0 dense)
    dense_prefix_layers: int = 0

    # --- SSM (Mamba2/SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv: int = 4
    ssm_groups: int = 1

    # --- hybrid (zamba2): shared attention block every k SSM layers ---
    hybrid_attn_every: int = 0

    # --- enc-dec (whisper) ---
    n_enc_layers: int = 0
    enc_seq: int = 1500  # precomputed frame embeddings (frontend stub)

    # --- vlm (pixtral): patch embeddings prepended (frontend stub) ---
    n_img_tokens: int = 0

    dtype: str = "bfloat16"
    vocab_pad_to: int = 256

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(1, self.n_heads))

    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return (self.vocab + p - 1) // p * p

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def d_inner(self) -> int:  # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def block_kinds(self) -> Tuple[str, ...]:
        """Per-layer block type sequence for the decoder stack."""
        if self.family == "ssm":
            return ("ssm",) * self.n_layers
        if self.family == "hybrid":
            k = self.hybrid_attn_every or 6
            kinds = []
            for i in range(self.n_layers):
                kinds.append("ssm")
                if (i + 1) % k == 0:
                    kinds.append("shared_attn")
            return tuple(kinds)
        return ("attn",) * self.n_layers

    def n_flop_params(self) -> float:
        """Active parameter count N for MODEL_FLOPS = 6*N*D (MoE: activated)."""
        d, hd = self.d_model, self.hd
        attn = self.n_heads * hd * d + 2 * self.n_kv_heads * hd * d + self.n_heads * hd * d
        if self.mlp_act == "swiglu":
            dense_mlp = 3 * d * self.d_ff
        else:
            dense_mlp = 2 * d * self.d_ff
        per_layer = 0.0
        if self.family in ("dense", "vlm", "encdec"):
            per_layer = attn + dense_mlp
        elif self.family == "moe":
            act_ff = (self.top_k + self.n_shared_experts) * self.moe_d_ff
            moe_mlp = 3 * d * act_ff
            per_layer = attn + moe_mlp
        elif self.family == "ssm":
            di, ns = self.d_inner, self.ssm_state
            per_layer = d * (2 * di + 2 * self.ssm_groups * ns + self.ssm_heads) + di * d
        elif self.family == "hybrid":
            di, ns = self.d_inner, self.ssm_state
            ssm = d * (2 * di + 2 * self.ssm_groups * ns + self.ssm_heads) + di * d
            n_shared = self.n_layers // (self.hybrid_attn_every or 6)
            return self.n_layers * ssm + n_shared * (attn + dense_mlp) + 2 * d * self.padded_vocab
        total = self.n_layers * per_layer
        if self.family == "encdec":
            total += self.n_enc_layers * (attn + dense_mlp)
        total += 2 * d * self.padded_vocab  # embed + unembed
        return float(total)
