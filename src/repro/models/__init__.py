"""Model zoo facade: family dispatch for init / loss / prefill / decode."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax.numpy as jnp

from ..parallel.plan import ParallelPlan
from . import encdec as _encdec
from . import lm as _lm
from .common import ModelConfig


def init_params(key, cfg: ModelConfig, plan: ParallelPlan):
    if cfg.family == "encdec":
        return _encdec.init_encdec(key, cfg, plan)
    return _lm.init_lm(key, cfg, plan)


def loss_fn(params, batch, cfg: ModelConfig, plan: ParallelPlan, attn_mode="blocked"):
    if cfg.family == "encdec":
        return _encdec.encdec_loss(params, batch, cfg, plan, attn_mode)
    return _lm.lm_loss(params, batch, cfg, plan, attn_mode)


def prefill_logits(params, batch, cfg: ModelConfig, plan: ParallelPlan, attn_mode="blocked"):
    """Inference prefill: forward to final hidden + last-position logits."""
    if cfg.family == "encdec":
        enc_out = _encdec.encode(params, batch["enc_frames"], cfg, plan)
        hidden = _encdec.decode_train(params, batch["tokens"], enc_out, cfg, plan, attn_mode)
    else:
        if "embeds" in batch:
            x = plan.act_btd(batch["embeds"].astype(cfg.param_dtype))
        else:
            x = _lm.embed_tokens(params, batch["tokens"], cfg, plan)
        hidden, _ = _lm.lm_backbone(params, x, cfg, plan, attn_mode)
    w = _lm.unembed_matrix(params, cfg)
    logits = (hidden[:, -1:, :] @ w).astype(jnp.float32)
    return logits[:, 0, : cfg.vocab]


def init_cache(params, cfg: ModelConfig, plan: ParallelPlan, batch: int, max_len: int, enc_frames=None):
    if cfg.family == "encdec":
        return _encdec.init_encdec_cache(params, enc_frames, cfg, plan, batch, max_len)
    return _lm.init_decode_cache(cfg, plan, batch, max_len)


def decode_step(params, cache, tokens, cfg: ModelConfig, plan: ParallelPlan):
    if cfg.family == "encdec":
        return _encdec.encdec_decode_step(params, cache, tokens, cfg, plan)
    return _lm.lm_decode_step(params, cache, tokens, cfg, plan)


__all__ = [
    "ModelConfig",
    "init_params",
    "loss_fn",
    "prefill_logits",
    "init_cache",
    "decode_step",
]
