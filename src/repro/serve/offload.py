"""Async multi-tenant KV-offload service (ROADMAP item 2).

The paper's APS use case is a serving-shaped workload: many concurrent
producers evicting KV pages through a composed pipeline and paging them back
in under tight latency budgets.  This module wraps the chunked container
engine (:mod:`repro.core.chunking`) in a service:

  * **asyncio front** — :class:`OffloadService` exposes ``await``-able
    ``put`` / ``fetch`` / ``evict`` for named ``(tenant, page)`` KV pages.
  * **pooled workers** — the GIL-bound NumPy compress/decode paths run on a
    ``ThreadPoolExecutor`` (default: zlib/numpy release the GIL) or a
    ``ProcessPoolExecutor`` (``executor="process"``; the worker functions are
    module-level and picklable, with a per-process decode-state cache).
  * **request coalescing** — fetches that arrive within ``coalesce_ms`` are
    drained into one batch, grouped by page, and submitted as one executor
    job per page, so a burst of small random-access reads pays one dispatch.
  * **cached decode state** — a bounded LRU (:class:`DecodeStateCache`)
    keyed by blob identity: parsed headers + chunk tables
    (:class:`~repro.core.chunking.ChunkedIndex`) so repeated fetches skip
    msgpack parsing, plus a byte-budgeted layer of decoded chunk arrays so
    re-reads of a hot KV page skip the entropy decode (the dominant
    per-fetch cost) entirely; the Huffman decode tables themselves live in
    the signature-keyed LRU inside :mod:`repro.core.encoders`, which these
    layers keep warm.

Per-chunk reads stay O(chunk): :func:`repro.core.chunking.decompress_chunk`
verifies the header CRC plus only the requested chunk's CRC, so a corrupt
sibling chunk surfaces a typed :class:`OffloadError` to exactly the request
that asked for it — the rest of the batch completes.

Telemetry (PR 8 spine): ``sz3_serve_request_seconds`` latency histogram,
``sz3_serve_queue_depth`` gauge, ``sz3_serve_index_cache_{hits,misses}_total``
counters, batch/coalescing counters, and an entries gauge for cache sizing.
"""
from __future__ import annotations

import asyncio
import multiprocessing
import threading
import time
import zlib
from collections import OrderedDict
from concurrent.futures import Executor, ProcessPoolExecutor, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import encoders
from repro.core import integrity
from repro.core import pipeline as pl_mod
from repro.core import telemetry
from repro.core.chunking import (
    DEFAULT_CANDIDATES,
    ChunkedIndex,
    decompress_chunk,
    parse_chunked_index,
    sz3_chunked,
)
from repro.core.config import CompressionConfig, ErrorBoundMode
from repro.core.integrity import IntegrityError

log = telemetry.get_logger("serve.offload")

__all__ = [
    "OffloadError",
    "DecodeStateCache",
    "OffloadService",
    "blob_key",
]


class OffloadError(RuntimeError):
    """A request-scoped service failure, addressed to its owning request.

    ``cause_type`` names the underlying error class (``"IntegrityError"``,
    ``"ContainerError"``, ...) so callers can branch without string matching;
    ``chunk`` is the chunk index the failing request asked for (None for
    whole-page requests), and ``chunk_index`` is the damaged chunk the
    integrity layer localized, when it did.
    """

    def __init__(
        self,
        message: str,
        *,
        tenant: Optional[str] = None,
        page: Optional[str] = None,
        chunk: Optional[int] = None,
        cause_type: Optional[str] = None,
        chunk_index: Optional[int] = None,
    ):
        super().__init__(message)
        self.tenant = tenant
        self.page = page
        self.chunk = chunk
        self.cause_type = cause_type
        self.chunk_index = chunk_index


def blob_key(blob: bytes) -> Tuple[int, int, int]:
    """Identity fingerprint of a container: O(header + trailer), not O(body).

    (length, CRC of the prologue + msgpack header, CRC of the integrity
    trailer).  These are exactly the bytes a :class:`ChunkedIndex` is derived
    from (the body contributes only its length, pinned by the prologue), so
    two blobs with equal keys parse to identical decode state and may share
    a cache entry — even when their bodies differ (e.g. a corrupt copy; the
    requested chunk's CRC check at read time still runs against the actual
    bytes).  Trailer-less (legacy) blobs have no body digest to lean on and
    fall back to a full-tail CRC, paying O(body) once per cache miss.
    """
    n = len(blob)
    if n >= 20 and blob[:4] == b"SZ3J":
        hlen = int.from_bytes(blob[4:12], "little", signed=True)
        head_end = min(n, 20 + max(hlen, 0))
        tail_crc = None
        if n >= 9 and blob[-4:] == integrity.TRAILER_MAGIC:
            plen = int.from_bytes(blob[-9:-5], "little")
            start = n - 9 - plen
            if start >= head_end:
                tail_crc = zlib.crc32(blob[start:])
        if tail_crc is None:
            tail_crc = zlib.crc32(blob[head_end:])
        return (n, zlib.crc32(blob[:head_end]), tail_crc)
    return (n, zlib.crc32(blob), 0)


class DecodeStateCache:
    """Bounded LRU of decode state keyed by blob identity.  Three layers:

    1. **parsed indexes** — :class:`~repro.core.chunking.ChunkedIndex`
       objects (header, chunk table, trailer CRCs), so repeated fetches skip
       the msgpack parse and trailer scan (``max_entries`` bound).
    2. **decoded chunks** — the arrays themselves, byte-budgeted
       (``max_chunk_bytes``): a KV page that is re-read while hot skips the
       whole entropy decode, which profiling shows dominates per-chunk
       latency by ~10x over parse + table build.  Entries are marked
       read-only and returned without copying; the chunk key includes the
       verify policy, so a ``verify="off"`` decode is never served to a
       strict reader.
    3. **Huffman decode tables** — not stored here: they live in the
       signature-keyed LRU inside :mod:`repro.core.encoders`, which layers
       1–2 keep warm.

    Thread-safe: the service decodes on a pool.  Indexes parse with
    ``verify="off"`` — integrity decisions (header CRC, per-chunk CRC,
    stripped trailer) are made per *read* from the cached fields.
    """

    def __init__(
        self,
        max_entries: int = 64,
        max_chunk_bytes: int = 32 << 20,
        metrics_prefix: str = "sz3_serve",
    ):
        self.max_entries = max(1, int(max_entries))
        self.max_chunk_bytes = max(0, int(max_chunk_bytes))
        self._prefix = metrics_prefix
        self._lock = threading.Lock()
        self._entries: "OrderedDict[Tuple[int, int, int], ChunkedIndex]" = OrderedDict()
        self._chunks: "OrderedDict[Tuple[Tuple[int, int, int], int, str], np.ndarray]" = (
            OrderedDict()
        )
        self._chunk_bytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.chunk_hits = 0
        self.chunk_misses = 0
        self.chunk_evictions = 0

    def index_for(self, blob: bytes) -> ChunkedIndex:
        key = blob_key(blob)
        with self._lock:
            idx = self._entries.get(key)
            if idx is not None:
                self._entries.move_to_end(key)
                self.hits += 1
        if idx is not None:
            telemetry.metric_count(f"{self._prefix}_index_cache_hits_total")
            return idx
        # parse outside the lock; concurrent misses on one blob parse twice
        idx = parse_chunked_index(blob, verify="off")
        evicted = 0
        with self._lock:
            self.misses += 1
            self._entries[key] = idx
            self._entries.move_to_end(key)
            while len(self._entries) > self.max_entries:
                self._entries.popitem(last=False)
                self.evictions += 1
                evicted += 1
            size = len(self._entries)
        telemetry.metric_count(f"{self._prefix}_index_cache_misses_total")
        if evicted:
            telemetry.metric_count(f"{self._prefix}_index_cache_evictions_total", evicted)
        telemetry.metric_gauge(f"{self._prefix}_index_cache_entries", size)
        return idx

    def get_chunk(
        self, blob: bytes, index: int, verify: str = "strict"
    ) -> Optional[np.ndarray]:
        """The decoded array for chunk ``index``, or None on miss.

        Hits return the cached read-only array directly (no copy) — callers
        that need to mutate copy on their side.
        """
        key = (blob_key(blob), int(index), verify)
        with self._lock:
            arr = self._chunks.get(key)
            if arr is not None:
                self._chunks.move_to_end(key)
                self.chunk_hits += 1
            else:
                self.chunk_misses += 1
        telemetry.metric_count(
            f"{self._prefix}_chunk_cache_{'hits' if arr is not None else 'misses'}_total"
        )
        return arr

    def put_chunk(
        self, blob: bytes, index: int, arr: np.ndarray, verify: str = "strict"
    ) -> None:
        nbytes = int(arr.nbytes)
        if nbytes > self.max_chunk_bytes:  # never evict everything for one entry
            return
        arr = np.asarray(arr)
        arr.setflags(write=False)
        key = (blob_key(blob), int(index), verify)
        evicted = 0
        with self._lock:
            old = self._chunks.pop(key, None)
            if old is not None:
                self._chunk_bytes -= old.nbytes
            self._chunks[key] = arr
            self._chunk_bytes += nbytes
            while self._chunk_bytes > self.max_chunk_bytes and self._chunks:
                _, dropped = self._chunks.popitem(last=False)
                self._chunk_bytes -= dropped.nbytes
                self.chunk_evictions += 1
                evicted += 1
            total = self._chunk_bytes
        if evicted:
            telemetry.metric_count(
                f"{self._prefix}_chunk_cache_evictions_total", evicted
            )
        telemetry.metric_gauge(f"{self._prefix}_chunk_cache_bytes", total)

    def invalidate(self, blob: bytes) -> None:
        key = blob_key(blob)
        with self._lock:
            self._entries.pop(key, None)
            for ck in [k for k in self._chunks if k[0] == key]:
                self._chunk_bytes -= self._chunks.pop(ck).nbytes
            size = len(self._entries)
            total = self._chunk_bytes
        telemetry.metric_gauge(f"{self._prefix}_index_cache_entries", size)
        telemetry.metric_gauge(f"{self._prefix}_chunk_cache_bytes", total)

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._chunks.clear()
            self._chunk_bytes = 0
        telemetry.metric_gauge(f"{self._prefix}_index_cache_entries", 0)
        telemetry.metric_gauge(f"{self._prefix}_chunk_cache_bytes", 0)

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "entries": len(self._entries),
                "max_entries": self.max_entries,
                "chunk_hits": self.chunk_hits,
                "chunk_misses": self.chunk_misses,
                "chunk_evictions": self.chunk_evictions,
                "chunk_entries": len(self._chunks),
                "chunk_bytes": self._chunk_bytes,
                "max_chunk_bytes": self.max_chunk_bytes,
            }


# ---------------------------------------------------------------------------
# executor-side work (module-level so ProcessPoolExecutor can pickle them)
# ---------------------------------------------------------------------------

#: per-process decode-state cache for ``executor="process"`` workers — each
#: worker process keeps its own bounded index LRU (the parent's cache object
#: is not shared across fork/spawn boundaries)
_WORKER_CACHE: Optional[DecodeStateCache] = None


def _process_cache() -> DecodeStateCache:
    global _WORKER_CACHE
    if _WORKER_CACHE is None:
        _WORKER_CACHE = DecodeStateCache(max_entries=32)
    return _WORKER_CACHE


def _compress_page(
    arr: np.ndarray,
    mode_value: str,
    eb: float,
    candidates: Optional[Sequence[str]],
    chunk_bytes: int,
) -> bytes:
    """Compress one page into a v2 chunked container (executor job)."""
    conf = CompressionConfig(mode=ErrorBoundMode(mode_value), eb=eb)
    comp = sz3_chunked(
        candidates=tuple(candidates) if candidates else DEFAULT_CANDIDATES,
        chunk_bytes=chunk_bytes,
    )
    return comp.compress(arr, conf).blob


def _fetch_batch(
    blob: bytes,
    chunks: Sequence[Optional[int]],
    verify: str,
    cache: Optional[DecodeStateCache] = None,
) -> List[Tuple[Any, ...]]:
    """Decode the requested chunk indices of one container (executor job).

    ``chunks`` entries are chunk indices, or None for a whole-page decode.
    Returns one entry per request — ``("ok", array)`` or
    ``("err", type_name, message, chunk_index)`` — so a damaged chunk fails
    only the request that asked for it.
    """
    cache = cache if cache is not None else _process_cache()
    try:
        parsed = cache.index_for(blob)
        if verify == "strict":
            if parsed.header.get("itg") and parsed.algo is None:
                raise IntegrityError(
                    "header advertises an integrity trailer but none is "
                    "present (trailer stripped or truncated)",
                    region="trailer",
                )
            if not parsed.header_ok:
                raise IntegrityError(
                    "container header fails its checksum", region="header"
                )
    except ValueError as e:
        # header-level failure: every request targeted this container
        err = ("err", type(e).__name__, str(e), getattr(e, "chunk_index", None))
        return [err for _ in chunks]
    out: List[Tuple[Any, ...]] = []
    for c in chunks:
        try:
            if c is None:
                arr = pl_mod.decompress(blob, verify=verify)
            else:
                arr = cache.get_chunk(blob, int(c), verify)
                if arr is None:
                    arr = decompress_chunk(
                        blob, int(c), verify=verify, parsed=parsed
                    )
                    cache.put_chunk(blob, int(c), arr, verify)
            out.append(("ok", arr))
        except ValueError as e:
            out.append(
                ("err", type(e).__name__, str(e), getattr(e, "chunk_index", None))
            )
    return out


# ---------------------------------------------------------------------------
# the service
# ---------------------------------------------------------------------------

@dataclass
class _Request:
    tenant: str
    page: str
    chunk: Optional[int]
    future: "asyncio.Future[np.ndarray]"
    t_enqueue: float = field(default_factory=time.perf_counter)


_SHUTDOWN = object()


class OffloadService:
    """Async compress/fetch/evict service over named KV pages.

    Parameters
    ----------
    workers:
        Executor pool size (compress and decode jobs share it).
    executor:
        ``"thread"`` (default — numpy/zlib release the GIL, and the decode
        cache is shared in-process) or ``"process"`` (true multi-core for
        pure-Python-bound profiles; each worker keeps its own cache).
    cache_entries / cache_chunk_bytes:
        Bounds on the decode-state LRU: ``cache_entries`` caps the
        parsed-index layer (one entry is a header dict + chunk table —
        kilobytes — so hundreds are cheap; a miss costs a msgpack parse +
        trailer scan), and ``cache_chunk_bytes`` budgets the decoded-chunk
        layer in bytes (a hot-chunk hit skips the entropy decode entirely —
        the dominant per-fetch cost; 0 disables result caching).
    coalesce_ms / max_batch:
        Fetches arriving within ``coalesce_ms`` of the first are drained
        (up to ``max_batch``) and grouped by page into one executor job per
        page.  Raising ``coalesce_ms`` trades first-byte latency for fewer,
        larger jobs.
    eb / mode / candidates / chunk_bytes:
        Compression policy for :meth:`put` (the v2 chunked engine).
    verify:
        Decode-side verify policy: ``"strict"`` checks the header CRC plus
        the requested chunk's CRC on every fetch (O(chunk), see
        ``decompress_chunk``); ``"off"`` trusts the bytes.
    """

    def __init__(
        self,
        workers: int = 4,
        executor: str = "thread",
        cache_entries: int = 64,
        cache_chunk_bytes: int = 32 << 20,
        coalesce_ms: float = 2.0,
        max_batch: int = 32,
        eb: float = 1e-3,
        mode: ErrorBoundMode = ErrorBoundMode.ABS,
        candidates: Optional[Sequence[str]] = None,
        chunk_bytes: int = 1 << 16,
        verify: str = "strict",
    ):
        if executor not in ("thread", "process"):
            raise ValueError("executor must be 'thread' or 'process'")
        if verify not in ("strict", "off"):
            raise ValueError("verify must be 'strict' or 'off'")
        self.workers = max(1, int(workers))
        self.executor_kind = executor
        self.coalesce_ms = float(coalesce_ms)
        self.max_batch = max(1, int(max_batch))
        self.eb = float(eb)
        self.mode = mode
        self.candidates = tuple(candidates) if candidates else None
        self.chunk_bytes = int(chunk_bytes)
        self.verify = verify
        self.cache = DecodeStateCache(cache_entries, cache_chunk_bytes)
        self._pages: Dict[Tuple[str, str], bytes] = {}
        self._executor: Optional[Executor] = None
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._queue: Optional["asyncio.Queue[Any]"] = None
        self._dispatcher: Optional["asyncio.Task[None]"] = None
        self._deliveries: "set[asyncio.Task[None]]" = set()
        self._closed = False

    # -- lifecycle ----------------------------------------------------------

    def _ensure_started(self) -> asyncio.AbstractEventLoop:
        if self._closed:
            raise RuntimeError("OffloadService is closed")
        loop = asyncio.get_running_loop()
        if self._loop is not loop:
            # first use, or a new asyncio.run() — rebind queue + dispatcher
            self._loop = loop
            self._queue = asyncio.Queue()
            self._dispatcher = loop.create_task(self._dispatch_loop())
        if self._executor is None:
            if self.executor_kind == "process":
                # spawn, not fork: the host process is multithreaded (asyncio
                # + jax) and fork-with-threads can deadlock in the child
                self._executor = ProcessPoolExecutor(
                    max_workers=self.workers,
                    mp_context=multiprocessing.get_context("spawn"),
                )
            else:
                self._executor = ThreadPoolExecutor(
                    max_workers=self.workers, thread_name_prefix="sz3-serve"
                )
        return loop

    async def close(self) -> None:
        """Drain and stop: pending deliveries finish, the dispatcher exits,
        and the executor shuts down.  Pages and caches stay readable via a
        later event loop only by constructing a new service."""
        if self._closed:
            return
        self._closed = True
        if self._dispatcher is not None and self._queue is not None:
            await self._queue.put(_SHUTDOWN)
            try:
                await self._dispatcher
            except asyncio.CancelledError:
                pass
        if self._deliveries:
            await asyncio.gather(*tuple(self._deliveries), return_exceptions=True)
        if self._executor is not None:
            self._executor.shutdown(wait=True)
            self._executor = None

    async def __aenter__(self) -> "OffloadService":
        return self

    async def __aexit__(self, *exc) -> None:
        await self.close()

    # -- requests -----------------------------------------------------------

    async def put(self, tenant: str, page: str, data: np.ndarray) -> Dict[str, Any]:
        """Compress ``data`` on the pool and register it as ``(tenant, page)``.

        Returns the offload report: source bytes (the array's OWN dtype —
        see the ratio-accounting fix in ``launch.serve``), container bytes,
        ratio, and chunk count.
        """
        loop = self._ensure_started()
        t0 = time.perf_counter()
        arr = np.ascontiguousarray(np.asarray(data))
        blob = await loop.run_in_executor(
            self._executor,
            _compress_page,
            arr,
            self.mode.value,
            self.eb,
            self.candidates,
            self.chunk_bytes,
        )
        return self._register(tenant, page, blob, n_in=arr.nbytes, t0=t0)

    async def put_compressed(
        self, tenant: str, page: str, blob: bytes, n_in: Optional[int] = None
    ) -> Dict[str, Any]:
        """Register a pre-built v2/v4 container as ``(tenant, page)``.

        The framing and header are validated here (malformed containers are
        rejected at admission); chunk *bodies* are not decoded, so a
        fault-injected chunk is accepted and surfaces later, at fetch time,
        to exactly the request that reads it.
        """
        self._ensure_started()
        t0 = time.perf_counter()
        parse_chunked_index(blob, verify="off")  # admission check: framing only
        return self._register(tenant, page, bytes(blob), n_in=n_in, t0=t0)

    def _register(
        self,
        tenant: str,
        page: str,
        blob: bytes,
        n_in: Optional[int],
        t0: float,
    ) -> Dict[str, Any]:
        old = self._pages.get((tenant, page))
        if old is not None:
            self.cache.invalidate(old)
        self._pages[(tenant, page)] = blob
        idx = self.cache.index_for(blob)  # warm the index cache at admission
        dt = time.perf_counter() - t0
        telemetry.metric_count("sz3_serve_puts_total")
        telemetry.metric_observe("sz3_serve_put_seconds", dt)
        telemetry.metric_gauge("sz3_serve_pages", len(self._pages))
        report: Dict[str, Any] = {
            "tenant": tenant,
            "page": page,
            "chunks": idx.n_chunks,
            "n_out": len(blob),
            "seconds": dt,
        }
        if n_in is not None:
            report["n_in"] = int(n_in)
            report["ratio"] = int(n_in) / max(1, len(blob))
        return report

    async def fetch(
        self, tenant: str, page: str, chunk: Optional[int] = None
    ) -> np.ndarray:
        """Fetch one chunk (or, with ``chunk=None``, the whole page).

        Enqueues into the coalescing dispatcher; resolves with the decoded
        array or raises :class:`OffloadError` scoped to this request.
        """
        loop = self._ensure_started()
        key = (tenant, page)
        if key not in self._pages:
            telemetry.metric_count("sz3_serve_errors_total")
            raise OffloadError(
                f"unknown page {tenant}/{page}", tenant=tenant, page=page, chunk=chunk
            )
        req = _Request(tenant, page, chunk, loop.create_future())
        telemetry.metric_gauge_add("sz3_serve_queue_depth", 1)
        assert self._queue is not None
        await self._queue.put(req)
        try:
            return await req.future
        finally:
            telemetry.metric_observe(
                "sz3_serve_request_seconds", time.perf_counter() - req.t_enqueue
            )

    async def evict(self, tenant: str, page: str) -> bool:
        """Drop a page and its cached decode state; True if it existed."""
        self._ensure_started()
        blob = self._pages.pop((tenant, page), None)
        if blob is None:
            return False
        self.cache.invalidate(blob)
        telemetry.metric_count("sz3_serve_evictions_total")
        telemetry.metric_gauge("sz3_serve_pages", len(self._pages))
        return True

    def stats(self) -> Dict[str, Any]:
        return {
            "pages": len(self._pages),
            "index_cache": self.cache.stats(),
            "huffman_table_cache": encoders.table_cache_stats(),
        }

    # -- dispatcher ---------------------------------------------------------

    async def _dispatch_loop(self) -> None:
        assert self._queue is not None
        while True:
            first = await self._queue.get()
            if first is _SHUTDOWN:
                break
            batch: List[_Request] = [first]
            if self.coalesce_ms > 0:
                await asyncio.sleep(self.coalesce_ms / 1000.0)
            while len(batch) < self.max_batch:
                try:
                    nxt = self._queue.get_nowait()
                except asyncio.QueueEmpty:
                    break
                if nxt is _SHUTDOWN:
                    await self._queue.put(_SHUTDOWN)  # re-post for the outer loop
                    break
                batch.append(nxt)
            self._dispatch_batch(batch)

    def _dispatch_batch(self, batch: List[_Request]) -> None:
        assert self._loop is not None
        groups: "OrderedDict[Tuple[str, str], List[_Request]]" = OrderedDict()
        for r in batch:
            groups.setdefault((r.tenant, r.page), []).append(r)
        telemetry.metric_count("sz3_serve_batches_total")
        telemetry.metric_count("sz3_serve_batched_requests_total", len(batch))
        for (tenant, page), reqs in groups.items():
            blob = self._pages.get((tenant, page))
            if blob is None:  # evicted between enqueue and dispatch
                for r in reqs:
                    self._fail(
                        r,
                        OffloadError(
                            f"page {tenant}/{page} evicted while queued",
                            tenant=tenant,
                            page=page,
                            chunk=r.chunk,
                        ),
                    )
                continue
            cache_arg = self.cache if self.executor_kind == "thread" else None
            job = self._loop.run_in_executor(
                self._executor,
                _fetch_batch,
                blob,
                [r.chunk for r in reqs],
                self.verify,
                cache_arg,
            )
            task = self._loop.create_task(self._deliver(reqs, job))
            self._deliveries.add(task)
            task.add_done_callback(self._deliveries.discard)

    async def _deliver(self, reqs: List[_Request], job: "asyncio.Future") -> None:
        try:
            results = await job
        except Exception as e:  # executor-level failure (e.g. broken pool)
            for r in reqs:
                self._fail(
                    r,
                    OffloadError(
                        f"fetch job failed: {type(e).__name__}: {e}",
                        tenant=r.tenant,
                        page=r.page,
                        chunk=r.chunk,
                        cause_type=type(e).__name__,
                    ),
                )
            return
        for r, res in zip(reqs, results):
            telemetry.metric_gauge_add("sz3_serve_queue_depth", -1)
            if r.future.done():
                continue
            if res[0] == "ok":
                r.future.set_result(res[1])
            else:
                _tag, cause, msg, chunk_index = res
                telemetry.metric_count("sz3_serve_errors_total")
                r.future.set_exception(
                    OffloadError(
                        f"fetch {r.tenant}/{r.page}"
                        f"[{'*' if r.chunk is None else r.chunk}] failed: "
                        f"{cause}: {msg}",
                        tenant=r.tenant,
                        page=r.page,
                        chunk=r.chunk,
                        cause_type=cause,
                        chunk_index=chunk_index,
                    )
                )

    def _fail(self, r: _Request, err: OffloadError) -> None:
        telemetry.metric_gauge_add("sz3_serve_queue_depth", -1)
        telemetry.metric_count("sz3_serve_errors_total")
        if not r.future.done():
            r.future.set_exception(err)
