"""Serve-step factory: jitted single-token decode with sharded (optionally
int8-quantized) caches.

Cache sharding: batch over the DP axes, kv-heads / ssm-heads over the model
axis (when divisible), ring dimension unsharded.  Parameters use the same
spec tree as training (incl. FSDP axes — per-layer gather streams inside the
layer scan).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .. import models
from ..models.common import ModelConfig
from ..parallel.plan import ParallelPlan
from ..parallel.specs import heads_shardable, param_specs


def cache_specs(cache, cfg: ModelConfig, plan: ParallelPlan):
    if plan.mesh is None:
        return jax.tree.map(lambda _: P(), cache)
    b = plan.b
    m = plan.model_axis if heads_shardable(cfg, plan) else None
    ms = plan.model_axis  # ssm dims use their own divisibility

    def spec(path, leaf):
        names = [
            p.name if hasattr(p, "name") else getattr(p, "key", str(p))
            for p in path
        ]
        last = names[-1]
        nd = leaf.ndim
        if last in ("k", "v", "cross_k", "cross_v"):
            return P(None, b, None, m, None)
        if last in ("k_scale", "v_scale"):
            return P(None, b, None, m)
        if last == "pos":
            return P(b, None)
        if last == "ssm":  # (L, B, H, P, N)
            h = cfg.ssm_heads
            return P(None, b, ms if h % plan.tp == 0 else None, None, None)
        if last == "conv":  # (L, B, K-1, C)
            c = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
            return P(None, b, None, ms if c % plan.tp == 0 else None)
        return P()

    return jax.tree_util.tree_map_with_path(spec, cache)


def make_serve_step(cfg: ModelConfig, plan: ParallelPlan):
    def serve_step(params, cache, tokens):
        return models.decode_step(params, cache, tokens, cfg, plan)

    return serve_step


def jit_serve_step(serve_step, params, cache, cfg: ModelConfig, plan: ParallelPlan):
    if plan.mesh is None:
        return jax.jit(serve_step)
    pspecs = param_specs(params, cfg, plan)
    cspecs = cache_specs(cache, cfg, plan)
    tok_spec = P(plan.b, None)
    sh = lambda tree: jax.tree.map(
        lambda s: jax.NamedSharding(plan.mesh, s) if isinstance(s, P) else s,
        tree,
        is_leaf=lambda s: isinstance(s, P),
    )
    logits_spec = P(plan.b, plan.model_axis if cfg.padded_vocab % max(1, plan.tp) == 0 else None)
    # logits sliced to cfg.vocab (may not divide TP) -> leave unsharded
    logits_spec = P(plan.b, None)
    return jax.jit(
        serve_step,
        in_shardings=(sh(pspecs), sh(cspecs), sh(tok_spec)),
        out_shardings=(sh(logits_spec), sh(cspecs)),
        donate_argnums=(1,),
    )
