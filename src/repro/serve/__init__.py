"""Serving layer: jitted decode steps (:mod:`.step`, needs jax) and the
async multi-tenant KV-offload service (:mod:`.offload`, numpy-only).

Only the offload service is imported eagerly — ``step`` pulls the model
stack and is imported by the launchers that need it.
"""
from .offload import (  # noqa: F401
    DecodeStateCache,
    OffloadError,
    OffloadService,
    blob_key,
)

__all__ = [
    "DecodeStateCache",
    "OffloadError",
    "OffloadService",
    "blob_key",
]
