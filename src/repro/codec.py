"""numcodecs-compatible codec facade: SZ3 as a drop-in array-store filter.

``Sz3Codec`` wraps the whole pipeline zoo behind the three-method protocol
(``encode`` / ``decode`` / ``get_config``) that zarr, numcodecs filter
chains, and anything else speaking the `numcodecs.abc.Codec` contract
expect.  The container is the ordinary self-describing SZ3 blob, so bytes
written through the codec decode with plain :func:`repro.core.decompress`
and vice versa — the codec adds vocabulary, not format.

numcodecs itself is OPTIONAL: when it is importable the codec subclasses
``numcodecs.abc.Codec`` and registers under ``codec_id="repro.sz3"`` (zarr
can then resolve it from stored metadata); without it the same class still
works standalone with an identical API.

    >>> codec = Sz3Codec(eb_mode="abs", eb_abs=1e-3, predictor="fast")
    >>> buf = codec.encode(np.arange(1e6, dtype=np.float32))
    >>> out = codec.decode(buf)
    >>> codec2 = Sz3Codec.from_config(codec.get_config())  # round-trips

Vocabulary: ``eb_mode`` picks the bound family (``abs``, ``rel``,
``pw_rel``, ``abs-and-rel``, ``abs-or-rel``, or ``psnr`` for the quality-
targeted controller), ``eb_abs`` / ``eb_rel`` / ``eb_psnr`` carry the
numbers, and ``predictor`` names the engine (friendly aliases or full
``sz3_*`` pipeline names).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import numpy as np

from .core import CompressionConfig, ErrorBoundMode
from .core import pipeline as pl_mod
from .core.pipeline import decompress as sz3_decompress

try:  # numcodecs is optional: the codec degrades to a plain class without it
    from numcodecs.abc import Codec as _CodecBase
    from numcodecs.registry import register_codec as _register_codec

    _HAVE_NUMCODECS = True
except Exception:  # pragma: no cover - exercised where numcodecs is absent
    _CodecBase = object
    _register_codec = None
    _HAVE_NUMCODECS = False

#: friendly predictor aliases -> registered pipeline factory names (the full
#: ``sz3_*`` names are accepted verbatim as well)
_PREDICTOR_ALIASES = {
    "auto": "sz3_auto",
    "fast": "sz3_fast",
    "chunked": "sz3_chunked",
    "hybrid": "sz3_hybrid",
    "lorenzo": "sz3_lorenzo",
    "lr": "sz3_lr",
    "interp": "sz3_interp",
    "transform": "sz3_transform",
    "pwr": "sz3_pwr",
}

_EB_MODES = ("abs", "rel", "pw_rel", "abs-and-rel", "abs-or-rel", "psnr")


class Sz3Codec(_CodecBase):
    """SZ3 error-bounded lossy compression as a numcodecs-style codec.

    Parameters
    ----------
    eb_mode:
        Bound family — one of ``abs``, ``rel``, ``pw_rel``, ``abs-and-rel``,
        ``abs-or-rel`` (both composite modes need ``eb_abs`` AND ``eb_rel``),
        or ``psnr`` (quality-targeted; needs ``eb_psnr``).
    eb_abs / eb_rel / eb_psnr:
        The bound numbers for the selected mode.
    predictor:
        Engine name: an alias from ``auto / fast / chunked / hybrid /
        lorenzo / lr / interp / transform / pwr`` or any registered
        ``sz3_*`` pipeline name.
    """

    codec_id = "repro.sz3"

    def __init__(
        self,
        eb_mode: str = "abs",
        eb_abs: float = 1e-3,
        eb_rel: Optional[float] = None,
        eb_psnr: Optional[float] = None,
        predictor: str = "auto",
    ):
        if eb_mode not in _EB_MODES:
            raise ValueError(
                f"eb_mode must be one of {_EB_MODES}, got {eb_mode!r}"
            )
        pname = _PREDICTOR_ALIASES.get(predictor, predictor)
        if pname not in pl_mod.PIPELINES:
            raise ValueError(
                f"unknown predictor {predictor!r} (aliases: "
                f"{sorted(_PREDICTOR_ALIASES)}; registered pipelines: "
                f"{sorted(pl_mod.PIPELINES)})"
            )
        if eb_mode in ("abs-and-rel", "abs-or-rel") and eb_rel is None:
            raise ValueError(f"eb_mode {eb_mode!r} needs eb_rel as well")
        if eb_mode == "psnr" and eb_psnr is None:
            raise ValueError("eb_mode 'psnr' needs eb_psnr")
        self.eb_mode = eb_mode
        self.eb_abs = float(eb_abs)
        self.eb_rel = None if eb_rel is None else float(eb_rel)
        self.eb_psnr = None if eb_psnr is None else float(eb_psnr)
        self.predictor = predictor
        self._pname = pname

    # -- engine construction --------------------------------------------------
    def _conf(self) -> CompressionConfig:
        if self.eb_mode == "abs":
            return CompressionConfig(mode=ErrorBoundMode.ABS, eb=self.eb_abs)
        if self.eb_mode == "rel":
            # REL carries the fraction in eb (matches CompressionConfig)
            eb = self.eb_rel if self.eb_rel is not None else self.eb_abs
            return CompressionConfig(mode=ErrorBoundMode.REL, eb=eb)
        if self.eb_mode == "pw_rel":
            eb = self.eb_rel if self.eb_rel is not None else self.eb_abs
            return CompressionConfig(mode=ErrorBoundMode.PW_REL, eb=eb)
        return CompressionConfig(
            mode=ErrorBoundMode(self.eb_mode), eb=self.eb_abs,
            eb_rel=self.eb_rel,
        )

    def _engine(self):
        if self.eb_mode == "psnr":
            from .core import sz3_quality

            return sz3_quality(
                target_psnr=self.eb_psnr,
                **(
                    {}
                    if self.predictor in ("auto", "sz3_auto")
                    else {"candidates": (self._pname,)}
                ),
            )
        factory = pl_mod.PIPELINES[self._pname]
        if self._pname == "sz3_pwr":
            return factory(eb=self.eb_rel if self.eb_rel is not None else self.eb_abs)
        return factory()

    # -- numcodecs protocol ---------------------------------------------------
    def encode(self, buf) -> bytes:
        data = np.asarray(buf)
        if data.dtype.kind not in "fiu":
            raise TypeError(
                f"Sz3Codec encodes numeric arrays, got dtype {data.dtype}"
            )
        conf = None if self.eb_mode == "psnr" else self._conf()
        if self.eb_mode == "pw_rel" and self._pname not in (
            "sz3_pwr", "sz3_auto", "sz3_chunked", "sz3_hybrid", "sz3_fast",
        ):
            # route pointwise-relative requests through the native engine
            # rather than a per-pipeline over-bound
            from .core import sz3_pwr

            return bytes(sz3_pwr(eb=conf.eb).compress(data, conf).blob)
        engine = self._engine()
        if self.eb_mode == "psnr":
            return bytes(engine.compress(data).blob)
        return bytes(engine.compress(data, conf).blob)

    def decode(self, buf, out=None):
        data = sz3_decompress(bytes(buf))
        if out is None:
            return data
        out_arr = (
            out
            if isinstance(out, np.ndarray)
            else np.frombuffer(out, dtype=data.dtype)
        )
        view = out_arr.reshape(-1).view(data.dtype)
        np.copyto(view[: data.size], data.reshape(-1), casting="no")
        return out

    # -- config round-trip ----------------------------------------------------
    def get_config(self) -> Dict[str, Any]:
        return {
            "id": self.codec_id,
            "eb_mode": self.eb_mode,
            "eb_abs": self.eb_abs,
            "eb_rel": self.eb_rel,
            "eb_psnr": self.eb_psnr,
            "predictor": self.predictor,
        }

    @classmethod
    def from_config(cls, config: Dict[str, Any]) -> "Sz3Codec":
        config = dict(config)
        config.pop("id", None)
        return cls(**config)

    def __repr__(self) -> str:
        parts = [f"eb_mode={self.eb_mode!r}", f"eb_abs={self.eb_abs!r}"]
        if self.eb_rel is not None:
            parts.append(f"eb_rel={self.eb_rel!r}")
        if self.eb_psnr is not None:
            parts.append(f"eb_psnr={self.eb_psnr!r}")
        parts.append(f"predictor={self.predictor!r}")
        return f"{type(self).__name__}({', '.join(parts)})"


if _HAVE_NUMCODECS:  # make "repro.sz3" resolvable from stored zarr metadata
    _register_codec(Sz3Codec)
