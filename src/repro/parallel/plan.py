"""Parallelism plan: how one model instance maps onto a device mesh.

Axes convention (DESIGN.md §5):
  pod    — data-parallel replicas across pods (also the optional PP axis)
  data   — data parallel + FSDP parameter sharding within a pod
  model  — tensor parallel (heads / d_ff / vocab) and expert parallel

The plan is threaded through model code; every sharding decision goes through
``ps()`` / ``constrain()`` so a single-device run (mesh=None) is the same code
path with constraints elided.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from . import compat


@dataclasses.dataclass(frozen=True)
class ParallelPlan:
    mesh: Optional[Mesh] = None
    batch_axes: Tuple[str, ...] = ("data",)  # batch dim sharding
    model_axis: Optional[str] = "model"  # TP/EP axis
    fsdp_axes: Tuple[str, ...] = ()  # ZeRO-3 param sharding axes
    seq_axes: Tuple[str, ...] = ()  # sequence/context parallel axes
    remat: str = "full"  # "none" | "full" | "dots"
    microbatches: int = 1  # gradient-accumulation steps
    kv_cache_dtype: str = "bf16"  # "bf16" | "int8" (paper-technique lever)
    grad_compress_bits: int = 0  # 0 = off; 8/4 = error-bounded grad quant
    grad_policy: str = ""  # full jit-codec policy spec for the DP grad
    # reduction (e.g. "int8:eb=1e-6:bs=512:pred=zero+lorenzo1+mean");
    # wins over grad_compress_bits when set
    # §Perf levers (default off = paper-faithful baseline):
    bwd_cast_bf16: bool = False  # cast activation cotangents to bf16 at block
    # boundaries -> backward TP all-reduces run at half width
    grad_accum_dtype: str = "float32"  # bf16 halves the per-microbatch
    # gradient reduce-scatter wire bytes (and the accumulator memory)
    manual_tp_psum: bool = False  # replace partitioner-chosen TP reductions
    # with explicit shard_map psums on bf16 values (XLA-CPU otherwise
    # all-reduces the f32 pre-convert dot accumulator: 2x wire bytes)
    decode_feature_shard: bool = False  # shard the feature dim over the fsdp
    # axis at decode: matmuls partial-sum tiny activations instead of
    # all-gathering the full weight shards every token (weight-stationary)

    def grad_compression(self):
        """The resolved gradient-compression JitPolicy, or None when off."""
        if self.grad_policy:
            from ..compression.grad import as_policy

            return as_policy(self.grad_policy)
        if self.grad_compress_bits:
            from ..compression.grad import as_policy

            return as_policy(self.grad_compress_bits)
        return None

    # -- mesh facts ----------------------------------------------------------
    def axis_size(self, name: Optional[str]) -> int:
        if self.mesh is None or name is None or name not in self.mesh.shape:
            return 1
        return self.mesh.shape[name]

    @property
    def tp(self) -> int:
        return self.axis_size(self.model_axis)

    @property
    def dp(self) -> int:
        return math.prod(self.axis_size(a) for a in self.batch_axes)

    def kv_repeat(self, n_kv: int, n_q: int = None) -> int:
        """Virtual KV-head duplication so kv-heads shard evenly over TP
        (GQA -> wider GQA; mathematically identical, standard TP practice).
        Only applied when the duplicated head count still divides the query
        heads (whisper's 12 heads on TP=16 stay unduplicated + unsharded)."""
        tp = self.tp
        if tp <= 1 or n_kv % tp == 0:
            return 1
        rep = math.lcm(n_kv, tp) // n_kv
        if n_q is not None and (n_q % (n_kv * rep) != 0 or n_q % tp != 0):
            return 1
        return rep

    @property
    def b(self):
        """Batch-dim spec entry: tuple of axes, single axis, or None.

        Empty batch_axes (inside a dp-manual shard_map region) => None so
        constraints never mention manual axes."""
        if not self.batch_axes:
            return None
        return self.batch_axes if len(self.batch_axes) > 1 else self.batch_axes[0]

    # -- spec builders -------------------------------------------------------
    def ps(self, *axes) -> PartitionSpec:
        """Build a PartitionSpec; each arg is a mesh-axis name, a tuple of
        names, or None."""
        if self.mesh is None:
            return PartitionSpec()
        return PartitionSpec(*axes)

    def batch_spec(self, *rest) -> PartitionSpec:
        return self.ps(self.b, *rest)

    def sharding(self, spec: PartitionSpec) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, spec)

    def constrain(self, x, spec: PartitionSpec):
        if self.mesh is None:
            return x
        # inside a (partially-)manual shard_map region the constraint must be
        # built against the ambient abstract mesh, not the concrete one
        mesh = self.smap_mesh()
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    # -- common activation constraints ----------------------------------------
    def act_btd(self, x):
        """(batch, seq, d_model): batch over DP axes, seq optionally SP,
        features over the fsdp axis in weight-stationary decode mode."""
        s = (
            (self.seq_axes if len(self.seq_axes) > 1 else self.seq_axes[0])
            if self.seq_axes
            else None
        )
        if self.decode_feature_shard and self.fsdp_axes:
            # weight-stationary decode: the residual stream shards its
            # FEATURE dim over the fsdp axis (batch left unsharded here —
            # it is tiny; caches keep batch sharding) so contractions
            # partial-sum activations instead of gathering weight shards.
            f = self.fsdp_axes if len(self.fsdp_axes) > 1 else self.fsdp_axes[0]
            x = self.constrain(x, self.ps(None, s, f))
        else:
            x = self.constrain(x, self.ps(self.b, s, None))
        if self.bwd_cast_bf16:
            x = _bf16_grad_barrier(x)
        return x

    def grad_barrier(self, x):
        """Cast the cotangent flowing backward through this point to bf16.

        Placed at layer-block ENTRY so the backward layer scan carries a
        bf16 residual cotangent — every per-layer TP collective in the
        backward pass then runs at half width (§Perf hypothesis P3)."""
        if self.bwd_cast_bf16:
            return _bf16_grad_barrier(x)
        return x

    def smap_mesh(self):
        """Mesh for nested shard_map: the ambient (possibly partially-manual)
        abstract mesh when inside another manual region, else the plan's."""
        am = compat.get_abstract_mesh()
        if am is not None and not getattr(am, "empty", True):
            return am
        return self.mesh

    def tp_project(self, h, w, shardable: bool = True):
        """Output projection h @ w with an EXPLICIT bf16 TP psum.

        h: (..., F) with F sharded over model; w: (F, D) rows sharded over
        model.  The local dot's result is cast to h.dtype BEFORE the psum so
        the reduction wire width is the model dtype — the auto partitioner
        (XLA-CPU especially) otherwise reduces the f32 dot accumulator."""
        import jax.numpy as jnp
        from jax.sharding import PartitionSpec as P

        if (
            not self.manual_tp_psum
            or self.mesh is None
            or self.tp == 1
            or not shardable
        ):
            return h @ w
        m = self.model_axis
        nd = h.ndim
        # manual over ALL dp axes too (partial-manual shard_map under
        # remat+scan trips an XLA-CPU partitioner bug — same workaround as
        # the MoE dispatch); w is all-gathered over fsdp at region entry,
        # which IS the usual FSDP gather.
        manual = {m} | set(self.batch_axes) | set(self.fsdp_axes)

        def f(hl, wl):
            y = hl @ wl
            return jax.lax.psum(y.astype(hl.dtype), m)

        in_h = P(*((self.b,) + (None,) * (nd - 2) + (m,)))
        out = P(*((self.b,) + (None,) * (nd - 1)))
        return compat.shard_map(
            f,
            self.smap_mesh(),
            axis_names=manual,
            in_specs=(in_h, P(m, None)),
            out_specs=out,
            check_vma=False,
        )(h, w)

    def act_heads(self, x, shardable: bool = True):
        """(batch, seq, heads, head_dim): heads over TP (when divisible)."""
        m = self.model_axis if shardable else None
        return self.constrain(x, self.ps(self.b, None, m, None))


def single_device_plan(**kw) -> ParallelPlan:
    return ParallelPlan(mesh=None, **kw)


import functools as _functools


@_functools.lru_cache(maxsize=None)
def _barrier_for(dtype_name: str):
    import jax.numpy as jnp

    dt = jnp.dtype(dtype_name)

    @jax.custom_vjp
    def barrier(x):
        return x

    def fwd(x):
        return x, None

    def bwd(_, ct):
        # round the cotangent through bf16: every collective in this
        # activation-gradient's upstream path runs at half width
        return (ct.astype(jnp.bfloat16).astype(dt),)

    barrier.defvjp(fwd, bwd)
    return barrier


def _bf16_grad_barrier(x):
    return _barrier_for(str(x.dtype))(x)
