"""Parameter PartitionSpec trees (TP over ``model``, FSDP over ``data``).

Name-based dispatch over the param tree paths; stacked layer dims get leading
``None``s automatically.  Head sharding is only applied when the (virtual)
head counts divide the TP size — otherwise attention weights fall back to
FSDP-only sharding (whisper's 12 heads on TP=16; the MLP/vocab dims still
shard).  See DESIGN.md §5.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import PartitionSpec as P

from ..models.common import ModelConfig
from ..models.layers import attn_dims
from .plan import ParallelPlan


def heads_shardable(cfg: ModelConfig, plan: ParallelPlan) -> bool:
    if cfg.n_heads == 0:
        return True
    dims = attn_dims(cfg, plan)
    tp = plan.tp
    return dims.n_q % tp == 0 and dims.n_kv % tp == 0


def _fsdp(plan: ParallelPlan) -> Optional[Any]:
    if not plan.fsdp_axes:
        return None
    return plan.fsdp_axes if len(plan.fsdp_axes) > 1 else plan.fsdp_axes[0]


def param_specs(params, cfg: ModelConfig, plan: ParallelPlan):
    """PartitionSpec pytree matching ``params``."""
    if plan.mesh is None:
        return jax.tree.map(lambda _: P(), params)
    m = plan.model_axis
    f = _fsdp(plan)
    hs = heads_shardable(cfg, plan)

    def spec(path, leaf) -> P:
        names = [
            p.key if isinstance(p, jax.tree_util.DictKey) else str(p) for p in path
        ]
        last = names[-1]
        nd = leaf.ndim

        def pad(*tail) -> P:
            """Left-pad with Nones for stacked layer/group dims."""
            lead = nd - len(tail)
            return P(*((None,) * lead + tail))

        if last in ("embed",):
            return P(m, f)
        if last in ("lm_head",):
            return P(f, m)
        if last in ("wq", "wk", "wv"):
            return pad(f, m) if hs else pad(f, None)
        if last in ("wo",):
            return pad(m, f) if hs else pad(None, f)
        if last in ("bq", "bk", "bv"):
            return pad(m) if hs else pad(None)
        if last in ("w1", "w3"):  # (d, f) or MoE (E, d, f)
            if "moe" in names and "shared" not in names:
                return pad(f, None) if nd == 3 else P(m, f, None)
            return pad(f, m)
        if last == "w2":  # (f, d) or MoE (E, f, d)
            if "moe" in names and "shared" not in names:
                return pad(None, f) if nd == 3 else P(m, None, f)
            return pad(m, f)
        if last == "router":
            return pad(None, None)
        if last == "in_proj":
            return pad(f, m)
        if last == "out_proj":
            return pad(m, f)
        if last == "conv_w":
            return pad(None, m)
        if last in ("conv_b", "norm_w"):
            return pad(m)
        if last in ("dt_bias", "A_log", "D"):
            return pad(m)
        # norms / scalars
        return pad(*((None,) * min(nd, 1)))

    def fix_moe_stacked(path, leaf):
        """MoE expert tensors inside stacked blocks: (L, E, d, f)."""
        names = [
            p.key if isinstance(p, jax.tree_util.DictKey) else str(p) for p in path
        ]
        s = spec(path, leaf)
        if "moe" in names and names[-1] in ("w1", "w3", "w2") and "shared" not in names:
            if leaf.ndim == 4:  # (L, E, d, f)
                if names[-1] == "w2":
                    return P(None, m, None, f)
                return P(None, m, f, None)
            if leaf.ndim == 3:  # unstacked (E, d, f)
                if names[-1] == "w2":
                    return P(m, None, f)
                return P(m, f, None)
        return s

    return jax.tree_util.tree_map_with_path(fix_moe_stacked, params)


def batch_specs(batch_shapes, plan: ParallelPlan):
    """Batch inputs: leading dim over the DP axes."""
    b = plan.batch_axes if len(plan.batch_axes) > 1 else plan.batch_axes[0]

    def spec(leaf):
        return P(*((b,) + (None,) * (len(leaf.shape) - 1)))

    return jax.tree.map(spec, batch_shapes)
