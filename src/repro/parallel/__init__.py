from .plan import ParallelPlan, single_device_plan

__all__ = ["ParallelPlan", "single_device_plan"]
