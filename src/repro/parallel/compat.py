"""Version compatibility for the jax sharding surface.

The repo is written against the modern names (``jax.shard_map`` with
``axis_names``/``check_vma``, ``jax.make_mesh(..., axis_types=...)``,
``jax.sharding.get_abstract_mesh``), but the pinned environment ships a jax
where shard_map still lives in ``jax.experimental.shard_map`` with the
``auto``/``check_rep`` spelling and meshes have no axis types.  Rather than
sprinkle version probes through ``parallel/``, ``models/`` and ``train/``,
every call site routes through this one module — which is also what lets
``tests/test_distributed.py`` actually run on the pinned jax instead of
skipping (the old ``requires_explicit_sharding`` probe keyed on the modern
names existing and deselected the whole distributed lane).

``shard_map`` here speaks the modern argument names: ``axis_names`` is the
set of mesh axes the region is MANUAL over; on old jax it is translated to
``auto = mesh.axis_names - axis_names``.  Callers should prefer manual over
ALL mesh axes — partial-manual regions (non-empty ``auto``) trip an XLA-CPU
partitioner crash (``IsManualSubgroup`` check failure in the SPMD
partitioner) on the pinned version, which is exactly why the compressed
train-step region runs fully manual.
"""
from __future__ import annotations

from typing import Optional

import jax

_HAS_MODERN_SHARD_MAP = hasattr(jax, "shard_map")


def shard_map(f, mesh, *, axis_names, in_specs, out_specs, check_vma=False):
    """Modern-style shard_map that also runs on the legacy API.

    ``axis_names``: iterable of mesh axis names the body is manual over.
    """
    manual = frozenset(axis_names)
    if _HAS_MODERN_SHARD_MAP:
        return jax.shard_map(
            f,
            mesh=mesh,
            axis_names=manual,
            in_specs=in_specs,
            out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _legacy

    auto = frozenset(mesh.axis_names) - manual
    return _legacy(
        f,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
        check_rep=check_vma,
        auto=auto,
    )


def make_mesh(axis_shapes, axis_names, *, auto_axis_types: bool = False):
    """``jax.make_mesh`` with the axis-type request dropped where the
    installed jax predates mesh axis types (plain meshes behave as Auto
    there, so the semantics match)."""
    if auto_axis_types and hasattr(jax.sharding, "AxisType"):
        types = (jax.sharding.AxisType.Auto,) * len(axis_names)
        try:
            return jax.make_mesh(axis_shapes, axis_names, axis_types=types)
        except TypeError:
            pass
    return jax.make_mesh(axis_shapes, axis_names)


def get_abstract_mesh() -> Optional[object]:
    """The ambient abstract mesh when the installed jax tracks one, else
    None (legacy jax: nested shard_map takes the concrete mesh)."""
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is None:
        return None
    return fn()
