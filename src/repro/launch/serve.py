"""Serving launcher: ``python -m repro.launch.serve --arch <id> --kv int8``.

Batched greedy decode with the (optionally int8-quantized) KV cache —
the paper's quantizer on the serving path.  ``--offload-kv chunked``
additionally streams the finished cache through the chunked compression
engine (repro.core.chunking) frame by frame — the bounded-memory offload
path for evicting sequences to host/disk under heavy traffic.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro import models
from repro.core import telemetry
from repro.parallel import ParallelPlan

log = telemetry.get_logger("serve")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b", choices=configs.ARCHS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--kv", default="bf16", choices=["bf16", "int8"])
    ap.add_argument(
        "--offload-kv",
        default="none",
        choices=["none", "chunked", "auto", "hybrid", "quality", "fast"],
        help="'chunked': prediction-pipeline candidates only; 'auto': adds "
        "the sz3_transform and sz3_hybrid candidates (KV channels are often "
        "oscillatory, and mixed hot/cold sequences suit per-block "
        "selection); 'hybrid': the block-hybrid engine only (per-block "
        "predictor selection inside every chunk); 'quality': closed-loop "
        "rate control to --offload-psnr dB instead of a hand-picked error "
        "bound; 'fast': the SZx-style fixed-length tier only — lowest "
        "latency on the eviction path, trading ratio for speed",
    )
    ap.add_argument("--offload-eb", type=float, default=1e-3)
    ap.add_argument(
        "--offload-psnr",
        type=float,
        default=60.0,
        help="PSNR target (dB) for --offload-kv quality",
    )
    ap.add_argument(
        "--offload-workers",
        type=int,
        default=1,
        help="chunk-compression threads for the KV offload stream",
    )
    ap.add_argument(
        "--offload-verify",
        action=argparse.BooleanOptionalAction,
        default=True,
        help="strict-decode every offloaded frame on read-back (checksum "
        "trailers verified) before counting it evicted; --no-offload-verify "
        "skips the read-back pass",
    )
    ap.add_argument(
        "--metrics",
        action=argparse.BooleanOptionalAction,
        default=False,
        help="dump the Prometheus-style metrics page (decode-step and "
        "offload-frame latency percentiles, verify-failure counters) and the "
        "per-stage offload trace summary before exiting",
    )
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch)
    plan = ParallelPlan(kv_cache_dtype=args.kv)
    params = models.init_params(jax.random.PRNGKey(0), cfg, plan)
    enc_frames = None
    if cfg.family == "encdec":
        enc_frames = jax.random.normal(
            jax.random.PRNGKey(1), (args.batch, cfg.enc_seq, cfg.d_model),
            cfg.param_dtype,
        )
    cache = models.init_cache(
        params, cfg, plan, args.batch, args.tokens + 8, enc_frames=enc_frames
    )
    step = jax.jit(
        lambda p, c, t: models.decode_step(p, c, t, cfg, plan), donate_argnums=1
    )
    tok = jax.random.randint(jax.random.PRNGKey(2), (args.batch, 1), 0, cfg.vocab)
    out = [tok]
    t0 = time.perf_counter()
    for _ in range(args.tokens):
        ts = time.perf_counter()
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits, -1, keepdims=True).astype(jnp.int32)
        tok.block_until_ready()
        telemetry.metric_observe(
            "sz3_decode_step_seconds", time.perf_counter() - ts
        )
        out.append(tok)
    dt = time.perf_counter() - t0
    seqs = np.concatenate([np.asarray(t) for t in out], axis=1)
    log.info(
        "decode_done", arch=args.arch, kv=args.kv,
        tok_per_s=args.tokens * args.batch / dt,
        sample=str(seqs[0][:12].tolist()),
    )
    tr = None
    if args.offload_kv in ("chunked", "auto", "hybrid", "quality", "fast"):
        candidates = None
        if args.offload_kv == "auto":
            candidates = "auto"
        elif args.offload_kv == "hybrid":
            candidates = ("sz3_hybrid",)
        elif args.offload_kv == "fast":
            candidates = ("sz3_fast",)
        scope = (
            telemetry.trace("kv_offload") if args.metrics
            else _NullScope()
        )
        with scope as tr:
            offload_cache(
                cache,
                eb=args.offload_eb,
                workers=args.offload_workers,
                candidates=candidates,
                target_psnr=args.offload_psnr if args.offload_kv == "quality" else None,
                verify=args.offload_verify,
            )
    if args.metrics:
        print(telemetry.prometheus_text(), end="")
        if tr is not None:
            print(telemetry.trace_summary(tr))


class _NullScope:
    def __enter__(self):
        return None

    def __exit__(self, *exc) -> bool:
        return False


def offload_cache(
    cache,
    eb: float = 1e-3,
    chunk_bytes: int = 1 << 20,
    workers: int = 1,
    candidates=None,
    target_psnr: float = None,
    verify: bool = True,
):
    """Stream every float cache leaf through the chunked engine; report totals.

    Frames are produced (and could be written to host/disk) one chunk at a
    time — working memory stays bounded by one chunk regardless of cache size.
    ``candidates="auto"`` (or an explicit name tuple) widens the per-chunk
    contest to the transform coder family.  ``target_psnr`` switches to the
    closed-loop quality-targeted controller: instead of a hand-picked error
    bound, each chunk is compressed at whatever bound hits the PSNR floor,
    and the achieved PSNR is reported alongside the ratio.

    ``verify=True`` strict-decodes every frame on read-back (checksum
    trailers verified, ``repro.core.integrity``) before the bytes are counted
    as safely evicted — the eviction path never trades a live KV page for a
    silently corrupt one.  Verification time is reported separately so the
    cost of the read-back pass is visible.
    """
    from repro.core import (
        AUTO_CANDIDATES,
        CompressionConfig,
        ErrorBoundMode,
        QualityCompressor,
        decompress as sz3_decompress,
    )
    from repro.core.chunking import DEFAULT_CANDIDATES, compress_stream

    if candidates is None:
        candidates = DEFAULT_CANDIDATES
    elif candidates == "auto":
        candidates = AUTO_CANDIDATES
    conf = CompressionConfig(mode=ErrorBoundMode.REL, eb=eb)
    quality = (
        QualityCompressor(
            target_psnr=target_psnr,
            candidates=candidates,
            chunk_bytes=chunk_bytes,
            workers=workers,
        )
        if target_psnr is not None
        else None
    )
    n_in = n_out = n_leaves = n_frames = 0
    worst_psnr = float("inf")
    t_verify = 0.0

    def _verify_frame(frame: bytes) -> float:
        """Strict read-back decode, timed into the request-latency histogram;
        failures are counted (globally and in any active trace) and re-raised."""
        tv = time.perf_counter()
        try:
            sz3_decompress(frame, verify="strict")
        except Exception:
            telemetry.metric_count("sz3_offload_verify_failures_total")
            raise
        dv = time.perf_counter() - tv
        telemetry.metric_observe("sz3_offload_verify_seconds", dv)
        return dv

    t0 = time.perf_counter()
    for leaf in jax.tree.leaves(cache):
        dt = getattr(leaf, "dtype", None)
        # jnp.issubdtype, not numpy dtype.kind: bfloat16 is kind 'V' to numpy
        if dt is None or not jnp.issubdtype(dt, jnp.floating) or leaf.size < 1024:
            continue
        a = np.asarray(jnp.asarray(leaf, jnp.float32))
        arr = np.ascontiguousarray(a.reshape(a.shape[0], -1) if a.ndim > 1 else a)
        tl = time.perf_counter()
        if quality is not None:
            res = quality.compress(arr)
            n_out += len(res.blob)
            worst_psnr = min(worst_psnr, res.meta["quality"]["achieved_psnr"])
            if verify:
                t_verify += _verify_frame(res.blob)
                n_frames += 1
        else:
            for frame in compress_stream(
                arr, conf, candidates=candidates, chunk_bytes=chunk_bytes,
                workers=workers,
            ):
                n_out += len(frame)
                # payload frames only: the stream prologue is not a container
                if verify and frame[:4] == b"SZ3J":
                    t_verify += _verify_frame(frame)
                    n_frames += 1
        telemetry.metric_observe(
            "sz3_offload_leaf_seconds", time.perf_counter() - tl
        )
        n_in += arr.nbytes
        n_leaves += 1
    dt = time.perf_counter() - t0
    telemetry.metric_count("sz3_offload_leaves_total", n_leaves)
    telemetry.metric_count("sz3_offload_bytes_in_total", n_in)
    telemetry.metric_count("sz3_offload_bytes_out_total", n_out)
    fields = dict(
        leaves=n_leaves,
        ratio=n_in / max(1, n_out),
        MB_per_s=n_in / 1e6 / max(dt, 1e-9),
    )
    if verify:
        fields.update(verified_frames=n_frames, verify_seconds=t_verify)
    if quality is not None:
        log.info(
            "kv_offload", mode="quality", target_psnr_db=target_psnr,
            worst_leaf_psnr_db=worst_psnr, **fields,
        )
    else:
        log.info("kv_offload", mode="chunked_stream", rel_eb=eb, **fields)
    return n_in, n_out


if __name__ == "__main__":
    main()
