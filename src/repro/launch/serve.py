"""Serving launcher: ``python -m repro.launch.serve --arch <id> --kv int8``.

Batched greedy decode with the (optionally int8-quantized) KV cache —
the paper's quantizer on the serving path.  ``--offload-kv chunked``
additionally streams the finished cache through the chunked compression
engine (repro.core.chunking) frame by frame — the bounded-memory offload
path for evicting sequences to host/disk under heavy traffic.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro import models
from repro.core import telemetry
from repro.parallel import ParallelPlan

log = telemetry.get_logger("serve")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b", choices=configs.ARCHS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--kv", default="bf16", choices=["bf16", "int8"])
    ap.add_argument(
        "--offload-kv",
        default="none",
        choices=["none", "chunked", "auto", "hybrid", "quality", "fast"],
        help="'chunked': prediction-pipeline candidates only; 'auto': adds "
        "the sz3_transform and sz3_hybrid candidates (KV channels are often "
        "oscillatory, and mixed hot/cold sequences suit per-block "
        "selection); 'hybrid': the block-hybrid engine only (per-block "
        "predictor selection inside every chunk); 'quality': closed-loop "
        "rate control to --offload-psnr dB instead of a hand-picked error "
        "bound; 'fast': the SZx-style fixed-length tier only — lowest "
        "latency on the eviction path, trading ratio for speed",
    )
    ap.add_argument("--offload-eb", type=float, default=1e-3)
    ap.add_argument(
        "--offload-psnr",
        type=float,
        default=60.0,
        help="PSNR target (dB) for --offload-kv quality",
    )
    ap.add_argument(
        "--offload-workers",
        type=int,
        default=1,
        help="chunk-compression threads for the KV offload stream",
    )
    ap.add_argument(
        "--offload-async",
        action=argparse.BooleanOptionalAction,
        default=False,
        help="route the offload through the async multi-tenant service "
        "(repro.serve.offload): leaves compress concurrently on the worker "
        "pool and verification reads go through the coalescing per-chunk "
        "fetch path instead of full-container decodes",
    )
    ap.add_argument(
        "--offload-executor",
        default="thread",
        choices=["thread", "process"],
        help="worker pool flavor for --offload-async",
    )
    ap.add_argument(
        "--offload-verify",
        action=argparse.BooleanOptionalAction,
        default=True,
        help="strict-decode every offloaded frame on read-back (checksum "
        "trailers verified) before counting it evicted; --no-offload-verify "
        "skips the read-back pass",
    )
    ap.add_argument(
        "--metrics",
        action=argparse.BooleanOptionalAction,
        default=False,
        help="dump the Prometheus-style metrics page (decode-step and "
        "offload-frame latency percentiles, verify-failure counters) and the "
        "per-stage offload trace summary before exiting",
    )
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch)
    plan = ParallelPlan(kv_cache_dtype=args.kv)
    params = models.init_params(jax.random.PRNGKey(0), cfg, plan)
    enc_frames = None
    if cfg.family == "encdec":
        enc_frames = jax.random.normal(
            jax.random.PRNGKey(1), (args.batch, cfg.enc_seq, cfg.d_model),
            cfg.param_dtype,
        )
    cache = models.init_cache(
        params, cfg, plan, args.batch, args.tokens + 8, enc_frames=enc_frames
    )
    step = jax.jit(
        lambda p, c, t: models.decode_step(p, c, t, cfg, plan), donate_argnums=1
    )
    tok = jax.random.randint(jax.random.PRNGKey(2), (args.batch, 1), 0, cfg.vocab)
    out = [tok]
    t0 = time.perf_counter()
    for _ in range(args.tokens):
        ts = time.perf_counter()
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits, -1, keepdims=True).astype(jnp.int32)
        tok.block_until_ready()
        telemetry.metric_observe(
            "sz3_decode_step_seconds", time.perf_counter() - ts
        )
        out.append(tok)
    dt = time.perf_counter() - t0
    seqs = np.concatenate([np.asarray(t) for t in out], axis=1)
    log.info(
        "decode_done", arch=args.arch, kv=args.kv,
        tok_per_s=args.tokens * args.batch / dt,
        sample=str(seqs[0][:12].tolist()),
    )
    tr = None
    if args.offload_kv in ("chunked", "auto", "hybrid", "quality", "fast"):
        candidates = None
        if args.offload_kv == "auto":
            candidates = "auto"
        elif args.offload_kv == "hybrid":
            candidates = ("sz3_hybrid",)
        elif args.offload_kv == "fast":
            candidates = ("sz3_fast",)
        scope = (
            telemetry.trace("kv_offload") if args.metrics
            else _NullScope()
        )
        with scope as tr:
            if args.offload_async and args.offload_kv != "quality":
                offload_cache_async(
                    cache,
                    eb=args.offload_eb,
                    workers=args.offload_workers,
                    candidates=candidates,
                    verify=args.offload_verify,
                    executor=args.offload_executor,
                )
            else:
                offload_cache(
                    cache,
                    eb=args.offload_eb,
                    workers=args.offload_workers,
                    candidates=candidates,
                    target_psnr=args.offload_psnr if args.offload_kv == "quality" else None,
                    verify=args.offload_verify,
                )
    if args.metrics:
        print(telemetry.prometheus_text(), end="")
        if tr is not None:
            print(telemetry.trace_summary(tr))


class _NullScope:
    def __enter__(self):
        return None

    def __exit__(self, *exc) -> bool:
        return False


def _iter_kv_leaves(cache):
    """Yield ``(arr, src_dtype_name, src_itemsize)`` per cache leaf.

    ``arr`` is the 2-D float32 working copy the compressor consumes, or
    ``None`` for leaves rejected by the size/dtype filter (callers count
    those as skipped).  ``src_itemsize`` is the itemsize of the leaf's OWN
    dtype — bf16 pages are 2 B/elem at rest, and offload accounting must
    charge what eviction actually frees, not the float32 working copy.
    """
    for leaf in jax.tree.leaves(cache):
        dt = getattr(leaf, "dtype", None)
        # jnp.issubdtype, not numpy dtype.kind: bfloat16 is kind 'V' to numpy
        if dt is None or not jnp.issubdtype(dt, jnp.floating) or leaf.size < 1024:
            yield None, None, 0
            continue
        a = np.asarray(jnp.asarray(leaf, jnp.float32))
        arr = np.ascontiguousarray(a.reshape(a.shape[0], -1) if a.ndim > 1 else a)
        sdt = np.dtype(dt)
        yield arr, sdt.name, sdt.itemsize


def offload_cache(
    cache,
    eb: float = 1e-3,
    chunk_bytes: int = 1 << 20,
    workers: int = 1,
    candidates=None,
    target_psnr: float = None,
    verify: bool = True,
):
    """Stream every float cache leaf through the chunked engine; report totals.

    Frames are produced (and could be written to host/disk) one chunk at a
    time — working memory stays bounded by one chunk regardless of cache size.
    ``candidates="auto"`` (or an explicit name tuple) widens the per-chunk
    contest to the transform coder family.  ``target_psnr`` switches to the
    closed-loop quality-targeted controller: instead of a hand-picked error
    bound, each chunk is compressed at whatever bound hits the PSNR floor,
    and the achieved PSNR is reported alongside the ratio.

    ``verify=True`` strict-decodes every frame on read-back (checksum
    trailers verified, ``repro.core.integrity``) before the bytes are counted
    as safely evicted — the eviction path never trades a live KV page for a
    silently corrupt one.  Verification time is reported separately so the
    cost of the read-back pass is visible.
    """
    from repro.core import (
        AUTO_CANDIDATES,
        CompressionConfig,
        ErrorBoundMode,
        QualityCompressor,
        decompress as sz3_decompress,
    )
    from repro.core.chunking import DEFAULT_CANDIDATES, compress_stream

    if candidates is None:
        candidates = DEFAULT_CANDIDATES
    elif candidates == "auto":
        candidates = AUTO_CANDIDATES
    conf = CompressionConfig(mode=ErrorBoundMode.REL, eb=eb)
    quality = (
        QualityCompressor(
            target_psnr=target_psnr,
            candidates=candidates,
            chunk_bytes=chunk_bytes,
            workers=workers,
        )
        if target_psnr is not None
        else None
    )
    n_in = n_out = n_leaves = n_frames = n_skipped = 0
    worst_psnr = None  # None until a leaf actually qualifies
    src_dtypes = set()
    t_verify = 0.0

    def _verify_frame(frame: bytes) -> float:
        """Strict read-back decode, timed into the request-latency histogram;
        failures are counted (globally and in any active trace) and re-raised."""
        tv = time.perf_counter()
        try:
            sz3_decompress(frame, verify="strict")
        except Exception:
            telemetry.metric_count("sz3_offload_verify_failures_total")
            raise
        dv = time.perf_counter() - tv
        telemetry.metric_observe("sz3_offload_verify_seconds", dv)
        return dv

    t0 = time.perf_counter()
    for arr, src_name, src_itemsize in _iter_kv_leaves(cache):
        if arr is None:
            n_skipped += 1
            continue
        tl = time.perf_counter()
        if quality is not None:
            res = quality.compress(arr)
            n_out += len(res.blob)
            psnr = res.meta["quality"]["achieved_psnr"]
            worst_psnr = psnr if worst_psnr is None else min(worst_psnr, psnr)
            if verify:
                t_verify += _verify_frame(res.blob)
                n_frames += 1
        else:
            for frame in compress_stream(
                arr, conf, candidates=candidates, chunk_bytes=chunk_bytes,
                workers=workers,
            ):
                n_out += len(frame)
                # payload frames only: the stream prologue is not a container
                if verify and frame[:4] == b"SZ3J":
                    t_verify += _verify_frame(frame)
                    n_frames += 1
        telemetry.metric_observe(
            "sz3_offload_leaf_seconds", time.perf_counter() - tl
        )
        # source-dtype bytes: eviction frees the leaf AT REST (bf16 = 2
        # B/elem), not the float32 working copy the compressor consumed —
        # counting arr.nbytes inflated bf16 ratios ~2x
        n_in += arr.size * src_itemsize
        src_dtypes.add(src_name)
        n_leaves += 1
    dt = time.perf_counter() - t0
    telemetry.metric_count("sz3_offload_leaves_total", n_leaves)
    if n_skipped:
        telemetry.metric_count("sz3_offload_leaves_skipped_total", n_skipped)
    telemetry.metric_count("sz3_offload_bytes_in_total", n_in)
    telemetry.metric_count("sz3_offload_bytes_out_total", n_out)
    fields = dict(
        leaves=n_leaves,
        skipped=n_skipped,
        src_dtype=",".join(sorted(src_dtypes)) if src_dtypes else None,
        ratio=n_in / max(1, n_out),
        MB_per_s=n_in / 1e6 / max(dt, 1e-9),
    )
    if verify:
        fields.update(verified_frames=n_frames, verify_seconds=t_verify)
    if quality is not None:
        psnr_field = (
            {} if worst_psnr is None else {"worst_leaf_psnr_db": worst_psnr}
        )
        log.info(
            "kv_offload", mode="quality", target_psnr_db=target_psnr,
            **psnr_field, **fields,
        )
    else:
        log.info("kv_offload", mode="chunked_stream", rel_eb=eb, **fields)
    return n_in, n_out


def offload_cache_async(
    cache,
    eb: float = 1e-3,
    chunk_bytes: int = 1 << 20,
    workers: int = 4,
    candidates=None,
    verify: bool = True,
    executor: str = "thread",
):
    """Offload every qualifying cache leaf through the async service.

    Leaves become pages of one ``kv`` tenant and compress concurrently on
    the service's worker pool; with ``verify`` each page's chunk 0 is
    fetched back through the coalescing read path (strict per-chunk CRC
    validation) before the bytes count as evicted.  Accounting matches
    :func:`offload_cache`: source-dtype bytes in, container bytes out.
    """
    import asyncio

    from repro.core import ErrorBoundMode
    from repro.serve.offload import OffloadService

    async def _run():
        svc = OffloadService(
            workers=workers,
            executor=executor,
            eb=eb,
            mode=ErrorBoundMode.REL,
            candidates=candidates,
            chunk_bytes=chunk_bytes,
            verify="strict" if verify else "off",
        )
        n_in = n_out = n_leaves = n_skipped = 0
        src_dtypes = set()
        t0 = time.perf_counter()
        try:
            puts = []
            for i, (arr, src_name, src_itemsize) in enumerate(
                _iter_kv_leaves(cache)
            ):
                if arr is None:
                    n_skipped += 1
                    continue
                n_in += arr.size * src_itemsize
                src_dtypes.add(src_name)
                puts.append(svc.put("kv", f"leaf{i}", arr))
            reports = await asyncio.gather(*puts)
            n_leaves = len(reports)
            n_out = sum(r["n_out"] for r in reports)
            if verify:
                await asyncio.gather(
                    *[svc.fetch("kv", r["page"], 0) for r in reports]
                )
        finally:
            await svc.close()
        dt = time.perf_counter() - t0
        telemetry.metric_count("sz3_offload_leaves_total", n_leaves)
        if n_skipped:
            telemetry.metric_count("sz3_offload_leaves_skipped_total", n_skipped)
        telemetry.metric_count("sz3_offload_bytes_in_total", n_in)
        telemetry.metric_count("sz3_offload_bytes_out_total", n_out)
        log.info(
            "kv_offload", mode="async_service", rel_eb=eb, leaves=n_leaves,
            skipped=n_skipped,
            src_dtype=",".join(sorted(src_dtypes)) if src_dtypes else None,
            ratio=n_in / max(1, n_out), MB_per_s=n_in / 1e6 / max(dt, 1e-9),
            workers=workers, executor=executor,
        )
        return n_in, n_out

    return asyncio.run(_run())


if __name__ == "__main__":
    main()
