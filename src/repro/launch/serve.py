"""Serving launcher: ``python -m repro.launch.serve --arch <id> --kv int8``.

Batched greedy decode with the (optionally int8-quantized) KV cache —
the paper's quantizer on the serving path.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro import models
from repro.parallel import ParallelPlan


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b", choices=configs.ARCHS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--kv", default="bf16", choices=["bf16", "int8"])
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch)
    plan = ParallelPlan(kv_cache_dtype=args.kv)
    params = models.init_params(jax.random.PRNGKey(0), cfg, plan)
    enc_frames = None
    if cfg.family == "encdec":
        enc_frames = jax.random.normal(
            jax.random.PRNGKey(1), (args.batch, cfg.enc_seq, cfg.d_model),
            cfg.param_dtype,
        )
    cache = models.init_cache(
        params, cfg, plan, args.batch, args.tokens + 8, enc_frames=enc_frames
    )
    step = jax.jit(
        lambda p, c, t: models.decode_step(p, c, t, cfg, plan), donate_argnums=1
    )
    tok = jax.random.randint(jax.random.PRNGKey(2), (args.batch, 1), 0, cfg.vocab)
    out = [tok]
    t0 = time.perf_counter()
    for _ in range(args.tokens):
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits, -1, keepdims=True).astype(jnp.int32)
        out.append(tok)
    dt = time.perf_counter() - t0
    seqs = np.concatenate([np.asarray(t) for t in out], axis=1)
    print(f"{args.arch} kv={args.kv}: {args.tokens * args.batch / dt:.1f} tok/s")
    print("sample:", seqs[0][:12].tolist())


if __name__ == "__main__":
    main()
