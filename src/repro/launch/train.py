"""Training launcher: ``python -m repro.launch.train --arch <id> [--smoke]``.

Selects any assigned architecture config, builds the per-cell parallel plan
(single device on CPU; production mesh when devices allow), and runs the full
production loop: sharded train step, microbatching, SZ3-compressed
checkpoints, deterministic resumable data, heartbeat monitoring.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.data import make_pipeline
from repro.ft import CheckpointManager, HeartbeatMonitor
from repro.optim import AdamWConfig
from repro.parallel import ParallelPlan
from repro.train.step import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=configs.ARCHS)
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="use the reduced config (full configs need a pod)")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--compress-moments", action="store_true")
    ap.add_argument("--mesh", default="",
                    help="mesh shape as data=N[,model=M]; needs that many "
                         "devices (XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=K simulates K on CPU)")
    ap.add_argument("--compress-grads", default="", metavar="POLICY",
                    help="error-bounded DP gradient reduction: a jitmode "
                         "policy spec ('int8', 'int4:bs=256', "
                         "'int8:eb=1e-6:pred=zero+lorenzo1+mean') or plain "
                         "8/4; needs --mesh with data>1")
    ap.add_argument("--compress-opt", default="", metavar="POLICY",
                    help="compressed optimizer moments with this jitmode "
                         "policy spec (implies --compress-moments)")
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    mesh = None
    if args.mesh:
        from .mesh import make_debug_mesh

        pairs = [kv.split("=") for kv in args.mesh.split(",")]
        names = tuple(k for k, _ in pairs)
        shape = tuple(int(v) for _, v in pairs)
        mesh = make_debug_mesh(shape, names)
    grad_policy = args.compress_grads
    if grad_policy in ("8", "4"):  # bare bit width -> default policy
        grad_policy = f"int{grad_policy}"
    plan = ParallelPlan(
        mesh=mesh,
        microbatches=args.microbatches,
        grad_policy=grad_policy,
    )
    opt = AdamWConfig(
        lr=args.lr,
        compress_moments=args.compress_moments or bool(args.compress_opt),
        moment_policy=args.compress_opt,
    )
    print(f"arch={cfg.name} family={cfg.family} ~{cfg.n_flop_params()/1e6:.0f}M params")

    pipe = make_pipeline(cfg, seq=args.seq, global_batch=args.batch)
    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    mon = HeartbeatMonitor(["host0"], timeout_s=600)

    state = init_train_state(jax.random.PRNGKey(0), cfg, plan, opt)
    start = 0
    if mgr.list_steps():
        host, extra = mgr.restore(jax.tree.map(np.asarray, state))
        state = jax.tree.map(jnp.asarray, host)
        start = int(extra.get("next_step", 0))
        print(f"resumed at step {start}")

    step_fn = jax.jit(make_train_step(cfg, plan, opt, total_steps=args.steps),
                      donate_argnums=0)
    t0 = time.perf_counter()
    for k in range(start, args.steps):
        batch = {k2: jnp.asarray(v) for k2, v in pipe.batch_at(k).items()}
        state, m = step_fn(state, batch)
        dt = time.perf_counter() - t0
        t0 = time.perf_counter()
        mon.beat("host0", dt)
        if k % 5 == 0 or k == args.steps - 1:
            print(f"step {k:4d} loss={float(m['loss']):.4f} "
                  f"({args.batch * args.seq / dt:,.0f} tok/s)")
        if (k + 1) % args.ckpt_every == 0:
            mgr.save(k + 1, state, extra={"next_step": k + 1})
    mgr.wait()
    print("done; checkpoints:", mgr.list_steps())


if __name__ == "__main__":
    main()
