"""Production mesh construction.

Importing this module never touches jax device state; the mesh is built on
demand (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

from repro.parallel import compat


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes, auto_axis_types=True)


def make_debug_mesh(shape=(1, 1), axes=("data", "model")):
    """Small mesh over however many (possibly fake) local devices exist."""
    return compat.make_mesh(shape, axes)
