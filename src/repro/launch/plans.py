"""Per-(arch x shape) parallelism policy — one source of truth for the
dry-run, the roofline harness and the examples.

Defaults are the BASELINE recorded in EXPERIMENTS.md §Roofline; §Perf
hillclimb variants override via ``overrides``.  Notable policy decisions:

  * train: FSDP over ``data`` (HSDP across pods: replicas over ``pod``),
    full remat, per-arch gradient-accumulation microbatches sized so stored
    scan carries fit HBM; nemotron additionally shards the residual stream's
    sequence dim over ``model`` (Megatron-style SP) and compresses optimizer
    moments to int8 (the paper's quantizer — without it m/v alone exceed v5e
    HBM; see EXPERIMENTS.md).
  * decode: weights stay FSDP-sharded (gather streams through the layer
    scan); nemotron's decode_32k KV cache only fits in int8 (the paper's
    technique as a *capacity enabler*, not just a bandwidth one).
  * long_500k: batch=1 cannot shard over DP axes -> batch replicated, TP
    only; the cache is window/state-sized (SWA / SSM) so this is cheap.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

from ..models.common import ModelConfig
from ..optim import AdamWConfig
from ..parallel.plan import ParallelPlan

TRAIN_MICROBATCHES: Dict[str, int] = {
    "nemotron-4-340b": 16,
    "granite-3-8b": 8,
    "pixtral-12b": 8,
    "zamba2-7b": 8,
    "deepseek-moe-16b": 8,
    "qwen3-moe-30b-a3b": 8,
    "mamba2-2.7b": 4,
    "h2o-danube-1.8b": 4,
    "qwen1.5-0.5b": 2,
    "whisper-small": 2,
}

SEQ_SHARD_TRAIN = {"nemotron-4-340b"}
COMPRESS_MOMENTS = {"nemotron-4-340b"}
KV_INT8_DECODE = {"nemotron-4-340b"}


def make_cell_plan(
    arch: str,
    cfg: ModelConfig,
    cell,
    mesh,
    multi_pod: bool,
    overrides: Optional[Dict[str, Any]] = None,
) -> Tuple[ParallelPlan, AdamWConfig]:
    overrides = dict(overrides or {})
    dp_axes = ("pod", "data") if multi_pod else ("data",)
    dp = math.prod(mesh.shape[a] for a in dp_axes)
    batch_axes = dp_axes if cell.batch % dp == 0 else ()

    opt = AdamWConfig(compress_moments=arch in COMPRESS_MOMENTS)
    if "compress_moments" in overrides:
        opt = opt._replace(compress_moments=overrides.pop("compress_moments"))

    kw: Dict[str, Any] = dict(
        mesh=mesh,
        batch_axes=batch_axes,
        model_axis="model",
        fsdp_axes=("data",),
        remat="full",
        microbatches=1,
        kv_cache_dtype="bf16",
    )
    if cell.kind == "train":
        kw["microbatches"] = TRAIN_MICROBATCHES.get(arch, 4)
        if arch in SEQ_SHARD_TRAIN:
            kw["seq_axes"] = ("model",)
    elif cell.kind == "decode":
        if arch in KV_INT8_DECODE:
            kw["kv_cache_dtype"] = "int8"
    kw.update(overrides)
    return ParallelPlan(**kw), opt
