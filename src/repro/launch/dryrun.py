import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): lower + compile every assigned
(architecture x input-shape) cell on the production meshes, record
memory/cost/roofline terms.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-0.5b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]
  PYTHONPATH=src python -m repro.launch.dryrun --list

Results land in results/dryrun/<mesh>/<arch>__<shape>[__variant].json and are
consumed by benchmarks/roofline.py and EXPERIMENTS.md.

v5e constants for the roofline terms (per brief): 197 TFLOP/s bf16/chip,
819 GB/s HBM, ~50 GB/s/link ICI.  HLO FLOPs/bytes/collectives come from the
while-trip-corrected parser (hlo_cost.py) because compiled.cost_analysis()
counts loop bodies once; both raw and corrected values are recorded.
"""
import argparse
import dataclasses
import functools
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro import models
from repro.configs.shapes import SHAPES, cell_skip_reason, input_specs
from repro.launch import hlo_cost
from repro.launch.mesh import make_production_mesh
from repro.launch.plans import make_cell_plan
from repro.serve.step import cache_specs, jit_serve_step, make_serve_step
from repro.train.step import init_train_state, jit_train_step, make_train_step
from repro.parallel.specs import batch_specs, param_specs

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
LINK_BW = 50e9  # B/s / link


def _mem_dict(mem) -> dict:
    out = {}
    for k in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
        "alias_size_in_bytes",
        "peak_memory_in_bytes",
    ):
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def lower_cell(arch: str, shape: str, mesh, multi_pod: bool, overrides=None):
    cfg = configs.get(arch)
    cell = SHAPES[shape]
    plan, opt_cfg = make_cell_plan(arch, cfg, cell, mesh, multi_pod, overrides)
    key = jax.random.PRNGKey(0)
    specs = input_specs(cfg, cell)

    if cell.kind == "train":
        state_shapes = jax.eval_shape(
            functools.partial(init_train_state, key, cfg, plan, opt_cfg)
        )
        step = make_train_step(cfg, plan, opt_cfg)
        jstep = jit_train_step(step, state_shapes, cfg, plan, opt_cfg, specs)
        lowered = jstep.lower(state_shapes, specs)
    elif cell.kind == "prefill":
        pspecs = param_specs(
            jax.eval_shape(functools.partial(models.init_params, key, cfg, plan)),
            cfg,
            plan,
        )
        bspecs = batch_specs(specs, plan)
        sh = lambda tree: jax.tree.map(
            lambda s: jax.NamedSharding(plan.mesh, s),
            tree,
            is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec),
        )

        def prefill(params, batch):
            return models.prefill_logits(params, batch, cfg, plan)

        params_shapes = jax.eval_shape(
            functools.partial(models.init_params, key, cfg, plan)
        )
        lowered = jax.jit(
            prefill, in_shardings=(sh(pspecs), sh(bspecs))
        ).lower(params_shapes, specs)
    else:  # decode
        params_shapes = jax.eval_shape(
            functools.partial(models.init_params, key, cfg, plan)
        )
        if cfg.family == "encdec":
            frames = jax.ShapeDtypeStruct(
                (cell.batch, cfg.enc_seq, cfg.d_model), cfg.param_dtype
            )
            cache_shapes = jax.eval_shape(
                functools.partial(
                    models.init_cache, cfg=cfg, plan=plan, batch=cell.batch,
                    max_len=cell.seq,
                ),
                params_shapes,
                enc_frames=frames,
            )
        else:
            cache_shapes = jax.eval_shape(
                functools.partial(
                    models.init_cache,
                    None,
                    cfg,
                    plan,
                    cell.batch,
                    cell.seq,
                )
            )
        serve = make_serve_step(cfg, plan)
        jstep = jit_serve_step(serve, params_shapes, cache_shapes, cfg, plan)
        lowered = jstep.lower(params_shapes, cache_shapes, specs["tokens"])
    return lowered, cfg, cell, plan


def analyze_cell(arch, shape, mesh, multi_pod, overrides=None, keep_hlo=False):
    t0 = time.time()
    lowered, cfg, cell, plan = lower_cell(arch, shape, mesh, multi_pod, overrides)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    chips = mesh.size
    mem = _mem_dict(compiled.memory_analysis())
    raw_cost = dict(compiled.cost_analysis() or {})
    text = compiled.as_text()
    cost = hlo_cost.analyze(text, n_devices=chips)

    compute_s = cost.flops / PEAK_FLOPS
    dot_compute_s = cost.dot_flops / PEAK_FLOPS
    memory_s = cost.hbm_bytes / HBM_BW
    collective_s = cost.collective_bytes / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)

    # MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference), D = global tokens
    n_params = cfg.n_flop_params()
    tokens = cell.batch * (cell.seq if cell.kind != "decode" else 1)
    mult = 6 if cell.kind == "train" else 2
    model_flops = mult * n_params * tokens
    hlo_flops_global = cost.dot_flops * chips

    result = {
        "arch": arch,
        "shape": shape,
        "mesh": "multi" if multi_pod else "single",
        "chips": chips,
        "kind": cell.kind,
        "overrides": overrides or {},
        "plan": {
            "batch_axes": list(plan.batch_axes),
            "fsdp_axes": list(plan.fsdp_axes),
            "seq_axes": list(plan.seq_axes),
            "microbatches": plan.microbatches,
            "kv_cache_dtype": plan.kv_cache_dtype,
            "remat": plan.remat,
        },
        "timing": {"lower_s": t_lower, "compile_s": t_compile},
        "memory_analysis": mem,
        "cost_analysis_raw": {
            k: float(v)
            for k, v in raw_cost.items()
            if isinstance(v, (int, float)) and k in ("flops", "bytes accessed")
        },
        "hlo_corrected": {
            "flops_per_chip": cost.flops,
            "dot_flops_per_chip": cost.dot_flops,
            "hbm_bytes_per_chip": cost.hbm_bytes,
            "collective_bytes_per_chip": cost.collective_bytes,
            "per_collective": dict(cost.per_collective),
            "while_trips": cost.while_trips,
        },
        "roofline": {
            "compute_s": compute_s,
            "dot_compute_s": dot_compute_s,
            "memory_s": memory_s,
            "collective_s": collective_s,
            "bottleneck": bottleneck,
            "model_flops": model_flops,
            "hlo_dot_flops_global": hlo_flops_global,
            "useful_flops_ratio": model_flops / max(1.0, hlo_flops_global),
        },
    }
    if keep_hlo:
        result["hlo_text_len"] = len(text)
    return result


def cell_list():
    out = []
    for arch in configs.ARCHS:
        cfg = configs.get(arch)
        for shape, cell in SHAPES.items():
            out.append((arch, shape, cell_skip_reason(cfg, cell)))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--variant", default=None, help="json overrides for the plan")
    ap.add_argument("--tag", default=None, help="suffix for variant result files")
    ap.add_argument("--force", action="store_true")
    ap.add_argument(
        "--isolate",
        action="store_true",
        help="run each cell in a subprocess (fatal XLA crashes can't kill the sweep)",
    )
    args = ap.parse_args()

    if args.isolate and (args.all or (args.arch and args.shape)):
        import subprocess
        import sys

        for mesh_kind in (["single", "multi"] if args.mesh == "both" else [args.mesh]):
            cells = (
                cell_list()
                if args.all
                else [(args.arch, args.shape, None)]
            )
            for arch, shape, _ in cells:
                tag = f"__{args.tag}" if args.tag else ""
                path = Path(args.out) / mesh_kind / f"{arch}__{shape}{tag}.json"
                if path.exists() and not args.force:
                    print(f"[skip-existing] {path}", flush=True)
                    continue
                cmd = [
                    sys.executable, "-m", "repro.launch.dryrun",
                    "--arch", arch, "--shape", shape, "--mesh", mesh_kind,
                    "--out", args.out,
                ]
                if args.variant:
                    cmd += ["--variant", args.variant]
                if args.tag:
                    cmd += ["--tag", args.tag]
                if args.force:
                    cmd += ["--force"]
                r = subprocess.run(cmd, timeout=3600)
                if r.returncode != 0:
                    err = path.with_suffix(".error.json")
                    if not err.exists():
                        err.write_text(json.dumps({
                            "arch": arch, "shape": shape, "mesh": mesh_kind,
                            "error": f"subprocess exited {r.returncode} (fatal crash)",
                        }, indent=2))
                    print(f"  FATAL (rc={r.returncode}) {arch} {shape}", flush=True)
        return

    if args.list:
        for arch, shape, skip in cell_list():
            print(f"{arch:20s} {shape:12s} {'SKIP: ' + skip if skip else 'run'}")
        return

    overrides = json.loads(args.variant) if args.variant else None
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = (
        [(a, s, sk) for a, s, sk in cell_list()]
        if args.all
        else [
            (
                args.arch,
                args.shape,
                cell_skip_reason(configs.get(args.arch), SHAPES[args.shape]),
            )
        ]
    )

    for mesh_kind in meshes:
        multi = mesh_kind == "multi"
        mesh = make_production_mesh(multi_pod=multi)
        out_dir = Path(args.out) / mesh_kind
        out_dir.mkdir(parents=True, exist_ok=True)
        for arch, shape, skip in cells:
            tag = f"__{args.tag}" if args.tag else ""
            path = out_dir / f"{arch}__{shape}{tag}.json"
            if path.exists() and not args.force:
                print(f"[skip-existing] {path}")
                continue
            if skip:
                path.write_text(
                    json.dumps(
                        {"arch": arch, "shape": shape, "mesh": mesh_kind,
                         "skipped": skip},
                        indent=2,
                    )
                )
                print(f"[SKIP] {arch} {shape}: {skip}")
                continue
            print(f"[dryrun] {arch} {shape} mesh={mesh_kind} ...", flush=True)
            try:
                res = analyze_cell(arch, shape, mesh, multi, overrides)
                path.write_text(json.dumps(res, indent=2))
                r = res["roofline"]
                print(
                    f"  ok: compile={res['timing']['compile_s']:.1f}s "
                    f"compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s "
                    f"collective={r['collective_s']:.4f}s -> {r['bottleneck']}",
                    flush=True,
                )
            except Exception as e:
                err = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                       "error": str(e), "traceback": traceback.format_exc()}
                path.with_suffix(".error.json").write_text(json.dumps(err, indent=2))
                print(f"  FAILED: {e}", flush=True)


if __name__ == "__main__":
    main()
