"""While-trip-corrected cost model over optimized HLO text.

``compiled.cost_analysis()`` visits a while body ONCE, so scan-over-layers
(and every other loop) undercounts FLOPs/bytes/collective traffic by the trip
count (verified experimentally; see EXPERIMENTS.md §Roofline methodology).
This parser rebuilds the call graph from ``compiled.as_text()``, extracts
each while loop's trip count from its condition computation (the
``compare(counter, constant(N)), direction=LT`` pattern jax.lax.scan emits),
and multiplies descendant costs accordingly.

Per-device outputs:
  flops            — exact dot/conv FLOPs + 1-flop-per-output elementwise est.
  dot_flops        — MXU-relevant FLOPs only
  hbm_bytes        — fusion-boundary operand+result bytes (HBM traffic model)
  collective_bytes — ring-model wire bytes per device, per collective kind
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# type group is fully lazy: tuple types may contain "/*index=5*/" comments
# (with '='); the opcode is the first word immediately followed by '('.
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)([\w\-]+)\((.*)$"
)
_CALL_ATTR_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_REPL_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_REPL_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_COLLECTIVES = {
    "all-reduce", "all-reduce-start", "all-gather", "all-gather-start",
    "reduce-scatter", "all-to-all", "collective-permute",
    "collective-permute-start",
}

_SKIP_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


def _shape_bytes_elems(type_str: str) -> Tuple[int, int]:
    total_b = total_e = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total_b += n * _DTYPE_BYTES[dt]
        total_e += n
    return total_b, total_e


@dataclasses.dataclass
class OpInfo:
    name: str
    opcode: str
    out_bytes: int
    out_elems: int
    operands: List[str]
    calls: List[str]
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[OpInfo]
    shapes: Dict[str, Tuple[int, int]]  # %name -> (bytes, elems)


def _split_args(arg_str: str) -> List[str]:
    """Operand names from 'op(%a, %b, ...), attr=...' (stop at depth-0 ')').

    Depth tracks (), [] and {} alike: typed operands carry shapes/layouts
    like ``f32[4,32]{1,0}`` whose commas must not split the argument.
    """
    out, depth, cur = [], 0, []
    for ch in arg_str:
        if ch in "([{":
            depth += 1
            cur.append(ch)
        elif ch in ")]}":
            if ch == ")" and depth == 0:
                break
            depth = max(0, depth - 1)
            cur.append(ch)
        elif ch == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur).strip())
    names = []
    for a in out:
        a = a.strip()
        if not a or a[0].isdigit():
            continue
        # scheduled-HLO operands are typed: "f32[4,32]{2,1,0} %Arg_0.1" — the
        # %-prefixed token is the name; bare "%name"/"name" forms keep working
        pm = re.search(r"%([\w.\-]+)", a)
        if pm:
            names.append(pm.group(1))
            continue
        # sigil-less typed form "f32[4,32]{1,0} Arg_0.1": drop the type prefix
        tm = re.match(r"[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?\s+([\w.\-]+)", a)
        if tm:
            names.append(tm.group(1))
            continue
        m = re.match(r"([\w.\-]+)", a)
        if m:
            names.append(m.group(1))
    return names


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        header = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{", stripped)
        if header and not stripped.startswith("//"):
            cur = Computation(name=header.group(1), ops=[], shapes={})
            comps[cur.name] = cur
            continue
        if stripped.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, type_str, opcode, rest = m.groups()
        ob, oe = _shape_bytes_elems(type_str)
        calls = [cm.group(1) for cm in _CALL_ATTR_RE.finditer(rest)]
        for bm in _BRANCH_RE.finditer(rest):
            calls += [c.strip().lstrip("%") for c in bm.group(1).split(",") if c.strip()]
        operands = _split_args(rest)
        cur.shapes[name] = (ob, oe)
        cur.ops.append(
            OpInfo(name=name, opcode=opcode, out_bytes=ob, out_elems=oe,
                   operands=operands, calls=calls, line=stripped)
        )
    return comps


def _dot_flops(op: OpInfo, comp: Computation) -> int:
    """2 * result_elems * prod(contracted dims of lhs)."""
    mm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    if not mm or not op.operands:
        return 2 * op.out_elems  # fallback
    lhs = op.operands[0]
    lhs_line = next((o.line for o in comp.ops if o.name == lhs), None)
    dims: List[int] = []
    if lhs_line is not None:
        sm = _SHAPE_RE.search(lhs_line.split("=", 1)[1])
        if sm and sm.group(2):
            dims = [int(d) for d in sm.group(2).split(",") if d]
    if not dims:
        return 2 * op.out_elems
    contract = 1
    for idx in mm.group(1).split(","):
        if idx != "" and int(idx) < len(dims):
            contract *= dims[int(idx)]
    return 2 * op.out_elems * contract


def _group_size(line: str, default: int) -> int:
    m = _REPL_GROUPS_V2_RE.search(line)
    if m:
        return int(m.group(2))
    m = _REPL_GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return default


def _collective_wire_bytes(op: OpInfo, comp: Computation, n_devices: int) -> Tuple[str, float]:
    opc = op.opcode.replace("-start", "")
    in_bytes = sum(comp.shapes.get(o, (0, 0))[0] for o in op.operands)
    out_bytes = op.out_bytes
    r = max(2, _group_size(op.line, n_devices))
    if opc == "all-reduce":
        wire = 2.0 * in_bytes * (r - 1) / r
    elif opc == "all-gather":
        wire = max(0, out_bytes - in_bytes)  # received bytes
    elif opc == "reduce-scatter":
        wire = max(0, in_bytes - out_bytes)  # sent beyond own shard
    elif opc == "all-to-all":
        wire = in_bytes * (r - 1) / r
    else:  # collective-permute
        wire = in_bytes
    return opc, wire


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    per_collective: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float)
    )
    while_trips: Dict[str, int] = dataclasses.field(default_factory=dict)

    def scaled(self, k: float) -> "Cost":
        c = Cost(
            flops=self.flops * k,
            dot_flops=self.dot_flops * k,
            hbm_bytes=self.hbm_bytes * k,
            collective_bytes=self.collective_bytes * k,
        )
        for kk, v in self.per_collective.items():
            c.per_collective[kk] = v * k
        return c

    def add(self, other: "Cost"):
        self.flops += other.flops
        self.dot_flops += other.dot_flops
        self.hbm_bytes += other.hbm_bytes
        self.collective_bytes += other.collective_bytes
        for kk, v in other.per_collective.items():
            self.per_collective[kk] += v
        self.while_trips.update(other.while_trips)


def _fusion_inplace_adjust(op: OpInfo, comps, in_b: float, out_b: float):
    """Discount buffers a fusion only touches via a dynamic slice.

    For each internal dynamic-update-slice: the destination buffer is updated
    in place — charge the update slice (read+write) instead of buffer-in +
    buffer-out.  For each internal dynamic-slice whose source is a fusion
    parameter: charge the slice, not the whole buffer (per-layer weight /
    carry reads inside scans)."""
    fused = comps.get(op.calls[0]) if op.calls else None
    if fused is None:
        return in_b, out_b
    for fop in fused.ops:
        if fop.opcode == "dynamic-update-slice" and fop.operands:
            buf_b = fused.shapes.get(fop.operands[0], (0, 0))[0]
            upd_b = (
                fused.shapes.get(fop.operands[1], (0, 0))[0]
                if len(fop.operands) > 1
                else 0
            )
            in_b = max(0.0, in_b - buf_b + upd_b)
            out_b = max(0.0, out_b - buf_b + upd_b)
        elif fop.opcode == "dynamic-slice" and fop.operands:
            src = fop.operands[0]
            src_line = next(
                (o for o in fused.ops if o.name == src), None
            )
            if src_line is not None and src_line.opcode == "parameter":
                buf_b = fused.shapes.get(src, (0, 0))[0]
                in_b = max(0.0, in_b - buf_b + fop.out_bytes)
    return in_b, out_b


def _trip_count(cond: Computation) -> int:
    """Largest integer constant in the condition computation (scan pattern)."""
    best = 1
    for op in cond.ops:
        if "compare" in op.opcode or op.opcode == "constant":
            for c in _CONST_RE.finditer(op.line):
                best = max(best, int(c.group(1)))
    return best


def analyze(text: str, n_devices: int = 1) -> Cost:
    comps = parse_hlo(text)
    memo: Dict[str, Cost] = {}

    # entry = first computation named ENTRY in text order; parse_hlo loses the
    # ENTRY marker, so detect via the computation that nobody calls.
    called = set()
    for c in comps.values():
        for op in c.ops:
            called.update(op.calls)
    entries = [c for c in comps if c not in called]

    def comp_cost(name: str, depth=0) -> Cost:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        cost = Cost()
        if comp is None or depth > 64:
            return cost
        memo[name] = cost  # break cycles
        for op in comp.ops:
            opc = op.opcode
            if opc == "while":
                body = cond = None
                bm = re.search(r"body=%?([\w.\-]+)", op.line)
                cm = re.search(r"condition=%?([\w.\-]+)", op.line)
                if bm:
                    body = bm.group(1)
                if cm:
                    cond = cm.group(1)
                trips = _trip_count(comps[cond]) if cond in comps else 1
                cost.while_trips[body or op.name] = trips
                if body:
                    cost.add(comp_cost(body, depth + 1).scaled(trips))
                if cond:
                    cost.add(comp_cost(cond, depth + 1).scaled(trips))
                continue
            if opc in ("call", "fusion", "conditional", "custom-call", "map",
                       "reduce", "sort", "scatter", "select-and-scatter",
                       "reduce-window", "async-start"):
                fused_internal = opc in (
                    "fusion", "map", "reduce", "sort", "scatter",
                    "select-and-scatter", "reduce-window",
                )
                for c in op.calls:
                    sub = comp_cost(c, depth + 1)
                    if fused_internal:
                        # fusion internals live in registers/VMEM: keep their
                        # flops/collectives, drop their byte traffic — only
                        # the fusion boundary (this op) touches HBM.
                        sub = Cost(
                            flops=sub.flops,
                            dot_flops=sub.dot_flops,
                            hbm_bytes=0.0,
                            collective_bytes=sub.collective_bytes,
                            per_collective=sub.per_collective,
                        )
                    cost.add(sub)
            if opc in ("dot", "convolution"):
                f = _dot_flops(op, comp)
                cost.flops += f
                cost.dot_flops += f
            elif opc in _COLLECTIVES:
                kind, wire = _collective_wire_bytes(op, comp, n_devices)
                cost.collective_bytes += wire
                cost.per_collective[kind] += wire
            elif opc not in _SKIP_BYTES:
                cost.flops += op.out_elems  # elementwise estimate
            # HBM traffic model: fusion-boundary operand+result bytes.
            # dynamic-(update-)slice are in-place on the big buffer: count
            # only the moved slice, not the whole cache/carry.  Fusions that
            # internally DUS/DS a big buffer (scan carries, stacked saved
            # activations, per-layer weight slices) get the same adjustment.
            if opc not in _SKIP_BYTES and opc != "while":
                if opc == "dynamic-update-slice":
                    upd = (
                        comp.shapes.get(op.operands[1], (0, 0))[0]
                        if len(op.operands) > 1
                        else 0
                    )
                    cost.hbm_bytes += 2 * upd
                elif opc == "dynamic-slice":
                    cost.hbm_bytes += 2 * op.out_bytes
                else:
                    in_b = sum(
                        comp.shapes.get(o, (0, 0))[0] for o in op.operands
                    )
                    out_b = op.out_bytes
                    if opc == "fusion":
                        in_b, out_b = _fusion_inplace_adjust(
                            op, comps, in_b, out_b
                        )
                    cost.hbm_bytes += in_b + out_b
        return cost

    total = Cost()
    for e in entries:
        total.add(comp_cost(e))
    return total
