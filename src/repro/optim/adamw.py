"""AdamW with optional error-bounded 8-bit moment compression.

Pure-functional (init/update); moments are stored either in f32 or in int8
with per-block scales via the paper's linear-scaling quantizer specialized to
a fixed radius (repro/compression/opt_state.py) — the memory-roofline lever
that fits Nemotron-340B optimizer state into v5e HBM (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..compression import opt_state as oc


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    compress_moments: bool = False  # blockwise jit-codec moments
    moment_policy: str = ""  # jitmode policy spec, e.g. "int8:bs=256";
    # empty = opt_state.DEFAULT_POLICY


def _moment_policy(cfg: "AdamWConfig"):
    if cfg.moment_policy:
        return oc.JitPolicy.parse(cfg.moment_policy)
    return None


def init_state(params, cfg: AdamWConfig):
    pol = _moment_policy(cfg)

    def zeros_like_compressed(domain):
        def init(p):
            if cfg.compress_moments:
                return oc.init_compressed(p, pol, domain=domain)
            return jnp.zeros_like(p, jnp.float32)

        return init

    return {
        # m linear (signed, block-REL bound); v in log2 domain — a block-REL
        # bound on v lets small entries collapse to 0 and m/sqrt(v) diverge
        "m": jax.tree.map(zeros_like_compressed("linear"), params),
        "v": jax.tree.map(zeros_like_compressed("log2"), params),
        "step": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree):
    sq = jax.tree.map(lambda g: jnp.sum(g.astype(jnp.float32) ** 2), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq, jnp.float32(0.0)))


def update(params, grads, state, cfg: AdamWConfig, lr_scale=1.0):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale
    pol = _moment_policy(cfg)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m_f = oc.decompress(m) if cfg.compress_moments else m
        v_f = oc.decompress(v) if cfg.compress_moments else v
        # v is a variance: block quantization error within the bound can
        # push small entries below zero, which sqrt would turn into NaN
        v_f = jnp.maximum(v_f, 0.0)
        m_new = b1 * m_f + (1 - b1) * g
        v_new = b2 * v_f + (1 - b2) * (g * g)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        if cfg.compress_moments:
            m_new = oc.compress(m_new, pol)
            v_new = oc.compress_nonneg(v_new, pol)
        return p_new, m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_params, new_state, {"grad_norm": gnorm}
