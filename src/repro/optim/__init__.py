from .adamw import AdamWConfig, init_state, update
from .schedule import warmup_cosine

__all__ = ["AdamWConfig", "init_state", "update", "warmup_cosine"]
