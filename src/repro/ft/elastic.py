"""Elastic scaling: restore any checkpoint onto any surviving device set.

Because checkpoints are mesh-agnostic (full host arrays per leaf), elastic
restart is: pick the best mesh for the survivors -> rebuild plan/specs ->
device_put each leaf with its new NamedSharding.  Data-pipeline determinism
(repro/data) makes the restart bit-reproducible modulo DP-width-dependent
reduction order.

``restore_resharded`` goes one step further for LARGE lossy leaves: the
checkpoint's chunked v2/v4 containers are random-access along the leading
axis (``core.chunking.parse_chunked_index`` / ``decompress_chunk``), so a
device that owns rows ``[r0, r1)`` of a leaf under the NEW mesh decodes only
the chunks overlapping that row range instead of materializing the whole
leaf on every host.  On a changed mesh this turns restore I/O per host from
O(leaf) into O(shard) for the optimizer moments and feedback — the leaves
that dominate checkpoint bytes.
"""
from __future__ import annotations

import dataclasses
import json
import math
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from ..core.chunking import ChunkedIndex, decompress_chunk, parse_chunked_index
from ..models.common import ModelConfig
from ..parallel.plan import ParallelPlan


def best_mesh_shape(n_devices: int, prefer_model: int = 16) -> Tuple[int, int]:
    """Largest (data, model) grid using <= n_devices, model as close to
    ``prefer_model`` as divisibility allows (TP axis prefers powers of two)."""
    best = (1, 1)
    m = prefer_model
    while m >= 1:
        d = n_devices // m
        if d >= 1 and d * m > best[0] * best[1]:
            best = (d, m)
        m //= 2
    return best


def make_elastic_mesh(devices=None, prefer_model: int = 16):
    devices = devices if devices is not None else jax.devices()
    d, m = best_mesh_shape(len(devices), prefer_model)
    n = d * m
    import numpy as np

    arr = np.asarray(devices[:n]).reshape(d, m)
    return jax.sharding.Mesh(arr, ("data", "model"))


def replan(cfg: ModelConfig, old_plan: ParallelPlan, mesh) -> ParallelPlan:
    """Carry the old policy onto a new mesh (drop axes the mesh lost)."""
    axes = set(mesh.shape)
    batch_axes = tuple(a for a in old_plan.batch_axes if a in axes) or ("data",)
    fsdp_axes = tuple(a for a in old_plan.fsdp_axes if a in axes)
    seq_axes = tuple(a for a in old_plan.seq_axes if a in axes)
    import dataclasses

    return dataclasses.replace(
        old_plan,
        mesh=mesh,
        batch_axes=batch_axes,
        fsdp_axes=fsdp_axes,
        seq_axes=seq_axes,
    )


def reshard_state(host_state, spec_tree, mesh):
    """device_put every leaf with its (new-mesh) sharding."""

    def put(leaf, spec):
        s = spec if isinstance(spec, PartitionSpec) else PartitionSpec()
        return jax.device_put(leaf, NamedSharding(mesh, s))

    return jax.tree.map(
        put, host_state, spec_tree,
        is_leaf=lambda x: not isinstance(x, (dict, list, tuple)),
    )


# ---------------------------------------------------------------------------
# chunk-range restore: decode only the chunks a shard needs
# ---------------------------------------------------------------------------

#: codecs whose blobs are v2/v4 multi-chunk containers (random-access rows)
_CHUNKED_CODECS = ("sz3_auto_rel", "sz3_chunked_rel", "sz3_psnr")


@dataclasses.dataclass
class LeafFetch:
    """Byte accounting for one leaf's resharded restore."""

    mode: str  # "chunk-range" | "full"
    bytes_read: int  # container bytes actually decoded
    bytes_full: int  # what a full-leaf decode would have read


@dataclasses.dataclass
class ReshardReport:
    step: int
    leaves: Dict[str, LeafFetch] = dataclasses.field(default_factory=dict)

    @property
    def bytes_read(self) -> int:
        return sum(f.bytes_read for f in self.leaves.values())

    @property
    def bytes_full(self) -> int:
        return sum(f.bytes_full for f in self.leaves.values())

    def summary(self) -> str:
        n_rng = sum(1 for f in self.leaves.values() if f.mode == "chunk-range")
        return (
            f"reshard restore step {self.step}: {n_rng}/{len(self.leaves)} "
            f"leaves by chunk range, {self.bytes_read}/{self.bytes_full} "
            "container bytes decoded"
        )


class ChunkRangeReader:
    """Row-range reads over one chunked container, decoded chunks memoized.

    Chunk ``i`` covers rows ``[row_starts[i], row_starts[i+1])`` of the
    leaf's leading axis (the checkpoint writer chunks ``leaf.reshape(
    shape[0], -1)`` along axis 0).  Replicated mesh axes re-request the same
    rows from several devices; the memo makes those free.
    """

    def __init__(self, blob: bytes, index: Optional[ChunkedIndex] = None):
        self.blob = blob
        self.index = index or parse_chunked_index(blob)
        self._decoded: Dict[int, np.ndarray] = {}
        self.bytes_read = self.index.body_off  # header always parsed
        starts = [0]
        for c in self.index.header["chunks"]:
            starts.append(starts[-1] + int(c["n0"]))
        self.row_starts = starts

    @property
    def n_rows(self) -> int:
        return self.row_starts[-1]

    def _chunk(self, i: int) -> np.ndarray:
        if i not in self._decoded:
            self._decoded[i] = np.asarray(
                decompress_chunk(self.blob, i, parsed=self.index)
            )
            self.bytes_read += self.index.bounds[i][1]
        return self._decoded[i]

    def rows(self, r0: int, r1: int) -> np.ndarray:
        """Rows ``[r0, r1)`` of the stored flat2d array."""
        if not 0 <= r0 <= r1 <= self.n_rows:
            raise IndexError(f"rows [{r0}, {r1}) outside [0, {self.n_rows})")
        parts = []
        for i in range(len(self.index.bounds)):
            c0, c1 = self.row_starts[i], self.row_starts[i + 1]
            if c1 <= r0 or c0 >= r1:
                continue
            part = self._chunk(i)
            part2d = part.reshape(part.shape[0] if part.ndim else part.size, -1)
            parts.append(part2d[max(r0 - c0, 0) : r1 - c0])
        return np.concatenate(parts, axis=0) if parts else np.empty((0, 1))


def _axis0_only(spec: PartitionSpec, ndim: int) -> bool:
    """True when the spec shards (at most) the leading dim."""
    entries = tuple(spec)
    return all(e is None for e in entries[1:])


def restore_leaf_resharded(
    blob: bytes,
    meta: Dict[str, Any],
    sharding: NamedSharding,
) -> Tuple[jax.Array, LeafFetch]:
    """Build a sharded jax.Array for one checkpoint leaf, decoding only the
    chunks each addressable shard overlaps when the container allows it."""
    shape = tuple(meta["shape"])
    dtype = np.dtype(meta["dtype"])
    spec = sharding.spec
    if (
        meta.get("codec") in _CHUNKED_CODECS
        and len(shape) >= 1
        and _axis0_only(spec, len(shape))
    ):
        try:
            reader = ChunkRangeReader(blob)
        except Exception:
            reader = None
        if reader is not None and reader.n_rows == (shape[0] if shape else 1):
            inner = shape[1:]

            def fetch(idx) -> np.ndarray:
                sl = idx[0] if idx else slice(None)
                r0, r1, _ = sl.indices(shape[0])
                rows = reader.rows(r0, r1)
                return rows.reshape((r1 - r0,) + inner).astype(dtype)

            arr = jax.make_array_from_callback(shape, sharding, fetch)
            return arr, LeafFetch("chunk-range", reader.bytes_read, len(blob))
    # fallback: decode the full leaf, device_put with the new sharding
    from .checkpoint import decode_leaf

    host = decode_leaf(blob, meta)
    return jax.device_put(host, sharding), LeafFetch("full", len(blob), len(blob))


def restore_resharded(
    mgr,
    template,
    spec_tree,
    mesh,
    step: Optional[int] = None,
) -> Tuple[Any, Dict[str, Any], ReshardReport]:
    """Restore checkpoint ``step`` from ``mgr`` directly onto ``mesh``.

    ``template`` fixes the pytree structure (``jax.eval_shape`` output is
    fine); ``spec_tree`` gives each leaf's PartitionSpec on the NEW mesh
    (missing/non-spec entries mean replicated).  Large lossy leaves restore
    by chunk range — each host decodes only the rows its devices own —
    everything else takes the decode-then-device_put path.  Returns
    ``(state, extra, ReshardReport)``.
    """
    from .checkpoint import _path_str

    steps = mgr.list_steps()
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {mgr.dir}")
    step = steps[-1] if step is None else step
    d = Path(mgr.dir) / f"step_{step}"
    manifest = json.loads((d / "manifest.json").read_text())
    leaves = manifest["leaves"]

    flat_spec = {
        _path_str(p): s
        for p, s in jax.tree_util.tree_flatten_with_path(
            spec_tree, is_leaf=lambda x: isinstance(x, PartitionSpec)
        )[0]
    }
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    report = ReshardReport(step=int(step))
    out = []
    for path, leaf in flat:
        pstr = _path_str(path)
        if pstr not in leaves:
            raise KeyError(f"leaf {pstr} missing from checkpoint {step}")
        meta = leaves[pstr]
        blob = (d / meta["file"]).read_bytes()
        spec = flat_spec.get(pstr)
        if not isinstance(spec, PartitionSpec):
            spec = PartitionSpec()
        arr, fetch = restore_leaf_resharded(
            blob, meta, NamedSharding(mesh, spec)
        )
        report.leaves[pstr] = fetch
        out.append(arr)
    state = jax.tree_util.tree_unflatten(treedef, out)
    return state, manifest.get("extra", {}), report


def validate_divisibility(cfg: ModelConfig, plan: ParallelPlan) -> Dict[str, bool]:
    """Pre-flight checks before committing to a new mesh size."""
    tp = plan.tp
    checks = {
        "d_ff % tp": cfg.d_ff % tp == 0 if cfg.d_ff else True,
        "padded_vocab % tp": cfg.padded_vocab % tp == 0,
        "d_model % fsdp": True,
    }
    for a in plan.fsdp_axes:
        checks["d_model % fsdp"] &= cfg.d_model % plan.axis_size(a) == 0
    return checks
