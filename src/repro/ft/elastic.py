"""Elastic scaling: restore any checkpoint onto any surviving device set.

Because checkpoints are mesh-agnostic (full host arrays per leaf), elastic
restart is: pick the best mesh for the survivors -> rebuild plan/specs ->
device_put each leaf with its new NamedSharding.  Data-pipeline determinism
(repro/data) makes the restart bit-reproducible modulo DP-width-dependent
reduction order.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec

from ..models.common import ModelConfig
from ..parallel.plan import ParallelPlan


def best_mesh_shape(n_devices: int, prefer_model: int = 16) -> Tuple[int, int]:
    """Largest (data, model) grid using <= n_devices, model as close to
    ``prefer_model`` as divisibility allows (TP axis prefers powers of two)."""
    best = (1, 1)
    m = prefer_model
    while m >= 1:
        d = n_devices // m
        if d >= 1 and d * m > best[0] * best[1]:
            best = (d, m)
        m //= 2
    return best


def make_elastic_mesh(devices=None, prefer_model: int = 16):
    devices = devices if devices is not None else jax.devices()
    d, m = best_mesh_shape(len(devices), prefer_model)
    n = d * m
    import numpy as np

    arr = np.asarray(devices[:n]).reshape(d, m)
    return jax.sharding.Mesh(arr, ("data", "model"))


def replan(cfg: ModelConfig, old_plan: ParallelPlan, mesh) -> ParallelPlan:
    """Carry the old policy onto a new mesh (drop axes the mesh lost)."""
    axes = set(mesh.shape)
    batch_axes = tuple(a for a in old_plan.batch_axes if a in axes) or ("data",)
    fsdp_axes = tuple(a for a in old_plan.fsdp_axes if a in axes)
    seq_axes = tuple(a for a in old_plan.seq_axes if a in axes)
    import dataclasses

    return dataclasses.replace(
        old_plan,
        mesh=mesh,
        batch_axes=batch_axes,
        fsdp_axes=fsdp_axes,
        seq_axes=seq_axes,
    )


def reshard_state(host_state, spec_tree, mesh):
    """device_put every leaf with its (new-mesh) sharding."""

    def put(leaf, spec):
        s = spec if isinstance(spec, PartitionSpec) else PartitionSpec()
        return jax.device_put(leaf, NamedSharding(mesh, s))

    return jax.tree.map(
        put, host_state, spec_tree,
        is_leaf=lambda x: not isinstance(x, (dict, list, tuple)),
    )


def validate_divisibility(cfg: ModelConfig, plan: ParallelPlan) -> Dict[str, bool]:
    """Pre-flight checks before committing to a new mesh size."""
    tp = plan.tp
    checks = {
        "d_ff % tp": cfg.d_ff % tp == 0 if cfg.d_ff else True,
        "padded_vocab % tp": cfg.padded_vocab % tp == 0,
        "d_model % fsdp": True,
    }
    for a in plan.fsdp_axes:
        checks["d_model % fsdp"] &= cfg.d_model % plan.axis_size(a) == 0
    return checks
