"""SZ3-compressed, atomic, async checkpointing (deliverable: fault tolerance).

Integration of the paper's pipelines at the checkpoint boundary:

  * bf16/int parameters   -> lossless: byte-shuffle (BLOSC-style, §3.2
    "Lossless Compressor" instances) + zstd.
  * f32 optimizer moments -> error-bounded lossy: dual-quant Lorenzo pipeline
    with a value-range-relative bound (default 1e-4) — moments tolerate
    bounded error (validated by tests/test_ft.py convergence checks).
  * arbitrary per-path policy overrides (the composability thesis: choosing a
    pipeline per tensor is a config change, paper §3.3).

Durability: manifest + one blob per leaf written to a temp dir, fsync'd, then
atomically renamed to ``step_<n>``; a crash mid-save never corrupts the
previous checkpoint.  Saves run on a background thread (async=True) double-
buffered against training.  Restore targets ANY mesh: leaves are materialized
on host and re-device_put with the new sharding (ft/elastic.py).
"""
from __future__ import annotations

import concurrent.futures as cf
import dataclasses
import hashlib
import json
import os
import shutil
import threading
import time
import zlib
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from ..core import (
    ChunkedCompressor,
    CompressionConfig,
    ErrorBoundMode,
    QualityCompressor,
    decompress as sz3_decompress,
    integrity,
    sz3_lorenzo,
    telemetry,
)
from ..core.integrity import IntegrityError, decode_errors
from ..core.lossless import Zstd, make as make_lossless

# leaves at/above this size go through the chunked engine (bounded working
# memory per chunk + per-chunk pipeline selection) instead of one-shot Lorenzo
_CHUNKED_MIN_BYTES = 1 << 22

# chunk workers for large lossy leaves: saves run on a background thread
# already, so stay modest — half the cores, at least 1
_CHUNK_WORKERS = max(1, (os.cpu_count() or 2) // 2)


# ---------------------------------------------------------------------------
# per-leaf codecs
# ---------------------------------------------------------------------------

def _byteshuffle(raw: bytes, itemsize: int) -> bytes:
    a = np.frombuffer(raw, np.uint8)
    n = a.size - (a.size % itemsize)
    if n == 0 or itemsize == 1:
        return raw
    body = a[:n].reshape(-1, itemsize).T.copy().tobytes()
    return body + a[n:].tobytes()


def _byteunshuffle(raw: bytes, itemsize: int, nbytes: int) -> bytes:
    n = nbytes - (nbytes % itemsize)
    a = np.frombuffer(raw[: n], np.uint8)
    body = a.reshape(itemsize, -1).T.copy().tobytes()
    return body + raw[n:]


@dataclasses.dataclass(frozen=True)
class LeafPolicy:
    mode: str = "lossless"  # "lossless" | "lossy" | "psnr" | "raw"
    rel_eb: float = 1e-4  # for lossy
    target_psnr: float = 60.0  # for psnr: quality-targeted rate control —
    # the leaf is stored at whatever error bound the closed-loop controller
    # finds to hit the PSNR floor, instead of a hand-picked eb


@dataclasses.dataclass(frozen=True)
class CheckpointPolicy:
    """Path-keyed policies; first substring match wins."""

    rules: Tuple[Tuple[str, LeafPolicy], ...] = (
        ("opt/m", LeafPolicy("lossy", 1e-4)),
        ("opt/v", LeafPolicy("lossy", 1e-4)),
        ("feedback", LeafPolicy("lossy", 1e-4)),
        ("", LeafPolicy("lossless")),
    )

    def for_path(self, path: str) -> LeafPolicy:
        for pat, pol in self.rules:
            if pat in path:
                return pol
        return LeafPolicy("lossless")


_zstd = Zstd(level=3)


def encode_leaf(
    arr: np.ndarray, pol: LeafPolicy, workers: Optional[int] = None
) -> Tuple[bytes, Dict[str, Any]]:
    meta: Dict[str, Any] = {
        "shape": list(arr.shape),
        "dtype": arr.dtype.str,
        "mode": pol.mode,
    }
    if (
        pol.mode in ("lossy", "psnr")
        and arr.dtype in (np.float32, np.float64)
        and arr.size >= 1024
        and np.isfinite(arr).all()
        and float(arr.max() - arr.min()) > 0
    ):
        flat2d = arr.reshape(arr.shape[0], -1) if arr.ndim > 1 else arr
        if pol.mode == "psnr":
            # quality-targeted: the controller finds the bound per chunk;
            # big leaves parallelize exactly like the chunked path
            comp = QualityCompressor(
                target_psnr=pol.target_psnr,
                workers=(_CHUNK_WORKERS if workers is None else workers)
                if arr.nbytes >= _CHUNKED_MIN_BYTES
                else 1,
            )
            meta["codec"] = "sz3_psnr"
            res = comp.compress(np.ascontiguousarray(flat2d))
            meta["achieved_psnr"] = float(res.meta["quality"]["achieved_psnr"])
            return res.blob, meta
        conf = CompressionConfig(mode=ErrorBoundMode.REL, eb=pol.rel_eb)
        if arr.nbytes >= _CHUNKED_MIN_BYTES:
            # every coder family contests per chunk: optimizer moments are
            # usually Lorenzo-friendly, attention-derived leaves can be
            # oscillatory along the feature axis (transform wins those),
            # leaves mixing regimes — embedding tables with hot/cold rows,
            # moments with dead blocks — go to the block-hybrid engine, and
            # near-constant slabs (zero-init moments) let the fixed-length
            # fast tier win on its constant-block path
            comp = ChunkedCompressor(
                candidates=(
                    "sz3_lorenzo",
                    "sz3_lr",
                    "sz3_transform",
                    "sz3_hybrid",
                    "sz3_fast",
                ),
                workers=_CHUNK_WORKERS if workers is None else workers,
            )
            meta["codec"] = "sz3_auto_rel"
        else:
            comp = sz3_lorenzo()
            meta["codec"] = "sz3_lorenzo_rel"
        res = comp.compress(np.ascontiguousarray(flat2d), conf)
        return res.blob, meta
    if pol.mode == "raw":
        meta["codec"] = "raw"
        return arr.tobytes(), meta
    raw = _byteshuffle(arr.tobytes(), arr.dtype.itemsize)
    # record the ACTUAL backend (the Zstd class degrades to 'gzip' when
    # zstandard is missing) so restore picks the right decompressor anywhere
    meta["codec"] = f"shuffle_{_zstd.name}"
    return _zstd.compress(raw), meta


def decode_leaf(blob: bytes, meta: Dict[str, Any]) -> np.ndarray:
    shape = tuple(meta["shape"])
    dtype = np.dtype(meta["dtype"])
    codec = meta["codec"]
    if codec in ("sz3_lorenzo_rel", "sz3_chunked_rel", "sz3_auto_rel", "sz3_psnr"):
        # all are self-describing SZ3 containers (v1 / v2 multi-chunk / v3)
        arr = sz3_decompress(blob)
        return arr.reshape(shape).astype(dtype)
    if codec == "raw":
        return np.frombuffer(blob, dtype).reshape(shape).copy()
    nbytes = int(np.prod(shape)) * dtype.itemsize if shape else dtype.itemsize
    lname = codec.split("_", 1)[1] if codec.startswith("shuffle_") else "zstd"
    backend = _zstd if lname == _zstd.name else make_lossless(lname)
    raw = _byteunshuffle(backend.decompress(blob), dtype.itemsize, nbytes)
    return np.frombuffer(raw, dtype, count=int(np.prod(shape)) if shape else 1).reshape(shape).copy()


# ---------------------------------------------------------------------------
# manager
# ---------------------------------------------------------------------------

def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


class CheckpointManager:
    def __init__(
        self,
        directory: str,
        policy: CheckpointPolicy = CheckpointPolicy(),
        keep: int = 3,
        use_async: bool = True,
        workers: Optional[int] = None,
    ):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.policy = policy
        self.keep = keep
        self.workers = workers  # chunk workers for large lossy leaves
        self._pool = cf.ThreadPoolExecutor(max_workers=1) if use_async else None
        self._pending: Optional[cf.Future] = None
        self._lock = threading.Lock()

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state, extra: Optional[Dict[str, Any]] = None):
        """Snapshot to host, then (optionally async) compress + atomic write."""
        host_state = jax.tree.map(lambda x: np.asarray(x), state)
        if self._pool is None:
            self._write(step, host_state, extra)
            return None
        self.wait()
        self._pending = self._pool.submit(self._write, step, host_state, extra)
        return self._pending

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _write(self, step: int, host_state, extra):
        tmp = self.dir / f".tmp_step_{step}"
        final = self.dir / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        leaves = {}
        flat, treedef = jax.tree_util.tree_flatten_with_path(host_state)
        total_in = total_out = 0
        for path, leaf in flat:
            pstr = _path_str(path)
            pol = self.policy.for_path(pstr)
            arr = np.asarray(leaf)
            t_leaf = time.perf_counter()
            with telemetry.span("leaf", path=pstr, bytes=arr.nbytes):
                blob, meta = encode_leaf(arr, pol, workers=self.workers)
            d_leaf = time.perf_counter() - t_leaf
            # per-leaf observability: which codec won, what it cost, what it
            # bought — queryable from the manifest long after the run
            meta["seconds"] = round(d_leaf, 6)
            meta["ratio"] = round(arr.nbytes / max(1, len(blob)), 4)
            telemetry.metric_observe("sz3_checkpoint_leaf_seconds", d_leaf)
            telemetry.observe("checkpoint_leaf_seconds", d_leaf)
            fname = hashlib.sha1(pstr.encode()).hexdigest()[:16] + ".bin"
            (tmp / fname).write_bytes(blob)
            meta["file"] = fname
            meta["crc"] = zlib.crc32(blob)  # kept for pre-integrity readers
            # algorithm-tagged per-leaf checksum (CRC32C when available) —
            # the manifest-side twin of the container trailer, covering raw
            # and lossless leaves that carry no SZ3J framing
            meta["csum"] = {
                "a": integrity.CHECKSUM_ALGO,
                "v": integrity.checksum(blob),
            }
            leaves[pstr] = meta
            total_in += arr.nbytes
            total_out += len(blob)
        manifest = {
            "step": step,
            "leaves": leaves,
            "treedef": jax.tree_util.tree_structure(host_state).serialize_using_proto().hex()
            if hasattr(jax.tree_util.tree_structure(host_state), "serialize_using_proto")
            else None,
            "bytes_in": total_in,
            "bytes_out": total_out,
            "ratio": total_in / max(1, total_out),
            "extra": extra or {},
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
        telemetry.metric_count("sz3_checkpoint_saves_total")
        telemetry.metric_count("sz3_checkpoint_bytes_out_total", total_out)
        # fsync the directory entries before rename (durability)
        for f in tmp.iterdir():
            fd = os.open(f, os.O_RDONLY)
            os.fsync(fd)
            os.close(fd)
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        return manifest

    def _gc(self):
        steps = sorted(self.list_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def list_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            try:
                out.append(int(p.name.split("_")[1]))
            except ValueError:
                pass
        return sorted(out)

    def restore(
        self,
        template,
        step: Optional[int] = None,
        *,
        salvage: bool = False,
        io_retries: int = 3,
        io_backoff: float = 0.05,
    ):
        """Restore into the structure of ``template`` (host numpy leaves).

        ``template`` supplies the pytree structure (e.g. from
        jax.eval_shape(init_fn)); leaves are validated against the manifest
        and their per-leaf checksums.  Returns ``(state, extra)``.

        ``salvage=True`` turns a corrupt leaf from a restore-killing error
        into a local loss: damaged / missing / shape-mismatched leaves are
        REFILLED from the template's own values (zeros when the template
        leaf is shape-only, e.g. ``jax.eval_shape`` output) and the call
        returns ``(state, extra, RestoreReport)`` naming what was refilled —
        the training loop decides whether a warm restart from N-1 leaves
        beats losing the checkpoint entirely.

        Transient I/O errors (``OSError`` other than a missing file) are
        retried ``io_retries`` times with exponential backoff starting at
        ``io_backoff`` seconds — NFS blips and overloaded object stores
        should not look like corruption."""
        steps = self.list_steps()
        if not steps:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        step = steps[-1] if step is None else step
        d = self.dir / f"step_{step}"
        manifest = json.loads(
            self._read_retry(d / "manifest.json", io_retries, io_backoff).decode()
        )
        leaves = manifest["leaves"]
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        out = []
        report = RestoreReport(step=int(step))
        for path, leaf in flat:
            pstr = _path_str(path)
            try:
                arr = self._restore_leaf(
                    d, leaves, pstr, step, leaf, io_retries, io_backoff
                )
            except FileNotFoundError:
                if not salvage:
                    raise
                arr, reason = None, "missing"
            except (KeyError, LookupError):
                if not salvage:
                    raise
                arr, reason = None, "missing"
            except (IntegrityError, IOError) as e:
                if not salvage:
                    raise
                arr, reason = None, "checksum"
            except ValueError:
                if not salvage:
                    raise
                arr, reason = None, "decode-error"
            if arr is None:
                arr = _template_fill(leaf)
                report.refilled.append((pstr, reason))
            else:
                report.restored.append(pstr)
            out.append(arr)
        state = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(template), out
        )
        extra = manifest.get("extra", {})
        if salvage:
            return state, extra, report
        return state, extra

    def _restore_leaf(
        self, d: Path, leaves, pstr: str, step, leaf, io_retries, io_backoff
    ) -> np.ndarray:
        if pstr not in leaves:
            raise KeyError(f"leaf {pstr} missing from checkpoint {step}")
        meta = leaves[pstr]
        blob = self._read_retry(d / meta["file"], io_retries, io_backoff)
        csum = meta.get("csum")
        if csum is not None:
            if integrity.checksum(blob, algo=csum["a"]) != csum["v"]:
                raise IntegrityError(
                    f"leaf {pstr} fails its {csum['a']} checksum — corrupt "
                    "checkpoint"
                )
        elif zlib.crc32(blob) != meta["crc"]:  # pre-integrity manifests
            raise IOError(f"checksum mismatch for {pstr} — corrupt checkpoint")
        with decode_errors(f"checkpoint leaf {pstr}"):
            arr = decode_leaf(blob, meta)
        want_shape = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != want_shape:
            raise ValueError(
                f"{pstr}: checkpoint shape {arr.shape} != expected {want_shape}"
            )
        return arr

    @staticmethod
    def _read_retry(path: Path, retries: int, backoff: float) -> bytes:
        """Read with bounded retry-with-backoff on transient I/O errors.
        A missing file is NOT transient (the checkpoint layout is immutable
        once renamed into place) and raises immediately."""
        attempt = 0
        while True:
            try:
                return path.read_bytes()
            except FileNotFoundError:
                raise
            except OSError:
                if attempt >= retries:
                    raise
                time.sleep(backoff * (2**attempt))
                attempt += 1


@dataclasses.dataclass
class RestoreReport:
    """What a ``salvage=True`` restore recovered vs refilled."""

    step: int
    restored: List[str] = dataclasses.field(default_factory=list)
    refilled: List[Tuple[str, str]] = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.refilled

    def summary(self) -> str:
        if self.ok:
            return f"restore step {self.step}: all {len(self.restored)} leaves"
        lost = ", ".join(f"{p} ({r})" for p, r in self.refilled)
        return (
            f"restore step {self.step}: {len(self.restored)} leaves restored, "
            f"{len(self.refilled)} refilled from template: {lost}"
        )


def _template_fill(leaf) -> np.ndarray:
    """A replacement value for a leaf the checkpoint could not supply: the
    template's own value when it carries one, zeros when it is shape-only
    (``jax.eval_shape`` / ``ShapeDtypeStruct`` templates)."""
    if hasattr(leaf, "__array__"):
        return np.asarray(leaf)
    return np.zeros(
        tuple(getattr(leaf, "shape", ())), np.dtype(getattr(leaf, "dtype", "f4"))
    )
